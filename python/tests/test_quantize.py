"""Quantization front-end tests: calibration, BN folding, end-to-end
float-vs-int8 layer error."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import quantize as Q
from compile.kernels import ref

settings.register_profile("ci2", max_examples=25, deadline=None)
settings.load_profile("ci2")


def test_scale_exp_basics():
    assert Q.scale_exp(1.0) == 7  # [-1,1] -> Q0.7
    assert Q.scale_exp(127.0) == 0
    assert Q.scale_exp(0.0) == 7
    assert Q.scale_exp(0.5) == 8


@given(st.floats(0.01, 100.0))
def test_quantize_fits_int8(max_abs):
    e = Q.scale_exp(max_abs)
    v = np.linspace(-max_abs, max_abs, 101)
    q = Q.quantize_tensor(v, e)
    assert q.dtype == np.int8
    assert np.abs(q).max() <= 127


@given(st.integers(0, 2**31 - 1))
def test_round_trip_error_is_bounded(seed):
    rng = np.random.default_rng(seed)
    v = rng.normal(0, 1.0, 256)
    e = Q.scale_exp(float(np.abs(v).max()))
    assert Q.quant_error(v, e) < 0.05, "8-bit symmetric quantization error"


def test_fold_batchnorm_is_equivalent():
    rng = np.random.default_rng(0)
    w = rng.normal(0, 0.1, (3, 3, 4, 8))
    b = rng.normal(0, 0.1, 8)
    gamma = rng.uniform(0.5, 1.5, 8)
    beta = rng.normal(0, 0.1, 8)
    mean = rng.normal(0, 0.1, 8)
    var = rng.uniform(0.5, 1.5, 8)
    x = rng.normal(0, 1, (6, 6, 4))

    # float reference: conv -> BN
    import jax.lax as lax

    y = lax.conv_general_dilated(
        jnp.asarray(x)[None], jnp.asarray(w), (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )[0] + b
    bn = (np.asarray(y) - mean) / np.sqrt(var + 1e-3) * gamma + beta

    wf, bf = Q.fold_batchnorm(w, b, gamma, beta, mean, var)
    y2 = lax.conv_general_dilated(
        jnp.asarray(x)[None], jnp.asarray(wf), (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )[0] + bf
    np.testing.assert_allclose(np.asarray(y2), bn, rtol=1e-6, atol=1e-6)


def test_quantized_layer_tracks_float_layer():
    """int8 conv with calibrated shifts stays within a few percent of the
    float computation — the 'CNN is tolerant to errors' premise (§III-A)."""
    rng = np.random.default_rng(1)
    w = rng.normal(0, 0.2, (3, 3, 8, 16))
    b = rng.normal(0, 0.2, 16)
    x = rng.normal(0, 1.0, (8, 8, 8))

    in_exp = Q.calibrate_activation(x)
    x_q = Q.quantize_tensor(x, in_exp)

    # float reference output and its exponent
    import jax.lax as lax

    y = np.asarray(
        lax.conv_general_dilated(
            jnp.asarray(x)[None], jnp.asarray(w), (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )[0]
    ) + b
    out_exp = Q.calibrate_activation(y)

    w_q, b_q, shift = Q.quantize_layer(w, b, in_exp, out_exp)
    assert shift >= 0
    y_q = ref.conv2d_int8_ref(jnp.asarray(x_q), jnp.asarray(w_q), jnp.asarray(b_q), shift, 1)
    y_hat = Q.dequantize(np.asarray(y_q), out_exp)

    rms = np.sqrt(np.mean((y_hat - y) ** 2)) / (np.sqrt(np.mean(y**2)) + 1e-12)
    assert rms < 0.08, f"quantized layer error {rms:.3f}"


def test_bias_scaling_matches_accumulator_domain():
    w = np.ones((1, 1, 1, 1)) * 0.5
    b = np.ones(1) * 0.25
    w_q, b_q, shift = Q.quantize_layer(w, b, in_exp=7, out_exp=7)
    # w_exp = 8 (max 0.5), total = 15, bias 0.25*2^15 = 8192
    assert b_q[0] == 8192
    assert shift == 8
