"""L2 correctness: quantized model semantics + TinyNet-SE golden paths."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels import ref


def test_pallas_and_ref_paths_agree():
    """TinyNet on the Pallas kernels == TinyNet on the jnp references."""
    params = model.gen_params(1234)
    x = jnp.asarray(model.gen_input())
    jp = {k: {kk: (jnp.asarray(v) if v is not None else None) for kk, v in p.items()} for k, p in params.items()}
    a = model.tinynet(x, jp, use_pallas=True)
    b = model.tinynet(x, jp, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_tinynet_deterministic():
    params = model.gen_params(1234)
    fn = model.tinynet_jit(params)
    x = jnp.asarray(model.gen_input())
    (a,) = fn(x)
    (b,) = fn(x)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert a.dtype == jnp.int8 and a.shape == (10,)


def test_gen_params_deterministic():
    p1 = model.gen_params(42)
    p2 = model.gen_params(42)
    np.testing.assert_array_equal(p1["stem"]["w"], p2["stem"]["w"])
    p3 = model.gen_params(43)
    assert (p1["stem"]["w"] != p3["stem"]["w"]).any()


def test_lut_generation_matches_formula():
    lut = model.make_lut(model.sigmoid_f, model.ACT_EXP, 7)
    assert lut.shape == (256,)
    # q = 0 -> sigmoid(0) = 0.5 -> 64 in Q0.7
    assert lut[0] == 64
    # large positive q -> ~1.0 -> clamps to 127
    assert lut[127] == 127
    # index 128 is q = -128 -> sigmoid(-8) ~ 0
    assert lut[128] == 0


def test_qmaxpool_matches_manual():
    x = jnp.asarray(np.arange(16, dtype=np.int8).reshape(4, 4, 1))
    out = np.asarray(model.qmaxpool(x, 2, 2))
    np.testing.assert_array_equal(out.reshape(2, 2), [[5, 7], [13, 15]])


def test_qgap_rounds_half_away():
    x = jnp.asarray(np.array([[[1], [2]], [[3], [5]]], dtype=np.int8))
    assert int(model.qgap(x)[0]) == 3  # 11/4 = 2.75 -> 3
    xn = jnp.asarray(np.array([[[-1], [-2]], [[-3], [-5]]], dtype=np.int8))
    assert int(model.qgap(xn)[0]) == -3


def test_qadd_saturates():
    a = jnp.asarray(np.array([100], dtype=np.int8))
    assert int(model.qadd(a, a, 0)[0]) == 127
    assert int(model.qadd(a, a, 1)[0]) == 100


def test_qleaky_arithmetic_shift():
    x = jnp.asarray(np.array([-64, -1, 5], dtype=np.int8))
    np.testing.assert_array_equal(np.asarray(model.qleaky(x)), [-8, -1, 5])


def test_qlut_unsigned_indexing():
    lut = np.zeros(256, dtype=np.int8)
    lut[5] = 50
    lut[251] = -50
    x = jnp.asarray(np.array([5, -5], dtype=np.int8))
    np.testing.assert_array_equal(np.asarray(model.qlut(x, jnp.asarray(lut))), [50, -50])


def test_qscale_gate_broadcast():
    x = jnp.asarray(np.full((2, 2, 3), 64, dtype=np.int8))
    gate = jnp.asarray(np.array([127, 64, 0], dtype=np.int8))  # ~1.0, 0.5, 0 in Q0.7
    out = np.asarray(model.qscale(x, gate, 7))
    assert (out[:, :, 0] == 64).all()  # 64*127/128 = 63.5 -> 64 (round)
    assert (out[:, :, 1] == 32).all()
    assert (out[:, :, 2] == 0).all()


def test_shortcut_contributes():
    """Zeroed res1/b weights make the residual pass the shortcut through
    (matches the rust funcsim test of the same name)."""
    params = model.gen_params(1234)
    params["res1/b"]["w"] = np.zeros_like(params["res1/b"]["w"])
    params["res1/b"]["b"] = np.zeros_like(params["res1/b"]["b"])
    params["res1/b"]["elt_shift"] = 0
    x = jnp.asarray(model.gen_input())
    jp = {k: {kk: (jnp.asarray(v) if v is not None else None) for kk, v in p.items()} for k, p in params.items()}
    # run the prefix manually
    stem = model.qrelu(model.qconv(x, jp["stem"]))
    pool = model.qmaxpool(stem)
    r1a = model.qrelu(model.qconv(pool, jp["res1/a"]))
    r1b = model.qconv(r1a, jp["res1/b"])
    r1 = model.qrelu(model.qadd(r1b, pool, 0))
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(model.qrelu(pool)))
