"""AOT export regression tests."""

import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model
from compile.aot import export_params_json, to_hlo_text


def test_hlo_text_does_not_elide_constants():
    """Regression: the deployment XLA text parser silently reads elided
    `constant({...})` literals as garbage — exports must print them."""
    w = jnp.asarray(np.arange(2048, dtype=np.int8).reshape(-1) % 100)

    def f(v):
        return (w + v.reshape(-1)[:1].astype(jnp.int8) * 0,)

    lowered = jax.jit(f).lower(jax.ShapeDtypeStruct((4, 4, 4), jnp.int8))
    hlo = to_hlo_text(lowered)
    assert "constant({...})" not in hlo, "large constants were elided"


def test_export_params_layout_is_hwio():
    """Weight flattening must match funcsim's ((ky*k+kx)*cin+ic)*cout+oc."""
    w = np.arange(2 * 2 * 3 * 4, dtype=np.int8).reshape(2, 2, 3, 4)
    params = {"g": {"w": w, "b": np.zeros(4, np.int32), "shift": 7, "lut": None, "elt_shift": 0}}
    doc = json.loads(export_params_json(params))
    flat = doc["groups"]["g"]["weights"]
    k, cin, cout = 2, 3, 4
    for ky in range(k):
        for kx in range(k):
            for ic in range(cin):
                for oc in range(cout):
                    assert flat[((ky * k + kx) * cin + ic) * cout + oc] == int(w[ky, kx, ic, oc])


def test_params_json_includes_luts_and_shifts():
    params = model.gen_params(1234)
    doc = json.loads(export_params_json(params))
    g = doc["groups"]
    assert "lut" in g["mb1/expand"] and len(g["mb1/expand"]["lut"]) == 256
    assert g["res1/b"]["elt_shift"] == 1
    assert "weights" not in g["mb1/se/scale"]  # scale has no weights
    assert g["mb1/se/scale"]["shift"] == 7


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/tinynet.hlo.txt")),
    reason="artifacts not built",
)
def test_artifacts_consistent_with_model():
    """The exported expectation must match a fresh forward pass."""
    root = os.path.join(os.path.dirname(__file__), "../../artifacts")
    with open(os.path.join(root, "tinynet_expected.json")) as f:
        expected = json.load(f)["logits"]
    with open(os.path.join(root, "tinynet_input.json")) as f:
        x = np.asarray(json.load(f)["data"], dtype=np.int8).reshape(model.TINY_INPUT)
    fn = model.tinynet_jit(model.gen_params(1234))
    (logits,) = fn(jnp.asarray(x))
    assert [int(v) for v in np.asarray(logits)] == expected
