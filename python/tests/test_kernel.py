"""L1 correctness: Pallas kernels vs the pure-jnp oracles.

Hypothesis sweeps shapes/strides/kernel sizes; every case must match the
reference bit-exactly (integer arithmetic — no tolerance)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv2d_int8, dwconv2d_int8, matmul_int8
from compile.kernels import ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def rng_for(seed):
    return np.random.default_rng(seed)


@given(
    m=st.integers(1, 130),
    k=st.integers(1, 130),
    n=st.integers(1, 130),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    rng = rng_for(seed)
    x = rng.integers(-128, 128, (m, k), dtype=np.int8)
    w = rng.integers(-128, 128, (k, n), dtype=np.int8)
    got = matmul_int8(jnp.asarray(x), jnp.asarray(w))
    want = ref.matmul_int8_ref(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(
    h=st.integers(3, 20),
    w=st.integers(3, 20),
    cin=st.integers(1, 9),
    cout=st.integers(1, 9),
    k=st.sampled_from([1, 3, 5]),
    stride=st.sampled_from([1, 2]),
    shift=st.integers(0, 10),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv_matches_ref(h, w, cin, cout, k, stride, shift, seed):
    rng = rng_for(seed)
    x = rng.integers(-128, 128, (h, w, cin), dtype=np.int8)
    wt = rng.integers(-16, 16, (k, k, cin, cout), dtype=np.int8)
    b = rng.integers(-1000, 1000, (cout,), dtype=np.int32)
    got = conv2d_int8(jnp.asarray(x), jnp.asarray(wt), jnp.asarray(b), shift, stride)
    want = ref.conv2d_int8_ref(jnp.asarray(x), jnp.asarray(wt), jnp.asarray(b), shift, stride)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(
    h=st.integers(3, 16),
    w=st.integers(3, 16),
    c=st.integers(1, 70),
    k=st.sampled_from([1, 3, 5, 7]),
    stride=st.sampled_from([1, 2]),
    shift=st.integers(0, 10),
    seed=st.integers(0, 2**31 - 1),
)
def test_dwconv_matches_ref(h, w, c, k, stride, shift, seed):
    rng = rng_for(seed)
    x = rng.integers(-128, 128, (h, w, c), dtype=np.int8)
    wt = rng.integers(-16, 16, (k, k, c), dtype=np.int8)
    b = rng.integers(-1000, 1000, (c,), dtype=np.int32)
    got = dwconv2d_int8(jnp.asarray(x), jnp.asarray(wt), jnp.asarray(b), shift, stride)
    want = ref.dwconv2d_int8_ref(jnp.asarray(x), jnp.asarray(wt), jnp.asarray(b), shift, stride)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_matmul_exact_tile_boundary():
    """64/128 boundaries exercise the un-padded fast path."""
    rng = rng_for(7)
    for m, k, n in [(64, 64, 64), (128, 64, 128), (64, 128, 64)]:
        x = rng.integers(-128, 128, (m, k), dtype=np.int8)
        w = rng.integers(-128, 128, (k, n), dtype=np.int8)
        got = matmul_int8(jnp.asarray(x), jnp.asarray(w))
        want = ref.matmul_int8_ref(jnp.asarray(x), jnp.asarray(w))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_conv_accumulator_no_overflow_at_worst_case():
    """Worst-case int8 conv accumulation stays within int32."""
    # 5x5 kernel, 64 channels, all extremes: |acc| <= 25*64*128*128 < 2^31
    x = np.full((8, 8, 64), -128, dtype=np.int8)
    w = np.full((5, 5, 64, 4), -128, dtype=np.int8)
    b = np.zeros(4, dtype=np.int32)
    got = conv2d_int8(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), 0, 1)
    want = ref.conv2d_int8_ref(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), 0, 1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert np.asarray(got).max() == 127  # saturated as expected


def test_round_shift_semantics():
    acc = jnp.asarray([7, 5, 6, -5, 3], dtype=jnp.int32)
    assert list(np.asarray(ref.round_shift(acc, 2))) == [2, 1, 2, -1, 1]
    assert list(np.asarray(ref.round_shift(acc, 0))) == [7, 5, 6, -5, 3]
    assert list(np.asarray(ref.round_shift(acc, -1))) == [14, 10, 12, -10, 6]
