"""Quantization front-end: float model → dynamic-fixed-point integers.

The paper's CNN parser "extract[s] the quantized parameters" from the
frozen model (§III-A, 8-bit non-zero quantization with per-layer dynamic
fixed-point). This module implements that step for the golden-model
pipeline: power-of-two scale calibration from weight/activation ranges,
batch-norm folding, bias quantization and requant-shift derivation.

Scheme (symmetric, power-of-two — exactly representable by the
accelerator's shift-based requantizer):

* activations: ``x ≈ q_x · 2^-e_x`` with ``e_x = 7 - ceil(log2(max|x|))``
* weights:     ``w ≈ q_w · 2^-e_w`` likewise
* conv:        ``acc = Σ q_w q_x ≈ (Σ w x) · 2^(e_w+e_x)``; the int32
  bias is pre-scaled by ``2^(e_w+e_x)``; the output shift is
  ``s = e_w + e_x - e_y`` (always ≥ 0 when ranges are sane).
"""

import numpy as np


def scale_exp(max_abs: float, bits: int = 8) -> int:
    """Power-of-two exponent e such that values fit int8: q = v * 2^e."""
    if max_abs <= 0:
        return bits - 1
    return int(bits - 1 - np.ceil(np.log2(max_abs)))


def quantize_tensor(v, e: int):
    """Symmetric int8 quantization at exponent ``e``."""
    q = np.round(np.asarray(v, dtype=np.float64) * (1 << e) if e >= 0 else np.asarray(v) / (1 << -e))
    return np.clip(q, -127, 127).astype(np.int8)


def dequantize(q, e: int):
    return np.asarray(q, dtype=np.float64) / (1 << e) if e >= 0 else np.asarray(q, dtype=np.float64) * (1 << -e)


def fold_batchnorm(w, b, gamma, beta, mean, var, eps=1e-3):
    """Fold BN(scale/shift) into conv weights+bias (HWIO weights)."""
    w = np.asarray(w, dtype=np.float64)
    std = np.sqrt(np.asarray(var, dtype=np.float64) + eps)
    g = np.asarray(gamma, dtype=np.float64) / std
    wf = w * g  # broadcast over the trailing (out-channel) axis
    bf = (np.asarray(b, dtype=np.float64) - np.asarray(mean)) * g + np.asarray(beta)
    return wf, bf


def quantize_layer(w, b, in_exp: int, out_exp: int):
    """Quantize one conv/fc layer given input/output activation exponents.

    Returns ``(w_i8, b_i32, shift)`` such that
    ``clamp(round_shift(Σ w_i8·x_i8 + b_i32, shift))`` approximates the
    float layer at the output exponent."""
    w = np.asarray(w, dtype=np.float64)
    w_exp = scale_exp(float(np.abs(w).max()))
    w_q = quantize_tensor(w, w_exp)
    total = w_exp + in_exp
    b_q = np.clip(np.round(np.asarray(b, dtype=np.float64) * (1 << total) if total >= 0
                           else np.asarray(b) / (1 << -total)),
                  -(2 ** 31), 2 ** 31 - 1).astype(np.int32)
    shift = total - out_exp
    return w_q, b_q, int(shift)


def calibrate_activation(samples) -> int:
    """Activation exponent from observed samples (max-abs calibration —
    adequate for the power-of-two scheme; percentile calibration is a
    drop-in replacement)."""
    return scale_exp(float(np.max(np.abs(samples))))


def quant_error(v, e: int) -> float:
    """RMS relative quantization error at exponent e (diagnostics)."""
    v = np.asarray(v, dtype=np.float64)
    err = dequantize(quantize_tensor(v, e), e) - v
    denom = np.sqrt(np.mean(v**2)) + 1e-12
    return float(np.sqrt(np.mean(err**2)) / denom)
