"""Layer-2: the quantized golden model (JAX, build-time only).

Defines the accelerator's datapath semantics as composable quantized ops
and builds **TinyNet-SE** — the same network, with the same node names,
as ``rust/src/zoo/tinynet.rs``. The e2e test executes the AOT-exported
HLO through the rust PJRT runtime and compares it bit-exactly against
the rust functional simulator, closing the hardware-verification loop of
Fig. 4 ("unified software reference code for hardware verification").

Integer semantics are documented in ``rust/src/funcsim/mod.rs``; this
file must stay in lock-step with it.
"""

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from .kernels import conv2d_int8, dwconv2d_int8
from .kernels import ref

# ---------------------------------------------------------------------------
# quantized ops (int8 activations, int32 accumulators)
# ---------------------------------------------------------------------------


def qconv(x, p, stride=1, use_pallas=True):
    """Conv + bias + requant. ``p = {"w": i8[k,k,ci,co], "b": i32, "shift"}``."""
    f = conv2d_int8 if use_pallas else ref.conv2d_int8_ref
    return f(x, p["w"], p["b"], int(p["shift"]), stride)


def qdwconv(x, p, stride=1, use_pallas=True):
    f = dwconv2d_int8 if use_pallas else ref.dwconv2d_int8_ref
    return f(x, p["w"], p["b"], int(p["shift"]), stride)


def qfc(v, p):
    """FC over a 1×1×C vector: ``w: i8[ci,co]``."""
    acc = jnp.dot(v.astype(jnp.int32), p["w"].astype(jnp.int32)) + p["b"].astype(jnp.int32)
    return ref.clamp_i8(ref.round_shift(acc, int(p["shift"])))


def qrelu(x):
    return jnp.maximum(x, 0)


def qleaky(x):
    """Hardware leaky: negatives arithmetic-shifted right by 3."""
    return jnp.where(x < 0, x >> 3, x)


def qlut(x, lut):
    """8-bit LUT activation: index = unsigned reinterpretation of int8."""
    idx = x.view(jnp.uint8).astype(jnp.int32)
    return jnp.take(lut, idx)


def qadd(a, b, elt_shift=0):
    acc = a.astype(jnp.int32) + b.astype(jnp.int32)
    return ref.clamp_i8(ref.round_shift(acc, int(elt_shift)))


def qscale(x, gate, shift):
    """SE channel scale (1×1 depthwise-like multiply)."""
    acc = x.astype(jnp.int32) * gate.astype(jnp.int32)[None, None, :]
    return ref.clamp_i8(ref.round_shift(acc, int(shift)))


def qmaxpool(x, k=2, s=2):
    """SAME max-pool; border windows padded with -128 (= clipped)."""
    return lax.reduce_window(
        x, jnp.int8(-128), lax.max, (k, k, 1), (s, s, 1), "SAME"
    )


def qgap(x):
    """Global average pool with round-half-away-from-zero division."""
    n = x.shape[0] * x.shape[1]
    acc = jnp.sum(x.astype(jnp.int32), axis=(0, 1))
    return ref.clamp_i8(_div_round(acc, n))


def _div_round(a, n: int):
    pos = (a + n // 2) // n
    neg = -((-a + n // 2) // n)
    return jnp.where(a >= 0, pos, neg)


def qupsample(x, f=2):
    return jnp.repeat(jnp.repeat(x, f, axis=0), f, axis=1)


def qconcat(a, b):
    return jnp.concatenate([a, b], axis=-1)


# ---------------------------------------------------------------------------
# LUT generation (build-time float math; shipped as integers)
# ---------------------------------------------------------------------------


def make_lut(fn, in_exp: int, out_exp: int):
    """256-entry int8 LUT for ``fn`` at dynamic-fixed-point scales
    ``x = q / 2^in_exp``, ``q' = round(f(x) · 2^out_exp)``.

    Index order is the unsigned reinterpretation of the int8 code (0..127,
    then -128..-1) — matching ``funcsim::ops::lut_act``."""
    codes = np.arange(256)
    q = np.where(codes < 128, codes, codes - 256).astype(np.float64)
    x = q / (1 << in_exp)
    y = fn(x)
    return np.clip(np.round(y * (1 << out_exp)), -128, 127).astype(np.int8)


def swish_f(x):
    return x / (1.0 + np.exp(-x))


def sigmoid_f(x):
    return 1.0 / (1.0 + np.exp(-x))


# ---------------------------------------------------------------------------
# TinyNet-SE (keep in lock-step with rust/src/zoo/tinynet.rs)
# ---------------------------------------------------------------------------

TINY_INPUT = (16, 16, 8)

# activation scale exponent used by the LUTs
ACT_EXP = 4


def gen_params(seed: int = 1234):
    """Deterministic quantized parameters for TinyNet-SE.

    Returns ``{group_main_name: {"w","b","shift","lut","elt_shift"}}`` with
    numpy arrays; keys are the rust group main-node names."""
    rng = np.random.default_rng(seed)

    def conv_p(k, ci, co, lut=None, elt_shift=0):
        return {
            "w": rng.integers(-7, 8, (k, k, ci, co), dtype=np.int8),
            "b": rng.integers(-64, 64, (co,), dtype=np.int32),
            "shift": 7,
            "lut": lut,
            "elt_shift": elt_shift,
        }

    def dw_p(k, c, lut=None):
        return {
            "w": rng.integers(-7, 8, (k, k, c), dtype=np.int8),
            "b": rng.integers(-64, 64, (c,), dtype=np.int32),
            "shift": 6,
            "lut": lut,
            "elt_shift": 0,
        }

    def fc_p(ci, co, lut=None):
        return {
            "w": rng.integers(-7, 8, (ci, co), dtype=np.int8),
            "b": rng.integers(-64, 64, (co,), dtype=np.int32),
            "shift": 5,
            "lut": lut,
            "elt_shift": 0,
        }

    swish_lut = make_lut(swish_f, ACT_EXP, ACT_EXP)
    sigmoid_lut = make_lut(sigmoid_f, ACT_EXP, 7)  # gate in Q0.7

    return {
        "stem": conv_p(3, 8, 16),
        "res1/a": conv_p(3, 16, 16),
        # res1/b carries the fused shortcut add (relu applied after)
        "res1/b": conv_p(3, 16, 16, elt_shift=1),
        "mb1/expand": conv_p(1, 16, 32, lut=swish_lut),
        "mb1/dw": dw_p(3, 32, lut=swish_lut),
        "mb1/se/reduce": fc_p(32, 8, lut=swish_lut),
        "mb1/se/expand": fc_p(8, 32, lut=sigmoid_lut),
        # SE scale: x·gate with gate in Q0.7 → shift 7 restores the scale
        "mb1/se/scale": {"w": None, "b": None, "shift": 7, "lut": None, "elt_shift": 0},
        "mb1/project": conv_p(1, 32, 16, elt_shift=1),
        "down": conv_p(3, 16, 24),
        "head": conv_p(1, 40, 16),
        "fc": fc_p(16, 10),
    }


def tinynet(x, params, use_pallas=True):
    """Forward pass; mirrors the rust graph node-for-node."""
    p = params

    stem = qrelu(qconv(x, p["stem"], 1, use_pallas))
    pool = qmaxpool(stem, 2, 2)

    r1a = qrelu(qconv(pool, p["res1/a"], 1, use_pallas))
    r1b = qconv(r1a, p["res1/b"], 1, use_pallas)
    r1 = qrelu(qadd(r1b, pool, p["res1/b"]["elt_shift"]))

    exp = qlut(qconv(r1, p["mb1/expand"], 1, use_pallas), p["mb1/expand"]["lut"])
    dw = qlut(qdwconv(exp, p["mb1/dw"], 1, use_pallas), p["mb1/dw"]["lut"])
    sq = qgap(dw)
    se_r = qlut(qfc(sq, p["mb1/se/reduce"]), p["mb1/se/reduce"]["lut"])
    se_e = qlut(qfc(se_r, p["mb1/se/expand"]), p["mb1/se/expand"]["lut"])
    se = qscale(dw, se_e, p["mb1/se/scale"]["shift"])
    proj = qconv(se, p["mb1/project"], 1, use_pallas)
    mb1 = qadd(proj, r1, p["mb1/project"]["elt_shift"])

    down = qrelu(qconv(mb1, p["down"], 2, use_pallas))
    up = qupsample(down, 2)
    cat = qconcat(mb1, up)

    head = qrelu(qconv(cat, p["head"], 1, use_pallas))
    g = qgap(head)
    return qfc(g, p["fc"])


def tinynet_jit(params, use_pallas=True):
    """jit-compiled closure over constant (baked-in) parameters."""
    jp = jax.tree_util.tree_map(
        lambda a: jnp.asarray(a) if a is not None else None, params
    )
    return jax.jit(lambda x: (tinynet(x, jp, use_pallas),))


def gen_input(seed: int = 99):
    rng = np.random.default_rng(seed)
    return rng.integers(-128, 128, TINY_INPUT, dtype=np.int8)
