"""Int8 convolution kernels (Pallas).

Arithmetic contract — keep in sync with ``rust/src/funcsim`` (the e2e
test enforces bit-exactness):

* accumulate in int32 (operands widened *before* the dot so the lowered
  HLO uses s32 dots — the image's XLA 0.5.1 CPU runtime predates s8 dot
  support);
* ``round_shift(acc, s) = (acc + (1 << (s-1))) >> s`` for ``s > 0``
  (arithmetic shift), ``acc << -s`` otherwise;
* saturate to ``[-128, 127]``.

The matmul tiling is the hardware mapping: ``TILE_M×TILE_K`` activation
and ``TILE_K×TILE_N`` weight blocks live in VMEM (the analogue of the
row/weight buffers), the int32 accumulator tile is the psum buffer
(eq. 4), and the grid's K-loop is the input-channel tiling of the MAC
array (Ti), with N the output-kernel parallelism (To).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Ti/To/K tiles — mirror the accelerator's Ti = To = 64.
TILE_M = 64
TILE_N = 64
TILE_K = 64


def _round_shift(acc, shift: int):
    """Round-to-nearest arithmetic shift (ties toward +inf), int32."""
    if shift > 0:
        return (acc + (1 << (shift - 1))) >> shift
    return acc << (-shift)


def _clamp_i8(v):
    return jnp.clip(v, -128, 127).astype(jnp.int8)


def _matmul_kernel(x_ref, w_ref, o_ref):
    """Tiled int32-accumulate matmul.

    The output tile doubles as the accumulator (psum buffer): the grid's
    innermost K dimension revisits the same (M, N) block, so `o_ref`
    persists across K steps — the standard Pallas reduction pattern.
    """

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.int32)
    w = w_ref[...].astype(jnp.int32)
    o_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.int32)


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=())
def matmul_int8(x, w):
    """``x:int8[M,K] @ w:int8[K,N] -> int32[M,N]`` via the Pallas kernel.

    Inputs are zero-padded to tile multiples; the result is sliced back.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims {k} != {k2}"
    xp = _pad_to(_pad_to(x, TILE_M, 0), TILE_K, 1)
    wp = _pad_to(_pad_to(w, TILE_K, 0), TILE_N, 1)
    mp, kp = xp.shape
    np_ = wp.shape[1]
    k_steps = kp // TILE_K
    grid = (mp // TILE_M, np_ // TILE_N, k_steps)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_M, TILE_K), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((TILE_K, TILE_N), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((TILE_M, TILE_N), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        interpret=True,
    )(xp, wp)
    return out[:m, :n]


def same_pads(size: int, k: int, s: int):
    """TF SAME padding (low, high) for one spatial dim."""
    out = -(-size // s)
    total = max((out - 1) * s + k - size, 0)
    lo = total // 2
    return lo, total - lo


def extract_patches(x, k: int, s: int):
    """im2col: ``x:[H,W,C] -> [OH*OW, k*k*C]`` with (ky, kx, c) ordering —
    exactly the rust funcsim / HWIO weight flattening order."""
    h, w, c = x.shape
    oh, ow = -(-h // s), -(-w // s)
    (pt, pb), (pl_, pr) = same_pads(h, k, s), same_pads(w, k, s)
    xp = jnp.pad(x, ((pt, pb), (pl_, pr), (0, 0)))
    cols = []
    for ky in range(k):
        for kx in range(k):
            cols.append(xp[ky : ky + oh * s : s, kx : kx + ow * s : s, :])
    return jnp.concatenate(cols, axis=-1).reshape(oh * ow, k * k * c), (oh, ow)


def conv2d_int8(x, w, b, shift: int, stride: int = 1):
    """SAME conv: ``x:[H,W,Cin] i8``, ``w:[k,k,Cin,Cout] i8``,
    ``b:[Cout] i32`` → int8 ``[OH,OW,Cout]``."""
    k = w.shape[0]
    cout = w.shape[3]
    patches, (oh, ow) = extract_patches(x, k, stride)
    acc = matmul_int8(patches, w.reshape(-1, cout))
    acc = acc + b[None, :].astype(jnp.int32)
    return _clamp_i8(_round_shift(acc, shift)).reshape(oh, ow, cout)


def _dwconv_kernel(taps_ref, w_ref, b_ref, o_ref, *, shift: int):
    """Depthwise unit: per-channel weighted tap sum (single-mult mode,
    Fig. 7b), bias + requant fused at the writeback like the datapath."""
    taps = taps_ref[...].astype(jnp.int32)  # [kk, BH, W, BC]
    w = w_ref[...].astype(jnp.int32)  # [kk, BC]
    acc = jnp.einsum("khwc,kc->hwc", taps, w).astype(jnp.int32)
    acc = acc + b_ref[...][None, None, :].astype(jnp.int32)
    if shift > 0:
        acc = (acc + (1 << (shift - 1))) >> shift
    else:
        acc = acc << (-shift)
    o_ref[...] = jnp.clip(acc, -128, 127).astype(jnp.int8)


def dwconv2d_int8(x, w, b, shift: int, stride: int = 1):
    """SAME depthwise conv: ``x:[H,W,C] i8``, ``w:[k,k,C] i8``,
    ``b:[C] i32`` → int8 ``[OH,OW,C]`` (channels tiled over the grid)."""
    h, wdim, c = x.shape
    k = w.shape[0]
    oh, ow = -(-h // stride), -(-wdim // stride)
    (pt, pb), (pl_, pr) = same_pads(h, k, stride), same_pads(wdim, k, stride)
    xp = jnp.pad(x, ((pt, pb), (pl_, pr), (0, 0)))
    taps = jnp.stack(
        [
            xp[ky : ky + oh * stride : stride, kx : kx + ow * stride : stride, :]
            for ky in range(k)
            for kx in range(k)
        ],
        axis=0,
    )  # [k*k, OH, OW, C]

    bc = min(TILE_N, c) if c % min(TILE_N, c) == 0 else c
    tapsp = _pad_to(taps, bc, 3)
    wp = _pad_to(w.reshape(k * k, c), bc, 1)
    bp = _pad_to(b, bc, 0)
    cp = tapsp.shape[3]
    grid = (cp // bc,)
    out = pl.pallas_call(
        functools.partial(_dwconv_kernel, shift=shift),
        grid=grid,
        in_specs=[
            pl.BlockSpec((k * k, oh, ow, bc), lambda j: (0, 0, 0, j)),
            pl.BlockSpec((k * k, bc), lambda j: (0, j)),
            pl.BlockSpec((bc,), lambda j: (j,)),
        ],
        out_specs=pl.BlockSpec((oh, ow, bc), lambda j: (0, 0, j)),
        out_shape=jax.ShapeDtypeStruct((oh, ow, cp), jnp.int8),
        interpret=True,
    )(tapsp, wp, bp)
    return out[:, :, :c]
