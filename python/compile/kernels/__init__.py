"""Layer-1 Pallas kernels for the quantized datapath.

The kernels mirror the paper's shared-MAC-array structure (Ti = To = 64
tiles) re-expressed for a TPU-like memory hierarchy: operands are staged
into VMEM blocks via ``BlockSpec`` and accumulated in int32, the MXU
analogue of the DSP48E2 double-INT8 accumulate. ``interpret=True``
everywhere — the CPU PJRT client cannot execute Mosaic custom-calls
(see DESIGN.md §Hardware-Adaptation).
"""

from .conv_int8 import matmul_int8, conv2d_int8, dwconv2d_int8, TILE_M, TILE_N, TILE_K
from . import ref

__all__ = [
    "matmul_int8",
    "conv2d_int8",
    "dwconv2d_int8",
    "ref",
    "TILE_M",
    "TILE_N",
    "TILE_K",
]
