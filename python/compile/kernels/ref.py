"""Pure-jnp oracles for the Pallas kernels (no pallas_call anywhere).

These are the correctness references: pytest checks every kernel against
them across shape/stride sweeps (hypothesis), and the quantized model can
be built on either path (``use_pallas=False``) to localize bugs.
"""

import jax.numpy as jnp


def round_shift(acc, shift: int):
    """(acc + 2^(s-1)) >> s for s > 0; arithmetic shift; << for s <= 0."""
    if shift > 0:
        return (acc + (1 << (shift - 1))) >> shift
    return acc << (-shift)


def clamp_i8(v):
    return jnp.clip(v, -128, 127).astype(jnp.int8)


def same_pads(size: int, k: int, s: int):
    out = -(-size // s)
    total = max((out - 1) * s + k - size, 0)
    lo = total // 2
    return lo, total - lo


def matmul_int8_ref(x, w):
    """int8 @ int8 -> int32 (widen first — s8 dots don't exist in the
    deployment XLA)."""
    return jnp.dot(x.astype(jnp.int32), w.astype(jnp.int32))


def conv2d_int8_ref(x, w, b, shift: int, stride: int = 1):
    """SAME conv via explicit patch extraction + int32 matmul."""
    h, wd, c = x.shape
    k, _, cin, cout = w.shape
    assert cin == c
    oh, ow = -(-h // stride), -(-wd // stride)
    (pt, pb), (pl_, pr) = same_pads(h, k, stride), same_pads(wd, k, stride)
    xp = jnp.pad(x, ((pt, pb), (pl_, pr), (0, 0)))
    cols = []
    for ky in range(k):
        for kx in range(k):
            cols.append(xp[ky : ky + oh * stride : stride, kx : kx + ow * stride : stride, :])
    patches = jnp.concatenate(cols, axis=-1).reshape(oh * ow, k * k * c)
    acc = matmul_int8_ref(patches, w.reshape(-1, cout)) + b[None, :].astype(jnp.int32)
    return clamp_i8(round_shift(acc, shift)).reshape(oh, ow, cout)


def dwconv2d_int8_ref(x, w, b, shift: int, stride: int = 1):
    """SAME depthwise conv, per-channel taps."""
    h, wd, c = x.shape
    k = w.shape[0]
    oh, ow = -(-h // stride), -(-wd // stride)
    (pt, pb), (pl_, pr) = same_pads(h, k, stride), same_pads(wd, k, stride)
    xp = jnp.pad(x, ((pt, pb), (pl_, pr), (0, 0)))
    acc = jnp.zeros((oh, ow, c), jnp.int32)
    for ky in range(k):
        for kx in range(k):
            tap = xp[ky : ky + oh * stride : stride, kx : kx + ow * stride : stride, :]
            acc = acc + tap.astype(jnp.int32) * w[ky, kx, :].astype(jnp.int32)[None, None, :]
    acc = acc + b[None, None, :].astype(jnp.int32)
    return clamp_i8(round_shift(acc, shift))
