"""AOT export: lower the golden model to HLO *text* + parameter JSON.

Run once at build time (``make artifacts``); python never executes at
inference time. The interchange format is HLO text, NOT a serialized
``HloModuleProto`` — jax ≥ 0.5 emits protos with 64-bit instruction ids
that the deployment XLA (xla_extension 0.5.1) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs (in ``artifacts/``):
  tinynet.hlo.txt        — golden TinyNet-SE (Pallas kernel path)
  tinynet_params.json    — quantized weights/biases/shifts/LUTs
  tinynet_input.json     — deterministic test input (int8)
  tinynet_expected.json  — logits computed at export time (sanity anchor)
  matmul64.hlo.txt       — bare Ti×To Pallas matmul (runtime smoke test)
"""

import argparse
import json
import os

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import matmul_int8


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is load-bearing: the default elides big
    # weight literals as `constant({...})`, which the deployment XLA's
    # text parser silently reads back as zeros/garbage.
    return comp.as_hlo_text(print_large_constants=True)


def export_params_json(params) -> str:
    """Serialize parameters with flattening that matches
    ``funcsim::params`` (HWIO / IO row-major)."""
    groups = {}
    for name, p in params.items():
        g = {}
        if p.get("w") is not None:
            g["weights"] = [int(v) for v in np.asarray(p["w"]).reshape(-1)]
        if p.get("b") is not None:
            g["bias"] = [int(v) for v in np.asarray(p["b"]).reshape(-1)]
        g["shift"] = int(p["shift"])
        if p.get("elt_shift"):
            g["elt_shift"] = int(p["elt_shift"])
        if p.get("lut") is not None:
            g["lut"] = [int(v) for v in np.asarray(p["lut"]).reshape(-1)]
        groups[name] = g
    return json.dumps({"groups": groups})


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=1234)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    params = model.gen_params(args.seed)
    x = model.gen_input()

    # --- golden TinyNet (Pallas kernels inside) -------------------------
    fn = model.tinynet_jit(params, use_pallas=True)
    lowered = fn.lower(jax.ShapeDtypeStruct(model.TINY_INPUT, jnp.int8))
    hlo = to_hlo_text(lowered)
    with open(os.path.join(args.out_dir, "tinynet.hlo.txt"), "w") as f:
        f.write(hlo)

    with open(os.path.join(args.out_dir, "tinynet_params.json"), "w") as f:
        f.write(export_params_json(params))

    with open(os.path.join(args.out_dir, "tinynet_input.json"), "w") as f:
        json.dump(
            {"shape": list(model.TINY_INPUT), "data": [int(v) for v in x.reshape(-1)]}, f
        )

    (logits,) = fn(jnp.asarray(x))
    with open(os.path.join(args.out_dir, "tinynet_expected.json"), "w") as f:
        json.dump({"logits": [int(v) for v in np.asarray(logits).reshape(-1)]}, f)

    # --- bare matmul kernel artifact (runtime smoke test) ----------------
    mm = jax.jit(lambda a, b: (matmul_int8(a, b),))
    spec = jax.ShapeDtypeStruct((64, 64), jnp.int8)
    mm_hlo = to_hlo_text(mm.lower(spec, spec))
    with open(os.path.join(args.out_dir, "matmul64.hlo.txt"), "w") as f:
        f.write(mm_hlo)

    print(
        f"wrote artifacts to {args.out_dir}: tinynet.hlo.txt ({len(hlo)} chars), "
        f"params/input/expected JSON, matmul64.hlo.txt ({len(mm_hlo)} chars)"
    )


if __name__ == "__main__":
    main()
