//! Ablation — the design choices behind the block-wise reuse switch:
//! naive fixed-row (weights ×H), proposed all-row (weights once),
//! all-frame, and the optimized block-wise switch, across the zoo; plus
//! the ASIC-style unified-buffer instantiation (§V-B).

use shortcutfusion::analyzer::analyze;
use shortcutfusion::baselines::fixed_reuse::{fixed_policy, naive_row_baseline};
use shortcutfusion::bench::{report_timing, time, Table};
use shortcutfusion::config::AccelConfig;
use shortcutfusion::isa::ReuseMode;
use shortcutfusion::optimizer::Optimizer;
use shortcutfusion::zoo;

fn main() {
    let cfg = AccelConfig::kcu1500_int8();
    let mut t = Table::new(
        "ablation — latency (ms) per reuse policy",
        &["model", "naive row (wxH)", "all-row", "all-frame", "block-wise opt", "opt vs naive"],
    );
    for name in [
        "vgg16-conv",
        "yolov2",
        "yolov3",
        "resnet50",
        "resnet152",
        "efficientnet-b1",
        "mobilenetv3-large",
    ] {
        let g = zoo::by_name(name, zoo::default_input(name)).unwrap();
        let gg = analyze(&g);
        let naive = naive_row_baseline(&gg, &cfg);
        let row = fixed_policy(&gg, &cfg, ReuseMode::Row);
        let frame = fixed_policy(&gg, &cfg, ReuseMode::Frame);
        let opt = Optimizer::new(&gg, &cfg);
        let best = opt.optimize();
        t.row(&[
            name.into(),
            format!("{:.2}", naive.latency_ms),
            format!("{:.2}", row.timing.latency_ms),
            format!("{:.2}", frame.timing.latency_ms),
            format!("{:.2}{}", best.latency_ms, if best.feasible { "" } else { "*" }),
            format!("x{:.2}", naive.latency_ms / best.latency_ms),
        ]);
    }
    t.print();
    println!("(* = infeasible under the SRAM budget; all-frame ignores feasibility)");

    // DRAM ablation
    let mut d = Table::new(
        "ablation — total DRAM (MB) per reuse policy",
        &["model", "all-row", "all-frame", "block-wise opt", "baseline-once"],
    );
    for name in ["yolov2", "resnet50", "efficientnet-b1"] {
        let g = zoo::by_name(name, zoo::default_input(name)).unwrap();
        let gg = analyze(&g);
        let row = fixed_policy(&gg, &cfg, ReuseMode::Row);
        let frame = fixed_policy(&gg, &cfg, ReuseMode::Frame);
        let opt = Optimizer::new(&gg, &cfg);
        let best = opt.optimize();
        d.row(&[
            name.into(),
            format!("{:.1}", row.dram.total as f64 / 1e6),
            format!("{:.1}", frame.dram.total as f64 / 1e6),
            format!("{:.1}", best.dram.total as f64 / 1e6),
            format!("{:.1}", best.dram.baseline_once as f64 / 1e6),
        ]);
    }
    d.print();

    // ASIC unified-buffer instantiation (§V-B)
    let asic = AccelConfig::from_toml_file(std::path::Path::new("configs/asic_unified.toml"))
        .unwrap_or_else(|_| {
            let mut c = AccelConfig::kcu1500_int8();
            c.name = "ASIC-unified".into();
            c.freq_mhz = 800.0;
            c.sram_budget = 24_000_000;
            c.bram18k_total = 16_000;
            c.dram_gbps = 25.6;
            c
        });
    let mut a = Table::new(
        "ASIC unified-buffer instantiation (§V-B) — same flow, bigger budget",
        &["model", "FPGA latency ms", "ASIC latency ms", "FPGA DRAM MB", "ASIC DRAM MB"],
    );
    for name in ["resnet152", "efficientnet-b1", "yolov3"] {
        let g = zoo::by_name(name, zoo::default_input(name)).unwrap();
        let gg = analyze(&g);
        let fpga = Optimizer::new(&gg, &cfg).optimize();
        let asic_best = Optimizer::new(&gg, &asic).optimize();
        a.row(&[
            name.into(),
            format!("{:.2}", fpga.latency_ms),
            format!("{:.2}", asic_best.latency_ms),
            format!("{:.1}", fpga.dram.total as f64 / 1e6),
            format!("{:.1}", asic_best.dram.total as f64 / 1e6),
        ]);
    }
    a.print();

    // multi-cut-point extension: EfficientDet-D0 (BiFPN x3 -> ~7 cuts)
    let g = zoo::efficientdet_d0(512);
    let gg = analyze(&g);
    let opt = Optimizer::new(&gg, &cfg);
    let best = opt.optimize();
    println!(
        "\nEfficientDet-D0 (BiFPN x3): {} segments (paper rule 2r+1 = 7), cuts {:?}, \
         latency {:.2} ms, feasible {}",
        opt.segs.len(),
        best.cuts.cuts,
        best.latency_ms,
        best.feasible
    );

    let g2 = zoo::resnet50(256);
    let gg2 = analyze(&g2);
    let opt2 = Optimizer::new(&gg2, &cfg);
    let timing = time(5, || opt2.optimize());
    report_timing("ablation optimize (resnet50@256)", &timing);
}
