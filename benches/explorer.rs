//! §Perf harness for the design-space explorer: one grid (2 models ×
//! 3 SRAM budgets × 4 strategies × 2 MAC arrays = 48 points) costed
//! serially, in parallel, and again on a warm session — the three
//! regimes that matter for sweep throughput.

use shortcutfusion::bench::{report_timing, time};
use shortcutfusion::compiler::Session;
use shortcutfusion::config::AccelConfig;
use shortcutfusion::explorer::SearchSpace;

fn space() -> SearchSpace {
    SearchSpace::new(AccelConfig::kcu1500_int8())
        .models(&["resnet18", "yolov2"])
        .input_sizes(&[64])
        .sram_budgets(&[1_000_000, 2_000_000, 8_000_000])
        .mac_arrays(&[(32, 32), (64, 64)])
        .ablation_strategies()
}

fn main() {
    let space = space();
    let n = space.enumerate().unwrap().points.len();
    let threads = std::thread::available_parallelism().map(|t| t.get()).unwrap_or(4);
    println!("explorer grid: {n} design points");

    // 1. cold serial sweep: a fresh session, one worker
    let t_serial = time(3, || {
        space.explore(&Session::new(), 1).unwrap().points.len()
    });
    report_timing("explorer sweep serial (1 thread)", &t_serial);

    // 2. cold parallel sweep: a fresh session, all workers
    let t_par = time(3, || {
        space.explore(&Session::new(), threads).unwrap().points.len()
    });
    report_timing(&format!("explorer sweep parallel ({threads} threads)"), &t_par);
    println!(
        "explorer sweep speedup: x{:.2} on {} threads",
        t_serial.median_ms / t_par.median_ms,
        threads
    );

    // 3. warm sweep: every point is a report-cache hit
    let warm = Session::new();
    let _ = space.explore(&warm, threads).unwrap();
    let t_warm = time(5, || space.explore(&warm, threads).unwrap().points.len());
    report_timing("explorer sweep warm (all cache hits)", &t_warm);
    let stats = warm.stats();
    println!(
        "warm session: {} report hits / {} misses, {} shared analyses",
        stats.report_hits, stats.report_misses, stats.analysis_hits
    );

    // 4. post-processing cost: Pareto extraction + recommendation
    let exploration = space.explore(&warm, threads).unwrap();
    let t_post = time(20, || {
        exploration
            .models()
            .iter()
            .map(|m| {
                let rec = exploration.recommend(m).is_some() as usize;
                exploration.pareto_front(m).len() + rec
            })
            .sum::<usize>()
    });
    report_timing("pareto front + recommend (48 points)", &t_post);
}
