//! Table VI — end-to-end FPGA frameworks on ResNet50 inference:
//! ML-Suite / FPL'19 / Cloud-DNN (published numbers) vs the proposed
//! flexible-reuse design.

use shortcutfusion::baselines::frameworks::TABLE6_FRAMEWORKS;
use shortcutfusion::bench::{report_timing, time, Table};
use shortcutfusion::config::AccelConfig;
use shortcutfusion::compiler::Compiler;
use shortcutfusion::zoo;

fn main() {
    let cfg = AccelConfig::kcu1500_int8();
    let graph = zoo::resnet50(256);
    let r = Compiler::new(cfg.clone()).compile(&graph).unwrap();

    let mut t = Table::new(
        "Table VI — end-to-end frameworks, ResNet50 inference",
        &[
            "framework",
            "platform",
            "input",
            "latency ms",
            "GOPS",
            "SRAM MB",
            "DSP eff %",
            "flex reuse",
            "shortcut HW",
        ],
    );
    for f in &TABLE6_FRAMEWORKS {
        t.row(&[
            f.name.into(),
            f.platform.into(),
            f.input.to_string(),
            format!("{:.2}", f.latency_ms),
            format!("{:.0}", f.gops),
            format!("{:.1}", f.sram_mb),
            format!("{:.2}", f.dsp_efficiency_pct),
            f.flexible_reuse.to_string(),
            f.shortcut_fusion_hw.to_string(),
        ]);
    }
    t.row(&[
        "proposed (measured)".into(),
        "KCU1500 (20nm, simulated)".into(),
        "256".into(),
        format!("{:.2}", r.latency_ms()),
        format!("{:.0}", r.gops()),
        format!("{:.1}", r.sram_mb()),
        format!("{:.2}", r.mac_efficiency_pct()),
        "true".into(),
        "true".into(),
    ]);
    t.print();

    let cloud = &TABLE6_FRAMEWORKS[2];
    let mls = &TABLE6_FRAMEWORKS[0];
    println!(
        "\nclaims: SRAM vs Cloud-DNN {:.1}x less (paper 7.4x); DSP efficiency vs ML-Suite \
         {:.1}x higher (paper 2.4x); SRAM vs ML-Suite {:.1}x less (paper 6.0x)",
        cloud.sram_mb / r.sram_mb(),
        r.mac_efficiency_pct() / mls.dsp_efficiency_pct,
        mls.sram_mb / r.sram_mb()
    );

    let timing = time(3, || Compiler::new(cfg.clone()).compile(&graph).unwrap());
    report_timing("table6 pipeline (resnet50@256)", &timing);
}
