//! Fig. 16 — YOLOv2 cut-point sweep: buffer size, DRAM access and
//! latency vs cut position, plus the headline claims: 2.17× speed-up and
//! 5.73× smaller buffer than the fixed row-based baseline, minimum SRAM
//! 0.76 MB.

use shortcutfusion::analyzer::analyze;
use shortcutfusion::baselines::fixed_reuse::naive_row_baseline;
use shortcutfusion::bench::{report_timing, time, Table};
use shortcutfusion::config::AccelConfig;
use shortcutfusion::optimizer::Optimizer;
use shortcutfusion::zoo;

fn main() {
    let cfg = AccelConfig::kcu1500_int8();
    let gg = analyze(&zoo::yolov2(416));
    let opt = Optimizer::new(&gg, &cfg);

    // --- Fig 16(a)/(b): the sweep series --------------------------------
    let sweep = opt.sweep_first_segment();
    let mut t = Table::new(
        "Fig 16 — YOLOv2@416 cut-point sweep (row-reuse before cut, frame-reuse after)",
        &["cut", "SRAM MB", "BRAM18K", "DRAM MB", "FM MB", "latency ms"],
    );
    for p in &sweep {
        t.row(&[
            p.cut.to_string(),
            format!("{:.3}", p.sram_mb),
            p.bram18k.to_string(),
            format!("{:.2}", p.dram_total_mb),
            format!("{:.2}", p.dram_fm_mb),
            format!("{:.3}", p.latency_ms),
        ]);
    }
    t.print();

    // --- headline numbers -------------------------------------------------
    let best = opt.optimize();
    let minbuf = opt.min_buffer();
    let baseline = naive_row_baseline(&gg, &cfg);

    let mut h = Table::new("Fig 16(c) — headline claims", &["metric", "paper", "measured"]);
    h.row(&[
        "min required SRAM (MB)".into(),
        "0.762".into(),
        format!("{:.3}", minbuf.sram.total as f64 / 1e6),
    ]);
    h.row(&[
        "speed-up vs fixed row-based".into(),
        "2.17x".into(),
        format!("{:.2}x", baseline.latency_ms / best.latency_ms),
    ]);
    h.row(&[
        "buffer reduction vs all-frame".into(),
        "5.73x".into(),
        format!(
            "{:.2}x",
            sweep.first().unwrap().sram_mb / (minbuf.sram.total as f64 / 1e6)
        ),
    ]);
    h.print();

    // --- harness timing ----------------------------------------------------
    let timing = time(5, || opt.optimize());
    report_timing("fig16_yolov2 full optimize", &timing);
}
