//! Multi-FPGA sharding sweep: device count × link bandwidth over zoo
//! models (small inputs keep the cut-point searches fast).
//!
//! For every (model, K, link GB/s) cell the partitioner runs its full
//! split search; the table reports the winning plan's single-image
//! latency, pipeline interval/throughput, total SRAM, how many splits
//! were evaluated, and the wall-clock of the search itself (warm rows
//! reuse nothing across cells — each plan() call is cold).
//!
//! Run: `cargo bench --bench sharding`

use std::time::Instant;

use shortcutfusion::bench::Table;
use shortcutfusion::config::AccelConfig;
use shortcutfusion::shard::{boundaries, LinkModel, Partitioner};
use shortcutfusion::zoo;

fn main() {
    let cfg = AccelConfig::kcu1500_int8();
    let models: &[(&str, usize)] = &[("tinynet", 16), ("resnet18", 64), ("vgg16-conv", 64)];
    let device_axis = [1usize, 2, 3];
    let gbps_axis = [4.0f64, 16.0, 64.0];

    let mut t = Table::new(
        "pipeline sharding: K x link bandwidth (KCU1500-int8 per device)",
        &[
            "model", "K", "GB/s", "latency ms", "interval ms", "fps", "SRAM MB", "splits",
            "search ms",
        ],
    );
    for &(name, input) in models {
        let graph = zoo::by_name(name, input).expect("zoo model");
        let cuts = boundaries(&graph).expect("valid graph").len();
        for &k in &device_axis {
            if cuts + 1 < k {
                println!("skip {name} at K={k}: only {cuts} cut-point boundaries");
                continue;
            }
            for &gbps in &gbps_axis {
                let link = LinkModel::new(gbps, 5.0).expect("link");
                let partitioner = Partitioner::homogeneous(cfg.clone(), k)
                    .expect("partitioner")
                    .with_link(link);
                let t0 = Instant::now();
                let plan = partitioner.plan(&graph).expect("plan");
                let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
                t.row(&[
                    name.to_string(),
                    k.to_string(),
                    format!("{gbps:.0}"),
                    format!("{:.3}", plan.latency_ms),
                    format!("{:.3}", plan.interval_ms),
                    format!("{:.1}", plan.throughput_fps()),
                    format!("{:.3}", plan.total_sram_bytes() as f64 / 1e6),
                    plan.splits_evaluated.to_string(),
                    format!("{wall_ms:.1}"),
                ]);
            }
        }
    }
    t.print();
}
