//! Table III — minimum required buffer size per CNN to meet the
//! eq.-(10) DRAM-access constraints (weights once, feature maps ≤ once).

use shortcutfusion::analyzer::analyze;
use shortcutfusion::bench::{report_timing, time, Table};
use shortcutfusion::config::AccelConfig;
use shortcutfusion::optimizer::Optimizer;
use shortcutfusion::zoo;

fn main() {
    let cfg = AccelConfig::kcu1500_int8();
    // (model, input, paper MB); ResNet row lists 50/152 at one figure.
    let rows: &[(&str, usize, f64)] = &[
        ("yolov2", 416, 0.762),
        ("vgg16-conv", 224, 0.712),
        ("yolov3", 416, 1.682),
        ("retinanet", 512, 2.392),
        ("resnet50", 224, 1.039),
        ("resnet152", 224, 1.039),
        ("efficientnet-b1", 256, 0.43),
    ];
    let mut t = Table::new(
        "Table III — minimum buffer size meeting the DRAM constraints",
        &["model", "input", "layers", "paper MB", "measured MB", "ratio"],
    );
    for &(name, input, paper) in rows {
        let graph = zoo::by_name(name, input).unwrap();
        let gg = analyze(&graph);
        let opt = Optimizer::new(&gg, &cfg);
        let e = opt.min_buffer();
        let mb = e.sram.total as f64 / 1e6;
        t.row(&[
            name.into(),
            input.to_string(),
            gg.graph.nodes.len().to_string(),
            format!("{paper:.3}"),
            format!("{mb:.3}"),
            format!("x{:.2}", mb / paper),
        ]);
    }
    t.print();

    let graph = zoo::efficientnet_b1(256);
    let gg = analyze(&graph);
    let opt = Optimizer::new(&gg, &cfg);
    let timing = time(3, || opt.min_buffer());
    report_timing("table3 min-buffer search (efficientnet-b1)", &timing);
}
