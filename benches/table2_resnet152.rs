//! Table II — ResNet152 (16-bit, 224×224) vs ShortcutMining (HPCA'19):
//! latency / GOPS / DSP efficiency / off-chip feature-map traffic under a
//! ShortcutMining-class BRAM budget.

use shortcutfusion::bench::{report_timing, time, Table};
use shortcutfusion::compiler::{Compiler, ShortcutMiningStrategy};
use shortcutfusion::config::AccelConfig;
use shortcutfusion::zoo;

fn main() {
    let cfg = AccelConfig::table2_int16();
    let graph = zoo::resnet152(224);
    let r = Compiler::new(cfg.clone()).compile(&graph).unwrap();

    // The HPCA'19 baseline runs through the same staged pipeline via its
    // ReuseStrategy port — one code path for both Table II columns.
    let sm = Compiler::with_strategy(cfg.clone(), std::sync::Arc::new(ShortcutMiningStrategy))
        .compile(&graph)
        .unwrap();
    let sm_fm = sm.offchip_fm_mb();
    let sm_w = sm.evaluation.dram.weight_bytes as f64 / 1e6;

    let mut t = Table::new(
        "Table II — ResNet152@224, 16-bit, ShortcutMining-class BRAM budget",
        &["metric", "HPCA'19 [8] (paper)", "proposed (paper)", "proposed (measured)"],
    );
    t.row(&[
        "CNN size (GOP)".into(),
        "22.63".into(),
        "23.86".into(),
        format!("{:.2}", graph.total_gop()),
    ]);
    t.row(&[
        "weights (MB)".into(),
        "112.6".into(),
        "112.6".into(),
        format!("{:.1}", graph.total_weight_bytes(cfg.qw as u64) as f64 / 1e6),
    ]);
    t.row(&[
        "latency (ms)".into(),
        "35.24".into(),
        "39.27".into(),
        format!("{:.2}", r.latency_ms()),
    ]);
    t.row(&[
        "throughput (GOPS)".into(),
        "608.3".into(),
        "607.5".into(),
        format!("{:.1}", r.gops()),
    ]);
    t.row(&[
        "DSP efficiency (%)".into(),
        "72.4".into(),
        "71.1".into(),
        format!("{:.1}", r.mac_efficiency_pct()),
    ]);
    t.row(&[
        "weight load".into(),
        "multiple times".into(),
        "once".into(),
        "once (by construction)".into(),
    ]);
    t.row(&[
        "off-chip FMs (MB)".into(),
        "62.93".into(),
        "11.97".into(),
        format!("{:.2}", r.offchip_fm_mb()),
    ]);
    t.print();

    let ours_fm = r.offchip_fm_mb();
    println!(
        "\nabstract claim: FM traffic reduction vs ShortcutMining = {:.2}x (paper 5.27x; \
         SM modelled at {:.1} MB FM + {:.1} MB weights)",
        sm_fm / ours_fm,
        sm_fm,
        sm_w
    );

    let timing = time(3, || Compiler::new(cfg.clone()).compile(&graph).unwrap());
    report_timing("table2 full pipeline (resnet152@224 int16)", &timing);
}
