//! §Perf harness: the L3 hot paths — funcsim convolution, the
//! optimizer's per-candidate evaluation, the multi-segment descent, and
//! the parallel compile `Session` vs the serial baseline.

use shortcutfusion::analyzer::analyze;
use shortcutfusion::bench::{report_timing, time};
use shortcutfusion::compiler::{Session, SweepJob};
use shortcutfusion::config::AccelConfig;
use shortcutfusion::funcsim::{Executor, Params, Tensor};
use shortcutfusion::graph::Shape;
use shortcutfusion::optimizer::Optimizer;
use shortcutfusion::testutil::Rng;
use shortcutfusion::zoo;

fn main() {
    let cfg = AccelConfig::kcu1500_int8();

    // 1. funcsim: efficientnet-b0@64 with random params
    let gg = analyze(&zoo::by_name("efficientnet-b0", 64).unwrap());
    let params = Params::random(&gg, 7);
    let mut rng = Rng::from_seed(8);
    let input = Tensor::from_vec(Shape::new(64, 64, 3), rng.i8_vec(64 * 64 * 3));
    let ex = Executor::new(&gg, &params);
    let t = time(5, || ex.run(&input).unwrap());
    report_timing("funcsim efficientnet-b0@64", &t);

    // 2. funcsim: resnet18@64
    let gg2 = analyze(&zoo::by_name("resnet18", 64).unwrap());
    let params2 = Params::random(&gg2, 7);
    let input2 = Tensor::from_vec(Shape::new(64, 64, 3), rng.i8_vec(64 * 64 * 3));
    let ex2 = Executor::new(&gg2, &params2);
    let t2 = time(5, || ex2.run(&input2).unwrap());
    report_timing("funcsim resnet18@64", &t2);

    // 3. optimizer single evaluation (resnet152)
    let gg3 = analyze(&zoo::resnet152(256));
    let opt3 = Optimizer::new(&gg3, &cfg);
    let t3 = time(20, || opt3.evaluate(&[10]));
    report_timing("optimizer evaluate resnet152", &t3);

    // 4. full descent on efficientdet-d0 (8 segments)
    let gg4 = analyze(&zoo::efficientdet_d0(512));
    let opt4 = Optimizer::new(&gg4, &cfg);
    println!("efficientdet space = {:.2e}", opt4.space());
    let t4 = time(3, || opt4.optimize());
    report_timing("optimizer descent efficientdet-d0", &t4);

    // 5. full exhaustive on yolov3
    let gg5 = analyze(&zoo::yolov3(416));
    let opt5 = Optimizer::new(&gg5, &cfg);
    println!("yolov3 space = {:.2e}", opt5.space());
    let t5 = time(3, || opt5.optimize());
    report_timing("optimizer exhaustive yolov3", &t5);

    // 6. Session sweep: the whole zoo × 3 configs, serial vs parallel.
    //    A fresh Session per run keeps every compile cold, so this times
    //    the thread scaling, not the memoization.
    let mut cfg_small = cfg.clone();
    cfg_small.name = "small".into();
    cfg_small.sram_budget = 4_000_000;
    let mut cfg_large = cfg.clone();
    cfg_large.name = "large".into();
    cfg_large.sram_budget = 14_000_000;
    cfg_large.bram18k_total = 6800;
    let cfgs = [cfg.clone(), cfg_small, cfg_large];
    let jobs: Vec<SweepJob> = zoo::MODEL_NAMES
        .iter()
        .flat_map(|&m| cfgs.iter().map(move |c| SweepJob::zoo_default(m, c).unwrap()))
        .collect();
    println!("sweep grid: {} jobs (zoo x {} configs)", jobs.len(), cfgs.len());
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);

    let t_serial = time(1, || {
        Session::new().run_jobs(&jobs, 1).iter().filter(|r| r.is_ok()).count()
    });
    report_timing("session sweep serial (1 thread)", &t_serial);

    let t_par = time(1, || {
        Session::new().run_jobs(&jobs, threads).iter().filter(|r| r.is_ok()).count()
    });
    report_timing(&format!("session sweep parallel ({threads} threads)"), &t_par);
    println!(
        "session sweep speedup: x{:.2} on {} threads",
        t_serial.median_ms / t_par.median_ms,
        threads
    );

    // 7. Session memoization: the same grid again on a warm session.
    let warm = Session::new();
    let _ = warm.run_jobs(&jobs, threads);
    let t_hot = time(3, || warm.run_jobs(&jobs, threads).len());
    report_timing("session sweep warm (all cache hits)", &t_hot);
    let stats = warm.stats();
    println!(
        "warm session: {} report hits / {} misses, {} analysis hits",
        stats.report_hits, stats.report_misses, stats.analysis_hits
    );
}
