//! Fig. 17 — on/off-chip access and latency vs cut-point position for
//! YOLOv3, ResNet152 and EfficientNet-B1 (weights always read once; the
//! frame-based side wins latency whenever buffers fit).

use shortcutfusion::analyzer::analyze;
use shortcutfusion::bench::{report_timing, time, Table};
use shortcutfusion::config::AccelConfig;
use shortcutfusion::optimizer::Optimizer;
use shortcutfusion::zoo;

fn main() {
    let cfg = AccelConfig::kcu1500_int8();
    for (name, input) in [("yolov3", 416), ("resnet152", 256), ("efficientnet-b1", 256)] {
        let gg = analyze(&zoo::by_name(name, input).unwrap());
        let opt = Optimizer::new(&gg, &cfg);
        let sweep = opt.sweep_first_segment();
        let mut t = Table::new(
            &format!("Fig 17 — {name}@{input}: cut-point sweep ({} segments)", opt.segs.len()),
            &["cut", "SRAM MB", "DRAM MB", "FM MB", "latency ms", "feasible"],
        );
        // subsample long sweeps for readability
        let step = (sweep.len() / 24).max(1);
        for p in sweep.iter().step_by(step) {
            t.row(&[
                p.cut.to_string(),
                format!("{:.3}", p.sram_mb),
                format!("{:.2}", p.dram_total_mb),
                format!("{:.2}", p.dram_fm_mb),
                format!("{:.3}", p.latency_ms),
                p.feasible.to_string(),
            ]);
        }
        t.print();

        // paper's qualitative claim: "the cut-point at the beginning
        // achieves a better latency at the cost of a larger buffer size"
        let first = sweep.first().unwrap();
        let last = sweep.last().unwrap();
        println!(
            "shape check {name}: frame-heavy latency {:.2} ms vs row-heavy {:.2} ms; \
             frame-heavy SRAM {:.2} MB vs row-heavy {:.2} MB",
            first.latency_ms, last.latency_ms, first.sram_mb, last.sram_mb
        );

        let timing = time(3, || opt.sweep_first_segment());
        report_timing(&format!("fig17 sweep {name}"), &timing);
    }
}
