//! Table V — full performance of the six evaluation CNNs on the 8-bit
//! KCU1500 configuration: latency, fps, GOPS, MAC efficiency, off-chip
//! feature maps, baseline-once traffic and the 47.8–84.8 % reduction.

use shortcutfusion::bench::{report_timing, time, Table};
use shortcutfusion::config::AccelConfig;
use shortcutfusion::compiler::Compiler;
use shortcutfusion::zoo;

struct PaperRow {
    model: &'static str,
    input: usize,
    gop: f64,
    latency_ms: f64,
    gops: f64,
    eff_pct: f64,
    offchip_fm_mb: f64,
    total_once_mb: f64,
    reduction_pct: f64,
}

const PAPER: &[PaperRow] = &[
    PaperRow {
        model: "resnet50",
        input: 256,
        gop: 11.76,
        latency_ms: 11.69,
        gops: 1006.0,
        eff_pct: 61.4,
        offchip_fm_mb: 0.19,
        total_once_mb: 59.09,
        reduction_pct: 60.62,
    },
    PaperRow {
        model: "resnet152",
        input: 256,
        gop: 31.16,
        latency_ms: 26.78,
        gops: 1163.0,
        eff_pct: 71.0,
        offchip_fm_mb: 0.19,
        total_once_mb: 130.2,
        reduction_pct: 56.7,
    },
    PaperRow {
        model: "yolov2",
        input: 416,
        gop: 17.18,
        latency_ms: 14.73,
        gops: 1166.0,
        eff_pct: 71.2,
        offchip_fm_mb: 0.66,
        total_once_mb: 48.9,
        reduction_pct: 70.31,
    },
    PaperRow {
        model: "yolov3",
        input: 416,
        gop: 65.86,
        latency_ms: 57.57,
        gops: 1142.0,
        eff_pct: 69.7,
        offchip_fm_mb: 90.6,
        total_once_mb: 153.5,
        reduction_pct: 60.34,
    },
    PaperRow {
        model: "retinanet",
        input: 512,
        gop: 102.2,
        latency_ms: 93.16,
        gops: 1097.0,
        eff_pct: 67.0,
        offchip_fm_mb: 136.4,
        total_once_mb: 261.34,
        reduction_pct: 47.81,
    },
    PaperRow {
        model: "efficientnet-b1",
        input: 256,
        gop: 1.38,
        latency_ms: 4.69,
        gops: 317.1,
        eff_pct: 19.37,
        offchip_fm_mb: 0.19,
        total_once_mb: 60.7,
        reduction_pct: 84.81,
    },
];

fn main() {
    let cfg = AccelConfig::kcu1500_int8();
    let mut t = Table::new(
        "Table V — proposed scheme on the 8-bit KCU1500 config (paper -> measured)",
        &[
            "model",
            "GOP",
            "latency ms",
            "GOPS",
            "MAC eff %",
            "off-chip FM MB",
            "baseline MB",
            "reduction %",
        ],
    );
    for p in PAPER {
        let graph = zoo::by_name(p.model, p.input).unwrap();
        let r = Compiler::new(cfg.clone()).compile(&graph).unwrap();
        t.row(&[
            format!("{}@{}", p.model, p.input),
            format!("{:.2} -> {:.2}", p.gop, graph.total_gop()),
            format!("{:.2} -> {:.2}", p.latency_ms, r.latency_ms()),
            format!("{:.0} -> {:.0}", p.gops, r.gops()),
            format!("{:.1} -> {:.1}", p.eff_pct, r.mac_efficiency_pct()),
            format!("{:.2} -> {:.2}", p.offchip_fm_mb, r.offchip_fm_mb()),
            format!("{:.1} -> {:.1}", p.total_once_mb, r.baseline_once_mb()),
            format!("{:.1} -> {:.1}", p.reduction_pct, r.reduction_pct()),
        ]);
    }
    t.print();
    println!("\npaper claim: total DRAM reduction spans 47.8–84.8 % across the six CNNs");

    let graph = zoo::resnet50(256);
    let timing = time(3, || Compiler::new(cfg.clone()).compile(&graph).unwrap());
    report_timing("table5 pipeline (resnet50@256)", &timing);
}
