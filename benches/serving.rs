//! Batched serving throughput of the `InferenceEngine`.
//!
//! Packs ResNet18@64 once, then serves waves of requests through the
//! virtual-accelerator backend while sweeping the worker count, batch
//! size and batch-formation policy (continuous joins vs the pre-0.9
//! fixed window), with a bursty-arrival pattern so continuous batching
//! has gaps to span. Reported numbers: wall-clock request throughput of
//! the serving stack itself, the timing model's per-request latency
//! percentiles (which are worker-independent — the hardware model prices
//! a single accelerator instance per worker), and the scheduler's
//! mid-batch join count.
//!
//! Run: `cargo bench --bench serving [-- --json-out FILE]` (or
//! `cargo run --release --bin ...` style via the harness-free bench
//! target). `--json-out` writes one row per (policy, workers, batch)
//! cell — including deadline misses and the engine's always-on
//! queue-wait / batch-size histograms — so `scripts/bench_diff.py` can
//! compare continuous against window batching across trajectory points.

use std::sync::Arc;
use std::time::Instant;

use shortcutfusion::bench::Table;
use shortcutfusion::compiler::Compiler;
use shortcutfusion::config::AccelConfig;
use shortcutfusion::engine::{
    BatchPolicy, EngineConfig, EngineStats, InferenceEngine, VirtualAccelBackend,
};
use shortcutfusion::funcsim::Tensor;
use shortcutfusion::program::Program;
use shortcutfusion::serialize::Json;
use shortcutfusion::testutil::Rng;
use shortcutfusion::zoo;

fn pack_model() -> Arc<Program> {
    let compiler = Compiler::new(AccelConfig::kcu1500_int8());
    let analyzed = compiler.analyze(&zoo::resnet18(64)).expect("analyze");
    let optimized = compiler.optimize(&analyzed).expect("optimize");
    let allocated = compiler.allocate(&optimized).expect("allocate");
    let lowered = compiler.lower(&allocated).expect("lower");
    Arc::new(compiler.pack(&lowered).expect("pack"))
}

/// One measured sweep cell, JSON-ready.
fn row_json(policy: &str, workers: usize, batch: usize, wall_ms: f64, stats: &EngineStats) -> Json {
    Json::obj(vec![
        ("policy", Json::str(policy)),
        ("workers", Json::num(workers as f64)),
        ("batch", Json::num(batch as f64)),
        ("wall_ms", Json::num(wall_ms)),
        ("completed", Json::num(stats.completed as f64)),
        ("deadline_misses", Json::num(stats.deadline_misses as f64)),
        ("joined", Json::num(stats.joined as f64)),
        ("batches", Json::num(stats.batches as f64)),
        ("p50_ms", Json::num(stats.p50_ms)),
        ("p95_ms", Json::num(stats.p95_ms)),
        ("mean_wait_ms", Json::num(stats.mean_wait_ms)),
        ("queue_wait_ms_hist", stats.queue_wait_ms_hist.to_json()),
        ("batch_size_hist", stats.batch_size_hist.to_json()),
    ])
}

fn main() {
    let json_out = std::env::args()
        .collect::<Vec<_>>()
        .windows(2)
        .find(|w| w[0] == "--json-out")
        .map(|w| w[1].clone());
    let program = pack_model();
    // exercise the on-disk path too: serve what was loaded, not what was packed
    let program = Arc::new(Program::from_bytes(&program.to_bytes()).expect("load"));
    let shape = program.input_shape();
    let requests = 64usize;

    let mut inputs = Vec::with_capacity(requests);
    let mut rng = Rng::from_seed(42);
    for _ in 0..requests {
        inputs.push(Tensor::from_vec(shape, rng.i8_vec(shape.numel())));
    }

    let mut t = Table::new(
        &format!(
            "serving {} ({} requests in bursts of 8, virtual accelerator)",
            program.model(),
            requests
        ),
        &[
            "policy",
            "workers",
            "batch",
            "wall ms",
            "req/s",
            "p50 ms",
            "p95 ms",
            "peak in-flight",
            "batches",
            "joins",
        ],
    );

    let mut rows = Vec::new();
    for &policy in &[BatchPolicy::Continuous, BatchPolicy::Window] {
        for &workers in &[1usize, 2, 4] {
            for &batch in &[1usize, 4, 8] {
                let engine = InferenceEngine::new(
                    program.clone(),
                    Arc::new(VirtualAccelBackend),
                    EngineConfig {
                        workers,
                        queue_capacity: 32,
                        max_batch: batch,
                        policy,
                        deadline_ms: None,
                    },
                );
                let t0 = Instant::now();
                let mut pending = Vec::with_capacity(requests);
                for (i, input) in inputs.iter().enumerate() {
                    // bursty arrivals: 8 back to back, then a breather —
                    // the traffic shape where mid-batch joins matter
                    if i > 0 && i % 8 == 0 {
                        std::thread::sleep(std::time::Duration::from_micros(200));
                    }
                    pending.push(engine.submit(input.clone()).expect("submit"));
                }
                for p in pending {
                    p.wait().expect("wait");
                }
                let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
                let stats = engine.shutdown();
                assert_eq!(stats.completed, requests as u64);
                t.row(&[
                    stats.policy.to_string(),
                    workers.to_string(),
                    batch.to_string(),
                    format!("{wall_ms:.2}"),
                    format!("{:.0}", requests as f64 / (wall_ms / 1e3)),
                    format!("{:.3}", stats.p50_ms),
                    format!("{:.3}", stats.p95_ms),
                    stats.peak_in_flight.to_string(),
                    stats.batches.to_string(),
                    stats.joined.to_string(),
                ]);
                rows.push(row_json(stats.policy, workers, batch, wall_ms, &stats));
            }
        }
    }
    t.print();

    if let Some(path) = json_out {
        let doc = Json::obj(vec![
            ("model", Json::str(program.model())),
            ("requests", Json::num(requests as f64)),
            ("rows", Json::Arr(rows)),
        ]);
        let mut text = doc.to_string_pretty();
        text.push('\n');
        std::fs::write(&path, text).expect("write --json-out");
        println!("wrote {path}");
    }
}
