//! Table IV — buffer size vs DRAM access on VGG-CONV (8-bit):
//! OLAccel [38] and SmartShuttle [12] vs the proposed adaptive switch.

use shortcutfusion::analyzer::analyze;
use shortcutfusion::baselines::olaccel::OLACCEL_VGG;
use shortcutfusion::baselines::smartshuttle_dram;
use shortcutfusion::bench::{report_timing, time, Table};
use shortcutfusion::compiler::{Compiler, MinBufferStrategy, SmartShuttleStrategy};
use shortcutfusion::config::AccelConfig;
use shortcutfusion::zoo;
use std::sync::Arc;

fn main() {
    let cfg = AccelConfig::kcu1500_int8();
    let graph = zoo::vgg16_conv(224);
    let gg = analyze(&graph);

    // Both designs run through the staged pipeline via their strategy
    // ports: SmartShuttle at its published 0.75 MB buffer, the proposed
    // design under the minimum-buffer policy (inputs/outputs once).
    let ss_report = Compiler::with_strategy(
        cfg.clone(),
        Arc::new(SmartShuttleStrategy { buffer_bytes: 750_000 }),
    )
    .compile(&graph)
    .unwrap();
    let min = Compiler::with_strategy(cfg.clone(), Arc::new(MinBufferStrategy))
        .compile(&graph)
        .unwrap()
        .evaluation;
    // layer-split detail from the raw cost model
    let ss = smartshuttle_dram(&gg, &cfg, 750_000);
    assert_eq!(ss.dram_bytes, ss_report.evaluation.dram.total);

    let mut t = Table::new(
        "Table IV — VGG-CONV buffer size vs DRAM access",
        &[
            "design",
            "precision",
            "SRAM MB (paper)",
            "SRAM MB (meas)",
            "DRAM MB (paper)",
            "DRAM MB (meas)",
        ],
    );
    t.row(&[
        "OLAccel [38]".into(),
        OLACCEL_VGG.precision.into(),
        format!("{:.2}", OLACCEL_VGG.sram_mb),
        "- (literature)".into(),
        format!("{:.1}", OLACCEL_VGG.dram_mb),
        "- (literature)".into(),
    ]);
    t.row(&[
        "SmartShuttle [12]".into(),
        "8-bit".into(),
        "0.75".into(),
        "0.75 (given)".into(),
        "58.1".into(),
        format!("{:.1}", ss_report.offchip_total_mb()),
    ]);
    t.row(&[
        "proposed".into(),
        "8-bit".into(),
        "0.712".into(),
        format!("{:.3}", min.sram.total as f64 / 1e6),
        "42.8".into(),
        format!("{:.1}", min.dram.total as f64 / 1e6),
    ]);
    t.print();

    println!(
        "\nclaims: DRAM reduction vs SmartShuttle = {:.2}x (paper 1.36x); \
         SmartShuttle split {} psum-oriented / {} weight-oriented layers",
        ss.dram_bytes as f64 / min.dram.total as f64,
        ss.psum_layers,
        ss.weight_layers
    );

    let timing = time(5, || smartshuttle_dram(&gg, &cfg, 750_000));
    report_timing("table4 smartshuttle model", &timing);
}
