//! Buffer-pool sweep: pool size × replacement policy × access pattern.
//!
//! Drives `pool::BufferPool` directly with a synthetic model zoo (64
//! segments of 4 MB) so the sweep isolates pool behaviour from compile
//! and backend cost. For every combination it reports the hit rate,
//! eviction count, total modeled cold-load time, and the *measured*
//! wall-clock cost of the three pin classes — hit, miss without
//! eviction, miss with eviction — which are distinct by construction
//! (a hit is a recency touch, a miss pays insert + modeled DRAM fill,
//! an evicting miss additionally runs the policy's victim search).
//!
//! The `mixed-scan` pattern (a hot pair touched twice per round, then a
//! scan longer than the pool) demonstrates the policy crossover: the
//! scan-resistant segmented LRU keeps the hot set while plain LRU loses
//! it to every scan. The bench asserts at least one measured crossover.
//!
//! Run: `cargo bench --bench pool [-- --json-out FILE]`.

use std::time::Instant;

use shortcutfusion::bench::Table;
use shortcutfusion::pool::{policy_by_name, BufferPool, PoolConfig, SegmentId, POLICY_NAMES};
use shortcutfusion::serialize::Json;
use shortcutfusion::testutil::Rng;

const SEGMENT_MB: u64 = 4;
const SEGMENTS: u64 = 64;
const ACCESSES: usize = 4096;

fn trace(pattern: &str) -> Vec<u64> {
    let mut rng = Rng::from_seed(0xB00C);
    match pattern {
        // a cyclic walk over the whole zoo — the classic loop that
        // thrashes every recency-based policy when it exceeds the pool
        "scan" => (0..ACCESSES).map(|i| i as u64 % SEGMENTS).collect(),
        // 1/8 of the zoo takes 80 % of the traffic
        "hot-set" => {
            let hot = SEGMENTS / 8;
            (0..ACCESSES)
                .map(|_| {
                    if rng.unit() < 0.8 {
                        rng.next_u64() % hot
                    } else {
                        hot + rng.next_u64() % (SEGMENTS - hot)
                    }
                })
                .collect()
        }
        // log-uniform ranks: a zipf-like popularity tail
        "zipf" => (0..ACCESSES)
            .map(|_| (((SEGMENTS as f64).powf(rng.unit()) as u64) - 1).min(SEGMENTS - 1))
            .collect(),
        // a hot pair touched twice per round, then a scan of fresh
        // segments longer than the pool: scan-resistance pays off here
        "mixed-scan" => {
            let mut t = Vec::new();
            let mut fresh = 1_000u64;
            for _ in 0..64 {
                t.extend([0u64, 1, 0, 1]);
                for _ in 0..40 {
                    t.push(fresh);
                    fresh += 1;
                }
            }
            t
        }
        other => unreachable!("unknown pattern {other}"),
    }
}

struct Row {
    pool_mb: u64,
    policy: &'static str,
    pattern: &'static str,
    accesses: usize,
    hit_rate: f64,
    evictions: u64,
    cold_total_ms: f64,
    hit_ns: f64,
    miss_ns: f64,
    evict_ns: f64,
}

fn run_one(pool_mb: u64, policy: &'static str, pattern: &'static str, trace: &[u64]) -> Row {
    let pool = BufferPool::new(
        PoolConfig::new(pool_mb * 1_000_000),
        policy_by_name(policy).expect("policy"),
    )
    .expect("pool");
    let bytes = SEGMENT_MB * 1_000_000;
    // (total ns, count) per pin class
    let (mut hit, mut miss, mut evict) = ((0.0, 0u64), (0.0, 0u64), (0.0, 0u64));
    for &seg in trace {
        let full = pool.capacity_bytes() - pool.used_bytes() < bytes;
        let t0 = Instant::now();
        let guard = pool.pin(SegmentId(seg), bytes, "bench");
        let was_hit = guard.hit();
        drop(guard);
        let ns = t0.elapsed().as_nanos() as f64;
        let class = if was_hit {
            &mut hit
        } else if full {
            &mut evict
        } else {
            &mut miss
        };
        class.0 += ns;
        class.1 += 1;
    }
    let stats = pool.stats();
    let mean = |(total, n): (f64, u64)| if n == 0 { 0.0 } else { total / n as f64 };
    Row {
        pool_mb,
        policy,
        pattern,
        accesses: trace.len(),
        hit_rate: stats.hit_rate(),
        evictions: stats.evictions,
        cold_total_ms: stats.cold_load_total_ms,
        hit_ns: mean(hit),
        miss_ns: mean(miss),
        evict_ns: mean(evict),
    }
}

fn main() {
    let json_out = std::env::args()
        .collect::<Vec<_>>()
        .windows(2)
        .find(|w| w[0] == "--json-out")
        .map(|w| w[1].clone());

    let patterns = ["scan", "hot-set", "zipf", "mixed-scan"];
    let traces: Vec<Vec<u64>> = patterns.iter().map(|p| trace(p)).collect();

    let mut rows = Vec::new();
    for &pool_mb in &[32u64, 128] {
        for &policy in POLICY_NAMES {
            for (&pattern, trace) in patterns.iter().zip(&traces) {
                rows.push(run_one(pool_mb, policy, pattern, trace));
            }
        }
    }

    let mut t = Table::new(
        &format!(
            "buffer pool: {SEGMENTS} segments x {SEGMENT_MB} MB, \
             pool size x policy x access pattern"
        ),
        &[
            "pool MB", "policy", "pattern", "hit %", "evictions", "cold ms",
            "hit ns", "miss ns", "evict ns",
        ],
    );
    for r in &rows {
        t.row(&[
            r.pool_mb.to_string(),
            r.policy.into(),
            r.pattern.into(),
            format!("{:.1}", r.hit_rate * 100.0),
            r.evictions.to_string(),
            format!("{:.1}", r.cold_total_ms),
            format!("{:.0}", r.hit_ns),
            format!("{:.0}", r.miss_ns),
            format!("{:.0}", r.evict_ns),
        ]);
    }
    t.print();

    // measured crossovers: (pool, pattern) combinations where the
    // scan-resistant policy strictly beats plain LRU
    let crossovers: Vec<&Row> = rows
        .iter()
        .filter(|r| r.policy == "slru")
        .filter(|s| {
            rows.iter().any(|l| {
                l.policy == "lru"
                    && l.pool_mb == s.pool_mb
                    && l.pattern == s.pattern
                    && s.hit_rate > l.hit_rate
            })
        })
        .collect();
    for c in &crossovers {
        println!(
            "crossover: slru {:.1} % beats lru on {} @ {} MB",
            c.hit_rate * 100.0,
            c.pattern,
            c.pool_mb
        );
    }
    assert!(
        !crossovers.is_empty(),
        "expected >= 1 policy crossover (slru > lru on a scan-heavy pattern)"
    );

    if let Some(path) = json_out {
        let doc = Json::obj(vec![
            ("segment_mb", Json::num(SEGMENT_MB as f64)),
            ("segments", Json::num(SEGMENTS as f64)),
            (
                "rows",
                Json::Arr(
                    rows.iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("pool_mb", Json::num(r.pool_mb as f64)),
                                ("policy", Json::str(r.policy)),
                                ("pattern", Json::str(r.pattern)),
                                ("accesses", Json::num(r.accesses as f64)),
                                ("hit_rate", Json::num(r.hit_rate)),
                                ("evictions", Json::num(r.evictions as f64)),
                                ("cold_total_ms", Json::num(r.cold_total_ms)),
                                ("hit_ns", Json::num(r.hit_ns)),
                                ("miss_ns", Json::num(r.miss_ns)),
                                ("evict_ns", Json::num(r.evict_ns)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "crossovers",
                Json::Arr(
                    crossovers
                        .iter()
                        .map(|c| {
                            Json::obj(vec![
                                ("pool_mb", Json::num(c.pool_mb as f64)),
                                ("pattern", Json::str(c.pattern)),
                                ("slru_hit_rate", Json::num(c.hit_rate)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]);
        let mut text = doc.to_string_pretty();
        text.push('\n');
        std::fs::write(&path, text).expect("write --json-out");
        println!("wrote {path}");
    }
}
