//! Figs. 2 + 18 — EfficientNet-B1 vs modern GPUs (batch 1): latency per
//! input size on Keras (Fig 2) and PyTorch (Fig 18a), and power
//! efficiency (Fig 18b). GPUs are the analytical model of DESIGN.md §2.

use shortcutfusion::analyzer::analyze;
use shortcutfusion::baselines::gpu_model::{
    estimate, estimate_keras, RTX_2080_TI, RTX_3090, TITAN_XP,
};
use shortcutfusion::bench::{report_timing, time, Table};
use shortcutfusion::config::AccelConfig;
use shortcutfusion::compiler::Compiler;
use shortcutfusion::zoo;

fn main() {
    let cfg = AccelConfig::kcu1500_int8();
    let sizes = [224usize, 256, 512, 768];

    // ---- Fig 2: Keras latency ------------------------------------------
    let mut f2 = Table::new(
        "Fig 2 — EfficientNet-B1 Keras/TF latency (ms) per input size [analytical GPUs]",
        &["input", "Titan Xp", "RTX 2080 Ti"],
    );
    for &s in &sizes {
        let gg = analyze(&zoo::efficientnet_b1(s));
        f2.row(&[
            s.to_string(),
            format!("{:.1}", estimate_keras(&gg, &TITAN_XP).latency_ms),
            format!("{:.1}", estimate_keras(&gg, &RTX_2080_TI).latency_ms),
        ]);
    }
    f2.print();

    // ---- Fig 18a: PyTorch latency vs the proposed accelerator -----------
    let mut f18 = Table::new(
        "Fig 18a — EfficientNet-B1 PyTorch latency (ms) vs proposed",
        &["input", "Titan Xp", "RTX 2080 Ti", "RTX 3090", "proposed", "2080Ti/ours"],
    );
    let mut speedup_256 = 0.0;
    for &s in &sizes {
        let graph = zoo::efficientnet_b1(s);
        let ours = Compiler::new(cfg.clone()).compile(&graph).unwrap();
        let gg = &ours.grouped;
        let g2080 = estimate(gg, &RTX_2080_TI);
        let ratio = g2080.latency_ms / ours.latency_ms();
        if s == 256 {
            speedup_256 = ratio;
        }
        f18.row(&[
            s.to_string(),
            format!("{:.1}", estimate(gg, &TITAN_XP).latency_ms),
            format!("{:.1}", g2080.latency_ms),
            format!("{:.1}", estimate(gg, &RTX_3090).latency_ms),
            format!("{:.2}", ours.latency_ms()),
            format!("x{:.2}", ratio),
        ]);
    }
    f18.print();
    println!(
        "\npaper: proposed is 2.8x faster than RTX 2080 Ti at 256 (measured x{:.2}); \
         GPUs overtake at larger inputs",
        speedup_256
    );

    // ---- Fig 18b: power efficiency ---------------------------------------
    let mut fp = Table::new(
        "Fig 18b — power and efficiency (EfficientNet-B1)",
        &["input", "2080Ti W", "2080Ti GOPS/W", "proposed W", "proposed GOPS/W", "eff ratio"],
    );
    for &s in &sizes[1..] {
        let graph = zoo::efficientnet_b1(s);
        let ours = Compiler::new(cfg.clone()).compile(&graph).unwrap();
        let gpu = estimate(&ours.grouped, &RTX_2080_TI);
        fp.row(&[
            s.to_string(),
            format!("{:.0}", gpu.power_w),
            format!("{:.2}", gpu.gops_per_w),
            format!("{:.1}", ours.power.total_w),
            format!("{:.1}", ours.power.gops_per_w),
            format!("x{:.1}", ours.power.gops_per_w / gpu.gops_per_w),
        ]);
    }
    fp.print();
    println!("\npaper: power efficiency 9.9x / 2.9x / 2.2x better at 256 / 512 / 768");

    let gg = analyze(&zoo::efficientnet_b1(512));
    let timing = time(10, || estimate(&gg, &RTX_2080_TI));
    report_timing("fig18 gpu model", &timing);
}
