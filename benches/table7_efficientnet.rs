//! Table VII — EfficientNet-B1 scalability across 256/512/768 inputs:
//! GOPS, DSP efficiency, off-chip traffic, reduction, power, GOPS/W.

use shortcutfusion::bench::{report_timing, time, Table};
use shortcutfusion::config::AccelConfig;
use shortcutfusion::compiler::Compiler;
use shortcutfusion::zoo;

struct PaperRow {
    input: usize,
    gops: f64,
    eff_pct: f64,
    offchip_fm_mb: f64,
    total_once_mb: f64,
    reduction_pct: f64,
    power_w: f64,
    gops_per_w: f64,
}

const PAPER: &[PaperRow] = &[
    PaperRow {
        input: 256,
        gops: 317.1,
        eff_pct: 19.37,
        offchip_fm_mb: 0.19,
        total_once_mb: 60.7,
        reduction_pct: 84.81,
        power_w: 21.09,
        gops_per_w: 15.0,
    },
    PaperRow {
        input: 512,
        gops: 267.4,
        eff_pct: 16.3,
        offchip_fm_mb: 144.0,
        total_once_mb: 216.0,
        reduction_pct: 29.2,
        power_w: 23.76,
        gops_per_w: 11.3,
    },
    PaperRow {
        input: 768,
        gops: 274.4,
        eff_pct: 16.75,
        offchip_fm_mb: 344.0,
        total_once_mb: 475.0,
        reduction_pct: 27.6,
        power_w: 26.71,
        gops_per_w: 10.3,
    },
];

fn main() {
    let cfg = AccelConfig::kcu1500_int8();
    let mut t = Table::new(
        "Table VII — EfficientNet-B1 scalability (paper -> measured)",
        &[
            "input",
            "GOPS",
            "MAC eff %",
            "off-chip FM MB",
            "baseline MB",
            "reduction %",
            "power W",
            "GOPS/W",
        ],
    );
    for p in PAPER {
        let graph = zoo::efficientnet_b1(p.input);
        let r = Compiler::new(cfg.clone()).compile(&graph).unwrap();
        t.row(&[
            p.input.to_string(),
            format!("{:.0} -> {:.0}", p.gops, r.gops()),
            format!("{:.1} -> {:.1}", p.eff_pct, r.mac_efficiency_pct()),
            format!("{:.1} -> {:.1}", p.offchip_fm_mb, r.offchip_fm_mb()),
            format!("{:.0} -> {:.0}", p.total_once_mb, r.baseline_once_mb()),
            format!("{:.1} -> {:.1}", p.reduction_pct, r.reduction_pct()),
            format!("{:.1} -> {:.1}", p.power_w, r.power.total_w),
            format!("{:.1} -> {:.1}", p.gops_per_w, r.power.gops_per_w),
        ]);
    }
    t.print();
    println!("\nweights read from DRAM exactly once at every resolution (eq. 10 constraint)");

    let graph = zoo::efficientnet_b1(512);
    let timing = time(3, || Compiler::new(cfg.clone()).compile(&graph).unwrap());
    report_timing("table7 pipeline (efficientnet-b1@512)", &timing);
}
