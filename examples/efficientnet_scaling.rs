//! Domain example: the §V-C scalability study — EfficientNet-B1 (and
//! MobileNetV3) across input resolutions, with the GPU comparison of
//! Fig. 18 and the power breakdown of Table VII. The resolution grid is
//! compiled in parallel through a [`Session`].
//!
//! ```text
//! cargo run --release --example efficientnet_scaling
//! ```

use shortcutfusion::baselines::gpu_model::{estimate, RTX_2080_TI};
use shortcutfusion::bench::Table;
use shortcutfusion::compiler::{Session, SweepJob};
use shortcutfusion::config::AccelConfig;

fn main() {
    let cfg = AccelConfig::kcu1500_int8();
    let inputs = [224usize, 256, 384, 512, 768];
    let session = Session::new();
    for model in ["efficientnet-b1", "mobilenetv3-large"] {
        let mut t = Table::new(
            &format!("{model}: resolution scaling on {}", cfg.name),
            &[
                "input",
                "GOP",
                "latency ms",
                "fps",
                "GOPS",
                "eff %",
                "DRAM MB",
                "red %",
                "W",
                "GOPS/W",
                "2080Ti ms",
                "speedup",
            ],
        );
        let jobs: Vec<SweepJob> = inputs
            .iter()
            .map(|&input| SweepJob { model: model.to_string(), input, cfg: cfg.clone() })
            .collect();
        for (input, r) in inputs.iter().zip(session.run_jobs(&jobs, jobs.len())) {
            let r = r.unwrap();
            let gpu = estimate(&r.grouped, &RTX_2080_TI);
            t.row(&[
                input.to_string(),
                format!("{:.2}", r.grouped.graph.total_gop()),
                format!("{:.2}", r.latency_ms()),
                format!("{:.1}", r.fps()),
                format!("{:.0}", r.gops()),
                format!("{:.1}", r.mac_efficiency_pct()),
                format!("{:.1}", r.offchip_total_mb()),
                format!("{:.1}", r.reduction_pct()),
                format!("{:.1}", r.power.total_w),
                format!("{:.1}", r.power.gops_per_w),
                format!("{:.1}", gpu.latency_ms),
                format!("x{:.2}", gpu.latency_ms / r.latency_ms()),
            ]);
        }
        t.print();
    }
    println!(
        "\nshape expectations (paper §V-C): the accelerator wins at small inputs \
         (kernel-launch-bound GPU), the GPU overtakes at large inputs, and the \
         accelerator keeps a multi-x GOPS/W advantage throughout."
    );
}
