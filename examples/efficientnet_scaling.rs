//! Domain example: the §V-C scalability study — EfficientNet-B1 (and
//! MobileNetV3) across input resolutions, with the GPU comparison of
//! Fig. 18 and the power breakdown of Table VII.
//!
//! ```text
//! cargo run --release --example efficientnet_scaling
//! ```

use shortcutfusion::analyzer::analyze;
use shortcutfusion::baselines::gpu_model::{estimate, RTX_2080_TI};
use shortcutfusion::bench::Table;
use shortcutfusion::config::AccelConfig;
use shortcutfusion::coordinator::compile_model;
use shortcutfusion::zoo;

fn main() {
    let cfg = AccelConfig::kcu1500_int8();
    for model in ["efficientnet-b1", "mobilenetv3-large"] {
        let mut t = Table::new(
            &format!("{model}: resolution scaling on {}", cfg.name),
            &[
                "input",
                "GOP",
                "latency ms",
                "fps",
                "GOPS",
                "eff %",
                "DRAM MB",
                "red %",
                "W",
                "GOPS/W",
                "2080Ti ms",
                "speedup",
            ],
        );
        for input in [224usize, 256, 384, 512, 768] {
            let graph = zoo::by_name(model, input).unwrap();
            let gg = analyze(&graph);
            let r = compile_model(&graph, &cfg);
            let gpu = estimate(&gg, &RTX_2080_TI);
            t.row(&[
                input.to_string(),
                format!("{:.2}", graph.total_gop()),
                format!("{:.2}", r.latency_ms()),
                format!("{:.1}", r.fps()),
                format!("{:.0}", r.gops()),
                format!("{:.1}", r.mac_efficiency_pct()),
                format!("{:.1}", r.offchip_total_mb()),
                format!("{:.1}", r.reduction_pct()),
                format!("{:.1}", r.power.total_w),
                format!("{:.1}", r.power.gops_per_w),
                format!("{:.1}", gpu.latency_ms),
                format!("x{:.2}", gpu.latency_ms / r.latency_ms()),
            ]);
        }
        t.print();
    }
    println!(
        "\nshape expectations (paper §V-C): the accelerator wins at small inputs \
         (kernel-launch-bound GPU), the GPU overtakes at large inputs, and the \
         accelerator keeps a multi-x GOPS/W advantage throughout."
    );
}
