//! Domain example: design-space exploration for a new FPGA target.
//!
//! The paper's §IV-B: "If different FPGA is selected, we can decide the
//! parallelisms (i.e., MAC array size) of the accelerator and the
//! switching points of the reuse schemes based on the optimization."
//! This driver sweeps cut-points for one CNN across *three* accelerator
//! configurations (small / KCU1500 / large) through a parallel
//! [`Session`] — the fusion analysis runs once and is shared across all
//! targets — and reports how the optimal cut and the feasible region
//! move with the SRAM budget.
//!
//! ```text
//! cargo run --release --example cutpoint_sweep [model] [input]
//! ```

use shortcutfusion::analyzer::analyze;
use shortcutfusion::bench::Table;
use shortcutfusion::compiler::{CompileError, Session, SweepJob};
use shortcutfusion::config::AccelConfig;
use shortcutfusion::optimizer::Optimizer;
use shortcutfusion::zoo;

fn main() -> shortcutfusion::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map(String::as_str).unwrap_or("yolov3");
    let input: usize = args
        .get(1)
        .map(|s| s.parse())
        .transpose()
        .map_err(|_| CompileError::config("input must be a number"))?
        .unwrap_or_else(|| zoo::default_input(model));
    let graph =
        zoo::by_name(model, input).ok_or_else(|| CompileError::unknown_model(model))?;

    // three hypothetical targets
    let mut small = AccelConfig::kcu1500_int8();
    small.name = "small-FPGA".into();
    small.bram18k_total = 1500;
    small.sram_budget = 2_500_000;
    let kcu = AccelConfig::kcu1500_int8();
    let mut large = AccelConfig::kcu1500_int8();
    large.name = "large-FPGA".into();
    large.bram18k_total = 6800;
    large.sram_budget = 14_000_000;

    let session = Session::new();
    let jobs: Vec<SweepJob> = [&small, &kcu, &large]
        .iter()
        .map(|cfg| SweepJob { model: model.to_string(), input, cfg: (*cfg).clone() })
        .collect();
    let results = session.run_jobs(&jobs, jobs.len());

    let mut t = Table::new(
        &format!("{model}@{input}: optimum across accelerator targets"),
        &["target", "SRAM budget MB", "cuts", "latency ms", "DRAM MB", "SRAM MB", "feasible"],
    );
    for (job, r) in jobs.iter().zip(results) {
        let r = r?;
        let best = &r.evaluation;
        t.row(&[
            job.cfg.name.clone(),
            format!("{:.1}", job.cfg.sram_budget as f64 / 1e6),
            format!("{:?}", best.cuts.cuts),
            format!("{:.3}", best.latency_ms),
            format!("{:.2}", best.dram.total as f64 / 1e6),
            format!("{:.3}", best.sram.total as f64 / 1e6),
            best.feasible.to_string(),
        ]);
    }
    t.print();
    let stats = session.stats();
    println!(
        "(session: {} compile misses, fusion analysis shared {} of {} times)",
        stats.report_misses,
        stats.analysis_hits,
        stats.analysis_hits + stats.analysis_misses
    );

    // detailed sweep on the main target
    let gg = analyze(&graph);
    let opt = Optimizer::new(&gg, &kcu);
    let mut s = Table::new(
        &format!("{model}@{input}: first-segment sweep on {}", kcu.name),
        &["cut", "SRAM MB", "DRAM MB", "latency ms", "feasible"],
    );
    let sweep = opt.sweep_first_segment();
    let step = (sweep.len() / 20).max(1);
    for p in sweep.iter().step_by(step) {
        s.row(&[
            p.cut.to_string(),
            format!("{:.3}", p.sram_mb),
            format!("{:.2}", p.dram_total_mb),
            format!("{:.3}", p.latency_ms),
            p.feasible.to_string(),
        ]);
    }
    s.print();
    Ok(())
}
