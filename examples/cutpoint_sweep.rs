//! Domain example: design-space exploration for a new FPGA target.
//!
//! The paper's §IV-B: "If different FPGA is selected, we can decide the
//! parallelisms (i.e., MAC array size) of the accelerator and the
//! switching points of the reuse schemes based on the optimization."
//! This driver sweeps cut-points for one CNN across *three* accelerator
//! configurations (small / KCU1500 / large) and reports how the optimal
//! cut and the feasible region move with the SRAM budget.
//!
//! ```text
//! cargo run --release --example cutpoint_sweep [model] [input]
//! ```

use shortcutfusion::analyzer::analyze;
use shortcutfusion::bench::Table;
use shortcutfusion::config::AccelConfig;
use shortcutfusion::optimizer::Optimizer;
use shortcutfusion::zoo;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map(String::as_str).unwrap_or("yolov3");
    let input: usize = args
        .get(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or_else(|| zoo::default_input(model));
    let graph = zoo::by_name(model, input)
        .ok_or_else(|| anyhow::anyhow!("unknown model {model:?}"))?;
    let gg = analyze(&graph);

    // three hypothetical targets
    let mut small = AccelConfig::kcu1500_int8();
    small.name = "small-FPGA".into();
    small.bram18k_total = 1500;
    small.sram_budget = 2_500_000;
    let kcu = AccelConfig::kcu1500_int8();
    let mut large = AccelConfig::kcu1500_int8();
    large.name = "large-FPGA".into();
    large.bram18k_total = 6800;
    large.sram_budget = 14_000_000;

    let mut t = Table::new(
        &format!("{model}@{input}: optimum across accelerator targets"),
        &["target", "SRAM budget MB", "cuts", "latency ms", "DRAM MB", "SRAM MB", "feasible"],
    );
    for cfg in [&small, &kcu, &large] {
        let opt = Optimizer::new(&gg, cfg);
        let best = opt.optimize();
        t.row(&[
            cfg.name.clone(),
            format!("{:.1}", cfg.sram_budget as f64 / 1e6),
            format!("{:?}", best.cuts.cuts),
            format!("{:.3}", best.latency_ms),
            format!("{:.2}", best.dram.total as f64 / 1e6),
            format!("{:.3}", best.sram.total as f64 / 1e6),
            best.feasible.to_string(),
        ]);
    }
    t.print();

    // detailed sweep on the main target
    let opt = Optimizer::new(&gg, &kcu);
    let mut s = Table::new(
        &format!("{model}@{input}: first-segment sweep on {}", kcu.name),
        &["cut", "SRAM MB", "DRAM MB", "latency ms", "feasible"],
    );
    let sweep = opt.sweep_first_segment();
    let step = (sweep.len() / 20).max(1);
    for p in sweep.iter().step_by(step) {
        s.row(&[
            p.cut.to_string(),
            format!("{:.3}", p.sram_mb),
            format!("{:.2}", p.dram_total_mb),
            format!("{:.3}", p.latency_ms),
            p.feasible.to_string(),
        ]);
    }
    s.print();
    Ok(())
}
