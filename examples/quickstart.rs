//! Quickstart: compile one CNN end-to-end, print the full report, then
//! pack + serve a deployable program.
//!
//! ```text
//! cargo run --release --example quickstart [model] [input]
//! ```
//! Walks the whole Fig.-4 pipeline through the staged API — parse/build →
//! analyzer fusion → reuse-aware cut-point optimization → static 3-buffer
//! allocation → 11-word instruction stream → cycle-accurate timing
//! simulation → power estimate — and shows the per-stage artifacts.
//! Afterwards it packs TinyNet-SE into a `Program` artifact, round-trips
//! it through disk, executes it on the reference and virtual-accelerator
//! backends, and serves a burst of requests through the
//! `InferenceEngine` (this half doubles as the CI serving smoke test).

use std::sync::Arc;

use shortcutfusion::bench::Table;
use shortcutfusion::compiler::{CompileError, Compiler};
use shortcutfusion::config::AccelConfig;
use shortcutfusion::engine::{
    EngineConfig, ExecutionBackend, InferenceEngine, ReferenceBackend, VirtualAccelBackend,
};
use shortcutfusion::funcsim::{Params, Tensor};
use shortcutfusion::isa::ReuseMode;
use shortcutfusion::program::Program;
use shortcutfusion::testutil::Rng;
use shortcutfusion::zoo;

fn main() -> shortcutfusion::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map(String::as_str).unwrap_or("resnet50");
    let input: usize = args
        .get(1)
        .map(|s| s.parse())
        .transpose()
        .map_err(|_| CompileError::config("input must be a number"))?
        .unwrap_or_else(|| zoo::default_input(model));

    let graph =
        zoo::by_name(model, input).ok_or_else(|| CompileError::unknown_model(model))?;
    let cfg = AccelConfig::kcu1500_int8();

    println!("ShortcutFusion quickstart — {model}@{input} on {}", cfg.name);
    println!(
        "graph: {} nodes, {} conv layers, {:.2} GOP, {:.2} M params",
        graph.nodes.len(),
        graph.conv_layer_count(),
        graph.total_gop(),
        graph.total_weight_bytes(1) as f64 / 1e6
    );

    // Each stage is an owned artifact — inspect them as they appear.
    let compiler = Compiler::new(cfg);
    let analyzed = compiler.analyze(&graph)?;
    println!(
        "analyzer: {} groups ({} with fused shortcut, {} with fused SE squeeze)",
        analyzed.group_count(),
        analyzed.grouped.groups.iter().filter(|g| g.shortcut_of.is_some()).count(),
        analyzed.grouped.groups.iter().filter(|g| g.se_squeeze).count(),
    );
    let optimized = compiler.optimize(&analyzed)?;
    println!(
        "optimizer: cuts {:?} -> {} row-reuse / {} frame-reuse groups ({})",
        optimized.evaluation.cuts.cuts,
        optimized.row_groups(),
        optimized.frame_groups(),
        if optimized.evaluation.feasible { "feasible" } else { "INFEASIBLE" }
    );
    let allocated = compiler.allocate(&optimized)?;
    let lowered = compiler.lower(&allocated)?;
    let r = compiler.simulate(&lowered)?.into_report();

    let mut t = Table::new("compile report", &["metric", "value"]);
    t.row(&["latency".into(), format!("{:.3} ms ({:.1} fps)", r.latency_ms(), r.fps())]);
    t.row(&["throughput".into(), format!("{:.1} GOPS", r.gops())]);
    t.row(&["MAC efficiency".into(), format!("{:.1} %", r.mac_efficiency_pct())]);
    t.row(&["SRAM".into(), format!("{:.3} MB / {} BRAM18K", r.sram_mb(), r.bram18k())]);
    t.row(&["DRAM total".into(), format!("{:.2} MB", r.offchip_total_mb())]);
    t.row(&["DRAM feature maps".into(), format!("{:.2} MB", r.offchip_fm_mb())]);
    t.row(&["baseline (once)".into(), format!("{:.2} MB", r.baseline_once_mb())]);
    t.row(&["off-chip reduction".into(), format!("{:.1} %", r.reduction_pct())]);
    t.row(&[
        "power".into(),
        format!("{:.1} W ({:.1} GOPS/W)", r.power.total_w, r.power.gops_per_w),
    ]);
    t.row(&["instructions".into(), format!("{} x 11 words", r.stream.len())]);
    t.print();

    // first few instructions, decoded
    println!("\nfirst instructions:");
    for ins in r.stream.instrs.iter().take(6) {
        println!(
            "  g{:>3} {:?} {}x{}x{} -> {}x{}x{} k{} s{} {} {}",
            ins.group,
            ins.opcode,
            ins.in_h,
            ins.in_w,
            ins.in_c,
            ins.out_h,
            ins.out_w,
            ins.out_c,
            ins.k,
            ins.stride,
            if ins.reuse == ReuseMode::Row { "row" } else { "frame" },
            if ins.fused_eltwise { "+shortcut" } else { "" },
        );
    }

    serve_demo()
}

/// Pack TinyNet-SE into a deployable `Program`, round-trip it through
/// disk, execute on both simulation backends, and serve a burst through
/// the batching engine.
fn serve_demo() -> shortcutfusion::Result<()> {
    println!("\n== deployable program + serving demo (TinyNet-SE) ==");
    let compiler = Compiler::new(AccelConfig::kcu1500_int8());
    let analyzed = compiler.analyze(&zoo::tinynet())?;
    let compiler = compiler.with_params(Params::random(&analyzed.grouped, 7));
    let lowered = compiler.lower(&compiler.allocate(&compiler.optimize(&analyzed)?)?)?;
    let program = compiler.pack(&lowered)?;

    let dir = std::env::temp_dir().join("sf_quickstart");
    std::fs::create_dir_all(&dir).map_err(|e| CompileError::io(&dir, e))?;
    let path = dir.join("tinynet.sfp");
    program.save(&path)?;
    let program = Arc::new(Program::load(&path)?);
    println!(
        "packed {} -> {} ({} instructions, params included: {})",
        program.model(),
        path.display(),
        program.stream().len(),
        program.params().is_some()
    );

    let shape = program.input_shape();
    let mut rng = Rng::from_seed(1);
    let input = Tensor::from_vec(shape, rng.i8_vec(shape.numel()));

    let bit_exact = ReferenceBackend.run(&program, &input)?;
    let out = bit_exact.output.expect("reference backend returns tensors");
    let head = &out.data[..out.data.len().min(6)];
    println!("reference backend: output {} ({head:?} ...)", out.shape);

    let cost = VirtualAccelBackend.run(&program, &input)?;
    println!(
        "virtual accelerator: {:.4} ms/inference, {:.3} MB DRAM traffic",
        cost.model_latency_ms.unwrap(),
        cost.dram_bytes.unwrap() as f64 / 1e6
    );

    let engine = InferenceEngine::new(
        program.clone(),
        Arc::new(VirtualAccelBackend),
        EngineConfig { workers: 2, queue_capacity: 16, max_batch: 4, ..EngineConfig::default() },
    );
    let pending: Vec<_> = (0..16)
        .map(|i| {
            let mut rng = Rng::from_seed(i as u64);
            engine.submit(Tensor::from_vec(shape, rng.i8_vec(shape.numel())))
        })
        .collect::<shortcutfusion::Result<_>>()?;
    for p in pending {
        p.wait()?;
    }
    let stats = engine.shutdown();
    println!(
        "engine: {} requests served by {} workers, {:.0} req/s, p50 {:.4} ms, p95 {:.4} ms, peak in-flight {}",
        stats.completed,
        stats.per_worker.len(),
        stats.throughput_rps,
        stats.p50_ms,
        stats.p95_ms,
        stats.peak_in_flight
    );
    assert_eq!(stats.completed, 16, "serving smoke: every request must complete");
    Ok(())
}
