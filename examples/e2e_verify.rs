//! END-TO-END verification driver (the Fig. 4 hardware-verification loop).
//!
//! Proves all three layers compose:
//!  1. L3 compiles TinyNet-SE through the staged API: analyze →
//!     reuse-aware optimize → static memory allocation → 11-word
//!     instruction stream;
//!  2. the functional simulator executes that instruction stream over the
//!     quantized parameters exported by the build-time python;
//!  3. the rust PJRT runtime loads the AOT HLO artifact (L2 JAX model
//!     calling the L1 Pallas kernels) and executes it on the same input;
//!  4. the two logits vectors must match **bit-exactly** (and both must
//!     match the expectation recorded at export time).
//!
//! Run: `make artifacts && cargo run --release --features pjrt --example e2e_verify`
//! (without the `pjrt` feature, step 3 is skipped with a notice).

use shortcutfusion::compiler::{CompileError, Compiler};
use shortcutfusion::config::AccelConfig;
use shortcutfusion::funcsim::{execute, Params};
use shortcutfusion::runtime::{artifacts_dir, load_expected_logits, load_input_tensor, Runtime};
use shortcutfusion::zoo;
use shortcutfusion::Result;

fn main() -> Result<()> {
    let dir = artifacts_dir();
    println!("== ShortcutFusion end-to-end verification ==");
    println!("artifacts: {}", dir.display());

    // ---- L3: compile the network through the staged API -----------------
    let cfg = AccelConfig::kcu1500_int8();
    let compiler = Compiler::new(cfg);
    let analyzed = compiler.analyze(&zoo::tinynet())?;
    let optimized = compiler.optimize(&analyzed)?;
    println!(
        "compiled {}: {} nodes -> {} groups, cuts {:?}, policy {} ",
        analyzed.model,
        analyzed.node_count(),
        analyzed.group_count(),
        optimized.evaluation.cuts.cuts,
        if optimized.evaluation.feasible { "feasible" } else { "INFEASIBLE" }
    );
    let lowered = compiler.lower(&compiler.allocate(&optimized)?)?;
    println!(
        "instruction stream: {} instructions, {} bytes; DRAM arena {} KB",
        lowered.stream.len(),
        lowered.stream.byte_size(),
        lowered.dram_layout.footprint() / 1024
    );
    let simulated = compiler.simulate(&lowered)?;
    println!(
        "timing sim: {:.3} ms, {:.1} GOPS ({:.1}% MAC efficiency); DRAM {:.2} MB (baseline {:.2} MB, -{:.1}%)",
        simulated.timing.latency_ms,
        simulated.timing.gops,
        100.0 * simulated.timing.mac_efficiency,
        simulated.evaluation.dram.total as f64 / 1e6,
        simulated.evaluation.dram.baseline_once as f64 / 1e6,
        simulated.evaluation.dram.reduction_pct()
    );

    // ---- funcsim over python-exported parameters ------------------------
    let params = Params::from_file(&dir.join("tinynet_params.json")).map_err(|e| {
        CompileError::params(format!("tinynet_params.json (run `make artifacts`): {e}"))
    })?;
    let input = load_input_tensor(&dir.join("tinynet_input.json"))?;
    let values = execute(&simulated.grouped, &simulated.stream, &params, &input)?;
    let fc = simulated.grouped.graph.find("fc").expect("fc node");
    let funcsim_logits: Vec<i8> = values[fc.0].data.clone();
    println!("funcsim logits:  {funcsim_logits:?}");

    let expected = load_expected_logits(&dir.join("tinynet_expected.json"))?;
    println!("export expected: {expected:?}");

    // ---- PJRT: run the AOT golden model ---------------------------------
    let mut rt = match Runtime::cpu() {
        Ok(rt) => rt,
        // Only the feature-off stub is skippable; a real backend that
        // fails to initialize is an error.
        Err(e @ CompileError::Unsupported(_)) => {
            println!("SKIP PJRT half ({e})");
            if funcsim_logits != expected {
                return Err(CompileError::Exec(format!(
                    "BIT-EXACTNESS FAILURE: funcsim {funcsim_logits:?} != expected {expected:?}"
                )));
            }
            println!(
                "OK: funcsim == export-time expectation, bit-exact ({} logits)",
                funcsim_logits.len()
            );
            return Ok(());
        }
        Err(e) => return Err(e),
    };
    println!("PJRT platform: {}", rt.platform());
    let model = rt.load(&dir.join("tinynet.hlo.txt"))?;
    let pjrt_logits = rt.run_i8(model, &[&input])?;
    println!("PJRT logits:     {pjrt_logits:?}");

    // ---- the verdict -----------------------------------------------------
    if pjrt_logits != expected {
        return Err(CompileError::Exec(
            "PJRT output diverges from export-time expectation — artifact mismatch".into(),
        ));
    }
    if funcsim_logits != pjrt_logits {
        return Err(CompileError::Exec(format!(
            "BIT-EXACTNESS FAILURE: funcsim {funcsim_logits:?} != PJRT {pjrt_logits:?}"
        )));
    }
    println!("OK: funcsim == PJRT golden model, bit-exact ({} logits)", pjrt_logits.len());
    Ok(())
}
