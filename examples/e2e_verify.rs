//! END-TO-END verification driver (the Fig. 4 hardware-verification loop).
//!
//! Proves all three layers compose:
//!  1. L3 compiles TinyNet-SE: analyze → reuse-aware optimize → static
//!     memory allocation → 11-word instruction stream;
//!  2. the functional simulator executes that instruction stream over the
//!     quantized parameters exported by the build-time python;
//!  3. the rust PJRT runtime loads the AOT HLO artifact (L2 JAX model
//!     calling the L1 Pallas kernels) and executes it on the same input;
//!  4. the two logits vectors must match **bit-exactly** (and both must
//!     match the expectation recorded at export time).
//!
//! Run: `make artifacts && cargo run --release --example e2e_verify`

use anyhow::{bail, Context, Result};
use shortcutfusion::alloc::{allocate, layout};
use shortcutfusion::analyzer::analyze;
use shortcutfusion::config::AccelConfig;
use shortcutfusion::funcsim::{execute, Params};
use shortcutfusion::isa::{lower, MemAssign, MemLoc};
use shortcutfusion::optimizer::Optimizer;
use shortcutfusion::runtime::{artifacts_dir, load_expected_logits, load_input_tensor, Runtime};
use shortcutfusion::sim::simulate;
use shortcutfusion::zoo;

fn main() -> Result<()> {
    let dir = artifacts_dir();
    println!("== ShortcutFusion end-to-end verification ==");
    println!("artifacts: {}", dir.display());

    // ---- L3: compile the network ---------------------------------------
    let graph = zoo::tinynet();
    let gg = analyze(&graph);
    let cfg = AccelConfig::kcu1500_int8();
    let opt = Optimizer::new(&gg, &cfg);
    let best = opt.optimize();
    println!(
        "compiled {}: {} nodes -> {} groups, cuts {:?}, policy {} ",
        graph.name,
        gg.graph.nodes.len(),
        gg.groups.len(),
        best.cuts.cuts,
        if best.feasible { "feasible" } else { "INFEASIBLE" }
    );
    let alloc = allocate(&gg, &best.policy, &cfg);
    let dram_layout = layout(&gg, &best.policy, &alloc, &cfg);
    let assigns: Vec<MemAssign> = gg
        .groups
        .iter()
        .enumerate()
        .map(|(gi, gr)| MemAssign {
            reuse: best.policy[gi],
            in_loc: loc_of(&alloc.assigns[gi].in_loc, &dram_layout, gi),
            out_loc: loc_of(&alloc.assigns[gi].out_loc, &dram_layout, gi),
            aux_loc: alloc.assigns[gi].aux_loc.as_ref().map(|l| loc_of(l, &dram_layout, gi)),
            weight_addr: dram_layout.weights[gi].offset,
            weight_bytes: gr.weight_bytes(&gg.graph, cfg.qw as u64) as u32,
            quant_shift: 0,
        })
        .collect();
    let stream = lower(&gg, &assigns);
    println!(
        "instruction stream: {} instructions, {} bytes; DRAM arena {} KB",
        stream.len(),
        stream.byte_size(),
        dram_layout.footprint() / 1024
    );
    let timing = simulate(&gg, &best.policy, &alloc, &cfg);
    println!(
        "timing sim: {:.3} ms, {:.1} GOPS ({:.1}% MAC efficiency); DRAM {:.2} MB (baseline {:.2} MB, -{:.1}%)",
        timing.latency_ms,
        timing.gops,
        100.0 * timing.mac_efficiency,
        best.dram.total as f64 / 1e6,
        best.dram.baseline_once as f64 / 1e6,
        best.dram.reduction_pct()
    );

    // ---- funcsim over python-exported parameters ------------------------
    let params = Params::from_file(&dir.join("tinynet_params.json"))
        .context("tinynet_params.json (run `make artifacts`)")?;
    let input = load_input_tensor(&dir.join("tinynet_input.json"))?;
    let values = execute(&gg, &stream, &params, &input)?;
    let fc = gg.graph.find("fc").expect("fc node");
    let funcsim_logits: Vec<i8> = values[fc.0].data.clone();
    println!("funcsim logits:  {funcsim_logits:?}");

    // ---- PJRT: run the AOT golden model ---------------------------------
    let mut rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    let model = rt.load(&dir.join("tinynet.hlo.txt"))?;
    let pjrt_logits = rt.run_i8(model, &[&input])?;
    println!("PJRT logits:     {pjrt_logits:?}");

    let expected = load_expected_logits(&dir.join("tinynet_expected.json"))?;
    println!("export expected: {expected:?}");

    // ---- the verdict -----------------------------------------------------
    if pjrt_logits != expected {
        bail!("PJRT output diverges from export-time expectation — artifact mismatch");
    }
    if funcsim_logits != pjrt_logits {
        bail!(
            "BIT-EXACTNESS FAILURE: funcsim {:?} != PJRT {:?}",
            funcsim_logits,
            pjrt_logits
        );
    }
    println!("OK: funcsim == PJRT golden model, bit-exact ({} logits)", pjrt_logits.len());
    Ok(())
}

fn loc_of(
    l: &shortcutfusion::alloc::Loc,
    lay: &shortcutfusion::alloc::OffchipLayout,
    gi: usize,
) -> MemLoc {
    match l {
        shortcutfusion::alloc::Loc::Buf(b) => MemLoc::Buf(*b),
        // aux vectors ride in the small SRAM; encode as buffer 0 offset 0
        shortcutfusion::alloc::Loc::Aux => MemLoc::Buf(0),
        shortcutfusion::alloc::Loc::Dram => MemLoc::Dram(lay.fmaps[gi].offset),
    }
}
