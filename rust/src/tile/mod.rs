//! Depth-first fused-tile streaming (the `tile` reuse-strategy family).
//!
//! Every other strategy in this repo schedules *whole feature-maps* per
//! group: a group reads its entire input, computes its entire output,
//! and only then does the next group start. Under a small SRAM budget
//! with large inputs even the paper's cut-point reuse spills, and the
//! Pareto front collapses onto row-streaming fallbacks. Block
//! Convolution (arXiv 2105.08937) and Petrica et al.'s memory-efficient
//! CNN dataflows (arXiv 2011.07317) show the escape hatch this module
//! implements: partition a chain of fused groups *depth-first* into
//! spatial tiles and stream one halo-padded tile through the whole
//! chain before touching DRAM again.
//!
//! ```text
//!            DRAM ──rows──▶ conv₁ ─▶ conv₂ ─▶ ⊕ ─▶ … ─▶ convₙ ──rows──▶ DRAM
//!                            │ tile slab │     ▲ shortcut tile
//!                            └── SRAM ───┘     │  (Buf 2, resident
//!                               ping-pong ─────┘   across the join)
//! ```
//!
//! A [`TileRegion`] is a maximal run of chained, tileable groups. Per
//! output tile of the region's last group the executor walks the chain
//! once; interior outputs live in two ping-pong SRAM slabs, shortcut
//! tiles stay resident in the third buffer across the residual join,
//! and only the region's first input and last output cross the DRAM
//! boundary. The price is the *halo*: a `k×k` convolution needs `k-1`
//! extra input rows per tile, so upstream tiles overlap and overlapping
//! rows are re-read (region input) or re-computed (interior groups) —
//! [`region_profile`] quantifies both, [`overheads`] turns them into
//! the eq. (8)/(9) DRAM extension, and [`region_tile_buff`] into the
//! eq. (1)–(7) SRAM extension.
//!
//! Weights of a region group are either held resident in SRAM for the
//! whole frame or re-streamed once per tile through a small
//! double-buffered chunk; the planner only streams when the re-read
//! cost `(n_tiles − 1) · W` is cheaper than the feature-map round trip
//! the fusion saves, and otherwise ends the region.
//!
//! The compile-side entry points are [`plan`] (build a [`TilePlan`] for
//! a tile height), [`apply_overlay`] (rewrite the static allocator's
//! per-group [`BufAssign`]s so interior tensors stay on-chip), and
//! [`TilePlan::from_stream`] (rebuild the plan from a packed
//! instruction stream, used by the virtual backend's traffic replay).
//! Tiled functional execution, bit-identical to the untiled reference,
//! lives in [`exec`].

pub mod exec;

use crate::alloc::{BufAssign, Loc};
use crate::analyzer::{Group, GroupId, GroupKind, GroupedGraph, PoolKind};
use crate::config::AccelConfig;
use crate::funcsim::ops::same_pad;
use crate::isa::InstructionStream;

/// Candidate tile heights swept when no explicit size is requested
/// (bounded by the 8-bit `tile_rows` instruction field).
pub const TILE_SIZES: &[usize] = &[4, 8, 16, 32, 64];

/// One depth-first fused region: groups `first..=last` execute
/// tile-by-tile, with interior feature-maps never reaching DRAM.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TileRegion {
    /// First group of the region (its input streams from DRAM).
    pub first: usize,
    /// Last group, inclusive (its output streams to DRAM).
    pub last: usize,
    /// Output rows of `last` computed per tile iteration.
    pub tile_rows: usize,
    /// Per region group (`first..=last`): weights re-streamed from DRAM
    /// once per tile instead of held resident in SRAM.
    pub streamed_weights: Vec<bool>,
}

impl TileRegion {
    /// Number of groups in the region.
    pub fn len(&self) -> usize {
        self.last - self.first + 1
    }

    /// Always false — a region holds at least two groups by
    /// construction.
    pub fn is_empty(&self) -> bool {
        false
    }

    /// Does the region contain group index `g`?
    pub fn contains(&self, g: usize) -> bool {
        (self.first..=self.last).contains(&g)
    }
}

/// A whole network's tiling decision: zero or more disjoint regions in
/// program order. An empty plan means untiled execution — every
/// consumer of a plan treats that case as exactly the pre-tile
/// behaviour.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct TilePlan {
    /// Disjoint fused regions, ascending by group index.
    pub regions: Vec<TileRegion>,
}

impl TilePlan {
    /// True when no region formed (untiled execution).
    pub fn is_empty(&self) -> bool {
        self.regions.is_empty()
    }

    /// The region containing group `g`, if any.
    pub fn region_of(&self, g: usize) -> Option<&TileRegion> {
        self.regions.iter().find(|r| r.contains(g))
    }

    /// Rebuild the plan from a lowered instruction stream's tile fields
    /// (a region is a run of `tile_rows > 0` instructions opened by
    /// `tile_first`). This is how [`crate::engine::VirtualAccelBackend`]
    /// recovers the schedule from a packed [`crate::program::Program`]
    /// without any side-channel metadata.
    pub fn from_stream(stream: &InstructionStream) -> TilePlan {
        let instrs = &stream.instrs;
        let mut regions = Vec::new();
        let mut i = 0;
        while i < instrs.len() {
            if instrs[i].tile_rows == 0 || !instrs[i].tile_first {
                i += 1;
                continue;
            }
            let first = i;
            let mut streamed = vec![instrs[i].tile_weight_stream];
            let mut last = i;
            while last + 1 < instrs.len()
                && instrs[last + 1].tile_rows == instrs[first].tile_rows
                && !instrs[last + 1].tile_first
            {
                last += 1;
                streamed.push(instrs[last].tile_weight_stream);
            }
            regions.push(TileRegion {
                first,
                last,
                tile_rows: instrs[first].tile_rows as usize,
                streamed_weights: streamed,
            });
            i = last + 1;
        }
        TilePlan { regions }
    }
}

/// Per-region row accounting at a concrete tile height, produced by
/// [`region_profile`]. All halo/overcompute modelling — DRAM, SRAM and
/// timing — derives from this one struct so the analytical model and
/// the instruction-stream replay can never disagree.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RegionProfile {
    /// Tile iterations over the region (`ceil(out_h(last) / tile_rows)`).
    pub n_tiles: usize,
    /// Total rows of the region-first group's *input* read across all
    /// tiles (≥ `in_h`; the excess is the re-read halo).
    pub rows_in_total: u64,
    /// Per region group: total *output* rows computed across all tiles
    /// (≥ `out_h`; the excess is halo overcompute).
    pub rows_out_total: Vec<u64>,
    /// Per region group: largest single-tile output row count — sizes
    /// the group's SRAM tile slab.
    pub rows_out_max: Vec<usize>,
    /// Per region group: total rows of an *out-of-region* DRAM shortcut
    /// operand read across all tiles (0 when the aux source is inside
    /// the region or absent).
    pub rows_aux_total: Vec<u64>,
}

/// Extra DRAM traffic a [`TilePlan`] adds on top of the placement-based
/// eq. (8)/(9) accounting: halo re-reads of region inputs and
/// out-of-region shortcut operands, and per-tile weight re-streaming.
/// Added identically by the analytical model
/// ([`crate::compiler::TileStreamingStrategy`]) and the traffic replay
/// ([`crate::sim::replay`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Overheads {
    /// Feature-map bytes re-read because consecutive tiles overlap.
    pub halo_fm_extra: u64,
    /// Weight bytes re-read by per-tile streaming
    /// (`(n_tiles − 1) · W` per streamed group).
    pub weight_extra: u64,
}

/// The second (shortcut / element-wise) operand's producing group, if
/// any — mirrors the static allocator's aux-operand resolution.
pub(crate) fn aux_source(gr: &Group) -> Option<GroupId> {
    if let Some(s) = gr.shortcut_of {
        Some(s)
    } else if matches!(gr.kind, GroupKind::Scale | GroupKind::Concat | GroupKind::Eltwise) {
        gr.inputs.get(1).copied()
    } else {
        None
    }
}

/// Input rows `[lo, hi]` a windowed op needs to produce output rows
/// `[a, b]`, under TF SAME padding.
pub(crate) fn window(
    in_h: usize,
    out_h: usize,
    k: usize,
    s: usize,
    a: usize,
    b: usize,
) -> (usize, usize) {
    let pad = same_pad(in_h, out_h, k, s);
    let lo = (a * s) as isize - pad;
    let hi = (b * s + k - 1) as isize - pad;
    let lo = lo.max(0) as usize;
    let hi = (hi.max(0) as usize).min(in_h - 1);
    (lo.min(hi), hi)
}

/// Map a group's output rows `[a, b]` back to the rows of its *main
/// input* it must read, composing the fused pool window behind a conv
/// when present.
pub(crate) fn group_input_rows(gg: &GroupedGraph, gr: &Group, a: usize, b: usize) -> (usize, usize) {
    match gr.kind {
        GroupKind::Conv | GroupKind::DwConv => {
            let (k, s, _) = gr.conv_geometry(&gg.graph);
            // A fused trailing pool sits between the conv output and the
            // group output: first map group-output rows to conv-output
            // rows, then through the conv window.
            let (ca, cb, conv_h) = match gr.pool {
                Some((pk, pk_k, pk_s)) if pk != PoolKind::Global => {
                    let conv_h = gg.graph.node(gr.main).out_shape.h;
                    let (pa, pb) = window(conv_h, gr.out_shape.h, pk_k, pk_s, a, b);
                    (pa, pb, conv_h)
                }
                _ => (a, b, gr.out_shape.h),
            };
            window(gr.in_shape.h, conv_h, k, s, ca, cb)
        }
        GroupKind::Pool => match gr.pool {
            Some((pk, k, s)) if pk != PoolKind::Global => {
                window(gr.in_shape.h, gr.out_shape.h, k, s, a, b)
            }
            _ => (a, b),
        },
        GroupKind::Upsample => {
            let f = gr.upsample.unwrap_or(1).max(1);
            (a / f, b / f)
        }
        // Element-wise / activation groups are pointwise in rows.
        _ => (a, b),
    }
}

/// Can this group participate in a depth-first tiled region?
fn tileable(gg: &GroupedGraph, gr: &Group) -> bool {
    if gr.se_squeeze || gr.in_shape.h * gr.in_shape.w <= 1 || gr.out_shape.h * gr.out_shape.w <= 1 {
        return false;
    }
    match gr.kind {
        GroupKind::Conv | GroupKind::DwConv => {
            gr.upsample.is_none()
                && !matches!(gr.pool, Some((PoolKind::Global, _, _)))
                // pool + shortcut in one group leaves the join's spatial
                // position ambiguous — keep those whole-frame
                && !(gr.pool.is_some() && gr.shortcut_of.is_some())
        }
        GroupKind::Pool => {
            matches!(gr.pool, Some((PoolKind::Max | PoolKind::Avg, _, _))) && gr.upsample.is_none()
        }
        GroupKind::Eltwise | GroupKind::Act => gr.pool.is_none() && gr.upsample.is_none(),
        GroupKind::Upsample => gr.pool.is_none() && gr.upsample.is_some(),
        _ => false,
    }
}

/// Group-level consumer map including shortcut edges (a shortcut read
/// pins its producer exactly like a data edge).
fn consumer_map(gg: &GroupedGraph) -> Vec<Vec<usize>> {
    let mut out = vec![Vec::new(); gg.groups.len()];
    for gr in &gg.groups {
        for &i in &gr.inputs {
            out[i.0].push(gr.id.0);
        }
        if let Some(s) = gr.shortcut_of {
            out[s.0].push(gr.id.0);
        }
    }
    out
}

/// Row accounting for one region: walk every tile's backward
/// need-propagation at group granularity and total the rows each group
/// reads/computes.
pub fn region_profile(gg: &GroupedGraph, region: &TileRegion) -> RegionProfile {
    let len = region.len();
    let out_h = gg.groups[region.last].out_shape.h;
    let t = region.tile_rows.clamp(1, out_h);
    let mut p = RegionProfile {
        n_tiles: 0,
        rows_in_total: 0,
        rows_out_total: vec![0; len],
        rows_out_max: vec![0; len],
        rows_aux_total: vec![0; len],
    };
    let mut t0 = 0;
    while t0 < out_h {
        let t1 = (t0 + t).min(out_h) - 1;
        p.n_tiles += 1;
        // Backward need-propagation: rows of each group's *output*
        // needed to produce rows [t0, t1] of the region's last group.
        let mut need: Vec<Option<(usize, usize)>> = vec![None; len];
        need[len - 1] = Some((t0, t1));
        for gi in (0..len).rev() {
            let Some((a, b)) = need[gi] else { continue };
            let g = region.first + gi;
            let gr = &gg.groups[g];
            if gi > 0 {
                let (ia, ib) = group_input_rows(gg, gr, a, b);
                merge(&mut need[gi - 1], ia, ib);
            }
            if let Some(src) = aux_source(gr) {
                if src.0 >= region.first && src.0 < g {
                    // shortcut operand rows == output rows (pointwise join)
                    merge(&mut need[src.0 - region.first], a, b);
                }
            }
        }
        for gi in 0..len {
            let Some((a, b)) = need[gi] else { continue };
            let rows = b - a + 1;
            p.rows_out_total[gi] += rows as u64;
            p.rows_out_max[gi] = p.rows_out_max[gi].max(rows);
            let gr = &gg.groups[region.first + gi];
            if let Some(src) = aux_source(gr) {
                if src.0 < region.first {
                    p.rows_aux_total[gi] += rows as u64;
                }
            }
        }
        if let Some((a, b)) = need[0] {
            let (ia, ib) = group_input_rows(gg, &gg.groups[region.first], a, b);
            p.rows_in_total += (ib - ia + 1) as u64;
        }
        t0 = t1 + 1;
    }
    p
}

/// Merge interval `[a, b]` into an optional interval accumulator.
fn merge(acc: &mut Option<(usize, usize)>, a: usize, b: usize) {
    *acc = match *acc {
        None => Some((a, b)),
        Some((x, y)) => Some((x.min(a), y.max(b))),
    };
}

/// SRAM bytes one region's tile working set occupies: resident weights,
/// the streamed-weight chunk double-buffer, two ping-pong activation
/// slabs, and shortcut tiles held resident across their joins. This is
/// the `tile_buff` term [`crate::optimizer::sram_size_tiled`] adds to
/// equations (1)–(7).
pub fn region_tile_buff(gg: &GroupedGraph, cfg: &AccelConfig, region: &TileRegion) -> usize {
    let p = region_profile(gg, region);
    let len = region.len();
    let mut is_aux_src = vec![false; len];
    for gi in 0..len {
        if let Some(src) = aux_source(&gg.groups[region.first + gi]) {
            if src.0 >= region.first && src.0 < region.first + gi {
                is_aux_src[src.0 - region.first] = true;
            }
        }
    }
    let mut resident_weights = 0usize;
    let mut stream_chunk = 0usize;
    let mut slab_max = 0usize;
    let mut resident_slabs = 0usize;
    for gi in 0..len {
        let gr = &gg.groups[region.first + gi];
        let wb = gr.weight_bytes(&gg.graph, cfg.qw as u64) as usize;
        if region.streamed_weights[gi] {
            let (k, _, _) = gr.conv_geometry(&gg.graph);
            // double-buffered Ti×To weight chunk, capped at 2× the layer
            stream_chunk = stream_chunk.max((2 * k * k * cfg.ti * cfg.to * cfg.qw).min(2 * wb));
        } else {
            resident_weights += wb;
        }
        let slab = p.rows_out_max[gi] * gr.out_shape.w * gr.out_shape.c * cfg.qa;
        if is_aux_src[gi] {
            resident_slabs += slab;
        } else {
            slab_max = slab_max.max(slab);
        }
    }
    resident_weights + stream_chunk + 2 * slab_max + resident_slabs
}

/// Largest per-region tile working set of the plan (the whole-network
/// `tile_buff`); 0 for an empty plan.
pub fn tile_buff(gg: &GroupedGraph, cfg: &AccelConfig, plan: &TilePlan) -> usize {
    plan.regions.iter().map(|r| region_tile_buff(gg, cfg, r)).max().unwrap_or(0)
}

/// DRAM overheads of the plan (see [`Overheads`]).
pub fn overheads(gg: &GroupedGraph, cfg: &AccelConfig, plan: &TilePlan) -> Overheads {
    let qa = cfg.qa as u64;
    let mut o = Overheads::default();
    for region in &plan.regions {
        let p = region_profile(gg, region);
        let first = &gg.groups[region.first];
        let in_row = (first.in_shape.w * first.in_shape.c) as u64 * qa;
        o.halo_fm_extra +=
            (p.rows_in_total * in_row).saturating_sub(first.in_shape.bytes(cfg.qa) as u64);
        for gi in 0..region.len() {
            let gr = &gg.groups[region.first + gi];
            if p.rows_aux_total[gi] > 0 {
                let row = (gr.out_shape.w * gr.out_shape.c) as u64 * qa;
                o.halo_fm_extra +=
                    (p.rows_aux_total[gi] * row).saturating_sub(gr.out_shape.bytes(cfg.qa) as u64);
            }
            if region.streamed_weights[gi] && p.n_tiles > 1 {
                o.weight_extra += (p.n_tiles as u64 - 1) * gr.weight_bytes(&gg.graph, cfg.qw as u64);
            }
        }
    }
    o
}

/// Rewrite an all-Row allocation so each region's interior tensors live
/// on-chip: interior outputs ping-pong between Buf 0/1 (shortcut
/// sources park in Buf 2 until their join), interior inputs read the
/// producer's slab, and only the region's first input / last output
/// keep their DRAM placement. Applied between `alloc::allocate` and
/// `alloc::layout`, so the off-chip arena also shrinks.
pub fn apply_overlay(assigns: &mut [BufAssign], gg: &GroupedGraph, plan: &TilePlan) {
    for region in &plan.regions {
        let len = region.len();
        let mut is_aux_src = vec![false; len];
        for gi in 0..len {
            if let Some(src) = aux_source(&gg.groups[region.first + gi]) {
                if src.0 >= region.first && src.0 < region.first + gi {
                    is_aux_src[src.0 - region.first] = true;
                }
            }
        }
        for g in region.first..=region.last {
            let gi = g - region.first;
            if g < region.last {
                assigns[g].out_loc =
                    if is_aux_src[gi] { Loc::Buf(2) } else { Loc::Buf((gi % 2) as u8) };
                assigns[g].also_dram = false;
            }
            if g > region.first {
                assigns[g].in_loc = assigns[g - 1].out_loc;
            }
            if let Some(src) = aux_source(&gg.groups[g]) {
                if src.0 >= region.first && src.0 < g {
                    assigns[g].aux_loc = Some(assigns[src.0].out_loc);
                }
            }
            assigns[g].staged_input = false;
        }
    }
}

/// Build a [`TilePlan`] for one tile height: grow maximal chained runs
/// of tileable groups, then shrink each run until (a) its tile working
/// set fits `cfg.sram_budget`, (b) no interior output escapes the
/// region, and (c) every streamed-weight group's re-read cost is below
/// the feature-map traffic its fusion saves. Runs that end up with
/// fewer than two convolution members are dropped (no traffic to save).
pub fn plan(gg: &GroupedGraph, cfg: &AccelConfig, tile_rows: usize) -> TilePlan {
    let t = tile_rows.clamp(1, 255);
    let n = gg.groups.len();
    let consumers = consumer_map(gg);
    let mut regions = Vec::new();
    let mut g = 0;
    while g < n {
        if !tileable(gg, &gg.groups[g]) {
            g += 1;
            continue;
        }
        let first = g;
        let mut end = g;
        while end + 1 < n
            && tileable(gg, &gg.groups[end + 1])
            && gg.groups[end + 1].inputs.first().copied() == Some(GroupId(end))
        {
            end += 1;
        }
        match carve_region(gg, cfg, &consumers, first, end, t) {
            Some(region) => {
                g = region.last + 1;
                regions.push(region);
            }
            None => g = first + 1,
        }
    }
    TilePlan { regions }
}

/// Candidate region with the greedy weight-residency split: weights stay
/// resident until half the SRAM budget is spoken for, later groups
/// stream per tile.
fn probe(gg: &GroupedGraph, cfg: &AccelConfig, first: usize, last: usize, t: usize) -> TileRegion {
    let mut streamed = Vec::with_capacity(last - first + 1);
    let mut resident = 0usize;
    for g in first..=last {
        let wb = gg.groups[g].weight_bytes(&gg.graph, cfg.qw as u64) as usize;
        if wb > 0 && resident + wb <= cfg.sram_budget / 2 {
            resident += wb;
            streamed.push(false);
        } else {
            streamed.push(wb > 0);
        }
    }
    TileRegion { first, last, tile_rows: t, streamed_weights: streamed }
}

enum Trim {
    Ok(TileRegion),
    Shrink(usize),
}

fn step_trim(
    gg: &GroupedGraph,
    cfg: &AccelConfig,
    consumers: &[Vec<usize>],
    first: usize,
    last: usize,
    t: usize,
) -> Trim {
    let region = probe(gg, cfg, first, last, t);
    // (a) the tile working set must fit the budget
    if region_tile_buff(gg, cfg, &region) > cfg.sram_budget {
        return Trim::Shrink(last - 1);
    }
    // (b) interior outputs never materialize in DRAM, so any interior
    // group with a consumer beyond the region must become a region end
    if let Some(bad) = (first..last).find(|&x| consumers[x].iter().any(|&c| c > last)) {
        return Trim::Shrink(bad);
    }
    // (c) weight streaming must pay for itself
    let p = region_profile(gg, &region);
    if p.n_tiles > 1 {
        for gi in 0..region.len() {
            if !region.streamed_weights[gi] {
                continue;
            }
            let gr = &gg.groups[region.first + gi];
            let extra = (p.n_tiles as u64 - 1) * gr.weight_bytes(&gg.graph, cfg.qw as u64);
            let fm = (gr.in_shape.bytes(cfg.qa) + gr.out_shape.bytes(cfg.qa)) as u64;
            if extra >= fm {
                // Truncate just before the group whose weights cannot
                // stream profitably; carve_region drops the region if
                // nothing is left.
                return Trim::Shrink((region.first + gi).saturating_sub(1));
            }
        }
    }
    Trim::Ok(region)
}

fn carve_region(
    gg: &GroupedGraph,
    cfg: &AccelConfig,
    consumers: &[Vec<usize>],
    first: usize,
    mut last: usize,
    t: usize,
) -> Option<TileRegion> {
    loop {
        if last <= first {
            return None;
        }
        match step_trim(gg, cfg, consumers, first, last, t) {
            Trim::Ok(region) => {
                let convs = (region.first..=region.last)
                    .filter(|&x| matches!(gg.groups[x].kind, GroupKind::Conv | GroupKind::DwConv))
                    .count();
                return if convs >= 2 { Some(region) } else { None };
            }
            Trim::Shrink(l) => {
                if l >= last {
                    return None; // no progress — give up on this run
                }
                last = l;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analyzer::analyze;
    use crate::isa::ReuseMode;
    use crate::zoo;

    fn cfg() -> AccelConfig {
        AccelConfig::kcu1500_int8()
    }

    #[test]
    fn resnet18_forms_fused_regions() {
        let gg = analyze(&zoo::resnet18(224));
        let p = plan(&gg, &cfg(), 8);
        assert!(!p.is_empty(), "resnet18 must form at least one region");
        for r in &p.regions {
            assert!(r.len() >= 2);
            assert_eq!(r.streamed_weights.len(), r.len());
        }
    }

    #[test]
    fn regions_are_disjoint_and_chained() {
        for model in ["resnet18", "vgg16-conv", "yolov3"] {
            let gg = analyze(&zoo::by_name(model, 224).unwrap());
            let p = plan(&gg, &cfg(), 16);
            let mut prev_end: Option<usize> = None;
            for r in &p.regions {
                if let Some(e) = prev_end {
                    assert!(r.first > e, "{model}: overlapping regions");
                }
                prev_end = Some(r.last);
                for g in r.first + 1..=r.last {
                    assert_eq!(
                        gg.groups[g].inputs.first().copied(),
                        Some(GroupId(g - 1)),
                        "{model}: region group {g} breaks the chain"
                    );
                }
            }
        }
    }

    #[test]
    fn interior_consumers_stay_inside_regions() {
        let gg = analyze(&zoo::yolov3(416));
        let p = plan(&gg, &cfg(), 16);
        let consumers = consumer_map(&gg);
        assert!(!p.is_empty());
        for r in &p.regions {
            for g in r.first..r.last {
                for &c in &consumers[g] {
                    assert!(c <= r.last, "interior output of group {g} escapes to {c}");
                }
            }
        }
    }

    #[test]
    fn single_tile_plan_has_zero_overheads() {
        let gg = analyze(&zoo::resnet18(224));
        let mut p = plan(&gg, &cfg(), 8);
        assert!(!p.is_empty());
        // Force every region to one tile covering the full frame.
        for r in &mut p.regions {
            r.tile_rows = 255;
        }
        let o = overheads(&gg, &cfg(), &p);
        assert_eq!(o.halo_fm_extra, 0, "full-frame tile re-reads nothing");
        assert_eq!(o.weight_extra, 0, "single tile streams weights once");
    }

    #[test]
    fn halo_shrinks_monotonically_toward_full_frame() {
        // Fixed regions, growing tile height: the re-read halo must
        // shrink to zero as the tile approaches the whole feature-map
        // (the tile cost model degenerates to the whole-frame model).
        let gg = analyze(&zoo::vgg16_conv(224));
        let base = plan(&gg, &cfg(), 4);
        assert!(!base.is_empty());
        let mut prev = u64::MAX;
        for t in [4usize, 8, 16, 32, 64, 255] {
            let mut p = base.clone();
            for r in &mut p.regions {
                r.tile_rows = t;
            }
            let o = overheads(&gg, &cfg(), &p);
            assert!(o.halo_fm_extra <= prev, "halo grew from {prev} at tile {t}");
            prev = o.halo_fm_extra;
        }
        assert_eq!(prev, 0, "255-row tiles cover every zoo frame at 224px");
    }

    #[test]
    fn overlay_keeps_interior_tensors_on_chip() {
        let gg = analyze(&zoo::resnet18(224));
        let c = cfg();
        let p = plan(&gg, &c, 8);
        assert!(!p.is_empty());
        let policy = vec![ReuseMode::Row; gg.groups.len()];
        let mut alloc = crate::alloc::allocate(&gg, &policy, &c);
        apply_overlay(&mut alloc.assigns, &gg, &p);
        for r in &p.regions {
            assert_eq!(alloc.assigns[r.first].in_loc, Loc::Dram, "region input streams from DRAM");
            assert_eq!(alloc.assigns[r.last].out_loc, Loc::Dram, "region output streams to DRAM");
            for g in r.first..r.last {
                assert!(
                    matches!(alloc.assigns[g].out_loc, Loc::Buf(_)),
                    "interior output of {g} must stay on-chip"
                );
                assert_eq!(alloc.assigns[g + 1].in_loc, alloc.assigns[g].out_loc);
            }
        }
    }

    #[test]
    fn budget_bounds_the_tile_working_set() {
        let mut small = cfg();
        small.sram_budget = 1_000_000;
        let gg = analyze(&zoo::vgg16_conv(224));
        let p = plan(&gg, &small, 8);
        assert!(!p.is_empty(), "vgg16 must still tile under 1 MB");
        for r in &p.regions {
            assert!(
                region_tile_buff(&gg, &small, r) <= small.sram_budget,
                "region [{}..={}] overflows the budget",
                r.first,
                r.last
            );
        }
    }

    #[test]
    fn window_math_matches_same_padding() {
        // 3×3 stride-1 SAME on 8 rows: out row 0 needs in rows 0..=1,
        // out rows 3..=4 need 2..=5, the last row needs 6..=7.
        assert_eq!(window(8, 8, 3, 1, 0, 0), (0, 1));
        assert_eq!(window(8, 8, 3, 1, 3, 4), (2, 5));
        assert_eq!(window(8, 8, 3, 1, 7, 7), (6, 7));
        // stride-2: out rows 0..=1 need in rows 0..=3 (pad trims row -1)
        assert_eq!(window(8, 4, 3, 2, 0, 1), (0, 3));
        // pointwise stride-2 downsample (1×1 s2) skips odd rows
        assert_eq!(window(8, 4, 1, 2, 1, 2), (2, 4));
    }
}
