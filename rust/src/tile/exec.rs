//! Tiled functional execution, bit-identical to the whole-frame
//! reference.
//!
//! [`run_tiled`] executes an analyzed network under a [`TilePlan`]:
//! groups outside every region run through the ordinary
//! [`Executor::compute_node`] walk, and each region runs tile-by-tile —
//! per output tile of the region's last group, a backward
//! need-propagation derives the halo-padded row range every region node
//! must produce, then a forward walk computes exactly those rows with
//! the row-windowed op variants in [`crate::funcsim::ops`]. Those
//! variants share the whole-frame ops' inner loops verbatim, and every
//! output pixel of the datapath depends only on its own input window,
//! so recomputed halo rows are idempotent and the result is
//! bit-identical — the cross-check the integration tests pin for every
//! zoo model.
//!
//! Completeness contract: tensors of *region-last* groups and of all
//! non-region nodes are fully computed. Region-*interior* tensors are
//! only guaranteed on rows some tile needed — e.g. a stride-2 1×1
//! convolution never reads odd input rows, so its producer's unused
//! rows stay zero. Nothing downstream may read region-interior tensors,
//! which the planner guarantees by keeping interior consumers inside
//! the region.

use super::{merge, window, TilePlan, TileRegion};
use crate::analyzer::GroupedGraph;
use crate::funcsim::ops;
use crate::funcsim::{ExecError, Executor, Params, Tensor};
use crate::graph::{Activation, Node, NodeId, OpKind};

/// Execute `gg` on `input` under `plan`; returns one value per graph
/// node, exactly like [`Executor::run`] (see the module docs for the
/// region-interior completeness contract).
pub fn run_tiled(
    gg: &GroupedGraph,
    params: &Params,
    input: &Tensor,
    plan: &TilePlan,
) -> Result<Vec<Tensor>, ExecError> {
    let g = &gg.graph;
    if input.shape != g.input().out_shape {
        return Err(ExecError(format!(
            "input shape {} != graph input {}",
            input.shape,
            g.input().out_shape
        )));
    }
    let exec = Executor::new(gg, params);
    let mut values: Vec<Option<Tensor>> = vec![None; g.nodes.len()];
    let mut gi = 0;
    while gi < gg.groups.len() {
        if let Some(region) = plan.region_of(gi) {
            run_region(&exec, region, &mut values, input)?;
            gi = region.last + 1;
        } else {
            for &nid in &gg.groups[gi].nodes {
                let node = g.node(nid);
                let out = exec.compute_node(node, &values, input)?;
                values[nid.0] = Some(out);
            }
            gi += 1;
        }
    }
    Ok(values.into_iter().map(Option::unwrap).collect())
}

/// Run one region tile-by-tile, filling `values` for all its nodes.
fn run_region(
    exec: &Executor<'_>,
    region: &TileRegion,
    values: &mut [Option<Tensor>],
    input: &Tensor,
) -> Result<(), ExecError> {
    let gg = exec.gg;
    let mut region_nodes: Vec<NodeId> = Vec::new();
    for g in region.first..=region.last {
        region_nodes.extend(gg.groups[g].nodes.iter().copied());
    }
    let mut in_region = vec![false; gg.graph.nodes.len()];
    for &nid in &region_nodes {
        in_region[nid.0] = true;
        values[nid.0] = Some(Tensor::zeros(gg.graph.node(nid).out_shape));
    }
    let out_node = *gg.groups[region.last].nodes.last().unwrap();
    let out_h = gg.graph.node(out_node).out_shape.h;
    let t = region.tile_rows.clamp(1, out_h);
    let mut t0 = 0;
    while t0 < out_h {
        let t1 = (t0 + t).min(out_h) - 1;
        // Backward: the output rows each region node must produce so
        // that out_node can produce rows [t0, t1] this tile.
        let mut need: Vec<Option<(usize, usize)>> = vec![None; gg.graph.nodes.len()];
        need[out_node.0] = Some((t0, t1));
        for &nid in region_nodes.iter().rev() {
            let Some((a, b)) = need[nid.0] else { continue };
            let node = gg.graph.node(nid);
            for (pi, &inp) in node.inputs.iter().enumerate() {
                if in_region[inp.0] {
                    let (ia, ib) = node_input_rows(node, pi, a, b);
                    merge(&mut need[inp.0], ia, ib);
                }
            }
        }
        // Forward: compute exactly the needed rows of each node.
        for &nid in &region_nodes {
            let Some((a, b)) = need[nid.0] else { continue };
            compute_node_rows(exec, gg.graph.node(nid), values, input, a, b)?;
        }
        t0 = t1 + 1;
    }
    Ok(())
}

/// Rows `[lo, hi]` of input operand `pi` that `node` reads to produce
/// its output rows `[a, b]`.
fn node_input_rows(node: &Node, pi: usize, a: usize, b: usize) -> (usize, usize) {
    let in_h = node.in_shapes[pi].h;
    match node.op {
        OpKind::Conv { k, stride, .. } => window(in_h, node.out_shape.h, k, stride, a, b),
        OpKind::MaxPool { k, stride } | OpKind::AvgPool { k, stride } => {
            window(in_h, node.out_shape.h, k, stride, a, b)
        }
        OpKind::Upsample { factor } => {
            let f = factor.max(1);
            ((a / f).min(in_h - 1), (b / f).min(in_h - 1))
        }
        // Pointwise in rows: eltwise (both operands), act, BN, bias, id.
        _ => (a.min(in_h - 1), b.min(in_h - 1)),
    }
}

fn get<'v>(values: &'v [Option<Tensor>], id: NodeId) -> Result<&'v Tensor, ExecError> {
    values[id.0]
        .as_ref()
        .ok_or_else(|| ExecError(format!("value of node {} missing", id.0)))
}

/// Compute output rows `[y0, y1]` of one region node into its
/// preallocated tensor, with the same arithmetic as
/// [`Executor::compute_node`].
fn compute_node_rows(
    exec: &Executor<'_>,
    node: &Node,
    values: &mut [Option<Tensor>],
    _input: &Tensor,
    y0: usize,
    y1: usize,
) -> Result<(), ExecError> {
    // Take the output tensor so reading sibling values can't alias it.
    let mut out = values[node.id.0]
        .take()
        .ok_or_else(|| ExecError(format!("tile output of node {} missing", node.id.0)))?;
    match node.op {
        OpKind::Conv { k, stride, depthwise, .. } => {
            let gp = exec
                .group_params(node.id)
                .ok_or_else(|| ExecError(format!("no params for {}", node.name)))?;
            let x = get(values, node.inputs[0])?;
            if depthwise {
                ops::dwconv2d_rows(x, &mut out, k, stride, &gp.weights, &gp.bias, gp.shift, y0, y1);
            } else {
                ops::conv2d_rows(x, &mut out, k, stride, &gp.weights, &gp.bias, gp.shift, y0, y1);
            }
        }
        OpKind::BatchNorm | OpKind::BiasAdd | OpKind::Identity => {
            copy_rows(get(values, node.inputs[0])?, &mut out, y0, y1);
        }
        OpKind::Act(a) => {
            copy_rows(get(values, node.inputs[0])?, &mut out, y0, y1);
            apply_act_rows(exec, &mut out, a, node.id, y0, y1)?;
        }
        OpKind::MaxPool { k, stride } => {
            ops::maxpool_rows(get(values, node.inputs[0])?, &mut out, k, stride, y0, y1);
        }
        OpKind::AvgPool { k, stride } => {
            ops::avgpool_rows(get(values, node.inputs[0])?, &mut out, k, stride, y0, y1);
        }
        OpKind::EltwiseAdd => {
            let shift = exec.group_params(node.id).map(|p| p.elt_shift).unwrap_or(0);
            let a = get(values, node.inputs[0])?;
            let b = get(values, node.inputs[1])?;
            ops::eltwise_add_rows(a, b, &mut out, shift, y0, y1);
        }
        OpKind::Upsample { factor } => {
            ops::upsample_rows(get(values, node.inputs[0])?, &mut out, factor, y0, y1);
        }
        other => {
            return Err(ExecError(format!("op {other:?} cannot execute tiled")));
        }
    }
    values[node.id.0] = Some(out);
    Ok(())
}

/// Copy rows `[y0, y1]` from `src` into `dst` (same shape).
fn copy_rows(src: &Tensor, dst: &mut Tensor, y0: usize, y1: usize) {
    let row = dst.shape.w * dst.shape.c;
    dst.data[y0 * row..(y1 + 1) * row].copy_from_slice(&src.data[y0 * row..(y1 + 1) * row]);
}

/// Row-windowed activation, LUTs included (mirrors the reference
/// executor's activation dispatch).
fn apply_act_rows(
    exec: &Executor<'_>,
    t: &mut Tensor,
    a: Activation,
    node: NodeId,
    y0: usize,
    y1: usize,
) -> Result<(), ExecError> {
    match a {
        Activation::Linear => {}
        Activation::Relu => ops::relu_rows(t, y0, y1),
        Activation::Leaky => ops::leaky_rows(t, y0, y1),
        Activation::Relu6
        | Activation::Swish
        | Activation::Sigmoid
        | Activation::HardSwish
        | Activation::HardSigmoid => {
            let lut = exec
                .group_params(node)
                .and_then(|p| p.lut.as_ref())
                .ok_or_else(|| {
                    ExecError(format!("activation {a:?} at node {} requires a LUT", node.0))
                })?;
            ops::lut_rows(t, lut, y0, y1);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analyzer::analyze;
    use crate::config::AccelConfig;
    use crate::graph::Shape;
    use crate::testutil::Rng;
    use crate::tile;
    use crate::zoo;

    /// Compare run_tiled against the whole-frame reference on the
    /// tensors the completeness contract covers: non-region nodes and
    /// region-last group outputs (which include the network outputs).
    fn assert_tiled_matches(name: &str, input_px: usize, tile_rows: usize) {
        let gg = analyze(&zoo::by_name(name, input_px).unwrap());
        let cfg = AccelConfig::kcu1500_int8();
        let plan = tile::plan(&gg, &cfg, tile_rows);
        assert!(!plan.is_empty(), "{name}: expected at least one region");
        let params = Params::random(&gg, 11);
        let mut rng = Rng::from_seed(12);
        let n = input_px * input_px * 3;
        let input = Tensor::from_vec(Shape::new(input_px, input_px, 3), rng.i8_vec(n));
        let reference = Executor::new(&gg, &params).run(&input).unwrap();
        let tiled = run_tiled(&gg, &params, &input, &plan).unwrap();
        for (ni, node) in gg.graph.nodes.iter().enumerate() {
            let gid = gg.node_group[ni];
            let covered = match plan.region_of(gid.0) {
                None => true,
                Some(r) => gid.0 == r.last && *gg.groups[gid.0].nodes.last().unwrap() == node.id,
            };
            if covered {
                assert_eq!(
                    reference[ni].data, tiled[ni].data,
                    "{name}: node {} ({}) diverges under {tile_rows}-row tiles",
                    ni, node.name
                );
            }
        }
    }

    #[test]
    fn resnet18_bit_identical_under_tiling() {
        assert_tiled_matches("resnet18", 64, 4);
    }

    #[test]
    fn yolov2_bit_identical_under_tiling() {
        assert_tiled_matches("yolov2", 64, 8);
    }

    #[test]
    fn odd_tile_heights_are_bit_identical() {
        // 5 does not divide 64 — exercises the ragged last tile.
        assert_tiled_matches("resnet18", 64, 5);
    }

    #[test]
    fn empty_plan_matches_reference_everywhere() {
        let gg = analyze(&zoo::by_name("tinynet", 32).unwrap());
        let params = Params::random(&gg, 3);
        let mut rng = Rng::from_seed(4);
        let input = Tensor::from_vec(Shape::new(32, 32, 3), rng.i8_vec(32 * 32 * 3));
        let reference = Executor::new(&gg, &params).run(&input).unwrap();
        let tiled = run_tiled(&gg, &params, &input, &TilePlan::default()).unwrap();
        assert_eq!(reference, tiled);
    }
}
