//! The static 3-buffer allocator.

use crate::analyzer::{GroupKind, GroupedGraph};
use crate::config::AccelConfig;
use crate::isa::ReuseMode;

/// Where a tensor lives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Loc {
    /// One of the three physical buffers.
    Buf(u8),
    /// Off-chip.
    Dram,
    /// The small auxiliary SRAM for 1×1×C vectors (SE squeeze results,
    /// FC activations, SE gates — Fig. 13c: "outputs from Global Average
    /// Pooling and two FC layers are stored on-chip because their size is
    /// small").
    Aux,
}

/// Per-group placement decision.
#[derive(Debug, Clone)]
pub struct BufAssign {
    /// Where the main input operand lives.
    pub in_loc: Loc,
    /// Where the output is written.
    pub out_loc: Loc,
    /// Location of the fused-shortcut operand (for groups with
    /// `shortcut_of`) or the second operand (scale gate, concat second).
    pub aux_loc: Option<Loc>,
    /// On-chip output additionally written to DRAM because a concat/route
    /// consumer needs the long-path copy off-chip.
    pub also_dram: bool,
    /// Frame-reuse group whose DRAM-resident input was staged into a
    /// buffer first (costs one DRAM read of the input).
    pub staged_input: bool,
}

/// Allocation outcome: placements plus buffer occupancy statistics.
#[derive(Debug, Clone)]
pub struct AllocResult {
    /// Per-group placement decisions, in program order.
    pub assigns: Vec<BufAssign>,
    /// Peak bytes resident in each physical buffer — Algorithm 1's
    /// `buff[0..2](L)`.
    pub buf_peak: [usize; 3],
    /// Peak bytes in the auxiliary vector SRAM.
    pub aux_peak: usize,
    /// Extra DRAM traffic caused by capacity evictions (bytes).
    pub spill_bytes: u64,
    /// The writeback portion of `spill_bytes` (one store per eviction);
    /// the remainder is re-read traffic. Lets the attribution model
    /// charge spill stores as `ofm` and spill re-reads as `ifm`.
    pub spill_write_bytes: u64,
    /// Number of eviction events behind `spill_bytes`.
    pub spill_events: usize,
}

#[derive(Debug, Clone)]
struct LiveTensor {
    loc: Loc,
    bytes: usize,
    /// Group indices that still need to read this tensor, ascending.
    pending_uses: Vec<usize>,
}

/// Run the reuse-aware static allocation for `policy` (one [`ReuseMode`]
/// per group; non-compute groups follow their block's mode).
pub fn allocate(gg: &GroupedGraph, policy: &[ReuseMode], cfg: &AccelConfig) -> AllocResult {
    assert_eq!(policy.len(), gg.groups.len());
    let qa = cfg.qa;
    let consumers = gg.consumers();
    let n = gg.groups.len();

    let mut live: Vec<Option<LiveTensor>> = vec![None; n];
    let mut assigns: Vec<BufAssign> = Vec::with_capacity(n);
    let mut buf_peak = [0usize; 3];
    let mut aux_peak = 0usize;
    let mut aux_now = 0usize;
    let mut spill_bytes = 0u64;
    let mut spill_write_bytes = 0u64;
    let mut spill_events = 0usize;

    // Buffer occupancy: which producer's tensor sits in each buffer.
    let mut buf_owner: [Option<usize>; 3] = [None; 3];

    for gi in 0..n {
        let gr = &gg.groups[gi];

        if gr.kind == GroupKind::Input {
            // The image arrives in DRAM.
            live[gi] = Some(LiveTensor {
                loc: Loc::Dram,
                bytes: gr.out_shape.bytes(qa),
                pending_uses: consumers[gi].iter().map(|c| c.0).collect(),
            });
            assigns.push(BufAssign {
                in_loc: Loc::Dram,
                out_loc: Loc::Dram,
                aux_loc: None,
                also_dram: false,
                staged_input: false,
            });
            continue;
        }

        // ---- resolve operand locations -------------------------------
        let vector_in = gr.in_shape.h * gr.in_shape.w == 1;
        let main_src = gr.inputs.first().copied();
        let mut in_loc = if vector_in {
            Loc::Aux
        } else {
            main_src
                .map(|s| live[s.0].as_ref().map(|t| t.loc).unwrap_or(Loc::Dram))
                .unwrap_or(Loc::Dram)
        };

        // Second operand: fused shortcut, scale gate, or concat second.
        let aux_src: Option<usize> = if let Some(s) = gr.shortcut_of {
            Some(s.0)
        } else if matches!(gr.kind, GroupKind::Scale | GroupKind::Concat | GroupKind::Eltwise) {
            gr.inputs.get(1).map(|s| s.0)
        } else {
            None
        };
        let aux_loc = aux_src.map(|s| {
            let t = live[s].as_ref();
            let is_vec = gg.groups[s].out_shape.h * gg.groups[s].out_shape.w == 1;
            if is_vec {
                Loc::Aux
            } else {
                t.map(|t| t.loc).unwrap_or(Loc::Dram)
            }
        });

        // Stage a DRAM-resident feature-map input into a buffer for
        // frame-reuse compute (the frame schedule re-reads the input per
        // weight block; a DRAM input is loaded on-chip exactly once).
        let mut staged_input = false;
        if policy[gi] == ReuseMode::Frame
            && in_loc == Loc::Dram
            && !vector_in
            && !matches!(gr.kind, GroupKind::Concat)
        {
            if let Some(src) = main_src {
                let pinned = pinned_bufs(&[aux_loc]);
                let b = take_buffer(
                    &mut buf_owner,
                    &mut live,
                    pinned,
                    gi,
                    &mut spill_bytes,
                    &mut spill_write_bytes,
                    &mut spill_events,
                );
                if let Some(t) = live[src.0].as_mut() {
                    t.loc = Loc::Buf(b);
                    buf_owner[b as usize] = Some(src.0);
                    buf_peak[b as usize] = buf_peak[b as usize].max(t.bytes);
                }
                in_loc = Loc::Buf(b);
                staged_input = true;
            }
        }

        // ---- consume operands -----------------------------------------
        for &src in gr.inputs.iter() {
            consume(&mut live, &mut buf_owner, &mut aux_now, src.0, gi);
        }
        if let Some(s) = gr.shortcut_of {
            consume(&mut live, &mut buf_owner, &mut aux_now, s.0, gi);
        }

        // ---- place the output ------------------------------------------
        let out_bytes = gr.out_shape.bytes(qa);
        let vector_out = gr.out_shape.h * gr.out_shape.w == 1;
        let my_consumers: Vec<usize> = consumers[gi].iter().map(|c| c.0).collect();
        let feeds_concat = my_consumers
            .iter()
            .any(|&c| gg.groups[c].kind == GroupKind::Concat);
        let non_concat_frame = my_consumers
            .iter()
            .filter(|&&c| gg.groups[c].kind != GroupKind::Concat)
            .all(|&c| policy[c] == ReuseMode::Frame);
        let has_non_concat = my_consumers
            .iter()
            .any(|&c| gg.groups[c].kind != GroupKind::Concat);

        let mut also_dram = false;
        let out_loc = if vector_out {
            aux_now += out_bytes;
            aux_peak = aux_peak.max(aux_now);
            Loc::Aux
        } else if my_consumers.is_empty() || gr.kind == GroupKind::Concat {
            // Final outputs and concat destinations live off-chip.
            Loc::Dram
        } else if !has_non_concat {
            // Long-path concat feed only: straight to DRAM (§IV-A).
            Loc::Dram
        } else if policy[gi] == ReuseMode::Frame || non_concat_frame {
            // Frame-reuse output, or a row-reuse group at the cut whose
            // consumers are all frame-reuse: keep on-chip.
            let pinned = pinned_bufs(&[Some(in_loc), aux_loc]);
            let b = take_buffer(
                &mut buf_owner,
                &mut live,
                pinned,
                gi,
                &mut spill_bytes,
                &mut spill_write_bytes,
                &mut spill_events,
            );
            buf_owner[b as usize] = Some(gi);
            buf_peak[b as usize] = buf_peak[b as usize].max(out_bytes);
            also_dram = feeds_concat;
            Loc::Buf(b)
        } else {
            Loc::Dram
        };

        live[gi] = Some(LiveTensor {
            loc: out_loc,
            bytes: out_bytes,
            pending_uses: my_consumers,
        });
        assigns.push(BufAssign { in_loc, out_loc, aux_loc, also_dram, staged_input });
    }

    AllocResult { assigns, buf_peak, aux_peak, spill_bytes, spill_write_bytes, spill_events }
}

fn pinned_bufs(locs: &[Option<Loc>]) -> [bool; 3] {
    let mut pinned = [false; 3];
    for l in locs.iter().flatten() {
        if let Loc::Buf(b) = l {
            pinned[*b as usize] = true;
        }
    }
    pinned
}

/// Pop `user` from the tensor's pending uses; free its space when dead.
fn consume(
    live: &mut [Option<LiveTensor>],
    buf_owner: &mut [Option<usize>; 3],
    aux_now: &mut usize,
    src: usize,
    user: usize,
) {
    if let Some(t) = live[src].as_mut() {
        t.pending_uses.retain(|&u| u != user);
        if t.pending_uses.is_empty() {
            match t.loc {
                Loc::Buf(b) => {
                    if buf_owner[b as usize] == Some(src) {
                        buf_owner[b as usize] = None;
                    }
                }
                Loc::Aux => *aux_now = aux_now.saturating_sub(t.bytes),
                Loc::Dram => {}
            }
            live[src] = None;
        }
    }
}

/// Return a free buffer, evicting the live tensor with the farthest next
/// use to DRAM when all three are occupied (never evicting pinned ones).
fn take_buffer(
    buf_owner: &mut [Option<usize>; 3],
    live: &mut [Option<LiveTensor>],
    pinned: [bool; 3],
    _for_group: usize,
    spill_bytes: &mut u64,
    spill_write_bytes: &mut u64,
    spill_events: &mut usize,
) -> u8 {
    for b in 0..3u8 {
        if buf_owner[b as usize].is_none() && !pinned[b as usize] {
            return b;
        }
    }
    // Belady eviction among un-pinned buffers.
    let victim = (0..3u8)
        .filter(|&b| !pinned[b as usize])
        .max_by_key(|&b| {
            buf_owner[b as usize]
                .and_then(|owner| live[owner].as_ref())
                .and_then(|t| t.pending_uses.first().copied())
                .unwrap_or(usize::MAX)
        })
        .expect("at most 2 of 3 buffers can be pinned");
    let owner = buf_owner[victim as usize].expect("victim buffer has an owner");
    if let Some(t) = live[owner].as_mut() {
        // write back + one read per remaining use
        *spill_bytes += (t.bytes * (1 + t.pending_uses.len())) as u64;
        *spill_write_bytes += t.bytes as u64;
        *spill_events += 1;
        t.loc = Loc::Dram;
    }
    buf_owner[victim as usize] = None;
    victim
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analyzer::analyze;
    use crate::zoo;

    fn frame_policy(n: usize) -> Vec<ReuseMode> {
        vec![ReuseMode::Frame; n]
    }

    fn row_policy(n: usize) -> Vec<ReuseMode> {
        vec![ReuseMode::Row; n]
    }

    #[test]
    fn resnet50_frame_fits_three_buffers() {
        let gg = analyze(&zoo::resnet50(224));
        let cfg = AccelConfig::kcu1500_int8();
        let r = allocate(&gg, &frame_policy(gg.groups.len()), &cfg);
        // Plain residual chains never need more than 3 live buffers.
        assert_eq!(r.spill_events, 0, "unexpected spills: {}", r.spill_events);
        // Largest tensor: conv1 output 112*112*64.
        let max_peak = *r.buf_peak.iter().max().unwrap();
        assert_eq!(max_peak, 112 * 112 * 64);
    }

    #[test]
    fn efficientnet_se_blocks_fit_three_buffers() {
        let gg = analyze(&zoo::efficientnet_b1(256));
        let cfg = AccelConfig::kcu1500_int8();
        let r = allocate(&gg, &frame_policy(gg.groups.len()), &cfg);
        assert_eq!(r.spill_events, 0, "MBConv+SE must fit 3 buffers (Fig 13d)");
        // SE vectors stay in aux, not the big buffers.
        assert!(r.aux_peak > 0 && r.aux_peak < 32 * 1024);
    }

    #[test]
    fn row_policy_streams_everything() {
        let gg = analyze(&zoo::vgg16_conv(224));
        let cfg = AccelConfig::kcu1500_int8();
        let r = allocate(&gg, &row_policy(gg.groups.len()), &cfg);
        assert_eq!(r.buf_peak, [0, 0, 0]);
        for a in &r.assigns[1..] {
            assert_eq!(a.out_loc, Loc::Dram);
        }
    }

    #[test]
    fn shortcut_operand_resolved_on_chip_in_frame_mode() {
        let gg = analyze(&zoo::resnet50(224));
        let cfg = AccelConfig::kcu1500_int8();
        let r = allocate(&gg, &frame_policy(gg.groups.len()), &cfg);
        let mut checked = 0;
        for (gi, gr) in gg.groups.iter().enumerate() {
            if gr.shortcut_of.is_some() {
                match r.assigns[gi].aux_loc {
                    Some(Loc::Buf(_)) => checked += 1,
                    other => panic!("shortcut operand off-chip: {other:?}"),
                }
            }
        }
        assert_eq!(checked, 16);
    }

    #[test]
    fn concat_feeds_go_offchip() {
        let gg = analyze(&zoo::yolov3(416));
        let cfg = AccelConfig::kcu1500_int8();
        let r = allocate(&gg, &frame_policy(gg.groups.len()), &cfg);
        for (gi, gr) in gg.groups.iter().enumerate() {
            if gr.kind == GroupKind::Concat {
                assert_eq!(r.assigns[gi].out_loc, Loc::Dram, "concat dest off-chip");
                for &src in &gr.inputs {
                    let sa = &r.assigns[src.0];
                    let off = sa.also_dram || sa.out_loc == Loc::Dram;
                    assert!(off, "concat operand {} must reach DRAM", src.0);
                }
            }
        }
    }

    #[test]
    fn retinanet_spills_are_bounded() {
        // FPN keeps C3/C4/C5 + laterals alive concurrently; Belady
        // eviction must keep the design legal with bounded extra traffic.
        let gg = analyze(&zoo::retinanet(512));
        let cfg = AccelConfig::kcu1500_int8();
        let r = allocate(&gg, &frame_policy(gg.groups.len()), &cfg);
        assert!(r.spill_events > 0, "expected long-lifetime evictions in FPN");
        assert!(
            r.spill_bytes < 64 * 1024 * 1024,
            "spill traffic blew up: {} bytes",
            r.spill_bytes
        );
    }

    #[test]
    fn input_group_is_dram() {
        let gg = analyze(&zoo::vgg16_conv(224));
        let cfg = AccelConfig::kcu1500_int8();
        let r = allocate(&gg, &frame_policy(gg.groups.len()), &cfg);
        assert_eq!(r.assigns[0].out_loc, Loc::Dram);
        // first conv stages the image on-chip in frame mode
        assert!(r.assigns[1].staged_input);
    }
}
