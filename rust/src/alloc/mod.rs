//! Reuse-aware static memory allocation (§IV-A, Fig. 13).
//!
//! Frame-reuse tensors are pinned to one of the three interchangeable
//! physical buffers {0,1,2}; row-reuse tensors stream through DRAM. The
//! allocator walks groups in program order, tracking tensor liveness, and
//! assigns `{alloc_input, alloc_output, alloc_shortcut}` exactly as
//! Algorithm 1's `buff_alloc` does — including the SE dataflow of
//! Fig. 13(c)/(d) (FC outputs and SE gates live in a small auxiliary
//! space, not the big buffers) and the long-path rule ("data of the
//! long-path shortcut connection for concatenation is stored off-chip to
//! avoid long lifetime data in the on-chip buffers").
//!
//! When all three buffers hold live tensors the allocator evicts the one
//! with the farthest next use (Belady) to DRAM and records the spill —
//! this is what keeps FPN-style graphs (RetinaNet, EfficientDet) legal
//! under 3 physical buffers.

mod static_alloc;
mod offchip;

pub use static_alloc::{allocate, AllocResult, BufAssign, Loc};
pub use offchip::{layout, OffchipArena, OffchipLayout};
