//! Off-chip (DRAM) address-space layout.
//!
//! The inference driver "packs parameters, input and all instructions and
//! sends them at once" (§III-A); this module lays the packed arena out:
//! instructions first, then all layer weights back-to-back, then the
//! network input, then ping-pong regions for row-reuse feature-map
//! streams and concat destinations.

use crate::analyzer::{GroupKind, GroupedGraph};
use crate::config::AccelConfig;
use crate::isa::ReuseMode;

use super::static_alloc::{AllocResult, Loc};

/// A contiguous DRAM allocation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OffchipArena {
    /// Byte offset in the accelerator's DRAM space.
    pub offset: u32,
    /// Allocation size in bytes.
    pub bytes: u32,
}

/// Full DRAM layout for one compiled network.
#[derive(Debug, Clone)]
pub struct OffchipLayout {
    /// Instruction stream region.
    pub instrs: OffchipArena,
    /// Per-group weight slices (zero-length for weight-less groups).
    pub weights: Vec<OffchipArena>,
    /// Network input image.
    pub input: OffchipArena,
    /// Per-group output regions for tensors that live in DRAM
    /// (zero-length when the output is on-chip only).
    pub fmaps: Vec<OffchipArena>,
    /// One past the last allocated byte.
    pub end: u32,
}

impl OffchipLayout {
    /// Total DRAM footprint in bytes.
    pub fn footprint(&self) -> u32 {
        self.end
    }
}

/// Lay out the DRAM arena. Feature-map regions are allocated for every
/// group whose output (or long-path copy) reaches DRAM; ping-pong reuse
/// of dead regions is applied so the footprint stays close to the live
/// working set.
pub fn layout(
    gg: &GroupedGraph,
    _policy: &[ReuseMode],
    alloc: &AllocResult,
    cfg: &AccelConfig,
) -> OffchipLayout {
    let qa = cfg.qa as u32;
    let qw = cfg.qw as u64;
    let mut cursor: u32;
    let align = |c: u32| (c + 63) & !63;

    // 1. instruction stream
    let instr_bytes = (gg.groups.len() * crate::isa::WORDS_PER_INSTR * 4) as u32;
    let instrs = OffchipArena { offset: 0, bytes: instr_bytes };
    cursor = align(instr_bytes);

    // 2. weights, packed in execution order
    let mut weights = Vec::with_capacity(gg.groups.len());
    for gr in &gg.groups {
        let wb = gr.weight_bytes(&gg.graph, qw) as u32;
        weights.push(OffchipArena { offset: cursor, bytes: wb });
        cursor = align(cursor + wb);
    }

    // 3. network input
    let in_bytes = gg.graph.input().out_shape.bytes(qa as usize) as u32;
    let input = OffchipArena { offset: cursor, bytes: in_bytes };
    cursor = align(cursor + in_bytes);

    // 4. DRAM-resident feature maps with ping-pong region reuse.
    let consumers = gg.consumers();
    let mut fmaps = vec![OffchipArena { offset: 0, bytes: 0 }; gg.groups.len()];
    // free list of (offset, bytes) regions whose tensor died
    let mut free: Vec<(u32, u32)> = Vec::new();
    let mut last_use: Vec<usize> = (0..gg.groups.len())
        .map(|g| consumers[g].iter().map(|c| c.0).max().unwrap_or(g))
        .collect();
    // Network outputs must persist to the end.
    for g in 0..gg.groups.len() {
        if consumers[g].is_empty() {
            last_use[g] = usize::MAX;
        }
    }
    let mut expiry: Vec<(usize, usize)> = Vec::new(); // (dies_at, group)

    for (gi, gr) in gg.groups.iter().enumerate() {
        // release regions whose tensors are dead by now
        expiry.retain(|&(dies, g)| {
            if dies < gi {
                free.push((fmaps[g].offset, fmaps[g].bytes));
                false
            } else {
                true
            }
        });

        let needs_dram = gi != 0
            && (alloc.assigns[gi].out_loc == Loc::Dram || alloc.assigns[gi].also_dram)
            && gr.kind != GroupKind::Input
            && gr.out_shape.h * gr.out_shape.w > 1;
        if !needs_dram {
            continue;
        }
        let bytes = gr.out_shape.bytes(qa as usize) as u32;
        // first-fit from the free list
        let slot = free
            .iter()
            .position(|&(_, b)| b >= bytes)
            .map(|i| free.remove(i));
        let offset = match slot {
            Some((off, b)) => {
                if b > bytes {
                    free.push((off + bytes, b - bytes));
                }
                off
            }
            None => {
                let off = cursor;
                cursor = align(cursor + bytes);
                off
            }
        };
        fmaps[gi] = OffchipArena { offset, bytes };
        if last_use[gi] != usize::MAX {
            expiry.push((last_use[gi], gi));
        }
    }

    OffchipLayout { instrs, weights, input, fmaps, end: cursor }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::allocate;
    use crate::analyzer::analyze;
    use crate::zoo;

    fn mk(
        model: &str,
        mode: ReuseMode,
    ) -> (GroupedGraph, Vec<ReuseMode>, AllocResult, AccelConfig) {
        let gg = analyze(&zoo::by_name(model, zoo::default_input(model)).unwrap());
        let cfg = AccelConfig::kcu1500_int8();
        let policy = vec![mode; gg.groups.len()];
        let alloc = allocate(&gg, &policy, &cfg);
        (gg, policy, alloc, cfg)
    }

    #[test]
    fn regions_do_not_overlap_live_ranges() {
        let (gg, policy, alloc, cfg) = mk("yolov3", ReuseMode::Row);
        let l = layout(&gg, &policy, &alloc, &cfg);
        // weights are disjoint and ordered
        for w in l.weights.windows(2) {
            assert!(w[0].offset + w[0].bytes <= w[1].offset || w[1].bytes == 0 || w[0].bytes == 0);
        }
        // fmap regions of two simultaneously-live tensors never overlap
        let consumers = gg.consumers();
        let n = gg.groups.len();
        for a in 0..n {
            for b in (a + 1)..n {
                let (fa, fb) = (l.fmaps[a], l.fmaps[b]);
                if fa.bytes == 0 || fb.bytes == 0 {
                    continue;
                }
                let a_dies = consumers[a].iter().map(|c| c.0).max().unwrap_or(usize::MAX);
                // b is produced at index b; a live iff a_dies >= b
                let overlap_time = a_dies >= b;
                let overlap_space =
                    fa.offset < fb.offset + fb.bytes && fb.offset < fa.offset + fa.bytes;
                assert!(
                    !(overlap_time && overlap_space),
                    "regions overlap for live tensors {a} and {b}"
                );
            }
        }
    }

    #[test]
    fn row_mode_footprint_is_modest() {
        // Ping-pong reuse keeps the YOLOv2 row-mode arena below the
        // "every tensor gets fresh DRAM" worst case.
        let (gg, policy, alloc, cfg) = mk("yolov2", ReuseMode::Row);
        let l = layout(&gg, &policy, &alloc, &cfg);
        let naive: u64 = gg
            .groups
            .iter()
            .map(|g| g.out_shape.bytes(cfg.qa) as u64)
            .sum::<u64>()
            + gg.graph.total_weight_bytes(cfg.qw as u64);
        assert!((l.footprint() as u64) < naive, "no reuse achieved");
    }

    #[test]
    fn weights_cover_model_size() {
        let (gg, policy, alloc, cfg) = mk("resnet50", ReuseMode::Frame);
        let l = layout(&gg, &policy, &alloc, &cfg);
        let total_w: u64 = l.weights.iter().map(|w| w.bytes as u64).sum();
        assert_eq!(total_w, gg.graph.total_weight_bytes(cfg.qw as u64));
    }
}
