//! Lowering: grouped graph + memory assignment → instruction stream.

use super::encode::{encode, Instruction, Opcode, ReuseMode, WORDS_PER_INSTR};
use super::MemLoc;
use crate::analyzer::{GroupKind, GroupedGraph};

/// Per-group memory assignment produced by the reuse-aware allocator
/// ([`crate::alloc`]): the reuse scheme, where each operand lives, and the
/// weight arena slice.
#[derive(Debug, Clone)]
pub struct MemAssign {
    /// Weight-reuse scheme the group runs under.
    pub reuse: ReuseMode,
    /// Where the main input operand lives.
    pub in_loc: MemLoc,
    /// Where the output is written.
    pub out_loc: MemLoc,
    /// Second operand (shortcut / concat second input / SE gate).
    pub aux_loc: Option<MemLoc>,
    /// Byte offset of the group's weights in the DRAM weight arena.
    pub weight_addr: u32,
    /// Weight bytes streamed for this group.
    pub weight_bytes: u32,
    /// Dynamic fixed-point output shift.
    pub quant_shift: i8,
    /// Depth-first tile height of the group's fused region (0 = whole
    /// frame; see [`crate::tile`]).
    pub tile_rows: u8,
    /// First group of a fused tile region.
    pub tile_first: bool,
    /// Weights re-streamed from DRAM once per tile.
    pub tile_weight_stream: bool,
}

impl Default for MemAssign {
    fn default() -> Self {
        MemAssign {
            reuse: ReuseMode::Row,
            in_loc: MemLoc::Dram(0),
            out_loc: MemLoc::Dram(0),
            aux_loc: None,
            weight_addr: 0,
            weight_bytes: 0,
            quant_shift: 0,
            tile_rows: 0,
            tile_first: false,
            tile_weight_stream: false,
        }
    }
}

/// The packed program for one network: decoded instructions plus the raw
/// word stream that would be DMA'd to the accelerator.
#[derive(Debug, Clone)]
pub struct InstructionStream {
    /// Decoded instruction per group, in program order.
    pub instrs: Vec<Instruction>,
    /// The packed 11-words-per-group stream.
    pub words: Vec<u32>,
}

impl InstructionStream {
    /// Number of instructions (= groups).
    pub fn len(&self) -> usize {
        self.instrs.len()
    }

    /// `true` for an empty stream.
    pub fn is_empty(&self) -> bool {
        self.instrs.is_empty()
    }

    /// Total byte size of the packed stream.
    pub fn byte_size(&self) -> usize {
        self.words.len() * 4
    }
}

/// Lower every group to its 11-word instruction. `assigns` must be
/// parallel to `gg.groups`.
pub fn lower(gg: &GroupedGraph, assigns: &[MemAssign]) -> InstructionStream {
    assert_eq!(gg.groups.len(), assigns.len(), "one MemAssign per group");
    let mut instrs = Vec::with_capacity(gg.groups.len());
    let mut words = Vec::with_capacity(gg.groups.len() * WORDS_PER_INSTR);
    for (gr, asg) in gg.groups.iter().zip(assigns) {
        let (k, stride, _dw) = gr.conv_geometry(&gg.graph);
        let opcode = match gr.kind {
            GroupKind::Input => Opcode::Input,
            GroupKind::Conv => Opcode::Conv,
            GroupKind::DwConv => Opcode::DwConv,
            GroupKind::Fc => Opcode::Fc,
            GroupKind::Scale => Opcode::Scale,
            GroupKind::Pool => Opcode::Pool,
            GroupKind::Eltwise => Opcode::Eltwise,
            GroupKind::Concat => Opcode::Concat,
            GroupKind::Upsample => Opcode::Upsample,
            GroupKind::Act => Opcode::Copy,
        };
        let instr = Instruction {
            group: gr.id.0 as u32,
            opcode,
            act: gr.act,
            reuse: asg.reuse,
            k: k as u8,
            stride: stride as u8,
            pad_same: true,
            in_h: gr.in_shape.h as u16,
            in_w: gr.in_shape.w as u16,
            in_c: gr.in_shape.c as u16,
            out_h: gr.out_shape.h as u16,
            out_w: gr.out_shape.w as u16,
            out_c: gr.out_shape.c as u16,
            pool: gr.pool.map(|(pk, k, s)| (pk, k as u8, s as u8)),
            upsample: gr.upsample.unwrap_or(0) as u8,
            fused_eltwise: gr.shortcut_of.is_some(),
            se_squeeze: gr.se_squeeze,
            quant_shift: asg.quant_shift,
            in_sel: asg.in_loc.selector() as u8,
            out_sel: asg.out_loc.selector() as u8,
            aux_sel: asg.aux_loc.map(|l| l.selector() as u8).unwrap_or(3),
            // On-chip operands carry 0 in the address word; the 2-bit
            // selector (not the address) is what marks them as buffers.
            in_addr: asg.in_loc.dram_addr().unwrap_or(0),
            out_addr: asg.out_loc.dram_addr().unwrap_or(0),
            aux_addr: asg.aux_loc.and_then(|l| l.dram_addr()).unwrap_or(0),
            weight_addr: asg.weight_addr,
            weight_bytes: asg.weight_bytes,
            tile_rows: asg.tile_rows,
            tile_first: asg.tile_first,
            tile_weight_stream: asg.tile_weight_stream,
        };
        words.extend_from_slice(&encode(&instr));
        instrs.push(instr);
    }
    InstructionStream { instrs, words }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analyzer::analyze;
    use crate::isa::decode;
    use crate::zoo;

    #[test]
    fn lower_resnet50_round_trips() {
        let gg = analyze(&zoo::resnet50(224));
        let assigns = vec![MemAssign::default(); gg.groups.len()];
        let stream = lower(&gg, &assigns);
        assert_eq!(stream.len(), gg.groups.len());
        assert_eq!(stream.words.len(), gg.groups.len() * WORDS_PER_INSTR);
        // every encoded instruction decodes back to the stored one
        for (i, ins) in stream.instrs.iter().enumerate() {
            let chunk: [u32; WORDS_PER_INSTR] =
                stream.words[i * WORDS_PER_INSTR..(i + 1) * WORDS_PER_INSTR].try_into().unwrap();
            assert_eq!(&decode(&chunk).unwrap(), ins);
        }
    }

    #[test]
    fn fused_flags_survive_lowering() {
        let gg = analyze(&zoo::efficientnet_b1(256));
        let assigns = vec![MemAssign::default(); gg.groups.len()];
        let stream = lower(&gg, &assigns);
        let fused_elt = stream.instrs.iter().filter(|i| i.fused_eltwise).count();
        let se = stream.instrs.iter().filter(|i| i.se_squeeze).count();
        assert_eq!(fused_elt, 16);
        assert_eq!(se, 23);
    }

    #[test]
    #[should_panic(expected = "one MemAssign per group")]
    fn mismatched_assign_len_panics() {
        let gg = analyze(&zoo::vgg16_conv(224));
        lower(&gg, &[]);
    }
}
