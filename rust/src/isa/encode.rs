//! Instruction word format: encode / decode.

use crate::analyzer::PoolKind;
use crate::graph::Activation;
use std::fmt;

/// Words per group instruction (Fig. 5b: "11 words").
pub const WORDS_PER_INSTR: usize = 11;

/// Magic tag in word 10 for stream-integrity checking.
const MAGIC: u32 = 0x5C;

/// Weight-reuse scheme of a group (§II): `Row` streams feature-maps
/// through DRAM with the whole layer weights resident on-chip; `Frame`
/// keeps feature-maps in the physical buffers and streams weight blocks.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ReuseMode {
    /// Row-based weight reuse: whole-layer weights resident on-chip.
    Row,
    /// Frame-based weight reuse: whole feature frames resident on-chip.
    Frame,
}

/// Datapath opcode (4 bits).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Opcode {
    /// Network-input placeholder group.
    Input = 0,
    /// Normal convolution.
    Conv = 1,
    /// Depthwise convolution.
    DwConv = 2,
    /// Fully-connected layer.
    Fc = 3,
    /// SE excitation channel scale.
    Scale = 4,
    /// Standalone pooling.
    Pool = 5,
    /// Standalone element-wise addition.
    Eltwise = 6,
    /// Channel concatenation (memory redirection).
    Concat = 7,
    /// Standalone nearest-neighbour upsampling.
    Upsample = 8,
    /// Standalone activation / copy.
    Copy = 9,
}

impl Opcode {
    fn from_u32(v: u32) -> Option<Opcode> {
        Some(match v {
            0 => Opcode::Input,
            1 => Opcode::Conv,
            2 => Opcode::DwConv,
            3 => Opcode::Fc,
            4 => Opcode::Scale,
            5 => Opcode::Pool,
            6 => Opcode::Eltwise,
            7 => Opcode::Concat,
            8 => Opcode::Upsample,
            9 => Opcode::Copy,
            _ => return None,
        })
    }
}

/// A fully-specified group instruction (decoded form).
#[derive(Debug, Clone, PartialEq)]
pub struct Instruction {
    /// Group index in program order (also echoed in word 10).
    pub group: u32,
    /// Datapath opcode.
    pub opcode: Opcode,
    /// Output activation.
    pub act: Activation,
    /// Weight-reuse scheme this group runs under.
    pub reuse: ReuseMode,
    /// Convolution kernel size (1 for non-conv groups).
    pub k: u8,
    /// Convolution stride (1 for non-conv groups).
    pub stride: u8,
    /// TensorFlow `Same` padding when set, `Valid` otherwise.
    pub pad_same: bool,
    /// Input feature-map height.
    pub in_h: u16,
    /// Input feature-map width.
    pub in_w: u16,
    /// Input channel count.
    pub in_c: u16,
    /// Output feature-map height.
    pub out_h: u16,
    /// Output feature-map width.
    pub out_w: u16,
    /// Output channel count.
    pub out_c: u16,
    /// Fused trailing pooling.
    pub pool: Option<(PoolKind, u8, u8)>,
    /// Fused nearest-neighbour upsampling factor (0 = none).
    pub upsample: u8,
    /// Fused element-wise shortcut addition.
    pub fused_eltwise: bool,
    /// Parallel SE squeeze output (GAP during writeback, Fig. 13d).
    pub se_squeeze: bool,
    /// Dynamic fixed-point output shift (§III-B).
    pub quant_shift: i8,
    /// Input buffer selector (2 bits; 3 = DRAM).
    pub in_sel: u8,
    /// Output buffer selector (2 bits; 3 = DRAM).
    pub out_sel: u8,
    /// Second-operand selector (shortcut / concat's second input /
    /// SE-scale gate).
    pub aux_sel: u8,
    /// Input DRAM byte offset (meaningful when `in_sel` = 3).
    pub in_addr: u32,
    /// Output DRAM byte offset (meaningful when `out_sel` = 3).
    pub out_addr: u32,
    /// Second-operand DRAM byte offset (meaningful when `aux_sel` = 3).
    pub aux_addr: u32,
    /// Byte offset of the group's weights in the DRAM weight arena.
    pub weight_addr: u32,
    /// Weight bytes streamed for this group.
    pub weight_bytes: u32,
    /// Depth-first tile height (output rows of the fused region's last
    /// group per tile iteration); 0 = whole-frame execution.
    pub tile_rows: u8,
    /// First instruction of a fused tile region (opens the tile loop).
    pub tile_first: bool,
    /// Weights re-streamed from DRAM once per tile instead of held
    /// resident for the whole frame.
    pub tile_weight_stream: bool,
}

impl Default for Instruction {
    fn default() -> Self {
        Instruction {
            group: 0,
            opcode: Opcode::Copy,
            act: Activation::Linear,
            reuse: ReuseMode::Row,
            k: 1,
            stride: 1,
            pad_same: true,
            in_h: 0,
            in_w: 0,
            in_c: 0,
            out_h: 0,
            out_w: 0,
            out_c: 0,
            pool: None,
            upsample: 0,
            fused_eltwise: false,
            se_squeeze: false,
            quant_shift: 0,
            in_sel: 3,
            out_sel: 3,
            aux_sel: 3,
            in_addr: 0,
            out_addr: 0,
            aux_addr: 0,
            weight_addr: 0,
            weight_bytes: 0,
            tile_rows: 0,
            tile_first: false,
            tile_weight_stream: false,
        }
    }
}

fn act_code(a: Activation) -> u32 {
    match a {
        Activation::Linear => 0,
        Activation::Relu => 1,
        Activation::Leaky => 2,
        Activation::Relu6 => 3,
        Activation::Swish => 4,
        Activation::Sigmoid => 5,
        Activation::HardSwish => 6,
        Activation::HardSigmoid => 7,
    }
}

fn act_from(code: u32) -> Option<Activation> {
    Some(match code {
        0 => Activation::Linear,
        1 => Activation::Relu,
        2 => Activation::Leaky,
        3 => Activation::Relu6,
        4 => Activation::Swish,
        5 => Activation::Sigmoid,
        6 => Activation::HardSwish,
        7 => Activation::HardSigmoid,
        _ => return None,
    })
}

fn pool_code(p: Option<(PoolKind, u8, u8)>) -> (u32, u32, u32) {
    match p {
        None => (0, 0, 0),
        Some((PoolKind::Max, k, s)) => (1, k as u32, s as u32),
        Some((PoolKind::Avg, k, s)) => (2, k as u32, s as u32),
        Some((PoolKind::Global, _, _)) => (3, 0, 0),
    }
}

/// Encode to the 11-word wire format.
///
/// ```text
/// w0  opcode[3:0] act[7:4] reuse[8] pad[9] elt[10] se[11]
///     pool_kind[13:12] tile_first[14] tile_wstream[15]
///     k[19:16] stride[23:20] upsample[27:24]
/// w1  in_h[31:16] in_w[15:0]
/// w2  in_c[31:16] out_c[15:0]
/// w3  out_h[31:16] out_w[15:0]
/// w4  pool_k[7:0] pool_s[15:8] in_sel[17:16] out_sel[19:18]
///     aux_sel[21:20] quant_shift[31:24]
/// w5  in_addr    w6 out_addr   w7 aux_addr
/// w8  weight_addr  w9 weight_bytes
/// w10 group[15:0] tile_rows[23:16] magic[31:24]
/// ```
///
/// Untiled programs carry zeros in every tile field, so their word
/// streams are byte-identical to the pre-tile wire format.
pub fn encode(i: &Instruction) -> [u32; WORDS_PER_INSTR] {
    let (pk, pool_k, pool_s) = pool_code(i.pool);
    let w0 = (i.opcode as u32)
        | (act_code(i.act) << 4)
        | (((i.reuse == ReuseMode::Frame) as u32) << 8)
        | ((i.pad_same as u32) << 9)
        | ((i.fused_eltwise as u32) << 10)
        | ((i.se_squeeze as u32) << 11)
        | (pk << 12)
        | ((i.tile_first as u32) << 14)
        | ((i.tile_weight_stream as u32) << 15)
        | ((i.k as u32 & 0xF) << 16)
        | ((i.stride as u32 & 0xF) << 20)
        | ((i.upsample as u32 & 0xF) << 24);
    let w4 = pool_k
        | (pool_s << 8)
        | ((i.in_sel as u32 & 3) << 16)
        | ((i.out_sel as u32 & 3) << 18)
        | ((i.aux_sel as u32 & 3) << 20)
        | (((i.quant_shift as u8) as u32) << 24);
    [
        w0,
        ((i.in_h as u32) << 16) | i.in_w as u32,
        ((i.in_c as u32) << 16) | i.out_c as u32,
        ((i.out_h as u32) << 16) | i.out_w as u32,
        w4,
        i.in_addr,
        i.out_addr,
        i.aux_addr,
        i.weight_addr,
        i.weight_bytes,
        (i.group & 0xFFFF) | ((i.tile_rows as u32) << 16) | (MAGIC << 24),
    ]
}

/// Decode failure (bad magic / invalid field).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DecodeError(pub String);

impl fmt::Display for DecodeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "instruction decode error: {}", self.0)
    }
}

impl std::error::Error for DecodeError {}

/// Decode an 11-word instruction; validates the magic tag and enums.
pub fn decode(w: &[u32; WORDS_PER_INSTR]) -> Result<Instruction, DecodeError> {
    if w[10] >> 24 != MAGIC {
        return Err(DecodeError(format!("bad magic {:#x}", w[10] >> 24)));
    }
    let opcode = Opcode::from_u32(w[0] & 0xF).ok_or_else(|| DecodeError("bad opcode".into()))?;
    let act = act_from((w[0] >> 4) & 0xF).ok_or_else(|| DecodeError("bad activation".into()))?;
    let pool = match (w[0] >> 12) & 0x3 {
        0 => None,
        1 => Some((PoolKind::Max, (w[4] & 0xFF) as u8, ((w[4] >> 8) & 0xFF) as u8)),
        2 => Some((PoolKind::Avg, (w[4] & 0xFF) as u8, ((w[4] >> 8) & 0xFF) as u8)),
        _ => Some((PoolKind::Global, 0, 0)),
    };
    Ok(Instruction {
        group: w[10] & 0xFFFF,
        opcode,
        act,
        reuse: if (w[0] >> 8) & 1 == 1 { ReuseMode::Frame } else { ReuseMode::Row },
        k: ((w[0] >> 16) & 0xF) as u8,
        stride: ((w[0] >> 20) & 0xF) as u8,
        pad_same: (w[0] >> 9) & 1 == 1,
        in_h: (w[1] >> 16) as u16,
        in_w: (w[1] & 0xFFFF) as u16,
        in_c: (w[2] >> 16) as u16,
        out_c: (w[2] & 0xFFFF) as u16,
        out_h: (w[3] >> 16) as u16,
        out_w: (w[3] & 0xFFFF) as u16,
        pool,
        upsample: ((w[0] >> 24) & 0xF) as u8,
        fused_eltwise: (w[0] >> 10) & 1 == 1,
        se_squeeze: (w[0] >> 11) & 1 == 1,
        quant_shift: ((w[4] >> 24) & 0xFF) as u8 as i8,
        in_sel: ((w[4] >> 16) & 3) as u8,
        out_sel: ((w[4] >> 18) & 3) as u8,
        aux_sel: ((w[4] >> 20) & 3) as u8,
        in_addr: w[5],
        out_addr: w[6],
        aux_addr: w[7],
        weight_addr: w[8],
        weight_bytes: w[9],
        tile_rows: ((w[10] >> 16) & 0xFF) as u8,
        tile_first: (w[0] >> 14) & 1 == 1,
        tile_weight_stream: (w[0] >> 15) & 1 == 1,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{forall, random_instruction};

    #[test]
    fn round_trip_random_instructions() {
        forall("encode∘decode = id", 500, |rng| {
            let i = random_instruction(rng);
            let words = encode(&i);
            let j = decode(&words).unwrap();
            assert_eq!(i, j);
        });
    }

    #[test]
    fn eleven_words() {
        assert_eq!(WORDS_PER_INSTR, 11);
        assert_eq!(encode(&Instruction::default()).len(), 11);
    }

    #[test]
    fn rejects_bad_magic() {
        let mut w = encode(&Instruction::default());
        w[10] = 0;
        assert!(decode(&w).is_err());
    }

    #[test]
    fn rejects_bad_opcode() {
        let mut w = encode(&Instruction::default());
        w[0] = (w[0] & !0xF) | 0xE;
        assert!(decode(&w).is_err());
    }

    #[test]
    fn quant_shift_sign_preserved() {
        let mut i = Instruction::default();
        i.quant_shift = -5;
        assert_eq!(decode(&encode(&i)).unwrap().quant_shift, -5);
    }

    #[test]
    fn tile_fields_round_trip() {
        let mut i = Instruction::default();
        i.tile_rows = 16;
        i.tile_first = true;
        i.tile_weight_stream = true;
        let j = decode(&encode(&i)).unwrap();
        assert_eq!(j.tile_rows, 16);
        assert!(j.tile_first);
        assert!(j.tile_weight_stream);
    }

    #[test]
    fn untiled_words_are_bit_identical_to_pre_tile_format() {
        // All tile fields zero: w0 bits 14/15 and w10[23:16] stay clear,
        // so untiled programs re-encode byte-identically to the format
        // before tile streaming existed.
        let w = encode(&Instruction::default());
        assert_eq!(w[0] & (0b11 << 14), 0);
        assert_eq!((w[10] >> 16) & 0xFF, 0);
    }
}
