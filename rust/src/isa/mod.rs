//! Group-wise instruction set (Fig. 5b).
//!
//! Each node group compiles to an 11-word (32-bit) instruction "describing
//! convolution size, activation type, pooling/upsampling option, fused
//! element-wise, etc." plus the static memory assignment produced by the
//! reuse-aware allocator (on-chip buffer ids + off-chip addresses). The
//! inference driver packs parameters, input, and *all* instructions and
//! ships them to the accelerator at once (§III-A).

mod encode;
mod lower;

pub use encode::{decode, encode, DecodeError, Instruction, Opcode, ReuseMode, WORDS_PER_INSTR};
pub use lower::{lower, InstructionStream, MemAssign};

/// On-chip physical buffer id {0,1,2} or DRAM.
///
/// The accelerator has three interchangeable SRAM buffers used for the
/// input / output / shortcut tensors of frame-reuse layers (§III-B);
/// row-reuse tensors live in DRAM.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MemLoc {
    /// One of the three on-chip physical buffers.
    Buf(u8),
    /// Off-chip, at a byte offset in the accelerator's DRAM arena.
    Dram(u32),
}

impl MemLoc {
    /// 2-bit buffer selector for the instruction word; 3 = DRAM.
    pub fn selector(&self) -> u32 {
        match self {
            MemLoc::Buf(b) => {
                debug_assert!(*b < 3);
                *b as u32
            }
            MemLoc::Dram(_) => 3,
        }
    }

    /// DRAM byte offset of an off-chip operand; `None` for on-chip
    /// buffers, so a mis-lowered buffer operand can never silently alias
    /// DRAM address 0 (callers must decide what a missing address means —
    /// the lowerer writes 0 into the word *because* the selector field
    /// already marks the operand as on-chip).
    pub fn dram_addr(&self) -> Option<u32> {
        match self {
            MemLoc::Dram(a) => Some(*a),
            MemLoc::Buf(_) => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dram_addr_is_none_for_buffers() {
        assert_eq!(MemLoc::Buf(0).dram_addr(), None);
        assert_eq!(MemLoc::Buf(2).dram_addr(), None);
        assert_eq!(MemLoc::Dram(0).dram_addr(), Some(0));
        assert_eq!(MemLoc::Dram(4096).dram_addr(), Some(4096));
    }

    #[test]
    fn selector_distinguishes_buf_from_dram() {
        assert_eq!(MemLoc::Buf(1).selector(), 1);
        assert_eq!(MemLoc::Dram(0).selector(), 3);
    }
}
