//! # ShortcutFusion
//!
//! Reproduction of *"ShortcutFusion: From Tensorflow to FPGA-based
//! accelerator with a reuse-aware memory allocation for shortcut data"*
//! (Nguyen et al., IEEE TCSI 2022).
//!
//! ShortcutFusion is an end-to-end CNN compiler + accelerator co-design:
//! a frozen CNN graph is parsed, fused into accelerator groups, assigned a
//! per-block weight-reuse scheme (row-based vs frame-based) by a
//! *reuse-aware shortcut optimizer* with static 3-buffer memory
//! allocation, lowered to an 11-word instruction stream, and executed on a
//! (here: simulated) shared-MAC-array accelerator.
//!
//! The pipeline mirrors Fig. 4 of the paper:
//!
//! ```text
//! frozen graph ──> analyzer (fusion) ──> reuse-aware optimizer ──┐
//!                                                                ▼
//!  funcsim  <── isa instruction stream <── static memory allocation
//!     │                                        │
//!     ▼                                        ▼
//!  verify vs JAX golden (PJRT)          cycle-accurate timing sim
//! ```
//!
//! See `DESIGN.md` for the full system inventory and the hardware
//! substitutions (FPGA → cycle-accurate simulator, GPU → analytical model).

pub mod config;
pub mod graph;
pub mod serialize;
pub mod zoo;
pub mod analyzer;
pub mod isa;
pub mod optimizer;
pub mod alloc;
pub mod sim;
pub mod funcsim;
pub mod power;
pub mod baselines;
pub mod runtime;
pub mod coordinator;
pub mod bench;
pub mod testutil;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
