//! # ShortcutFusion
//!
//! Reproduction of *"ShortcutFusion: From Tensorflow to FPGA-based
//! accelerator with a reuse-aware memory allocation for shortcut data"*
//! (Nguyen et al., IEEE TCSI 2022).
//!
//! ShortcutFusion is an end-to-end CNN compiler + accelerator co-design:
//! a frozen CNN graph is parsed, fused into accelerator groups, assigned a
//! per-block weight-reuse scheme (row-based vs frame-based) by a
//! *reuse-aware shortcut optimizer* with static 3-buffer memory
//! allocation, lowered to an 11-word instruction stream, and executed on a
//! (here: simulated) shared-MAC-array accelerator.
//!
//! ## The staged compile API
//!
//! The paper's Fig.-4 pipeline is exposed by [`compiler`] as five typed
//! stages, each an owned, cacheable artifact:
//!
//! ```text
//! Graph ─analyze→ Analyzed ─optimize→ Optimized ─allocate→ Allocated
//!                                        ─lower→ Lowered ─simulate→ Simulated
//! ```
//!
//! ```no_run
//! use shortcutfusion::compiler::Compiler;
//! use shortcutfusion::config::AccelConfig;
//! use shortcutfusion::zoo;
//!
//! let compiler = Compiler::new(AccelConfig::kcu1500_int8());
//! let report = compiler.compile(&zoo::yolov2(416)).unwrap();
//! println!("{}: {:.2} ms, {:.1} % off-chip reduction",
//!          report.model, report.latency_ms(), report.reduction_pct());
//! ```
//!
//! Reuse-policy selection is pluggable through
//! [`compiler::ReuseStrategy`]: the paper's cut-point optimizer is the
//! default, and the evaluation baselines (fixed row/frame,
//! ShortcutMining [8], SmartShuttle [12]) implement the same trait, so
//! every Table II/IV/VI comparison runs through one compile path.
//!
//! Multi-model / multi-config sweeps go through [`compiler::Session`],
//! which memoizes stage artifacts per `(model, input, config, strategy)`
//! and fans jobs out over scoped threads:
//!
//! ```no_run
//! use shortcutfusion::compiler::Session;
//! use shortcutfusion::config::AccelConfig;
//!
//! let session = Session::new();
//! for r in session.sweep_zoo(&AccelConfig::kcu1500_int8(), 8) {
//!     let r = r.unwrap();
//!     println!("{}: {:.2} ms", r.model, r.latency_ms());
//! }
//! ```
//!
//! Failures are typed ([`compiler::CompileError`]); the deprecated
//! one-shot `coordinator::compile_model` remains as a thin wrapper over
//! the stages (see `MIGRATION.md` for the porting guide).
//!
//! ## The run side: `Program` + `ExecutionBackend` + `InferenceEngine`
//!
//! `Compiler::pack` collapses a [`compiler::Lowered`] artifact into a
//! deployable [`program::Program`] — the §III-A driver payload
//! (instruction stream, memory assignment, target config, optional
//! quantized parameters) with a versioned, checksummed binary
//! `save`/`load`. Execution is unified behind
//! [`engine::ExecutionBackend`] with three implementations —
//! bit-exact [`engine::ReferenceBackend`], cost-modeling
//! [`engine::VirtualAccelBackend`], and the feature-gated
//! [`engine::PjrtBackend`] — and [`engine::InferenceEngine`] serves
//! concurrent batched requests on top (see the `pack`, `run`, and
//! `serve-bench` CLI commands and `benches/serving.rs`).
//!
//! ## Telemetry: `telemetry`
//!
//! The stack is observable end to end through [`telemetry`]: a
//! [`telemetry::TraceSink`] trait with a lock-sharded
//! [`telemetry::TraceRecorder`] exporting Chrome trace-event JSON
//! (`--trace-out` on `run`/`serve-bench`/`serve-zoo`), always-on atomic
//! [`telemetry::Counter`]s / fixed-bucket [`telemetry::Histogram`]s
//! snapshotted into [`engine::EngineStats`], and
//! [`telemetry::ClassBytes`] — the `{weights, ifm, ofm, shortcut}`
//! per-tensor-class DRAM attribution threaded through the analytical
//! model (eq. 8/9) and the instruction replay, which turns the paper's
//! headline shortcut-traffic share into a regression-gated observable.
//! Every trace timestamp comes from [`engine::Clock`], so traces are
//! byte-deterministic under [`engine::VirtualClock`].
//!
//! ## Design-space exploration: `explorer`
//!
//! The paper frames §IV as an *optimization tool*: given resource
//! constraints, pick the configuration maximizing on-chip reuse.
//! [`explorer::SearchSpace`] automates that search — grids over the
//! [`config::AccelConfig`] axes (buffer budget, MAC-array geometry,
//! DRAM bandwidth, input resolution) × every reuse strategy, pruned
//! against device ceilings before costing, evaluated in parallel through
//! one memoizing [`compiler::Session`], and reduced to per-model
//! [`explorer::ParetoFront`]s over (latency, DRAM bytes, SRAM KB) plus a
//! recommended configuration that packs straight into a deployable
//! [`program::Program`]. The CLI front-end is `shortcutfusion explore`.
//!
//! ## Multi-FPGA pipeline sharding: `shard`
//!
//! Models too large for one device's SRAM/DSP budget split across
//! several: [`shard::Partitioner`] enumerates cut-point-aligned splits
//! of the segment graph (exactly one live tensor crossing — the places
//! feature-maps already spill to DRAM), costs each candidate with the
//! analytical models plus a configurable inter-device
//! [`shard::LinkModel`], and emits a [`shard::ShardPlan`] whose
//! [`pack`](shard::ShardPlan::pack) produces one checksummed program per
//! shard with matching ingress/egress tensor descriptors.
//! [`engine::ShardedBackend`] chains the shards through any execution
//! backend so the [`engine::InferenceEngine`] serves sharded models
//! transparently, and
//! [`explorer::SearchSpace::explore_sharded`] sweeps device counts ×
//! heterogeneous per-shard config grids with a Pareto front over
//! (latency, pipeline interval, total SRAM, device count). The CLI
//! front-end is `shortcutfusion shard`.
//!
//! ## Multi-tenant model-zoo serving: `pool`
//!
//! The paper's reuse-aware allocation of on-chip SRAM has a serving-time
//! sibling one level up: device DRAM cannot hold every packed program a
//! multi-tenant zoo deployment wants resident. [`pool::BufferPool`]
//! pages program weight segments in and out of a modeled DRAM budget
//! with `pin`/`unpin` refcounting, dirty-free eviction under a pluggable
//! [`pool::ReplacementPolicy`] (LRU, clock, scan-resistant segmented
//! LRU), per-tenant admission quotas, and a link-model cold-load cost
//! per miss. [`pool::PooledBackend`] slots the pool beneath the engine
//! by wrapping any execution backend (sharded chains included); the CLI
//! front-end is `shortcutfusion serve-zoo` and the policy × pool-size ×
//! access-pattern sweep lives in `benches/pool.rs`.
//!
//! ## Layout
//!
//! | module | role |
//! |---|---|
//! | [`graph`], [`serialize`], [`zoo`] | frozen-graph model + JSON interchange + paper model zoo |
//! | [`import`] | **ONNX front end**: dependency-free wire reader, lowering pass, inverse exporter |
//! | [`analyzer`] | fusion into accelerator groups (Fig. 5a) |
//! | [`optimizer`] | reuse-aware cut-point search (§IV, Algorithm 1, eq. 1–10) |
//! | [`alloc`] | static 3-buffer + off-chip arena allocation (Fig. 13) |
//! | [`tile`] | **depth-first fused-tile streaming**: region planner, halo math, tiled funcsim |
//! | [`isa`] | 11-word instruction encode/decode + lowering (Fig. 5b) |
//! | [`compiler`] | **the staged API**: stages, strategies, session, errors |
//! | [`program`] | **the deployable artifact**: packed program, binary container |
//! | [`engine`] | **unified execution**: backends + batch-serving engine |
//! | [`explorer`] | **design-space search**: pruned config sweeps, Pareto fronts, recommender |
//! | [`shard`] | **multi-FPGA pipeline sharding**: cut-point partitioner, link model, shard plans |
//! | [`pool`] | **multi-tenant serving**: device-DRAM buffer pool, eviction policies, pooled backend |
//! | [`telemetry`] | **observability**: trace sinks + Chrome export, atomic metrics, per-class DRAM attribution |
//! | [`sim`], [`funcsim`], [`power`] | cycle-accurate timing, bit-exact functional sim, power model |
//! | [`baselines`], [`bench`] | comparison models + offline bench harness |
//! | [`coordinator`] | CLI and deprecated one-shot wrappers |
//! | [`runtime`] | PJRT artifact loaders (deprecated entry point — use [`engine::PjrtBackend`]; stubbed unless the `pjrt` feature is on) |
//!
//! See `DESIGN.md` for the hardware substitutions (FPGA → cycle-accurate
//! simulator, GPU → analytical model).

#![warn(missing_docs)]

pub mod config;
pub mod graph;
pub mod serialize;
pub mod zoo;
pub mod import;
pub mod analyzer;
pub mod isa;
pub mod optimizer;
pub mod alloc;
pub mod tile;
pub mod compiler;
pub mod program;
pub mod engine;
pub mod explorer;
pub mod shard;
pub mod pool;
pub mod telemetry;
pub mod sim;
pub mod funcsim;
pub mod power;
pub mod baselines;
pub mod runtime;
pub mod coordinator;
pub mod bench;
pub mod testutil;

pub use compiler::CompileError;

/// Crate-wide result alias over the typed compile error.
pub type Result<T> = std::result::Result<T, CompileError>;
