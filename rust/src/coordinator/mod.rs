//! Coordinator: the CLI plus deprecated one-shot wrappers over the
//! staged [`crate::compiler`] API (Fig. 4's end-to-end driver).

pub mod pipeline;
pub mod sweep;
pub mod cli;

#[allow(deprecated)]
pub use pipeline::compile_model;
pub use pipeline::CompileReport;
#[allow(deprecated)]
pub use sweep::{run_jobs, sweep_zoo};
pub use sweep::Job;
