//! Coordinator: the end-to-end pipeline driver (Fig. 4) and the CLI.

pub mod pipeline;
pub mod sweep;
pub mod cli;

pub use pipeline::{compile_model, CompileReport};
pub use sweep::{run_jobs, sweep_zoo, Job};
