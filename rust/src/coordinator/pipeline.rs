//! The one-shot compile entry point, kept as a thin compatibility
//! wrapper over the staged [`crate::compiler`] API.
//!
//! New code should drive the stages directly:
//!
//! ```no_run
//! use shortcutfusion::compiler::Compiler;
//! use shortcutfusion::config::AccelConfig;
//! use shortcutfusion::zoo;
//!
//! let report = Compiler::new(AccelConfig::kcu1500_int8())
//!     .compile(&zoo::yolov2(416))
//!     .unwrap();
//! ```
//!
//! See `MIGRATION.md` for the full porting guide. The equivalence test in
//! `rust/tests/staged_api.rs` pins this wrapper to the staged pipeline
//! bit-for-bit.

pub use crate::compiler::CompileReport;

use crate::compiler::Compiler;
use crate::config::AccelConfig;
use crate::graph::Graph;

/// Run the whole pipeline on a graph.
///
/// Panics on graphs that fail [`crate::graph::validate`] — a check the
/// staged path added (the seed wrapper fed unvalidated graphs straight
/// to the analyzer). Use [`Compiler::compile`] for typed errors.
#[deprecated(
    since = "0.2.0",
    note = "use `compiler::Compiler::compile` (staged API); see MIGRATION.md"
)]
pub fn compile_model(graph: &Graph, cfg: &AccelConfig) -> CompileReport {
    Compiler::new(cfg.clone())
        .compile(graph)
        .unwrap_or_else(|e| panic!("compile_model({}): {e}", graph.name))
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::zoo;

    #[test]
    fn pipeline_runs_for_all_models() {
        let cfg = AccelConfig::kcu1500_int8();
        for &name in zoo::MODEL_NAMES {
            let g = zoo::by_name(name, zoo::default_input(name)).unwrap();
            let r = compile_model(&g, &cfg);
            assert!(r.latency_ms() > 0.0, "{name}");
            assert_eq!(r.stream.len(), r.grouped.groups.len(), "{name}");
            assert!(r.power.total_w > 0.0, "{name}");
            assert!(r.row_groups + r.frame_groups == r.grouped.groups.len());
        }
    }

    #[test]
    fn table5_shape_for_efficientnet() {
        // Table V: EfficientNet-B1@256 → 4.69 ms, off-chip reduction
        // 84.81 %, MAC eff 19.37 %.
        let cfg = AccelConfig::kcu1500_int8();
        let r = compile_model(&zoo::efficientnet_b1(256), &cfg);
        assert!((1.0..12.0).contains(&r.latency_ms()), "{}", r.latency_ms());
        assert!(r.reduction_pct() > 55.0, "{}", r.reduction_pct());
        assert!((5.0..35.0).contains(&r.mac_efficiency_pct()), "{}", r.mac_efficiency_pct());
    }
}
