//! The full compile pipeline: graph → analyze → optimize → allocate →
//! lower → simulate → report.

use crate::alloc::{allocate, layout};
use crate::analyzer::{analyze, GroupedGraph};
use crate::config::AccelConfig;
use crate::graph::Graph;
use crate::isa::{lower, InstructionStream, MemAssign, MemLoc, ReuseMode};
use crate::optimizer::{Evaluation, Optimizer};
use crate::power::{estimate as power_estimate, PowerEstimate, PowerModel};
use crate::sim::{simulate, NetworkTiming};

/// Everything the pipeline produces for one network.
pub struct CompileReport {
    pub model: String,
    pub grouped: GroupedGraph,
    pub evaluation: Evaluation,
    pub timing: NetworkTiming,
    pub power: PowerEstimate,
    pub stream: InstructionStream,
    /// Row-reuse / frame-reuse group counts.
    pub row_groups: usize,
    pub frame_groups: usize,
}

impl CompileReport {
    pub fn latency_ms(&self) -> f64 {
        self.timing.latency_ms
    }

    pub fn fps(&self) -> f64 {
        1000.0 / self.timing.latency_ms
    }

    pub fn gops(&self) -> f64 {
        self.timing.gops
    }

    pub fn mac_efficiency_pct(&self) -> f64 {
        100.0 * self.timing.mac_efficiency
    }

    pub fn offchip_fm_mb(&self) -> f64 {
        self.evaluation.dram.fm_bytes as f64 / 1e6
    }

    pub fn offchip_total_mb(&self) -> f64 {
        self.evaluation.dram.total as f64 / 1e6
    }

    pub fn baseline_once_mb(&self) -> f64 {
        self.evaluation.dram.baseline_once as f64 / 1e6
    }

    pub fn reduction_pct(&self) -> f64 {
        self.evaluation.dram.reduction_pct()
    }

    pub fn sram_mb(&self) -> f64 {
        self.evaluation.sram.total as f64 / 1e6
    }

    pub fn bram18k(&self) -> usize {
        self.evaluation.sram.bram18k
    }
}

/// Run the whole pipeline on a graph.
pub fn compile_model(graph: &Graph, cfg: &AccelConfig) -> CompileReport {
    let grouped = analyze(graph);
    let opt = Optimizer::new(&grouped, cfg);
    let evaluation = opt.optimize();
    drop(opt); // releases the &grouped borrow (Box<dyn Fn> has drop glue)
    let alloc = allocate(&grouped, &evaluation.policy, cfg);
    let timing = simulate(&grouped, &evaluation.policy, &alloc, cfg);
    let dram_layout = layout(&grouped, &evaluation.policy, &alloc, cfg);

    let assigns: Vec<MemAssign> = grouped
        .groups
        .iter()
        .enumerate()
        .map(|(gi, gr)| MemAssign {
            reuse: evaluation.policy[gi],
            in_loc: to_memloc(&alloc.assigns[gi].in_loc, &dram_layout, gi),
            out_loc: to_memloc(&alloc.assigns[gi].out_loc, &dram_layout, gi),
            aux_loc: alloc.assigns[gi].aux_loc.as_ref().map(|l| to_memloc(l, &dram_layout, gi)),
            weight_addr: dram_layout.weights[gi].offset,
            weight_bytes: gr.weight_bytes(&grouped.graph, cfg.qw as u64) as u32,
            quant_shift: 0,
        })
        .collect();
    let stream = lower(&grouped, &assigns);

    let power = power_estimate(
        &PowerModel::default(),
        cfg,
        timing.mac_efficiency,
        evaluation.sram.bram18k,
        evaluation.dram.total,
        timing.latency_ms,
        timing.gops,
    );

    let row_groups = evaluation.policy.iter().filter(|m| **m == ReuseMode::Row).count();
    let frame_groups = evaluation.policy.len() - row_groups;

    CompileReport {
        model: graph.name.clone(),
        grouped,
        evaluation,
        timing,
        power,
        stream,
        row_groups,
        frame_groups,
    }
}

fn to_memloc(l: &crate::alloc::Loc, lay: &crate::alloc::OffchipLayout, gi: usize) -> MemLoc {
    match l {
        crate::alloc::Loc::Buf(b) => MemLoc::Buf(*b),
        crate::alloc::Loc::Aux => MemLoc::Buf(0),
        crate::alloc::Loc::Dram => MemLoc::Dram(lay.fmaps[gi].offset),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::zoo;

    #[test]
    fn pipeline_runs_for_all_models() {
        let cfg = AccelConfig::kcu1500_int8();
        for &name in zoo::MODEL_NAMES {
            let g = zoo::by_name(name, zoo::default_input(name)).unwrap();
            let r = compile_model(&g, &cfg);
            assert!(r.latency_ms() > 0.0, "{name}");
            assert_eq!(r.stream.len(), r.grouped.groups.len(), "{name}");
            assert!(r.power.total_w > 0.0, "{name}");
            assert!(r.row_groups + r.frame_groups == r.grouped.groups.len());
        }
    }

    #[test]
    fn table5_shape_for_efficientnet() {
        // Table V: EfficientNet-B1@256 → 4.69 ms, off-chip reduction
        // 84.81 %, MAC eff 19.37 %.
        let cfg = AccelConfig::kcu1500_int8();
        let r = compile_model(&zoo::efficientnet_b1(256), &cfg);
        assert!((1.0..12.0).contains(&r.latency_ms()), "{}", r.latency_ms());
        assert!(r.reduction_pct() > 55.0, "{}", r.reduction_pct());
        assert!((5.0..35.0).contains(&r.mac_efficiency_pct()), "{}", r.mac_efficiency_pct());
    }
}
