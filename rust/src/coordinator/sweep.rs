//! Multi-threaded sweep executor: compile many (model, input, config)
//! jobs in parallel with `std::thread` (the pipeline is CPU-bound search;
//! tokio would add nothing — DESIGN.md §9).

use crate::config::AccelConfig;
use crate::coordinator::pipeline::{compile_model, CompileReport};
use crate::zoo;
use std::sync::mpsc;

/// One sweep job.
#[derive(Debug, Clone)]
pub struct Job {
    pub model: String,
    pub input: usize,
    pub cfg: AccelConfig,
}

/// Compile all jobs across `threads` workers; results come back in job
/// order. Unknown models yield `Err` entries instead of poisoning the
/// batch.
pub fn run_jobs(jobs: Vec<Job>, threads: usize) -> Vec<Result<CompileReport, String>> {
    assert!(threads > 0);
    let n = jobs.len();
    let (tx, rx) = mpsc::channel::<(usize, Result<CompileReport, String>)>();
    let jobs = std::sync::Arc::new(jobs);
    let next = std::sync::Arc::new(std::sync::atomic::AtomicUsize::new(0));

    std::thread::scope(|s| {
        for _ in 0..threads.min(n.max(1)) {
            let tx = tx.clone();
            let jobs = jobs.clone();
            let next = next.clone();
            s.spawn(move || loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= jobs.len() {
                    return;
                }
                let job = &jobs[i];
                let result = match zoo::by_name(&job.model, job.input) {
                    Some(g) => Ok(compile_model(&g, &job.cfg)),
                    None => Err(format!("unknown model {:?}", job.model)),
                };
                let _ = tx.send((i, result));
            });
        }
    });
    drop(tx);

    let mut out: Vec<Option<Result<CompileReport, String>>> = (0..n).map(|_| None).collect();
    for (i, r) in rx {
        out[i] = Some(r);
    }
    out.into_iter().map(|r| r.expect("worker delivered every job")).collect()
}

/// Compile every zoo model at its default input on `cfg`.
pub fn sweep_zoo(cfg: &AccelConfig, threads: usize) -> Vec<Result<CompileReport, String>> {
    let jobs = zoo::MODEL_NAMES
        .iter()
        .map(|&m| Job { model: m.to_string(), input: zoo::default_input(m), cfg: cfg.clone() })
        .collect();
    run_jobs(jobs, threads)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_matches_serial() {
        let cfg = AccelConfig::kcu1500_int8();
        let jobs: Vec<Job> = ["resnet18", "vgg16-conv", "yolov2"]
            .iter()
            .map(|&m| Job { model: m.into(), input: 64, cfg: cfg.clone() })
            .collect();
        let par = run_jobs(jobs.clone(), 3);
        let ser = run_jobs(jobs, 1);
        for (p, s) in par.iter().zip(&ser) {
            let (p, s) = (p.as_ref().unwrap(), s.as_ref().unwrap());
            assert_eq!(p.model, s.model);
            assert_eq!(p.timing.total_cycles, s.timing.total_cycles);
            assert_eq!(p.evaluation.dram.total, s.evaluation.dram.total);
        }
    }

    #[test]
    fn unknown_model_is_isolated() {
        let cfg = AccelConfig::kcu1500_int8();
        let jobs = vec![
            Job { model: "resnet18".into(), input: 64, cfg: cfg.clone() },
            Job { model: "alexnet".into(), input: 64, cfg: cfg.clone() },
        ];
        let out = run_jobs(jobs, 2);
        assert!(out[0].is_ok());
        assert!(out[1].is_err());
    }
}
