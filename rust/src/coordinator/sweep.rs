//! Compatibility sweep entry points, now thin wrappers over
//! [`crate::compiler::Session`] (which adds per-`(model, config,
//! strategy)` memoization on top of the same scoped-thread worker pool).

use crate::compiler::{CompileReport, Session, SweepJob};
use crate::config::AccelConfig;
use crate::zoo;

/// One sweep job (legacy shape; [`SweepJob`] is the staged-API form).
#[derive(Debug, Clone)]
pub struct Job {
    /// Zoo model name.
    pub model: String,
    /// Square input resolution.
    pub input: usize,
    /// Target configuration.
    pub cfg: AccelConfig,
}

/// Compile all jobs across `threads` workers; results come back in job
/// order. Unknown models yield `Err` entries instead of poisoning the
/// batch.
#[deprecated(
    since = "0.2.0",
    note = "use `compiler::Session::run_jobs`; see MIGRATION.md"
)]
pub fn run_jobs(jobs: Vec<Job>, threads: usize) -> Vec<Result<CompileReport, String>> {
    let session = Session::new();
    let staged: Vec<SweepJob> = jobs
        .into_iter()
        .map(|j| SweepJob { model: j.model, input: j.input, cfg: j.cfg })
        .collect();
    session
        .run_jobs(&staged, threads)
        .into_iter()
        .map(|r| match r {
            Ok(report) => Ok((*report).clone()),
            Err(e) => Err(e.to_string()),
        })
        .collect()
}

/// Compile every zoo model at its default input on `cfg`.
#[deprecated(
    since = "0.2.0",
    note = "use `compiler::Session::sweep_zoo`; see MIGRATION.md"
)]
pub fn sweep_zoo(cfg: &AccelConfig, threads: usize) -> Vec<Result<CompileReport, String>> {
    let jobs = zoo::MODEL_NAMES
        .iter()
        .map(|&m| Job { model: m.to_string(), input: zoo::default_input(m), cfg: cfg.clone() })
        .collect();
    #[allow(deprecated)]
    let out = run_jobs(jobs, threads);
    out
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;

    #[test]
    fn parallel_matches_serial() {
        let cfg = AccelConfig::kcu1500_int8();
        let jobs: Vec<Job> = ["resnet18", "vgg16-conv", "yolov2"]
            .iter()
            .map(|&m| Job { model: m.into(), input: 64, cfg: cfg.clone() })
            .collect();
        let par = run_jobs(jobs.clone(), 3);
        let ser = run_jobs(jobs, 1);
        for (p, s) in par.iter().zip(&ser) {
            let (p, s) = (p.as_ref().unwrap(), s.as_ref().unwrap());
            assert_eq!(p.model, s.model);
            assert_eq!(p.timing.total_cycles, s.timing.total_cycles);
            assert_eq!(p.evaluation.dram.total, s.evaluation.dram.total);
        }
    }

    #[test]
    fn unknown_model_is_isolated() {
        let cfg = AccelConfig::kcu1500_int8();
        let jobs = vec![
            Job { model: "resnet18".into(), input: 64, cfg: cfg.clone() },
            Job { model: "alexnet".into(), input: 64, cfg: cfg.clone() },
        ];
        let out = run_jobs(jobs, 2);
        assert!(out[0].is_ok());
        assert!(out[1].is_err());
    }
}
