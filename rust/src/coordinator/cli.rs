//! Hand-rolled CLI (clap is unavailable offline — DESIGN.md §9), driving
//! the staged [`crate::compiler`] API.
//!
//! ```text
//! shortcutfusion list
//! shortcutfusion compile <model> [--input N] [--config FILE] [--strategy S]
//! shortcutfusion pack    <model> [--input N] [--config FILE] [--strategy S]
//!                        [--params FILE | --random-params] --out FILE
//! shortcutfusion run     FILE [--backend B] [--seed N] [--trace-out FILE]
//! shortcutfusion serve-bench FILE [--backend B] [--requests N] [--workers N]
//!                        [--batch N] [--queue N] [--batch-policy continuous|window]
//!                        [--deadline-ms X] [--max-deadline-misses N] [--burst N]
//!                        [--burst-gap-ms X] [--json-out FILE] [--trace-out FILE]
//! shortcutfusion serve-zoo <model> [<model> ...] [--input N] [--config FILE]
//!                        [--backend B] [--pool-mb X] [--policy P] [--quota-mb X]
//!                        [--link-gbps X] [--link-latency-us X] [--rounds N]
//!                        [--requests N] [--workers N] [--batch N]
//!                        [--batch-policy continuous|window] [--deadline-ms X]
//!                        [--random-params] [--verify] [--json-out FILE]
//!                        [--expect-evictions] [--trace-out FILE]
//! shortcutfusion explore <model> [...] [--sram-budgets N,N] [--mac RxC,...]
//!                        [--dram-gbps X,...] [--strategies S,...] [--input N]
//!                        [--max-bram N] [--max-dram-gbps X] [--max-dsp N]
//!                        [--threads N] [--format text|json|csv] [--out FILE]
//!                        [--json-out FILE] [--pack-best FILE]
//! shortcutfusion shard   <model> [--input N] [--config FILE] [--devices K]
//!                        [--link-gbps X] [--link-latency-us X] [--strategy S]
//!                        [--objective latency|throughput] [--format text|json]
//!                        [--json-out FILE] [--pack [PREFIX]] [--random-params]
//! shortcutfusion sweep   <model> [--input N]
//! shortcutfusion minbuf  [<model> ...]
//! shortcutfusion import  FILE.onnx [--config FILE] [--strategy S]
//!                        [--out FILE.sfp] [--verify-zoo NAME]
//! shortcutfusion export  <model> [--input N] [--random-params] --out FILE
//! shortcutfusion load    FILE
//! shortcutfusion report  [--threads N] [--strategy S]
//! shortcutfusion help
//! ```
//!
//! Every `<model>` argument resolves through [`crate::import::resolve`]:
//! a zoo name, a `.onnx` model (parameters ride along), or a
//! frozen-graph `.json` file.

use std::sync::Arc;

use crate::bench::Table;
use crate::compiler::{strategy, CompileError, Compiler, Session};
use crate::config::AccelConfig;
use crate::engine::{
    backend_by_name, BatchPolicy, Clock, EngineConfig, EngineStats, ExecutionBackend,
    InferenceEngine, RealClock, ReferenceBackend, BACKEND_NAMES,
};
use crate::explorer::{ExplorePoint, Exploration, SearchSpace};
use crate::funcsim::{Params, Tensor};
use crate::optimizer::Optimizer;
use crate::pool::{policy_by_name, BufferPool, PoolConfig, PooledBackend, POLICY_NAMES};
use crate::program::Program;
use crate::shard::{LinkModel, Objective, Partitioner, ShardPlan};
use crate::serialize::{load_frozen, save_frozen};
use crate::telemetry::{TraceEvent, TraceRecorder, TraceSink};
use crate::testutil::Rng;
use crate::zoo;
use crate::Result;

const HELP: &str = "\
ShortcutFusion — reuse-aware CNN compiler for a shared-MAC accelerator

USAGE:
    shortcutfusion <command> [args]

COMMANDS:
    list                         list zoo models and reuse strategies
    compile <model> [--input N] [--config FILE] [--strategy S]
                                 run the staged pipeline and print the report
    pack <model> [--input N] [--config FILE] [--strategy S]
         [--params FILE | --random-params] --out FILE
                                 compile and pack a deployable program artifact
    run FILE [--backend B] [--seed N] [--trace-out FILE]
                                 execute a packed program once (--trace-out
                                 writes the run's span as Chrome trace-event
                                 JSON, loadable in chrome://tracing / Perfetto)
    serve-bench FILE [--backend B] [--requests N] [--workers N] [--batch N] [--queue N]
                [--batch-policy continuous|window] [--deadline-ms X]
                [--max-deadline-misses N] [--burst N] [--burst-gap-ms X]
                [--json-out FILE] [--trace-out FILE]
                                 serve a packed program through the inference
                                 engine and print the serving stats (--burst
                                 submits in bursts of N separated by
                                 --burst-gap-ms; --deadline-ms sets a per-request
                                 SLO; --max-deadline-misses exits nonzero when
                                 the engine missed more deadlines than allowed;
                                 --json-out additionally writes the stats as
                                 machine-readable JSON; --trace-out writes the
                                 request-lifecycle trace as Chrome trace-event
                                 JSON)
    serve-zoo <model> [<model> ...] [--input N] [--config FILE] [--backend B]
              [--pool-mb X] [--policy P] [--quota-mb X] [--link-gbps X]
              [--link-latency-us X] [--rounds N] [--requests N] [--workers N]
              [--batch N] [--batch-policy continuous|window] [--deadline-ms X]
              [--random-params] [--verify] [--json-out FILE]
              [--expect-evictions] [--trace-out FILE]
                                 serve several models through one multi-tenant
                                 device-DRAM buffer pool, one engine + tenant per
                                 model (default pool: half the combined weight
                                 footprint, so paging is visible; --verify checks
                                 pooled reference outputs bit-identical to
                                 unpooled runs; --expect-evictions exits nonzero
                                 unless the pool evicted and no request failed;
                                 --trace-out merges request + pool events from
                                 every tenant into one Chrome trace-event file)
    explore <model> [<model> ...] [--config FILE] [--input N]
            [--sram-budgets N,N,..] [--mac RxC,..] [--dram-gbps X,..]
            [--strategies S,..] [--max-bram N] [--max-dram-gbps X] [--max-dsp N]
            [--threads N] [--format text|json|csv] [--out FILE] [--json-out FILE]
            [--pack-best FILE]
                                 design-space sweep: grid x strategies under
                                 resource constraints, Pareto front + best config
                                 (defaults: budgets base/4,base/2,base; strategies
                                 cutpoint,fixed-row,fixed-frame,tile; --pack-best packs
                                 the first listed model's winner; --json-out writes
                                 the JSON rendering regardless of --format)
    shard <model> [--input N] [--config FILE] [--devices K] [--link-gbps X]
          [--link-latency-us X] [--strategy S] [--objective latency|throughput]
          [--format text|json] [--json-out FILE] [--pack [PREFIX]] [--random-params]
                                 partition the model across K pipeline devices at
                                 cut-point boundaries, print the best split plan,
                                 and optionally pack one program per shard
                                 (PREFIX.shard<i>.sfp, default PREFIX = model name)
    sweep <model> [--input N] [--csv FILE]
                                 cut-point sweep (Fig 16/17 series)
    minbuf [<model> ...]         minimum buffer search (Table III)
    import FILE.onnx [--config FILE] [--strategy S] [--out FILE.sfp]
           [--verify-zoo NAME]
                                 import an ONNX model through the
                                 dependency-free front end; --out packs it
                                 into a deployable program (imported
                                 parameters included), --verify-zoo checks
                                 it structurally and bit-exactly against a
                                 zoo builder
    export <model> [--input N] [--random-params] --out FILE
                                 write the model as frozen-graph JSON, or
                                 as ONNX when FILE ends in .onnx
                                 (--random-params embeds the seeded
                                 parameter set so the file re-imports into
                                 a servable program)
    load FILE                    parse a frozen-graph JSON and report stats
    report [--threads N] [--strategy S]
                                 compile the whole zoo in parallel (summary table)
    help                         this text

STRATEGIES (for --strategy):
    cutpoint (default), min-buffer, fixed-row, fixed-frame,
    shortcut-mining, smartshuttle, tile (depth-first fused-tile
    streaming; tile-<rows> pins the tile height, e.g. tile-8)

BACKENDS (for --backend):
    virtual (default: timing + DRAM traffic of the virtual accelerator),
    reference (bit-exact funcsim; the program must carry parameters),
    pjrt (stub: packed programs do not embed HLO artifacts yet — always
          reports Unsupported; see MIGRATION.md)

POLICIES (for serve-zoo --policy):
    slru (default: scan-resistant segmented LRU), lru, clock

MODELS:
    every <model> argument accepts a zoo name (see `list`), a path to an
    imported model (.onnx), or a frozen-graph file (.json)
";

/// CLI entry point.
pub fn run(args: Vec<String>) -> Result<()> {
    let mut it = args.into_iter();
    let cmd = it.next().unwrap_or_else(|| "help".to_string());
    let rest: Vec<String> = it.collect();
    match cmd.as_str() {
        "list" => {
            for &m in zoo::MODEL_NAMES {
                println!("{m} (default input {})", zoo::default_input(m));
            }
            println!("strategies: {}", strategy::STRATEGY_NAMES.join(", "));
            Ok(())
        }
        "compile" => cmd_compile(&rest),
        "pack" => cmd_pack(&rest),
        "run" => cmd_run(&rest),
        "serve-bench" => cmd_serve_bench(&rest),
        "serve-zoo" => cmd_serve_zoo(&rest),
        "explore" => cmd_explore(&rest),
        "shard" => cmd_shard(&rest),
        "sweep" => cmd_sweep(&rest),
        "minbuf" => cmd_minbuf(&rest),
        "import" => cmd_import(&rest),
        "export" => cmd_export(&rest),
        "load" => cmd_load(&rest),
        "report" => cmd_report(&rest),
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            Ok(())
        }
        other => Err(CompileError::config(format!(
            "unknown command {other:?} — try `shortcutfusion help`"
        ))),
    }
}

fn flag_value(args: &[String], flag: &str) -> Option<String> {
    args.iter().position(|a| a == flag).and_then(|i| args.get(i + 1).cloned())
}

/// Reject an explicit `--input` that a fixed-geometry model's builder
/// would silently ignore (shared by `compile`/`pack` and `explore`).
fn check_fixed_input(name: &str, input: usize) -> Result<()> {
    match zoo::fixed_input(name) {
        Some(fixed) if input != fixed => Err(CompileError::config(format!(
            "{name} is fixed-geometry (input {fixed}); --input {input} is not supported"
        ))),
        _ => Ok(()),
    }
}

fn parse_strategy(args: &[String]) -> Result<Box<dyn crate::compiler::ReuseStrategy>> {
    let name = flag_value(args, "--strategy").unwrap_or_else(|| "cutpoint".into());
    strategy::by_name(&name).ok_or_else(|| {
        CompileError::config(format!(
            "unknown strategy {name:?} — one of {:?}",
            strategy::STRATEGY_NAMES
        ))
    })
}

/// Resolve `--input` for `name` (default: the model's default input),
/// rejecting explicit values a fixed-geometry builder would ignore.
fn model_input(args: &[String], name: &str) -> Result<usize> {
    match flag_value(args, "--input") {
        Some(v) => {
            let n = v
                .parse::<usize>()
                .map_err(|_| CompileError::config(format!("bad --input {v:?}")))?;
            check_fixed_input(name, n)?;
            Ok(n)
        }
        None => Ok(zoo::default_input(name)),
    }
}

/// Resolve the leading `<model>` argument (zoo name, `.onnx` model, or
/// frozen-graph `.json` — see [`crate::import::resolve`]) plus `--input`
/// and `--config`. `.onnx` models carry their own quantized parameters.
fn parse_model(
    args: &[String],
) -> Result<(crate::graph::Graph, AccelConfig, Option<Params>)> {
    let name = args
        .first()
        .filter(|a| !a.starts_with("--"))
        .ok_or_else(|| {
            CompileError::config("expected a model name — see `shortcutfusion list`")
        })?;
    let input = model_input(args, name)?;
    let cfg = match flag_value(args, "--config") {
        Some(p) => AccelConfig::from_toml_file(std::path::Path::new(&p))?,
        None => AccelConfig::kcu1500_int8(),
    };
    let (graph, params) = crate::import::resolve(name, input)?;
    Ok((graph, cfg, params))
}

fn cmd_compile(args: &[String]) -> Result<()> {
    let (graph, cfg, _params) = parse_model(args)?;
    let compiler = Compiler::with_strategy(cfg.clone(), parse_strategy(args)?.into());
    let r = compiler.compile(&graph)?;
    println!(
        "model: {} ({} nodes, {} groups)",
        r.model,
        r.grouped.graph.nodes.len(),
        r.grouped.groups.len()
    );
    println!(
        "target: {} ({} MHz, Ti=To={}, {} DSP MACs)",
        cfg.name, cfg.freq_mhz, cfg.ti, cfg.dsp_mac
    );
    println!(
        "strategy: {} — cuts {:?} ({} row / {} frame groups)",
        r.strategy, r.evaluation.cuts.cuts, r.row_groups, r.frame_groups
    );
    println!(
        "instruction stream: {} x 11 words = {} bytes",
        r.stream.len(),
        r.stream.byte_size()
    );
    println!("latency: {:.3} ms ({:.1} fps)", r.latency_ms(), r.fps());
    println!(
        "throughput: {:.1} GOPS, MAC efficiency {:.1} %",
        r.gops(),
        r.mac_efficiency_pct()
    );
    println!("SRAM: {:.3} MB ({} BRAM18K)", r.sram_mb(), r.bram18k());
    println!(
        "DRAM: {:.2} MB total ({:.2} MB feature maps); baseline-once {:.2} MB -> reduction {:.1} %",
        r.offchip_total_mb(),
        r.offchip_fm_mb(),
        r.baseline_once_mb(),
        r.reduction_pct()
    );
    let c = &r.evaluation.dram.classes;
    println!(
        "DRAM by class: weights {:.2} MB, ifm {:.2} MB, ofm {:.2} MB, shortcut {:.2} MB \
         ({:.1} % of feature-map traffic)",
        c.weights as f64 / 1e6,
        c.ifm as f64 / 1e6,
        c.ofm as f64 / 1e6,
        c.shortcut as f64 / 1e6,
        c.shortcut_share() * 100.0
    );
    println!(
        "power: {:.1} W (chip {:.1} + DRAM {:.1}) -> {:.1} GOPS/W",
        r.power.total_w, r.power.chip_w, r.power.dram_w, r.power.gops_per_w
    );
    if !r.evaluation.feasible {
        println!("WARNING: no feasible policy under the configured SRAM budget");
    }
    Ok(())
}

fn cmd_pack(args: &[String]) -> Result<()> {
    let (graph, cfg, imported) = parse_model(args)?;
    let out = flag_value(args, "--out")
        .ok_or_else(|| CompileError::config("--out FILE required"))?;
    let mut compiler = Compiler::with_strategy(cfg, parse_strategy(args)?.into());
    let analyzed = compiler.analyze(&graph)?;
    if let Some(p) = flag_value(args, "--params") {
        compiler = compiler.with_params(Params::from_file(std::path::Path::new(&p))?);
    } else if args.iter().any(|a| a == "--random-params") {
        // deterministic synthetic parameters, for demos and CI smoke runs
        compiler = compiler.with_params(Params::random(&analyzed.grouped, 7));
    } else if let Some(p) = imported {
        // a .onnx model brings its own quantized parameters
        compiler = compiler.with_params(p);
    }
    let lowered = compiler.lower(&compiler.allocate(&compiler.optimize(&analyzed)?)?)?;
    let program = compiler.pack(&lowered)?;
    let bytes = program.to_bytes();
    std::fs::write(&out, &bytes).map_err(|e| CompileError::io(&out, e))?;
    println!(
        "packed {} [{}] for {}: {} instructions, {} artifact bytes{} -> {}",
        program.model(),
        program.strategy(),
        program.cfg().name,
        program.stream().len(),
        bytes.len(),
        if program.params().is_some() { " (params included)" } else { "" },
        out
    );
    Ok(())
}

fn parse_backend(args: &[String]) -> Result<Arc<dyn ExecutionBackend>> {
    let name = flag_value(args, "--backend").unwrap_or_else(|| "virtual".into());
    backend_by_name(&name).ok_or_else(|| {
        CompileError::config(format!("unknown backend {name:?} — one of {BACKEND_NAMES:?}"))
    })
}

fn parse_count(args: &[String], flag: &str, default: usize) -> Result<usize> {
    match flag_value(args, flag) {
        None => Ok(default),
        Some(v) => match v.parse::<usize>() {
            Ok(n) if n > 0 => Ok(n),
            _ => Err(CompileError::config(format!(
                "bad {flag} {v:?} (need a positive integer)"
            ))),
        },
    }
}

/// Deterministic random input for a loaded program.
fn program_input(program: &Program, seed: u64) -> Tensor {
    let shape = program.input_shape();
    let mut rng = Rng::from_seed(seed);
    Tensor::from_vec(shape, rng.i8_vec(shape.numel()))
}

/// Compile a graph into a packed [`Program`] under `--strategy` (default
/// cutpoint), attaching `params` when present.
fn pack_graph(
    graph: &crate::graph::Graph,
    cfg: AccelConfig,
    args: &[String],
    params: Option<Params>,
) -> Result<Program> {
    let mut compiler = Compiler::with_strategy(cfg, parse_strategy(args)?.into());
    let analyzed = compiler.analyze(graph)?;
    if let Some(p) = params {
        compiler = compiler.with_params(p);
    }
    let lowered = compiler.lower(&compiler.allocate(&compiler.optimize(&analyzed)?)?)?;
    compiler.pack(&lowered)
}

/// Compile a `.onnx` / frozen-graph `.json` model file into a program in
/// memory (imported parameters ride along, so `--backend reference`
/// works straight off an import).
fn compile_model_file(path: &str, args: &[String]) -> Result<Program> {
    // the input-resolution argument is ignored for file paths — the
    // file carries its own geometry
    let (graph, params) = crate::import::resolve(path, 0)?;
    pack_graph(&graph, AccelConfig::kcu1500_int8(), args, params)
}

fn cmd_run(args: &[String]) -> Result<()> {
    let path = args
        .first()
        .filter(|a| !a.starts_with("--"))
        .ok_or_else(|| CompileError::config("expected a packed program file"))?;
    let program = match std::path::Path::new(path).extension().and_then(|e| e.to_str()) {
        Some("onnx") | Some("json") => compile_model_file(path, args)?,
        _ => Program::load(std::path::Path::new(path))?,
    };
    let backend = parse_backend(args)?;
    let seed = flag_value(args, "--seed")
        .map(|v| {
            v.parse::<u64>()
                .map_err(|_| CompileError::config(format!("bad --seed {v:?}")))
        })
        .transpose()?
        .unwrap_or(1);
    println!(
        "{} [{}] on {} via {} (input {}, seed {seed})",
        program.model(),
        program.strategy(),
        program.cfg().name,
        backend.name(),
        program.input_shape(),
    );
    let input = program_input(&program, seed);
    let clock = RealClock::new();
    let t0 = clock.now_ms();
    let r = backend.run(&program, &input)?;
    let wall_ms = clock.now_ms() - t0;
    if let Some(out) = &r.output {
        let preview: Vec<i8> = out.data.iter().copied().take(8).collect();
        println!("output: shape {}, first values {preview:?}", out.shape);
    }
    if let Some(lat) = r.model_latency_ms {
        println!("latency: {:.3} ms ({:.1} fps)", lat, 1000.0 / lat);
    }
    if let Some(bytes) = r.dram_bytes {
        println!("DRAM traffic: {:.2} MB per inference", bytes as f64 / 1e6);
    }
    if let Some(c) = &r.traffic_classes {
        println!(
            "DRAM by class: weights {:.2} MB, ifm {:.2} MB, ofm {:.2} MB, shortcut {:.2} MB \
             ({:.1} % of feature-map traffic)",
            c.weights as f64 / 1e6,
            c.ifm as f64 / 1e6,
            c.ofm as f64 / 1e6,
            c.shortcut as f64 / 1e6,
            c.shortcut_share() * 100.0
        );
    }
    if let Some(path) = flag_value(args, "--trace-out") {
        // a single run has no engine lifecycle: export the one run span,
        // with modeled latency when the backend reports one
        let rec = TraceRecorder::new();
        rec.record(
            TraceEvent::span("request", "run", t0, r.model_latency_ms.unwrap_or(wall_ms), 1)
                .arg("dram_bytes", r.dram_bytes.unwrap_or(0) as f64),
        );
        write_trace(&path, &rec)?;
    }
    Ok(())
}

fn cmd_serve_bench(args: &[String]) -> Result<()> {
    let path = args
        .first()
        .filter(|a| !a.starts_with("--"))
        .ok_or_else(|| CompileError::config("expected a packed program file"))?;
    let program = Arc::new(Program::load(std::path::Path::new(path))?);
    let backend = parse_backend(args)?;
    let requests = parse_count(args, "--requests", 32)?;
    let workers = parse_count(args, "--workers", 2)?;
    let max_batch = parse_count(args, "--batch", 4)?;
    let queue_capacity = parse_count(args, "--queue", workers * max_batch * 2)?;
    let policy = parse_batch_policy(args)?;
    let deadline_ms = flag_value(args, "--deadline-ms")
        .map(|v| match v.parse::<f64>() {
            Ok(d) if d > 0.0 => Ok(d),
            _ => Err(CompileError::config(format!(
                "bad --deadline-ms {v:?} (need a positive number of milliseconds)"
            ))),
        })
        .transpose()?;
    // bursty arrivals: submit `burst` back to back, then pause, so the
    // continuous scheduler's mid-batch joins actually have gaps to span
    let burst = parse_count(args, "--burst", 0)?;
    let burst_gap_ms = parse_float(args, "--burst-gap-ms", 2.0)?;

    let trace = flag_value(args, "--trace-out").map(|p| (p, Arc::new(TraceRecorder::new())));
    let mut engine = InferenceEngine::new_paused(
        program.clone(),
        backend,
        EngineConfig { workers, queue_capacity, max_batch, policy, deadline_ms },
    );
    if let Some((_, rec)) = &trace {
        engine = engine.with_trace(rec.clone());
    }
    engine.start();
    let mut pending = Vec::with_capacity(requests);
    for i in 0..requests {
        if burst > 0 && i > 0 && i % burst == 0 && burst_gap_ms > 0.0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(burst_gap_ms / 1e3));
        }
        pending.push(engine.submit(program_input(&program, i as u64))?);
    }
    for p in pending {
        match p.wait() {
            Ok(_) => {}
            // a missed deadline is a counted outcome here, not an abort —
            // the --max-deadline-misses gate decides the exit status
            Err(CompileError::DeadlineMiss { .. }) => {}
            Err(e) => return Err(e),
        }
    }
    let stats = engine.shutdown();

    let mut t = Table::new(
        &format!(
            "serving {} via {} ({} workers, batch {}, queue {}, {} batching)",
            program.model(),
            stats.backend,
            workers,
            max_batch,
            queue_capacity,
            stats.policy,
        ),
        &["metric", "value"],
    );
    t.row(&["requests completed".into(), stats.completed.to_string()]);
    t.row(&["throughput".into(), format!("{:.1} req/s", stats.throughput_rps)]);
    t.row(&["p50 latency".into(), format!("{:.3} ms", stats.p50_ms)]);
    t.row(&["p95 latency".into(), format!("{:.3} ms", stats.p95_ms)]);
    t.row(&["mean queue wait".into(), format!("{:.3} ms", stats.mean_wait_ms)]);
    t.row(&["peak in-flight".into(), stats.peak_in_flight.to_string()]);
    t.row(&["batches".into(), format!("{} (largest {})", stats.batches, stats.max_batch_seen)]);
    t.row(&["mid-batch joins".into(), stats.joined.to_string()]);
    t.row(&["rejected / deadline misses".into(),
        format!("{} / {}", stats.rejected, stats.deadline_misses)]);
    t.row(&[
        "per-worker completions".into(),
        format!("{:?}", stats.per_worker),
    ]);
    t.print();
    if let Some(path) = flag_value(args, "--json-out") {
        // machine-readable stats for CI bench-trajectory files
        write_json(&path, &engine_stats_json(&stats))?;
    }
    if let Some((path, rec)) = &trace {
        write_trace(path, rec)?;
    }
    if let Some(limit) = flag_value(args, "--max-deadline-misses") {
        let limit: u64 = limit.parse().map_err(|_| {
            CompileError::config(format!("bad --max-deadline-misses {limit:?} (need a count)"))
        })?;
        if stats.deadline_misses > limit {
            return Err(CompileError::Exec(format!(
                "--max-deadline-misses: {} deadline misses exceed the allowed {limit}",
                stats.deadline_misses
            )));
        }
    }
    Ok(())
}

/// Parse the `--batch-policy` flag (default: continuous).
fn parse_batch_policy(args: &[String]) -> Result<BatchPolicy> {
    match flag_value(args, "--batch-policy") {
        None => Ok(BatchPolicy::Continuous),
        Some(v) => BatchPolicy::by_name(&v).ok_or_else(|| {
            CompileError::config(format!(
                "unknown --batch-policy {v:?} — one of continuous, window"
            ))
        }),
    }
}

/// Parse an optional `--flag MB` value into bytes.
fn parse_mb_bytes(args: &[String], flag: &str) -> Result<Option<u64>> {
    match flag_value(args, flag) {
        None => Ok(None),
        Some(v) => match v.parse::<f64>() {
            Ok(mb) if mb > 0.0 => Ok(Some((mb * 1e6) as u64)),
            _ => Err(CompileError::config(format!(
                "bad {flag} {v:?} (need a positive number of megabytes)"
            ))),
        },
    }
}

/// Compile, pack, and serve several zoo models through one shared
/// device-DRAM buffer pool — one engine and one pool tenant per model.
fn cmd_serve_zoo(args: &[String]) -> Result<()> {
    let models: Vec<String> =
        args.iter().take_while(|a| !a.starts_with("--")).cloned().collect();
    if models.is_empty() {
        return Err(CompileError::config(
            "expected at least one model — see `shortcutfusion list`",
        ));
    }
    let cfg = match flag_value(args, "--config") {
        Some(p) => AccelConfig::from_toml_file(std::path::Path::new(&p))?,
        None => AccelConfig::kcu1500_int8(),
    };
    let backend = parse_backend(args)?;
    let verify = args.iter().any(|a| a == "--verify");
    if verify && backend.name() != "reference" {
        return Err(CompileError::config(
            "--verify compares bit-exact outputs and needs --backend reference",
        ));
    }
    // the reference backend computes, so it needs packed parameters
    let with_params =
        args.iter().any(|a| a == "--random-params") || backend.name() == "reference";
    let policy_name = flag_value(args, "--policy").unwrap_or_else(|| "slru".into());
    let policy = policy_by_name(&policy_name).ok_or_else(|| {
        CompileError::config(format!(
            "unknown policy {policy_name:?} — one of {POLICY_NAMES:?}"
        ))
    })?;
    let link = LinkModel::new(
        parse_float(args, "--link-gbps", LinkModel::pcie_gen3().gbps)?,
        parse_float(args, "--link-latency-us", LinkModel::pcie_gen3().latency_us)?,
    )?;
    let explicit_pool = parse_mb_bytes(args, "--pool-mb")?;
    let quota = parse_mb_bytes(args, "--quota-mb")?;

    let mut programs: Vec<Arc<Program>> = Vec::with_capacity(models.len());
    for name in &models {
        let input = model_input(args, name)?;
        // zoo names and imported .onnx / frozen .json tenants serve
        // side by side through the same pool
        let (graph, imported) = crate::import::resolve(name, input)?;
        let mut compiler = Compiler::new(cfg.clone());
        let analyzed = compiler.analyze(&graph)?;
        if let Some(p) = imported {
            compiler = compiler.with_params(p);
        } else if with_params {
            compiler = compiler.with_params(Params::random(&analyzed.grouped, 7));
        }
        let lowered =
            compiler.lower(&compiler.allocate(&compiler.optimize(&analyzed)?)?)?;
        programs.push(Arc::new(compiler.pack(&lowered)?));
    }

    let combined: u64 = programs.iter().map(|p| p.resident_bytes()).sum();
    // default pool: half the combined footprint — large enough to serve,
    // small enough that cross-model paging is visible
    let pool_bytes = explicit_pool.unwrap_or((combined / 2).max(1));
    let mut pool_cfg = PoolConfig::new(pool_bytes).with_link(link);
    if let Some(quota) = quota {
        pool_cfg = pool_cfg.with_tenant_quota(quota);
    }
    let pool = Arc::new(BufferPool::new(pool_cfg, policy)?);

    // one recorder + one clock shared by every tenant engine and the
    // pool, so request and pool events interleave on one timeline
    let trace = flag_value(args, "--trace-out").map(|p| (p, Arc::new(TraceRecorder::new())));
    let clock: Arc<dyn Clock> = Arc::new(RealClock::new());
    if let Some((_, rec)) = &trace {
        pool.set_trace(clock.clone(), rec.clone());
    }

    let rounds = parse_count(args, "--rounds", 3)?;
    let requests = parse_count(args, "--requests", 4)?;
    let workers = parse_count(args, "--workers", 2)?;
    let max_batch = parse_count(args, "--batch", 2)?;
    let batch_policy = parse_batch_policy(args)?;
    let deadline_ms = flag_value(args, "--deadline-ms")
        .map(|v| match v.parse::<f64>() {
            Ok(d) if d > 0.0 => Ok(d),
            _ => Err(CompileError::config(format!(
                "bad --deadline-ms {v:?} (need a positive number of milliseconds)"
            ))),
        })
        .transpose()?;
    let engines: Vec<InferenceEngine> = programs
        .iter()
        .map(|p| {
            let mut e = InferenceEngine::new_paused_with_clock(
                p.clone(),
                Arc::new(PooledBackend::new(backend.clone(), pool.clone(), p.model())),
                EngineConfig {
                    workers,
                    queue_capacity: workers * max_batch * 2,
                    max_batch,
                    policy: batch_policy,
                    deadline_ms,
                },
                clock.clone(),
            );
            if let Some((_, rec)) = &trace {
                e = e.with_trace(rec.clone());
            }
            e.start();
            e
        })
        .collect();

    // round-robin the tenants: each round every model serves `requests`
    // inputs, so with pool < combined footprint the pool must page
    let mut verified = 0u64;
    for round in 0..rounds as u64 {
        for (mi, engine) in engines.iter().enumerate() {
            let mut pending = Vec::with_capacity(requests);
            for r in 0..requests as u64 {
                let seed = round * 7919 + mi as u64 * 131 + r + 1;
                pending.push((seed, engine.submit(program_input(&programs[mi], seed))?));
            }
            for (seed, p) in pending {
                let done = p.wait()?;
                if verify {
                    let input = program_input(&programs[mi], seed);
                    let expect = ReferenceBackend.run(&programs[mi], &input)?;
                    if done.result.output != expect.output {
                        return Err(CompileError::Exec(format!(
                            "{}: pooled output diverged from the unpooled reference",
                            programs[mi].model()
                        )));
                    }
                    verified += 1;
                }
            }
        }
    }
    let per_model: Vec<EngineStats> =
        engines.into_iter().map(|e| e.shutdown()).collect();
    let stats = pool.stats();

    let mut t = Table::new(
        &format!(
            "serve-zoo: {} models via {} ({} pool, {:.1} of {:.1} MB combined)",
            models.len(),
            backend.name(),
            stats.policy,
            pool_bytes as f64 / 1e6,
            combined as f64 / 1e6,
        ),
        &["model", "weights MB", "completed", "failed", "p50 ms", "p95 ms"],
    );
    for (p, s) in programs.iter().zip(&per_model) {
        t.row(&[
            p.model().to_string(),
            format!("{:.1}", p.resident_bytes() as f64 / 1e6),
            s.completed.to_string(),
            s.failed.to_string(),
            format!("{:.3}", s.p50_ms),
            format!("{:.3}", s.p95_ms),
        ]);
    }
    t.print();
    let mut pt = Table::new("pool", &["metric", "value"]);
    pt.row(&["hits / misses".into(), format!("{} / {}", stats.hits, stats.misses)]);
    pt.row(&["hit rate".into(), format!("{:.1} %", stats.hit_rate() * 100.0)]);
    pt.row(&["evictions".into(), stats.evictions.to_string()]);
    pt.row(&["bypasses / overcommits".into(),
        format!("{} / {}", stats.bypasses, stats.overcommits)]);
    pt.row(&["quota overruns".into(), stats.quota_overruns.to_string()]);
    pt.row(&["peak used".into(),
        format!("{:.1} MB", stats.peak_used_bytes as f64 / 1e6)]);
    pt.row(&["cold load p50 / p95".into(),
        format!("{:.3} / {:.3} ms", stats.cold_load_p50_ms, stats.cold_load_p95_ms)]);
    pt.print();
    if verify {
        println!("verified {verified} outputs bit-identical to the unpooled reference");
    }

    if let Some(path) = flag_value(args, "--json-out") {
        use crate::serialize::Json;
        let doc = Json::obj(vec![
            ("pool", stats.to_json()),
            ("combined_weight_bytes", Json::num(combined as f64)),
            ("verified", Json::num(verified as f64)),
            (
                "models",
                Json::Arr(
                    programs
                        .iter()
                        .zip(&per_model)
                        .map(|(p, s)| {
                            Json::obj(vec![
                                ("model", Json::str(p.model())),
                                (
                                    "weight_bytes",
                                    Json::num(p.resident_bytes() as f64),
                                ),
                                ("engine", engine_stats_json(s)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]);
        write_json(&path, &doc)?;
    }
    if let Some((path, rec)) = &trace {
        write_trace(path, rec)?;
    }

    if args.iter().any(|a| a == "--expect-evictions") {
        let failed: u64 = per_model.iter().map(|s| s.failed).sum();
        if failed > 0 {
            return Err(CompileError::Exec(format!(
                "--expect-evictions: {failed} requests failed"
            )));
        }
        if stats.evictions == 0 {
            return Err(CompileError::Exec(
                "--expect-evictions: the pool never evicted (pool too large \
                 for the workload?)"
                    .into(),
            ));
        }
    }
    Ok(())
}

/// Parse a float flag with a default.
fn parse_float(args: &[String], flag: &str, default: f64) -> Result<f64> {
    match flag_value(args, flag) {
        None => Ok(default),
        Some(v) => v
            .parse::<f64>()
            .map_err(|_| CompileError::config(format!("bad {flag} {v:?} (need a number)"))),
    }
}

/// A flag that may appear bare or with a value: `None` when absent,
/// `Some(None)` when bare, `Some(Some(v))` when a non-flag value follows.
fn flag_optional_value(args: &[String], flag: &str) -> Option<Option<String>> {
    args.iter()
        .position(|a| a == flag)
        .map(|i| args.get(i + 1).filter(|v| !v.starts_with("--")).cloned())
}

fn engine_stats_json(stats: &EngineStats) -> crate::serialize::Json {
    use crate::serialize::Json;
    Json::obj(vec![
        ("backend", Json::str(stats.backend)),
        ("policy", Json::str(stats.policy)),
        ("submitted", Json::num(stats.submitted as f64)),
        ("completed", Json::num(stats.completed as f64)),
        ("failed", Json::num(stats.failed as f64)),
        ("rejected", Json::num(stats.rejected as f64)),
        ("deadline_misses", Json::num(stats.deadline_misses as f64)),
        ("joined", Json::num(stats.joined as f64)),
        ("queue_depth", Json::num(stats.queue_depth as f64)),
        ("in_flight", Json::num(stats.in_flight as f64)),
        ("peak_in_flight", Json::num(stats.peak_in_flight as f64)),
        (
            "per_worker",
            Json::Arr(stats.per_worker.iter().map(|&n| Json::num(n as f64)).collect()),
        ),
        ("batches", Json::num(stats.batches as f64)),
        ("max_batch_seen", Json::num(stats.max_batch_seen as f64)),
        ("elapsed_s", Json::num(stats.elapsed_s)),
        ("throughput_rps", Json::num(stats.throughput_rps)),
        ("p50_ms", Json::num(stats.p50_ms)),
        ("p95_ms", Json::num(stats.p95_ms)),
        ("mean_wait_ms", Json::num(stats.mean_wait_ms)),
        (
            "pool",
            stats.pool.as_ref().map(|p| p.to_json()).unwrap_or(Json::Null),
        ),
        ("queue_wait_ms_hist", stats.queue_wait_ms_hist.to_json()),
        ("batch_size_hist", stats.batch_size_hist.to_json()),
        ("cold_load_ms_hist", stats.cold_load_ms_hist.to_json()),
    ])
}

/// Write a JSON document to `path` with a trailing newline.
fn write_json(path: &str, doc: &crate::serialize::Json) -> Result<()> {
    let mut text = doc.to_string_pretty();
    text.push('\n');
    std::fs::write(path, text).map_err(|e| CompileError::io(path, e))?;
    println!("wrote {path}");
    Ok(())
}

/// Write a recorder's Chrome trace-event JSON to `path` (load it in
/// chrome://tracing or Perfetto).
fn write_trace(path: &str, rec: &TraceRecorder) -> Result<()> {
    std::fs::write(path, rec.export_chrome()).map_err(|e| CompileError::io(path, e))?;
    println!("wrote {path}");
    Ok(())
}

fn cmd_shard(args: &[String]) -> Result<()> {
    let (graph, cfg, imported) = parse_model(args)?;
    let devices = parse_count(args, "--devices", 2)?;
    let link = LinkModel::new(
        parse_float(args, "--link-gbps", LinkModel::pcie_gen3().gbps)?,
        parse_float(args, "--link-latency-us", LinkModel::pcie_gen3().latency_us)?,
    )?;
    let objective = match flag_value(args, "--objective").as_deref() {
        None | Some("latency") => Objective::Latency,
        Some("throughput") => Objective::Throughput,
        Some(other) => {
            return Err(CompileError::config(format!(
                "unknown --objective {other:?} — one of latency, throughput"
            )))
        }
    };
    let format = flag_value(args, "--format").unwrap_or_else(|| "text".into());
    if !matches!(format.as_str(), "text" | "json") {
        return Err(CompileError::config(format!(
            "unknown --format {format:?} — one of text, json"
        )));
    }

    let plan = Partitioner::homogeneous(cfg, devices)?
        .with_link(link)
        .with_strategy(parse_strategy(args)?.into())
        .with_objective(objective)
        .plan(&graph)?;

    match format.as_str() {
        "json" => {
            let mut text = plan.to_json().to_string_pretty();
            text.push('\n');
            print!("{text}");
        }
        _ => print!("{}", render_shard_text(&plan)),
    }
    if let Some(path) = flag_value(args, "--json-out") {
        write_json(&path, &plan.to_json())?;
    }

    if let Some(prefix) = flag_optional_value(args, "--pack") {
        // bare --pack defaults to the model name as the file prefix
        let prefix = prefix
            .or_else(|| args.first().filter(|a| !a.starts_with("--")).cloned())
            .unwrap_or_else(|| "shardplan".into());
        let params = if args.iter().any(|a| a == "--random-params") {
            Some(Params::random(&crate::analyzer::analyze(&graph), 7))
        } else {
            // imported .onnx parameters shard along with the graph
            imported
        };
        let programs = plan.pack_with_params(params.as_ref())?;
        for program in &programs {
            let index = program.boundary().map(|b| b.index).unwrap_or(0);
            let path = format!("{prefix}.shard{index}.sfp");
            program.save(std::path::Path::new(&path))?;
            println!(
                "packed {} [{}] for {} -> {path}",
                program.model(),
                program.strategy(),
                program.cfg().name
            );
        }
    }
    Ok(())
}

fn render_shard_text(plan: &ShardPlan) -> String {
    let mut t = Table::new(
        &format!(
            "shard plan: {} across {} device(s) — objective {}, {} boundaries, {} splits",
            plan.model,
            plan.devices(),
            plan.objective.name(),
            plan.boundaries,
            plan.splits_evaluated
        ),
        &[
            "shard", "blocks", "groups", "latency ms", "SRAM MB", "DRAM MB", "feasible",
            "egress", "link ms",
        ],
    );
    for s in &plan.shards {
        let egress = s
            .egress
            .as_ref()
            .map(|e| format!("{} {}", e.name, e.shape))
            .unwrap_or_else(|| "(model output)".into());
        let link = plan
            .transfers
            .get(s.index)
            .map(|tr| format!("{:.4}", tr.transfer_ms))
            .unwrap_or_else(|| "-".into());
        t.row(&[
            s.index.to_string(),
            format!("{}..{}", s.first_block, s.last_block),
            s.groups.to_string(),
            format!("{:.3}", s.latency_ms),
            format!("{:.3}", s.sram_bytes as f64 / 1e6),
            format!("{:.2}", s.dram_bytes as f64 / 1e6),
            s.feasible.to_string(),
            egress,
            link,
        ]);
    }
    let mut out = t.render();
    out.push_str(&format!(
        "single-image latency {:.3} ms; pipeline interval {:.3} ms ({:.1} fps); \
         total SRAM {:.3} MB\n",
        plan.latency_ms,
        plan.interval_ms,
        plan.throughput_fps(),
        plan.total_sram_bytes() as f64 / 1e6
    ));
    out.push_str(&format!(
        "link: {} GB/s, {} us per transfer; strategy {}\n",
        plan.link.gbps,
        plan.link.latency_us,
        plan.strategy_name()
    ));
    if !plan.feasible {
        out.push_str("WARNING: at least one shard misses its device's SRAM budget\n");
    }
    out
}

/// Parse a comma-separated flag value with `parse` applied per element.
fn parse_list<T>(
    args: &[String],
    flag: &str,
    parse: impl Fn(&str) -> Result<T>,
) -> Result<Vec<T>> {
    match flag_value(args, flag) {
        None => Ok(Vec::new()),
        Some(v) => v.split(',').map(|s| parse(s.trim())).collect(),
    }
}

fn cmd_explore(args: &[String]) -> Result<()> {
    let models: Vec<&str> = args
        .iter()
        .take_while(|a| !a.starts_with("--"))
        .map(String::as_str)
        .collect();
    if models.is_empty() {
        return Err(CompileError::config(
            "expected at least one model — see `shortcutfusion list`",
        ));
    }
    let base = match flag_value(args, "--config") {
        Some(p) => AccelConfig::from_toml_file(std::path::Path::new(&p))?,
        None => AccelConfig::kcu1500_int8(),
    };

    let mut space = SearchSpace::new(base.clone()).models(&models);
    if let Some(v) = flag_value(args, "--input") {
        let n = v
            .parse::<usize>()
            .map_err(|_| CompileError::config(format!("bad --input {v:?}")))?;
        // same contract as `compile --input`: a fixed-geometry model
        // must not silently ignore an explicit size
        for m in &models {
            check_fixed_input(m, n)?;
        }
        space = space.input_sizes(&[n]);
    }
    let budgets = parse_list(args, "--sram-budgets", |s| {
        s.parse::<usize>()
            .map_err(|_| CompileError::config(format!("bad --sram-budgets entry {s:?}")))
    })?;
    space = if budgets.is_empty() {
        // default ablation axis: quarter, half and full base budget
        space.sram_budgets(&[base.sram_budget / 4, base.sram_budget / 2, base.sram_budget])
    } else {
        space.sram_budgets(&budgets)
    };
    let macs = parse_list(args, "--mac", |s| {
        s.split_once('x')
            .and_then(|(r, c)| Some((r.parse::<usize>().ok()?, c.parse::<usize>().ok()?)))
            .filter(|&(r, c)| r > 0 && c > 0)
            .ok_or_else(|| {
                CompileError::config(format!("bad --mac entry {s:?} (want RxC, both >= 1)"))
            })
    })?;
    if !macs.is_empty() {
        space = space.mac_arrays(&macs);
    }
    let gbps = parse_list(args, "--dram-gbps", |s| {
        s.parse::<f64>()
            .map_err(|_| CompileError::config(format!("bad --dram-gbps entry {s:?}")))
    })?;
    if !gbps.is_empty() {
        space = space.dram_bandwidths(&gbps);
    }
    space = match flag_value(args, "--strategies") {
        Some(v) => {
            let names: Vec<&str> = v.split(',').map(str::trim).collect();
            space.strategy_names(&names)?
        }
        None => space.ablation_strategies(),
    };
    if let Some(v) = flag_value(args, "--max-bram") {
        let n = v
            .parse::<usize>()
            .map_err(|_| CompileError::config(format!("bad --max-bram {v:?}")))?;
        space = space.max_bram18k(n);
    }
    if let Some(v) = flag_value(args, "--max-dram-gbps") {
        let x = v
            .parse::<f64>()
            .map_err(|_| CompileError::config(format!("bad --max-dram-gbps {v:?}")))?;
        space = space.max_dram_gbps(x);
    }
    if let Some(v) = flag_value(args, "--max-dsp") {
        let n = v
            .parse::<usize>()
            .map_err(|_| CompileError::config(format!("bad --max-dsp {v:?}")))?;
        space = space.max_dsp(n);
    }
    let threads = match flag_value(args, "--threads") {
        Some(_) => parse_count(args, "--threads", 4)?,
        None => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
    };
    // validate the output format up front: a typo must not cost a sweep
    let format = flag_value(args, "--format").unwrap_or_else(|| "text".into());
    if !matches!(format.as_str(), "text" | "json" | "csv") {
        return Err(CompileError::config(format!(
            "unknown --format {format:?} — one of text, json, csv"
        )));
    }

    let session = Session::new();
    let exploration = space.explore(&session, threads)?;

    // membership keys for Pareto / recommendation markers
    let key = |p: &ExplorePoint| {
        (p.model.clone(), p.input, p.strategy_name().to_string(), p.cfg.name.clone())
    };
    let mut pareto_keys = std::collections::BTreeSet::new();
    let mut best_keys = std::collections::BTreeSet::new();
    for model in exploration.models() {
        for p in &exploration.pareto_front(&model).points {
            pareto_keys.insert(key(p));
        }
        if let Some(p) = exploration.recommend(&model) {
            best_keys.insert(key(p));
        }
    }

    let rendered = match format.as_str() {
        "text" => render_explore_text(&exploration, &pareto_keys, &best_keys, threads, &session),
        "csv" => render_explore_csv(&exploration, &pareto_keys, &best_keys),
        _ => render_explore_json(&exploration, &pareto_keys, &best_keys),
    };
    match flag_value(args, "--out") {
        Some(path) => {
            std::fs::write(&path, rendered).map_err(|e| CompileError::io(&path, e))?;
            println!("wrote {path}");
        }
        None => print!("{rendered}"),
    }
    if let Some(path) = flag_value(args, "--json-out") {
        // always the JSON rendering, independent of --format/--out, so
        // CI can emit a human table *and* a machine-readable file
        let text = render_explore_json(&exploration, &pareto_keys, &best_keys);
        std::fs::write(&path, text).map_err(|e| CompileError::io(&path, e))?;
        println!("wrote {path}");
    }

    if let Some(out) = flag_value(args, "--pack-best") {
        let model = models[0];
        if models.len() > 1 {
            println!(
                "note: --pack-best packs the winner of the first listed model ({model}); \
                 other models are only reported"
            );
        }
        let best = exploration.recommend(model).ok_or_else(|| {
            CompileError::config(format!("{model}: no feasible point to pack"))
        })?;
        let program = best.pack()?;
        program.save(std::path::Path::new(&out))?;
        println!(
            "packed best config for {model} [{}] on {} -> {out}",
            best.strategy_name(),
            best.cfg.name
        );
    }
    Ok(())
}

fn render_explore_text(
    exploration: &Exploration,
    pareto: &std::collections::BTreeSet<(String, usize, String, String)>,
    best: &std::collections::BTreeSet<(String, usize, String, String)>,
    threads: usize,
    session: &Session,
) -> String {
    let mut out = String::new();
    let mut t = Table::new(
        &format!(
            "design-space exploration: {} points, {} pruned, {} failed ({} threads)",
            exploration.points.len(),
            exploration.pruned.len(),
            exploration.failures.len(),
            threads
        ),
        &[
            "model", "input", "strategy", "Ti-To", "budget MB", "GB/s", "latency ms",
            "DRAM MB", "sc %", "SRAM KB", "BRAM", "feasible", "front",
        ],
    );
    for p in &exploration.points {
        let k = (p.model.clone(), p.input, p.strategy_name().to_string(), p.cfg.name.clone());
        let marker = if best.contains(&k) {
            "best"
        } else if pareto.contains(&k) {
            "pareto"
        } else {
            ""
        };
        t.row(&[
            p.model.clone(),
            p.input.to_string(),
            p.strategy_name().to_string(),
            format!("{}x{}", p.cfg.ti, p.cfg.to),
            format!("{:.2}", p.cfg.sram_budget as f64 / 1e6),
            format!("{:.1}", p.cfg.dram_gbps),
            format!("{:.3}", p.latency_ms),
            format!("{:.2}", p.dram_mb()),
            format!("{:.1}", p.classes.shortcut_share() * 100.0),
            format!("{:.0}", p.sram_kb()),
            p.bram18k.to_string(),
            p.feasible.to_string(),
            marker.to_string(),
        ]);
    }
    out.push_str(&t.render());
    for pr in &exploration.pruned {
        out.push_str(&format!(
            "pruned: {}@{} on {} — {}\n",
            pr.model, pr.input, pr.cfg_name, pr.reason
        ));
    }
    for f in &exploration.failures {
        out.push_str(&format!("failed: {} — {}\n", f.point, f.error));
    }
    for model in exploration.models() {
        match exploration.recommend(&model) {
            Some(p) => out.push_str(&format!(
                "best {model}: {} on {} — {:.3} ms, {:.2} MB DRAM, {:.0} KB SRAM\n",
                p.strategy_name(),
                p.cfg.name,
                p.latency_ms,
                p.dram_mb(),
                p.sram_kb()
            )),
            None => out.push_str(&format!("best {model}: no feasible point\n")),
        }
    }
    let stats = session.stats();
    out.push_str(&format!(
        "session: {} compiles, {} cache hits, {} shared analyses\n",
        stats.report_misses, stats.report_hits, stats.analysis_hits
    ));
    out
}

fn render_explore_csv(
    exploration: &Exploration,
    pareto: &std::collections::BTreeSet<(String, usize, String, String)>,
    best: &std::collections::BTreeSet<(String, usize, String, String)>,
) -> String {
    let mut out = String::from(
        "model,input,strategy,ti,to,sram_budget,dram_gbps,latency_ms,dram_bytes,\
         weight_bytes,ifm_bytes,ofm_bytes,shortcut_bytes,\
         sram_bytes,bram18k,gops,reduction_pct,feasible,pareto,recommended\n",
    );
    for p in &exploration.points {
        let k = (p.model.clone(), p.input, p.strategy_name().to_string(), p.cfg.name.clone());
        out.push_str(&format!(
            "{},{},{},{},{},{},{:.3},{:.6},{},{},{},{},{},{},{},{:.2},{:.2},{},{},{}\n",
            p.model,
            p.input,
            p.strategy_name(),
            p.cfg.ti,
            p.cfg.to,
            p.cfg.sram_budget,
            p.cfg.dram_gbps,
            p.latency_ms,
            p.dram_bytes,
            p.classes.weights,
            p.classes.ifm,
            p.classes.ofm,
            p.classes.shortcut,
            p.sram_bytes,
            p.bram18k,
            p.gops,
            p.reduction_pct,
            p.feasible,
            pareto.contains(&k),
            best.contains(&k)
        ));
    }
    out
}

fn render_explore_json(
    exploration: &Exploration,
    pareto: &std::collections::BTreeSet<(String, usize, String, String)>,
    best: &std::collections::BTreeSet<(String, usize, String, String)>,
) -> String {
    use crate::serialize::Json;
    let points: Vec<Json> = exploration
        .points
        .iter()
        .map(|p| {
            let k =
                (p.model.clone(), p.input, p.strategy_name().to_string(), p.cfg.name.clone());
            match p.to_json() {
                Json::Obj(mut m) => {
                    m.insert("pareto".into(), Json::Bool(pareto.contains(&k)));
                    m.insert("recommended".into(), Json::Bool(best.contains(&k)));
                    Json::Obj(m)
                }
                other => other,
            }
        })
        .collect();
    let pruned: Vec<Json> = exploration
        .pruned
        .iter()
        .map(|pr| {
            Json::obj(vec![
                ("model", Json::str(&pr.model)),
                ("input", Json::num(pr.input as f64)),
                ("config", Json::str(&pr.cfg_name)),
                ("reason", Json::str(&pr.reason)),
            ])
        })
        .collect();
    let failures: Vec<Json> = exploration
        .failures
        .iter()
        .map(|f| {
            Json::obj(vec![
                ("point", Json::str(&f.point)),
                ("error", Json::str(&f.error.to_string())),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("points", Json::Arr(points)),
        ("pruned", Json::Arr(pruned)),
        ("failures", Json::Arr(failures)),
    ]);
    let mut text = doc.to_string_pretty();
    text.push('\n');
    text
}

fn cmd_sweep(args: &[String]) -> Result<()> {
    let (graph, cfg, _params) = parse_model(args)?;
    let gg = crate::analyzer::analyze(&graph);
    let opt = Optimizer::new(&gg, &cfg);
    let sweep = opt.sweep_first_segment();
    // figure-regeneration output: --csv FILE writes the raw series
    if let Some(csv) = flag_value(args, "--csv") {
        let mut out =
            String::from("cut,sram_mb,bram18k,dram_total_mb,dram_fm_mb,latency_ms,feasible\n");
        for p in &sweep {
            out.push_str(&format!(
                "{},{:.6},{},{:.6},{:.6},{:.6},{}\n",
                p.cut, p.sram_mb, p.bram18k, p.dram_total_mb, p.dram_fm_mb, p.latency_ms, p.feasible
            ));
        }
        std::fs::write(&csv, out).map_err(|e| CompileError::io(&csv, e))?;
        println!("wrote {csv}");
    }
    let mut t = Table::new(
        &format!("cut-point sweep: {} (first of {} segments)", graph.name, opt.segs.len()),
        &["cut", "SRAM MB", "BRAM18K", "DRAM MB", "FM MB", "latency ms", "feasible"],
    );
    for p in sweep {
        t.row(&[
            p.cut.to_string(),
            format!("{:.3}", p.sram_mb),
            p.bram18k.to_string(),
            format!("{:.2}", p.dram_total_mb),
            format!("{:.2}", p.dram_fm_mb),
            format!("{:.3}", p.latency_ms),
            p.feasible.to_string(),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_minbuf(args: &[String]) -> Result<()> {
    let models: Vec<&str> = if args.is_empty() {
        zoo::MODEL_NAMES.to_vec()
    } else {
        args.iter().map(String::as_str).collect()
    };
    let cfg = AccelConfig::kcu1500_int8();
    let compiler = Compiler::with_strategy(
        cfg.clone(),
        std::sync::Arc::new(crate::compiler::MinBufferStrategy),
    );
    let mut t = Table::new(
        "minimum buffer size meeting the DRAM constraints (Table III)",
        &["model", "input", "min SRAM MB", "BRAM18K", "latency ms"],
    );
    for name in models {
        let input = zoo::default_input(name);
        let graph =
            zoo::by_name(name, input).ok_or_else(|| CompileError::unknown_model(name))?;
        let analyzed = compiler.analyze(&graph)?;
        let e = compiler.optimize(&analyzed)?.evaluation;
        t.row(&[
            name.to_string(),
            input.to_string(),
            format!("{:.3}", e.sram.total as f64 / 1e6),
            e.sram.bram18k.to_string(),
            format!("{:.3}", e.latency_ms),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_export(args: &[String]) -> Result<()> {
    let (graph, _cfg, imported) = parse_model(args)?;
    let out = flag_value(args, "--out")
        .ok_or_else(|| CompileError::config("--out FILE required"))?;
    let out_path = std::path::Path::new(&out);
    if out_path.extension().and_then(|e| e.to_str()) == Some("onnx") {
        // ONNX export; parameters (seeded-random or carried over from an
        // imported source) ride along on sf_* attributes so the file
        // re-imports into a servable program bit-identically
        let params = if args.iter().any(|a| a == "--random-params") {
            Some(Params::random(&crate::analyzer::analyze(&graph), 7))
        } else {
            imported
        };
        crate::import::export_file(&graph, params.as_ref(), out_path)?;
        println!(
            "wrote {} ({} nodes, ONNX{})",
            out,
            graph.nodes.len(),
            if params.is_some() { ", params included" } else { "" }
        );
    } else {
        save_frozen(&graph, out_path)?;
        println!("wrote {} ({} nodes)", out, graph.nodes.len());
    }
    Ok(())
}

/// `import FILE.onnx`: decode and lower an ONNX model, report it, and
/// optionally verify it against a zoo builder (`--verify-zoo NAME`:
/// structural node-for-node identity plus bit-identical reference-backend
/// outputs under the imported parameters) or pack it into a deployable
/// program (`--out FILE.sfp`, imported parameters included).
fn cmd_import(args: &[String]) -> Result<()> {
    let path = args
        .first()
        .filter(|a| !a.starts_with("--"))
        .ok_or_else(|| CompileError::config("expected a .onnx model file"))?;
    let imported = crate::import::import_file(std::path::Path::new(path))?;
    let (graph, params) = (imported.graph, imported.params);
    println!(
        "{}: {} nodes, {} conv layers, {:.2} GOP, input {}, {} parameter groups",
        graph.name,
        graph.nodes.len(),
        graph.conv_layer_count(),
        graph.total_gop(),
        graph.input().out_shape,
        params.groups.len()
    );
    let cfg = match flag_value(args, "--config") {
        Some(p) => AccelConfig::from_toml_file(std::path::Path::new(&p))?,
        None => AccelConfig::kcu1500_int8(),
    };

    if let Some(zoo_name) = flag_value(args, "--verify-zoo") {
        let input = graph.input().out_shape.h;
        let reference = zoo::by_name(&zoo_name, input)
            .ok_or_else(|| CompileError::unknown_model(zoo_name.clone()))?;
        if reference.nodes.len() != graph.nodes.len() {
            return Err(CompileError::Exec(format!(
                "import differs from zoo {zoo_name}: {} nodes imported, {} built",
                graph.nodes.len(),
                reference.nodes.len()
            )));
        }
        for (b, a) in reference.nodes.iter().zip(&graph.nodes) {
            if a.name != b.name || a.op != b.op || a.inputs != b.inputs
                || a.out_shape != b.out_shape
            {
                return Err(CompileError::Exec(format!(
                    "import differs from zoo {zoo_name} at node {:?} (built {:?})",
                    a.name, b.name
                )));
            }
        }
        // same structure + same parameters must give the same integers
        let p_imp = pack_graph(&graph, cfg.clone(), args, Some(params.clone()))?;
        let p_ref = pack_graph(&reference, cfg.clone(), args, Some(params.clone()))?;
        let input_t = program_input(&p_imp, 1);
        let got = ReferenceBackend.run(&p_imp, &input_t)?;
        let want = ReferenceBackend.run(&p_ref, &input_t)?;
        if got.output != want.output {
            return Err(CompileError::Exec(format!(
                "imported outputs diverge from the zoo {zoo_name} reference"
            )));
        }
        println!(
            "verified against zoo {zoo_name}: {} nodes structurally identical, \
             reference outputs bit-identical",
            graph.nodes.len()
        );
    }

    if let Some(out) = flag_value(args, "--out") {
        let attach = if params.groups.is_empty() { None } else { Some(params) };
        let with_params = attach.is_some();
        let program = pack_graph(&graph, cfg, args, attach)?;
        program.save(std::path::Path::new(&out))?;
        println!(
            "packed {} [{}] for {}: {} instructions{} -> {}",
            program.model(),
            program.strategy(),
            program.cfg().name,
            program.stream().len(),
            if with_params { " (params included)" } else { "" },
            out
        );
    }
    Ok(())
}

fn cmd_report(args: &[String]) -> Result<()> {
    let threads = match flag_value(args, "--threads") {
        Some(v) => match v.parse::<usize>() {
            Ok(n) if n > 0 => n,
            _ => {
                return Err(CompileError::config(format!(
                    "bad --threads {v:?} (need a positive integer)"
                )))
            }
        },
        None => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
    };
    let cfg = AccelConfig::kcu1500_int8();
    let session = Session::with_strategy(parse_strategy(args)?.into());
    let results = session.sweep_zoo(&cfg, threads);
    let mut t = Table::new(
        &format!(
            "zoo report on {} ({} threads, strategy {})",
            cfg.name,
            threads,
            session.strategy_name()
        ),
        &["model", "latency ms", "GOPS", "eff %", "DRAM MB", "reduction %", "SRAM MB", "feasible"],
    );
    for r in results {
        match r {
            Ok(r) => t.row(&[
                r.model.clone(),
                format!("{:.2}", r.latency_ms()),
                format!("{:.0}", r.gops()),
                format!("{:.1}", r.mac_efficiency_pct()),
                format!("{:.1}", r.offchip_total_mb()),
                format!("{:.1}", r.reduction_pct()),
                format!("{:.2}", r.sram_mb()),
                r.evaluation.feasible.to_string(),
            ]),
            Err(e) => t.row(&[
                e.to_string(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
            ]),
        }
    }
    t.print();
    Ok(())
}

fn cmd_load(args: &[String]) -> Result<()> {
    let path = args
        .first()
        .ok_or_else(|| CompileError::config("expected a file path"))?;
    let g = load_frozen(std::path::Path::new(path))?;
    println!(
        "{}: {} nodes, {} conv layers, {:.2} GOP, {:.2} M params",
        g.name,
        g.nodes.len(),
        g.conv_layer_count(),
        g.total_gop(),
        g.total_weight_bytes(1) as f64 / 1e6
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn help_and_list_run() {
        run(vec!["help".into()]).unwrap();
        run(vec!["list".into()]).unwrap();
    }

    #[test]
    fn unknown_command_errors() {
        assert!(run(vec!["frobnicate".into()]).is_err());
    }

    #[test]
    fn compile_small_model() {
        run(vec!["compile".into(), "resnet18".into(), "--input".into(), "64".into()]).unwrap();
    }

    #[test]
    fn fixed_geometry_input_is_rejected_typed() {
        // tinynet compiles at its canonical size…
        run(vec!["compile".into(), "tinynet".into()]).unwrap();
        // …but an explicit non-canonical --input is a config error, not a
        // silently ignored flag
        assert!(matches!(
            run(vec!["compile".into(), "tinynet".into(), "--input".into(), "224".into()]),
            Err(CompileError::Config(_))
        ));
    }

    #[test]
    fn compile_with_baseline_strategy() {
        run(vec![
            "compile".into(),
            "resnet18".into(),
            "--input".into(),
            "64".into(),
            "--strategy".into(),
            "fixed-frame".into(),
        ])
        .unwrap();
        // the tile family resolves both as the auto sweep and pinned
        run(vec![
            "compile".into(),
            "resnet18".into(),
            "--input".into(),
            "64".into(),
            "--strategy".into(),
            "tile-8".into(),
        ])
        .unwrap();
        let err = run(vec![
            "compile".into(),
            "resnet18".into(),
            "--strategy".into(),
            "bogus".into(),
        ]);
        assert!(matches!(err, Err(CompileError::Config(_))));
    }

    #[test]
    fn export_load_roundtrip() {
        let dir = std::env::temp_dir().join("sf_cli_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("m.json");
        run(vec![
            "export".into(),
            "resnet18".into(),
            "--input".into(),
            "64".into(),
            "--out".into(),
            p.to_string_lossy().into_owned(),
        ])
        .unwrap();
        run(vec!["load".into(), p.to_string_lossy().into_owned()]).unwrap();
    }

    #[test]
    fn export_import_onnx_roundtrip_via_cli() {
        // the CI smoke path: export tinynet to ONNX with embedded seeded
        // parameters, re-import it, verify it against the zoo builder
        // (structural + bit-identical reference outputs), pack it, and
        // execute the packed artifact on the reference backend
        let dir = std::env::temp_dir().join("sf_cli_onnx_test");
        std::fs::create_dir_all(&dir).unwrap();
        let onnx = dir.join("tiny.onnx");
        let sfp = dir.join("tiny_imported.sfp");
        run(vec![
            "export".into(),
            "tinynet".into(),
            "--random-params".into(),
            "--out".into(),
            onnx.to_string_lossy().into_owned(),
        ])
        .unwrap();
        run(vec![
            "import".into(),
            onnx.to_string_lossy().into_owned(),
            "--verify-zoo".into(),
            "tinynet".into(),
            "--out".into(),
            sfp.to_string_lossy().into_owned(),
        ])
        .unwrap();
        run(vec![
            "run".into(),
            sfp.to_string_lossy().into_owned(),
            "--backend".into(),
            "reference".into(),
        ])
        .unwrap();
        // a .onnx path is a model anywhere a zoo name is: compile and
        // run it directly (run compiles the file in memory)
        run(vec!["compile".into(), onnx.to_string_lossy().into_owned()]).unwrap();
        run(vec![
            "run".into(),
            onnx.to_string_lossy().into_owned(),
            "--backend".into(),
            "reference".into(),
        ])
        .unwrap();
        // verifying an import against a structurally different zoo
        // model is a typed execution error, not a panic
        assert!(matches!(
            run(vec![
                "import".into(),
                onnx.to_string_lossy().into_owned(),
                "--verify-zoo".into(),
                "resnet18".into(),
            ]),
            Err(CompileError::Exec(_))
        ));
        // a truncated file is a typed parse error
        let bytes = std::fs::read(&onnx).unwrap();
        let bad = dir.join("bad.onnx");
        std::fs::write(&bad, &bytes[..bytes.len() / 2]).unwrap();
        assert!(run(vec!["import".into(), bad.to_string_lossy().into_owned()]).is_err());
    }

    #[test]
    fn sweep_writes_csv() {
        let dir = std::env::temp_dir().join("sf_cli_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("sweep.csv");
        run(vec![
            "sweep".into(),
            "resnet18".into(),
            "--input".into(),
            "64".into(),
            "--csv".into(),
            p.to_string_lossy().into_owned(),
        ])
        .unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.starts_with("cut,sram_mb"));
        assert!(text.lines().count() > 2);
    }

    #[test]
    fn bad_model_errors() {
        assert!(matches!(
            run(vec!["compile".into(), "alexnet".into()]),
            Err(CompileError::UnknownModel { .. })
        ));
    }

    #[test]
    fn pack_run_serve_round_trip() {
        // the acceptance path: compile -> pack -> save -> load -> execute
        // through both backends -> serve, all via the CLI
        let dir = std::env::temp_dir().join("sf_cli_pack_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("resnet18_32.sfp");
        let path = p.to_string_lossy().into_owned();
        run(vec![
            "pack".into(),
            "resnet18".into(),
            "--input".into(),
            "32".into(),
            "--random-params".into(),
            "--out".into(),
            path.clone(),
        ])
        .unwrap();
        run(vec!["run".into(), path.clone(), "--backend".into(), "virtual".into()]).unwrap();
        run(vec!["run".into(), path.clone(), "--backend".into(), "reference".into()]).unwrap();
        run(vec![
            "serve-bench".into(),
            path,
            "--requests".into(),
            "8".into(),
            "--workers".into(),
            "2".into(),
            "--batch".into(),
            "2".into(),
        ])
        .unwrap();
    }

    #[test]
    fn serve_zoo_pages_a_small_pool_and_writes_json() {
        // size the pool between the largest single footprint and the
        // combined one: either model fits alone, both together do not,
        // so every tenant switch must evict
        let a = crate::testutil::pack_program(&zoo::by_name("resnet18", 32).unwrap(), None);
        let b = crate::testutil::pack_program(&zoo::by_name("resnet34", 32).unwrap(), None);
        let (am, bm) = (a.resident_bytes() as f64 / 1e6, b.resident_bytes() as f64 / 1e6);
        let pool_mb = (am.max(bm) + am + bm) / 2.0;
        let dir = std::env::temp_dir().join("sf_cli_zoo_test");
        std::fs::create_dir_all(&dir).unwrap();
        let json = dir.join("zoo.json");
        run(vec![
            "serve-zoo".into(),
            "resnet18".into(),
            "resnet34".into(),
            "--input".into(),
            "32".into(),
            "--pool-mb".into(),
            format!("{pool_mb}"),
            "--rounds".into(),
            "2".into(),
            "--requests".into(),
            "2".into(),
            "--expect-evictions".into(),
            "--json-out".into(),
            json.to_string_lossy().into_owned(),
        ])
        .unwrap();
        let doc = crate::serialize::parse(&std::fs::read_to_string(&json).unwrap()).unwrap();
        let pool = doc.get("pool").unwrap();
        assert!(pool.get("evictions").and_then(|e| e.as_usize()).unwrap() > 0);
        assert_eq!(pool.get("policy").and_then(|p| p.as_str()), Some("slru"));
        let models = doc.get("models").and_then(|m| m.as_arr()).unwrap();
        assert_eq!(models.len(), 2);
        for m in models {
            let engine = m.get("engine").unwrap();
            assert_eq!(engine.get("failed").and_then(|f| f.as_usize()), Some(0));
            assert_eq!(engine.get("completed").and_then(|c| c.as_usize()), Some(4));
        }
    }

    #[test]
    fn serve_zoo_verify_is_bit_identical_even_when_bypassing() {
        // one model + the half-footprint default pool: the segment is
        // larger than the whole pool, so every request takes the bypass
        // path — outputs must still match the unpooled reference
        run(vec![
            "serve-zoo".into(),
            "tinynet".into(),
            "--backend".into(),
            "reference".into(),
            "--verify".into(),
            "--rounds".into(),
            "2".into(),
            "--requests".into(),
            "2".into(),
        ])
        .unwrap();
    }

    #[test]
    fn serve_zoo_rejects_bad_flags() {
        assert!(matches!(run(vec!["serve-zoo".into()]), Err(CompileError::Config(_))));
        assert!(matches!(
            run(vec!["serve-zoo".into(), "tinynet".into(), "--policy".into(), "mru".into()]),
            Err(CompileError::Config(_))
        ));
        // --verify needs bit-exact outputs, i.e. the reference backend
        assert!(matches!(
            run(vec!["serve-zoo".into(), "tinynet".into(), "--verify".into()]),
            Err(CompileError::Config(_))
        ));
        assert!(matches!(
            run(vec![
                "serve-zoo".into(),
                "tinynet".into(),
                "--pool-mb".into(),
                "-3".into()
            ]),
            Err(CompileError::Config(_))
        ));
    }

    #[test]
    fn explore_runs_all_formats_and_packs_best() {
        // tinynet keeps the 3-budget × 4-strategy default grid fast; the
        // CI quickstart step smoke-runs the same command.
        run(vec!["explore".into(), "tinynet".into(), "--threads".into(), "2".into()]).unwrap();

        let dir = std::env::temp_dir().join("sf_cli_explore_test");
        std::fs::create_dir_all(&dir).unwrap();
        let csv = dir.join("points.csv");
        run(vec![
            "explore".into(),
            "tinynet".into(),
            "--format".into(),
            "csv".into(),
            "--out".into(),
            csv.to_string_lossy().into_owned(),
        ])
        .unwrap();
        let text = std::fs::read_to_string(&csv).unwrap();
        assert!(text.starts_with("model,input,strategy"));
        assert_eq!(text.lines().count(), 1 + 12, "3 budgets x 4 strategies");
        assert!(text.contains("cutpoint"));

        let json = dir.join("points.json");
        let packed = dir.join("best.sfp");
        run(vec![
            "explore".into(),
            "tinynet".into(),
            "--format".into(),
            "json".into(),
            "--out".into(),
            json.to_string_lossy().into_owned(),
            "--pack-best".into(),
            packed.to_string_lossy().into_owned(),
        ])
        .unwrap();
        let doc = crate::serialize::parse(&std::fs::read_to_string(&json).unwrap()).unwrap();
        assert_eq!(doc.get("points").and_then(|p| p.as_arr()).unwrap().len(), 12);
        let best = Program::load(&packed).unwrap();
        assert_eq!(best.model(), "TinyNet-SE");
    }

    #[test]
    fn explore_rejects_bad_input() {
        assert!(matches!(
            run(vec!["explore".into(), "alexnet".into()]),
            Err(CompileError::UnknownModel { .. })
        ));
        assert!(matches!(run(vec!["explore".into()]), Err(CompileError::Config(_))));
        assert!(matches!(
            run(vec!["explore".into(), "tinynet".into(), "--format".into(), "xml".into()]),
            Err(CompileError::Config(_))
        ));
        assert!(matches!(
            run(vec!["explore".into(), "tinynet".into(), "--mac".into(), "64".into()]),
            Err(CompileError::Config(_))
        ));
        // hex-looking typo: "0x40" must be a typed error, not a
        // divide-by-zero panic in a worker thread
        assert!(matches!(
            run(vec!["explore".into(), "tinynet".into(), "--mac".into(), "0x40".into()]),
            Err(CompileError::Config(_))
        ));
        // fixed-geometry models reject explicit non-canonical inputs
        // here too, matching `compile --input`
        assert!(matches!(
            run(vec!["explore".into(), "tinynet".into(), "--input".into(), "224".into()]),
            Err(CompileError::Config(_))
        ));
    }

    #[test]
    fn shard_plans_packs_and_writes_json() {
        let dir = std::env::temp_dir().join("sf_cli_shard_test");
        std::fs::create_dir_all(&dir).unwrap();
        let json = dir.join("plan.json");
        let prefix = dir.join("tiny");
        run(vec![
            "shard".into(),
            "tinynet".into(),
            "--devices".into(),
            "2".into(),
            "--json-out".into(),
            json.to_string_lossy().into_owned(),
            "--pack".into(),
            prefix.to_string_lossy().into_owned(),
        ])
        .unwrap();
        let doc = crate::serialize::parse(&std::fs::read_to_string(&json).unwrap()).unwrap();
        assert_eq!(doc.get("devices").and_then(|d| d.as_usize()), Some(2));
        assert_eq!(
            doc.get("shards").and_then(|s| s.as_arr()).map(|s| s.len()),
            Some(2)
        );
        for i in 0..2 {
            let p = Program::load(&dir.join(format!("tiny.shard{i}.sfp"))).unwrap();
            let b = p.boundary().expect("sharded artifact carries its boundary");
            assert_eq!((b.index, b.count), (i, 2));
        }
        // json format on stdout + throughput objective also run
        run(vec![
            "shard".into(),
            "tinynet".into(),
            "--devices".into(),
            "2".into(),
            "--format".into(),
            "json".into(),
            "--objective".into(),
            "throughput".into(),
        ])
        .unwrap();
    }

    #[test]
    fn shard_rejects_bad_flags() {
        assert!(matches!(
            run(vec!["shard".into(), "tinynet".into(), "--objective".into(), "power".into()]),
            Err(CompileError::Config(_))
        ));
        assert!(matches!(
            run(vec!["shard".into(), "tinynet".into(), "--format".into(), "csv".into()]),
            Err(CompileError::Config(_))
        ));
        assert!(matches!(
            run(vec!["shard".into(), "tinynet".into(), "--link-gbps".into(), "0".into()]),
            Err(CompileError::Config(_))
        ));
        // more devices than boundaries is a typed error, not a panic
        assert!(matches!(
            run(vec!["shard".into(), "tinynet".into(), "--devices".into(), "60".into()]),
            Err(CompileError::Config(_))
        ));
    }

    #[test]
    fn serve_bench_and_explore_write_json_out() {
        let dir = std::env::temp_dir().join("sf_cli_jsonout_test");
        std::fs::create_dir_all(&dir).unwrap();
        let program = dir.join("tiny.sfp");
        run(vec![
            "pack".into(),
            "tinynet".into(),
            "--out".into(),
            program.to_string_lossy().into_owned(),
        ])
        .unwrap();
        let stats = dir.join("stats.json");
        run(vec![
            "serve-bench".into(),
            program.to_string_lossy().into_owned(),
            "--requests".into(),
            "4".into(),
            "--json-out".into(),
            stats.to_string_lossy().into_owned(),
        ])
        .unwrap();
        let doc = crate::serialize::parse(&std::fs::read_to_string(&stats).unwrap()).unwrap();
        assert_eq!(doc.get("completed").and_then(|c| c.as_usize()), Some(4));
        assert!(doc.get("p95_ms").and_then(|p| p.as_f64()).is_some());

        // explore: text on stdout AND machine-readable file
        let front = dir.join("front.json");
        run(vec![
            "explore".into(),
            "tinynet".into(),
            "--json-out".into(),
            front.to_string_lossy().into_owned(),
        ])
        .unwrap();
        let doc = crate::serialize::parse(&std::fs::read_to_string(&front).unwrap()).unwrap();
        assert_eq!(doc.get("points").and_then(|p| p.as_arr()).map(|p| p.len()), Some(12));
    }

    #[test]
    fn pack_requires_out_flag() {
        assert!(matches!(
            run(vec!["pack".into(), "resnet18".into(), "--input".into(), "32".into()]),
            Err(CompileError::Config(_))
        ));
    }

    #[test]
    fn run_rejects_unknown_backend_and_missing_file() {
        let dir = std::env::temp_dir().join("sf_cli_pack_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("tiny.sfp");
        let path = p.to_string_lossy().into_owned();
        run(vec![
            "pack".into(),
            "resnet18".into(),
            "--input".into(),
            "32".into(),
            "--out".into(),
            path.clone(),
        ])
        .unwrap();
        assert!(matches!(
            run(vec!["run".into(), path.clone(), "--backend".into(), "gpu".into()]),
            Err(CompileError::Config(_))
        ));
        assert!(matches!(
            run(vec!["run".into(), "/nonexistent/x.sfp".into()]),
            Err(CompileError::Io { .. })
        ));
        // reference needs packed params; this artifact has none
        assert!(matches!(
            run(vec!["run".into(), path, "--backend".into(), "reference".into()]),
            Err(CompileError::Artifact(_))
        ));
    }
}
