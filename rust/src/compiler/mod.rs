//! The staged compile API (the redesign of the one-shot `compile_model`).
//!
//! The paper's Fig.-4 pipeline is exposed as five typed stages, each an
//! owned artifact that can be inspected, serialized, or cached on its
//! own:
//!
//! ```text
//! Graph ──analyze──> Analyzed ──optimize──> Optimized ──allocate──>
//!     Allocated ──lower──> Lowered ──simulate──> Simulated ──> CompileReport
//! ```
//!
//! * [`Compiler`] holds the target [`AccelConfig`], the pluggable
//!   [`ReuseStrategy`] (the paper's cut-point optimizer by default), and
//!   optional quantized [`Params`] whose per-group shifts are encoded
//!   into the instruction stream.
//! * [`Session`] memoizes stage artifacts per `(model, input, config,
//!   strategy)` and runs multi-model / multi-config sweeps across scoped
//!   threads.
//! * [`CompileError`] is the typed error for the whole path — no
//!   `anyhow`, no hot-path panics.
//!
//! ```no_run
//! use shortcutfusion::compiler::Compiler;
//! use shortcutfusion::config::AccelConfig;
//! use shortcutfusion::zoo;
//!
//! let compiler = Compiler::new(AccelConfig::kcu1500_int8());
//! let analyzed = compiler.analyze(&zoo::resnet50(256)).unwrap();
//! let optimized = compiler.optimize(&analyzed).unwrap();
//! println!("cuts: {:?}", optimized.evaluation.cuts.cuts);
//! let allocated = compiler.allocate(&optimized).unwrap();
//! let lowered = compiler.lower(&allocated).unwrap();
//! let simulated = compiler.simulate(&lowered).unwrap();
//! let report = simulated.into_report();
//! println!("{}: {:.2} ms", report.model, report.latency_ms());
//! ```

mod error;
mod session;
mod stages;
pub mod strategy;

pub use error::CompileError;
pub(crate) use session::fan_out;
pub use session::{Session, SessionStats, SweepJob};
pub use stages::{Allocated, Analyzed, CompileReport, Lowered, Optimized, Simulated};
pub use strategy::{
    CutPointStrategy, FixedReuseStrategy, MinBufferStrategy, ReuseStrategy,
    ShortcutMiningStrategy, SmartShuttleStrategy, TileStreamingStrategy,
};

use std::sync::Arc;

use crate::analyzer::analyze;
use crate::config::AccelConfig;
use crate::funcsim::Params;
use crate::graph::{validate, Graph};
use crate::isa::{lower, MemAssign};
use crate::power::{estimate as power_estimate, PowerModel};
use crate::sim::simulate_with_tiles;

use stages::to_memloc;
pub(crate) use stages::quant_shift_for;

/// The staged compiler: one target configuration + one reuse strategy.
///
/// Cheap to clone (the strategy is shared); every stage method borrows
/// its input artifact, so artifacts can be cached and re-fed freely.
#[derive(Clone)]
pub struct Compiler {
    cfg: AccelConfig,
    strategy: Arc<dyn ReuseStrategy>,
    params: Option<Arc<Params>>,
    strict_feasibility: bool,
}

impl Compiler {
    /// A compiler using the paper's reuse-aware cut-point optimizer.
    pub fn new(cfg: AccelConfig) -> Compiler {
        Compiler::with_strategy(cfg, Arc::new(CutPointStrategy))
    }

    /// A compiler with an explicit reuse strategy (baselines plug in
    /// here — see [`strategy`]).
    pub fn with_strategy(cfg: AccelConfig, strategy: Arc<dyn ReuseStrategy>) -> Compiler {
        Compiler { cfg, strategy, params: None, strict_feasibility: false }
    }

    /// Attach quantized parameters; their per-group shifts are encoded
    /// into the lowered instruction stream (`quant_shift`).
    pub fn with_params(mut self, params: Params) -> Compiler {
        self.params = Some(Arc::new(params));
        self
    }

    /// Fail [`Compiler::optimize`] with [`CompileError::Infeasible`] when
    /// no policy meets the eq-(10) buffer constraint (default: report the
    /// best-effort policy with `feasible = false`, like the seed API).
    pub fn strict_feasibility(mut self, strict: bool) -> Compiler {
        self.strict_feasibility = strict;
        self
    }

    /// The target configuration this compiler produces artifacts for.
    pub fn cfg(&self) -> &AccelConfig {
        &self.cfg
    }

    /// Name of the configured reuse strategy.
    pub fn strategy_name(&self) -> &'static str {
        self.strategy.name()
    }

    /// Stage 1: validate the graph and fuse it into accelerator groups.
    /// Config-independent — one `Analyzed` serves any number of configs.
    pub fn analyze(&self, graph: &Graph) -> Result<Analyzed, CompileError> {
        validate(graph)?;
        Ok(Analyzed { model: graph.name.clone(), grouped: Arc::new(analyze(graph)) })
    }

    /// Stage 2: choose the per-group reuse policy via the strategy.
    pub fn optimize(&self, analyzed: &Analyzed) -> Result<Optimized, CompileError> {
        let evaluation = self.strategy.decide(&analyzed.grouped, &self.cfg)?;
        if evaluation.policy.len() != analyzed.grouped.groups.len() {
            return Err(CompileError::stage(format!(
                "strategy {:?} produced {} policy entries for {} groups",
                self.strategy.name(),
                evaluation.policy.len(),
                analyzed.grouped.groups.len()
            )));
        }
        if self.strict_feasibility && !evaluation.feasible {
            return Err(CompileError::Infeasible {
                model: analyzed.model.clone(),
                sram_required: evaluation.sram.total,
                sram_budget: self.cfg.sram_budget,
            });
        }
        Ok(Optimized {
            model: analyzed.model.clone(),
            grouped: analyzed.grouped.clone(),
            strategy: self.strategy.name(),
            cfg: self.cfg.clone(),
            evaluation,
        })
    }

    /// Reject artifacts computed under a different configuration — mixing
    /// them would yield an internally inconsistent report.
    fn check_cfg(&self, stage: &str, cfg: &AccelConfig) -> Result<(), CompileError> {
        if *cfg != self.cfg {
            // Full Debug forms: configs often differ only in one tweaked
            // field while sharing a name, so names alone can't diagnose.
            return Err(CompileError::stage(format!(
                "{stage} artifact was produced under a different config \
                 (artifact: {cfg:?}; compiler: {:?})",
                self.cfg
            )));
        }
        Ok(())
    }

    /// Stage 3: static 3-buffer allocation + off-chip arena layout.
    pub fn allocate(&self, optimized: &Optimized) -> Result<Allocated, CompileError> {
        self.check_cfg("Optimized", &optimized.cfg)?;
        let gg = &optimized.grouped;
        let policy = &optimized.evaluation.policy;
        let mut alloc = crate::alloc::allocate(gg, policy, &self.cfg);
        // The tile overlay pins region interiors on-chip *before* the
        // off-chip arena is laid out, so fused tensors never get DRAM
        // extents either.
        if let Some(plan) = &optimized.evaluation.tiles {
            crate::tile::apply_overlay(&mut alloc.assigns, gg, plan);
        }
        let dram_layout = crate::alloc::layout(gg, policy, &alloc, &self.cfg);
        Ok(Allocated {
            model: optimized.model.clone(),
            grouped: optimized.grouped.clone(),
            strategy: optimized.strategy,
            cfg: optimized.cfg.clone(),
            evaluation: optimized.evaluation.clone(),
            alloc,
            dram_layout,
        })
    }

    /// Stage 4: lower every group to its 11-word instruction.
    pub fn lower(&self, allocated: &Allocated) -> Result<Lowered, CompileError> {
        self.check_cfg("Allocated", &allocated.cfg)?;
        let gg = &allocated.grouped;
        if allocated.alloc.assigns.len() != gg.groups.len() {
            return Err(CompileError::stage(format!(
                "{} buffer assignments for {} groups",
                allocated.alloc.assigns.len(),
                gg.groups.len()
            )));
        }
        let params = self.params.as_deref();
        let tiles = allocated.evaluation.tiles.as_ref();
        let mut assigns: Vec<MemAssign> = Vec::with_capacity(gg.groups.len());
        for (gi, gr) in gg.groups.iter().enumerate() {
            let region = tiles.and_then(|p| p.region_of(gi));
            assigns.push(MemAssign {
                reuse: allocated.evaluation.policy[gi],
                in_loc: to_memloc(&allocated.alloc.assigns[gi].in_loc, &allocated.dram_layout, gi),
                out_loc: to_memloc(
                    &allocated.alloc.assigns[gi].out_loc,
                    &allocated.dram_layout,
                    gi,
                ),
                aux_loc: allocated.alloc.assigns[gi]
                    .aux_loc
                    .as_ref()
                    .map(|l| to_memloc(l, &allocated.dram_layout, gi)),
                weight_addr: allocated.dram_layout.weights[gi].offset,
                weight_bytes: gr.weight_bytes(&gg.graph, self.cfg.qw as u64) as u32,
                quant_shift: quant_shift_for(gg, gi, params)?,
                tile_rows: region.map(|r| r.tile_rows.min(255) as u8).unwrap_or(0),
                tile_first: region.is_some_and(|r| r.first == gi),
                tile_weight_stream: region.is_some_and(|r| r.streamed_weights[gi - r.first]),
            });
        }
        let stream = lower(gg, &assigns);
        Ok(Lowered {
            model: allocated.model.clone(),
            grouped: allocated.grouped.clone(),
            strategy: allocated.strategy,
            cfg: allocated.cfg.clone(),
            evaluation: allocated.evaluation.clone(),
            alloc: allocated.alloc.clone(),
            dram_layout: allocated.dram_layout.clone(),
            assigns,
            stream,
        })
    }

    /// Stage 5: cycle-accurate timing + power estimate.
    pub fn simulate(&self, lowered: &Lowered) -> Result<Simulated, CompileError> {
        self.check_cfg("Lowered", &lowered.cfg)?;
        let gg = &lowered.grouped;
        let timing = simulate_with_tiles(
            gg,
            &lowered.evaluation.policy,
            &lowered.alloc,
            &self.cfg,
            lowered.evaluation.tiles.as_ref(),
        );
        let power = power_estimate(
            &PowerModel::default(),
            &self.cfg,
            timing.mac_efficiency,
            lowered.evaluation.sram.bram18k,
            lowered.evaluation.dram.total,
            timing.latency_ms,
            timing.gops,
        );
        Ok(Simulated {
            model: lowered.model.clone(),
            grouped: lowered.grouped.clone(),
            strategy: lowered.strategy,
            cfg: lowered.cfg.clone(),
            evaluation: lowered.evaluation.clone(),
            alloc: lowered.alloc.clone(),
            dram_layout: lowered.dram_layout.clone(),
            assigns: lowered.assigns.clone(),
            stream: lowered.stream.clone(),
            timing,
            power,
        })
    }

    /// Stage 6 — packing: collapse a lowered artifact into a deployable
    /// [`crate::program::Program`], the §III-A driver payload
    /// (instructions + memory assignment + target config + the attached
    /// quantized parameters, if any) that the [`crate::engine`] backends
    /// execute and [`crate::program::Program::save`] writes to disk.
    pub fn pack(&self, lowered: &Lowered) -> Result<crate::program::Program, CompileError> {
        self.check_cfg("Lowered", &lowered.cfg)?;
        crate::program::Program::from_parts(
            lowered.model.clone(),
            lowered.strategy.to_string(),
            lowered.cfg.clone(),
            lowered.grouped.clone(),
            lowered.alloc.assigns.clone(),
            lowered.stream.words.clone(),
            self.params.as_deref().cloned(),
        )
    }

    /// All five stages in sequence.
    pub fn compile(&self, graph: &Graph) -> Result<CompileReport, CompileError> {
        let analyzed = self.analyze(graph)?;
        self.compile_analyzed(&analyzed)
    }

    /// Stages 2–5 over a cached analysis (what [`Session`] uses to share
    /// one `Analyzed` across configs).
    pub fn compile_analyzed(&self, analyzed: &Analyzed) -> Result<CompileReport, CompileError> {
        let optimized = self.optimize(analyzed)?;
        let allocated = self.allocate(&optimized)?;
        let lowered = self.lower(&allocated)?;
        Ok(self.simulate(&lowered)?.into_report())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::zoo;

    #[test]
    fn staged_chain_produces_consistent_artifacts() {
        let compiler = Compiler::new(AccelConfig::kcu1500_int8());
        let g = zoo::resnet18(64);
        let analyzed = compiler.analyze(&g).unwrap();
        let optimized = compiler.optimize(&analyzed).unwrap();
        assert_eq!(optimized.strategy, "cutpoint");
        assert_eq!(optimized.evaluation.policy.len(), analyzed.group_count());
        let allocated = compiler.allocate(&optimized).unwrap();
        assert_eq!(allocated.alloc.assigns.len(), analyzed.group_count());
        let lowered = compiler.lower(&allocated).unwrap();
        assert_eq!(lowered.stream.len(), analyzed.group_count());
        assert_eq!(lowered.stream_bytes().len(), lowered.stream.byte_size());
        let simulated = compiler.simulate(&lowered).unwrap();
        assert!(simulated.timing.latency_ms > 0.0);
        let report = simulated.into_report();
        assert_eq!(report.row_groups + report.frame_groups, analyzed.group_count());
    }

    #[test]
    fn artifacts_are_reusable_across_stages() {
        // The same Analyzed feeds two different configs; the same
        // Optimized feeds allocate twice — artifacts are plain values.
        let g = zoo::resnet18(64);
        let a = Compiler::new(AccelConfig::kcu1500_int8());
        let b = Compiler::new(AccelConfig::table2_int16());
        let analyzed = a.analyze(&g).unwrap();
        let ra = a.compile_analyzed(&analyzed).unwrap();
        let rb = b.compile_analyzed(&analyzed).unwrap();
        assert_ne!(ra.evaluation.sram.total, rb.evaluation.sram.total);
        let optimized = a.optimize(&analyzed).unwrap();
        let l1 = a.lower(&a.allocate(&optimized).unwrap()).unwrap();
        let l2 = a.lower(&a.allocate(&optimized).unwrap()).unwrap();
        assert_eq!(l1.stream.words, l2.stream.words);
    }

    #[test]
    fn cross_config_artifacts_are_rejected() {
        // Feeding a stage artifact to a compiler with a different config
        // must fail typed, not produce an inconsistent report.
        let g = zoo::resnet18(64);
        let a = Compiler::new(AccelConfig::kcu1500_int8());
        let b = Compiler::new(AccelConfig::table2_int16());
        let optimized = a.optimize(&a.analyze(&g).unwrap()).unwrap();
        assert!(matches!(b.allocate(&optimized), Err(CompileError::StageMismatch(_))));
        let allocated = a.allocate(&optimized).unwrap();
        assert!(matches!(b.lower(&allocated), Err(CompileError::StageMismatch(_))));
        let lowered = a.lower(&allocated).unwrap();
        assert!(matches!(b.simulate(&lowered), Err(CompileError::StageMismatch(_))));
    }

    #[test]
    fn strict_feasibility_reports_typed_error() {
        let mut cfg = AccelConfig::kcu1500_int8();
        cfg.sram_budget = 1; // nothing fits
        let compiler = Compiler::new(cfg).strict_feasibility(true);
        match compiler.compile(&zoo::resnet18(64)) {
            Err(CompileError::Infeasible { model, sram_budget, .. }) => {
                assert_eq!(model, "ResNet18");
                assert_eq!(sram_budget, 1);
            }
            other => panic!("expected Infeasible, got {:?}", other.map(|r| r.model)),
        }
    }

    #[test]
    fn params_shifts_reach_the_stream() {
        let compiler = Compiler::new(AccelConfig::kcu1500_int8());
        let g = zoo::tinynet();
        let analyzed = compiler.analyze(&g).unwrap();
        let params = Params::random(&analyzed.grouped, 3);
        let with = Compiler::new(AccelConfig::kcu1500_int8()).with_params(params.clone());
        let lowered =
            with.lower(&with.allocate(&with.optimize(&analyzed).unwrap()).unwrap()).unwrap();
        // Params::random sets shift = 7 on every weighted group.
        let shifted = lowered.assigns.iter().filter(|a| a.quant_shift == 7).count();
        assert!(shifted > 0, "no group picked up a parameter shift");
        // and the encoded words carry it
        let any = lowered
            .stream
            .instrs
            .iter()
            .any(|i| i.quant_shift == 7);
        assert!(any);
        // without params every shift is the documented identity 0
        let bare = compiler
            .lower(&compiler.allocate(&compiler.optimize(&analyzed).unwrap()).unwrap())
            .unwrap();
        assert!(bare.assigns.iter().all(|a| a.quant_shift == 0));
    }
}
