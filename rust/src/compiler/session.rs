//! The parallel compile session: memoized stage artifacts + scoped-thread
//! sweeps.
//!
//! A [`Session`] serves many `(model, input, config)` compile jobs:
//!
//! * the **analysis cache** shares one [`Analyzed`] artifact per
//!   `(model, input)` across every configuration (fusion analysis is
//!   config-independent);
//! * the **report cache** memoizes the finished [`CompileReport`] per
//!   `(model, input, config, strategy)`, so repeated jobs — sweeps that
//!   revisit a point, dashboards, A/B strategy comparisons — are O(1);
//! * [`Session::run_jobs`] fans a job list out over `std::thread::scope`
//!   workers, replacing the seed's serial per-model loops.
//!
//! Cached results are shared through `Arc`, so a cache hit is a pointer
//! clone and two hits for the same key return bit-identical artifacts
//! (the property test in `rust/tests/staged_api.rs` pins this down).

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::config::AccelConfig;
use crate::zoo;

use super::error::CompileError;
use super::stages::{Analyzed, CompileReport};
use super::strategy::{CutPointStrategy, ReuseStrategy};
use super::Compiler;

/// One compile job of a sweep.
#[derive(Debug, Clone)]
pub struct SweepJob {
    /// Zoo model name (see [`zoo::by_name`]).
    pub model: String,
    /// Square input resolution the model is built at.
    pub input: usize,
    /// Target configuration to compile for.
    pub cfg: AccelConfig,
}

impl SweepJob {
    /// A zoo model at its paper-default input size.
    ///
    /// Unknown names are a typed [`CompileError::UnknownModel`] (carrying
    /// the valid zoo names) — they used to fall back silently to input
    /// 256 and only fail later, deep inside the sweep.
    pub fn zoo_default(model: &str, cfg: &AccelConfig) -> Result<SweepJob, CompileError> {
        let input = zoo::try_default_input(model)
            .ok_or_else(|| CompileError::unknown_model(model))?;
        Ok(SweepJob { model: model.to_string(), input, cfg: cfg.clone() })
    }
}

/// Cache-effectiveness counters (reads are racy snapshots, which is fine
/// for reporting).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SessionStats {
    /// Finished-report cache hits.
    pub report_hits: usize,
    /// Finished-report cache misses (full stage-2–5 compiles).
    pub report_misses: usize,
    /// Analysis-artifact cache hits.
    pub analysis_hits: usize,
    /// Analysis-artifact cache misses (fusion analyses actually run).
    pub analysis_misses: usize,
}

/// A memoizing, thread-safe compile service over one reuse strategy.
pub struct Session {
    strategy: Arc<dyn ReuseStrategy>,
    analyzed: Mutex<HashMap<(String, usize), Arc<Analyzed>>>,
    /// Each entry keeps its strategy `Arc` alive so the pointer-identity
    /// component of [`ReportKey`] can never be recycled by a later
    /// allocation (ABA) while the entry exists.
    reports: Mutex<HashMap<ReportKey, (Arc<dyn ReuseStrategy>, Arc<CompileReport>)>>,
    report_hits: AtomicUsize,
    report_misses: AtomicUsize,
    analysis_hits: AtomicUsize,
    analysis_misses: AtomicUsize,
}

/// `(model, input, config fingerprint, strategy name, strategy
/// identity)`. The strategy components keep entries from different
/// strategies apart: [`Session::compile_with`] takes a per-call strategy
/// (the design-space explorer sweeps several through one session), so
/// two strategies with the same model/config must never alias each
/// other's cached reports. The name alone cannot guarantee that —
/// parameterized strategies (e.g. two `SmartShuttleStrategy` buffer
/// sizes) share one name — so the `Arc`'s pointer identity rides along:
/// clones of one strategy hit the same entry, distinct instances never
/// collide (at worst a logically-equal re-instantiation recomputes).
type ReportKey = (String, usize, String, &'static str, usize);

/// Thin-pointer identity of a shared strategy instance.
fn strategy_id(strategy: &Arc<dyn ReuseStrategy>) -> usize {
    Arc::as_ptr(strategy) as *const u8 as usize
}

/// Fan `count` independent work items out over `threads` scoped workers
/// (work-stealing index, one result slot per item); results come back in
/// item order. Shared by [`Session::run_jobs`] and the design-space
/// explorer's sweep so the pool machinery lives in one place.
pub(crate) fn fan_out<T: Send>(
    count: usize,
    threads: usize,
    work: impl Fn(usize) -> T + Sync,
) -> Vec<T> {
    assert!(threads > 0, "need at least one worker");
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> = (0..count).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..threads.min(count.max(1)) {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= count {
                    return;
                }
                *slots[i].lock().unwrap() = Some(work(i));
            });
        }
    });
    slots
        .into_iter()
        .map(|slot| slot.into_inner().unwrap().expect("worker filled every slot"))
        .collect()
}

/// `AccelConfig` carries floats, so it fingerprints through its `Debug`
/// form (deterministic: derived, field order is fixed).
fn cfg_key(cfg: &AccelConfig) -> String {
    format!("{cfg:?}")
}

impl Default for Session {
    fn default() -> Self {
        Session::new()
    }
}

impl Session {
    /// A session running the paper's cut-point optimizer.
    pub fn new() -> Session {
        Session::with_strategy(Arc::new(CutPointStrategy))
    }

    /// A session running an explicit strategy (e.g. a baseline).
    pub fn with_strategy(strategy: Arc<dyn ReuseStrategy>) -> Session {
        Session {
            strategy,
            analyzed: Mutex::new(HashMap::new()),
            reports: Mutex::new(HashMap::new()),
            report_hits: AtomicUsize::new(0),
            report_misses: AtomicUsize::new(0),
            analysis_hits: AtomicUsize::new(0),
            analysis_misses: AtomicUsize::new(0),
        }
    }

    /// Name of the session's default strategy (what [`Session::compile`]
    /// and the sweep helpers run; [`Session::compile_with`] overrides it
    /// per call).
    pub fn strategy_name(&self) -> &'static str {
        self.strategy.name()
    }

    /// Snapshot of the cache counters.
    pub fn stats(&self) -> SessionStats {
        SessionStats {
            report_hits: self.report_hits.load(Ordering::Relaxed),
            report_misses: self.report_misses.load(Ordering::Relaxed),
            analysis_hits: self.analysis_hits.load(Ordering::Relaxed),
            analysis_misses: self.analysis_misses.load(Ordering::Relaxed),
        }
    }

    /// The shared analysis artifact for a zoo model (config-independent).
    ///
    /// The cache lock is held across the analysis itself: fusion analysis
    /// is O(nodes) and cheap, and holding it guarantees one analysis per
    /// `(model, input)` even when parallel workers hit the same model
    /// with different configs at once (sweep grids are model-major).
    pub fn analyzed(&self, model: &str, input: usize) -> Result<Arc<Analyzed>, CompileError> {
        let key = (model.to_string(), input);
        let mut cache = self.analyzed.lock().unwrap();
        if let Some(a) = cache.get(&key) {
            self.analysis_hits.fetch_add(1, Ordering::Relaxed);
            return Ok(a.clone());
        }
        self.analysis_misses.fetch_add(1, Ordering::Relaxed);
        // zoo name, imported .onnx model, or frozen .json graph — the
        // same resolution the CLI front-end uses (parameters, if any,
        // are not part of analysis and are dropped here)
        let graph = crate::import::resolve(model, input)?.0;
        // Any config works for stage 1; analysis never reads it.
        let compiler =
            Compiler::with_strategy(AccelConfig::kcu1500_int8(), self.strategy.clone());
        let analyzed = Arc::new(compiler.analyze(&graph)?);
        cache.insert(key, analyzed.clone());
        Ok(analyzed)
    }

    /// Compile one `(model, input, config)` point with the session's
    /// default strategy, memoized.
    pub fn compile(
        &self,
        model: &str,
        input: usize,
        cfg: &AccelConfig,
    ) -> Result<Arc<CompileReport>, CompileError> {
        let strategy = self.strategy.clone();
        self.compile_with(model, input, cfg, &strategy)
    }

    /// Compile one `(model, input, config)` point under an explicit
    /// strategy, memoized per `(model, input, config, strategy name +
    /// instance)` — reuse the same `Arc` clone across calls to hit the
    /// cache.
    ///
    /// This is what lets one session serve mixed-strategy sweeps (the
    /// [`crate::explorer`] evaluates every [`ReuseStrategy`] through a
    /// shared session): the analysis cache is strategy-independent and
    /// stays shared, while finished reports are keyed by the strategy's
    /// [`ReuseStrategy::name`] *and* the `Arc`'s identity, so `cutpoint`
    /// and `fixed-row` never alias and neither do two
    /// differently-parameterized instances sharing a name. Reuse the
    /// same `Arc` clone across calls to get cache hits.
    pub fn compile_with(
        &self,
        model: &str,
        input: usize,
        cfg: &AccelConfig,
        strategy: &Arc<dyn ReuseStrategy>,
    ) -> Result<Arc<CompileReport>, CompileError> {
        let key: ReportKey =
            (model.to_string(), input, cfg_key(cfg), strategy.name(), strategy_id(strategy));
        if let Some((_, r)) = self.reports.lock().unwrap().get(&key) {
            self.report_hits.fetch_add(1, Ordering::Relaxed);
            return Ok(r.clone());
        }
        self.report_misses.fetch_add(1, Ordering::Relaxed);
        let analyzed = self.analyzed(model, input)?;
        let compiler = Compiler::with_strategy(cfg.clone(), strategy.clone());
        let report = Arc::new(compiler.compile_analyzed(&analyzed)?);
        // Two threads may race to the same miss; both compute identical
        // reports and the first insert wins, keeping hits bit-stable.
        let mut cache = self.reports.lock().unwrap();
        Ok(cache.entry(key).or_insert((strategy.clone(), report)).1.clone())
    }

    /// Compile every job across `threads` scoped workers; results come
    /// back in job order, with per-job errors isolated.
    pub fn run_jobs(
        &self,
        jobs: &[SweepJob],
        threads: usize,
    ) -> Vec<Result<Arc<CompileReport>, CompileError>> {
        fan_out(jobs.len(), threads, |i| {
            let job = &jobs[i];
            self.compile(&job.model, job.input, &job.cfg)
        })
    }

    /// The full grid `models × configs`, in row-major job order.
    ///
    /// Unknown model names keep the per-job error isolation of
    /// [`Session::run_jobs`]: their grid slots come back as
    /// [`CompileError::UnknownModel`] entries instead of failing the
    /// whole sweep.
    pub fn sweep_grid(
        &self,
        models: &[&str],
        cfgs: &[AccelConfig],
        threads: usize,
    ) -> Vec<Result<Arc<CompileReport>, CompileError>> {
        let jobs: Vec<SweepJob> = models
            .iter()
            .flat_map(|&m| {
                cfgs.iter().map(move |c| SweepJob {
                    model: m.to_string(),
                    // The unknown-model error surfaces from the compile
                    // itself; any input placeholder works for that.
                    input: zoo::default_input(m),
                    cfg: c.clone(),
                })
            })
            .collect();
        self.run_jobs(&jobs, threads)
    }

    /// Every zoo model at its default input on one config.
    pub fn sweep_zoo(
        &self,
        cfg: &AccelConfig,
        threads: usize,
    ) -> Vec<Result<Arc<CompileReport>, CompileError>> {
        self.sweep_grid(zoo::MODEL_NAMES, std::slice::from_ref(cfg), threads)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cache_hit_returns_same_artifact() {
        let s = Session::new();
        let cfg = AccelConfig::kcu1500_int8();
        let a = s.compile("resnet18", 64, &cfg).unwrap();
        let b = s.compile("resnet18", 64, &cfg).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second compile must be a cache hit");
        let st = s.stats();
        assert_eq!(st.report_hits, 1);
        assert_eq!(st.report_misses, 1);
    }

    #[test]
    fn analysis_is_shared_across_configs() {
        let s = Session::new();
        let mut cfg2 = AccelConfig::kcu1500_int8();
        cfg2.sram_budget /= 2;
        cfg2.name = "half-budget".into();
        s.compile("resnet18", 64, &AccelConfig::kcu1500_int8()).unwrap();
        s.compile("resnet18", 64, &cfg2).unwrap();
        let st = s.stats();
        assert_eq!(st.report_misses, 2, "different configs are different points");
        assert_eq!(st.analysis_misses, 1, "fusion analysis runs once");
        assert_eq!(st.analysis_hits, 1);
    }

    #[test]
    fn parallel_jobs_match_serial_and_keep_order() {
        let cfg = AccelConfig::kcu1500_int8();
        let jobs: Vec<SweepJob> = ["resnet18", "vgg16-conv", "yolov2"]
            .iter()
            .map(|&m| SweepJob { model: m.into(), input: 64, cfg: cfg.clone() })
            .collect();
        let par = Session::new().run_jobs(&jobs, 3);
        let ser = Session::new().run_jobs(&jobs, 1);
        for ((p, s), job) in par.iter().zip(&ser).zip(&jobs) {
            let (p, s) = (p.as_ref().unwrap(), s.as_ref().unwrap());
            assert_eq!(p.model, s.model);
            assert_eq!(p.model, zoo::by_name(&job.model, job.input).unwrap().name);
            assert_eq!(p.timing.total_cycles, s.timing.total_cycles);
            assert_eq!(p.stream.words, s.stream.words);
        }
    }

    #[test]
    fn unknown_model_is_isolated_and_typed() {
        let cfg = AccelConfig::kcu1500_int8();
        let jobs = vec![
            SweepJob { model: "resnet18".into(), input: 64, cfg: cfg.clone() },
            SweepJob { model: "alexnet".into(), input: 64, cfg: cfg.clone() },
        ];
        let out = Session::new().run_jobs(&jobs, 2);
        assert!(out[0].is_ok());
        assert!(matches!(out[1], Err(CompileError::UnknownModel { .. })));
    }

    #[test]
    fn zoo_default_rejects_unknown_models_with_the_valid_names() {
        let cfg = AccelConfig::kcu1500_int8();
        let job = SweepJob::zoo_default("resnet18", &cfg).unwrap();
        assert_eq!(job.input, 224);
        match SweepJob::zoo_default("alexnet", &cfg) {
            Err(CompileError::UnknownModel { name, valid }) => {
                assert_eq!(name, "alexnet");
                assert_eq!(valid, zoo::KNOWN_NAMES);
            }
            other => panic!("expected UnknownModel, got {other:?}"),
        }
    }

    #[test]
    fn parameterized_strategies_sharing_a_name_do_not_alias() {
        // Two SmartShuttle instances differ only in buffer size — same
        // name() — and must still get distinct cache entries.
        let s = Session::new();
        let cfg = AccelConfig::kcu1500_int8();
        let a: Arc<dyn ReuseStrategy> =
            Arc::new(super::super::SmartShuttleStrategy { buffer_bytes: 100_000 });
        let b: Arc<dyn ReuseStrategy> =
            Arc::new(super::super::SmartShuttleStrategy { buffer_bytes: 750_000 });
        let ra = s.compile_with("vgg16-conv", 64, &cfg, &a).unwrap();
        let rb = s.compile_with("vgg16-conv", 64, &cfg, &b).unwrap();
        assert!(!Arc::ptr_eq(&ra, &rb), "same name must not mean same cache slot");
        assert_eq!(s.stats().report_misses, 2);
        // the same instance still hits its own entry
        assert!(Arc::ptr_eq(&ra, &s.compile_with("vgg16-conv", 64, &cfg, &a).unwrap()));
        assert_eq!(s.stats().report_hits, 1);
    }

    #[test]
    fn mixed_strategies_do_not_alias_cache_entries() {
        // One session, two strategies, same (model, input, config): the
        // report cache must keep them apart and each must still hit on
        // its own second compile.
        let s = Session::new();
        let cfg = AccelConfig::kcu1500_int8();
        let cut: Arc<dyn ReuseStrategy> = Arc::new(CutPointStrategy);
        let row: Arc<dyn ReuseStrategy> =
            Arc::new(super::super::FixedReuseStrategy(crate::isa::ReuseMode::Row));
        let a = s.compile_with("resnet18", 64, &cfg, &cut).unwrap();
        let b = s.compile_with("resnet18", 64, &cfg, &row).unwrap();
        assert_eq!(a.strategy, "cutpoint");
        assert_eq!(b.strategy, "fixed-row");
        assert!(!Arc::ptr_eq(&a, &b), "strategies must not share a cache slot");
        let a2 = s.compile_with("resnet18", 64, &cfg, &cut).unwrap();
        let b2 = s.compile_with("resnet18", 64, &cfg, &row).unwrap();
        assert!(Arc::ptr_eq(&a, &a2));
        assert!(Arc::ptr_eq(&b, &b2));
        let st = s.stats();
        assert_eq!((st.report_hits, st.report_misses), (2, 2));
        assert_eq!(st.analysis_misses, 1, "analysis stays strategy-independent");
    }

    #[test]
    fn per_strategy_sessions_compile_independently() {
        // (Each Session runs one strategy, so this exercises strategy
        // isolation across sessions, not key separation within one.)
        let cfg = AccelConfig::kcu1500_int8();
        let cut = Session::new();
        let fixed = Session::with_strategy(Arc::new(
            super::super::FixedReuseStrategy(crate::isa::ReuseMode::Row),
        ));
        let a = cut.compile("resnet18", 64, &cfg).unwrap();
        let b = fixed.compile("resnet18", 64, &cfg).unwrap();
        assert_eq!(a.strategy, "cutpoint");
        assert_eq!(b.strategy, "fixed-row");
        assert!(b.evaluation.policy.iter().all(|m| *m == crate::isa::ReuseMode::Row));
    }
}
