//! The parallel compile session: memoized stage artifacts + scoped-thread
//! sweeps.
//!
//! A [`Session`] serves many `(model, input, config)` compile jobs:
//!
//! * the **analysis cache** shares one [`Analyzed`] artifact per
//!   `(model, input)` across every configuration (fusion analysis is
//!   config-independent);
//! * the **report cache** memoizes the finished [`CompileReport`] per
//!   `(model, input, config, strategy)`, so repeated jobs — sweeps that
//!   revisit a point, dashboards, A/B strategy comparisons — are O(1);
//! * [`Session::run_jobs`] fans a job list out over `std::thread::scope`
//!   workers, replacing the seed's serial per-model loops.
//!
//! Cached results are shared through `Arc`, so a cache hit is a pointer
//! clone and two hits for the same key return bit-identical artifacts
//! (the property test in `rust/tests/staged_api.rs` pins this down).

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::config::AccelConfig;
use crate::zoo;

use super::error::CompileError;
use super::stages::{Analyzed, CompileReport};
use super::strategy::{CutPointStrategy, ReuseStrategy};
use super::Compiler;

/// One compile job of a sweep.
#[derive(Debug, Clone)]
pub struct SweepJob {
    pub model: String,
    pub input: usize,
    pub cfg: AccelConfig,
}

impl SweepJob {
    /// A zoo model at its paper-default input size.
    pub fn zoo_default(model: &str, cfg: &AccelConfig) -> SweepJob {
        SweepJob { model: model.to_string(), input: zoo::default_input(model), cfg: cfg.clone() }
    }
}

/// Cache-effectiveness counters (reads are racy snapshots, which is fine
/// for reporting).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SessionStats {
    pub report_hits: usize,
    pub report_misses: usize,
    pub analysis_hits: usize,
    pub analysis_misses: usize,
}

/// A memoizing, thread-safe compile service over one reuse strategy.
pub struct Session {
    strategy: Arc<dyn ReuseStrategy>,
    analyzed: Mutex<HashMap<(String, usize), Arc<Analyzed>>>,
    reports: Mutex<HashMap<ReportKey, Arc<CompileReport>>>,
    report_hits: AtomicUsize,
    report_misses: AtomicUsize,
    analysis_hits: AtomicUsize,
    analysis_misses: AtomicUsize,
}

/// `(model, input, config fingerprint, strategy name)`. The strategy
/// component is constant within one `Session` (a session runs exactly one
/// strategy); it is kept in the key so cache entries stay self-describing
/// and the invariant survives if sessions ever take per-call strategies.
type ReportKey = (String, usize, String, &'static str);

/// `AccelConfig` carries floats, so it fingerprints through its `Debug`
/// form (deterministic: derived, field order is fixed).
fn cfg_key(cfg: &AccelConfig) -> String {
    format!("{cfg:?}")
}

impl Default for Session {
    fn default() -> Self {
        Session::new()
    }
}

impl Session {
    /// A session running the paper's cut-point optimizer.
    pub fn new() -> Session {
        Session::with_strategy(Arc::new(CutPointStrategy))
    }

    /// A session running an explicit strategy (e.g. a baseline).
    pub fn with_strategy(strategy: Arc<dyn ReuseStrategy>) -> Session {
        Session {
            strategy,
            analyzed: Mutex::new(HashMap::new()),
            reports: Mutex::new(HashMap::new()),
            report_hits: AtomicUsize::new(0),
            report_misses: AtomicUsize::new(0),
            analysis_hits: AtomicUsize::new(0),
            analysis_misses: AtomicUsize::new(0),
        }
    }

    pub fn strategy_name(&self) -> &'static str {
        self.strategy.name()
    }

    pub fn stats(&self) -> SessionStats {
        SessionStats {
            report_hits: self.report_hits.load(Ordering::Relaxed),
            report_misses: self.report_misses.load(Ordering::Relaxed),
            analysis_hits: self.analysis_hits.load(Ordering::Relaxed),
            analysis_misses: self.analysis_misses.load(Ordering::Relaxed),
        }
    }

    /// The shared analysis artifact for a zoo model (config-independent).
    ///
    /// The cache lock is held across the analysis itself: fusion analysis
    /// is O(nodes) and cheap, and holding it guarantees one analysis per
    /// `(model, input)` even when parallel workers hit the same model
    /// with different configs at once (sweep grids are model-major).
    pub fn analyzed(&self, model: &str, input: usize) -> Result<Arc<Analyzed>, CompileError> {
        let key = (model.to_string(), input);
        let mut cache = self.analyzed.lock().unwrap();
        if let Some(a) = cache.get(&key) {
            self.analysis_hits.fetch_add(1, Ordering::Relaxed);
            return Ok(a.clone());
        }
        self.analysis_misses.fetch_add(1, Ordering::Relaxed);
        let graph = zoo::by_name(model, input)
            .ok_or_else(|| CompileError::UnknownModel(model.to_string()))?;
        // Any config works for stage 1; analysis never reads it.
        let compiler =
            Compiler::with_strategy(AccelConfig::kcu1500_int8(), self.strategy.clone());
        let analyzed = Arc::new(compiler.analyze(&graph)?);
        cache.insert(key, analyzed.clone());
        Ok(analyzed)
    }

    /// Compile one `(model, input, config)` point, memoized.
    pub fn compile(
        &self,
        model: &str,
        input: usize,
        cfg: &AccelConfig,
    ) -> Result<Arc<CompileReport>, CompileError> {
        let key: ReportKey =
            (model.to_string(), input, cfg_key(cfg), self.strategy.name());
        if let Some(r) = self.reports.lock().unwrap().get(&key) {
            self.report_hits.fetch_add(1, Ordering::Relaxed);
            return Ok(r.clone());
        }
        self.report_misses.fetch_add(1, Ordering::Relaxed);
        let analyzed = self.analyzed(model, input)?;
        let compiler = Compiler::with_strategy(cfg.clone(), self.strategy.clone());
        let report = Arc::new(compiler.compile_analyzed(&analyzed)?);
        // Two threads may race to the same miss; both compute identical
        // reports and the first insert wins, keeping hits bit-stable.
        let mut cache = self.reports.lock().unwrap();
        Ok(cache.entry(key).or_insert(report).clone())
    }

    /// Compile every job across `threads` scoped workers; results come
    /// back in job order, with per-job errors isolated.
    pub fn run_jobs(
        &self,
        jobs: &[SweepJob],
        threads: usize,
    ) -> Vec<Result<Arc<CompileReport>, CompileError>> {
        assert!(threads > 0, "need at least one worker");
        let next = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<Result<Arc<CompileReport>, CompileError>>>> =
            jobs.iter().map(|_| Mutex::new(None)).collect();
        std::thread::scope(|s| {
            for _ in 0..threads.min(jobs.len().max(1)) {
                s.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= jobs.len() {
                        return;
                    }
                    let job = &jobs[i];
                    let result = self.compile(&job.model, job.input, &job.cfg);
                    *slots[i].lock().unwrap() = Some(result);
                });
            }
        });
        slots
            .into_iter()
            .map(|slot| slot.into_inner().unwrap().expect("worker filled every slot"))
            .collect()
    }

    /// The full grid `models × configs`, in row-major job order.
    pub fn sweep_grid(
        &self,
        models: &[&str],
        cfgs: &[AccelConfig],
        threads: usize,
    ) -> Vec<Result<Arc<CompileReport>, CompileError>> {
        let jobs: Vec<SweepJob> = models
            .iter()
            .flat_map(|&m| cfgs.iter().map(move |c| SweepJob::zoo_default(m, c)))
            .collect();
        self.run_jobs(&jobs, threads)
    }

    /// Every zoo model at its default input on one config.
    pub fn sweep_zoo(
        &self,
        cfg: &AccelConfig,
        threads: usize,
    ) -> Vec<Result<Arc<CompileReport>, CompileError>> {
        self.sweep_grid(zoo::MODEL_NAMES, std::slice::from_ref(cfg), threads)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cache_hit_returns_same_artifact() {
        let s = Session::new();
        let cfg = AccelConfig::kcu1500_int8();
        let a = s.compile("resnet18", 64, &cfg).unwrap();
        let b = s.compile("resnet18", 64, &cfg).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second compile must be a cache hit");
        let st = s.stats();
        assert_eq!(st.report_hits, 1);
        assert_eq!(st.report_misses, 1);
    }

    #[test]
    fn analysis_is_shared_across_configs() {
        let s = Session::new();
        let mut cfg2 = AccelConfig::kcu1500_int8();
        cfg2.sram_budget /= 2;
        cfg2.name = "half-budget".into();
        s.compile("resnet18", 64, &AccelConfig::kcu1500_int8()).unwrap();
        s.compile("resnet18", 64, &cfg2).unwrap();
        let st = s.stats();
        assert_eq!(st.report_misses, 2, "different configs are different points");
        assert_eq!(st.analysis_misses, 1, "fusion analysis runs once");
        assert_eq!(st.analysis_hits, 1);
    }

    #[test]
    fn parallel_jobs_match_serial_and_keep_order() {
        let cfg = AccelConfig::kcu1500_int8();
        let jobs: Vec<SweepJob> = ["resnet18", "vgg16-conv", "yolov2"]
            .iter()
            .map(|&m| SweepJob { model: m.into(), input: 64, cfg: cfg.clone() })
            .collect();
        let par = Session::new().run_jobs(&jobs, 3);
        let ser = Session::new().run_jobs(&jobs, 1);
        for ((p, s), job) in par.iter().zip(&ser).zip(&jobs) {
            let (p, s) = (p.as_ref().unwrap(), s.as_ref().unwrap());
            assert_eq!(p.model, s.model);
            assert_eq!(p.model, zoo::by_name(&job.model, job.input).unwrap().name);
            assert_eq!(p.timing.total_cycles, s.timing.total_cycles);
            assert_eq!(p.stream.words, s.stream.words);
        }
    }

    #[test]
    fn unknown_model_is_isolated_and_typed() {
        let cfg = AccelConfig::kcu1500_int8();
        let jobs = vec![
            SweepJob { model: "resnet18".into(), input: 64, cfg: cfg.clone() },
            SweepJob { model: "alexnet".into(), input: 64, cfg: cfg.clone() },
        ];
        let out = Session::new().run_jobs(&jobs, 2);
        assert!(out[0].is_ok());
        assert!(matches!(out[1], Err(CompileError::UnknownModel(_))));
    }

    #[test]
    fn per_strategy_sessions_compile_independently() {
        // (Each Session runs one strategy, so this exercises strategy
        // isolation across sessions, not key separation within one.)
        let cfg = AccelConfig::kcu1500_int8();
        let cut = Session::new();
        let fixed = Session::with_strategy(Arc::new(
            super::super::FixedReuseStrategy(crate::isa::ReuseMode::Row),
        ));
        let a = cut.compile("resnet18", 64, &cfg).unwrap();
        let b = fixed.compile("resnet18", 64, &cfg).unwrap();
        assert_eq!(a.strategy, "cutpoint");
        assert_eq!(b.strategy, "fixed-row");
        assert!(b.evaluation.policy.iter().all(|m| *m == crate::isa::ReuseMode::Row));
    }
}
