//! Owned stage artifacts of the staged compile pipeline.
//!
//! Each struct is the output of exactly one Fig.-4 stage. They own their
//! data (the grouped graph is shared through an `Arc`, so chaining stages
//! never copies the model), can be inspected or serialized on their own,
//! and are what [`super::Session`] memoizes.
//!
//! Chaining clones the smaller per-stage products (policy vector, buffer
//! assignments, packed stream — all O(groups)) rather than `Arc`-wrapping
//! every field: those clones are noise next to the cut-point search,
//! which simulates the whole network per candidate. Revisit if profiles
//! ever say otherwise.

use std::sync::Arc;

use crate::alloc::{AllocResult, Loc, OffchipLayout};
use crate::analyzer::GroupedGraph;
use crate::funcsim::Params;
use crate::isa::{InstructionStream, MemAssign, MemLoc, ReuseMode};
use crate::optimizer::Evaluation;
use crate::power::PowerEstimate;
use crate::serialize::Json;
use crate::sim::NetworkTiming;

/// Stage 1 — fusion analysis (config-independent): the frozen graph
/// reorganized into accelerator groups.
#[derive(Debug, Clone)]
pub struct Analyzed {
    /// Model name (the graph's name).
    pub model: String,
    /// The fused accelerator groups, shared across downstream stages.
    pub grouped: Arc<GroupedGraph>,
}

impl Analyzed {
    /// Nodes in the source graph.
    pub fn node_count(&self) -> usize {
        self.grouped.graph.nodes.len()
    }

    /// Fused accelerator groups.
    pub fn group_count(&self) -> usize {
        self.grouped.groups.len()
    }

    /// Compact inspection record.
    pub fn summary_json(&self) -> Json {
        Json::obj(vec![
            ("stage", Json::str("analyzed")),
            ("model", Json::str(&self.model)),
            ("nodes", Json::num(self.node_count() as f64)),
            ("groups", Json::num(self.group_count() as f64)),
        ])
    }
}

/// Stage 2 — reuse-policy selection: the chosen per-group policy with its
/// SRAM / DRAM / latency evaluation, tagged with the strategy that
/// produced it.
#[derive(Debug, Clone)]
pub struct Optimized {
    /// Model name.
    pub model: String,
    /// The fused accelerator groups.
    pub grouped: Arc<GroupedGraph>,
    /// [`super::ReuseStrategy::name`] of the deciding strategy.
    pub strategy: &'static str,
    /// The config this evaluation was computed under; downstream stages
    /// refuse artifacts from a different config (`StageMismatch`).
    pub cfg: crate::config::AccelConfig,
    /// The chosen policy with its SRAM / DRAM / latency costing.
    pub evaluation: Evaluation,
}

impl Optimized {
    /// Groups assigned row reuse.
    pub fn row_groups(&self) -> usize {
        self.evaluation.policy.iter().filter(|m| **m == ReuseMode::Row).count()
    }

    /// Groups assigned frame reuse.
    pub fn frame_groups(&self) -> usize {
        self.evaluation.policy.len() - self.row_groups()
    }

    /// Compact inspection record.
    pub fn summary_json(&self) -> Json {
        Json::obj(vec![
            ("stage", Json::str("optimized")),
            ("model", Json::str(&self.model)),
            ("strategy", Json::str(self.strategy)),
            (
                "cuts",
                Json::Arr(self.evaluation.cuts.cuts.iter().map(|&c| Json::num(c as f64)).collect()),
            ),
            ("row_groups", Json::num(self.row_groups() as f64)),
            ("frame_groups", Json::num(self.frame_groups() as f64)),
            ("sram_bytes", Json::num(self.evaluation.sram.total as f64)),
            ("dram_bytes", Json::num(self.evaluation.dram.total as f64)),
            ("latency_ms", Json::num(self.evaluation.latency_ms)),
            ("feasible", Json::Bool(self.evaluation.feasible)),
        ])
    }
}

/// Stage 3 — static memory allocation: on-chip buffer assignments
/// (Algorithm 1) plus the off-chip arena layout.
#[derive(Debug, Clone)]
pub struct Allocated {
    /// Model name.
    pub model: String,
    /// The fused accelerator groups.
    pub grouped: Arc<GroupedGraph>,
    /// Name of the deciding strategy.
    pub strategy: &'static str,
    /// The config the chain was computed under.
    pub cfg: crate::config::AccelConfig,
    /// The chosen policy with its costing.
    pub evaluation: Evaluation,
    /// On-chip buffer placements (Algorithm 1).
    pub alloc: AllocResult,
    /// Off-chip arena layout.
    pub dram_layout: OffchipLayout,
}

impl Allocated {
    /// Compact inspection record.
    pub fn summary_json(&self) -> Json {
        Json::obj(vec![
            ("stage", Json::str("allocated")),
            ("model", Json::str(&self.model)),
            ("spill_events", Json::num(self.alloc.spill_events as f64)),
            ("dram_footprint", Json::num(self.dram_layout.footprint() as f64)),
        ])
    }
}

/// Stage 4 — ISA lowering: the per-group memory assignments and the
/// packed 11-word instruction stream.
#[derive(Debug, Clone)]
pub struct Lowered {
    /// Model name.
    pub model: String,
    /// The fused accelerator groups.
    pub grouped: Arc<GroupedGraph>,
    /// Name of the deciding strategy.
    pub strategy: &'static str,
    /// The config the chain was computed under.
    pub cfg: crate::config::AccelConfig,
    /// The chosen policy with its costing.
    pub evaluation: Evaluation,
    /// On-chip buffer placements.
    pub alloc: AllocResult,
    /// Off-chip arena layout.
    pub dram_layout: OffchipLayout,
    /// Per-group ISA memory assignments.
    pub assigns: Vec<MemAssign>,
    /// The packed 11-word instruction stream.
    pub stream: InstructionStream,
}

impl Lowered {
    /// The packed stream as little-endian bytes — exactly what the
    /// inference driver would DMA to the accelerator.
    pub fn stream_bytes(&self) -> Vec<u8> {
        self.stream.words.iter().flat_map(|w| w.to_le_bytes()).collect()
    }

    /// Compact inspection record.
    pub fn summary_json(&self) -> Json {
        Json::obj(vec![
            ("stage", Json::str("lowered")),
            ("model", Json::str(&self.model)),
            ("instructions", Json::num(self.stream.len() as f64)),
            ("stream_bytes", Json::num(self.stream.byte_size() as f64)),
        ])
    }
}

/// Stage 5 — simulation: cycle-accurate timing and the power estimate.
#[derive(Debug, Clone)]
pub struct Simulated {
    /// Model name.
    pub model: String,
    /// The fused accelerator groups.
    pub grouped: Arc<GroupedGraph>,
    /// Name of the deciding strategy.
    pub strategy: &'static str,
    /// The config the chain was computed under.
    pub cfg: crate::config::AccelConfig,
    /// The chosen policy with its costing.
    pub evaluation: Evaluation,
    /// On-chip buffer placements.
    pub alloc: AllocResult,
    /// Off-chip arena layout.
    pub dram_layout: OffchipLayout,
    /// Per-group ISA memory assignments.
    pub assigns: Vec<MemAssign>,
    /// The packed 11-word instruction stream.
    pub stream: InstructionStream,
    /// Cycle-accurate timing result.
    pub timing: NetworkTiming,
    /// Power estimate.
    pub power: PowerEstimate,
}

impl Simulated {
    /// Collapse the chain into the classic flat report.
    pub fn into_report(self) -> CompileReport {
        let row_groups =
            self.evaluation.policy.iter().filter(|m| **m == ReuseMode::Row).count();
        let frame_groups = self.evaluation.policy.len() - row_groups;
        CompileReport {
            model: self.model,
            strategy: self.strategy,
            grouped: self.grouped,
            evaluation: self.evaluation,
            timing: self.timing,
            power: self.power,
            stream: self.stream,
            row_groups,
            frame_groups,
        }
    }
}

/// Everything the pipeline produces for one network (the seed API's
/// report shape, now produced by [`Simulated::into_report`]; the grouped
/// graph is shared, so cloning a report is cheap).
#[derive(Debug, Clone)]
pub struct CompileReport {
    /// Model name.
    pub model: String,
    /// Which [`super::ReuseStrategy`] chose the policy.
    pub strategy: &'static str,
    /// The fused accelerator groups.
    pub grouped: Arc<GroupedGraph>,
    /// The chosen policy with its costing.
    pub evaluation: Evaluation,
    /// Cycle-accurate timing result.
    pub timing: NetworkTiming,
    /// Power estimate.
    pub power: PowerEstimate,
    /// The packed 11-word instruction stream.
    pub stream: InstructionStream,
    /// Groups assigned row reuse.
    pub row_groups: usize,
    /// Groups assigned frame reuse.
    pub frame_groups: usize,
}

impl CompileReport {
    /// End-to-end latency, ms.
    pub fn latency_ms(&self) -> f64 {
        self.timing.latency_ms
    }

    /// Frames per second at batch 1.
    pub fn fps(&self) -> f64 {
        1000.0 / self.timing.latency_ms
    }

    /// Average throughput, GOPS.
    pub fn gops(&self) -> f64 {
        self.timing.gops
    }

    /// DSP / MAC efficiency as a percentage of peak.
    pub fn mac_efficiency_pct(&self) -> f64 {
        100.0 * self.timing.mac_efficiency
    }

    /// Off-chip feature-map traffic, MB (eq. 8).
    pub fn offchip_fm_mb(&self) -> f64 {
        self.evaluation.dram.fm_bytes as f64 / 1e6
    }

    /// Total off-chip traffic, MB (eq. 9).
    pub fn offchip_total_mb(&self) -> f64 {
        self.evaluation.dram.total as f64 / 1e6
    }

    /// The everything-once baseline traffic, MB (Tables V/VII `[*]`).
    pub fn baseline_once_mb(&self) -> f64 {
        self.evaluation.dram.baseline_once as f64 / 1e6
    }

    /// Off-chip access reduction vs the baseline, %.
    pub fn reduction_pct(&self) -> f64 {
        self.evaluation.dram.reduction_pct()
    }

    /// Total SRAM requirement, MB (eq. 6).
    pub fn sram_mb(&self) -> f64 {
        self.evaluation.sram.total as f64 / 1e6
    }

    /// BRAM18K blocks (eq. 7).
    pub fn bram18k(&self) -> usize {
        self.evaluation.sram.bram18k
    }
}

/// Map an allocator placement to the ISA's memory-location encoding.
pub(super) fn to_memloc(l: &Loc, lay: &OffchipLayout, gi: usize) -> MemLoc {
    match l {
        Loc::Buf(b) => MemLoc::Buf(*b),
        Loc::Aux => MemLoc::Buf(0),
        Loc::Dram => MemLoc::Dram(lay.fmaps[gi].offset),
    }
}

/// Per-group dynamic-fixed-point output shift for the instruction word.
///
/// When quantized parameters are attached (`Compiler::with_params`), the
/// shift comes from the export-time quantization of the group's main node
/// (`python/compile/quantize.py` derives it from the weight/activation
/// exponents); a shift outside the instruction field's `i8` range is a
/// typed error, not a silent clamp. Without parameters the shift is
/// **0 — the identity**: the dynamic-fixed-point shift is a property of
/// the exported integer parameters, not of the architecture, so an
/// unparameterized compile has nothing principled to encode, and the
/// functional simulator reads the real shifts from the parameter file at
/// execution time either way.
pub(crate) fn quant_shift_for(
    gg: &GroupedGraph,
    gi: usize,
    params: Option<&Params>,
) -> Result<i8, super::CompileError> {
    let name = &gg.graph.node(gg.groups[gi].main).name;
    match params.and_then(|p| p.get(name)) {
        None => Ok(0),
        Some(gp) => i8::try_from(gp.shift).map_err(|_| {
            super::CompileError::params(format!(
                "{name}: quantization shift {} does not fit the instruction's i8 field",
                gp.shift
            ))
        }),
    }
}
