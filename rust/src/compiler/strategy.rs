//! Pluggable reuse-policy selection.
//!
//! The paper's cut-point optimizer (§IV-B) and every comparison baseline
//! (fixed row/frame ablations, ShortcutMining [8], SmartShuttle [12])
//! answer the same question — *which reuse scheme does each group run
//! under, and what does that cost in SRAM / DRAM / latency?* — so they
//! all implement one trait and the Table II/IV/VI comparisons run through
//! a single compile path instead of per-baseline ad-hoc drivers.

use crate::alloc::allocate;
use crate::analyzer::GroupedGraph;
use crate::baselines::shortcut_mining::{
    shortcut_mining_fm_traffic, shortcut_mining_weight_traffic,
};
use crate::baselines::smartshuttle::{smartshuttle_dram, smartshuttle_weight_traffic};
use crate::config::AccelConfig;
use crate::isa::ReuseMode;
use crate::optimizer::{dram_access, sram_size, sram_size_tiled, CutPolicy, Evaluation, Optimizer};
use crate::sim::{simulate, simulate_with_tiles};

use super::error::CompileError;

/// A reuse-policy selector: maps a grouped graph + target hardware to a
/// fully-costed [`Evaluation`] (per-group policy, SRAM/BRAM, DRAM traffic
/// and simulated latency).
///
/// `Send + Sync` so a [`super::Session`] can share one strategy across
/// its worker threads.
pub trait ReuseStrategy: Send + Sync {
    /// Stable identifier used in reports and as part of session cache
    /// keys.
    fn name(&self) -> &'static str;

    /// Choose the policy and cost it.
    fn decide(&self, gg: &GroupedGraph, cfg: &AccelConfig) -> Result<Evaluation, CompileError>;
}

/// Cost a fixed per-group policy with the crate's own models (Algorithm 1
/// buffers, eq. 8–9 DRAM, cycle-accurate latency) — shared by the
/// uniform-policy strategies.
///
/// Stages 3/5 later re-run `allocate`/`simulate` on the winning policy;
/// that recomputation is deterministic and mirrors the default cut-point
/// strategy (whose search simulates thousands of candidates before the
/// stages cost the winner once more).
pub fn evaluate_policy(gg: &GroupedGraph, cfg: &AccelConfig, policy: Vec<ReuseMode>) -> Evaluation {
    let alloc = allocate(gg, &policy, cfg);
    let sram = sram_size(gg, &policy, &alloc, cfg);
    let dram = dram_access(gg, &policy, &alloc, cfg);
    let latency_ms = simulate(gg, &policy, &alloc, cfg).latency_ms;
    let feasible = sram.total <= cfg.sram_budget && sram.bram18k <= cfg.bram18k_total;
    Evaluation {
        cuts: CutPolicy { cuts: Vec::new() },
        policy,
        sram,
        dram,
        latency_ms,
        feasible,
        tiles: None,
    }
}

/// The paper's reuse-aware shortcut optimizer (default strategy):
/// exhaustive / coordinate-descent cut-point search for the
/// latency-optimal feasible policy.
#[derive(Debug, Clone, Copy, Default)]
pub struct CutPointStrategy;

impl ReuseStrategy for CutPointStrategy {
    fn name(&self) -> &'static str {
        "cutpoint"
    }

    fn decide(&self, gg: &GroupedGraph, cfg: &AccelConfig) -> Result<Evaluation, CompileError> {
        Ok(Optimizer::new(gg, cfg).optimize())
    }
}

/// Table III's minimum-buffer search: the smallest SRAM total over the
/// whole cut space that still meets the eq-(10) DRAM constraints.
#[derive(Debug, Clone, Copy, Default)]
pub struct MinBufferStrategy;

impl ReuseStrategy for MinBufferStrategy {
    fn name(&self) -> &'static str {
        "min-buffer"
    }

    fn decide(&self, gg: &GroupedGraph, cfg: &AccelConfig) -> Result<Evaluation, CompileError> {
        Ok(Optimizer::new(gg, cfg).min_buffer())
    }
}

/// Fig 16's single-scheme ablations: the proposed hardware running a
/// uniform all-row or all-frame policy, with no block-wise switching.
#[derive(Debug, Clone, Copy)]
pub struct FixedReuseStrategy(pub ReuseMode);

impl ReuseStrategy for FixedReuseStrategy {
    fn name(&self) -> &'static str {
        match self.0 {
            ReuseMode::Row => "fixed-row",
            ReuseMode::Frame => "fixed-frame",
        }
    }

    fn decide(&self, gg: &GroupedGraph, cfg: &AccelConfig) -> Result<Evaluation, CompileError> {
        Ok(evaluate_policy(gg, cfg, vec![self.0; gg.groups.len()]))
    }
}

/// ShortcutMining (HPCA'19 [8], Table II): fixed streaming dataflow with
/// on-chip shortcut mining. The per-group policy is all-row (every
/// layer's fmaps cross DRAM); the DRAM breakdown is replaced by [8]'s
/// published cost model (shortcut operands free, weights fetched twice).
#[derive(Debug, Clone, Copy, Default)]
pub struct ShortcutMiningStrategy;

impl ReuseStrategy for ShortcutMiningStrategy {
    fn name(&self) -> &'static str {
        "shortcut-mining"
    }

    fn decide(&self, gg: &GroupedGraph, cfg: &AccelConfig) -> Result<Evaluation, CompileError> {
        let mut e = evaluate_policy(gg, cfg, vec![ReuseMode::Row; gg.groups.len()]);
        let fm = shortcut_mining_fm_traffic(gg, cfg);
        let weights = shortcut_mining_weight_traffic(gg, cfg);
        e.dram.fm_bytes = fm;
        e.dram.weight_bytes = weights;
        e.dram.spill_bytes = 0;
        e.dram.total = fm + weights;
        // Reconcile the class attribution with [8]'s model: shortcut
        // operands are mined on chip (class zeroed), the remaining fm
        // ratio from the structural walk is rescaled onto [8]'s fm total.
        e.dram.classes.shortcut = 0;
        e.dram.classes = e.dram.classes.rescale_fm(fm);
        e.dram.classes.weights = weights;
        Ok(e)
    }
}

/// SmartShuttle (DATE'18 [12], Table IV): per-layer psum-oriented vs
/// weight-oriented switching under a global buffer capacity. The policy
/// vector is all-row (its tiles stream through DRAM); the DRAM total
/// comes from [12]'s published cost model at the configured buffer size.
#[derive(Debug, Clone, Copy)]
pub struct SmartShuttleStrategy {
    /// On-chip buffer capacity in bytes ([12] saturates past 512 KB).
    pub buffer_bytes: usize,
}

impl Default for SmartShuttleStrategy {
    fn default() -> Self {
        // Table IV's operating point: 0.75 MB.
        SmartShuttleStrategy { buffer_bytes: 750_000 }
    }
}

impl ReuseStrategy for SmartShuttleStrategy {
    fn name(&self) -> &'static str {
        "smartshuttle"
    }

    fn decide(&self, gg: &GroupedGraph, cfg: &AccelConfig) -> Result<Evaluation, CompileError> {
        let mut e = evaluate_policy(gg, cfg, vec![ReuseMode::Row; gg.groups.len()]);
        let r = smartshuttle_dram(gg, cfg, self.buffer_bytes);
        // Split the model's own total with the weight charge it actually
        // applies (standard convs only), so fm + weights == total exactly.
        let weights = smartshuttle_weight_traffic(gg, cfg);
        e.dram.fm_bytes = r.dram_bytes - weights;
        e.dram.weight_bytes = weights;
        e.dram.spill_bytes = 0;
        e.dram.total = r.dram_bytes;
        // Reconcile the classes with [12]'s totals: keep the structural
        // class ratios, rescale their sum onto the published fm bytes.
        e.dram.classes = e.dram.classes.rescale_fm(e.dram.fm_bytes);
        e.dram.classes.weights = weights;
        Ok(e)
    }
}

/// Depth-first fused-tile streaming ([`crate::tile`]): partition fused
/// group chains into halo-padded spatial tiles, keep every interior
/// tensor (shortcut included) on chip across the chain, and spill only
/// region boundaries to DRAM. Cuts feature-map traffic precisely where
/// whole-fmap cut-point reuse spills — large inputs under small SRAM
/// budgets.
#[derive(Debug, Clone, Copy, Default)]
pub struct TileStreamingStrategy {
    /// Fixed tile height in output rows. `None` sweeps
    /// [`crate::tile::TILE_SIZES`] and keeps the best candidate.
    pub tile_rows: Option<usize>,
}

/// `Optimizer`'s candidate ordering, restated for the tile sweep:
/// feasibility first, then (latency, DRAM, SRAM) lexicographically.
fn tile_better(a: &Evaluation, b: &Evaluation) -> bool {
    match (a.feasible, b.feasible) {
        (true, false) => true,
        (false, true) => false,
        _ => {
            (a.latency_ms, a.dram.total, a.sram.total)
                < (b.latency_ms, b.dram.total, b.sram.total)
        }
    }
}

/// Cost one tile plan: all-row base policy, the tile overlay keeping
/// region interiors on chip, eq. 1–7 with the tile working set, eq. 8–9
/// plus halo re-reads and per-tile weight restreams.
fn evaluate_tiled(
    gg: &GroupedGraph,
    cfg: &AccelConfig,
    plan: crate::tile::TilePlan,
) -> Evaluation {
    let policy = vec![ReuseMode::Row; gg.groups.len()];
    let mut alloc = allocate(gg, &policy, cfg);
    crate::tile::apply_overlay(&mut alloc.assigns, gg, &plan);
    let sram = sram_size_tiled(gg, &policy, &alloc, cfg, &plan);
    let mut dram = dram_access(gg, &policy, &alloc, cfg);
    let over = crate::tile::overheads(gg, cfg, &plan);
    dram.fm_bytes += over.halo_fm_extra;
    dram.weight_bytes += over.weight_extra;
    dram.total += over.halo_fm_extra + over.weight_extra;
    // tile overheads by class: halo overreads are input traffic, weight
    // restreams are parameter traffic
    dram.classes.ifm += over.halo_fm_extra;
    dram.classes.weights += over.weight_extra;
    let latency_ms = simulate_with_tiles(gg, &policy, &alloc, cfg, Some(&plan)).latency_ms;
    let feasible = sram.total <= cfg.sram_budget && sram.bram18k <= cfg.bram18k_total;
    Evaluation {
        cuts: CutPolicy { cuts: Vec::new() },
        policy,
        sram,
        dram,
        latency_ms,
        feasible,
        tiles: Some(plan),
    }
}

impl ReuseStrategy for TileStreamingStrategy {
    /// `"tile"` for the auto sweep; canonical fixed heights get their own
    /// name (`"tile-8"`, …) so sweep reports and Pareto fronts can tell
    /// the axis points apart. Non-canonical heights share `"tile-fixed"`.
    fn name(&self) -> &'static str {
        match self.tile_rows {
            None => "tile",
            Some(4) => "tile-4",
            Some(8) => "tile-8",
            Some(16) => "tile-16",
            Some(32) => "tile-32",
            Some(64) => "tile-64",
            Some(_) => "tile-fixed",
        }
    }

    fn decide(&self, gg: &GroupedGraph, cfg: &AccelConfig) -> Result<Evaluation, CompileError> {
        let candidates: &[usize] = match self.tile_rows {
            Some(ref t) => std::slice::from_ref(t),
            None => crate::tile::TILE_SIZES,
        };
        let mut best: Option<Evaluation> = None;
        for &t in candidates {
            let plan = crate::tile::plan(gg, cfg, t);
            if plan.is_empty() {
                continue;
            }
            let e = evaluate_tiled(gg, cfg, plan);
            if best.as_ref().is_none_or(|b| tile_better(&e, b)) {
                best = Some(e);
            }
        }
        // Nothing tileable (tiny frames, concat-heavy graphs): degrade to
        // the plain all-row streaming policy the overlay builds on.
        Ok(best.unwrap_or_else(|| evaluate_policy(gg, cfg, vec![ReuseMode::Row; gg.groups.len()])))
    }
}

/// Resolve a strategy by its CLI / config name. Besides the registry
/// names, `tile-<rows>` resolves to a fixed-height
/// [`TileStreamingStrategy`] (e.g. `tile-8`).
pub fn by_name(name: &str) -> Option<Box<dyn ReuseStrategy>> {
    if let Some(t) = name.strip_prefix("tile-").and_then(|s| s.parse::<usize>().ok()) {
        if t > 0 {
            return Some(Box::new(TileStreamingStrategy { tile_rows: Some(t) }));
        }
    }
    Some(match name {
        "cutpoint" => Box::new(CutPointStrategy),
        "min-buffer" => Box::new(MinBufferStrategy),
        "fixed-row" => Box::new(FixedReuseStrategy(ReuseMode::Row)),
        "fixed-frame" => Box::new(FixedReuseStrategy(ReuseMode::Frame)),
        "shortcut-mining" => Box::new(ShortcutMiningStrategy),
        "smartshuttle" => Box::new(SmartShuttleStrategy::default()),
        "tile" => Box::new(TileStreamingStrategy::default()),
        _ => return None,
    })
}

/// All registered strategy names (CLI help, sweep drivers).
pub const STRATEGY_NAMES: &[&str] = &[
    "cutpoint",
    "min-buffer",
    "fixed-row",
    "fixed-frame",
    "shortcut-mining",
    "smartshuttle",
    "tile",
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analyzer::analyze;
    use crate::zoo;

    #[test]
    fn cutpoint_beats_fixed_schemes() {
        let gg = analyze(&zoo::yolov2(416));
        let cfg = AccelConfig::kcu1500_int8();
        let best = CutPointStrategy.decide(&gg, &cfg).unwrap();
        for mode in [ReuseMode::Row, ReuseMode::Frame] {
            let fixed = FixedReuseStrategy(mode).decide(&gg, &cfg).unwrap();
            if fixed.feasible {
                assert!(
                    best.latency_ms <= fixed.latency_ms * 1.0001,
                    "{mode:?}: opt {} > fixed {}",
                    best.latency_ms,
                    fixed.latency_ms
                );
            }
        }
    }

    #[test]
    fn shortcut_mining_traffic_matches_model() {
        // The strategy must report exactly the Table II cost model.
        let gg = analyze(&zoo::resnet152(224));
        let cfg = AccelConfig::table2_int16();
        let e = ShortcutMiningStrategy.decide(&gg, &cfg).unwrap();
        assert_eq!(e.dram.fm_bytes, shortcut_mining_fm_traffic(&gg, &cfg));
        assert_eq!(e.dram.weight_bytes, shortcut_mining_weight_traffic(&gg, &cfg));
        assert_eq!(e.dram.total, e.dram.fm_bytes + e.dram.weight_bytes);
        assert_eq!(e.policy.len(), gg.groups.len());
    }

    #[test]
    fn smartshuttle_total_matches_model() {
        let cfg = AccelConfig::kcu1500_int8();
        // include a depthwise/FC-heavy model: the fm/weight split must
        // stay exact when layers fall outside [12]'s conv-only charge
        for name in ["vgg16-conv", "mobilenetv3-large"] {
            let gg = analyze(&zoo::by_name(name, zoo::default_input(name)).unwrap());
            let s = SmartShuttleStrategy::default();
            let e = s.decide(&gg, &cfg).unwrap();
            let raw = smartshuttle_dram(&gg, &cfg, s.buffer_bytes).dram_bytes;
            assert_eq!(e.dram.total, raw, "{name}");
            assert_eq!(e.dram.fm_bytes + e.dram.weight_bytes, e.dram.total, "{name}");
        }
    }

    #[test]
    fn registry_resolves_all_names() {
        for &n in STRATEGY_NAMES {
            assert_eq!(by_name(n).unwrap().name(), n);
        }
        assert!(by_name("bogus").is_none());
    }
}
