//! Typed compile errors.
//!
//! The staged API reports failures through one exhaustive enum instead of
//! the seed's mix of `anyhow` strings and hot-path panics, so callers can
//! match on the failure class (CLI: exit codes; `Session`: per-job error
//! isolation; tests: precise assertions).

use std::fmt;
use std::path::PathBuf;

/// Every way the compile pipeline (and its serialization front-end) can
/// fail.
#[derive(Debug)]
pub enum CompileError {
    /// Filesystem failure, with the path that was being accessed.
    Io {
        /// The path being read or written.
        path: PathBuf,
        /// The underlying OS error.
        source: std::io::Error,
    },
    /// JSON / frozen-graph / parameter-file syntax or schema violation.
    Parse(String),
    /// Accelerator-config (TOML subset) problem: unknown preset/key, bad
    /// number.
    Config(String),
    /// Model name not in the zoo (and not loadable from a file). Carries
    /// the valid names so sweep drivers and the CLI can print them
    /// instead of silently falling back to a default model or input.
    UnknownModel {
        /// The name that failed to resolve.
        name: String,
        /// Every name [`crate::zoo::by_name`] accepts.
        valid: &'static [&'static str],
    },
    /// The input graph failed structural validation.
    Graph(String),
    /// Quantized parameter store inconsistent with the graph.
    Params(String),
    /// No reuse policy satisfies the eq-(10) buffer constraint and the
    /// caller asked for strict feasibility.
    Infeasible {
        /// Model being compiled.
        model: String,
        /// SRAM bytes the best-effort policy needs.
        sram_required: usize,
        /// The configured `sram_budget` it exceeded.
        sram_budget: usize,
    },
    /// Stage artifacts passed out of order or with mismatched shapes
    /// (e.g. a policy vector whose length differs from the group count).
    StageMismatch(String),
    /// Functional simulation of a lowered stream failed.
    Exec(String),
    /// A packed [`crate::program::Program`] artifact is malformed:
    /// bad magic/version, checksum mismatch, truncated section, or
    /// contents inconsistent with the embedded graph.
    Artifact(String),
    /// Functionality compiled out of this build (e.g. the PJRT runtime
    /// without the `pjrt` feature).
    Unsupported(String),
    /// Serving backpressure: the engine's admission controller turned
    /// the request away instead of silently blocking. Carries the
    /// observed load and a retry-after hint so callers can shed or
    /// reschedule (see [`crate::engine::Rejection`]).
    Rejected {
        /// Queue depth plus backend-reported pending load at rejection.
        depth: usize,
        /// Earliest absolute deadline among queued requests, on the
        /// engine's clock (`None` when no queued request carries one).
        deadline_ms: Option<f64>,
    },
    /// A serving request's deadline passed before it finished: it was
    /// dropped unexecuted (queued or at dispatch). Counted in
    /// [`crate::engine::EngineStats::deadline_misses`].
    DeadlineMiss {
        /// The request's absolute deadline on the engine's clock.
        deadline_ms: f64,
        /// The clock reading when the miss was detected.
        now_ms: f64,
    },
}

impl CompileError {
    /// Shorthand for [`CompileError::Parse`].
    pub fn parse(msg: impl Into<String>) -> Self {
        CompileError::Parse(msg.into())
    }

    /// Shorthand for [`CompileError::Config`].
    pub fn config(msg: impl Into<String>) -> Self {
        CompileError::Config(msg.into())
    }

    /// Shorthand for [`CompileError::Params`].
    pub fn params(msg: impl Into<String>) -> Self {
        CompileError::Params(msg.into())
    }

    /// Shorthand for [`CompileError::StageMismatch`].
    pub fn stage(msg: impl Into<String>) -> Self {
        CompileError::StageMismatch(msg.into())
    }

    /// Shorthand for [`CompileError::Unsupported`].
    pub fn unsupported(msg: impl Into<String>) -> Self {
        CompileError::Unsupported(msg.into())
    }

    /// Shorthand for [`CompileError::Artifact`].
    pub fn artifact(msg: impl Into<String>) -> Self {
        CompileError::Artifact(msg.into())
    }

    /// An [`CompileError::UnknownModel`] carrying the current zoo
    /// registry, so the caller never has to assemble the valid-name list.
    pub fn unknown_model(name: impl Into<String>) -> Self {
        CompileError::UnknownModel { name: name.into(), valid: crate::zoo::KNOWN_NAMES }
    }

    /// Shorthand for [`CompileError::Io`].
    pub fn io(path: impl Into<PathBuf>, source: std::io::Error) -> Self {
        CompileError::Io { path: path.into(), source }
    }
}

impl fmt::Display for CompileError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CompileError::Io { path, source } => {
                write!(f, "{}: {source}", path.display())
            }
            CompileError::Parse(m) => write!(f, "parse error: {m}"),
            CompileError::Config(m) => write!(f, "config error: {m}"),
            CompileError::UnknownModel { name, valid } => {
                write!(
                    f,
                    "unknown model {name:?} — valid zoo models: {}; or pass a path to \
                     an imported model (.onnx) or frozen graph (.json)",
                    valid.join(", ")
                )
            }
            CompileError::Graph(m) => write!(f, "invalid graph: {m}"),
            CompileError::Params(m) => write!(f, "parameter error: {m}"),
            CompileError::Infeasible { model, sram_required, sram_budget } => write!(
                f,
                "{model}: no feasible reuse policy (needs {sram_required} B of SRAM, \
                 budget {sram_budget} B)"
            ),
            CompileError::StageMismatch(m) => write!(f, "stage mismatch: {m}"),
            CompileError::Exec(m) => write!(f, "execution error: {m}"),
            CompileError::Artifact(m) => write!(f, "program artifact error: {m}"),
            CompileError::Unsupported(m) => write!(f, "unsupported: {m}"),
            CompileError::Rejected { depth, deadline_ms } => {
                write!(f, "backpressure: request rejected at queue depth {depth}")?;
                if let Some(d) = deadline_ms {
                    write!(f, " (earliest queued deadline {d:.3} ms)")?;
                }
                Ok(())
            }
            CompileError::DeadlineMiss { deadline_ms, now_ms } => write!(
                f,
                "deadline miss: request expired {:.3} ms past its {deadline_ms:.3} ms \
                 deadline before execution",
                now_ms - deadline_ms
            ),
        }
    }
}

impl std::error::Error for CompileError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CompileError::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

impl From<crate::graph::ValidateError> for CompileError {
    fn from(e: crate::graph::ValidateError) -> Self {
        CompileError::Graph(e.to_string())
    }
}

impl From<crate::serialize::JsonError> for CompileError {
    fn from(e: crate::serialize::JsonError) -> Self {
        CompileError::Parse(e.to_string())
    }
}

impl From<crate::funcsim::ExecError> for CompileError {
    fn from(e: crate::funcsim::ExecError) -> Self {
        CompileError::Exec(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_is_informative() {
        let e = CompileError::unknown_model("alexnet");
        assert!(e.to_string().contains("alexnet"));
        // the valid zoo names ride along so sweep drivers can print them
        assert!(e.to_string().contains("resnet18"));
        assert!(e.to_string().contains("tinynet"));
        let e = CompileError::Infeasible {
            model: "yolov2".into(),
            sram_required: 10,
            sram_budget: 5,
        };
        assert!(e.to_string().contains("yolov2"));
        assert!(e.to_string().contains("10"));
    }

    #[test]
    fn io_preserves_source() {
        use std::error::Error as _;
        let e = CompileError::io(
            "/nope",
            std::io::Error::new(std::io::ErrorKind::NotFound, "gone"),
        );
        assert!(e.source().is_some());
        assert!(e.to_string().contains("/nope"));
    }
}
