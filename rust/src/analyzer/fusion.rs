//! The fusion pass: fine-grained nodes → accelerator groups.
//!
//! Two passes:
//! 1. *Partition*: walk nodes topologically; a compute node (conv / fc /
//!    scale / …) opens a group, then greedily absorbs its single-consumer
//!    chain of batch-norm, bias, activation, pooling, element-wise
//!    shortcut, upsampling and identity nodes. The SE squeeze is special:
//!    a conv output consumed by exactly {GlobalAvgPool, ScaleMul} keeps
//!    the GAP *inside* the conv group (computed in parallel with
//!    writeback, Fig. 13d).
//! 2. *Wire*: resolve group-level dataflow edges and the fused shortcut's
//!    source group.
//!
//! First-come-first-served absorption matches the paper's choice of
//! forwarding the *last conv of a residual block* into the shortcut adder
//! (Fig. 9): the residual-path conv reaches the `EltwiseAdd` before the
//! projection path does.

use super::groups::{Group, GroupId, GroupKind, GroupedGraph, PoolKind};
use crate::graph::{Activation, Graph, NodeId, OpKind};

/// Partition `graph` into accelerator groups.
pub fn analyze(graph: &Graph) -> GroupedGraph {
    let consumers = graph.consumers();
    let n = graph.nodes.len();
    let mut node_group: Vec<Option<GroupId>> = vec![None; n];
    let mut groups: Vec<Group> = Vec::new();

    for start in 0..n {
        if node_group[start].is_some() {
            continue;
        }
        let node = &graph.nodes[start];
        let gid = GroupId(groups.len());
        let kind = match node.op {
            OpKind::Input => GroupKind::Input,
            OpKind::Conv { depthwise: true, .. } => GroupKind::DwConv,
            OpKind::Conv { .. } => GroupKind::Conv,
            OpKind::Fc { .. } => GroupKind::Fc,
            OpKind::ScaleMul => GroupKind::Scale,
            OpKind::EltwiseAdd => GroupKind::Eltwise,
            OpKind::MaxPool { .. } | OpKind::AvgPool { .. } | OpKind::GlobalAvgPool => {
                GroupKind::Pool
            }
            OpKind::Concat => GroupKind::Concat,
            OpKind::Upsample { .. } => GroupKind::Upsample,
            OpKind::Act(_) | OpKind::BatchNorm | OpKind::BiasAdd | OpKind::Identity => {
                GroupKind::Act
            }
        };
        let mut group = Group {
            id: gid,
            kind,
            nodes: vec![NodeId(start)],
            main: NodeId(start),
            inputs: Vec::new(),
            act: match node.op {
                OpKind::Act(a) => a,
                _ => Activation::Linear,
            },
            pool: match node.op {
                OpKind::MaxPool { k, stride } => Some((PoolKind::Max, k, stride)),
                OpKind::AvgPool { k, stride } => Some((PoolKind::Avg, k, stride)),
                OpKind::GlobalAvgPool => Some((PoolKind::Global, 0, 0)),
                _ => None,
            },
            shortcut_of: None,
            upsample: match node.op {
                OpKind::Upsample { factor } => Some(factor),
                _ => None,
            },
            se_squeeze: false,
            in_shape: node.in_shapes.first().copied().unwrap_or(node.out_shape),
            out_shape: node.out_shape,
        };
        node_group[start] = Some(gid);

        // Concat/Input groups never absorb anything (concat output often
        // has multiple consumers and is pure redirection anyway).
        let absorbing = !matches!(kind, GroupKind::Concat | GroupKind::Input);
        if absorbing {
            extend_chain(graph, &consumers, &mut node_group, &mut group);
        }
        groups.push(group);
    }

    // Pass 2: group-level dataflow edges.
    let mut assignment: Vec<GroupId> = node_group.into_iter().map(Option::unwrap).collect();
    for gr in groups.iter_mut() {
        let mut seen = std::collections::HashSet::new();
        let mut inputs = Vec::new();
        for &nid in &gr.nodes {
            for &op_in in &graph.node(nid).inputs {
                let src = assignment[op_in.0];
                if src != gr.id && seen.insert(src) {
                    inputs.push(src);
                }
            }
            // Resolve the fused shortcut source.
            if graph.node(nid).op.is_shortcut() && nid != gr.main {
                for &op_in in &graph.node(nid).inputs {
                    if assignment[op_in.0] != gr.id {
                        gr.shortcut_of = Some(assignment[op_in.0]);
                    }
                }
            }
        }
        gr.inputs = inputs;
    }

    // Pass 3: topologically renumber. Chain absorption can make a group
    // read a group opened later (a residual block's projection branch is
    // emitted after the main path but consumed by the fused EltwiseAdd),
    // so instruction order = group order requires a re-sort.
    toposort_groups(&mut groups, &mut assignment);

    GroupedGraph { graph: graph.clone(), groups, node_group: assignment }
}

/// Kahn's algorithm over group dataflow edges; stable w.r.t. original
/// order so unrelated groups keep their program order.
fn toposort_groups(groups: &mut Vec<Group>, assignment: &mut [GroupId]) {
    let n = groups.len();
    let mut indeg = vec![0usize; n];
    let mut succ: Vec<Vec<usize>> = vec![Vec::new(); n];
    for gr in groups.iter() {
        for &i in &gr.inputs {
            indeg[gr.id.0] += 1;
            succ[i.0].push(gr.id.0);
        }
    }
    // Min-heap on original index keeps the order stable.
    let mut ready: std::collections::BinaryHeap<std::cmp::Reverse<usize>> = (0..n)
        .filter(|&i| indeg[i] == 0)
        .map(std::cmp::Reverse)
        .collect();
    let mut order = Vec::with_capacity(n);
    while let Some(std::cmp::Reverse(i)) = ready.pop() {
        order.push(i);
        for &s in &succ[i] {
            indeg[s] -= 1;
            if indeg[s] == 0 {
                ready.push(std::cmp::Reverse(s));
            }
        }
    }
    assert_eq!(order.len(), n, "group dataflow has a cycle");

    // old id -> new id
    let mut remap = vec![GroupId(0); n];
    for (new, &old) in order.iter().enumerate() {
        remap[old] = GroupId(new);
    }
    let mut reordered: Vec<Group> = order
        .into_iter()
        .map(|old| {
            let mut g = groups[old].clone();
            g.id = remap[g.id.0];
            g.inputs = g.inputs.iter().map(|&i| remap[i.0]).collect();
            g.shortcut_of = g.shortcut_of.map(|i| remap[i.0]);
            g
        })
        .collect();
    std::mem::swap(groups, &mut reordered);
    for a in assignment.iter_mut() {
        *a = remap[a.0];
    }
}

/// Greedy single-consumer chain absorption.
fn extend_chain(
    graph: &Graph,
    consumers: &[Vec<NodeId>],
    node_group: &mut [Option<GroupId>],
    group: &mut Group,
) {
    let compute = matches!(
        group.kind,
        GroupKind::Conv | GroupKind::DwConv | GroupKind::Fc | GroupKind::Scale | GroupKind::Eltwise
    );
    let mut tail = group.main;
    loop {
        let cons = &consumers[tail.0];

        // SE pattern: conv output read by exactly {GAP, ScaleMul} — keep
        // the squeeze inside this group (parallel writeback, Fig. 13d).
        if cons.len() == 2 && compute && !group.se_squeeze {
            let (a, b) = (cons[0], cons[1]);
            let is_gap = |id: NodeId| matches!(graph.node(id).op, OpKind::GlobalAvgPool);
            let is_scale = |id: NodeId| matches!(graph.node(id).op, OpKind::ScaleMul);
            let gap = if is_gap(a) && is_scale(b) {
                Some(a)
            } else if is_gap(b) && is_scale(a) {
                Some(b)
            } else {
                None
            };
            if let Some(gap_id) = gap {
                if node_group[gap_id.0].is_none() {
                    node_group[gap_id.0] = Some(group.id);
                    group.nodes.push(gap_id);
                    group.se_squeeze = true;
                }
            }
            return; // the feature-map output itself goes to the ScaleMul
        }

        if cons.len() != 1 {
            return;
        }
        let next = cons[0];
        if node_group[next.0].is_some() {
            return; // already claimed (e.g. an EltwiseAdd absorbed by the other branch)
        }
        let nnode = graph.node(next);
        let absorbed = match nnode.op {
            OpKind::BatchNorm | OpKind::BiasAdd | OpKind::Identity => true,
            OpKind::Act(a) => {
                group.act = a;
                true
            }
            OpKind::MaxPool { k, stride } if group.pool.is_none() && group.upsample.is_none() => {
                group.pool = Some((PoolKind::Max, k, stride));
                true
            }
            OpKind::AvgPool { k, stride } if group.pool.is_none() && group.upsample.is_none() => {
                group.pool = Some((PoolKind::Avg, k, stride));
                true
            }
            OpKind::GlobalAvgPool if group.pool.is_none() && group.upsample.is_none() => {
                group.pool = Some((PoolKind::Global, 0, 0));
                true
            }
            OpKind::EltwiseAdd if compute && group.shortcut_of.is_none() => {
                // `shortcut_of` is resolved in pass 2 (the other operand's
                // group may not exist yet); mark by membership only.
                true
            }
            OpKind::Upsample { factor } if group.upsample.is_none() && group.pool.is_none() => {
                group.upsample = Some(factor);
                true
            }
            _ => false,
        };
        if !absorbed {
            return;
        }
        node_group[next.0] = Some(group.id);
        group.nodes.push(next);
        group.out_shape = nnode.out_shape;
        tail = next;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::zoo;

    fn grouped(name: &str) -> GroupedGraph {
        let g = zoo::by_name(name, zoo::default_input(name)).unwrap();
        analyze(&g)
    }

    #[test]
    fn every_node_in_exactly_one_group() {
        for &name in zoo::MODEL_NAMES {
            let gg = grouped(name);
            let mut count = vec![0usize; gg.graph.nodes.len()];
            for gr in &gg.groups {
                for &nid in &gr.nodes {
                    count[nid.0] += 1;
                }
            }
            assert!(count.iter().all(|&c| c == 1), "{name}: node multiplicity wrong");
            // node_group agrees with membership
            for gr in &gg.groups {
                for &nid in &gr.nodes {
                    assert_eq!(gg.node_group[nid.0], gr.id, "{name}");
                }
            }
        }
    }

    #[test]
    fn group_inputs_are_earlier_groups() {
        for &name in zoo::MODEL_NAMES {
            let gg = grouped(name);
            for gr in &gg.groups {
                for &i in &gr.inputs {
                    assert!(i.0 < gr.id.0, "{name}: group {} reads later group {}", gr.id.0, i.0);
                }
            }
        }
    }

    #[test]
    fn resnet_blocks_fuse_shortcut() {
        let gg = grouped("resnet50");
        let fused = gg.groups.iter().filter(|g| g.shortcut_of.is_some()).count();
        // All 16 residual adds fuse into the last conv of their block.
        assert_eq!(fused, 16);
        // And each such group ends with ReLU.
        for gr in gg.groups.iter().filter(|g| g.shortcut_of.is_some()) {
            assert_eq!(gr.act, Activation::Relu);
        }
    }

    #[test]
    fn efficientnet_group_count_matches_fig5() {
        // Fig 5(a): 418 nodes → 139 groups for EfficientNet. Our B1
        // granularity (no explicit Pad/Reshape plumbing nodes) gives ~342
        // nodes → ~140 groups; the grouping ratio is the reproduction
        // target.
        let gg = grouped("efficientnet-b1");
        let n_nodes = gg.graph.nodes.len();
        let n_groups = gg.groups.len();
        assert!(
            (300..=460).contains(&n_nodes),
            "nodes {n_nodes} out of protobuf-scale range"
        );
        assert!(
            (130..=150).contains(&n_groups),
            "groups {n_groups} not in Fig-5 range"
        );
    }

    #[test]
    fn efficientnet_se_squeeze_fused() {
        let gg = grouped("efficientnet-b1");
        let se = gg.groups.iter().filter(|g| g.se_squeeze).count();
        assert_eq!(se, 23, "one fused squeeze per MBConv block");
        // every SE squeeze group is a depthwise conv group
        for gr in gg.groups.iter().filter(|g| g.se_squeeze) {
            assert_eq!(gr.kind, GroupKind::DwConv);
        }
    }

    #[test]
    fn yolov2_pools_fuse_behind_convs() {
        let gg = grouped("yolov2");
        // Four backbone max-pools fuse into their producing conv groups.
        // pool5 cannot (conv13 also feeds the passthrough branch), and the
        // 4 reorg quadrant pools share one producer — 5 standalone pools.
        let fused_pools = gg
            .groups
            .iter()
            .filter(|g| matches!(g.kind, GroupKind::Conv) && g.pool.is_some())
            .count();
        assert_eq!(fused_pools, 4);
        let standalone = gg.groups.iter().filter(|g| g.kind == GroupKind::Pool).count();
        assert_eq!(standalone, 5);
    }

    #[test]
    fn yolov3_upsamples_fuse() {
        let gg = grouped("yolov3");
        let fused_up = gg
            .groups
            .iter()
            .filter(|g| g.kind == GroupKind::Conv && g.upsample.is_some())
            .count();
        assert_eq!(fused_up, 2);
        assert_eq!(gg.groups.iter().filter(|g| g.kind == GroupKind::Upsample).count(), 0);
    }

    #[test]
    fn vgg_group_count() {
        let gg = grouped("vgg16-conv");
        // 13 conv groups (+input); every pool fused.
        assert_eq!(gg.groups.len(), 14);
        assert_eq!(gg.groups.iter().filter(|g| g.kind == GroupKind::Conv).count(), 13);
    }

    #[test]
    fn macs_conserved_by_grouping() {
        for &name in zoo::MODEL_NAMES {
            let gg = grouped(name);
            let group_macs: u64 = gg.groups.iter().map(|g| g.macs(&gg.graph)).sum();
            assert_eq!(group_macs, gg.graph.total_macs(), "{name}");
        }
    }
}
