//! CNN analyzer: reorganizes the fine-grained frozen graph into
//! accelerator-executable *groups* (Fig. 5a — e.g. EfficientNet's 418
//! protobuf nodes → 139 groups).
//!
//! A group is one invocation of the accelerator datapath: a main compute
//! op (convolution / depthwise convolution / FC) plus everything the
//! hardware chains behind the MAC arrays without a memory round-trip —
//! batch-norm/bias (folded into the MAC output), activation, pooling,
//! element-wise shortcut addition, SE squeeze (global average pooling,
//! computed in parallel with the conv writeback, Fig. 13d) and
//! upsampling ("Convolution, Activation, Normalization, Pooling,
//! Elementwise (shortcut pass), and/or Up-sampling layers are fused
//! together", §III-A).

mod groups;
mod fusion;

pub use groups::{Group, GroupId, GroupKind, GroupedGraph, PoolKind};
pub use fusion::analyze;
