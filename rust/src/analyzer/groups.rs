//! Group data model produced by the analyzer.

use crate::graph::{Activation, Graph, NodeId, Shape};

/// Index of a group within a [`GroupedGraph`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct GroupId(pub usize);

/// The main compute class of a group — selects the datapath.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GroupKind {
    /// Normal convolution (shared-MAC double-multiplication mode).
    Conv,
    /// Depthwise convolution (single-multiplication mode).
    DwConv,
    /// Fully-connected (SE reduce/expand, classifier).
    Fc,
    /// SE excitation scale (1×1 depthwise-like multiply, §IV-A).
    Scale,
    /// Standalone pooling (not fused behind a conv).
    Pool,
    /// Standalone element-wise addition (when the producer could not
    /// absorb it, e.g. both operands come from concat/route data).
    Eltwise,
    /// Channel concatenation — pure memory redirection ("feature-merging
    /// ... redirecting the output to the eventual destination", §III-A).
    Concat,
    /// Standalone upsampling.
    Upsample,
    /// Standalone activation / affine / copy (a producer with multiple
    /// consumers could not absorb it, e.g. RetinaNet's P6→ReLU→P7).
    Act,
    /// The graph input feed.
    Input,
}

/// Fused trailing pooling.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PoolKind {
    /// Max pooling.
    Max,
    /// Average pooling.
    Avg,
    /// Global average pooling (SE squeeze / classifier head).
    Global,
}

/// One accelerator invocation: the main op plus fused pre/post ops.
#[derive(Debug, Clone)]
pub struct Group {
    /// This group's index.
    pub id: GroupId,
    /// Datapath class of the main op.
    pub kind: GroupKind,
    /// All graph nodes folded into this group, in topological order.
    pub nodes: Vec<NodeId>,
    /// The main compute node (conv/fc/pool/…).
    pub main: NodeId,
    /// Data-producing groups this group reads, in operand order.
    pub inputs: Vec<GroupId>,
    /// Activation applied at the datapath output.
    pub act: Activation,
    /// Fused trailing pooling `(kind, k, stride)`; `Global` uses k=s=0.
    pub pool: Option<(PoolKind, usize, usize)>,
    /// Fused element-wise shortcut: the group whose output is added.
    pub shortcut_of: Option<GroupId>,
    /// Fused nearest-neighbour upsampling factor.
    pub upsample: Option<usize>,
    /// A parallel SE-squeeze output (GAP computed during writeback,
    /// Fig. 13d): the consuming FC reads a 1×1×C vector.
    pub se_squeeze: bool,
    /// Input feature-map shape (main operand).
    pub in_shape: Shape,
    /// Output feature-map shape after all fused ops.
    pub out_shape: Shape,
}

impl Group {
    /// MAC count of the group's compute nodes.
    pub fn macs(&self, g: &Graph) -> u64 {
        self.nodes.iter().map(|&n| g.node(n).macs()).sum()
    }

    /// Weight bytes this group streams from DRAM.
    pub fn weight_bytes(&self, g: &Graph, bytes_per_weight: u64) -> u64 {
        self.nodes.iter().map(|&n| g.node(n).weight_count() * bytes_per_weight).sum()
    }

    /// True when the group's main op carries weights.
    pub fn has_weights(&self, g: &Graph) -> bool {
        self.nodes.iter().any(|&n| g.node(n).op.has_weights())
    }

    /// Kernel size / stride / depthwise of the main conv (1,1,false for
    /// non-conv groups).
    pub fn conv_geometry(&self, g: &Graph) -> (usize, usize, bool) {
        match g.node(self.main).op {
            crate::graph::OpKind::Conv { k, stride, depthwise, .. } => (k, stride, depthwise),
            _ => (1, 1, false),
        }
    }
}

/// The analyzer output: the original graph plus its group partition.
#[derive(Debug, Clone)]
pub struct GroupedGraph {
    /// The validated source graph.
    pub graph: Graph,
    /// The fused accelerator groups, in topological order.
    pub groups: Vec<Group>,
    /// For each graph node, the group that contains it.
    pub node_group: Vec<GroupId>,
}

impl GroupedGraph {
    /// The group with the given id.
    pub fn group(&self, id: GroupId) -> &Group {
        &self.groups[id.0]
    }

    /// Groups that carry compute (conv/dwconv/fc/scale) — the paper's
    /// "CONV layer" count at group granularity.
    pub fn compute_groups(&self) -> impl Iterator<Item = &Group> {
        self.groups.iter().filter(|gr| {
            matches!(
                gr.kind,
                GroupKind::Conv | GroupKind::DwConv | GroupKind::Fc | GroupKind::Scale
            )
        })
    }

    /// Group-level consumer map.
    pub fn consumers(&self) -> Vec<Vec<GroupId>> {
        let mut out = vec![Vec::new(); self.groups.len()];
        for gr in &self.groups {
            for &i in &gr.inputs {
                out[i.0].push(gr.id);
            }
        }
        out
    }
}
