//! Test utilities: a miniature property-testing driver (the offline
//! registry has no `proptest`; see DESIGN.md §9) plus shared fixture
//! builders for the program/engine suites.

pub mod prop;

pub use prop::{forall, random_instruction, Rng};

/// Compile a graph with the default cut-point compiler and pack it into
/// a [`crate::program::Program`] — the boilerplate shared by the
/// program/engine test suites. `params_seed` packs deterministic random
/// parameters (what the reference backend needs).
///
/// Panics on any stage failure: this is test fixture code.
pub fn pack_program(
    graph: &crate::graph::Graph,
    params_seed: Option<u64>,
) -> crate::program::Program {
    use crate::compiler::Compiler;
    use crate::config::AccelConfig;
    use crate::funcsim::Params;

    let compiler = Compiler::new(AccelConfig::kcu1500_int8());
    let analyzed = compiler.analyze(graph).unwrap();
    let compiler = match params_seed {
        Some(seed) => compiler.with_params(Params::random(&analyzed.grouped, seed)),
        None => compiler,
    };
    let lowered = compiler
        .lower(&compiler.allocate(&compiler.optimize(&analyzed).unwrap()).unwrap())
        .unwrap();
    compiler.pack(&lowered).unwrap()
}
