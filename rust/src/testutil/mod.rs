//! Test utilities: a miniature property-testing driver (the offline
//! registry has no `proptest`; see DESIGN.md §9).

pub mod prop;

pub use prop::{Rng, forall};
