//! Miniature property-testing driver over a deterministic xorshift64*
//! PRNG. Usage:
//!
//! ```
//! use shortcutfusion::testutil::forall;
//! forall("addition commutes", 100, |rng| {
//!     let (a, b) = (rng.below(1000) as i64, rng.below(1000) as i64);
//!     assert_eq!(a + b, b + a);
//! });
//! ```
//!
//! Failures re-raise the inner panic annotated with the case's seed so the
//! exact input can be replayed with [`Rng::from_seed`].

/// xorshift64* PRNG — deterministic, seedable, no external crates.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Deterministic generator from a seed.
    pub fn from_seed(seed: u64) -> Self {
        // avoid the all-zero fixed point
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) | 1 }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[0, n)`. `n` must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn unit(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Fair coin.
    pub fn coin(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Random i8 (for quantized tensor generation).
    pub fn i8(&mut self) -> i8 {
        self.next_u64() as i8
    }

    /// Vector of random i8 values.
    pub fn i8_vec(&mut self, n: usize) -> Vec<i8> {
        (0..n).map(|_| self.i8()).collect()
    }
}

/// Uniformly sample a valid (encodable) group instruction over the full
/// field space — shared by the ISA round-trip unit tests and the
/// `program_roundtrip` property suite.
pub fn random_instruction(rng: &mut Rng) -> crate::isa::Instruction {
    use crate::analyzer::PoolKind;
    use crate::graph::Activation;
    use crate::isa::{Instruction, Opcode, ReuseMode};

    let acts = [
        Activation::Linear,
        Activation::Relu,
        Activation::Leaky,
        Activation::Relu6,
        Activation::Swish,
        Activation::Sigmoid,
        Activation::HardSwish,
        Activation::HardSigmoid,
    ];
    let ops = [
        Opcode::Input,
        Opcode::Conv,
        Opcode::DwConv,
        Opcode::Fc,
        Opcode::Scale,
        Opcode::Pool,
        Opcode::Eltwise,
        Opcode::Concat,
        Opcode::Upsample,
        Opcode::Copy,
    ];
    Instruction {
        // 16-bit group field: w10[23:16] now carries the tile height.
        group: rng.below(1 << 16) as u32,
        opcode: *rng.choose(&ops),
        act: *rng.choose(&acts),
        reuse: if rng.coin() { ReuseMode::Frame } else { ReuseMode::Row },
        k: rng.range(1, 15) as u8,
        stride: rng.range(1, 4) as u8,
        pad_same: rng.coin(),
        in_h: rng.below(2048) as u16,
        in_w: rng.below(2048) as u16,
        in_c: rng.below(4096) as u16,
        out_h: rng.below(2048) as u16,
        out_w: rng.below(2048) as u16,
        out_c: rng.below(4096) as u16,
        pool: match rng.below(4) {
            0 => None,
            1 => Some((PoolKind::Max, rng.range(2, 3) as u8, 2)),
            2 => Some((PoolKind::Avg, 2, 2)),
            _ => Some((PoolKind::Global, 0, 0)),
        },
        upsample: rng.below(4) as u8 * 2,
        fused_eltwise: rng.coin(),
        se_squeeze: rng.coin(),
        quant_shift: rng.next_u64() as i8,
        in_sel: rng.below(4) as u8,
        out_sel: rng.below(4) as u8,
        aux_sel: rng.below(4) as u8,
        in_addr: rng.next_u64() as u32,
        out_addr: rng.next_u64() as u32,
        aux_addr: rng.next_u64() as u32,
        weight_addr: rng.next_u64() as u32,
        weight_bytes: rng.next_u64() as u32,
        tile_rows: rng.below(256) as u8,
        tile_first: rng.coin(),
        tile_weight_stream: rng.coin(),
    }
}

/// Run `cases` property checks with per-case seeded RNGs. On panic, the
/// failing seed is reported for replay.
pub fn forall(name: &str, cases: u64, mut property: impl FnMut(&mut Rng)) {
    for case in 0..cases {
        let seed = 0x5F00u64
            .wrapping_mul(31)
            .wrapping_add(case)
            .wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::from_seed(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            property(&mut rng);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property {name:?} failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::from_seed(42);
        let mut b = Rng::from_seed(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Rng::from_seed(7);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
            let v = r.range(5, 9);
            assert!((5..=9).contains(&v));
        }
    }

    #[test]
    fn unit_in_01() {
        let mut r = Rng::from_seed(3);
        for _ in 0..1000 {
            let u = r.unit();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn forall_passes_trivial_property() {
        forall("trivial", 50, |rng| {
            let x = rng.below(100);
            assert!(x < 100);
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn forall_reports_seed_on_failure() {
        forall("fails", 10, |rng| {
            assert!(rng.below(10) < 5, "too big");
        });
    }
}
