//! Inter-device link cost model.

use crate::compiler::CompileError;
use crate::serialize::Json;
use crate::Result;

/// Analytical model of the device-to-device interconnect a shard
/// hand-off crosses: a fixed per-transfer latency plus a bandwidth term,
/// mirroring how [`crate::config::AccelConfig::dram_gbps`] models the
/// DRAM channel.
///
/// `transfer_ms(bytes) = latency_us / 1e3 + bytes / (gbps · 1e9) · 1e3`
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkModel {
    /// Effective link bandwidth in GB/s. `f64::INFINITY` is accepted and
    /// makes the bandwidth term vanish (useful for bounding experiments).
    pub gbps: f64,
    /// Fixed per-transfer latency in microseconds (DMA setup, protocol
    /// round trip).
    pub latency_us: f64,
}

impl LinkModel {
    /// A link model; `gbps` must be positive (infinity allowed) and
    /// `latency_us` non-negative and finite.
    pub fn new(gbps: f64, latency_us: f64) -> Result<LinkModel> {
        if gbps.is_nan() || gbps <= 0.0 {
            return Err(CompileError::config(format!(
                "link bandwidth {gbps} GB/s must be positive"
            )));
        }
        if !latency_us.is_finite() || latency_us < 0.0 {
            return Err(CompileError::config(format!(
                "link latency {latency_us} us must be finite and non-negative"
            )));
        }
        Ok(LinkModel { gbps, latency_us })
    }

    /// A PCIe-Gen3-x16-class board-to-board link: ~12 GB/s effective,
    /// 5 µs per transfer.
    pub fn pcie_gen3() -> LinkModel {
        LinkModel { gbps: 12.0, latency_us: 5.0 }
    }

    /// Time to move `bytes` across the link, in milliseconds.
    pub fn transfer_ms(&self, bytes: u64) -> f64 {
        self.latency_us / 1e3 + bytes as f64 / (self.gbps * 1e9) * 1e3
    }

    /// Flat JSON record (`gbps`, `latency_us`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("gbps", Json::num(self.gbps)),
            ("latency_us", Json::num(self.latency_us)),
        ])
    }
}

impl Default for LinkModel {
    fn default() -> LinkModel {
        LinkModel::pcie_gen3()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_cost_decomposes() {
        let link = LinkModel::new(10.0, 5.0).unwrap();
        // 10 MB at 10 GB/s = 1 ms, plus 5 us setup
        let ms = link.transfer_ms(10_000_000);
        assert!((ms - 1.005).abs() < 1e-12, "{ms}");
        // infinite bandwidth leaves only the setup latency
        let inf = LinkModel::new(f64::INFINITY, 5.0).unwrap();
        assert_eq!(inf.transfer_ms(u64::MAX), 0.005);
        // zero-latency infinite link transfers for free
        let free = LinkModel::new(f64::INFINITY, 0.0).unwrap();
        assert_eq!(free.transfer_ms(1 << 40), 0.0);
    }

    #[test]
    fn invalid_links_are_typed_errors() {
        for gbps in [0.0, -1.0, f64::NAN] {
            assert!(LinkModel::new(gbps, 0.0).is_err(), "{gbps}");
        }
        for lat in [-1.0, f64::NAN, f64::INFINITY] {
            assert!(LinkModel::new(1.0, lat).is_err(), "{lat}");
        }
    }
}
