//! Sharded design-space exploration on top of
//! [`SearchSpace`](crate::explorer::SearchSpace).
//!
//! The single-device explorer answers *which config should this model
//! run on*; [`SearchSpace::explore_sharded`] answers *how many devices,
//! and which config at each pipeline position*: it crosses the space's
//! constraint-pruned config grid with a device-count axis, assigns
//! configs to pipeline positions (heterogeneously up to a bounded
//! assignment count, homogeneously beyond it — never silently), runs the
//! [`Partitioner`] split search per assignment, and reduces the results
//! to a Pareto front over `(latency, pipeline interval, total SRAM,
//! device count)`.

use std::collections::HashMap;
use std::sync::Arc;

use super::{LinkModel, Objective, Partitioner, PlanCache, ShardPlan};
use crate::compiler::{fan_out, CompileError, ReuseStrategy};
use crate::config::AccelConfig;
use crate::explorer::{pareto_indices, SearchSpace};
use crate::serialize::Json;
use crate::zoo;
use crate::Result;

/// Heterogeneous-assignment ceiling per model × device count: beyond
/// `|configs|^K` assignments, the sweep falls back to homogeneous
/// assignments only and reports what it skipped.
const ASSIGNMENT_CAP: usize = 512;

/// One costed sharding candidate: a device count, a per-position config
/// assignment, and the best split the [`Partitioner`] found for it.
#[derive(Debug, Clone)]
pub struct ShardPoint {
    /// Zoo model name.
    pub model: String,
    /// Square input resolution the point was compiled at.
    pub input: usize,
    /// Pipeline devices.
    pub devices: usize,
    /// The winning split for this assignment.
    pub plan: ShardPlan,
}

impl ShardPoint {
    /// Config names, in pipeline order.
    pub fn cfg_names(&self) -> Vec<&str> {
        self.plan.shards.iter().map(|s| s.cfg.name.as_str()).collect()
    }

    /// Flat JSON record for machine-readable sweep output.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::str(&self.model)),
            ("input", Json::num(self.input as f64)),
            ("devices", Json::num(self.devices as f64)),
            (
                "configs",
                Json::Arr(self.cfg_names().iter().map(|n| Json::str(n)).collect()),
            ),
            ("strategy", Json::str(self.plan.strategy_name())),
            ("latency_ms", Json::num(self.plan.latency_ms)),
            ("interval_ms", Json::num(self.plan.interval_ms)),
            ("throughput_fps", Json::num(self.plan.throughput_fps())),
            ("total_sram_bytes", Json::num(self.plan.total_sram_bytes() as f64)),
            ("total_dram_bytes", Json::num(self.plan.total_dram_bytes() as f64)),
            ("feasible", Json::Bool(self.plan.feasible)),
        ])
    }

    fn objectives(&self) -> Vec<f64> {
        vec![
            self.plan.latency_ms,
            self.plan.interval_ms,
            self.plan.total_sram_bytes() as f64,
            self.devices as f64,
        ]
    }
}

/// A sharding candidate the sweep could not cost.
#[derive(Debug)]
pub struct ShardFailure {
    /// `model@input xK [configs]` of the failing assignment.
    pub point: String,
    /// The typed failure.
    pub error: CompileError,
}

/// The finished sharded sweep.
#[derive(Debug)]
pub struct ShardExploration {
    /// Costed points, in enumeration order (model-major, then device
    /// count, then assignment).
    pub points: Vec<ShardPoint>,
    /// Assignments whose plan failed (isolated per point).
    pub failures: Vec<ShardFailure>,
    /// Heterogeneous assignments dropped by the per-point cap (the sweep
    /// kept the homogeneous ones) — reported, never silent.
    pub skipped_assignments: usize,
}

impl ShardExploration {
    /// Unique model names in enumeration order.
    pub fn models(&self) -> Vec<String> {
        let mut seen = Vec::new();
        for p in &self.points {
            if !seen.contains(&p.model) {
                seen.push(p.model.clone());
            }
        }
        seen
    }

    /// The Pareto front of one model's *feasible* points over
    /// `(latency, pipeline interval, total SRAM, device count)` — all
    /// minimized; fewer devices dominate at equal cost.
    pub fn pareto_front(&self, model: &str) -> Vec<&ShardPoint> {
        let feasible: Vec<&ShardPoint> = self
            .points
            .iter()
            .filter(|p| p.model == model && p.plan.feasible)
            .collect();
        let objectives: Vec<Vec<f64>> = feasible.iter().map(|p| p.objectives()).collect();
        pareto_indices(&objectives).into_iter().map(|i| feasible[i]).collect()
    }

    /// The best feasible point of one model under `objective`
    /// (latency → single-image latency; throughput → pipeline interval),
    /// ties broken by the other metric, then total SRAM, then device
    /// count. `None` when nothing feasible was costed.
    pub fn recommend(&self, model: &str, objective: Objective) -> Option<&ShardPoint> {
        let key = |p: &ShardPoint| {
            let (a, b) = match objective {
                Objective::Latency => (p.plan.latency_ms, p.plan.interval_ms),
                Objective::Throughput => (p.plan.interval_ms, p.plan.latency_ms),
            };
            (a, b, p.plan.total_sram_bytes() as f64, p.devices as f64)
        };
        self.points
            .iter()
            .filter(|p| p.model == model && p.plan.feasible)
            .fold(None, |best: Option<&ShardPoint>, p| match best {
                Some(b) if key(b) <= key(p) => Some(b),
                _ => Some(p),
            })
    }
}

struct Assignment {
    model: String,
    input: usize,
    configs: Vec<AccelConfig>,
    strategy: Arc<dyn ReuseStrategy>,
}

impl SearchSpace {
    /// Sharded exploration: cross this space's constraint-pruned config
    /// grid with a `devices` axis and the space's reuse-strategy set
    /// (every shard of one candidate uses one strategy; default
    /// cut-point, matching [`SearchSpace::enumerate`]), assign configs
    /// to pipeline positions (all heterogeneous assignments while
    /// `|configs|^K` stays within a bounded budget, homogeneous ones
    /// beyond it — the drop count is reported in
    /// [`ShardExploration::skipped_assignments`]), and run the
    /// [`Partitioner`] split search for every assignment across
    /// `threads` workers. Shard subgraph compiles are memoized per model
    /// across assignments, so overlapping assignments only pay
    /// arithmetic.
    ///
    /// The split search per assignment minimizes `objective`; the
    /// returned exploration still carries both latency and interval for
    /// every point, so the 4-axis Pareto front is objective-independent.
    pub fn explore_sharded(
        &self,
        devices: &[usize],
        link: &LinkModel,
        objective: Objective,
        threads: usize,
    ) -> Result<ShardExploration> {
        if threads == 0 {
            return Err(CompileError::config("need at least one explore worker thread"));
        }
        if devices.is_empty() || devices.contains(&0) {
            return Err(CompileError::config(
                "device-count axis must be non-empty with every entry >= 1",
            ));
        }
        let enumeration = self.enumerate()?;

        // distinct configs and strategies per (model, input), in
        // enumeration order (the space's strategy set applies per shard,
        // so it crosses the assignment axis rather than the positions)
        let mut order: Vec<(String, usize)> = Vec::new();
        let mut grids: HashMap<(String, usize), Vec<AccelConfig>> = HashMap::new();
        let mut strategies: HashMap<(String, usize), Vec<Arc<dyn ReuseStrategy>>> =
            HashMap::new();
        for p in &enumeration.points {
            let key = (p.model.clone(), p.input);
            let cfgs = grids.entry(key.clone()).or_insert_with(|| {
                order.push(key.clone());
                Vec::new()
            });
            if cfgs.iter().all(|c| c.name != p.cfg.name) {
                cfgs.push(p.cfg.clone());
            }
            // dedup by Arc identity, not name: parameterized strategies
            // (SmartShuttle at two buffer sizes) share a name but are
            // distinct candidates; enumerate() clones one Arc per
            // configured strategy, so identity is exact here
            let strats = strategies.entry(key).or_default();
            if strats.iter().all(|s| !Arc::ptr_eq(s, &p.strategy)) {
                strats.push(p.strategy.clone());
            }
        }

        let mut assignments: Vec<Assignment> = Vec::new();
        let mut skipped = 0usize;
        for key in &order {
            let cfgs = &grids[key];
            for strategy in &strategies[key] {
                for &k in devices {
                    let total = cfgs.len().checked_pow(k as u32);
                    if total.is_some_and(|t| t <= ASSIGNMENT_CAP) {
                        for_each_assignment(cfgs, k, |configs| {
                            assignments.push(Assignment {
                                model: key.0.clone(),
                                input: key.1,
                                configs,
                                strategy: strategy.clone(),
                            });
                        });
                    } else {
                        // keep the homogeneous diagonal, report the rest
                        for cfg in cfgs {
                            assignments.push(Assignment {
                                model: key.0.clone(),
                                input: key.1,
                                configs: vec![cfg.clone(); k],
                                strategy: strategy.clone(),
                            });
                        }
                        skipped = skipped
                            .saturating_add(total.map_or(usize::MAX, |t| t - cfgs.len()));
                    }
                }
            }
        }

        // one graph + one memo per (model, input): every assignment of a
        // model reuses the same extracted subgraphs and range costs
        let mut graphs: HashMap<(String, usize), Arc<crate::graph::Graph>> = HashMap::new();
        let mut caches: HashMap<(String, usize), Arc<PlanCache>> = HashMap::new();
        for key in &order {
            let graph = zoo::by_name(&key.0, key.1)
                .ok_or_else(|| CompileError::unknown_model(key.0.clone()))?;
            graphs.insert(key.clone(), Arc::new(graph));
            caches.insert(key.clone(), Arc::new(PlanCache::default()));
        }

        let results: Vec<Result<ShardPlan>> = fan_out(assignments.len(), threads, |i| {
            let a = &assignments[i];
            let key = (a.model.clone(), a.input);
            let partitioner = Partitioner::heterogeneous(a.configs.clone())?
                .with_link(*link)
                .with_strategy(a.strategy.clone())
                .with_objective(objective);
            partitioner.plan_cached(&graphs[&key], &caches[&key])
        });

        let mut points = Vec::with_capacity(assignments.len());
        let mut failures = Vec::new();
        for (a, r) in assignments.iter().zip(results) {
            match r {
                Ok(plan) => points.push(ShardPoint {
                    model: a.model.clone(),
                    input: a.input,
                    devices: a.configs.len(),
                    plan,
                }),
                Err(error) => failures.push(ShardFailure {
                    point: format!(
                        "{}@{} x{} [{}] ({})",
                        a.model,
                        a.input,
                        a.configs.len(),
                        a.configs.iter().map(|c| c.name.as_str()).collect::<Vec<_>>().join(", "),
                        a.strategy.name()
                    ),
                    error,
                }),
            }
        }
        Ok(ShardExploration { points, failures, skipped_assignments: skipped })
    }
}

/// Visit every length-`k` assignment of `cfgs` to pipeline positions
/// (odometer over `|cfgs|^k`).
fn for_each_assignment(cfgs: &[AccelConfig], k: usize, mut f: impl FnMut(Vec<AccelConfig>)) {
    let mut digits = vec![0usize; k];
    loop {
        f(digits.iter().map(|&d| cfgs[d].clone()).collect());
        let mut i = 0;
        loop {
            if i == k {
                return;
            }
            digits[i] += 1;
            if digits[i] < cfgs.len() {
                break;
            }
            digits[i] = 0;
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sharded_exploration_sweeps_devices_and_assignments() {
        let space = SearchSpace::new(AccelConfig::kcu1500_int8())
            .model("tinynet")
            .sram_budgets(&[2_000_000, 8_000_000]);
        let link = LinkModel::pcie_gen3();
        let e = space
            .explore_sharded(&[1, 2], &link, Objective::Latency, 2)
            .unwrap();
        // 2 configs: K=1 -> 2 assignments, K=2 -> 4 assignments
        assert_eq!(e.points.len() + e.failures.len(), 6);
        assert!(e.failures.is_empty(), "{:?}", e.failures);
        assert_eq!(e.skipped_assignments, 0);
        assert!(e.points.iter().any(|p| p.devices == 2));
        // heterogeneous assignments made it in
        assert!(e
            .points
            .iter()
            .any(|p| p.devices == 2 && p.cfg_names()[0] != p.cfg_names()[1]));
        let front = e.pareto_front("tinynet");
        assert!(!front.is_empty());
        // a 1-device point at equal-or-better cost dominates; the front
        // never lists a point beaten on all four axes
        for p in &front {
            assert!(p.plan.feasible);
        }
        let best = e.recommend("tinynet", Objective::Latency).unwrap();
        assert!(front
            .iter()
            .any(|p| p.plan.latency_ms <= best.plan.latency_ms));
        assert!(e.recommend("missing", Objective::Latency).is_none());
    }

    #[test]
    fn sharded_exploration_honours_the_space_strategy_set() {
        // a space restricted to one baseline must never cost a shard
        // under the default cut-point optimizer
        let space = SearchSpace::new(AccelConfig::kcu1500_int8())
            .model("tinynet")
            .strategy_names(&["fixed-frame"])
            .unwrap();
        let e = space
            .explore_sharded(&[2], &LinkModel::pcie_gen3(), Objective::Latency, 2)
            .unwrap();
        assert!(!e.points.is_empty());
        for p in &e.points {
            assert_eq!(p.plan.strategy_name(), "fixed-frame");
        }
    }

    #[test]
    fn sharded_exploration_carries_tile_strategies() {
        // a tile axis on the space must reach every shard candidate —
        // sharded sweeps never silently fall back to cut-point only
        let space = SearchSpace::new(AccelConfig::kcu1500_int8())
            .model("tinynet")
            .tile_sizes(&[8]);
        let e = space
            .explore_sharded(&[2], &LinkModel::pcie_gen3(), Objective::Latency, 2)
            .unwrap();
        assert!(!e.points.is_empty());
        for p in &e.points {
            assert_eq!(p.plan.strategy_name(), "tile-8");
        }
    }

    #[test]
    fn sharded_exploration_rejects_bad_axes() {
        let space = SearchSpace::new(AccelConfig::kcu1500_int8()).model("tinynet");
        let link = LinkModel::pcie_gen3();
        assert!(space
            .explore_sharded(&[], &link, Objective::Latency, 2)
            .is_err());
        assert!(space
            .explore_sharded(&[0], &link, Objective::Latency, 2)
            .is_err());
        assert!(space
            .explore_sharded(&[1], &link, Objective::Latency, 0)
            .is_err());
    }

    #[test]
    fn assignment_odometer_counts() {
        let cfgs = vec![AccelConfig::kcu1500_int8(), AccelConfig::table2_int16()];
        let mut n = 0;
        for_each_assignment(&cfgs, 3, |a| {
            assert_eq!(a.len(), 3);
            n += 1;
        });
        assert_eq!(n, 8);
    }
}
