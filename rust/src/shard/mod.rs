//! Multi-FPGA pipeline sharding: partition one model's segment graph
//! across several accelerator configurations and serve the shards as a
//! device pipeline.
//!
//! ShortcutFusion sizes on-chip reuse for *one* device under fixed
//! resource constraints. This subsystem adds the next scaling axis:
//! models too large for any single device's SRAM/DSP budget are split at
//! **cut-point-aligned boundaries** — basic-block edges where exactly one
//! live feature-map crosses, i.e. precisely the places the reuse policy
//! already spills feature-maps to DRAM — into K contiguous shards, each
//! compiled for its own [`AccelConfig`] and deployed as its own
//! checksummed [`crate::program::Program`].
//!
//! The moving parts:
//!
//! * [`boundaries`] enumerates the legal split positions of a model
//!   (single crossing tensor, every graph output in the final shard).
//! * [`Partitioner`] searches every K-way combination of those
//!   boundaries, costing each shard with the crate's analytical models
//!   (cut-point search, eq. 8–9 DRAM traffic, cycle-accurate timing) and
//!   each hand-off with a configurable inter-device [`LinkModel`], under
//!   either a single-image-latency or a pipelined-throughput
//!   [`Objective`].
//! * [`ShardPlan`] is the winning split: per-shard subgraphs, costs and
//!   ingress/egress [`crate::program::TensorDesc`]s, plus the pipeline
//!   totals. [`ShardPlan::pack`] drives [`crate::compiler::Compiler::pack`]
//!   to emit one program per shard; a 1-device plan degenerates *exactly*
//!   to the unsharded pack (byte-identical artifact, no boundary record).
//! * [`crate::engine::ShardedBackend`] chains the shard programs through
//!   any [`crate::engine::ExecutionBackend`] with staged hand-off
//!   buffers, so [`crate::engine::InferenceEngine`] serves a sharded
//!   model transparently.
//! * [`ShardExploration`] (via
//!   [`SearchSpace::explore_sharded`](crate::explorer::SearchSpace))
//!   sweeps device counts × heterogeneous per-shard config assignments
//!   drawn from an explorer grid, with a Pareto front over
//!   (latency, pipeline interval, total SRAM, device count).
//!
//! ```no_run
//! use shortcutfusion::config::AccelConfig;
//! use shortcutfusion::shard::{LinkModel, Partitioner};
//! use shortcutfusion::zoo;
//!
//! let plan = Partitioner::homogeneous(AccelConfig::kcu1500_int8(), 2)
//!     .unwrap()
//!     .with_link(LinkModel::pcie_gen3())
//!     .plan(&zoo::resnet50(224))
//!     .unwrap();
//! println!(
//!     "{} devices: {:.3} ms / image, {:.1} fps pipelined",
//!     plan.devices(),
//!     plan.latency_ms,
//!     plan.throughput_fps()
//! );
//! for program in plan.pack().unwrap() {
//!     println!("{}", program.model());
//! }
//! ```
//!
//! The CLI front-end is `shortcutfusion shard` (text/JSON plan output,
//! `--pack`); `benches/sharding.rs` sweeps K × link bandwidth over the
//! zoo, and `rust/tests/sharding.rs` proves the 2-shard reference chain
//! bit-identical to the unsharded functional simulator.

mod link;
mod partition;
mod search;

pub use link::LinkModel;
pub use partition::{
    boundaries, Boundary, Partitioner, ShardPlan, ShardSpec, Transfer,
};
pub use search::{ShardExploration, ShardFailure, ShardPoint};

pub(crate) use partition::PlanCache;

/// What the split search minimizes (feasibility always ranks first).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Objective {
    /// Minimize single-image latency: the sum of shard latencies plus
    /// every inter-device transfer (one image traverses the whole chain).
    #[default]
    Latency,
    /// Maximize pipelined throughput: minimize the initiation interval,
    /// the slowest pipeline stage — device or link — once every shard
    /// works on a different in-flight image.
    Throughput,
}

impl Objective {
    /// Stable identifier used by reports and the CLI (`latency`,
    /// `throughput`).
    pub fn name(&self) -> &'static str {
        match self {
            Objective::Latency => "latency",
            Objective::Throughput => "throughput",
        }
    }
}
