//! Cut-point-aligned partitioning of a model across accelerator devices.
//!
//! A split position must satisfy two structural conditions, checked at
//! basic-block granularity (the unit that shares one reuse decision,
//! Fig. 10):
//!
//! 1. **single crossing tensor** — exactly one node on the producing side
//!    is read by the consuming side, so the hand-off is one feature-map
//!    DMA (these are exactly the positions where the reuse policy already
//!    spills to DRAM);
//! 2. **outputs stay last** — every graph output (detection heads) lives
//!    in the final shard, so each earlier shard has the crossing tensor
//!    as its unique sink and the chain forwards one tensor per hop.
//!
//! The [`Partitioner`] enumerates every K-way combination of the legal
//! boundaries, compiles each candidate shard through the staged
//! [`Compiler`] (memoized per group-range × config), prices hand-offs
//! with the [`LinkModel`], and keeps the best split under the configured
//! [`Objective`].

use std::collections::{HashMap, HashSet};
use std::fmt;
use std::sync::{Arc, Mutex};

use super::{LinkModel, Objective};
use crate::analyzer::{analyze, GroupedGraph};
use crate::compiler::{CompileError, Compiler, CutPointStrategy, ReuseStrategy};
use crate::config::AccelConfig;
use crate::funcsim::Params;
use crate::graph::{validate, Graph, Node, NodeId, OpKind};
use crate::optimizer::{basic_blocks, BasicBlock};
use crate::program::{Program, ShardBoundary, TensorDesc};
use crate::serialize::Json;
use crate::Result;

/// One legal split position: after a basic block whose boundary exactly
/// one live tensor crosses.
#[derive(Debug, Clone)]
pub struct Boundary {
    /// The split is after this block (index into the model's
    /// [`basic_blocks`] partition).
    pub after_block: usize,
    /// Last group index on the producing side of the split.
    pub last_group: usize,
    /// The unique tensor crossing the boundary (named after its producing
    /// node in the unsharded graph).
    pub tensor: TensorDesc,
    /// Node id of the crossing tensor's producer in the source graph.
    crossing_node: usize,
}

/// Every legal split position of a model, in program order.
///
/// Validates the graph, fuses it, partitions it into basic blocks and
/// keeps the block boundaries where exactly one tensor crosses and no
/// graph output is stranded on the producing side.
pub fn boundaries(graph: &Graph) -> Result<Vec<Boundary>> {
    validate(graph)?;
    let gg = analyze(graph);
    let blocks = basic_blocks(&gg);
    Ok(find_boundaries(&gg, &blocks))
}

fn find_boundaries(gg: &GroupedGraph, blocks: &[BasicBlock]) -> Vec<Boundary> {
    let g = &gg.graph;
    let mut is_output = vec![true; g.nodes.len()];
    for node in &g.nodes {
        for &inp in &node.inputs {
            is_output[inp.0] = false;
        }
    }
    let mut out = Vec::new();
    for (bi, block) in blocks.iter().enumerate().take(blocks.len().saturating_sub(1)) {
        let last_group = block.end;
        let left = |node: usize| gg.node_group[node].0 <= last_group;
        // graph outputs (detection heads) must stay in the final shard:
        // a stranded output would give the producing shard two sinks and
        // the chain forwards exactly one tensor per hop
        if (0..g.nodes.len()).any(|n| left(n) && is_output[n]) {
            continue;
        }
        let mut crossing: Option<usize> = None;
        let mut single = true;
        'scan: for node in g.nodes.iter().filter(|n| !left(n.id.0)) {
            for &inp in &node.inputs {
                if !left(inp.0) {
                    continue;
                }
                match crossing {
                    None => crossing = Some(inp.0),
                    Some(c) if c == inp.0 => {}
                    Some(_) => {
                        single = false;
                        break 'scan;
                    }
                }
            }
        }
        let Some(c) = crossing else { continue };
        // a boundary whose hand-off is the raw model input would make
        // the first shard dead weight — never useful, skip defensively
        if !single || c == 0 {
            continue;
        }
        out.push(Boundary {
            after_block: bi,
            last_group,
            tensor: TensorDesc {
                name: g.nodes[c].name.clone(),
                shape: g.nodes[c].out_shape,
            },
            crossing_node: c,
        });
    }
    out
}

/// Extract the subgraph of groups `gs..=ge`, replacing the previous
/// boundary's crossing tensor (if any) with a synthetic `Input` feed.
/// Node names and relative order are preserved, so quantized parameters
/// keyed by node name apply unchanged.
fn extract_shard(
    src: &Graph,
    gg: &GroupedGraph,
    name: String,
    gs: usize,
    ge: usize,
    ingress: Option<&Boundary>,
) -> Result<Graph> {
    if gs == 0 && ge + 1 == gg.groups.len() && ingress.is_none() {
        // full range: the shard IS the model — bit-identical clone so a
        // 1-device plan packs exactly today's artifact
        return Ok(Graph { name, nodes: src.nodes.clone() });
    }
    let member = |node: usize| {
        let gi = gg.node_group[node].0;
        gi >= gs && gi <= ge
    };
    let mut nodes: Vec<Node> = Vec::new();
    let mut map: HashMap<usize, NodeId> = HashMap::new();
    if let Some(b) = ingress {
        // unique, parameter-free name: `Params` lookups must miss so the
        // feed encodes the documented identity shift 0
        let mut feed = format!("{}@ingress", b.tensor.name);
        while src.find(&feed).is_some() {
            feed.push('+');
        }
        nodes.push(Node {
            id: NodeId(0),
            name: feed,
            op: OpKind::Input,
            inputs: Vec::new(),
            in_shapes: Vec::new(),
            out_shape: b.tensor.shape,
        });
        map.insert(b.crossing_node, NodeId(0));
    }
    for nd in src.nodes.iter().filter(|n| member(n.id.0)) {
        let id = NodeId(nodes.len());
        let inputs: Vec<NodeId> = nd
            .inputs
            .iter()
            .map(|i| {
                map.get(&i.0).copied().ok_or_else(|| {
                    CompileError::stage(format!(
                        "shard extraction: {} reads {:?} from outside the shard — \
                         boundary is not a single-tensor cut",
                        nd.name, src.nodes[i.0].name
                    ))
                })
            })
            .collect::<Result<_>>()?;
        let in_shapes = inputs.iter().map(|i| nodes[i.0].out_shape).collect();
        map.insert(nd.id.0, id);
        nodes.push(Node {
            id,
            name: nd.name.clone(),
            op: nd.op,
            inputs,
            in_shapes,
            out_shape: nd.out_shape,
        });
    }
    let graph = Graph { name, nodes };
    validate(&graph)?;
    Ok(graph)
}

/// Compile metrics of one shard candidate (one group range under one
/// config) — what the split search combines arithmetically.
#[derive(Debug, Clone, Copy)]
struct RangeCost {
    latency_ms: f64,
    sram_bytes: usize,
    dram_bytes: u64,
    feasible: bool,
    groups: usize,
}

/// Memoized shard subgraphs and compile costs, shared across every split
/// combination of one `plan` call (and, in
/// [`SearchSpace::explore_sharded`](crate::explorer::SearchSpace), across
/// heterogeneous config assignments of one model × input).
///
/// Keys are group ranges of **one fixed source graph** — never share a
/// cache across models or input sizes.
#[derive(Default)]
pub(crate) struct PlanCache {
    graphs: Mutex<HashMap<(usize, usize), Arc<Graph>>>,
    /// Range → (strategy+config fingerprint → cost). Two-level so the
    /// hot lookup borrows the fingerprint instead of allocating a key
    /// per split combination.
    costs: Mutex<HashMap<(usize, usize), HashMap<String, RangeCost>>>,
}

/// Split-search ceiling: combinations beyond this are a configuration
/// error (the arithmetic walk would dominate the compile-cost cache).
const MAX_SPLITS: f64 = 2_000_000.0;

/// Searches cut-point-aligned K-way splits of a model over K device
/// configurations and an inter-device [`LinkModel`].
#[derive(Clone)]
pub struct Partitioner {
    configs: Vec<AccelConfig>,
    link: LinkModel,
    strategy: Arc<dyn ReuseStrategy>,
    objective: Objective,
}

impl fmt::Debug for Partitioner {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Partitioner")
            .field("devices", &self.configs.len())
            .field("configs", &self.configs.iter().map(|c| c.name.as_str()).collect::<Vec<_>>())
            .field("link", &self.link)
            .field("strategy", &self.strategy.name())
            .field("objective", &self.objective)
            .finish()
    }
}

impl Partitioner {
    /// K identical devices running `cfg` (the common case: a rack of the
    /// same board).
    pub fn homogeneous(cfg: AccelConfig, devices: usize) -> Result<Partitioner> {
        if devices == 0 {
            return Err(CompileError::config("need at least one device"));
        }
        Partitioner::heterogeneous(vec![cfg; devices])
    }

    /// One explicit config per pipeline position (heterogeneous
    /// deployments: big backbone board, small head board). All configs
    /// must share the feature-map precision `qa` — the hand-off tensor
    /// crosses devices unconverted.
    pub fn heterogeneous(configs: Vec<AccelConfig>) -> Result<Partitioner> {
        if configs.is_empty() {
            return Err(CompileError::config("need at least one device config"));
        }
        if let Some(c) = configs.iter().find(|c| c.qa != configs[0].qa) {
            return Err(CompileError::config(format!(
                "device configs disagree on feature-map precision: {} has qa={}, {} has qa={}",
                configs[0].name, configs[0].qa, c.name, c.qa
            )));
        }
        Ok(Partitioner {
            configs,
            link: LinkModel::default(),
            strategy: Arc::new(CutPointStrategy),
            objective: Objective::default(),
        })
    }

    /// Set the inter-device link model (default:
    /// [`LinkModel::pcie_gen3`]).
    pub fn with_link(mut self, link: LinkModel) -> Partitioner {
        self.link = link;
        self
    }

    /// Set the per-shard reuse strategy (default: the paper's cut-point
    /// optimizer).
    pub fn with_strategy(mut self, strategy: Arc<dyn ReuseStrategy>) -> Partitioner {
        self.strategy = strategy;
        self
    }

    /// Set the split-search objective (default:
    /// [`Objective::Latency`]).
    pub fn with_objective(mut self, objective: Objective) -> Partitioner {
        self.objective = objective;
        self
    }

    /// Number of pipeline devices.
    pub fn devices(&self) -> usize {
        self.configs.len()
    }

    /// The per-device configurations, in pipeline order.
    pub fn configs(&self) -> &[AccelConfig] {
        &self.configs
    }

    /// Search every cut-point-aligned K-way split and return the best
    /// plan under the configured objective (feasibility ranks first; ties
    /// break on the secondary objective, then DRAM traffic, then SRAM).
    pub fn plan(&self, graph: &Graph) -> Result<ShardPlan> {
        self.plan_cached(graph, &PlanCache::default())
    }

    /// [`Partitioner::plan`] against an external memo — the sharded
    /// explorer reuses one cache across config assignments of the same
    /// model.
    pub(crate) fn plan_cached(&self, graph: &Graph, cache: &PlanCache) -> Result<ShardPlan> {
        validate(graph)?;
        let gg = analyze(graph);
        let blocks = basic_blocks(&gg);
        let k = self.configs.len();
        let bounds = find_boundaries(&gg, &blocks);
        if bounds.len() < k - 1 {
            return Err(CompileError::config(format!(
                "{}: cannot split into {k} shards — only {} cut-point-aligned boundaries",
                graph.name,
                bounds.len()
            )));
        }
        let splits = binomial(bounds.len(), k - 1);
        if splits > MAX_SPLITS {
            return Err(CompileError::config(format!(
                "{}: {} candidate splits for {k} devices over {} boundaries exceeds the \
                 search ceiling ({MAX_SPLITS}) — use fewer devices",
                graph.name,
                splits,
                bounds.len()
            )));
        }
        // Cost-cache identity: strategy name + Arc instance address +
        // full config Debug form. The cache outlives this call when the
        // sharded explorer shares it, and the same range costs
        // differently under another strategy — including another
        // *instance* of a parameterized strategy sharing a name
        // (SmartShuttle at two buffer sizes), so the address
        // disambiguates exactly like the Session report cache. Sound
        // because every sharer holds its strategy Arc for the cache's
        // whole lifetime (no address reuse).
        let strategy_addr = Arc::as_ptr(&self.strategy) as *const () as usize;
        let fingerprints: Vec<String> = self
            .configs
            .iter()
            .map(|c| format!("{}@{strategy_addr:x}::{c:?}", self.strategy.name()))
            .collect();
        let last_group = gg.groups.len() - 1;

        // ---- search every combination of k-1 boundaries ------------------
        let mut best: Option<SplitScore> = None;
        let mut evaluated = 0usize;
        for_each_combination(bounds.len(), k - 1, |combo| -> Result<()> {
            let mut latency = 0.0f64;
            let mut interval = 0.0f64;
            let mut feasible = true;
            let mut sram = 0usize;
            let mut dram = 0u64;
            for (j, cfg) in self.configs.iter().enumerate() {
                let (gs, ge) = range_of(&bounds, combo, j, k, last_group);
                let cost = self.range_cost(
                    graph,
                    &gg,
                    cache,
                    &bounds,
                    gs,
                    ge,
                    combo,
                    j,
                    cfg,
                    &fingerprints[j],
                )?;
                latency += cost.latency_ms;
                interval = interval.max(cost.latency_ms);
                feasible &= cost.feasible;
                sram += cost.sram_bytes;
                dram += cost.dram_bytes;
                if j + 1 < k {
                    let bytes = bounds[combo[j]].tensor.bytes(cfg.qa) as u64;
                    let t = self.link.transfer_ms(bytes);
                    latency += t;
                    interval = interval.max(t);
                }
            }
            evaluated += 1;
            let (primary, secondary) = match self.objective {
                Objective::Latency => (latency, interval),
                Objective::Throughput => (interval, latency),
            };
            let score =
                SplitScore { cuts: combo.to_vec(), feasible, primary, secondary, dram, sram };
            if best.as_ref().is_none_or(|b| score.beats(b)) {
                best = Some(score);
            }
            Ok(())
        })?;
        let best = best.expect("the combination walk visits at least one split");

        // ---- materialize the winning split, in chain order ---------------
        // (latency accumulates shard → transfer → shard …, matching the
        // ShardedBackend exactly so the cross-check is rounding-free)
        let mut shards = Vec::with_capacity(k);
        let mut transfers = Vec::with_capacity(k - 1);
        let mut latency = 0.0f64;
        let mut interval = 0.0f64;
        for (j, cfg) in self.configs.iter().enumerate() {
            let (gs, ge) = range_of(&bounds, &best.cuts, j, k, last_group);
            let shard_graph =
                self.shard_graph(graph, &gg, cache, &bounds, gs, ge, &best.cuts, j)?;
            let cost = self.range_cost(
                graph,
                &gg,
                cache,
                &bounds,
                gs,
                ge,
                &best.cuts,
                j,
                cfg,
                &fingerprints[j],
            )?;
            latency += cost.latency_ms;
            interval = interval.max(cost.latency_ms);
            let ingress = (j > 0).then(|| bounds[best.cuts[j - 1]].tensor.clone());
            let egress = (j + 1 < k).then(|| bounds[best.cuts[j]].tensor.clone());
            shards.push(ShardSpec {
                index: j,
                cfg: cfg.clone(),
                graph: shard_graph,
                first_block: if j == 0 { 0 } else { bounds[best.cuts[j - 1]].after_block + 1 },
                last_block: if j + 1 < k {
                    bounds[best.cuts[j]].after_block
                } else {
                    blocks.len().saturating_sub(1)
                },
                groups: cost.groups,
                latency_ms: cost.latency_ms,
                sram_bytes: cost.sram_bytes,
                dram_bytes: cost.dram_bytes,
                feasible: cost.feasible,
                ingress,
                egress,
            });
            if j + 1 < k {
                let tensor = bounds[best.cuts[j]].tensor.clone();
                let bytes = tensor.bytes(cfg.qa);
                let transfer_ms = self.link.transfer_ms(bytes as u64);
                latency += transfer_ms;
                interval = interval.max(transfer_ms);
                transfers.push(Transfer { tensor, bytes, transfer_ms });
            }
        }
        Ok(ShardPlan {
            model: graph.name.clone(),
            link: self.link,
            objective: self.objective,
            shards,
            transfers,
            latency_ms: latency,
            interval_ms: interval,
            feasible: best.feasible,
            boundaries: bounds.len(),
            splits_evaluated: evaluated,
            strategy: self.strategy.clone(),
        })
    }

    /// The (memoized) extracted subgraph of one group range.
    #[allow(clippy::too_many_arguments)]
    fn shard_graph(
        &self,
        graph: &Graph,
        gg: &GroupedGraph,
        cache: &PlanCache,
        bounds: &[Boundary],
        gs: usize,
        ge: usize,
        combo: &[usize],
        j: usize,
    ) -> Result<Arc<Graph>> {
        if let Some(g) = cache.graphs.lock().unwrap().get(&(gs, ge)) {
            return Ok(g.clone());
        }
        let ingress = if j == 0 { None } else { Some(&bounds[combo[j - 1]]) };
        let name = if gs == 0 && ge + 1 == gg.groups.len() {
            graph.name.clone()
        } else {
            format!("{}[g{gs}-{ge}]", graph.name)
        };
        let extracted = Arc::new(extract_shard(graph, gg, name, gs, ge, ingress)?);
        cache.graphs.lock().unwrap().insert((gs, ge), extracted.clone());
        Ok(extracted)
    }

    /// The (memoized) compile cost of one group range under one config.
    #[allow(clippy::too_many_arguments)]
    fn range_cost(
        &self,
        graph: &Graph,
        gg: &GroupedGraph,
        cache: &PlanCache,
        bounds: &[Boundary],
        gs: usize,
        ge: usize,
        combo: &[usize],
        j: usize,
        cfg: &AccelConfig,
        fingerprint: &str,
    ) -> Result<RangeCost> {
        if let Some(c) =
            cache.costs.lock().unwrap().get(&(gs, ge)).and_then(|m| m.get(fingerprint))
        {
            return Ok(*c);
        }
        let shard_graph = self.shard_graph(graph, gg, cache, bounds, gs, ge, combo, j)?;
        let compiler = Compiler::with_strategy(cfg.clone(), self.strategy.clone());
        let report = compiler.compile(&shard_graph)?;
        let cost = RangeCost {
            latency_ms: report.timing.latency_ms,
            sram_bytes: report.evaluation.sram.total,
            dram_bytes: report.evaluation.dram.total,
            feasible: report.evaluation.feasible,
            groups: report.grouped.groups.len(),
        };
        cache
            .costs
            .lock()
            .unwrap()
            .entry((gs, ge))
            .or_default()
            .insert(fingerprint.to_string(), cost);
        Ok(cost)
    }
}

/// Group span of shard `j` under the chosen boundary combination.
fn range_of(
    bounds: &[Boundary],
    combo: &[usize],
    j: usize,
    k: usize,
    last_group: usize,
) -> (usize, usize) {
    let gs = if j == 0 { 0 } else { bounds[combo[j - 1]].last_group + 1 };
    let ge = if j + 1 < k { bounds[combo[j]].last_group } else { last_group };
    (gs, ge)
}

struct SplitScore {
    cuts: Vec<usize>,
    feasible: bool,
    primary: f64,
    secondary: f64,
    dram: u64,
    sram: usize,
}

impl SplitScore {
    fn beats(&self, other: &SplitScore) -> bool {
        match (self.feasible, other.feasible) {
            (true, false) => true,
            (false, true) => false,
            _ => {
                (self.primary, self.secondary, self.dram, self.sram)
                    < (other.primary, other.secondary, other.dram, other.sram)
            }
        }
    }
}

/// Visit every ascending `k`-combination of `0..n`, in lexicographic
/// order; `k = 0` visits the empty combination once.
fn for_each_combination<E>(
    n: usize,
    k: usize,
    mut f: impl FnMut(&[usize]) -> std::result::Result<(), E>,
) -> std::result::Result<(), E> {
    if k == 0 {
        return f(&[]);
    }
    if k > n {
        return Ok(());
    }
    let mut idx: Vec<usize> = (0..k).collect();
    loop {
        f(&idx)?;
        let mut i = k;
        loop {
            if i == 0 {
                return Ok(());
            }
            i -= 1;
            if idx[i] != i + n - k {
                break;
            }
        }
        idx[i] += 1;
        for x in i + 1..k {
            idx[x] = idx[x - 1] + 1;
        }
    }
}

fn binomial(n: usize, k: usize) -> f64 {
    if k > n {
        return 0.0;
    }
    let k = k.min(n - k);
    let mut acc = 1.0f64;
    for i in 0..k {
        acc = acc * (n - i) as f64 / (i + 1) as f64;
    }
    acc
}

/// Quantized parameters restricted to the nodes of one shard graph (the
/// hand-off feed has a fresh name, so it deliberately matches nothing and
/// keeps the identity shift).
fn subset_params(p: &Params, graph: &Graph) -> Params {
    let names: HashSet<&str> = graph.nodes.iter().map(|n| n.name.as_str()).collect();
    Params {
        groups: p
            .groups
            .iter()
            .filter(|(name, _)| names.contains(name.as_str()))
            .map(|(name, gp)| (name.clone(), gp.clone()))
            .collect(),
    }
}

/// One inter-device hand-off of a [`ShardPlan`].
#[derive(Debug, Clone)]
pub struct Transfer {
    /// The tensor crossing the link.
    pub tensor: TensorDesc,
    /// Transfer size in bytes (at the producing device's `qa`).
    pub bytes: usize,
    /// Modeled transfer time, ms.
    pub transfer_ms: f64,
}

/// One pipeline stage of a [`ShardPlan`]: a contiguous block range
/// compiled for one device.
#[derive(Debug, Clone)]
pub struct ShardSpec {
    /// Pipeline position (0-based).
    pub index: usize,
    /// The device configuration this shard compiles for.
    pub cfg: AccelConfig,
    /// The extracted shard subgraph (shared with the plan's memo).
    pub graph: Arc<Graph>,
    /// First basic block of the shard (index into the *unsharded*
    /// model's block partition).
    pub first_block: usize,
    /// Last basic block of the shard (inclusive).
    pub last_block: usize,
    /// Accelerator groups in the shard subgraph (its input feed
    /// included).
    pub groups: usize,
    /// Shard latency from the cycle-accurate timing model, ms.
    pub latency_ms: f64,
    /// Shard SRAM requirement (eq. 6), bytes.
    pub sram_bytes: usize,
    /// Shard DRAM traffic per inference (eq. 9), bytes.
    pub dram_bytes: u64,
    /// Whether the shard's policy meets its device's eq-(10) budget.
    pub feasible: bool,
    /// Tensor this shard receives (`None` for the first shard, which
    /// reads the model input).
    pub ingress: Option<TensorDesc>,
    /// Tensor this shard emits downstream (`None` for the final shard,
    /// which produces the model output).
    pub egress: Option<TensorDesc>,
}

/// The winning split: per-shard specs, hand-offs and pipeline totals.
#[derive(Clone)]
pub struct ShardPlan {
    /// The unsharded model's name.
    pub model: String,
    /// The inter-device link model used for costing.
    pub link: LinkModel,
    /// The objective the split was chosen under.
    pub objective: Objective,
    /// Pipeline stages, in order. A 1-device plan has exactly one.
    pub shards: Vec<ShardSpec>,
    /// Hand-offs between consecutive shards (`shards.len() - 1` entries).
    pub transfers: Vec<Transfer>,
    /// Single-image latency: shard latencies plus every transfer, ms.
    pub latency_ms: f64,
    /// Pipeline initiation interval: the slowest stage (device or link),
    /// ms.
    pub interval_ms: f64,
    /// Whether every shard meets its device's buffer budget.
    pub feasible: bool,
    /// Legal cut-point boundaries the model offered.
    pub boundaries: usize,
    /// Split combinations the search evaluated.
    pub splits_evaluated: usize,
    strategy: Arc<dyn ReuseStrategy>,
}

impl fmt::Debug for ShardPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("ShardPlan")
            .field("model", &self.model)
            .field("devices", &self.shards.len())
            .field("latency_ms", &self.latency_ms)
            .field("interval_ms", &self.interval_ms)
            .field("feasible", &self.feasible)
            .field("strategy", &self.strategy.name())
            .finish()
    }
}

impl ShardPlan {
    /// Number of pipeline devices.
    pub fn devices(&self) -> usize {
        self.shards.len()
    }

    /// Steady-state pipelined throughput, frames per second.
    pub fn throughput_fps(&self) -> f64 {
        1000.0 / self.interval_ms
    }

    /// Sum of the shards' SRAM requirements, bytes.
    pub fn total_sram_bytes(&self) -> usize {
        self.shards.iter().map(|s| s.sram_bytes).sum()
    }

    /// Sum of the shards' DRAM traffic per inference, bytes.
    pub fn total_dram_bytes(&self) -> u64 {
        self.shards.iter().map(|s| s.dram_bytes).sum()
    }

    /// Name of the per-shard reuse strategy.
    pub fn strategy_name(&self) -> &'static str {
        self.strategy.name()
    }

    /// Compile and pack every shard into a deployable
    /// [`Program`] (stage 6 per shard). Multi-device plans stamp each
    /// artifact with its [`ShardBoundary`] descriptors; a 1-device plan
    /// produces exactly the unsharded [`Compiler::pack`] artifact.
    pub fn pack(&self) -> Result<Vec<Program>> {
        self.pack_with_params(None)
    }

    /// [`ShardPlan::pack`] with quantized parameters for the *unsharded*
    /// model: each shard packs the subset its nodes need (what the
    /// bit-exact [`crate::engine::ReferenceBackend`] chain requires).
    pub fn pack_with_params(&self, params: Option<&Params>) -> Result<Vec<Program>> {
        let k = self.shards.len();
        let mut out = Vec::with_capacity(k);
        for s in &self.shards {
            let mut compiler = Compiler::with_strategy(s.cfg.clone(), self.strategy.clone());
            if let Some(p) = params {
                compiler = compiler.with_params(subset_params(p, &s.graph));
            }
            let analyzed = compiler.analyze(&s.graph)?;
            let lowered =
                compiler.lower(&compiler.allocate(&compiler.optimize(&analyzed)?)?)?;
            let mut program = compiler.pack(&lowered)?;
            if k > 1 {
                program = program.with_boundary(ShardBoundary {
                    index: s.index,
                    count: k,
                    ingress: s.ingress.clone(),
                    egress: s.egress.clone(),
                })?;
            }
            out.push(program);
        }
        Ok(out)
    }

    /// Machine-readable plan record (what `shard --format json` and
    /// `--json-out` emit).
    pub fn to_json(&self) -> Json {
        let shards: Vec<Json> = self
            .shards
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("index", Json::num(s.index as f64)),
                    ("config", Json::str(&s.cfg.name)),
                    ("model", Json::str(&s.graph.name)),
                    ("first_block", Json::num(s.first_block as f64)),
                    ("last_block", Json::num(s.last_block as f64)),
                    ("groups", Json::num(s.groups as f64)),
                    ("latency_ms", Json::num(s.latency_ms)),
                    ("sram_bytes", Json::num(s.sram_bytes as f64)),
                    ("dram_bytes", Json::num(s.dram_bytes as f64)),
                    ("feasible", Json::Bool(s.feasible)),
                    ("ingress", tensor_json(s.ingress.as_ref())),
                    ("egress", tensor_json(s.egress.as_ref())),
                ])
            })
            .collect();
        let transfers: Vec<Json> = self
            .transfers
            .iter()
            .map(|t| {
                Json::obj(vec![
                    ("tensor", tensor_json(Some(&t.tensor))),
                    ("bytes", Json::num(t.bytes as f64)),
                    ("transfer_ms", Json::num(t.transfer_ms)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("model", Json::str(&self.model)),
            ("devices", Json::num(self.shards.len() as f64)),
            ("objective", Json::str(self.objective.name())),
            ("strategy", Json::str(self.strategy.name())),
            ("link", self.link.to_json()),
            ("latency_ms", Json::num(self.latency_ms)),
            ("interval_ms", Json::num(self.interval_ms)),
            ("throughput_fps", Json::num(self.throughput_fps())),
            ("total_sram_bytes", Json::num(self.total_sram_bytes() as f64)),
            ("total_dram_bytes", Json::num(self.total_dram_bytes() as f64)),
            ("feasible", Json::Bool(self.feasible)),
            ("boundaries", Json::num(self.boundaries as f64)),
            ("splits_evaluated", Json::num(self.splits_evaluated as f64)),
            ("shards", Json::Arr(shards)),
            ("transfers", Json::Arr(transfers)),
        ])
    }
}

fn tensor_json(t: Option<&TensorDesc>) -> Json {
    // one serialization for descriptors, shared with the packed artifact
    t.map(TensorDesc::to_json).unwrap_or(Json::Null)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::zoo;

    #[test]
    fn tinynet_boundaries_are_single_tensor_cuts() {
        let g = zoo::tinynet();
        let bounds = boundaries(&g).unwrap();
        assert!(bounds.len() >= 3, "{bounds:?}");
        // strictly increasing positions, all naming real nodes
        for pair in bounds.windows(2) {
            assert!(pair[0].after_block < pair[1].after_block);
        }
        for b in &bounds {
            let node = g.find(&b.tensor.name).expect("crossing node exists");
            assert_eq!(g.node(node).out_shape, b.tensor.shape);
        }
        // the residual-block exits are among the cuts
        assert!(bounds.iter().any(|b| b.tensor.name == "res1/relu"));
        assert!(bounds.iter().any(|b| b.tensor.name == "mb1/add"));
        // the down/up branch is NOT a legal cut (two tensors cross)
        assert!(bounds.iter().all(|b| b.tensor.name != "up"));
    }

    #[test]
    fn detector_boundaries_keep_heads_in_the_final_shard() {
        let g = zoo::yolov3(256);
        let outputs = g.outputs();
        assert!(outputs.len() > 1, "yolov3 is multi-output");
        let bounds = boundaries(&g).unwrap();
        assert!(!bounds.is_empty(), "backbone offers cuts");
        let gg = analyze(&g);
        for b in &bounds {
            for &o in &outputs {
                assert!(
                    gg.node_group[o.0].0 > b.last_group,
                    "boundary {b:?} strands head {:?} on the producing side",
                    g.node(o).name
                );
            }
        }
    }

    #[test]
    fn two_shard_plan_decomposes_latency() {
        let g = zoo::tinynet();
        let link = LinkModel::new(1.0, 100.0).unwrap();
        let p = Partitioner::homogeneous(AccelConfig::kcu1500_int8(), 2)
            .unwrap()
            .with_link(link)
            .plan(&g)
            .unwrap();
        assert_eq!(p.devices(), 2);
        assert_eq!(p.transfers.len(), 1);
        let parts: f64 = p.shards.iter().map(|s| s.latency_ms).sum::<f64>()
            + p.transfers.iter().map(|t| t.transfer_ms).sum::<f64>();
        assert!((p.latency_ms - parts).abs() < 1e-12, "{} vs {parts}", p.latency_ms);
        let widest = p
            .shards
            .iter()
            .map(|s| s.latency_ms)
            .chain(p.transfers.iter().map(|t| t.transfer_ms))
            .fold(0.0f64, f64::max);
        assert_eq!(p.interval_ms, widest);
        // shard graphs chain: shard 1's egress is shard 2's ingress
        assert_eq!(p.shards[0].egress, p.shards[1].ingress);
        assert!(p.shards[0].ingress.is_none());
        assert!(p.shards[1].egress.is_none());
        // each shard graph validates and shard 2 starts at the hand-off
        let in_shape = p.shards[1].graph.input().out_shape;
        assert_eq!(in_shape, p.shards[0].egress.as_ref().unwrap().shape);
    }

    #[test]
    fn single_device_plan_is_the_whole_model() {
        let g = zoo::tinynet();
        let p = Partitioner::homogeneous(AccelConfig::kcu1500_int8(), 1)
            .unwrap()
            .plan(&g)
            .unwrap();
        assert_eq!(p.devices(), 1);
        assert!(p.transfers.is_empty());
        assert_eq!(p.shards[0].graph.name, "TinyNet-SE");
        assert_eq!(p.shards[0].graph.nodes.len(), g.nodes.len());
        assert_eq!(p.latency_ms, p.interval_ms);
        assert_eq!(p.splits_evaluated, 1);
    }

    #[test]
    fn impossible_splits_and_bad_configs_are_typed_errors() {
        let g = zoo::tinynet();
        let err = Partitioner::homogeneous(AccelConfig::kcu1500_int8(), 64)
            .unwrap()
            .plan(&g)
            .unwrap_err();
        assert!(matches!(err, CompileError::Config(_)), "{err}");
        assert!(Partitioner::homogeneous(AccelConfig::kcu1500_int8(), 0).is_err());
        assert!(Partitioner::heterogeneous(Vec::new()).is_err());
        // mixed feature-map precisions cannot hand off unconverted
        let mixed =
            vec![AccelConfig::kcu1500_int8(), AccelConfig::table2_int16()];
        assert!(Partitioner::heterogeneous(mixed).is_err());
    }

    #[test]
    fn combination_walk_is_exhaustive_and_ordered() {
        let mut seen = Vec::new();
        for_each_combination(4, 2, |c| -> std::result::Result<(), ()> {
            seen.push(c.to_vec());
            Ok(())
        })
        .unwrap();
        assert_eq!(
            seen,
            vec![
                vec![0, 1],
                vec![0, 2],
                vec![0, 3],
                vec![1, 2],
                vec![1, 3],
                vec![2, 3]
            ]
        );
        let mut empty = 0;
        for_each_combination(5, 0, |c| -> std::result::Result<(), ()> {
            assert!(c.is_empty());
            empty += 1;
            Ok(())
        })
        .unwrap();
        assert_eq!(empty, 1);
        assert_eq!(binomial(50, 3), 19600.0);
        assert_eq!(binomial(3, 5), 0.0);
    }
}
