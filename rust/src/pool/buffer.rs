//! The buffer pool: refcounted residency over a modeled DRAM budget.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::compiler::CompileError;
use crate::engine::{Clock, RealClock};
use crate::serialize::Json;
use crate::shard::LinkModel;
use crate::telemetry::{NullSink, TraceEvent, TraceSink};
use crate::Result;

use super::{ReplacementPolicy, SegmentId};

/// Cold-load latency samples kept for percentile reporting (ring buffer,
/// same window the serving engine uses for request latencies).
const COLD_WINDOW: usize = 4096;

/// Sizing and cost model of a [`BufferPool`].
#[derive(Debug, Clone)]
pub struct PoolConfig {
    /// Modeled device-DRAM bytes available for weight segments.
    pub capacity_bytes: u64,
    /// Channel filling DRAM on a miss: a cold pin of `b` bytes costs
    /// `link.transfer_ms(b)` of modeled latency.
    pub link: LinkModel,
    /// Per-tenant admission quota in bytes. A tenant past its quota
    /// evicts its *own* unpinned segments before taking pool capacity
    /// from others; `None` disables quota enforcement.
    pub tenant_quota_bytes: Option<u64>,
}

impl PoolConfig {
    /// A pool of `capacity_bytes` filled over the default PCIe-class
    /// link, with quotas disabled.
    pub fn new(capacity_bytes: u64) -> PoolConfig {
        PoolConfig { capacity_bytes, link: LinkModel::default(), tenant_quota_bytes: None }
    }

    /// Replace the DRAM-fill link model.
    pub fn with_link(mut self, link: LinkModel) -> PoolConfig {
        self.link = link;
        self
    }

    /// Enable a per-tenant admission quota.
    pub fn with_tenant_quota(mut self, bytes: u64) -> PoolConfig {
        self.tenant_quota_bytes = Some(bytes);
        self
    }
}

/// One resident segment's bookkeeping.
struct Resident {
    bytes: u64,
    pins: u32,
    tenant: String,
}

/// Mutable pool state behind the lock.
struct Inner {
    resident: HashMap<SegmentId, Resident>,
    policy: Box<dyn ReplacementPolicy>,
    used_bytes: u64,
    tenant_bytes: HashMap<String, u64>,
    // counters
    hits: u64,
    misses: u64,
    evictions: u64,
    bypasses: u64,
    overcommits: u64,
    quota_overruns: u64,
    peak_used_bytes: u64,
    /// Miss pins (bypasses included) whose guards are still alive: the
    /// pool's "cold fills in flight", reported to the serving engine's
    /// admission controller as hidden backend load.
    active_cold_pins: usize,
    cold_ms: Vec<f64>,
    cold_next: usize,
    cold_total_ms: f64,
    /// Trace sink + its time source ([`NullSink`] until
    /// [`BufferPool::set_trace`]); kept inside the lock the pin path
    /// already holds, so attaching a sink costs nothing extra.
    clock: Arc<dyn Clock>,
    trace: Arc<dyn TraceSink>,
}

impl Inner {
    /// Evict one unpinned segment chosen by the policy among those
    /// matching `tenant` (or any tenant when `None`). Returns false when
    /// no such segment exists. Split-borrows so the policy's candidate
    /// filter can read the residency map while the policy is `&mut`.
    fn evict_one(&mut self, tenant: Option<&str>) -> bool {
        let Inner { resident, policy, .. } = self;
        let victim = policy.victim(&|s| {
            resident.get(&s).is_some_and(|r| {
                r.pins == 0 && tenant.is_none_or(|t| r.tenant == t)
            })
        });
        let Some(victim) = victim else { return false };
        let r = self.resident.remove(&victim).expect("victim must be resident");
        self.policy.remove(victim);
        self.used_bytes -= r.bytes;
        if let Some(t) = self.tenant_bytes.get_mut(&r.tenant) {
            *t = t.saturating_sub(r.bytes);
        }
        self.evictions += 1;
        if self.trace.enabled() {
            self.trace.record(
                TraceEvent::instant("pool", "evict", self.clock.now_ms(), victim.0)
                    .arg("bytes", r.bytes as f64),
            );
        }
        true
    }

    /// Drop unpinned segments until the pool is back under `capacity`
    /// (or only pinned segments remain).
    fn trim(&mut self, capacity: u64) {
        while self.used_bytes > capacity {
            if !self.evict_one(None) {
                break;
            }
        }
    }

    fn record_cold(&mut self, ms: f64) {
        self.cold_total_ms += ms;
        if self.cold_ms.len() < COLD_WINDOW {
            self.cold_ms.push(ms);
        } else {
            self.cold_ms[self.cold_next] = ms;
            self.cold_next = (self.cold_next + 1) % COLD_WINDOW;
        }
    }
}

/// Refcounted residency manager for packed-program weight segments over
/// a modeled device-DRAM budget. See the [module docs](super) for the
/// design; thread-safe (`pin` from any number of serving workers).
///
/// `pin` is infallible by design: a request never waits for capacity.
/// When eviction cannot make room (everything resident is pinned) the
/// segment is admitted as a transient over-commit and the pool trims
/// itself back under budget as pins release.
pub struct BufferPool {
    inner: Mutex<Inner>,
    capacity_bytes: u64,
    link: LinkModel,
    tenant_quota_bytes: Option<u64>,
    policy_name: &'static str,
}

impl BufferPool {
    /// A pool with the given budget and replacement policy. The capacity
    /// must be positive.
    pub fn new(cfg: PoolConfig, policy: Box<dyn ReplacementPolicy>) -> Result<BufferPool> {
        if cfg.capacity_bytes == 0 {
            return Err(CompileError::config("pool capacity must be positive"));
        }
        if let Some(q) = cfg.tenant_quota_bytes {
            if q == 0 {
                return Err(CompileError::config("tenant quota must be positive"));
            }
        }
        Ok(BufferPool {
            capacity_bytes: cfg.capacity_bytes,
            link: cfg.link,
            tenant_quota_bytes: cfg.tenant_quota_bytes,
            policy_name: policy.name(),
            inner: Mutex::new(Inner {
                resident: HashMap::new(),
                policy,
                used_bytes: 0,
                tenant_bytes: HashMap::new(),
                hits: 0,
                misses: 0,
                evictions: 0,
                bypasses: 0,
                overcommits: 0,
                quota_overruns: 0,
                peak_used_bytes: 0,
                active_cold_pins: 0,
                cold_ms: Vec::new(),
                cold_next: 0,
                cold_total_ms: 0.0,
                clock: Arc::new(RealClock::new()),
                trace: Arc::new(NullSink),
            }),
        })
    }

    /// Attach a trace sink (and the clock its timestamps come from):
    /// every pin then records a `pool/hit` instant or a `pool/cold_load`
    /// span whose duration is the modeled DRAM-fill time (annotated with
    /// the segment bytes and whether it bypassed residency), and every
    /// eviction a `pool/evict` instant with the victim's bytes. The
    /// trace thread id is the segment id.
    pub fn set_trace(&self, clock: Arc<dyn Clock>, trace: Arc<dyn TraceSink>) {
        let mut inner = self.lock();
        inner.clock = clock;
        inner.trace = trace;
    }

    /// Pin `seg` (a segment of `bytes` weight payload, requested by
    /// `tenant`) for the duration of the returned guard. A resident
    /// segment is a free hit; a miss pays the modeled DRAM-fill cost and
    /// may evict unpinned segments to make room. Segments larger than
    /// the whole pool bypass residency entirely.
    pub fn pin(&self, seg: SegmentId, bytes: u64, tenant: &str) -> PinGuard<'_> {
        let mut inner = self.lock();
        if bytes > self.capacity_bytes {
            // bypass: stream straight through, never resident
            inner.misses += 1;
            inner.bypasses += 1;
            inner.active_cold_pins += 1;
            let cold = self.link.transfer_ms(bytes);
            inner.record_cold(cold);
            if inner.trace.enabled() {
                inner.trace.record(
                    TraceEvent::span("pool", "cold_load", inner.clock.now_ms(), cold, seg.0)
                        .arg("bytes", bytes as f64)
                        .arg("bypass", 1.0),
                );
            }
            return PinGuard { pool: self, seg, hit: false, bypass: true, cold_load_ms: cold };
        }
        if let Some(r) = inner.resident.get_mut(&seg) {
            r.pins += 1;
            inner.policy.touch(seg);
            inner.hits += 1;
            if inner.trace.enabled() {
                inner.trace.record(
                    TraceEvent::instant("pool", "hit", inner.clock.now_ms(), seg.0)
                        .arg("bytes", bytes as f64),
                );
            }
            return PinGuard { pool: self, seg, hit: true, bypass: false, cold_load_ms: 0.0 };
        }
        inner.misses += 1;
        // quota: a tenant over budget makes room out of its own residency
        if let Some(quota) = self.tenant_quota_bytes {
            let over = |inner: &Inner| {
                inner.tenant_bytes.get(tenant).copied().unwrap_or(0) + bytes > quota
            };
            while over(&inner) {
                if !inner.evict_one(Some(tenant)) {
                    // everything of this tenant's is pinned (or gone):
                    // admit over quota rather than stall the request
                    inner.quota_overruns += 1;
                    break;
                }
            }
        }
        // capacity: evict by policy order; over-commit if all pinned
        while inner.used_bytes + bytes > self.capacity_bytes {
            if !inner.evict_one(None) {
                inner.overcommits += 1;
                break;
            }
        }
        inner
            .resident
            .insert(seg, Resident { bytes, pins: 1, tenant: tenant.to_string() });
        inner.policy.insert(seg);
        inner.used_bytes += bytes;
        *inner.tenant_bytes.entry(tenant.to_string()).or_insert(0) += bytes;
        inner.peak_used_bytes = inner.peak_used_bytes.max(inner.used_bytes);
        inner.active_cold_pins += 1;
        let cold = self.link.transfer_ms(bytes);
        inner.record_cold(cold);
        if inner.trace.enabled() {
            inner.trace.record(
                TraceEvent::span("pool", "cold_load", inner.clock.now_ms(), cold, seg.0)
                    .arg("bytes", bytes as f64)
                    .arg("bypass", 0.0),
            );
        }
        PinGuard { pool: self, seg, hit: false, bypass: false, cold_load_ms: cold }
    }

    /// Guard-drop path: release one pin and trim any over-commit that
    /// this release made collectable. `cold` pins (misses, bypasses
    /// included) also retire their in-flight cold-fill accounting;
    /// `bypass` pins were never resident, so only that accounting drops.
    fn release(&self, seg: SegmentId, bypass: bool, cold: bool) {
        let mut inner = self.lock();
        if cold {
            inner.active_cold_pins = inner.active_cold_pins.saturating_sub(1);
        }
        if bypass {
            return;
        }
        if let Some(r) = inner.resident.get_mut(&seg) {
            debug_assert!(r.pins > 0, "unpin of an unpinned segment");
            r.pins = r.pins.saturating_sub(1);
        }
        if inner.used_bytes > self.capacity_bytes {
            inner.trim(self.capacity_bytes);
        }
    }

    /// Point-in-time counters and residency snapshot.
    pub fn stats(&self) -> PoolStats {
        let inner = self.lock();
        let mut sorted = inner.cold_ms.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        PoolStats {
            policy: self.policy_name,
            capacity_bytes: self.capacity_bytes,
            used_bytes: inner.used_bytes,
            peak_used_bytes: inner.peak_used_bytes,
            resident_segments: inner.resident.len(),
            hits: inner.hits,
            misses: inner.misses,
            evictions: inner.evictions,
            bypasses: inner.bypasses,
            overcommits: inner.overcommits,
            quota_overruns: inner.quota_overruns,
            pending_cold_loads: inner.active_cold_pins,
            cold_load_p50_ms: percentile(&sorted, 50.0),
            cold_load_p95_ms: percentile(&sorted, 95.0),
            cold_load_total_ms: inner.cold_total_ms,
        }
    }

    /// Whether `seg` is currently resident (tests and diagnostics).
    pub fn contains(&self, seg: SegmentId) -> bool {
        self.lock().resident.contains_key(&seg)
    }

    /// Miss pins (bypasses included) whose guards are still alive — the
    /// modeled cold DRAM fills currently in flight. The serving engine's
    /// admission controller adds this to its queue depth via
    /// [`crate::engine::ExecutionBackend::queue_depth_hint`], so a burst
    /// of cold tenants produces backpressure before the queue itself
    /// fills.
    pub fn pending_cold_loads(&self) -> usize {
        self.lock().active_cold_pins
    }

    /// Currently resident bytes.
    pub fn used_bytes(&self) -> u64 {
        self.lock().used_bytes
    }

    /// The pool's byte budget.
    pub fn capacity_bytes(&self) -> u64 {
        self.capacity_bytes
    }

    /// Name of the replacement policy this pool was built with.
    pub fn policy_name(&self) -> &'static str {
        self.policy_name
    }

    /// The DRAM-fill link model misses are charged against.
    pub fn link(&self) -> LinkModel {
        self.link
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        // a panic while holding the lock leaves only counters possibly
        // stale; keep serving rather than poisoning every later request
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// RAII pin: the segment stays resident (never evicted) until the guard
/// drops. Produced by [`BufferPool::pin`].
pub struct PinGuard<'a> {
    pool: &'a BufferPool,
    seg: SegmentId,
    hit: bool,
    bypass: bool,
    cold_load_ms: f64,
}

impl PinGuard<'_> {
    /// The pinned segment.
    pub fn segment(&self) -> SegmentId {
        self.seg
    }

    /// Whether the pin found the segment already resident.
    pub fn hit(&self) -> bool {
        self.hit
    }

    /// Whether the segment bypassed the pool (larger than its capacity).
    pub fn bypassed(&self) -> bool {
        self.bypass
    }

    /// Modeled milliseconds spent filling DRAM for this pin (0 on a hit).
    pub fn cold_load_ms(&self) -> f64 {
        self.cold_load_ms
    }
}

impl Drop for PinGuard<'_> {
    fn drop(&mut self) {
        self.pool.release(self.seg, self.bypass, !self.hit);
    }
}

/// Counter snapshot of a [`BufferPool`], embedded in
/// [`crate::engine::EngineStats`] when the serving backend is pooled.
#[derive(Debug, Clone, PartialEq)]
pub struct PoolStats {
    /// Replacement policy name.
    pub policy: &'static str,
    /// Byte budget.
    pub capacity_bytes: u64,
    /// Bytes resident right now.
    pub used_bytes: u64,
    /// High-water residency (can exceed capacity during over-commit).
    pub peak_used_bytes: u64,
    /// Segments resident right now.
    pub resident_segments: usize,
    /// Pins that found the segment resident.
    pub hits: u64,
    /// Pins that paid a cold load (bypasses included).
    pub misses: u64,
    /// Segments dropped to make room.
    pub evictions: u64,
    /// Misses too large for the pool, streamed through unbuffered.
    pub bypasses: u64,
    /// Admissions past capacity because every resident segment was
    /// pinned (trimmed back as pins release).
    pub overcommits: u64,
    /// Admissions past a tenant's quota because none of its segments
    /// were evictable.
    pub quota_overruns: u64,
    /// Miss pins still held at snapshot time — modeled cold DRAM fills
    /// in flight (see [`BufferPool::pending_cold_loads`]).
    pub pending_cold_loads: usize,
    /// Median modeled cold-load latency, over a sliding window of the
    /// most recent misses (same window size as the serving engine's
    /// latency percentiles).
    pub cold_load_p50_ms: f64,
    /// 95th-percentile modeled cold-load latency.
    pub cold_load_p95_ms: f64,
    /// Total modeled milliseconds spent filling DRAM.
    pub cold_load_total_ms: f64,
}

impl PoolStats {
    /// Fraction of pins served without a cold load.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Flat JSON record (CLI `--json-out` and bench snapshots).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("policy", Json::str(self.policy)),
            ("capacity_bytes", Json::num(self.capacity_bytes as f64)),
            ("used_bytes", Json::num(self.used_bytes as f64)),
            ("peak_used_bytes", Json::num(self.peak_used_bytes as f64)),
            ("resident_segments", Json::num(self.resident_segments as f64)),
            ("hits", Json::num(self.hits as f64)),
            ("misses", Json::num(self.misses as f64)),
            ("hit_rate", Json::num(self.hit_rate())),
            ("evictions", Json::num(self.evictions as f64)),
            ("bypasses", Json::num(self.bypasses as f64)),
            ("overcommits", Json::num(self.overcommits as f64)),
            ("quota_overruns", Json::num(self.quota_overruns as f64)),
            ("pending_cold_loads", Json::num(self.pending_cold_loads as f64)),
            ("cold_load_p50_ms", Json::num(self.cold_load_p50_ms)),
            ("cold_load_p95_ms", Json::num(self.cold_load_p95_ms)),
            ("cold_load_total_ms", Json::num(self.cold_load_total_ms)),
        ])
    }
}

/// Nearest-rank percentile over an ascending-sorted sample (0.0 when
/// empty) — same convention as the serving engine's latency percentiles.
fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::super::policy_by_name;
    use super::*;

    fn pool(capacity: u64, policy: &str) -> BufferPool {
        // infinite-bandwidth link: cold cost is the 5 us setup only,
        // keeping the latency arithmetic in tests exact
        let cfg = PoolConfig::new(capacity)
            .with_link(LinkModel::new(f64::INFINITY, 5.0).unwrap());
        BufferPool::new(cfg, policy_by_name(policy).unwrap()).unwrap()
    }

    fn id(n: u64) -> SegmentId {
        SegmentId(n)
    }

    #[test]
    fn hits_are_free_and_misses_pay_the_link() {
        let p = pool(100, "lru");
        let g = p.pin(id(1), 60, "t");
        assert!(!g.hit());
        assert_eq!(g.cold_load_ms(), 0.005);
        drop(g);
        let g = p.pin(id(1), 60, "t");
        assert!(g.hit());
        assert_eq!(g.cold_load_ms(), 0.0);
        drop(g);
        let s = p.stats();
        assert_eq!((s.hits, s.misses, s.evictions), (1, 1, 0));
        assert_eq!(s.used_bytes, 60);
        assert_eq!(s.resident_segments, 1);
    }

    #[test]
    fn pinned_segments_are_never_evicted() {
        let p = pool(100, "lru");
        let hold = p.pin(id(1), 60, "t");
        // needs 60 more: id(1) is the only candidate but it is pinned,
        // so the pool over-commits instead of evicting it
        let g2 = p.pin(id(2), 60, "t");
        assert!(p.contains(id(1)), "pinned segment evicted");
        assert_eq!(p.used_bytes(), 120);
        let s = p.stats();
        assert_eq!(s.evictions, 0);
        assert_eq!(s.overcommits, 1);
        assert_eq!(s.peak_used_bytes, 120);
        // releasing the over-committed state trims back under budget
        drop(g2);
        drop(hold);
        assert!(p.used_bytes() <= 100, "trim did not restore the budget");
        assert_eq!(p.stats().evictions, 1);
    }

    #[test]
    fn eviction_follows_policy_order() {
        let p = pool(100, "lru");
        drop(p.pin(id(1), 40, "t"));
        drop(p.pin(id(2), 40, "t"));
        drop(p.pin(id(1), 40, "t")); // 1 is now MRU
        drop(p.pin(id(3), 40, "t")); // must evict 2, the LRU
        assert!(p.contains(id(1)));
        assert!(!p.contains(id(2)));
        assert!(p.contains(id(3)));
        assert_eq!(p.stats().evictions, 1);
    }

    #[test]
    fn oversized_segments_bypass_the_pool() {
        let p = pool(100, "clock");
        drop(p.pin(id(1), 80, "t"));
        let g = p.pin(id(9), 1000, "t");
        assert!(g.bypassed());
        assert!(!g.hit());
        assert!(g.cold_load_ms() > 0.0);
        drop(g);
        // the resident segment was untouched and the giant never admitted
        assert!(p.contains(id(1)));
        assert!(!p.contains(id(9)));
        let s = p.stats();
        assert_eq!(s.bypasses, 1);
        assert_eq!(s.evictions, 0);
        assert_eq!(s.used_bytes, 80);
    }

    #[test]
    fn tenant_quota_evicts_own_segments_first() {
        let cfg = PoolConfig::new(200)
            .with_link(LinkModel::new(f64::INFINITY, 0.0).unwrap())
            .with_tenant_quota(80);
        let p = BufferPool::new(cfg, policy_by_name("lru").unwrap()).unwrap();
        drop(p.pin(id(1), 40, "alice")); // alice's oldest
        drop(p.pin(id(2), 40, "alice"));
        drop(p.pin(id(3), 40, "bob"));
        // alice asks for 40 more: pool has room (120/200) but alice is at
        // her 80-byte quota — her own LRU (1) must go, not bob's segment
        drop(p.pin(id(4), 40, "alice"));
        assert!(!p.contains(id(1)), "quota must evict the owner's LRU");
        assert!(p.contains(id(2)));
        assert!(p.contains(id(3)), "quota eviction stole from another tenant");
        assert!(p.contains(id(4)));
        assert_eq!(p.stats().quota_overruns, 0);
        // all of alice's residency pinned -> over-quota admission, counted
        let _g2 = p.pin(id(2), 40, "alice");
        let _g4 = p.pin(id(4), 40, "alice");
        let g5 = p.pin(id(5), 40, "alice");
        assert!(!g5.bypassed());
        assert_eq!(p.stats().quota_overruns, 1);
    }

    #[test]
    fn refcounts_balance_under_threads() {
        let p = std::sync::Arc::new(pool(120, "slru"));
        let threads: Vec<_> = (0..4)
            .map(|t| {
                let p = p.clone();
                std::thread::spawn(move || {
                    for i in 0..200u64 {
                        let seg = id((t + i) % 6);
                        let g = p.pin(seg, 40, "t");
                        assert!(p.contains(seg) || g.bypassed());
                        drop(g);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        // every pin released: nothing left pinned, pool within budget
        let s = p.stats();
        assert_eq!(s.hits + s.misses, 800);
        assert!(s.used_bytes <= 120, "over-commit survived all releases");
        let inner = p.lock();
        assert!(inner.resident.values().all(|r| r.pins == 0), "leaked pin");
    }

    #[test]
    fn pending_cold_loads_track_live_miss_pins() {
        let p = pool(100, "lru");
        assert_eq!(p.pending_cold_loads(), 0);
        let cold = p.pin(id(1), 60, "t");
        assert_eq!(p.pending_cold_loads(), 1, "a held miss pin is a cold fill in flight");
        let hit = p.pin(id(1), 60, "t");
        assert_eq!(p.pending_cold_loads(), 1, "hits never count as cold load");
        let bypass = p.pin(id(9), 1000, "t");
        assert_eq!(p.pending_cold_loads(), 2, "bypasses are cold fills too");
        assert_eq!(p.stats().pending_cold_loads, 2);
        drop(hit);
        drop(bypass);
        drop(cold);
        assert_eq!(p.pending_cold_loads(), 0, "released pins retire their fills");
        assert_eq!(p.stats().pending_cold_loads, 0);
    }

    #[test]
    fn zero_capacity_and_zero_quota_are_rejected() {
        assert!(BufferPool::new(PoolConfig::new(0), policy_by_name("lru").unwrap()).is_err());
        let cfg = PoolConfig::new(10).with_tenant_quota(0);
        assert!(BufferPool::new(cfg, policy_by_name("lru").unwrap()).is_err());
    }

    #[test]
    fn trace_records_pool_lifecycle() {
        use crate::engine::VirtualClock;
        use crate::telemetry::TraceRecorder;
        let p = pool(100, "lru");
        let rec = std::sync::Arc::new(TraceRecorder::new());
        p.set_trace(std::sync::Arc::new(VirtualClock::new()), rec.clone());
        drop(p.pin(id(1), 60, "t")); // cold load
        drop(p.pin(id(1), 60, "t")); // hit
        drop(p.pin(id(2), 60, "t")); // cold load + evicts 1
        drop(p.pin(id(9), 1000, "t")); // bypass cold load
        let evs = rec.events();
        assert_eq!(evs.iter().filter(|e| e.name == "cold_load").count(), 3);
        assert_eq!(evs.iter().filter(|e| e.name == "hit").count(), 1);
        assert_eq!(evs.iter().filter(|e| e.name == "evict").count(), 1);
        assert!(evs.iter().all(|e| e.cat == "pool"));
        let bypassed: Vec<f64> = evs
            .iter()
            .filter(|e| e.name == "cold_load")
            .map(|e| e.args.iter().find(|(k, _)| *k == "bypass").unwrap().1)
            .collect();
        assert_eq!(bypassed.iter().filter(|&&b| b == 1.0).count(), 1);
    }

    #[test]
    fn stats_json_is_flat_and_complete() {
        let p = pool(100, "lru");
        drop(p.pin(id(1), 60, "t"));
        drop(p.pin(id(1), 60, "t"));
        let doc = p.stats().to_json();
        assert_eq!(doc.get("policy").and_then(Json::as_str), Some("lru"));
        assert_eq!(doc.get("hits").and_then(Json::as_f64), Some(1.0));
        assert_eq!(doc.get("hit_rate").and_then(Json::as_f64), Some(0.5));
        assert!(doc.get("cold_load_p50_ms").and_then(Json::as_f64).unwrap() > 0.0);
    }
}
