//! Multi-tenant device-DRAM buffer pool for packed-program weights.
//!
//! ShortcutFusion's compile-time story is reuse-aware *static* allocation
//! of on-chip SRAM; the serving stack meets the same capacity problem one
//! level up. A multi-tenant deployment wants to serve a whole model zoo,
//! but device DRAM cannot hold every packed [`crate::program::Program`]'s
//! weights at once. This subsystem pages weight *segments* (one per
//! program: quantized weights + instruction stream) in and out of a
//! modeled DRAM budget on demand:
//!
//! * [`BufferPool`] — `pin`/`unpin` with reference counting over a byte
//!   budget. Weights are read-only, so eviction is *dirty-free*: dropping
//!   a segment never writes anything back. A pinned segment is never
//!   evicted; a request for a non-resident segment pays a modeled
//!   cold-load cost (DRAM-fill bytes over a [`crate::shard::LinkModel`]
//!   channel, the same idiom shard hand-offs use).
//! * [`ReplacementPolicy`] — pluggable eviction ordering
//!   ([`LruPolicy`], [`ClockPolicy`], scan-resistant
//!   [`SegmentedLruPolicy`]), chosen by name via [`policy_by_name`].
//! * Per-tenant admission quotas — a hot tenant past its byte quota
//!   evicts its *own* unpinned segments first, so it cannot thrash other
//!   tenants out of the pool.
//! * [`PooledBackend`] — integrates the pool beneath
//!   [`crate::engine::InferenceEngine`] by wrapping any
//!   [`crate::engine::ExecutionBackend`] (sharded included): each request
//!   pins its program's segment around execution and reports the cold
//!   cost in [`crate::engine::RunResult::cold_load_ms`].
//!
//! The pool never blocks and never fails a request: when every resident
//! segment is pinned and capacity is exhausted, it admits the new segment
//! as a *transient over-commit* (counted in [`PoolStats`]) rather than
//! deadlocking the serving workers — trimmed back under budget as soon as
//! pins release. Segments larger than the whole pool bypass it entirely
//! (always a miss, never resident).
//!
//! ```no_run
//! use std::sync::Arc;
//! use shortcutfusion::engine::{ExecutionBackend, ReferenceBackend};
//! use shortcutfusion::pool::{policy_by_name, BufferPool, PoolConfig, PooledBackend};
//!
//! let pool = Arc::new(
//!     BufferPool::new(PoolConfig::new(24 << 20), policy_by_name("slru").unwrap()).unwrap(),
//! );
//! // one PooledBackend per tenant, all sharing the pool
//! let alice = PooledBackend::new(Arc::new(ReferenceBackend), pool.clone(), "alice");
//! let bob = PooledBackend::new(Arc::new(ReferenceBackend), pool.clone(), "bob");
//! # let _ = (alice, bob);
//! println!("{}", pool.stats().to_json().to_string_pretty());
//! ```

mod backend;
mod buffer;
mod policy;

pub use backend::PooledBackend;
pub use buffer::{BufferPool, PinGuard, PoolConfig, PoolStats};
pub use policy::{
    policy_by_name, ClockPolicy, LruPolicy, ReplacementPolicy, SegmentedLruPolicy, POLICY_NAMES,
};

/// Identity of one pageable weight segment: the owning program's
/// [`crate::program::Program::fingerprint`]. Two handles to byte-identical
/// artifacts share a segment; re-pinning a resident id is a pool hit.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SegmentId(pub u64);

impl std::fmt::Display for SegmentId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:016x}", self.0)
    }
}
