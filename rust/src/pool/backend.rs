//! [`PooledBackend`]: the pool's integration point with the engine.

use std::sync::Arc;

use crate::engine::{ExecutionBackend, RunResult};
use crate::funcsim::Tensor;
use crate::program::Program;
use crate::Result;

use super::{BufferPool, PoolStats, SegmentId};

/// An [`ExecutionBackend`] decorator that pages each served program's
/// weight segment through a shared [`BufferPool`] before delegating to
/// the wrapped backend (reference, virtual-accel, sharded — anything).
///
/// One `PooledBackend` represents one *tenant*: construct one per tenant
/// over the same `Arc<BufferPool>` and the pool arbitrates capacity (and
/// quotas) between them. The segment stays pinned for the duration of
/// each request — a pinned segment is never evicted — and the modeled
/// DRAM-fill cost of a miss is reported in
/// [`RunResult::cold_load_ms`] (0 on a hit). A batch pins its program
/// once: the first result in the batch carries the cold cost, the rest
/// ran against the already-resident segment.
pub struct PooledBackend {
    inner: Arc<dyn ExecutionBackend>,
    pool: Arc<BufferPool>,
    tenant: String,
}

impl PooledBackend {
    /// Wrap `inner` so its programs page through `pool`, attributed to
    /// `tenant` for quota accounting.
    pub fn new(
        inner: Arc<dyn ExecutionBackend>,
        pool: Arc<BufferPool>,
        tenant: impl Into<String>,
    ) -> PooledBackend {
        PooledBackend { inner, pool, tenant: tenant.into() }
    }

    /// The shared pool this tenant serves through.
    pub fn pool(&self) -> &Arc<BufferPool> {
        &self.pool
    }

    /// The tenant name used for quota accounting.
    pub fn tenant(&self) -> &str {
        &self.tenant
    }

    /// The segment identity the pool tracks for `program`.
    pub fn segment_of(program: &Program) -> SegmentId {
        SegmentId(program.fingerprint())
    }
}

impl ExecutionBackend for PooledBackend {
    fn name(&self) -> &'static str {
        "pooled"
    }

    fn run(&self, program: &Program, input: &Tensor) -> Result<RunResult> {
        let guard =
            self.pool.pin(Self::segment_of(program), program.resident_bytes(), &self.tenant);
        let mut r = self.inner.run(program, input)?;
        r.cold_load_ms = Some(guard.cold_load_ms() + r.cold_load_ms.unwrap_or(0.0));
        Ok(r)
    }

    fn run_batch(&self, program: &Program, inputs: &[Tensor]) -> Vec<Result<RunResult>> {
        let guard =
            self.pool.pin(Self::segment_of(program), program.resident_bytes(), &self.tenant);
        let mut cold = guard.cold_load_ms();
        self.inner
            .run_batch(program, inputs)
            .into_iter()
            .map(|res| {
                res.map(|mut r| {
                    // the batch shares one pin: only its first completed
                    // request pays the fill, the rest hit the residency
                    r.cold_load_ms = Some(cold + r.cold_load_ms.unwrap_or(0.0));
                    cold = 0.0;
                    r
                })
            })
            .collect()
    }

    fn pool_stats(&self) -> Option<PoolStats> {
        Some(self.pool.stats())
    }

    fn queue_depth_hint(&self) -> usize {
        // cold fills in flight are device-side work the engine's queue
        // cannot see: report them so admission tightens under cold bursts
        self.pool.pending_cold_loads()
    }
}

#[cfg(test)]
mod tests {
    use super::super::{policy_by_name, PoolConfig};
    use super::*;
    use crate::engine::{ReferenceBackend, VirtualAccelBackend};
    use crate::zoo;

    fn pooled(capacity: u64, inner: Arc<dyn ExecutionBackend>) -> PooledBackend {
        let pool = Arc::new(
            BufferPool::new(PoolConfig::new(capacity), policy_by_name("lru").unwrap()).unwrap(),
        );
        PooledBackend::new(inner, pool, "test")
    }

    #[test]
    fn first_run_is_cold_then_hits_are_free() {
        let program = crate::testutil::pack_program(&zoo::tinynet(), Some(7));
        let input = Tensor::zeros(program.input_shape());
        let b = pooled(program.resident_bytes() * 2, Arc::new(ReferenceBackend));
        let first = b.run(&program, &input).unwrap();
        assert!(first.cold_load_ms.unwrap() > 0.0, "miss must pay the DRAM fill");
        let second = b.run(&program, &input).unwrap();
        assert_eq!(second.cold_load_ms, Some(0.0), "resident hit must be free");
        // pooling is transparent to what the inner backend computes
        assert_eq!(first.output, second.output);
        let s = b.pool_stats().unwrap();
        assert_eq!((s.hits, s.misses), (1, 1));
    }

    #[test]
    fn batches_share_one_pin() {
        let program = crate::testutil::pack_program(&zoo::tinynet(), None);
        let inputs = vec![Tensor::zeros(program.input_shape()); 3];
        let b = pooled(program.resident_bytes() * 2, Arc::new(VirtualAccelBackend));
        let results = b.run_batch(&program, &inputs);
        let colds: Vec<f64> =
            results.iter().map(|r| r.as_ref().unwrap().cold_load_ms.unwrap()).collect();
        assert!(colds[0] > 0.0);
        assert_eq!(&colds[1..], &[0.0, 0.0], "only the batch head pays the fill");
        let s = b.pool_stats().unwrap();
        assert_eq!((s.hits, s.misses), (0, 1), "one pin for the whole batch");
    }

    #[test]
    fn inner_errors_pass_through_and_release_the_pin() {
        // no packed params: the reference backend fails typed
        let program = crate::testutil::pack_program(&zoo::tinynet(), None);
        let input = Tensor::zeros(program.input_shape());
        let b = pooled(program.resident_bytes() * 2, Arc::new(ReferenceBackend));
        assert!(b.run(&program, &input).is_err());
        // the failed request's pin was still released (evictable again)
        let s = b.pool_stats().unwrap();
        assert_eq!(s.misses, 1);
        assert!(b.pool().contains(PooledBackend::segment_of(&program)));
    }
}
