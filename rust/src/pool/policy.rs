//! Pluggable replacement policies for the device-DRAM buffer pool.
//!
//! The pool tells a policy which segments become resident, which resident
//! segments are re-pinned (hits), and which leave the pool; when capacity
//! pressure demands an eviction, the pool asks the policy for a *victim*
//! among the segments an `evictable` predicate accepts (unpinned ones,
//! optionally restricted to one tenant for quota enforcement). Policies
//! never see pin counts or byte sizes — residency bookkeeping stays in
//! [`super::BufferPool`], the policy only orders candidates.
//!
//! Three policies ship, mirroring the classic buffer-manager trio:
//!
//! | name | behaviour |
//! |---|---|
//! | `lru` | strict recency order |
//! | `clock` | second-chance approximation of LRU (reference bits + sweep hand) |
//! | `slru` | segmented LRU: scan-resistant two-queue (probation → protected) |
//!
//! All bookkeeping is `O(resident segments)` per operation — the pool
//! tracks whole model-weight segments (a model zoo has tens to hundreds
//! of entries), not 4 KB pages, so constant-factor simplicity beats
//! intrusive-list cleverness here.

use super::SegmentId;

/// Eviction-ordering strategy of a [`super::BufferPool`].
///
/// Implementations must be `Send`: the pool shares one policy instance
/// across serving threads behind its internal mutex.
pub trait ReplacementPolicy: Send {
    /// Stable registry name (`"lru"`, `"clock"`, `"slru"`).
    fn name(&self) -> &'static str;

    /// A segment just became resident (always followed by eventual
    /// [`ReplacementPolicy::remove`] or pool drop).
    fn insert(&mut self, seg: SegmentId);

    /// A resident segment was pinned again (a pool hit).
    fn touch(&mut self, seg: SegmentId);

    /// A segment left the pool (evicted or invalidated). Unknown ids are
    /// ignored.
    fn remove(&mut self, seg: SegmentId);

    /// Choose the next eviction victim among tracked segments for which
    /// `evictable` returns true, or `None` when no tracked segment
    /// qualifies. The pool removes the victim itself (via
    /// [`ReplacementPolicy::remove`]), so `victim` must not.
    fn victim(&mut self, evictable: &dyn Fn(SegmentId) -> bool) -> Option<SegmentId>;
}

/// Strict least-recently-used ordering.
#[derive(Default)]
pub struct LruPolicy {
    /// Recency queue, front = least recently used.
    order: Vec<SegmentId>,
}

impl LruPolicy {
    /// An empty LRU policy.
    pub fn new() -> LruPolicy {
        LruPolicy::default()
    }
}

impl ReplacementPolicy for LruPolicy {
    fn name(&self) -> &'static str {
        "lru"
    }

    fn insert(&mut self, seg: SegmentId) {
        self.order.retain(|&s| s != seg);
        self.order.push(seg);
    }

    fn touch(&mut self, seg: SegmentId) {
        if let Some(pos) = self.order.iter().position(|&s| s == seg) {
            self.order.remove(pos);
            self.order.push(seg);
        }
    }

    fn remove(&mut self, seg: SegmentId) {
        self.order.retain(|&s| s != seg);
    }

    fn victim(&mut self, evictable: &dyn Fn(SegmentId) -> bool) -> Option<SegmentId> {
        self.order.iter().copied().find(|&s| evictable(s))
    }
}

/// Second-chance (clock) approximation of LRU: each resident segment has
/// a reference bit set on access; the sweep hand clears bits until it
/// finds an evictable segment whose bit is already clear.
#[derive(Default)]
pub struct ClockPolicy {
    entries: Vec<(SegmentId, bool)>,
    hand: usize,
}

impl ClockPolicy {
    /// An empty clock policy.
    pub fn new() -> ClockPolicy {
        ClockPolicy::default()
    }
}

impl ReplacementPolicy for ClockPolicy {
    fn name(&self) -> &'static str {
        "clock"
    }

    fn insert(&mut self, seg: SegmentId) {
        self.remove(seg);
        // new segments enter with the reference bit set: they survive one
        // full sweep, matching the grace a fresh LRU insertion gets
        self.entries.push((seg, true));
    }

    fn touch(&mut self, seg: SegmentId) {
        if let Some(e) = self.entries.iter_mut().find(|(s, _)| *s == seg) {
            e.1 = true;
        }
    }

    fn remove(&mut self, seg: SegmentId) {
        if let Some(pos) = self.entries.iter().position(|(s, _)| *s == seg) {
            self.entries.remove(pos);
            if pos < self.hand {
                self.hand -= 1;
            }
            if !self.entries.is_empty() {
                self.hand %= self.entries.len();
            } else {
                self.hand = 0;
            }
        }
    }

    fn victim(&mut self, evictable: &dyn Fn(SegmentId) -> bool) -> Option<SegmentId> {
        if !self.entries.iter().any(|&(s, _)| evictable(s)) {
            return None;
        }
        // one pass may only clear bits; a second pass over (at most) the
        // same entries must then find a clear evictable bit
        let n = self.entries.len();
        for _ in 0..2 * n + 1 {
            let i = self.hand % n;
            let (seg, referenced) = &mut self.entries[i];
            let seg = *seg;
            if !evictable(seg) {
                self.hand = (i + 1) % n;
                continue;
            }
            if *referenced {
                *referenced = false;
                self.hand = (i + 1) % n;
                continue;
            }
            self.hand = (i + 1) % n;
            return Some(seg);
        }
        unreachable!("an evictable entry exists, so two sweeps must find one")
    }
}

/// Segmented LRU (scan-resistant): first-touch segments sit in a
/// *probation* queue; only a second access promotes them to *protected*.
/// Victims come from probation first, so a one-touch scan stream evicts
/// itself and cannot displace the multi-touch working set — the crossover
/// against plain LRU that `benches/pool.rs` measures.
#[derive(Default)]
pub struct SegmentedLruPolicy {
    /// One-touch residents, front = least recently used.
    probation: Vec<SegmentId>,
    /// Multi-touch residents, front = least recently used.
    protected: Vec<SegmentId>,
}

impl SegmentedLruPolicy {
    /// An empty segmented-LRU policy.
    pub fn new() -> SegmentedLruPolicy {
        SegmentedLruPolicy::default()
    }

    /// Protected may hold at most two thirds of the tracked segments;
    /// beyond that the protected LRU is demoted back to probation (as
    /// its most-recent entry), keeping room for new arrivals to prove
    /// themselves.
    fn protected_cap(&self) -> usize {
        let total = self.probation.len() + self.protected.len();
        (2 * total / 3).max(1)
    }
}

impl ReplacementPolicy for SegmentedLruPolicy {
    fn name(&self) -> &'static str {
        "slru"
    }

    fn insert(&mut self, seg: SegmentId) {
        self.remove(seg);
        self.probation.push(seg);
    }

    fn touch(&mut self, seg: SegmentId) {
        if let Some(pos) = self.protected.iter().position(|&s| s == seg) {
            self.protected.remove(pos);
            self.protected.push(seg);
            return;
        }
        if let Some(pos) = self.probation.iter().position(|&s| s == seg) {
            self.probation.remove(pos);
            self.protected.push(seg);
            while self.protected.len() > self.protected_cap() {
                let demoted = self.protected.remove(0);
                self.probation.push(demoted);
            }
        }
    }

    fn remove(&mut self, seg: SegmentId) {
        self.probation.retain(|&s| s != seg);
        self.protected.retain(|&s| s != seg);
    }

    fn victim(&mut self, evictable: &dyn Fn(SegmentId) -> bool) -> Option<SegmentId> {
        self.probation
            .iter()
            .copied()
            .find(|&s| evictable(s))
            .or_else(|| self.protected.iter().copied().find(|&s| evictable(s)))
    }
}

/// Policy registry names accepted by [`policy_by_name`] (and the CLI's
/// `--policy` flag).
pub const POLICY_NAMES: &[&str] = &["lru", "clock", "slru"];

/// Construct a policy from its registry name (`"segmented-lru"` is
/// accepted as an alias for `"slru"`).
pub fn policy_by_name(name: &str) -> Option<Box<dyn ReplacementPolicy>> {
    Some(match name {
        "lru" => Box::new(LruPolicy::new()),
        "clock" => Box::new(ClockPolicy::new()),
        "slru" | "segmented-lru" => Box::new(SegmentedLruPolicy::new()),
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn id(n: u64) -> SegmentId {
        SegmentId(n)
    }

    fn any(_: SegmentId) -> bool {
        true
    }

    #[test]
    fn lru_evicts_in_recency_order() {
        let mut p = LruPolicy::new();
        for n in 1..=3 {
            p.insert(id(n));
        }
        p.touch(id(1)); // order is now 2, 3, 1
        assert_eq!(p.victim(&any), Some(id(2)));
        p.remove(id(2));
        assert_eq!(p.victim(&any), Some(id(3)));
        // a pinned (non-evictable) head is skipped, not returned
        assert_eq!(p.victim(&|s| s != id(3)), Some(id(1)));
        assert_eq!(p.victim(&|_| false), None);
    }

    #[test]
    fn clock_gives_second_chances() {
        let mut p = ClockPolicy::new();
        for n in 1..=3 {
            p.insert(id(n));
        }
        // every entry enters referenced: the first sweep clears 1..3 and
        // the second pass picks 1, the oldest unreferenced entry
        assert_eq!(p.victim(&any), Some(id(1)));
        p.remove(id(1));
        // touching 2 re-arms its bit, so 3 (cleared above) goes first
        p.touch(id(2));
        assert_eq!(p.victim(&any), Some(id(3)));
        assert_eq!(p.victim(&|_| false), None);
    }

    #[test]
    fn clock_hand_survives_removals() {
        let mut p = ClockPolicy::new();
        for n in 1..=4 {
            p.insert(id(n));
        }
        let v = p.victim(&any).unwrap();
        p.remove(v);
        // removing entries before/after the hand must keep it in bounds
        p.remove(id(4));
        p.remove(id(2));
        let survivor = p.victim(&any).unwrap();
        assert!(survivor == id(1) || survivor == id(3));
    }

    #[test]
    fn slru_protects_multi_touch_segments_from_scans() {
        let mut p = SegmentedLruPolicy::new();
        // hot pair, touched twice -> protected
        p.insert(id(1));
        p.insert(id(2));
        p.touch(id(1));
        p.touch(id(2));
        // scan stream: one-touch entries stay probationary
        p.insert(id(10));
        p.insert(id(11));
        // victims drain the scan before ever considering the hot pair
        assert_eq!(p.victim(&any), Some(id(10)));
        p.remove(id(10));
        assert_eq!(p.victim(&any), Some(id(11)));
        p.remove(id(11));
        // only then does the protected LRU become the victim
        assert_eq!(p.victim(&any), Some(id(1)));
    }

    #[test]
    fn slru_demotes_when_protected_overflows() {
        let mut p = SegmentedLruPolicy::new();
        for n in 1..=3 {
            p.insert(id(n));
            p.touch(id(n)); // all promoted
        }
        // 3 tracked, protected cap = 2 -> the protected LRU (1) was
        // demoted back to probation and is the preferred victim
        assert_eq!(p.victim(&any), Some(id(1)));
    }

    #[test]
    fn registry_resolves_names() {
        for &n in POLICY_NAMES {
            let p = policy_by_name(n).unwrap_or_else(|| panic!("{n}"));
            assert_eq!(p.name(), n);
        }
        assert_eq!(policy_by_name("segmented-lru").unwrap().name(), "slru");
        assert!(policy_by_name("bogus").is_none());
    }

    #[test]
    fn policies_tolerate_unknown_ids() {
        for &n in POLICY_NAMES {
            let mut p = policy_by_name(n).unwrap();
            p.touch(id(99));
            p.remove(id(99));
            assert_eq!(p.victim(&any), None, "{n}: empty policy has no victim");
            p.insert(id(1));
            p.insert(id(1)); // double insert collapses to one entry
            assert_eq!(p.victim(&any), Some(id(1)), "{n}");
            p.remove(id(1));
            assert_eq!(p.victim(&any), None, "{n}");
        }
    }
}
