//! Chained execution of a sharded program pipeline.

use std::sync::Arc;

use super::{Clock, ExecutionBackend, RealClock, RunResult};
use crate::compiler::CompileError;
use crate::funcsim::Tensor;
use crate::graph::Shape;
use crate::program::Program;
use crate::shard::LinkModel;
use crate::telemetry::{NullSink, TraceEvent, TraceSink};
use crate::Result;

/// Executes a [`crate::shard::ShardPlan`]'s programs as one pipeline:
/// every request runs through all K shards in order, with a staged
/// hand-off buffer between consecutive stages that enforces the packed
/// ingress/egress tensor descriptors before the tensor crosses the
/// (modeled) inter-device link.
///
/// The chain is itself an [`ExecutionBackend`], so an
/// [`crate::engine::InferenceEngine`] serves a sharded model
/// transparently — construct the engine with the **first shard's
/// program** (see [`ShardedBackend::front`]) and this backend:
///
/// * through [`super::ReferenceBackend`], each stage's output tensor is
///   carried to the next stage — the chain is bit-identical to running
///   the unsharded model;
/// * through [`super::VirtualAccelBackend`], per-stage model latencies
///   and DRAM bytes are summed and each hand-off adds the link-model
///   transfer time, reproducing the partitioner's analytical pipeline
///   cost exactly.
pub struct ShardedBackend {
    stages: Vec<Arc<Program>>,
    backend: Arc<dyn ExecutionBackend>,
    link: LinkModel,
    clock: Arc<dyn Clock>,
    trace: Arc<dyn TraceSink>,
}

impl ShardedBackend {
    /// Build the chain, validating it end to end: programs must be in
    /// pipeline order, any packed [`crate::program::ShardBoundary`]
    /// records must match their position and total, and each stage's
    /// output shape must equal the next stage's input feed.
    pub fn new(
        stages: Vec<Arc<Program>>,
        backend: Arc<dyn ExecutionBackend>,
        link: LinkModel,
    ) -> Result<ShardedBackend> {
        if stages.is_empty() {
            return Err(CompileError::config("sharded backend needs at least one shard"));
        }
        for (i, p) in stages.iter().enumerate() {
            if let Some(b) = p.boundary() {
                if b.count != stages.len() || b.index != i {
                    return Err(CompileError::artifact(format!(
                        "{}: packed as shard {}/{} but chained at position {}/{}",
                        p.model(),
                        b.index + 1,
                        b.count,
                        i + 1,
                        stages.len()
                    )));
                }
            }
        }
        for pair in stages.windows(2) {
            let out = chain_output_shape(&pair[0]);
            let want = pair[1].input_shape();
            if out != want {
                return Err(CompileError::artifact(format!(
                    "hand-off mismatch: {} emits {} but {} expects {}",
                    pair[0].model(),
                    out,
                    pair[1].model(),
                    want
                )));
            }
        }
        Ok(ShardedBackend {
            stages,
            backend,
            link,
            clock: Arc::new(RealClock::new()),
            trace: Arc::new(NullSink),
        })
    }

    /// Attach a trace sink (and the clock its timestamps come from).
    /// Each request then records one `shard/stage` span per stage — the
    /// span duration is the *modeled* stage latency — and one
    /// `shard/handoff` instant per link crossing, annotated with the
    /// hand-off bytes and transfer milliseconds.
    pub fn with_trace(
        mut self,
        clock: Arc<dyn Clock>,
        trace: Arc<dyn TraceSink>,
    ) -> ShardedBackend {
        self.clock = clock;
        self.trace = trace;
        self
    }

    /// The first shard's program — what an
    /// [`crate::engine::InferenceEngine`] serving this chain must be
    /// constructed with.
    pub fn front(&self) -> &Arc<Program> {
        &self.stages[0]
    }

    /// Number of pipeline stages.
    pub fn stage_count(&self) -> usize {
        self.stages.len()
    }

    /// Hand-off size after stage `i`, in bytes at the producing device's
    /// feature-map precision.
    fn handoff_bytes(&self, i: usize) -> u64 {
        let p = &self.stages[i];
        chain_output_shape(p).bytes(p.cfg().qa) as u64
    }
}

/// The tensor a program forwards downstream: its packed egress
/// descriptor when sharded, otherwise the final node's output.
fn chain_output_shape(p: &Program) -> Shape {
    p.boundary()
        .and_then(|b| b.egress.as_ref())
        .map(|t| t.shape)
        .unwrap_or_else(|| {
            p.grouped().graph.nodes.last().expect("graphs are non-empty").out_shape
        })
}

impl ExecutionBackend for ShardedBackend {
    fn name(&self) -> &'static str {
        "sharded"
    }

    fn run(&self, program: &Program, input: &Tensor) -> Result<RunResult> {
        let front = self.front();
        // the engine hands back the program it serves — accept exactly
        // the chain's own first shard (pointer fast path, then content)
        if !std::ptr::eq(program, front.as_ref())
            && (program.model() != front.model()
                || program.stream().words != front.stream().words)
        {
            return Err(CompileError::Exec(format!(
                "sharded backend serves its own chain (first shard {}); got program {}",
                front.model(),
                program.model()
            )));
        }

        let mut result = self.backend.run(front, input)?;
        if self.trace.enabled() {
            self.trace.record(
                TraceEvent::span(
                    "shard",
                    "stage",
                    self.clock.now_ms(),
                    result.model_latency_ms.unwrap_or(0.0),
                    0,
                )
                .arg("dram_bytes", result.dram_bytes.unwrap_or(0) as f64),
            );
        }
        let mut latency = result.model_latency_ms;
        let mut dram = result.dram_bytes;
        let mut cold = result.cold_load_ms;
        let mut classes = result.traffic_classes;
        for i in 1..self.stages.len() {
            // inter-device transfer of the hand-off tensor
            let transfer = self.link.transfer_ms(self.handoff_bytes(i - 1));
            latency = latency.map(|ms| ms + transfer);
            if self.trace.enabled() {
                self.trace.record(
                    TraceEvent::instant("shard", "handoff", self.clock.now_ms(), i as u64)
                        .arg("bytes", self.handoff_bytes(i - 1) as f64)
                        .arg("transfer_ms", transfer),
                );
            }

            // staged hand-off buffer: the carried tensor must match the
            // next stage's ingress descriptor; cost-only backends carry
            // no values, so the buffer stages a zero tensor of the
            // declared shape instead
            let stage = &self.stages[i];
            let carried = match result.output.take() {
                Some(t) => {
                    if t.shape != stage.input_shape() {
                        return Err(CompileError::Exec(format!(
                            "hand-off into {} carries {} but the ingress descriptor \
                             declares {}",
                            stage.model(),
                            t.shape,
                            stage.input_shape()
                        )));
                    }
                    t
                }
                None => Tensor::zeros(stage.input_shape()),
            };
            result = self.backend.run(stage, &carried)?;
            if self.trace.enabled() {
                self.trace.record(
                    TraceEvent::span(
                        "shard",
                        "stage",
                        self.clock.now_ms(),
                        result.model_latency_ms.unwrap_or(0.0),
                        i as u64,
                    )
                    .arg("dram_bytes", result.dram_bytes.unwrap_or(0) as f64),
                );
            }
            latency = match (latency, result.model_latency_ms) {
                (Some(a), Some(b)) => Some(a + b),
                _ => None,
            };
            dram = match (dram, result.dram_bytes) {
                (Some(a), Some(b)) => Some(a + b),
                _ => None,
            };
            // each stage pins its own weight segment when the chained
            // backend is pooled; the pipeline's cold cost is their sum
            cold = match (cold, result.cold_load_ms) {
                (Some(a), Some(b)) => Some(a + b),
                _ => None,
            };
            classes = match (classes, result.traffic_classes) {
                (Some(mut a), Some(b)) => {
                    a.accumulate(b);
                    Some(a)
                }
                _ => None,
            };
        }
        Ok(RunResult {
            backend: self.name(),
            output: result.output,
            model_latency_ms: latency,
            dram_bytes: dram,
            cold_load_ms: cold,
            traffic_classes: classes,
        })
    }

    fn pool_stats(&self) -> Option<crate::pool::PoolStats> {
        self.backend.pool_stats()
    }

    fn queue_depth_hint(&self) -> usize {
        // the chain adds no queue of its own — hidden load lives in the
        // backend it chains (e.g. a pool's cold fills in flight)
        self.backend.queue_depth_hint()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AccelConfig;
    use crate::engine::VirtualAccelBackend;
    use crate::shard::Partitioner;
    use crate::zoo;

    fn chain(devices: usize) -> ShardedBackend {
        let plan = Partitioner::homogeneous(AccelConfig::kcu1500_int8(), devices)
            .unwrap()
            .plan(&zoo::tinynet())
            .unwrap();
        let programs = plan.pack().unwrap().into_iter().map(Arc::new).collect();
        ShardedBackend::new(programs, Arc::new(VirtualAccelBackend), LinkModel::pcie_gen3())
            .unwrap()
    }

    #[test]
    fn virtual_chain_sums_stage_costs() {
        let two = chain(2);
        let input = Tensor::zeros(two.front().input_shape());
        let front = two.front().clone();
        let r = two.run(&front, &input).unwrap();
        assert_eq!(r.backend, "sharded");
        let lat2 = r.model_latency_ms.unwrap();
        let dram2 = r.dram_bytes.unwrap();
        // the summed per-class attribution must conserve the summed total
        assert_eq!(r.traffic_classes.unwrap().total(), dram2);

        let one = chain(1);
        let r1 = one.run(&one.front().clone(), &Tensor::zeros(one.front().input_shape()))
            .unwrap();
        // two devices pay at least one link transfer on top of compute
        assert!(lat2 > 0.0 && dram2 > 0);
        assert!(r1.model_latency_ms.unwrap() > 0.0);
    }

    #[test]
    fn chain_traces_stages_and_handoffs() {
        use crate::engine::VirtualClock;
        use crate::telemetry::TraceRecorder;
        let rec = Arc::new(TraceRecorder::new());
        let two = chain(2).with_trace(Arc::new(VirtualClock::new()), rec.clone());
        let input = Tensor::zeros(two.front().input_shape());
        let front = two.front().clone();
        two.run(&front, &input).unwrap();
        let evs = rec.events();
        assert_eq!(evs.iter().filter(|e| e.name == "stage").count(), 2);
        assert_eq!(evs.iter().filter(|e| e.name == "handoff").count(), 1);
        assert!(evs.iter().all(|e| e.cat == "shard"));
    }

    #[test]
    fn chain_rejects_foreign_programs_and_bad_order() {
        let two = chain(2);
        let other = crate::testutil::pack_program(&zoo::tinynet(), None);
        let input = Tensor::zeros(two.front().input_shape());
        assert!(matches!(
            two.run(&other, &input),
            Err(CompileError::Exec(_))
        ));

        // reversing the chain breaks both position and shape validation
        let plan = Partitioner::homogeneous(AccelConfig::kcu1500_int8(), 2)
            .unwrap()
            .plan(&zoo::tinynet())
            .unwrap();
        let mut programs: Vec<Arc<Program>> =
            plan.pack().unwrap().into_iter().map(Arc::new).collect();
        programs.reverse();
        assert!(ShardedBackend::new(
            programs,
            Arc::new(VirtualAccelBackend),
            LinkModel::pcie_gen3()
        )
        .is_err());
        assert!(ShardedBackend::new(
            Vec::new(),
            Arc::new(VirtualAccelBackend),
            LinkModel::pcie_gen3()
        )
        .is_err());
    }
}
