//! Batch-serving inference engine.
//!
//! One [`InferenceEngine`] serves one packed [`Program`] through one
//! [`ExecutionBackend`]: requests enter a *bounded* submission queue,
//! worker threads claim batches of up to `max_batch` requests (the
//! per-program batching — every claimed batch shares the already-resident
//! program, mirroring how the accelerator driver reuses the shipped
//! instruction/parameter payload across inputs), and each completion is
//! delivered back through a per-request channel. [`EngineStats`] reports
//! throughput, p50/p95 latency from the timing model, queue depth and the
//! observed cross-worker overlap.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use super::{ExecutionBackend, RunResult};
use crate::compiler::CompileError;
use crate::funcsim::Tensor;
use crate::program::Program;
use crate::Result;

/// Serving knobs. Zero values are clamped to 1.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Worker threads (backend instances executing concurrently).
    pub workers: usize,
    /// Bound of the submission queue: [`InferenceEngine::submit`] blocks
    /// and [`InferenceEngine::try_submit`] rejects beyond it.
    pub queue_capacity: usize,
    /// Most requests one worker claims per queue visit.
    pub max_batch: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { workers: 2, queue_capacity: 64, max_batch: 8 }
    }
}

/// A finished request: the backend result plus serving-side timing.
#[derive(Debug, Clone)]
pub struct Completion {
    /// What the backend produced.
    pub result: RunResult,
    /// Time spent waiting in the submission queue.
    pub wait_ms: f64,
    /// Wall-clock share of the batch execution attributed to this
    /// request.
    pub wall_ms: f64,
    /// Which worker ran it.
    pub worker: usize,
}

/// Handle returned by `submit`; resolves to the completion.
pub struct PendingRequest {
    rx: mpsc::Receiver<Result<Completion>>,
}

impl PendingRequest {
    /// Block until the request finishes (or the engine shuts down).
    pub fn wait(self) -> Result<Completion> {
        match self.rx.recv() {
            Ok(r) => r,
            Err(_) => Err(CompileError::Exec(
                "request dropped: engine shut down before it ran".into(),
            )),
        }
    }
}

struct Job {
    input: Tensor,
    tx: mpsc::Sender<Result<Completion>>,
    enqueued: Instant,
}

/// Latency samples kept for the percentile estimates: a sliding window
/// of the most recent completions, so a long-lived engine's stats stay
/// O(1) per request instead of growing one f64 per request forever.
const LATENCY_WINDOW: usize = 4096;

#[derive(Default)]
struct StatsInner {
    submitted: u64,
    completed: u64,
    failed: u64,
    rejected: u64,
    peak_in_flight: usize,
    per_worker: Vec<u64>,
    /// Per-request service latency: the timing model's prediction when
    /// the backend reports one, otherwise the measured wall share.
    /// Bounded ring of the last [`LATENCY_WINDOW`] completions.
    latencies_ms: Vec<f64>,
    /// Next overwrite index once the latency ring is full.
    lat_next: usize,
    wait_ms_total: f64,
    batches: u64,
    max_batch_seen: usize,
}

impl StatsInner {
    fn record_latency(&mut self, ms: f64) {
        if self.latencies_ms.len() < LATENCY_WINDOW {
            self.latencies_ms.push(ms);
        } else {
            let i = self.lat_next;
            self.latencies_ms[i] = ms;
            self.lat_next = (i + 1) % LATENCY_WINDOW;
        }
    }
}

struct Shared {
    program: Arc<Program>,
    backend: Arc<dyn ExecutionBackend>,
    queue: Mutex<VecDeque<Job>>,
    not_empty: Condvar,
    not_full: Condvar,
    stats: Mutex<StatsInner>,
    in_flight: AtomicUsize,
    shutdown: AtomicBool,
    capacity: usize,
    max_batch: usize,
    /// Stamped at construction and re-stamped when the workers start, so
    /// a paused engine's queue-filling time never deflates throughput.
    started: Mutex<Instant>,
}

/// Snapshot of an engine's counters (see [`InferenceEngine::stats`]).
#[derive(Debug, Clone)]
pub struct EngineStats {
    /// Name of the serving backend.
    pub backend: &'static str,
    /// Requests accepted into the queue.
    pub submitted: u64,
    /// Requests finished successfully.
    pub completed: u64,
    /// Requests whose backend run errored.
    pub failed: u64,
    /// `try_submit` calls bounced off the full queue.
    pub rejected: u64,
    /// Requests currently waiting in the queue.
    pub queue_depth: usize,
    /// Requests currently claimed by workers.
    pub in_flight: usize,
    /// Most requests ever claimed by workers simultaneously — the
    /// observable overlap across backend instances.
    pub peak_in_flight: usize,
    /// Completions per worker thread.
    pub per_worker: Vec<u64>,
    /// Batches executed.
    pub batches: u64,
    /// Largest batch a worker claimed.
    pub max_batch_seen: usize,
    /// Wall-clock seconds since the workers started.
    pub elapsed_s: f64,
    /// Completed requests per wall-clock second since engine start.
    pub throughput_rps: f64,
    /// Median per-request latency (timing model when available),
    /// over a sliding window of the most recent completions.
    pub p50_ms: f64,
    /// 95th-percentile per-request latency over the same window.
    pub p95_ms: f64,
    /// Mean queue wait over the same window, ms.
    pub mean_wait_ms: f64,
    /// Buffer-pool counters (hit/miss/eviction, cold-start latency
    /// percentiles) when the serving backend pages weights through a
    /// [`crate::pool::BufferPool`]; `None` for unpooled backends.
    pub pool: Option<crate::pool::PoolStats>,
}

/// Serves concurrent inference requests against one packed program.
///
/// ```no_run
/// use shortcutfusion::engine::{EngineConfig, InferenceEngine, VirtualAccelBackend};
/// use shortcutfusion::funcsim::Tensor;
/// use shortcutfusion::program::Program;
/// use std::sync::Arc;
///
/// let program = Arc::new(Program::load(std::path::Path::new("resnet18.sfp")).unwrap());
/// let engine = InferenceEngine::new(
///     program.clone(),
///     Arc::new(VirtualAccelBackend),
///     EngineConfig::default(),
/// );
/// let pending = engine.submit(Tensor::zeros(program.input_shape())).unwrap();
/// let done = pending.wait().unwrap();
/// println!("{:.3} ms", done.result.model_latency_ms.unwrap());
/// println!("{:#?}", engine.shutdown());
/// ```
pub struct InferenceEngine {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    worker_count: usize,
}

impl InferenceEngine {
    /// Create the engine and start its workers.
    pub fn new(
        program: Arc<Program>,
        backend: Arc<dyn ExecutionBackend>,
        cfg: EngineConfig,
    ) -> InferenceEngine {
        let mut engine = InferenceEngine::new_paused(program, backend, cfg);
        engine.start();
        engine
    }

    /// Create the engine without starting workers: requests can be
    /// pre-queued (up to the capacity bound) and begin executing at
    /// [`InferenceEngine::start`]. Used for deterministic tests and
    /// cold-start benchmarks.
    pub fn new_paused(
        program: Arc<Program>,
        backend: Arc<dyn ExecutionBackend>,
        cfg: EngineConfig,
    ) -> InferenceEngine {
        let worker_count = cfg.workers.max(1);
        let shared = Arc::new(Shared {
            program,
            backend,
            queue: Mutex::new(VecDeque::new()),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            stats: Mutex::new(StatsInner {
                per_worker: vec![0; worker_count],
                ..StatsInner::default()
            }),
            in_flight: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
            capacity: cfg.queue_capacity.max(1),
            max_batch: cfg.max_batch.max(1),
            started: Mutex::new(Instant::now()),
        });
        InferenceEngine { shared, workers: Vec::new(), worker_count }
    }

    /// Spawn the worker threads (no-op if already running).
    pub fn start(&mut self) {
        if !self.workers.is_empty() {
            return;
        }
        *self.shared.started.lock().unwrap() = Instant::now();
        let mut handles = Vec::with_capacity(self.worker_count);
        for wid in 0..self.worker_count {
            let shared = self.shared.clone();
            handles.push(std::thread::spawn(move || worker_loop(shared, wid)));
        }
        self.workers = handles;
    }

    /// Enqueue one request, blocking while the queue is at capacity.
    pub fn submit(&self, input: Tensor) -> Result<PendingRequest> {
        let (tx, rx) = mpsc::channel();
        let job = Job { input, tx, enqueued: Instant::now() };
        {
            let mut q = self.shared.queue.lock().unwrap();
            while q.len() >= self.shared.capacity {
                if self.shared.shutdown.load(Ordering::SeqCst) {
                    return Err(CompileError::Exec("engine is shut down".into()));
                }
                q = self.shared.not_full.wait(q).unwrap();
            }
            if self.shared.shutdown.load(Ordering::SeqCst) {
                return Err(CompileError::Exec("engine is shut down".into()));
            }
            // count before the job becomes claimable, so a snapshot can
            // never observe completed > submitted (lock order is always
            // queue -> stats, matching the workers)
            self.shared.stats.lock().unwrap().submitted += 1;
            q.push_back(job);
        }
        self.shared.not_empty.notify_one();
        Ok(PendingRequest { rx })
    }

    /// Enqueue without blocking; a full queue is a typed rejection
    /// (counted in [`EngineStats::rejected`]).
    pub fn try_submit(&self, input: Tensor) -> Result<PendingRequest> {
        let (tx, rx) = mpsc::channel();
        let job = Job { input, tx, enqueued: Instant::now() };
        {
            let mut q = self.shared.queue.lock().unwrap();
            if self.shared.shutdown.load(Ordering::SeqCst) {
                return Err(CompileError::Exec("engine is shut down".into()));
            }
            if q.len() >= self.shared.capacity {
                drop(q);
                self.shared.stats.lock().unwrap().rejected += 1;
                return Err(CompileError::Exec(format!(
                    "submission queue full ({} requests)",
                    self.shared.capacity
                )));
            }
            self.shared.stats.lock().unwrap().submitted += 1;
            q.push_back(job);
        }
        self.shared.not_empty.notify_one();
        Ok(PendingRequest { rx })
    }

    /// Requests currently waiting in the submission queue.
    pub fn queue_depth(&self) -> usize {
        self.shared.queue.lock().unwrap().len()
    }

    /// Snapshot of the serving counters.
    pub fn stats(&self) -> EngineStats {
        snapshot(&self.shared)
    }

    /// Drain the queue, stop the workers and return the final stats.
    pub fn shutdown(mut self) -> EngineStats {
        self.stop();
        snapshot(&self.shared)
    }

    fn stop(&mut self) {
        // Always flag shutdown and wake both condvars — even a paused
        // engine (no workers ever started) can have submitters blocked
        // on a full queue who must observe the shutdown.
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.not_empty.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        self.shared.not_full.notify_all();
    }
}

impl Drop for InferenceEngine {
    fn drop(&mut self) {
        self.stop();
    }
}

fn worker_loop(shared: Arc<Shared>, wid: usize) {
    loop {
        // ---- claim a batch (or exit once drained + shut down) -----------
        let (batch, claimed_at) = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if !q.is_empty() {
                    break;
                }
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                q = shared.not_empty.wait(q).unwrap();
            }
            let n = q.len().min(shared.max_batch);
            let batch: Vec<Job> = q.drain(..n).collect();
            shared.in_flight.fetch_add(batch.len(), Ordering::SeqCst);
            shared.not_full.notify_all();
            (batch, Instant::now())
        };
        let now_in_flight = shared.in_flight.load(Ordering::SeqCst);
        {
            let mut s = shared.stats.lock().unwrap();
            s.peak_in_flight = s.peak_in_flight.max(now_in_flight);
            s.batches += 1;
            s.max_batch_seen = s.max_batch_seen.max(batch.len());
        }

        // ---- execute -----------------------------------------------------
        // move the tensors out of the jobs rather than cloning them: the
        // input copy would otherwise dominate the virtual backend's cost
        let mut inputs = Vec::with_capacity(batch.len());
        let mut replies = Vec::with_capacity(batch.len());
        for job in batch {
            inputs.push(job.input);
            replies.push((job.tx, job.enqueued));
        }
        let t0 = Instant::now();
        let mut results = shared.backend.run_batch(&shared.program, &inputs).into_iter();
        let wall_each = t0.elapsed().as_secs_f64() * 1e3 / inputs.len() as f64;

        // ---- complete ----------------------------------------------------
        // walk the replies (not a zip) so a misbehaving run_batch override
        // that returns too few results still answers every waiter and
        // keeps the in-flight counter balanced
        for (tx, enqueued) in replies {
            shared.in_flight.fetch_sub(1, Ordering::SeqCst);
            let res = results.next().unwrap_or_else(|| {
                Err(CompileError::Exec(
                    "backend returned fewer results than batch inputs".into(),
                ))
            });
            let wait_ms = claimed_at.saturating_duration_since(enqueued).as_secs_f64() * 1e3;
            let outcome = match res {
                Ok(result) => {
                    let service_ms = result.model_latency_ms.unwrap_or(wall_each);
                    {
                        let mut s = shared.stats.lock().unwrap();
                        s.completed += 1;
                        s.per_worker[wid] += 1;
                        s.record_latency(service_ms);
                        s.wait_ms_total += wait_ms;
                    }
                    Ok(Completion { result, wait_ms, wall_ms: wall_each, worker: wid })
                }
                Err(e) => {
                    shared.stats.lock().unwrap().failed += 1;
                    Err(e)
                }
            };
            // receiver may have been dropped — not the engine's problem
            let _ = tx.send(outcome);
        }
    }
}

fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

fn snapshot(shared: &Shared) -> EngineStats {
    let queue_depth = shared.queue.lock().unwrap().len();
    let s = shared.stats.lock().unwrap();
    let mut lat = s.latencies_ms.clone();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let elapsed_s = shared.started.lock().unwrap().elapsed().as_secs_f64();
    EngineStats {
        backend: shared.backend.name(),
        submitted: s.submitted,
        completed: s.completed,
        failed: s.failed,
        rejected: s.rejected,
        queue_depth,
        in_flight: shared.in_flight.load(Ordering::SeqCst),
        peak_in_flight: s.peak_in_flight,
        per_worker: s.per_worker.clone(),
        batches: s.batches,
        max_batch_seen: s.max_batch_seen,
        elapsed_s,
        throughput_rps: if elapsed_s > 0.0 { s.completed as f64 / elapsed_s } else { 0.0 },
        p50_ms: percentile(&lat, 0.50),
        p95_ms: percentile(&lat, 0.95),
        mean_wait_ms: if s.completed > 0 { s.wait_ms_total / s.completed as f64 } else { 0.0 },
        pool: shared.backend.pool_stats(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::VirtualAccelBackend;
    use crate::zoo;

    fn tinynet_program() -> Arc<Program> {
        Arc::new(crate::testutil::pack_program(&zoo::tinynet(), None))
    }

    #[test]
    fn serves_requests_and_reports_stats() {
        let program = tinynet_program();
        let engine = InferenceEngine::new(
            program.clone(),
            Arc::new(VirtualAccelBackend),
            EngineConfig { workers: 3, queue_capacity: 8, max_batch: 2 },
        );
        let shape = program.input_shape();
        let pending: Vec<PendingRequest> =
            (0..12).map(|_| engine.submit(Tensor::zeros(shape)).unwrap()).collect();
        for p in pending {
            let done = p.wait().unwrap();
            assert!(done.result.model_latency_ms.unwrap() > 0.0);
        }
        let stats = engine.shutdown();
        assert_eq!(stats.completed, 12);
        assert_eq!(stats.submitted, 12);
        assert_eq!(stats.failed, 0);
        assert_eq!(stats.queue_depth, 0);
        assert!(stats.p50_ms > 0.0);
        assert!(stats.p95_ms >= stats.p50_ms);
        assert!(stats.throughput_rps > 0.0);
        assert_eq!(stats.per_worker.iter().sum::<u64>(), 12);
    }

    #[test]
    fn bounded_queue_rejects_when_full() {
        let program = tinynet_program();
        // paused: nothing drains the queue while we fill it
        let engine = InferenceEngine::new_paused(
            program.clone(),
            Arc::new(VirtualAccelBackend),
            EngineConfig { workers: 1, queue_capacity: 2, max_batch: 1 },
        );
        let shape = program.input_shape();
        let a = engine.try_submit(Tensor::zeros(shape)).unwrap();
        let b = engine.try_submit(Tensor::zeros(shape)).unwrap();
        assert!(engine.try_submit(Tensor::zeros(shape)).is_err());
        assert_eq!(engine.stats().rejected, 1);
        assert_eq!(engine.queue_depth(), 2);
        let mut engine = engine;
        engine.start();
        a.wait().unwrap();
        b.wait().unwrap();
        let stats = engine.shutdown();
        assert_eq!(stats.completed, 2);
        assert_eq!(stats.rejected, 1);
    }

    #[test]
    fn shutdown_drains_pending_requests() {
        let program = tinynet_program();
        let engine = InferenceEngine::new_paused(
            program.clone(),
            Arc::new(VirtualAccelBackend),
            EngineConfig { workers: 2, queue_capacity: 16, max_batch: 4 },
        );
        let shape = program.input_shape();
        let pending: Vec<PendingRequest> =
            (0..6).map(|_| engine.submit(Tensor::zeros(shape)).unwrap()).collect();
        let mut engine = engine;
        engine.start();
        let stats = engine.shutdown(); // must wait for the 6 queued requests
        assert_eq!(stats.completed, 6);
        for p in pending {
            assert!(p.wait().is_ok());
        }
    }

    #[test]
    fn percentile_edges() {
        assert_eq!(percentile(&[], 0.5), 0.0);
        assert_eq!(percentile(&[3.0], 0.95), 3.0);
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 1.0), 4.0);
    }
}
