//! Event-driven batch-serving inference engine.
//!
//! One [`InferenceEngine`] serves one packed [`Program`] through one
//! [`ExecutionBackend`]. All scheduling decisions — admission control,
//! batch formation, mid-batch joins, deadlines, per-client ordering —
//! live in the deterministic [`super::Scheduler`] core; this module is
//! the threaded shell around it: worker threads execute what the
//! scheduler dispatches, timestamps come from the engine's [`Clock`]
//! (wall clock in production, [`super::VirtualClock`] in tests), and
//! each completion is delivered back through a per-request channel.
//!
//! Under [`BatchPolicy::Continuous`] (the default) a request arriving
//! while a worker's batch is executing *joins that batch* at the next
//! execution boundary instead of waiting for the window to drain;
//! [`BatchPolicy::Window`] keeps the pre-0.9 fixed-window behaviour.
//! Admission is queue-depth-aware: [`InferenceEngine::try_submit`]
//! rejects with a typed [`CompileError::Rejected`] (depth + retry-after
//! hint) when the queue — plus the backend's reported pending load, see
//! [`ExecutionBackend::queue_depth_hint`] — is at capacity, and
//! per-request deadlines surface as typed
//! [`CompileError::DeadlineMiss`] errors and
//! [`EngineStats::deadline_misses`].
//!
//! The engine is observable: always-on atomic histograms (queue wait,
//! batch size, cold-load time — snapshotted into [`EngineStats`]) and an
//! optional [`crate::telemetry::TraceSink`] attached via
//! [`InferenceEngine::with_trace`] that records every request's
//! lifecycle on the engine's clock. The default sink is
//! [`crate::telemetry::NullSink`]; its `enabled()` check gates event
//! construction, so the submit/complete path never allocates for
//! telemetry unless a recorder is attached.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use super::scheduler::{BatchPolicy, Scheduler, SchedulerConfig, Ticket};
use super::{Clock, ExecutionBackend, RealClock, RunResult};
use crate::compiler::CompileError;
use crate::funcsim::Tensor;
use crate::program::Program;
use crate::telemetry::{
    Histogram, HistogramSnapshot, NullSink, TraceEvent, TraceSink, BATCH_BOUNDS, MS_BOUNDS,
};
use crate::Result;

/// Serving knobs. Zero sizes are clamped to 1.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Worker threads (backend instances executing concurrently).
    pub workers: usize,
    /// Bound of the submission queue: [`InferenceEngine::submit`] blocks
    /// and [`InferenceEngine::try_submit`] rejects beyond it.
    pub queue_capacity: usize,
    /// Most requests one worker holds in an open batch.
    pub max_batch: usize,
    /// Batch formation policy: [`BatchPolicy::Continuous`] (default)
    /// admits arrivals into in-flight batches at execution boundaries;
    /// [`BatchPolicy::Window`] is the pre-0.9 fixed-window path.
    pub policy: BatchPolicy,
    /// Default *relative* deadline applied to every submission that
    /// does not carry its own (see [`SubmitOptions::deadline_ms`]);
    /// `None` disables deadlines by default.
    pub deadline_ms: Option<f64>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            workers: 2,
            queue_capacity: 64,
            max_batch: 8,
            policy: BatchPolicy::Continuous,
            deadline_ms: None,
        }
    }
}

/// Per-request submission options (see
/// [`InferenceEngine::submit_opts`]). The default is an untagged
/// request with the engine's default deadline.
#[derive(Debug, Clone, Copy, Default)]
pub struct SubmitOptions {
    /// Ordering domain: responses are never reordered within a client.
    /// `None` (default) assigns a fresh client per request, so untagged
    /// requests spread freely across workers.
    pub client: Option<u64>,
    /// Relative deadline in milliseconds from submission; overrides the
    /// engine's [`EngineConfig::deadline_ms`] default. A request past
    /// its deadline is dropped unexecuted with a typed
    /// [`CompileError::DeadlineMiss`], and late completions are counted
    /// in [`EngineStats::deadline_misses`].
    pub deadline_ms: Option<f64>,
}

/// A finished request: the backend result plus serving-side timing.
#[derive(Debug, Clone)]
pub struct Completion {
    /// What the backend produced.
    pub result: RunResult,
    /// Time spent waiting for dispatch (submission to batch admission),
    /// on the engine's clock.
    pub wait_ms: f64,
    /// Wall-clock share of the batch execution attributed to this
    /// request.
    pub wall_ms: f64,
    /// Which worker ran it.
    pub worker: usize,
    /// The request finished after its deadline (counted in
    /// [`EngineStats::deadline_misses`]; the result is still valid).
    pub deadline_missed: bool,
}

/// Handle returned by `submit`; resolves to the completion.
pub struct PendingRequest {
    rx: mpsc::Receiver<Result<Completion>>,
}

impl PendingRequest {
    /// Block until the request finishes (or the engine shuts down).
    pub fn wait(self) -> Result<Completion> {
        match self.rx.recv() {
            Ok(r) => r,
            Err(_) => Err(CompileError::Exec(
                "request dropped: engine shut down before it ran".into(),
            )),
        }
    }
}

/// Latency samples kept for the percentile estimates: a sliding window
/// of the most recent completions, so a long-lived engine's stats stay
/// O(1) per request instead of growing one f64 per request forever.
const LATENCY_WINDOW: usize = 4096;

/// Measured-sample side of the stats (the counters live in the
/// scheduler): latency ring, wait accounting, per-worker tallies.
#[derive(Default)]
struct StatsInner {
    per_worker: Vec<u64>,
    /// Per-request service latency: the timing model's prediction when
    /// the backend reports one, otherwise the measured wall share.
    /// Bounded ring of the last [`LATENCY_WINDOW`] completions.
    latencies_ms: Vec<f64>,
    /// Next overwrite index once the latency ring is full.
    lat_next: usize,
    wait_ms_total: f64,
}

impl StatsInner {
    fn record_latency(&mut self, ms: f64) {
        if self.latencies_ms.len() < LATENCY_WINDOW {
            self.latencies_ms.push(ms);
        } else {
            let i = self.lat_next;
            self.latencies_ms[i] = ms;
            self.lat_next = (i + 1) % LATENCY_WINDOW;
        }
    }
}

/// A queued request's payload, keyed by ticket id (the scheduler only
/// tracks the scheduling-relevant fields).
struct Payload {
    input: Tensor,
    tx: mpsc::Sender<Result<Completion>>,
}

/// Scheduler plus payload store — everything behind the state mutex.
struct State {
    sched: Scheduler,
    jobs: HashMap<u64, Payload>,
}

struct Shared {
    program: Arc<Program>,
    backend: Arc<dyn ExecutionBackend>,
    clock: Arc<dyn Clock>,
    state: Mutex<State>,
    not_empty: Condvar,
    not_full: Condvar,
    // lock order is always state -> stats
    stats: Mutex<StatsInner>,
    shutdown: AtomicBool,
    capacity: usize,
    policy: BatchPolicy,
    /// Fresh client ids for untagged submissions — the high bit keeps
    /// them out of any caller-chosen client namespace.
    next_client: AtomicU64,
    /// Stamped at construction and re-stamped when the workers start, so
    /// a paused engine's queue-filling time never deflates throughput.
    started: Mutex<Instant>,
    /// Request-lifecycle trace sink ([`NullSink`] unless attached via
    /// [`InferenceEngine::with_trace`]); `enabled()` is checked before
    /// any event is even built, so the default costs one virtual call.
    trace: Arc<dyn TraceSink>,
    /// Always-on distributions (atomic; the record path never
    /// allocates): dispatch wait, claimed batch size, pool cold-load
    /// time. Snapshotted into [`EngineStats`].
    hist_queue_wait: Histogram,
    hist_batch_size: Histogram,
    hist_cold_load: Histogram,
}

/// Snapshot of an engine's counters (see [`InferenceEngine::stats`]).
#[derive(Debug, Clone)]
pub struct EngineStats {
    /// Name of the serving backend.
    pub backend: &'static str,
    /// Batch formation policy name (`"continuous"` / `"window"`).
    pub policy: &'static str,
    /// Requests accepted into the queue.
    pub submitted: u64,
    /// Requests finished successfully.
    pub completed: u64,
    /// Requests whose backend run errored.
    pub failed: u64,
    /// `try_submit` calls bounced by admission control.
    pub rejected: u64,
    /// Requests whose deadline was missed: dropped unexecuted past the
    /// deadline (queued or at dispatch) plus completions that finished
    /// late.
    pub deadline_misses: u64,
    /// Requests admitted into an already-running batch at an execution
    /// boundary (continuous batching's defining event; always 0 under
    /// [`BatchPolicy::Window`]).
    pub joined: u64,
    /// Requests currently waiting in the queue.
    pub queue_depth: usize,
    /// Requests currently claimed by workers.
    pub in_flight: usize,
    /// Most requests ever claimed by workers simultaneously — the
    /// observable overlap across backend instances.
    pub peak_in_flight: usize,
    /// Completions per worker thread.
    pub per_worker: Vec<u64>,
    /// Batches formed (mid-batch joins extend a batch, they do not
    /// start one).
    pub batches: u64,
    /// Largest open batch one worker ever held (claimed + joined).
    pub max_batch_seen: usize,
    /// Wall-clock seconds since the workers started.
    pub elapsed_s: f64,
    /// Completed requests per wall-clock second since engine start.
    pub throughput_rps: f64,
    /// Median per-request latency (timing model when available),
    /// over a sliding window of the most recent completions.
    pub p50_ms: f64,
    /// 95th-percentile per-request latency over the same window.
    pub p95_ms: f64,
    /// Mean dispatch wait over the same window, ms.
    pub mean_wait_ms: f64,
    /// Buffer-pool counters (hit/miss/eviction, cold-start latency
    /// percentiles) when the serving backend pages weights through a
    /// [`crate::pool::BufferPool`]; `None` for unpooled backends.
    pub pool: Option<crate::pool::PoolStats>,
    /// Dispatch-wait distribution over completed requests
    /// ([`crate::telemetry::MS_BOUNDS`] buckets; always on).
    pub queue_wait_ms_hist: HistogramSnapshot,
    /// Claimed-batch-size distribution, one sample per batch formed
    /// ([`crate::telemetry::BATCH_BOUNDS`] buckets).
    pub batch_size_hist: HistogramSnapshot,
    /// Pool cold-load-time distribution; samples land only when the
    /// backend reports [`RunResult::cold_load_ms`]
    /// ([`crate::telemetry::MS_BOUNDS`] buckets).
    pub cold_load_ms_hist: HistogramSnapshot,
}

/// Serves concurrent inference requests against one packed program.
///
/// ```no_run
/// use shortcutfusion::engine::{EngineConfig, InferenceEngine, VirtualAccelBackend};
/// use shortcutfusion::funcsim::Tensor;
/// use shortcutfusion::program::Program;
/// use std::sync::Arc;
///
/// let program = Arc::new(Program::load(std::path::Path::new("resnet18.sfp")).unwrap());
/// let engine = InferenceEngine::new(
///     program.clone(),
///     Arc::new(VirtualAccelBackend),
///     EngineConfig::default(),
/// );
/// let pending = engine.submit(Tensor::zeros(program.input_shape())).unwrap();
/// let done = pending.wait().unwrap();
/// println!("{:.3} ms", done.result.model_latency_ms.unwrap());
/// println!("{:#?}", engine.shutdown());
/// ```
pub struct InferenceEngine {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    worker_count: usize,
}

impl InferenceEngine {
    /// Create the engine on the wall clock and start its workers.
    pub fn new(
        program: Arc<Program>,
        backend: Arc<dyn ExecutionBackend>,
        cfg: EngineConfig,
    ) -> InferenceEngine {
        let mut engine = InferenceEngine::new_paused(program, backend, cfg);
        engine.start();
        engine
    }

    /// Create the engine without starting workers: requests can be
    /// pre-queued (up to the capacity bound) and begin executing at
    /// [`InferenceEngine::start`]. Used for deterministic tests and
    /// cold-start benchmarks.
    pub fn new_paused(
        program: Arc<Program>,
        backend: Arc<dyn ExecutionBackend>,
        cfg: EngineConfig,
    ) -> InferenceEngine {
        InferenceEngine::new_paused_with_clock(program, backend, cfg, Arc::new(RealClock::new()))
    }

    /// [`InferenceEngine::new`] with an explicit time source — pass a
    /// [`super::VirtualClock`] to make dispatch waits and deadline
    /// expiry deterministic in tests.
    pub fn with_clock(
        program: Arc<Program>,
        backend: Arc<dyn ExecutionBackend>,
        cfg: EngineConfig,
        clock: Arc<dyn Clock>,
    ) -> InferenceEngine {
        let mut engine = InferenceEngine::new_paused_with_clock(program, backend, cfg, clock);
        engine.start();
        engine
    }

    /// [`InferenceEngine::new_paused`] with an explicit time source.
    pub fn new_paused_with_clock(
        program: Arc<Program>,
        backend: Arc<dyn ExecutionBackend>,
        cfg: EngineConfig,
        clock: Arc<dyn Clock>,
    ) -> InferenceEngine {
        let worker_count = cfg.workers.max(1);
        let sched = Scheduler::new(
            SchedulerConfig {
                policy: cfg.policy,
                max_batch: cfg.max_batch,
                queue_capacity: cfg.queue_capacity,
                deadline_ms: cfg.deadline_ms,
            },
            worker_count,
        );
        let shared = Arc::new(Shared {
            program,
            backend,
            clock,
            state: Mutex::new(State { sched, jobs: HashMap::new() }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            stats: Mutex::new(StatsInner {
                per_worker: vec![0; worker_count],
                ..StatsInner::default()
            }),
            shutdown: AtomicBool::new(false),
            capacity: cfg.queue_capacity.max(1),
            policy: cfg.policy,
            next_client: AtomicU64::new(1 << 63),
            started: Mutex::new(Instant::now()),
            trace: Arc::new(NullSink),
            hist_queue_wait: Histogram::new(MS_BOUNDS),
            hist_batch_size: Histogram::new(BATCH_BOUNDS),
            hist_cold_load: Histogram::new(MS_BOUNDS),
        });
        InferenceEngine { shared, workers: Vec::new(), worker_count }
    }

    /// Attach a trace sink recording the request lifecycle — `submit`,
    /// `reject`, `claim`, `join`, `run`, `complete`, `fail`, `expire`
    /// instants/spans under category `"request"`, with the ticket id as
    /// the trace thread id. Every timestamp comes from the engine's
    /// [`Clock`], so a [`super::VirtualClock`] makes the exported trace
    /// byte-deterministic. Build the engine paused
    /// ([`InferenceEngine::new_paused`] /
    /// [`InferenceEngine::new_paused_with_clock`]), attach, then
    /// [`InferenceEngine::start`].
    ///
    /// # Panics
    /// Panics if the workers are already running.
    pub fn with_trace(mut self, trace: Arc<dyn TraceSink>) -> InferenceEngine {
        Arc::get_mut(&mut self.shared)
            .expect("attach the trace sink before starting the workers")
            .trace = trace;
        self
    }

    /// Spawn the worker threads (no-op if already running).
    pub fn start(&mut self) {
        if !self.workers.is_empty() {
            return;
        }
        *self.shared.started.lock().unwrap() = Instant::now();
        let mut handles = Vec::with_capacity(self.worker_count);
        for wid in 0..self.worker_count {
            let shared = self.shared.clone();
            handles.push(std::thread::spawn(move || worker_loop(shared, wid)));
        }
        self.workers = handles;
    }

    /// Enqueue one untagged request, blocking while the queue is at
    /// capacity (the flow-control path; [`InferenceEngine::try_submit`]
    /// is the load-shedding one).
    pub fn submit(&self, input: Tensor) -> Result<PendingRequest> {
        self.submit_opts(input, SubmitOptions::default())
    }

    /// [`InferenceEngine::submit`] with per-request options (client tag
    /// for ordering, deadline override). Blocks while the queue is at
    /// capacity; the backend's [`ExecutionBackend::queue_depth_hint`]
    /// only tightens the non-blocking path.
    pub fn submit_opts(&self, input: Tensor, opts: SubmitOptions) -> Result<PendingRequest> {
        let (tx, rx) = mpsc::channel();
        {
            let mut st = self.shared.state.lock().unwrap();
            while st.sched.queued() >= self.shared.capacity {
                if self.shared.shutdown.load(Ordering::SeqCst) {
                    return Err(CompileError::Exec("engine is shut down".into()));
                }
                st = self.shared.not_full.wait(st).unwrap();
            }
            if self.shared.shutdown.load(Ordering::SeqCst) {
                return Err(CompileError::Exec("engine is shut down".into()));
            }
            let now = self.shared.clock.now_ms();
            deliver_expired(&self.shared, &mut st, now);
            let ticket = st
                .sched
                .submit(self.client_of(opts), now, opts.deadline_ms.map(|d| now + d), 0)
                .expect("capacity was checked under the same lock");
            if self.shared.trace.enabled() {
                self.shared
                    .trace
                    .record(TraceEvent::instant("request", "submit", now, ticket.id));
            }
            st.jobs.insert(ticket.id, Payload { input, tx });
        }
        self.shared.not_empty.notify_one();
        Ok(PendingRequest { rx })
    }

    /// Enqueue an untagged request without blocking; admission control
    /// turns it away with a typed [`CompileError::Rejected`] (counted
    /// in [`EngineStats::rejected`]) when the queue plus the backend's
    /// reported pending load is at capacity.
    pub fn try_submit(&self, input: Tensor) -> Result<PendingRequest> {
        self.try_submit_opts(input, SubmitOptions::default())
    }

    /// [`InferenceEngine::try_submit`] with per-request options.
    pub fn try_submit_opts(&self, input: Tensor, opts: SubmitOptions) -> Result<PendingRequest> {
        let (tx, rx) = mpsc::channel();
        {
            let mut st = self.shared.state.lock().unwrap();
            if self.shared.shutdown.load(Ordering::SeqCst) {
                return Err(CompileError::Exec("engine is shut down".into()));
            }
            let now = self.shared.clock.now_ms();
            deliver_expired(&self.shared, &mut st, now);
            let extra = self.shared.backend.queue_depth_hint();
            match st.sched.submit(
                self.client_of(opts),
                now,
                opts.deadline_ms.map(|d| now + d),
                extra,
            ) {
                Ok(ticket) => {
                    if self.shared.trace.enabled() {
                        self.shared
                            .trace
                            .record(TraceEvent::instant("request", "submit", now, ticket.id));
                    }
                    st.jobs.insert(ticket.id, Payload { input, tx });
                }
                Err(rej) => {
                    if self.shared.trace.enabled() {
                        self.shared.trace.record(
                            TraceEvent::instant("request", "reject", now, 0)
                                .arg("depth", rej.depth as f64),
                        );
                    }
                    return Err(CompileError::Rejected {
                        depth: rej.depth,
                        deadline_ms: rej.deadline_ms,
                    })
                }
            }
        }
        self.shared.not_empty.notify_one();
        Ok(PendingRequest { rx })
    }

    /// Requests currently waiting in the submission queue.
    pub fn queue_depth(&self) -> usize {
        self.shared.state.lock().unwrap().sched.queued()
    }

    /// Snapshot of the serving counters. Expires overdue queued
    /// requests first, so deadline misses are visible without waiting
    /// for a worker to touch the queue.
    pub fn stats(&self) -> EngineStats {
        {
            let mut st = self.shared.state.lock().unwrap();
            let now = self.shared.clock.now_ms();
            deliver_expired(&self.shared, &mut st, now);
        }
        snapshot(&self.shared)
    }

    /// Drain the queue, stop the workers and return the final stats.
    pub fn shutdown(mut self) -> EngineStats {
        self.stop();
        snapshot(&self.shared)
    }

    /// Client id for a submission: the caller's tag, or a fresh one.
    fn client_of(&self, opts: SubmitOptions) -> u64 {
        opts.client
            .unwrap_or_else(|| self.shared.next_client.fetch_add(1, Ordering::Relaxed))
    }

    fn stop(&mut self) {
        // Always flag shutdown and wake both condvars — even a paused
        // engine (no workers ever started) can have submitters blocked
        // on a full queue who must observe the shutdown.
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.not_empty.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        self.shared.not_full.notify_all();
    }
}

impl Drop for InferenceEngine {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Expire overdue queued tickets and answer their waiters with the
/// typed deadline error. Called under the state lock on every queue
/// touch (submit, claim, join, stats).
fn deliver_expired(shared: &Shared, st: &mut State, now_ms: f64) {
    for t in st.sched.expire(now_ms) {
        if let Some(p) = st.jobs.remove(&t.id) {
            let deadline_ms = t.deadline_ms.expect("expired tickets carry deadlines");
            if shared.trace.enabled() {
                shared.trace.record(
                    TraceEvent::instant("request", "expire", now_ms, t.id)
                        .arg("deadline_ms", deadline_ms),
                );
            }
            let _ = p.tx.send(Err(CompileError::DeadlineMiss { deadline_ms, now_ms }));
        }
    }
}

/// One dispatched request on its way through a worker: the scheduler
/// ticket, the admission timestamp (claim or join time), and the
/// payload.
struct Dispatched {
    ticket: Ticket,
    admitted_ms: f64,
    input: Tensor,
    tx: mpsc::Sender<Result<Completion>>,
}

fn worker_loop(shared: Arc<Shared>, wid: usize) {
    loop {
        // ---- claim a batch (or exit once drained + shut down) -----------
        let batch: VecDeque<Dispatched> = {
            let mut st = shared.state.lock().unwrap();
            loop {
                let now = shared.clock.now_ms();
                deliver_expired(&shared, &mut st, now);
                let claimed = st.sched.claim(wid, now);
                if !claimed.is_empty() {
                    break claimed
                        .into_iter()
                        .map(|t| attach_payload(&mut st, t, now))
                        .collect();
                }
                if shared.shutdown.load(Ordering::SeqCst) {
                    // wake any peer still parked here so the exit cascades
                    shared.not_empty.notify_all();
                    return;
                }
                st = shared.not_empty.wait(st).unwrap();
            }
        };
        shared.not_full.notify_all();
        shared.hist_batch_size.record(batch.len() as f64);
        if shared.trace.enabled() {
            for d in &batch {
                shared.trace.record(
                    TraceEvent::instant("request", "claim", d.admitted_ms, d.ticket.id)
                        .arg("worker", wid as f64),
                );
            }
        }

        match shared.policy {
            BatchPolicy::Window => run_window(&shared, wid, batch),
            BatchPolicy::Continuous => run_continuous(&shared, wid, batch),
        }
    }
}

/// Move a claimed/joined ticket's payload out of the store.
fn attach_payload(st: &mut State, ticket: Ticket, admitted_ms: f64) -> Dispatched {
    let p = st.jobs.remove(&ticket.id).expect("dispatched tickets have payloads");
    Dispatched { ticket, admitted_ms, input: p.input, tx: p.tx }
}

/// The pre-0.9 window path: the whole claimed batch executes as one
/// `run_batch` call and closes; arrivals wait for the next window.
fn run_window(shared: &Shared, wid: usize, batch: VecDeque<Dispatched>) {
    // move the tensors out of the jobs rather than cloning them: the
    // input copy would otherwise dominate the virtual backend's cost
    let mut inputs = Vec::with_capacity(batch.len());
    let mut replies = Vec::with_capacity(batch.len());
    for d in batch {
        inputs.push(d.input);
        replies.push((d.ticket, d.admitted_ms, d.tx));
    }
    let ts0 = shared.clock.now_ms();
    let t0 = Instant::now();
    let mut results = shared.backend.run_batch(&shared.program, &inputs).into_iter();
    let wall = t0.elapsed().as_secs_f64() * 1e3;
    let wall_each = wall / inputs.len() as f64;
    if shared.trace.enabled() {
        // one span for the whole window (the window path executes the
        // batch as a unit); its duration is measured wall time
        shared.trace.record(
            TraceEvent::span("request", "run", ts0, wall, wid as u64)
                .arg("batch", inputs.len() as f64),
        );
    }

    // walk the replies (not a zip) so a misbehaving run_batch override
    // that returns too few results still answers every waiter and
    // keeps the scheduler's in-flight accounting balanced
    for (ticket, admitted_ms, tx) in replies {
        let res = results.next().unwrap_or_else(|| {
            Err(CompileError::Exec(
                "backend returned fewer results than batch inputs".into(),
            ))
        });
        let wait_ms = (admitted_ms - ticket.enqueued_ms).max(0.0);
        finish_one(shared, wid, &ticket, tx, res, wait_ms, wall_each);
    }
}

/// The continuous path: requests execute one boundary at a time, and
/// after every boundary the worker pulls newly arrived requests into
/// its still-open batch.
fn run_continuous(shared: &Shared, wid: usize, mut batch: VecDeque<Dispatched>) {
    while let Some(d) = batch.pop_front() {
        let now = shared.clock.now_ms();
        if d.ticket.deadline_ms.is_some_and(|dl| dl < now) {
            // overdue before dispatch: don't burn device time on it
            shared.state.lock().unwrap().sched.abandon(wid, d.ticket.id);
            let deadline_ms = d.ticket.deadline_ms.expect("checked above");
            if shared.trace.enabled() {
                shared.trace.record(
                    TraceEvent::instant("request", "expire", now, d.ticket.id)
                        .arg("deadline_ms", deadline_ms),
                );
            }
            let _ = d.tx.send(Err(CompileError::DeadlineMiss { deadline_ms, now_ms: now }));
        } else {
            let ts0 = shared.clock.now_ms();
            let t0 = Instant::now();
            let res = shared.backend.run(&shared.program, &d.input);
            let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
            if shared.trace.enabled() {
                // span duration is the model's latency when the backend
                // reports one (deterministic under a virtual clock),
                // else measured wall time
                let service =
                    res.as_ref().ok().and_then(|r| r.model_latency_ms).unwrap_or(wall_ms);
                shared.trace.record(
                    TraceEvent::span("request", "run", ts0, service, d.ticket.id)
                        .arg("worker", wid as f64),
                );
            }
            let wait_ms = (d.admitted_ms - d.ticket.enqueued_ms).max(0.0);
            finish_one(shared, wid, &d.ticket, d.tx, res, wait_ms, wall_ms);
        }

        // ---- execution boundary: extend the open batch -----------------
        let joined_any = {
            let mut st = shared.state.lock().unwrap();
            let now = shared.clock.now_ms();
            deliver_expired(shared, &mut st, now);
            let joined = st.sched.join(wid, now);
            let any = !joined.is_empty();
            for t in joined {
                let d = attach_payload(&mut st, t, now);
                if shared.trace.enabled() {
                    shared.trace.record(
                        TraceEvent::instant("request", "join", now, d.ticket.id)
                            .arg("worker", wid as f64),
                    );
                }
                batch.push_back(d);
            }
            any
        };
        if joined_any {
            // joins freed queue slots — wake blocked submitters
            shared.not_full.notify_all();
        }
    }
}

/// Record one finished execution (success or backend error) and answer
/// the waiter.
fn finish_one(
    shared: &Shared,
    wid: usize,
    ticket: &Ticket,
    tx: mpsc::Sender<Result<Completion>>,
    res: Result<RunResult>,
    wait_ms: f64,
    wall_ms: f64,
) {
    let now = shared.clock.now_ms();
    let outcome = match res {
        Ok(result) => {
            let late = {
                let mut st = shared.state.lock().unwrap();
                let late = st.sched.complete(wid, ticket.id, now);
                // waiters parked on non-dispatchable work (per-client
                // ordering) or on the shutdown drain need a recheck
                if st.sched.queued() > 0 || shared.shutdown.load(Ordering::SeqCst) {
                    shared.not_empty.notify_all();
                }
                late
            };
            let service_ms = result.model_latency_ms.unwrap_or(wall_ms);
            shared.hist_queue_wait.record(wait_ms);
            if let Some(cold) = result.cold_load_ms {
                shared.hist_cold_load.record(cold);
            }
            {
                let mut s = shared.stats.lock().unwrap();
                s.per_worker[wid] += 1;
                s.record_latency(service_ms);
                s.wait_ms_total += wait_ms;
            }
            if shared.trace.enabled() {
                shared.trace.record(
                    TraceEvent::instant("request", "complete", now, ticket.id)
                        .arg("worker", wid as f64)
                        .arg("wait_ms", wait_ms),
                );
            }
            Ok(Completion { result, wait_ms, wall_ms, worker: wid, deadline_missed: late })
        }
        Err(e) => {
            let mut st = shared.state.lock().unwrap();
            st.sched.fail(wid, ticket.id);
            if st.sched.queued() > 0 || shared.shutdown.load(Ordering::SeqCst) {
                shared.not_empty.notify_all();
            }
            drop(st);
            if shared.trace.enabled() {
                shared.trace.record(
                    TraceEvent::instant("request", "fail", now, ticket.id)
                        .arg("worker", wid as f64),
                );
            }
            Err(e)
        }
    };
    // receiver may have been dropped — not the engine's problem
    let _ = tx.send(outcome);
}

fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

fn snapshot(shared: &Shared) -> EngineStats {
    // lock order is always state -> stats
    let (c, queue_depth, in_flight) = {
        let st = shared.state.lock().unwrap();
        (st.sched.counters(), st.sched.queued(), st.sched.in_flight())
    };
    let s = shared.stats.lock().unwrap();
    let mut lat = s.latencies_ms.clone();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let elapsed_s = shared.started.lock().unwrap().elapsed().as_secs_f64();
    EngineStats {
        backend: shared.backend.name(),
        policy: shared.policy.name(),
        submitted: c.submitted,
        completed: c.completed,
        failed: c.failed,
        rejected: c.rejected,
        deadline_misses: c.deadline_misses(),
        joined: c.joined,
        queue_depth,
        in_flight,
        peak_in_flight: c.peak_in_flight,
        per_worker: s.per_worker.clone(),
        batches: c.batches,
        max_batch_seen: c.max_batch_seen,
        elapsed_s,
        throughput_rps: if elapsed_s > 0.0 { c.completed as f64 / elapsed_s } else { 0.0 },
        p50_ms: percentile(&lat, 0.50),
        p95_ms: percentile(&lat, 0.95),
        mean_wait_ms: if c.completed > 0 {
            s.wait_ms_total / c.completed as f64
        } else {
            0.0
        },
        pool: shared.backend.pool_stats(),
        queue_wait_ms_hist: shared.hist_queue_wait.snapshot(),
        batch_size_hist: shared.hist_batch_size.snapshot(),
        cold_load_ms_hist: shared.hist_cold_load.snapshot(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{VirtualAccelBackend, VirtualClock};
    use crate::zoo;

    fn tinynet_program() -> Arc<Program> {
        Arc::new(crate::testutil::pack_program(&zoo::tinynet(), None))
    }

    #[test]
    fn serves_requests_and_reports_stats() {
        let program = tinynet_program();
        let engine = InferenceEngine::new(
            program.clone(),
            Arc::new(VirtualAccelBackend),
            EngineConfig {
                workers: 3,
                queue_capacity: 8,
                max_batch: 2,
                ..EngineConfig::default()
            },
        );
        let shape = program.input_shape();
        let pending: Vec<PendingRequest> =
            (0..12).map(|_| engine.submit(Tensor::zeros(shape)).unwrap()).collect();
        for p in pending {
            let done = p.wait().unwrap();
            assert!(done.result.model_latency_ms.unwrap() > 0.0);
            assert!(!done.deadline_missed, "no deadlines were configured");
        }
        let stats = engine.shutdown();
        assert_eq!(stats.completed, 12);
        assert_eq!(stats.submitted, 12);
        assert_eq!(stats.failed, 0);
        assert_eq!(stats.deadline_misses, 0);
        assert_eq!(stats.queue_depth, 0);
        assert_eq!(stats.policy, "continuous");
        assert!(stats.p50_ms > 0.0);
        assert!(stats.p95_ms >= stats.p50_ms);
        assert!(stats.throughput_rps > 0.0);
        assert_eq!(stats.per_worker.iter().sum::<u64>(), 12);
        // always-on histograms: one wait sample per completion, at
        // least one batch formed, no pooled backend -> no cold loads
        assert_eq!(stats.queue_wait_ms_hist.count, 12);
        assert!(stats.batch_size_hist.count >= 1);
        assert_eq!(stats.cold_load_ms_hist.count, 0);
    }

    #[test]
    fn trace_records_request_lifecycle() {
        use crate::telemetry::TraceRecorder;
        let program = tinynet_program();
        let clock = Arc::new(VirtualClock::new());
        let rec = Arc::new(TraceRecorder::new());
        let mut engine = InferenceEngine::new_paused_with_clock(
            program.clone(),
            Arc::new(VirtualAccelBackend),
            EngineConfig { workers: 1, ..EngineConfig::default() },
            clock.clone(),
        )
        .with_trace(rec.clone());
        let shape = program.input_shape();
        let pending: Vec<PendingRequest> =
            (0..3).map(|_| engine.submit(Tensor::zeros(shape)).unwrap()).collect();
        engine.start();
        for p in pending {
            p.wait().unwrap();
        }
        engine.shutdown();
        let evs = rec.events();
        for name in ["submit", "claim", "run", "complete"] {
            assert_eq!(
                evs.iter().filter(|e| e.name == name).count(),
                3,
                "expected one `{name}` event per request"
            );
        }
        assert!(evs.iter().all(|e| e.cat == "request"));
    }

    #[test]
    fn bounded_queue_rejects_when_full_with_typed_backpressure() {
        let program = tinynet_program();
        // paused: nothing drains the queue while we fill it
        let engine = InferenceEngine::new_paused(
            program.clone(),
            Arc::new(VirtualAccelBackend),
            EngineConfig {
                workers: 1,
                queue_capacity: 2,
                max_batch: 1,
                ..EngineConfig::default()
            },
        );
        let shape = program.input_shape();
        let a = engine.try_submit(Tensor::zeros(shape)).unwrap();
        let b = engine.try_submit(Tensor::zeros(shape)).unwrap();
        match engine.try_submit(Tensor::zeros(shape)) {
            Err(CompileError::Rejected { depth, deadline_ms }) => {
                assert_eq!(depth, 2);
                assert_eq!(deadline_ms, None, "no queued request carries a deadline");
            }
            other => panic!("expected typed backpressure, got {other:?}"),
        }
        assert_eq!(engine.stats().rejected, 1);
        assert_eq!(engine.queue_depth(), 2);
        let mut engine = engine;
        engine.start();
        a.wait().unwrap();
        b.wait().unwrap();
        let stats = engine.shutdown();
        assert_eq!(stats.completed, 2);
        assert_eq!(stats.rejected, 1);
    }

    #[test]
    fn shutdown_drains_pending_requests() {
        let program = tinynet_program();
        let engine = InferenceEngine::new_paused(
            program.clone(),
            Arc::new(VirtualAccelBackend),
            EngineConfig {
                workers: 2,
                queue_capacity: 16,
                max_batch: 4,
                ..EngineConfig::default()
            },
        );
        let shape = program.input_shape();
        let pending: Vec<PendingRequest> =
            (0..6).map(|_| engine.submit(Tensor::zeros(shape)).unwrap()).collect();
        let mut engine = engine;
        engine.start();
        let stats = engine.shutdown(); // must wait for the 6 queued requests
        assert_eq!(stats.completed, 6);
        for p in pending {
            assert!(p.wait().is_ok());
        }
    }

    #[test]
    fn window_policy_still_serves() {
        let program = tinynet_program();
        let engine = InferenceEngine::new(
            program.clone(),
            Arc::new(VirtualAccelBackend),
            EngineConfig { policy: BatchPolicy::Window, ..EngineConfig::default() },
        );
        let shape = program.input_shape();
        let pending: Vec<PendingRequest> =
            (0..8).map(|_| engine.submit(Tensor::zeros(shape)).unwrap()).collect();
        for p in pending {
            p.wait().unwrap();
        }
        let stats = engine.shutdown();
        assert_eq!(stats.completed, 8);
        assert_eq!(stats.policy, "window");
        assert_eq!(stats.joined, 0, "the window never admits mid-batch");
    }

    #[test]
    fn virtual_clock_expires_queued_deadlines_without_sleeping() {
        let program = tinynet_program();
        let clock = Arc::new(VirtualClock::new());
        // paused: the request can only expire, never execute
        let engine = InferenceEngine::new_paused_with_clock(
            program.clone(),
            Arc::new(VirtualAccelBackend),
            EngineConfig { deadline_ms: Some(5.0), ..EngineConfig::default() },
            clock.clone(),
        );
        let p = engine.submit(Tensor::zeros(program.input_shape())).unwrap();
        clock.advance_ms(10.0);
        let stats = engine.stats(); // stats() sweeps the queue
        assert_eq!(stats.deadline_misses, 1);
        assert_eq!(stats.queue_depth, 0);
        match p.wait() {
            Err(CompileError::DeadlineMiss { deadline_ms, now_ms }) => {
                assert_eq!(deadline_ms, 5.0);
                assert_eq!(now_ms, 10.0);
            }
            other => panic!("expected a deadline miss, got {other:?}"),
        }
    }

    #[test]
    fn percentile_edges() {
        assert_eq!(percentile(&[], 0.5), 0.0);
        assert_eq!(percentile(&[3.0], 0.95), 3.0);
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 1.0), 4.0);
    }
}
