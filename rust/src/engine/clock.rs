//! Serving time source: one [`Clock`] trait with a monotonic wall-clock
//! implementation and a deterministic, manually-advanced [`VirtualClock`]
//! for scheduler tests.
//!
//! Every timestamp the serving stack takes — enqueue times, queue-wait
//! accounting, per-request deadlines — goes through the engine's clock,
//! so swapping in a [`VirtualClock`] makes batch formation, deadline
//! expiry and backpressure onset unit-testable without sleeps or flaky
//! wall-clock timing: the test *sets* the time and observes exactly what
//! the scheduler does at that instant.

use std::sync::Mutex;
use std::time::Instant;

/// A monotonic millisecond time source for the serving stack.
///
/// The epoch is arbitrary (per-clock); only differences between two
/// `now_ms` readings of the *same* clock are meaningful. Implementations
/// must be monotonic: a later call never returns a smaller value.
pub trait Clock: Send + Sync {
    /// Milliseconds since this clock's epoch.
    fn now_ms(&self) -> f64;
}

/// Wall-clock [`Clock`]: milliseconds since the clock was created,
/// measured with [`std::time::Instant`]. The default for
/// [`crate::engine::InferenceEngine`].
pub struct RealClock {
    epoch: Instant,
}

impl RealClock {
    /// A real-time clock whose epoch is "now".
    pub fn new() -> RealClock {
        RealClock { epoch: Instant::now() }
    }
}

impl Default for RealClock {
    fn default() -> Self {
        RealClock::new()
    }
}

impl Clock for RealClock {
    fn now_ms(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64() * 1e3
    }
}

/// Deterministic test [`Clock`]: time stands still until the test
/// advances it.
///
/// ```
/// use shortcutfusion::engine::{Clock, VirtualClock};
/// let clock = VirtualClock::new();
/// assert_eq!(clock.now_ms(), 0.0);
/// clock.advance_ms(5.0);
/// assert_eq!(clock.now_ms(), 5.0);
/// ```
pub struct VirtualClock {
    ms: Mutex<f64>,
}

impl VirtualClock {
    /// A virtual clock starting at 0 ms.
    pub fn new() -> VirtualClock {
        VirtualClock { ms: Mutex::new(0.0) }
    }

    /// Move time forward by `ms` (negative or non-finite steps are
    /// ignored — the clock stays monotonic no matter what a test does).
    pub fn advance_ms(&self, ms: f64) {
        if ms.is_finite() && ms > 0.0 {
            *self.ms.lock().unwrap() += ms;
        }
    }

    /// Jump to an absolute time, clamped to never run backwards.
    pub fn set_ms(&self, ms: f64) {
        let mut now = self.ms.lock().unwrap();
        if ms.is_finite() && ms > *now {
            *now = ms;
        }
    }
}

impl Default for VirtualClock {
    fn default() -> Self {
        VirtualClock::new()
    }
}

impl Clock for VirtualClock {
    fn now_ms(&self) -> f64 {
        *self.ms.lock().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn real_clock_is_monotonic() {
        let c = RealClock::new();
        let a = c.now_ms();
        let b = c.now_ms();
        assert!(b >= a);
        assert!(a >= 0.0);
    }

    #[test]
    fn virtual_clock_only_moves_when_told() {
        let c = VirtualClock::new();
        assert_eq!(c.now_ms(), 0.0);
        assert_eq!(c.now_ms(), 0.0);
        c.advance_ms(2.5);
        assert_eq!(c.now_ms(), 2.5);
        c.set_ms(10.0);
        assert_eq!(c.now_ms(), 10.0);
        // monotonicity guards: backwards jumps and garbage are ignored
        c.set_ms(4.0);
        c.advance_ms(-3.0);
        c.advance_ms(f64::NAN);
        assert_eq!(c.now_ms(), 10.0);
    }
}
