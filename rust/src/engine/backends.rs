//! The three [`ExecutionBackend`] implementations.

use std::sync::Arc;

use super::{ExecutionBackend, RunResult};
use crate::compiler::CompileError;
use crate::funcsim::{execute, Tensor};
use crate::program::Program;
use crate::sim;
use crate::Result;

/// Bit-exact execution through the functional instruction-stream
/// simulator. Requires the program to carry packed quantized parameters
/// (`Compiler::with_params` before `pack`, or the CLI's `--params` /
/// `--random-params`).
pub struct ReferenceBackend;

impl ExecutionBackend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn run(&self, program: &Program, input: &Tensor) -> Result<RunResult> {
        let params = program.params().ok_or_else(|| {
            CompileError::artifact(
                "program carries no quantized parameters — the reference backend needs \
                 them (pack with `Compiler::with_params`, `--params` or `--random-params`)",
            )
        })?;
        let values = execute(program.grouped(), program.stream(), params, input)?;
        let output = values
            .last()
            .cloned()
            .ok_or_else(|| CompileError::Exec("empty graph produced no output".into()))?;
        Ok(RunResult {
            backend: self.name(),
            output: Some(output),
            model_latency_ms: None,
            dram_bytes: None,
            cold_load_ms: None,
            traffic_classes: None,
        })
    }
}

/// The virtual accelerator: replays the *packed* instruction stream
/// against the cycle-accurate timing model and the instruction-level
/// traffic model, reporting per-request latency and DRAM bytes. No
/// tensor arithmetic — this is the serving-cost oracle.
pub struct VirtualAccelBackend;

impl ExecutionBackend for VirtualAccelBackend {
    fn name(&self) -> &'static str {
        "virtual-accel"
    }

    fn run(&self, program: &Program, input: &Tensor) -> Result<RunResult> {
        let gg = program.grouped();
        let expected = program.input_shape();
        if input.shape != expected {
            return Err(CompileError::Exec(format!(
                "input shape {} != program input {}",
                input.shape, expected
            )));
        }
        // Policy and flags come from the artifact itself: the reuse bit of
        // every decoded instruction, the packed-header assignment flags,
        // and the tile schedule recovered from the tile fields.
        let policy = program.policy();
        let alloc = program.alloc_view();
        let plan = crate::tile::TilePlan::from_stream(program.stream());
        let tiles = (!plan.is_empty()).then_some(&plan);
        let timing = sim::simulate_with_tiles(gg, &policy, &alloc, program.cfg(), tiles);
        let staged: Vec<bool> = program.assigns().iter().map(|a| a.staged_input).collect();
        let also: Vec<bool> = program.assigns().iter().map(|a| a.also_dram).collect();
        let traffic = sim::replay(gg, program.stream(), &staged, &also, program.cfg());
        Ok(RunResult {
            backend: self.name(),
            output: None,
            model_latency_ms: Some(timing.latency_ms),
            dram_bytes: Some(traffic.dram_total()),
            cold_load_ms: None,
            traffic_classes: Some(traffic.classes),
        })
    }
}

/// PJRT-backed execution of the AOT HLO artifact. Without the `pjrt`
/// cargo feature the underlying [`crate::runtime::Runtime`] is a stub and
/// every run reports [`CompileError::Unsupported`]; with the feature the
/// client initializes, but per-program HLO dispatch still goes through
/// `runtime::Runtime::load` directly (see MIGRATION.md).
pub struct PjrtBackend;

impl ExecutionBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn run(&self, _program: &Program, _input: &Tensor) -> Result<RunResult> {
        let _rt = crate::runtime::Runtime::cpu()?;
        Err(CompileError::unsupported(
            "pjrt backend: packed programs do not embed HLO artifacts; load the \
             exported .hlo.txt through runtime::Runtime::load (see MIGRATION.md)",
        ))
    }
}

/// Backend registry names accepted by [`backend_by_name`] (and the CLI's
/// `--backend` flag).
pub const BACKEND_NAMES: &[&str] = &["reference", "virtual", "pjrt"];

/// Construct a backend from its registry name (`"virtual-accel"` is
/// accepted as an alias for `"virtual"`).
pub fn backend_by_name(name: &str) -> Option<Arc<dyn ExecutionBackend>> {
    Some(match name {
        "reference" => Arc::new(ReferenceBackend),
        "virtual" | "virtual-accel" => Arc::new(VirtualAccelBackend),
        "pjrt" => Arc::new(PjrtBackend),
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Rng;
    use crate::zoo;

    fn program(with_params: bool) -> Program {
        crate::testutil::pack_program(&zoo::tinynet(), with_params.then_some(5))
    }

    #[test]
    fn reference_backend_produces_output() {
        let p = program(true);
        let shape = p.input_shape();
        let mut rng = Rng::from_seed(2);
        let input = Tensor::from_vec(shape, rng.i8_vec(shape.numel()));
        let r = ReferenceBackend.run(&p, &input).unwrap();
        assert_eq!(r.backend, "reference");
        assert!(r.output.is_some());
        assert!(r.model_latency_ms.is_none());
    }

    #[test]
    fn reference_backend_requires_params() {
        let p = program(false);
        let input = Tensor::zeros(p.input_shape());
        assert!(matches!(
            ReferenceBackend.run(&p, &input),
            Err(CompileError::Artifact(_))
        ));
    }

    #[test]
    fn virtual_backend_reports_costs() {
        let p = program(false);
        let input = Tensor::zeros(p.input_shape());
        let r = VirtualAccelBackend.run(&p, &input).unwrap();
        assert!(r.model_latency_ms.unwrap() > 0.0);
        assert!(r.dram_bytes.unwrap() > 0);
        assert!(r.output.is_none());
    }

    #[test]
    fn virtual_backend_checks_input_shape() {
        let p = program(false);
        let bad = Tensor::zeros(crate::graph::Shape::new(4, 4, 4));
        assert!(VirtualAccelBackend.run(&p, &bad).is_err());
    }

    #[test]
    fn pjrt_backend_is_gated() {
        if cfg!(feature = "pjrt") {
            return; // with a real client the error text differs
        }
        let p = program(false);
        let input = Tensor::zeros(p.input_shape());
        assert!(matches!(
            PjrtBackend.run(&p, &input),
            Err(CompileError::Unsupported(_))
        ));
    }

    #[test]
    fn registry_resolves_names() {
        for &n in BACKEND_NAMES {
            assert!(backend_by_name(n).is_some(), "{n}");
        }
        assert!(backend_by_name("virtual-accel").is_some());
        assert!(backend_by_name("bogus").is_none());
    }
}
