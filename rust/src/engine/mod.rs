//! Unified execution: one [`ExecutionBackend`] trait over every way the
//! repo can run a packed [`Program`], plus a batch-serving
//! [`InferenceEngine`] on top.
//!
//! Before this subsystem the run side was three disconnected code paths
//! with incompatible APIs: the bit-exact functional simulator
//! ([`crate::funcsim`]), the cycle/traffic simulators ([`crate::sim`]),
//! and the feature-gated PJRT runtime ([`crate::runtime`]). They are now
//! the three implementations of one trait, all consuming the same
//! deployable artifact:
//!
//! | backend | computes | reports |
//! |---|---|---|
//! | [`ReferenceBackend`] | bit-exact int8 outputs via funcsim | `output` |
//! | [`VirtualAccelBackend`] | timing + traffic replay of the *packed* instructions | `model_latency_ms`, `dram_bytes` |
//! | [`PjrtBackend`] | AOT HLO artifacts via PJRT (needs the `pjrt` feature) | `output` |
//!
//! [`ShardedBackend`] composes any of them over a multi-device
//! [`crate::shard::ShardPlan`]: it chains the K shard programs with
//! staged hand-off buffers and link-model transfer costs, and is itself
//! an `ExecutionBackend`, so the engine serves sharded models
//! transparently. [`crate::pool::PooledBackend`] wraps any of them (the
//! sharded chain included) to page program weights through a
//! multi-tenant device-DRAM [`crate::pool::BufferPool`].
//!
//! ```no_run
//! use shortcutfusion::compiler::Compiler;
//! use shortcutfusion::config::AccelConfig;
//! use shortcutfusion::engine::{ExecutionBackend, VirtualAccelBackend};
//! use shortcutfusion::funcsim::Tensor;
//! use shortcutfusion::zoo;
//!
//! let compiler = Compiler::new(AccelConfig::kcu1500_int8());
//! let analyzed = compiler.analyze(&zoo::resnet18(224)).unwrap();
//! let lowered = compiler
//!     .lower(&compiler.allocate(&compiler.optimize(&analyzed).unwrap()).unwrap())
//!     .unwrap();
//! let program = compiler.pack(&lowered).unwrap();
//! let input = Tensor::zeros(program.input_shape());
//! let r = VirtualAccelBackend.run(&program, &input).unwrap();
//! println!("{:.3} ms, {} DRAM bytes", r.model_latency_ms.unwrap(), r.dram_bytes.unwrap());
//! ```
//!
//! [`InferenceEngine`] serves concurrent requests against one program:
//! an event-driven continuous-batching core (arrivals join a worker's
//! in-flight batch at execution boundaries — [`BatchPolicy::Continuous`];
//! the pre-0.9 fixed window survives as [`BatchPolicy::Window`]),
//! queue-depth-aware admission with typed backpressure, per-request
//! deadlines, and [`EngineStats`] (throughput, p50/p95 latency from the
//! timing model, queue depth, deadline misses). All scheduling decisions
//! live in the deterministic [`Scheduler`] state machine, timestamped by
//! a [`Clock`] — the wall-clock [`RealClock`] in production, the
//! manually-advanced [`VirtualClock`] in tests.

mod backends;
mod clock;
mod scheduler;
mod serving;
mod sharded;

pub use backends::{
    backend_by_name, PjrtBackend, ReferenceBackend, VirtualAccelBackend, BACKEND_NAMES,
};
pub use clock::{Clock, RealClock, VirtualClock};
pub use scheduler::{
    BatchPolicy, Rejection, SchedCounters, Scheduler, SchedulerConfig, Ticket,
};
pub use serving::{
    Completion, EngineConfig, EngineStats, InferenceEngine, PendingRequest, SubmitOptions,
};
pub use sharded::ShardedBackend;

use crate::funcsim::Tensor;
use crate::program::Program;
use crate::Result;

/// One inference outcome. Which fields are populated depends on what the
/// backend models: the reference simulator produces real tensors, the
/// virtual accelerator produces hardware cost numbers. `PartialEq` so
/// tests can pin windowed-vs-continuous serving equivalence bit-for-bit.
#[derive(Debug, Clone, PartialEq)]
pub struct RunResult {
    /// [`ExecutionBackend::name`] of the producing backend.
    pub backend: &'static str,
    /// The network output tensor (the last node's value), when the
    /// backend computes real values.
    pub output: Option<Tensor>,
    /// Single-request latency predicted by the cycle-accurate timing
    /// model, when the backend models hardware time.
    pub model_latency_ms: Option<f64>,
    /// Bytes crossing the chip boundary for this request (instruction
    /// traffic replay), when the backend models the memory system.
    pub dram_bytes: Option<u64>,
    /// Modeled milliseconds spent paging the program's weight segment
    /// into device DRAM before execution (0 on a pool hit), when the
    /// request went through a [`crate::pool::PooledBackend`].
    pub cold_load_ms: Option<f64>,
    /// Per-tensor-class breakdown of `dram_bytes`
    /// (`{weights, ifm, ofm, shortcut}`), when the backend replays
    /// traffic. `classes.total() == dram_bytes` for the virtual
    /// accelerator; sharded chains sum the per-shard classes.
    pub traffic_classes: Option<crate::telemetry::ClassBytes>,
}

/// Anything that can execute a packed [`Program`] on one input.
///
/// Implementations must be `Send + Sync`: the [`InferenceEngine`] shares
/// one backend instance across its worker threads.
pub trait ExecutionBackend: Send + Sync {
    /// Stable identifier (`"reference"`, `"virtual-accel"`, `"pjrt"`).
    fn name(&self) -> &'static str;

    /// Execute one request.
    fn run(&self, program: &Program, input: &Tensor) -> Result<RunResult>;

    /// Execute a batch claimed from the serving queue. The default runs
    /// requests sequentially; backends with per-batch setup amortization
    /// can override. Overrides must return exactly one result per input,
    /// in order — the engine answers any missing tail entries with typed
    /// errors rather than dropping their requests.
    fn run_batch(&self, program: &Program, inputs: &[Tensor]) -> Vec<Result<RunResult>> {
        inputs.iter().map(|t| self.run(program, t)).collect()
    }

    /// Buffer-pool counters, when this backend (or a backend it wraps)
    /// serves through a [`crate::pool::BufferPool`]. The default — no
    /// pool — is `None`; [`crate::pool::PooledBackend`] reports its
    /// pool's stats and [`ShardedBackend`] forwards to the backend it
    /// chains.
    fn pool_stats(&self) -> Option<crate::pool::PoolStats> {
        None
    }

    /// Pending work this backend already holds beyond the engine's own
    /// queue — the engine's admission controller adds it to the queue
    /// depth on the non-blocking submit path, so load the queue cannot
    /// see (e.g. cold weight loads in flight inside a
    /// [`crate::pool::PooledBackend`]) still produces backpressure. The
    /// default — a backend with no hidden queue — is 0.
    fn queue_depth_hint(&self) -> usize {
        0
    }
}
