//! The continuous-batching scheduler core: a deterministic, clock-free
//! state machine behind the [`crate::engine::InferenceEngine`].
//!
//! The scheduler owns every serving *decision* — admission control,
//! batch formation, mid-batch joins, per-request deadlines, per-client
//! ordering — but no time source, no threads and no tensors: every entry
//! point takes the current time as an argument and returns what happened.
//! The threaded engine drives it under one mutex with a real
//! [`super::Clock`]; tests drive it directly with virtual timestamps, so
//! batch formation, deadline expiry and backpressure onset are exact,
//! repeatable assertions instead of sleep-and-hope timing.
//!
//! ## Dispatch model
//!
//! A worker *claims* a fresh batch when idle ([`Scheduler::claim`]) and —
//! under [`BatchPolicy::Continuous`] — *joins* waiting requests into its
//! still-open batch at every execution boundary ([`Scheduler::join`]):
//! the group/shard boundary at which the modeled accelerator can accept
//! new work without draining the pipeline. Under [`BatchPolicy::Window`]
//! the batch is closed at claim time (the pre-0.9 fixed-window
//! behaviour) and `join` never admits anything.
//!
//! ## Ordering guarantee
//!
//! Responses are never reordered within a client: a queued ticket is
//! only dispatchable to a worker when its client has no request in
//! flight on a *different* worker, and within one worker's batch tickets
//! execute in admission order. Untagged submissions get a fresh client
//! id each, so independent requests spread freely across workers.
//!
//! ## Conservation
//!
//! At every point in virtual time the counters satisfy
//!
//! ```text
//! submitted == completed + failed + expired + queued + in_flight
//! ```
//!
//! with `rejected` counted separately (a rejected request never entered
//! the queue). `rust/tests/prop_invariants.rs` asserts this identity at
//! every step of random arrival/boundary/expiry interleavings.

use std::collections::VecDeque;

/// How a worker's batch relates to requests that arrive while it runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchPolicy {
    /// Fixed batch windows (the pre-0.9 engine): a batch is closed when
    /// claimed and executes to completion; arrivals during execution
    /// wait for the next window.
    Window,
    /// Event-driven continuous batching: arrivals join a worker's
    /// in-flight batch at the next execution boundary instead of
    /// waiting for the window to drain.
    Continuous,
}

impl BatchPolicy {
    /// Stable name (`"window"` / `"continuous"`), as accepted by the
    /// CLI's `--batch-policy` flag.
    pub fn name(&self) -> &'static str {
        match self {
            BatchPolicy::Window => "window",
            BatchPolicy::Continuous => "continuous",
        }
    }

    /// Parse a policy name (the inverse of [`BatchPolicy::name`]).
    pub fn by_name(name: &str) -> Option<BatchPolicy> {
        match name {
            "window" => Some(BatchPolicy::Window),
            "continuous" => Some(BatchPolicy::Continuous),
            _ => None,
        }
    }
}

/// Scheduling knobs of a [`Scheduler`] (the serving-relevant subset of
/// [`crate::engine::EngineConfig`]). Zero sizes are clamped to 1.
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Batch formation policy.
    pub policy: BatchPolicy,
    /// Most requests one worker holds in an open batch.
    pub max_batch: usize,
    /// Admission bound: [`Scheduler::submit`] rejects when the queue
    /// depth (plus the caller's reported extra load) reaches this.
    pub queue_capacity: usize,
    /// Default *relative* deadline applied at submission when the
    /// request carries none; `None` disables deadlines by default.
    pub deadline_ms: Option<f64>,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            policy: BatchPolicy::Continuous,
            max_batch: 8,
            queue_capacity: 64,
            deadline_ms: None,
        }
    }
}

/// One scheduled request as the scheduler sees it (no payload — the
/// engine keeps tensors and reply channels keyed by [`Ticket::id`]).
#[derive(Debug, Clone, PartialEq)]
pub struct Ticket {
    /// Unique id assigned at submission.
    pub id: u64,
    /// Client the request belongs to (ordering domain).
    pub client: u64,
    /// Submission timestamp, on the driving clock.
    pub enqueued_ms: f64,
    /// Absolute deadline on the driving clock, when one applies.
    pub deadline_ms: Option<f64>,
}

/// Typed backpressure: the admission controller turned a request away.
/// Embedded in [`crate::compiler::CompileError::Rejected`] by the
/// engine's submission paths.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Rejection {
    /// Observed load at rejection time: queued requests plus the
    /// backend's reported extra load.
    pub depth: usize,
    /// Earliest absolute deadline among the queued requests — a
    /// retry-after hint (`None` when nothing queued carries one).
    pub deadline_ms: Option<f64>,
}

/// Monotonic counters of a [`Scheduler`] (all-time, not a window).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SchedCounters {
    /// Requests accepted into the queue.
    pub submitted: u64,
    /// Requests turned away by admission control.
    pub rejected: u64,
    /// Requests whose execution finished (deadline met or missed).
    pub completed: u64,
    /// Requests whose execution errored.
    pub failed: u64,
    /// Requests dropped before execution because their deadline passed
    /// (in the queue, or at dispatch inside an open batch).
    pub expired: u64,
    /// Requests that completed *after* their deadline.
    pub late: u64,
    /// Requests admitted into an already-running batch at an execution
    /// boundary (continuous batching's defining event; always 0 under
    /// [`BatchPolicy::Window`]).
    pub joined: u64,
    /// Batches formed by [`Scheduler::claim`].
    pub batches: u64,
    /// Largest open batch ever held by one worker (claimed + joined).
    pub max_batch_seen: usize,
    /// Most requests ever in flight across all workers at once.
    pub peak_in_flight: usize,
}

impl SchedCounters {
    /// Deadline misses: requests dropped unexecuted past their deadline
    /// plus requests completed late.
    pub fn deadline_misses(&self) -> u64 {
        self.expired + self.late
    }
}

/// An in-flight ticket inside a worker's open batch.
#[derive(Debug, Clone)]
struct InFlight {
    id: u64,
    client: u64,
    deadline_ms: Option<f64>,
}

/// Deterministic continuous-batching core. See the [module docs](self)
/// for the dispatch model, ordering guarantee and conservation law.
#[derive(Debug)]
pub struct Scheduler {
    policy: BatchPolicy,
    max_batch: usize,
    queue_capacity: usize,
    default_deadline_ms: Option<f64>,
    queue: VecDeque<Ticket>,
    /// Per-worker open batch (claim order == execution order).
    open: Vec<Vec<InFlight>>,
    counters: SchedCounters,
    next_id: u64,
}

impl Scheduler {
    /// A scheduler for `workers` executors (at least 1).
    pub fn new(cfg: SchedulerConfig, workers: usize) -> Scheduler {
        Scheduler {
            policy: cfg.policy,
            max_batch: cfg.max_batch.max(1),
            queue_capacity: cfg.queue_capacity.max(1),
            default_deadline_ms: cfg.deadline_ms,
            queue: VecDeque::new(),
            open: vec![Vec::new(); workers.max(1)],
            counters: SchedCounters::default(),
            next_id: 0,
        }
    }

    /// The batch formation policy this scheduler runs.
    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }

    /// Admit one request for `client` at `now_ms`, or reject it when the
    /// queue depth plus `extra_load` (backend-reported pending work, e.g.
    /// buffer-pool cold fills) has reached the configured capacity.
    /// `deadline_ms` is an absolute override; `None` applies the
    /// configured default relative deadline.
    pub fn submit(
        &mut self,
        client: u64,
        now_ms: f64,
        deadline_ms: Option<f64>,
        extra_load: usize,
    ) -> Result<Ticket, Rejection> {
        let depth = self.queue.len() + extra_load;
        if depth >= self.queue_capacity {
            self.counters.rejected += 1;
            return Err(Rejection { depth, deadline_ms: self.earliest_queued_deadline() });
        }
        self.next_id += 1;
        let ticket = Ticket {
            id: self.next_id,
            client,
            enqueued_ms: now_ms,
            deadline_ms: deadline_ms.or(self.default_deadline_ms.map(|d| now_ms + d)),
        };
        self.counters.submitted += 1;
        self.queue.push_back(ticket.clone());
        Ok(ticket)
    }

    /// Form a fresh batch for an idle `worker`: up to `max_batch`
    /// dispatchable tickets in queue order. Returns empty when the
    /// worker still holds an open batch or nothing is dispatchable.
    /// Call [`Scheduler::expire`] first so overdue tickets are reported,
    /// not claimed.
    pub fn claim(&mut self, worker: usize, _now_ms: f64) -> Vec<Ticket> {
        if !self.open[worker].is_empty() {
            return Vec::new();
        }
        let taken = self.take_dispatchable(worker, self.max_batch);
        if !taken.is_empty() {
            self.counters.batches += 1;
            self.note_open(worker);
        }
        taken
    }

    /// Admit waiting tickets into `worker`'s open batch at an execution
    /// boundary, up to `max_batch` open. The continuous-batching event:
    /// under [`BatchPolicy::Window`] (or with no open batch) this never
    /// admits anything — the window stays closed.
    pub fn join(&mut self, worker: usize, _now_ms: f64) -> Vec<Ticket> {
        if self.policy != BatchPolicy::Continuous || self.open[worker].is_empty() {
            return Vec::new();
        }
        let room = self.max_batch.saturating_sub(self.open[worker].len());
        let taken = self.take_dispatchable(worker, room);
        if !taken.is_empty() {
            self.counters.joined += taken.len() as u64;
            self.note_open(worker);
        }
        taken
    }

    /// Record that `worker` finished executing ticket `id`. Returns
    /// `true` when the completion missed its deadline (counted in
    /// [`SchedCounters::late`]).
    pub fn complete(&mut self, worker: usize, id: u64, now_ms: f64) -> bool {
        let deadline = self.remove_in_flight(worker, id);
        self.counters.completed += 1;
        let late = deadline.is_some_and(|d| now_ms > d);
        if late {
            self.counters.late += 1;
        }
        late
    }

    /// Record that `worker`'s execution of ticket `id` errored.
    pub fn fail(&mut self, worker: usize, id: u64) {
        self.remove_in_flight(worker, id);
        self.counters.failed += 1;
    }

    /// Drop ticket `id` from `worker`'s open batch unexecuted because
    /// its deadline passed before dispatch (counted in
    /// [`SchedCounters::expired`]).
    pub fn abandon(&mut self, worker: usize, id: u64) {
        self.remove_in_flight(worker, id);
        self.counters.expired += 1;
    }

    /// Remove every queued ticket whose deadline lies strictly before
    /// `now_ms` and return them (counted in [`SchedCounters::expired`]).
    /// The caller answers their waiters with a typed deadline error.
    pub fn expire(&mut self, now_ms: f64) -> Vec<Ticket> {
        let mut expired = Vec::new();
        self.queue.retain(|t| {
            let overdue = t.deadline_ms.is_some_and(|d| d < now_ms);
            if overdue {
                expired.push(t.clone());
            }
            !overdue
        });
        self.counters.expired += expired.len() as u64;
        expired
    }

    /// Requests waiting in the queue.
    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Requests claimed into open batches across all workers.
    pub fn in_flight(&self) -> usize {
        self.open.iter().map(Vec::len).sum()
    }

    /// Size of `worker`'s open batch.
    pub fn open_batch(&self, worker: usize) -> usize {
        self.open[worker].len()
    }

    /// Snapshot of the monotonic counters.
    pub fn counters(&self) -> SchedCounters {
        self.counters.clone()
    }

    /// Earliest absolute deadline among queued tickets.
    fn earliest_queued_deadline(&self) -> Option<f64> {
        self.queue
            .iter()
            .filter_map(|t| t.deadline_ms)
            .min_by(|a, b| a.partial_cmp(b).expect("deadlines are finite"))
    }

    /// Pop up to `limit` dispatchable tickets for `worker`, preserving
    /// queue order. A ticket is dispatchable when its client has no
    /// request in flight on a *different* worker (per-client ordering).
    fn take_dispatchable(&mut self, worker: usize, limit: usize) -> Vec<Ticket> {
        let mut taken = Vec::new();
        let mut i = 0;
        while taken.len() < limit && i < self.queue.len() {
            let client = self.queue[i].client;
            if self.client_busy_elsewhere(client, worker) {
                i += 1;
                continue;
            }
            let t = self.queue.remove(i).expect("index checked");
            self.open[worker].push(InFlight {
                id: t.id,
                client: t.client,
                deadline_ms: t.deadline_ms,
            });
            taken.push(t);
        }
        taken
    }

    /// Whether `client` has an in-flight request on a worker other than
    /// `worker` (tickets behind it must wait to preserve ordering).
    fn client_busy_elsewhere(&self, client: u64, worker: usize) -> bool {
        self.open
            .iter()
            .enumerate()
            .any(|(w, b)| w != worker && b.iter().any(|f| f.client == client))
    }

    /// Update high-water marks after `worker`'s batch changed.
    fn note_open(&mut self, worker: usize) {
        self.counters.max_batch_seen = self.counters.max_batch_seen.max(self.open[worker].len());
        self.counters.peak_in_flight = self.counters.peak_in_flight.max(self.in_flight());
    }

    /// Remove one in-flight ticket, returning its deadline.
    fn remove_in_flight(&mut self, worker: usize, id: u64) -> Option<f64> {
        let batch = &mut self.open[worker];
        let pos = batch
            .iter()
            .position(|f| f.id == id)
            .expect("completion of a ticket the worker does not hold");
        batch.remove(pos).deadline_ms
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sched(policy: BatchPolicy, max_batch: usize, capacity: usize) -> Scheduler {
        Scheduler::new(
            SchedulerConfig {
                policy,
                max_batch,
                queue_capacity: capacity,
                deadline_ms: None,
            },
            2,
        )
    }

    #[test]
    fn window_claims_but_never_joins() {
        let mut s = sched(BatchPolicy::Window, 4, 16);
        for c in 0..3 {
            s.submit(c, 0.0, None, 0).unwrap();
        }
        let batch = s.claim(0, 0.0);
        assert_eq!(batch.len(), 3);
        s.submit(9, 1.0, None, 0).unwrap();
        assert!(s.join(0, 1.0).is_empty(), "window must not admit mid-batch");
        assert_eq!(s.counters().joined, 0);
    }

    #[test]
    fn continuous_joins_up_to_the_batch_bound() {
        let mut s = sched(BatchPolicy::Continuous, 3, 16);
        s.submit(1, 0.0, None, 0).unwrap();
        assert_eq!(s.claim(0, 0.0).len(), 1);
        for c in [2, 3, 4] {
            s.submit(c, 1.0, None, 0).unwrap();
        }
        let joined = s.join(0, 1.0);
        assert_eq!(joined.len(), 2, "room for max_batch - 1 open");
        assert_eq!(s.counters().joined, 2);
        assert_eq!(s.queued(), 1);
        assert_eq!(s.counters().max_batch_seen, 3);
    }

    #[test]
    fn admission_rejects_at_depth_with_a_deadline_hint() {
        let mut s = sched(BatchPolicy::Continuous, 2, 2);
        s.submit(1, 0.0, Some(9.0), 0).unwrap();
        s.submit(2, 0.0, Some(7.0), 0).unwrap();
        let err = s.submit(3, 0.0, None, 0).unwrap_err();
        assert_eq!(err.depth, 2);
        assert_eq!(err.deadline_ms, Some(7.0), "hint is the earliest queued deadline");
        assert_eq!(s.counters().rejected, 1);
        // backend-reported load tightens admission before the queue fills
        let mut s = sched(BatchPolicy::Continuous, 2, 2);
        let err = s.submit(1, 0.0, None, 5).unwrap_err();
        assert_eq!(err.depth, 5);
    }

    #[test]
    fn expiry_and_late_completions_both_count_as_misses() {
        let mut s = sched(BatchPolicy::Continuous, 2, 8);
        s.submit(1, 0.0, Some(5.0), 0).unwrap();
        let t2 = s.submit(2, 0.0, Some(50.0), 0).unwrap();
        let expired = s.expire(10.0);
        assert_eq!(expired.len(), 1);
        assert_eq!(expired[0].client, 1);
        let batch = s.claim(0, 10.0);
        assert_eq!(batch.len(), 1);
        assert!(s.complete(0, t2.id, 60.0), "finished past the deadline");
        let c = s.counters();
        assert_eq!((c.expired, c.late, c.deadline_misses()), (1, 1, 2));
    }

    #[test]
    fn per_client_order_holds_across_workers() {
        let mut s = sched(BatchPolicy::Continuous, 1, 16);
        let a1 = s.submit(7, 0.0, None, 0).unwrap();
        s.submit(7, 0.0, None, 0).unwrap();
        let b1 = s.submit(8, 0.0, None, 0).unwrap();
        assert_eq!(s.claim(0, 0.0)[0].id, a1.id);
        // worker 1 must skip client 7's second request (in flight on
        // worker 0) and dispatch client 8 instead
        let w1 = s.claim(1, 0.0);
        assert_eq!(w1.len(), 1);
        assert_eq!(w1[0].id, b1.id);
        // once a1 completes, 7's second request becomes dispatchable
        s.complete(0, a1.id, 1.0);
        assert_eq!(s.claim(0, 1.0)[0].client, 7);
    }

    #[test]
    fn conservation_holds_through_a_mixed_run() {
        let mut s = sched(BatchPolicy::Continuous, 2, 3);
        let check = |s: &Scheduler| {
            let c = s.counters();
            assert_eq!(
                c.submitted,
                c.completed
                    + c.failed
                    + c.expired
                    + s.queued() as u64
                    + s.in_flight() as u64
            );
        };
        let t1 = s.submit(1, 0.0, None, 0).unwrap();
        let t2 = s.submit(2, 0.0, Some(4.0), 0).unwrap();
        s.submit(3, 0.0, None, 0).unwrap();
        assert!(s.submit(4, 0.0, None, 0).is_err());
        check(&s);
        let b = s.claim(0, 1.0);
        assert_eq!(b.len(), 2);
        check(&s);
        s.complete(0, t1.id, 2.0);
        s.fail(0, t2.id);
        check(&s);
        s.expire(100.0);
        let b = s.claim(1, 100.0);
        assert_eq!(b.len(), 1);
        s.abandon(1, b[0].id);
        check(&s);
        assert_eq!(s.queued() + s.in_flight(), 0);
    }
}
