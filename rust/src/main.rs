//! ShortcutFusion CLI — see `shortcutfusion help`.
fn main() -> anyhow::Result<()> {
    shortcutfusion::coordinator::cli::run(std::env::args().skip(1).collect())
}
