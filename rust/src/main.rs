//! ShortcutFusion CLI — see `shortcutfusion help`.
fn main() {
    if let Err(e) = shortcutfusion::coordinator::cli::run(std::env::args().skip(1).collect()) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
