//! End-to-end telemetry: request spans, always-on metrics, and
//! per-tensor-class DRAM traffic attribution.
//!
//! Zero-dependency (std-only, matching the import subsystem's house
//! style) and deliberately small:
//!
//! - [`trace`] — a [`TraceSink`] trait with a lock-sharded in-memory
//!   [`TraceRecorder`] and a Chrome trace-event JSON exporter. Every
//!   timestamp is passed in by the caller (the engine reads its
//!   [`crate::engine::Clock`]), so traces are byte-deterministic under
//!   [`crate::engine::VirtualClock`].
//! - [`metrics`] — named [`Counter`]s and fixed-bucket [`Histogram`]s
//!   built on atomics: recording is a linear bucket scan plus
//!   `fetch_add`, with no per-event allocation, so the registry stays
//!   always-on in the serving hot path.
//! - [`ClassBytes`] — the `{weights, ifm, ofm, shortcut}` DRAM byte
//!   attribution carried by the analytical model (eq. 8/9) and the
//!   instruction-replay simulator, making the paper's headline
//!   shortcut-traffic share a first-class observable.

pub mod metrics;
pub mod trace;

pub use metrics::{
    Counter, Histogram, HistogramSnapshot, MetricsRegistry, BATCH_BOUNDS, MS_BOUNDS,
};
pub use trace::{NullSink, TraceEvent, TracePhase, TraceRecorder, TraceSink};

use crate::serialize::Json;

/// Per-tensor-class DRAM byte attribution.
///
/// The four classes partition every off-chip byte the cost model (or the
/// replay simulator) charges:
///
/// - `weights` — kernel/bias parameter reads (eq. 8's weight term),
/// - `ifm` — input-feature-map reads, including spill re-reads and tile
///   halo overreads,
/// - `ofm` — output-feature-map writes, including spill writebacks,
/// - `shortcut` — reads of a residual shortcut operand at its consuming
///   eltwise join (the traffic class ShortcutFusion exists to eliminate).
///
/// Invariant maintained by every producer:
/// `total() == DramBreakdown::total` for the same evaluation, and
/// `fm_total() == DramBreakdown::fm_bytes`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ClassBytes {
    /// Parameter (kernel + bias) read bytes.
    pub weights: u64,
    /// Input-feature-map read bytes (incl. spill re-reads, tile halos).
    pub ifm: u64,
    /// Output-feature-map write bytes (incl. spill writebacks).
    pub ofm: u64,
    /// Residual-shortcut read bytes at eltwise joins.
    pub shortcut: u64,
}

impl ClassBytes {
    /// Sum over all four classes.
    pub fn total(&self) -> u64 {
        self.weights + self.ifm + self.ofm + self.shortcut
    }

    /// Feature-map portion: everything except weights.
    pub fn fm_total(&self) -> u64 {
        self.ifm + self.ofm + self.shortcut
    }

    /// Shortcut share of feature-map traffic in `[0, 1]`
    /// (0 when there is no feature-map traffic at all).
    pub fn shortcut_share(&self) -> f64 {
        let fm = self.fm_total();
        if fm == 0 {
            0.0
        } else {
            self.shortcut as f64 / fm as f64
        }
    }

    /// Element-wise accumulate (used by sharded chains and replay).
    pub fn accumulate(&mut self, other: ClassBytes) {
        self.weights += other.weights;
        self.ifm += other.ifm;
        self.ofm += other.ofm;
        self.shortcut += other.shortcut;
    }

    /// Proportionally rescale the feature-map classes so that
    /// `fm_total()` becomes exactly `new_fm`, leaving `weights`
    /// untouched. Integer rounding remainders are absorbed by `ifm`, so
    /// the result conserves `new_fm` exactly.
    ///
    /// Used by strategies whose published cost models overwrite the
    /// aggregate feature-map total (shortcut-mining, SmartShuttle): the
    /// class *ratios* from the structural walk survive, the *sum*
    /// matches the external model.
    pub fn rescale_fm(&self, new_fm: u64) -> ClassBytes {
        let old = self.fm_total();
        if old == 0 {
            // no structural ratio to preserve: charge everything as ifm
            return ClassBytes { weights: self.weights, ifm: new_fm, ofm: 0, shortcut: 0 };
        }
        let ofm = (self.ofm as u128 * new_fm as u128 / old as u128) as u64;
        let shortcut = (self.shortcut as u128 * new_fm as u128 / old as u128) as u64;
        ClassBytes { weights: self.weights, ifm: new_fm - ofm - shortcut, ofm, shortcut }
    }

    /// JSON object with one key per class plus the invariant totals.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("weights", Json::num(self.weights as f64)),
            ("ifm", Json::num(self.ifm as f64)),
            ("ofm", Json::num(self.ofm as f64)),
            ("shortcut", Json::num(self.shortcut as f64)),
            ("total", Json::num(self.total() as f64)),
            ("shortcut_share", Json::Num(self.shortcut_share())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_partition() {
        let c = ClassBytes { weights: 10, ifm: 3, ofm: 2, shortcut: 5 };
        assert_eq!(c.total(), 20);
        assert_eq!(c.fm_total(), 10);
        assert!((c.shortcut_share() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn rescale_conserves_exactly() {
        let c = ClassBytes { weights: 7, ifm: 333, ofm: 334, shortcut: 333 };
        for new_fm in [0u64, 1, 999, 1000, 1001, 123_456_789] {
            let r = c.rescale_fm(new_fm);
            assert_eq!(r.fm_total(), new_fm, "fm_total must hit the target exactly");
            assert_eq!(r.weights, 7, "weights untouched");
        }
    }

    #[test]
    fn rescale_from_empty_goes_to_ifm() {
        let c = ClassBytes { weights: 5, ..ClassBytes::default() };
        let r = c.rescale_fm(100);
        assert_eq!(r, ClassBytes { weights: 5, ifm: 100, ofm: 0, shortcut: 0 });
    }

    #[test]
    fn accumulate_sums_classwise() {
        let mut a = ClassBytes { weights: 1, ifm: 2, ofm: 3, shortcut: 4 };
        a.accumulate(ClassBytes { weights: 10, ifm: 20, ofm: 30, shortcut: 40 });
        assert_eq!(a, ClassBytes { weights: 11, ifm: 22, ofm: 33, shortcut: 44 });
    }

    #[test]
    fn json_carries_share() {
        let c = ClassBytes { weights: 0, ifm: 1, ofm: 1, shortcut: 2 };
        let j = c.to_json();
        assert_eq!(j.get("total").unwrap().as_usize(), Some(4));
        assert!((j.get("shortcut_share").unwrap().as_f64().unwrap() - 0.5).abs() < 1e-12);
    }
}
