//! Span/event tracing: the [`TraceSink`] trait, a lock-sharded in-memory
//! [`TraceRecorder`], and a Chrome trace-event JSON exporter.
//!
//! Design contract (the determinism contract, see ARCHITECTURE.md):
//! the sink never reads a clock — every timestamp is passed in by the
//! instrumented code, which draws it from [`crate::engine::Clock`]. Under
//! [`crate::engine::VirtualClock`] the recorded stream, and therefore the
//! exported JSON, is byte-deterministic: the exporter sorts events by
//! `(ts, cat, name, tid, dur)` so thread interleaving cannot reorder the
//! output, and [`crate::serialize::Json`] objects serialize with sorted
//! keys.

use std::sync::Mutex;

use crate::serialize::Json;

/// How an event spans time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TracePhase {
    /// A complete span with a duration (Chrome phase `"X"`).
    Complete,
    /// A zero-duration point event (Chrome phase `"i"`).
    Instant,
}

/// One recorded event.
///
/// `name` and `cat` are `&'static str` so constructing an event on the
/// serving path allocates only for `args` (and the common lifecycle
/// events pass an empty or small fixed-capacity vector).
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    /// Event category (`"request"`, `"pool"`, `"shard"`).
    pub cat: &'static str,
    /// Event name within the category (`"run"`, `"queued"`, …).
    pub name: &'static str,
    /// Span or instant.
    pub phase: TracePhase,
    /// Start timestamp in clock milliseconds.
    pub ts_ms: f64,
    /// Span duration in milliseconds (0 for instants).
    pub dur_ms: f64,
    /// Logical lane: worker id, request ticket, or shard stage index.
    pub tid: u64,
    /// Small set of numeric annotations (batch size, bytes, …).
    pub args: Vec<(&'static str, f64)>,
}

impl TraceEvent {
    /// A zero-duration instant event with no annotations.
    pub fn instant(cat: &'static str, name: &'static str, ts_ms: f64, tid: u64) -> TraceEvent {
        TraceEvent { cat, name, phase: TracePhase::Instant, ts_ms, dur_ms: 0.0, tid, args: Vec::new() }
    }

    /// A complete span covering `[ts_ms, ts_ms + dur_ms]`.
    pub fn span(
        cat: &'static str,
        name: &'static str,
        ts_ms: f64,
        dur_ms: f64,
        tid: u64,
    ) -> TraceEvent {
        TraceEvent { cat, name, phase: TracePhase::Complete, ts_ms, dur_ms, tid, args: Vec::new() }
    }

    /// Attach a numeric annotation (builder style).
    pub fn arg(mut self, key: &'static str, value: f64) -> TraceEvent {
        self.args.push((key, value));
        self
    }
}

/// Where instrumented code sends events.
///
/// The default sink is [`NullSink`]; instrumentation checks
/// [`TraceSink::enabled`] before building an event so the disabled path
/// costs one virtual call and no allocation.
pub trait TraceSink: Send + Sync {
    /// Record one event.
    fn record(&self, event: TraceEvent);

    /// Whether events are being kept. Callers skip event construction
    /// (and the clock read for durations) when this is `false`.
    fn enabled(&self) -> bool {
        true
    }
}

/// A sink that drops everything — the always-on default.
#[derive(Debug, Default, Clone, Copy)]
pub struct NullSink;

impl TraceSink for NullSink {
    fn record(&self, _event: TraceEvent) {}

    fn enabled(&self) -> bool {
        false
    }
}

/// Number of independently locked event buffers. Events shard by `tid`,
/// so concurrent workers rarely contend on the same mutex.
const SHARDS: usize = 8;

/// Lock-sharded in-memory recorder behind `--trace-out`.
pub struct TraceRecorder {
    shards: [Mutex<Vec<TraceEvent>>; SHARDS],
}

impl TraceRecorder {
    /// An empty recorder.
    pub fn new() -> TraceRecorder {
        TraceRecorder { shards: std::array::from_fn(|_| Mutex::new(Vec::new())) }
    }

    /// All events recorded so far, in the canonical deterministic order
    /// `(ts, cat, name, tid, dur)`.
    pub fn events(&self) -> Vec<TraceEvent> {
        let mut all: Vec<TraceEvent> = Vec::new();
        for shard in &self.shards {
            all.extend(shard.lock().unwrap().iter().cloned());
        }
        all.sort_by(|a, b| {
            a.ts_ms
                .total_cmp(&b.ts_ms)
                .then_with(|| a.cat.cmp(b.cat))
                .then_with(|| a.name.cmp(b.name))
                .then_with(|| a.tid.cmp(&b.tid))
                .then_with(|| a.dur_ms.total_cmp(&b.dur_ms))
        });
        all
    }

    /// Number of events recorded so far.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    /// Whether nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The trace as a Chrome trace-event JSON document
    /// (`chrome://tracing` / Perfetto "JSON" format): an object with a
    /// `traceEvents` array whose `ts`/`dur` are microseconds.
    pub fn to_chrome_json(&self) -> Json {
        let events: Vec<Json> = self
            .events()
            .into_iter()
            .map(|e| {
                let mut pairs = vec![
                    ("name", Json::str(e.name)),
                    ("cat", Json::str(e.cat)),
                    ("ph", Json::str(match e.phase {
                        TracePhase::Complete => "X",
                        TracePhase::Instant => "i",
                    })),
                    ("ts", Json::Num(e.ts_ms * 1e3)),
                    ("pid", Json::num(0.0)),
                    ("tid", Json::num(e.tid as f64)),
                ];
                match e.phase {
                    TracePhase::Complete => pairs.push(("dur", Json::Num(e.dur_ms * 1e3))),
                    // instant scope: thread (the tid lane)
                    TracePhase::Instant => pairs.push(("s", Json::str("t"))),
                }
                if !e.args.is_empty() {
                    pairs.push((
                        "args",
                        Json::Obj(
                            e.args.iter().map(|(k, v)| (k.to_string(), Json::Num(*v))).collect(),
                        ),
                    ));
                }
                Json::obj(pairs)
            })
            .collect();
        Json::obj(vec![
            ("displayTimeUnit", Json::str("ms")),
            ("traceEvents", Json::Arr(events)),
        ])
    }

    /// The Chrome trace serialized with a trailing newline, ready for
    /// `--trace-out FILE`.
    pub fn export_chrome(&self) -> String {
        let mut text = self.to_chrome_json().to_string_pretty();
        text.push('\n');
        text
    }
}

impl Default for TraceRecorder {
    fn default() -> Self {
        TraceRecorder::new()
    }
}

impl TraceSink for TraceRecorder {
    fn record(&self, event: TraceEvent) {
        let shard = (event.tid as usize) % SHARDS;
        self.shards[shard].lock().unwrap().push(event);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn null_sink_is_disabled() {
        assert!(!NullSink.enabled());
        NullSink.record(TraceEvent::instant("request", "submit", 0.0, 1));
    }

    #[test]
    fn recorder_orders_canonically() {
        let rec = TraceRecorder::new();
        // recorded out of order, across shards
        rec.record(TraceEvent::span("request", "run", 2.0, 1.0, 9));
        rec.record(TraceEvent::instant("request", "submit", 1.0, 3));
        rec.record(TraceEvent::instant("pool", "hit", 1.0, 3));
        let evs = rec.events();
        assert_eq!(evs.len(), 3);
        assert_eq!((evs[0].cat, evs[0].name), ("pool", "hit"));
        assert_eq!((evs[1].cat, evs[1].name), ("request", "submit"));
        assert_eq!((evs[2].cat, evs[2].name), ("request", "run"));
    }

    #[test]
    fn chrome_export_shape() {
        let rec = TraceRecorder::new();
        rec.record(TraceEvent::span("request", "run", 1.5, 0.5, 2).arg("batch", 4.0));
        rec.record(TraceEvent::instant("request", "submit", 1.0, 2));
        let doc = rec.to_chrome_json();
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(evs.len(), 2);
        // µs conversion and phase tagging
        assert_eq!(evs[0].get("ph").unwrap().as_str(), Some("i"));
        assert_eq!(evs[0].get("ts").unwrap().as_f64(), Some(1000.0));
        assert_eq!(evs[1].get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(evs[1].get("dur").unwrap().as_f64(), Some(500.0));
        assert_eq!(
            evs[1].get("args").unwrap().get("batch").unwrap().as_f64(),
            Some(4.0)
        );
    }

    #[test]
    fn export_is_deterministic_for_same_events() {
        let make = || {
            let rec = TraceRecorder::new();
            rec.record(TraceEvent::span("request", "run", 2.0, 1.0, 1));
            rec.record(TraceEvent::instant("request", "submit", 0.0, 1));
            rec.record(TraceEvent::instant("request", "claim", 1.0, 0));
            rec.export_chrome()
        };
        assert_eq!(make(), make());
    }
}
