//! Always-on metrics: atomic [`Counter`]s and fixed-bucket
//! [`Histogram`]s behind a named [`MetricsRegistry`].
//!
//! The hot path is allocation-free by construction: a counter bump is
//! one `fetch_add`, a histogram record is a linear scan over a fixed
//! bounds slice plus two `fetch_add`s (bucket + sum). Registration (the
//! only allocating operation) happens once at engine construction;
//! `rust/tests/metrics_overhead.rs` pins the zero-allocation property
//! with a counting global allocator.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::serialize::Json;

/// Default millisecond bucket bounds (upper edges) shared by the
/// latency-flavoured histograms: queue wait and cold-load time.
pub const MS_BOUNDS: &[f64] = &[
    0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0, 500.0, 1000.0,
    2500.0, 5000.0, 10000.0,
];

/// Default batch-size bucket bounds (upper edges).
pub const BATCH_BOUNDS: &[f64] = &[1.0, 2.0, 3.0, 4.0, 6.0, 8.0, 12.0, 16.0, 24.0, 32.0];

/// Fixed-point scale for the histogram running sum: values are
/// accumulated as `round(value * SUM_SCALE)` in a `u64`, keeping the
/// hot path integer-only and the snapshot sum deterministic.
const SUM_SCALE: f64 = 1e3;

/// A monotonically increasing atomic counter.
#[derive(Debug, Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    /// A counter at zero.
    pub fn new() -> Counter {
        Counter { value: AtomicU64::new(0) }
    }

    /// Add one.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Add `n`.
    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// A fixed-bucket histogram over `f64` samples.
///
/// `bounds` are the inclusive upper edges of the first `bounds.len()`
/// buckets; one extra overflow bucket catches everything above the last
/// bound. Negative samples clamp into the first bucket.
#[derive(Debug)]
pub struct Histogram {
    bounds: &'static [f64],
    buckets: Box<[AtomicU64]>,
    count: AtomicU64,
    sum_scaled: AtomicU64,
}

impl Histogram {
    /// A histogram over `bounds` (must be non-empty and strictly
    /// increasing — checked once here, never on the record path).
    pub fn new(bounds: &'static [f64]) -> Histogram {
        assert!(!bounds.is_empty(), "histogram needs at least one bucket bound");
        assert!(
            bounds.windows(2).all(|w| w[0] < w[1]),
            "histogram bounds must be strictly increasing"
        );
        let buckets: Vec<AtomicU64> =
            (0..=bounds.len()).map(|_| AtomicU64::new(0)).collect();
        Histogram {
            bounds,
            buckets: buckets.into_boxed_slice(),
            count: AtomicU64::new(0),
            sum_scaled: AtomicU64::new(0),
        }
    }

    /// Record one sample. Allocation-free: a bounded linear scan plus
    /// three relaxed `fetch_add`s.
    pub fn record(&self, value: f64) {
        let mut idx = self.bounds.len(); // overflow bucket
        for (i, b) in self.bounds.iter().enumerate() {
            if value <= *b {
                idx = i;
                break;
            }
        }
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        let scaled = if value > 0.0 { (value * SUM_SCALE).round() as u64 } else { 0 };
        self.sum_scaled.fetch_add(scaled, Ordering::Relaxed);
    }

    /// Number of samples recorded so far.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// An owned point-in-time copy (the only allocating reader).
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            bounds: self.bounds.to_vec(),
            counts: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum_scaled.load(Ordering::Relaxed) as f64 / SUM_SCALE,
        }
    }
}

/// An owned snapshot of a [`Histogram`].
#[derive(Debug, Clone, PartialEq)]
pub struct HistogramSnapshot {
    /// Upper bucket edges (same as the histogram's bounds).
    pub bounds: Vec<f64>,
    /// Per-bucket sample counts; `counts.len() == bounds.len() + 1`
    /// (the last entry is the overflow bucket).
    pub counts: Vec<u64>,
    /// Total samples.
    pub count: u64,
    /// Sum of samples (fixed-point accumulated, so deterministic).
    pub sum: f64,
}

impl HistogramSnapshot {
    /// An all-zero snapshot over `bounds` (what an engine reports before
    /// any sample lands).
    pub fn empty(bounds: &[f64]) -> HistogramSnapshot {
        HistogramSnapshot {
            bounds: bounds.to_vec(),
            counts: vec![0; bounds.len() + 1],
            count: 0,
            sum: 0.0,
        }
    }

    /// Mean sample value (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Upper-edge quantile estimate: the bound of the first bucket whose
    /// cumulative count reaches `q * count`. Returns the last bound for
    /// overflow samples and 0 when empty. `q` is clamped into `[0, 1]`.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = (q.clamp(0.0, 1.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return self.bounds.get(i).copied().unwrap_or(*self.bounds.last().unwrap());
            }
        }
        *self.bounds.last().unwrap()
    }

    /// JSON form: `{bounds, counts, count, sum}` — everything a later
    /// session needs to merge or re-quantile.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("bounds", Json::Arr(self.bounds.iter().map(|b| Json::Num(*b)).collect())),
            (
                "counts",
                Json::Arr(self.counts.iter().map(|c| Json::num(*c as f64)).collect()),
            ),
            ("count", Json::num(self.count as f64)),
            ("sum", Json::Num(self.sum)),
        ])
    }
}

/// A named registry of counters and histograms.
///
/// `counter`/`histogram` get-or-create: callers register once at
/// construction, keep the returned [`Arc`], and touch only atomics
/// afterwards. Names are `&'static str` so lookups never allocate keys.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    counters: Mutex<BTreeMap<&'static str, Arc<Counter>>>,
    histograms: Mutex<BTreeMap<&'static str, Arc<Histogram>>>,
}

impl MetricsRegistry {
    /// An empty registry.
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// The counter named `name`, created at zero on first use.
    pub fn counter(&self, name: &'static str) -> Arc<Counter> {
        self.counters.lock().unwrap().entry(name).or_insert_with(|| Arc::new(Counter::new())).clone()
    }

    /// The histogram named `name`, created over `bounds` on first use.
    /// Later calls return the existing histogram regardless of `bounds`.
    pub fn histogram(&self, name: &'static str, bounds: &'static [f64]) -> Arc<Histogram> {
        self.histograms
            .lock()
            .unwrap()
            .entry(name)
            .or_insert_with(|| Arc::new(Histogram::new(bounds)))
            .clone()
    }

    /// Snapshot every metric as one JSON object:
    /// `{counters: {name: value}, histograms: {name: snapshot}}`.
    pub fn to_json(&self) -> Json {
        let counters: BTreeMap<String, Json> = self
            .counters
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.to_string(), Json::num(v.get() as f64)))
            .collect();
        let histograms: BTreeMap<String, Json> = self
            .histograms
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.to_string(), v.snapshot().to_json()))
            .collect();
        Json::obj(vec![
            ("counters", Json::Obj(counters)),
            ("histograms", Json::Obj(histograms)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_counts() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn histogram_buckets_and_overflow() {
        let h = Histogram::new(&[1.0, 10.0]);
        h.record(0.5); // bucket 0
        h.record(-3.0); // clamps into bucket 0
        h.record(1.0); // inclusive upper edge -> bucket 0
        h.record(5.0); // bucket 1
        h.record(1e9); // overflow
        let s = h.snapshot();
        assert_eq!(s.counts, vec![3, 1, 1]);
        assert_eq!(s.count, 5);
    }

    #[test]
    fn snapshot_mean_and_quantile() {
        let h = Histogram::new(&[1.0, 2.0, 4.0]);
        for v in [0.5, 1.5, 1.5, 3.0] {
            h.record(v);
        }
        let s = h.snapshot();
        assert!((s.mean() - 1.625).abs() < 1e-9);
        assert_eq!(s.quantile(0.5), 2.0); // 2nd of 4 samples sits in (1,2]
        assert_eq!(s.quantile(1.0), 4.0);
        assert_eq!(HistogramSnapshot::empty(&[1.0]).quantile(0.5), 0.0);
    }

    #[test]
    fn sum_is_fixed_point_deterministic() {
        let h = Histogram::new(&[10.0]);
        for _ in 0..3 {
            h.record(0.1);
        }
        // 3 * round(0.1 * 1000) / 1000 exactly, no float-order drift
        assert_eq!(h.snapshot().sum, 0.3);
    }

    #[test]
    fn registry_get_or_create() {
        let reg = MetricsRegistry::new();
        let a = reg.counter("requests");
        let b = reg.counter("requests");
        a.inc();
        assert_eq!(b.get(), 1);
        let h = reg.histogram("wait_ms", MS_BOUNDS);
        h.record(1.0);
        let j = reg.to_json();
        assert_eq!(j.get("counters").unwrap().get("requests").unwrap().as_usize(), Some(1));
        assert_eq!(
            j.get("histograms").unwrap().get("wait_ms").unwrap().get("count").unwrap().as_usize(),
            Some(1)
        );
    }

    #[test]
    #[should_panic(expected = "strictly increasing")]
    fn unsorted_bounds_rejected() {
        let _ = Histogram::new(&[2.0, 1.0]);
    }
}
