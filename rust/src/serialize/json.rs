//! Minimal JSON value model, parser and writer (serde is unavailable in
//! the offline registry — see DESIGN.md §9).
//!
//! Supports the full JSON grammar except `\u` surrogate pairs beyond the
//! BMP; numbers are kept as `f64` (adequate for model metadata).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` for deterministic output ordering.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// A number (all JSON numbers parse as `f64`).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object with deterministically ordered keys.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build a string value.
    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    /// Build a number value.
    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    /// Field access on an object; `None` otherwise.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The value as a string, if it is one.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The value as a number, if it is one.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The value as a non-negative integer, if it is one.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as usize)
    }

    /// The value as a bool, if it is one.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The value as an array slice, if it is one.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Serialize to a compact string.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in): (String, String, String) = match indent {
            Some(w) => (
                "\n".into(),
                " ".repeat(w * depth),
                " ".repeat(w * (depth + 1)),
            ),
            None => (String::new(), String::new(), String::new()),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(&nl);
                    out.push_str(&pad_in);
                    v.write(out, indent, depth + 1);
                }
                out.push_str(&nl);
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(&nl);
                    out.push_str(&pad_in);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                out.push_str(&nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    /// Byte offset of the failure in the input.
    pub offset: usize,
    /// What went wrong.
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

/// Parse a JSON document (must consume the whole input).
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser { b: input.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, m: &str) -> JsonError {
        JsonError { offset: self.i, message: m.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000C}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // advance one UTF-8 char
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_scalars() {
        for src in ["null", "true", "false", "42", "-3.5", "\"hi\\n\""] {
            let v = parse(src).unwrap();
            let v2 = parse(&v.to_string_compact()).unwrap();
            assert_eq!(v, v2);
        }
    }

    #[test]
    fn nested_structures() {
        let v = parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn pretty_parses_back() {
        let v = parse(r#"{"k":[1,2,3],"m":{"n":true}}"#).unwrap();
        assert_eq!(parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("'single'").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap().as_str(), Some("A"));
    }

    #[test]
    fn usize_accessor() {
        assert_eq!(parse("7").unwrap().as_usize(), Some(7));
        assert_eq!(parse("7.5").unwrap().as_usize(), None);
        assert_eq!(parse("-1").unwrap().as_usize(), None);
    }
}
