//! Serialization substrate.
//!
//! The offline crate registry has no `serde`, so the repo carries its own
//! minimal JSON implementation: a [`Json`] value model, a recursive-descent
//! [`parse`](json::parse) and a writer. On top of it,
//! [`frozen`] defines the *frozen-graph* interchange format — the role the
//! TensorFlow protobuf plays in the paper's front-end (Fig. 4): the model
//! zoo can export graphs to JSON and the parser re-imports them, so the
//! compiler genuinely consumes a serialized model file.

pub mod json;
pub mod frozen;

pub use json::{parse, Json, JsonError};
pub use frozen::{graph_from_json, graph_to_json, load_frozen, save_frozen};
