//! Frozen-graph JSON interchange (the repo's stand-in for the TensorFlow
//! protobuf the paper's CNN parser consumes, Fig. 4).
//!
//! Schema:
//! ```json
//! {
//!   "name": "ResNet50",
//!   "nodes": [
//!     {"name":"input","op":"input","inputs":[],"shape":[224,224,3]},
//!     {"name":"conv1","op":"conv","inputs":["input"],
//!      "k":7,"stride":2,"out_c":64,"pad":"same","depthwise":false},
//!     {"name":"conv1/relu","op":"act","inputs":["conv1"],"act":"relu"},
//!     ...
//!   ]
//! }
//! ```
//! Shapes are re-inferred on load; only the input shape is stored.

use super::json::{parse, Json};
use crate::graph::{Activation, Graph, GraphBuilder, NodeId, OpKind, PadMode, Shape};
use crate::compiler::CompileError;
use crate::Result;

fn act_to_str(a: Activation) -> &'static str {
    match a {
        Activation::Linear => "linear",
        Activation::Relu => "relu",
        Activation::Leaky => "leaky",
        Activation::Relu6 => "relu6",
        Activation::Swish => "swish",
        Activation::Sigmoid => "sigmoid",
        Activation::HardSwish => "hardswish",
        Activation::HardSigmoid => "hardsigmoid",
    }
}

fn act_from_str(s: &str) -> Result<Activation> {
    Ok(match s {
        "linear" => Activation::Linear,
        "relu" => Activation::Relu,
        "leaky" => Activation::Leaky,
        "relu6" => Activation::Relu6,
        "swish" => Activation::Swish,
        "sigmoid" => Activation::Sigmoid,
        "hardswish" => Activation::HardSwish,
        "hardsigmoid" => Activation::HardSigmoid,
        _ => return Err(CompileError::parse(format!("unknown activation {s:?}"))),
    })
}

/// Serialize a graph to the frozen JSON format.
pub fn graph_to_json(g: &Graph) -> Json {
    let nodes: Vec<Json> = g
        .nodes
        .iter()
        .map(|n| {
            let mut pairs: Vec<(&str, Json)> = vec![
                ("name", Json::str(&n.name)),
                (
                    "inputs",
                    Json::Arr(
                        n.inputs
                            .iter()
                            .map(|&i| Json::str(&g.node(i).name))
                            .collect(),
                    ),
                ),
            ];
            match n.op {
                OpKind::Input => {
                    pairs.push(("op", Json::str("input")));
                    pairs.push((
                        "shape",
                        Json::Arr(vec![
                            Json::num(n.out_shape.h as f64),
                            Json::num(n.out_shape.w as f64),
                            Json::num(n.out_shape.c as f64),
                        ]),
                    ));
                }
                OpKind::Conv { k, stride, out_c, pad, depthwise } => {
                    pairs.push(("op", Json::str("conv")));
                    pairs.push(("k", Json::num(k as f64)));
                    pairs.push(("stride", Json::num(stride as f64)));
                    pairs.push(("out_c", Json::num(out_c as f64)));
                    pairs.push(("pad", Json::str(match pad {
                        PadMode::Same => "same",
                        PadMode::Valid => "valid",
                    })));
                    pairs.push(("depthwise", Json::Bool(depthwise)));
                }
                OpKind::Fc { out_c } => {
                    pairs.push(("op", Json::str("fc")));
                    pairs.push(("out_c", Json::num(out_c as f64)));
                }
                OpKind::BatchNorm => pairs.push(("op", Json::str("bn"))),
                OpKind::BiasAdd => pairs.push(("op", Json::str("bias"))),
                OpKind::Act(a) => {
                    pairs.push(("op", Json::str("act")));
                    pairs.push(("act", Json::str(act_to_str(a))));
                }
                OpKind::MaxPool { k, stride } => {
                    pairs.push(("op", Json::str("maxpool")));
                    pairs.push(("k", Json::num(k as f64)));
                    pairs.push(("stride", Json::num(stride as f64)));
                }
                OpKind::AvgPool { k, stride } => {
                    pairs.push(("op", Json::str("avgpool")));
                    pairs.push(("k", Json::num(k as f64)));
                    pairs.push(("stride", Json::num(stride as f64)));
                }
                OpKind::GlobalAvgPool => pairs.push(("op", Json::str("gap"))),
                OpKind::EltwiseAdd => pairs.push(("op", Json::str("add"))),
                OpKind::ScaleMul => pairs.push(("op", Json::str("scale"))),
                OpKind::Concat => pairs.push(("op", Json::str("concat"))),
                OpKind::Upsample { factor } => {
                    pairs.push(("op", Json::str("upsample")));
                    pairs.push(("factor", Json::num(factor as f64)));
                }
                OpKind::Identity => pairs.push(("op", Json::str("identity"))),
            }
            Json::obj(pairs)
        })
        .collect();
    Json::obj(vec![("name", Json::str(&g.name)), ("nodes", Json::Arr(nodes))])
}

/// Deserialize a frozen JSON document into a validated graph.
pub fn graph_from_json(doc: &Json) -> Result<Graph> {
    let name = doc
        .get("name")
        .and_then(Json::as_str)
        .ok_or_else(|| CompileError::parse("missing model name"))?;
    let nodes = doc
        .get("nodes")
        .and_then(Json::as_arr)
        .ok_or_else(|| CompileError::parse("missing nodes array"))?;
    if nodes.is_empty() {
        return Err(CompileError::parse("empty node list"));
    }

    // First node must be the input with an explicit shape.
    let first = &nodes[0];
    if first.get("op").and_then(Json::as_str) != Some("input") {
        return Err(CompileError::parse("first node must be the input"));
    }
    let shape_arr = first
        .get("shape")
        .and_then(Json::as_arr)
        .ok_or_else(|| CompileError::parse("input node lacks shape"))?;
    if shape_arr.len() != 3 {
        return Err(CompileError::parse("input shape must be [h,w,c]"));
    }
    let dim = |i: usize| -> Result<usize> {
        shape_arr[i].as_usize().ok_or_else(|| CompileError::parse(format!("bad input dim {i}")))
    };
    let mut b = GraphBuilder::new(name, Shape::new(dim(0)?, dim(1)?, dim(2)?));

    let mut ids: std::collections::HashMap<String, NodeId> = std::collections::HashMap::new();
    let input_name = first
        .get("name")
        .and_then(Json::as_str)
        .ok_or_else(|| CompileError::parse("input lacks name"))?;
    ids.insert(input_name.to_string(), b.input_id());

    for nd in &nodes[1..] {
        let nname = nd
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| CompileError::parse("node lacks name"))?;
        let op = nd
            .get("op")
            .and_then(Json::as_str)
            .ok_or_else(|| CompileError::parse(format!("node {nname} lacks op")))?;
        let inputs: Vec<NodeId> = nd
            .get("inputs")
            .and_then(Json::as_arr)
            .ok_or_else(|| CompileError::parse(format!("node {nname} lacks inputs")))?
            .iter()
            .map(|j| {
                let s = j
                    .as_str()
                    .ok_or_else(|| CompileError::parse(format!("bad input ref in {nname}")))?;
                ids.get(s)
                    .copied()
                    .ok_or_else(|| CompileError::parse(format!("unknown input {s:?} in {nname}")))
            })
            .collect::<Result<_>>()?;
        let one = || -> Result<NodeId> {
            inputs
                .first()
                .copied()
                .ok_or_else(|| CompileError::parse(format!("{nname}: missing operand")))
        };
        let two = || -> Result<(NodeId, NodeId)> {
            if inputs.len() == 2 {
                Ok((inputs[0], inputs[1]))
            } else {
                Err(CompileError::parse(format!("{nname}: expected 2 operands")))
            }
        };
        let get_usize = |key: &str| -> Result<usize> {
            nd.get(key)
                .and_then(Json::as_usize)
                .ok_or_else(|| CompileError::parse(format!("{nname}: missing {key}")))
        };
        let id = match op {
            "conv" => {
                let pad = match nd.get("pad").and_then(Json::as_str).unwrap_or("same") {
                    "same" => PadMode::Same,
                    "valid" => PadMode::Valid,
                    p => return Err(CompileError::parse(format!("{nname}: bad pad {p:?}"))),
                };
                let depthwise = nd.get("depthwise").and_then(Json::as_bool).unwrap_or(false);
                if depthwise {
                    b.dwconv(nname, one()?, get_usize("k")?, get_usize("stride")?, pad)
                } else {
                    b.conv(
                        nname,
                        one()?,
                        get_usize("k")?,
                        get_usize("stride")?,
                        get_usize("out_c")?,
                        pad,
                    )
                }
            }
            "fc" => b.fc(nname, one()?, get_usize("out_c")?),
            "bn" => b.batchnorm(nname, one()?),
            "bias" => b.bias(nname, one()?),
            "act" => {
                let a = act_from_str(
                    nd.get("act")
                        .and_then(Json::as_str)
                        .ok_or_else(|| CompileError::parse(format!("{nname}: missing act")))?,
                )?;
                b.activation(nname, one()?, a)
            }
            "maxpool" => b.maxpool(nname, one()?, get_usize("k")?, get_usize("stride")?),
            "avgpool" => b.avgpool(nname, one()?, get_usize("k")?, get_usize("stride")?),
            "gap" => b.gap(nname, one()?),
            "add" => {
                let (x, y) = two()?;
                b.add(nname, x, y)
            }
            "scale" => {
                let (x, y) = two()?;
                b.scale(nname, x, y)
            }
            "concat" => {
                let (x, y) = two()?;
                b.concat(nname, x, y)
            }
            "upsample" => b.upsample(nname, one()?, get_usize("factor")?),
            "identity" => b.identity(nname, one()?),
            _ => return Err(CompileError::parse(format!("unknown op {op:?} at node {nname}"))),
        };
        ids.insert(nname.to_string(), id);
    }
    let g = b.finish();
    crate::graph::validate(&g)?;
    Ok(g)
}

/// Save a graph as pretty-printed frozen JSON.
pub fn save_frozen(g: &Graph, path: &std::path::Path) -> Result<()> {
    std::fs::write(path, graph_to_json(g).to_string_pretty())
        .map_err(|e| CompileError::io(path, e))
}

/// Load a frozen JSON model file.
pub fn load_frozen(path: &std::path::Path) -> Result<Graph> {
    let text = std::fs::read_to_string(path).map_err(|e| CompileError::io(path, e))?;
    let doc = parse(&text)
        .map_err(|e| CompileError::parse(format!("{}: {e}", path.display())))?;
    graph_from_json(&doc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::zoo;

    #[test]
    fn round_trip_all_zoo_models() {
        for &name in zoo::MODEL_NAMES {
            let g = zoo::by_name(name, zoo::default_input(name)).unwrap();
            let j = graph_to_json(&g);
            let g2 = graph_from_json(&j).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(g.nodes.len(), g2.nodes.len(), "{name}");
            assert_eq!(g.total_macs(), g2.total_macs(), "{name}");
            for (a, b) in g.nodes.iter().zip(&g2.nodes) {
                assert_eq!(a.op, b.op, "{name}/{}", a.name);
                assert_eq!(a.out_shape, b.out_shape, "{name}/{}", a.name);
            }
        }
    }

    #[test]
    fn file_round_trip() {
        let dir = std::env::temp_dir().join("sf_frozen_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("resnet18.json");
        let g = zoo::resnet18(224);
        save_frozen(&g, &p).unwrap();
        let g2 = load_frozen(&p).unwrap();
        assert_eq!(g.total_macs(), g2.total_macs());
    }

    #[test]
    fn rejects_unknown_input_ref() {
        let doc = parse(
            r#"{"name":"x","nodes":[
              {"name":"input","op":"input","inputs":[],"shape":[8,8,3]},
              {"name":"c","op":"conv","inputs":["nope"],"k":3,"stride":1,"out_c":8,"pad":"same","depthwise":false}
            ]}"#,
        )
        .unwrap();
        assert!(graph_from_json(&doc).is_err());
    }

    #[test]
    fn rejects_missing_attrs() {
        let doc = parse(
            r#"{"name":"x","nodes":[
              {"name":"input","op":"input","inputs":[],"shape":[8,8,3]},
              {"name":"c","op":"conv","inputs":["input"],"stride":1,"out_c":8}
            ]}"#,
        )
        .unwrap();
        assert!(graph_from_json(&doc).is_err());
    }
}
