//! Reuse-aware hardware design-space exploration (§IV as an
//! *optimization tool*).
//!
//! The paper pitches ShortcutFusion as a tool that, *given resource
//! constraints, picks the reuse configuration maximizing on-chip reuse*
//! (Tables II/IV). This module automates that search over whole grids of
//! targets instead of one hand-picked [`AccelConfig`]:
//!
//! 1. [`SearchSpace`] describes the grids — on-chip buffer budget,
//!    MAC-array geometry (`Ti×To`), DRAM bandwidth, input resolution —
//!    crossed with any set of [`crate::compiler::ReuseStrategy`]s and
//!    zoo models, under device ceilings ([`Constraints`]).
//! 2. [`SearchSpace::enumerate`] expands the grids and **prunes**
//!    candidates that violate a ceiling *before* any cut-point search
//!    runs, reporting what was skipped and why.
//! 3. [`SearchSpace::explore`] costs every surviving [`DesignPoint`]
//!    with the crate's analytical models (Algorithm 1 buffers, eq. 8–9
//!    DRAM traffic, cycle-accurate timing) through a shared memoizing
//!    [`Session`] — fusion analysis runs once per model while points
//!    evaluate in parallel across worker threads.
//! 4. [`Exploration`] post-processes the sweep: [`ParetoFront`]s over
//!    `(latency, DRAM bytes, SRAM bytes)` with dominated-point
//!    elimination, and a per-model recommender whose winner goes
//!    straight through [`Compiler::pack`](crate::compiler::Compiler::pack)
//!    into a deployable [`Program`] ([`ExplorePoint::pack`]).
//!
//! ```
//! use shortcutfusion::compiler::Session;
//! use shortcutfusion::config::AccelConfig;
//! use shortcutfusion::explorer::SearchSpace;
//!
//! let exploration = SearchSpace::new(AccelConfig::kcu1500_int8())
//!     .model("tinynet")
//!     .sram_budgets(&[2_000_000, 8_000_000])
//!     .ablation_strategies() // cutpoint, fixed-row, fixed-frame, tile
//!     .explore(&Session::new(), 2)
//!     .unwrap();
//! let best = exploration.recommend("tinynet").unwrap();
//! let program = best.pack().unwrap(); // deployable artifact of the winner
//! assert_eq!(program.model(), "TinyNet-SE");
//! ```
//!
//! The CLI front-end is `shortcutfusion explore` (text/JSON/CSV output);
//! `benches/explorer.rs` measures serial vs parallel vs warm-cache sweep
//! throughput, and `rust/tests/explorer.rs` reproduces the paper's
//! buffer-size ablation (fixed-row/fixed-frame/cutpoint crossover as the
//! SRAM budget shrinks).

mod pareto;
mod space;

pub use pareto::{dominates, dominates_objectives, pareto_indices, ParetoFront};
pub use space::{
    Constraints, DesignPoint, Enumeration, Pruned, SearchSpace, BRAM18K_BYTES,
};

use std::fmt;
use std::sync::Arc;

use crate::compiler::{fan_out, CompileError, CompileReport, Compiler, ReuseStrategy, Session};
use crate::config::AccelConfig;
use crate::program::Program;
use crate::serialize::Json;
use crate::telemetry::ClassBytes;

/// One costed design point: the candidate plus the metrics the sweep
/// ranks it by.
#[derive(Clone)]
pub struct ExplorePoint {
    /// Zoo model name this point was compiled for.
    pub model: String,
    /// Square input resolution.
    pub input: usize,
    /// The derived target configuration.
    pub cfg: AccelConfig,
    /// Strategy that decided the reuse policy.
    pub strategy: Arc<dyn ReuseStrategy>,
    /// End-to-end latency from the cycle-accurate timing model, ms.
    pub latency_ms: f64,
    /// Total DRAM traffic per inference (eq. 9), bytes.
    pub dram_bytes: u64,
    /// Per-tensor-class attribution of `dram_bytes`
    /// (`classes.total() == dram_bytes`).
    pub classes: ClassBytes,
    /// Total on-chip SRAM requirement (eq. 6), bytes.
    pub sram_bytes: usize,
    /// BRAM18K blocks the SRAM requirement maps to (eq. 7).
    pub bram18k: usize,
    /// Average throughput in GOPS.
    pub gops: f64,
    /// Off-chip access reduction vs the everything-once baseline, %.
    pub reduction_pct: f64,
    /// Whether the point satisfies the eq-(10) budget constraints.
    pub feasible: bool,
    /// Groups running row reuse under the chosen policy.
    pub row_groups: usize,
    /// Groups running frame reuse under the chosen policy.
    pub frame_groups: usize,
}

impl fmt::Debug for ExplorePoint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("ExplorePoint")
            .field("model", &self.model)
            .field("input", &self.input)
            .field("cfg", &self.cfg.name)
            .field("strategy", &self.strategy.name())
            .field("latency_ms", &self.latency_ms)
            .field("dram_bytes", &self.dram_bytes)
            .field("sram_bytes", &self.sram_bytes)
            .field("feasible", &self.feasible)
            .finish()
    }
}

impl ExplorePoint {
    fn from_report(point: &DesignPoint, r: &CompileReport) -> ExplorePoint {
        ExplorePoint {
            model: point.model.clone(),
            input: point.input,
            cfg: point.cfg.clone(),
            strategy: point.strategy.clone(),
            latency_ms: r.timing.latency_ms,
            dram_bytes: r.evaluation.dram.total,
            classes: r.evaluation.dram.classes,
            sram_bytes: r.evaluation.sram.total,
            bram18k: r.evaluation.sram.bram18k,
            gops: r.timing.gops,
            reduction_pct: r.reduction_pct(),
            feasible: r.evaluation.feasible,
            row_groups: r.row_groups,
            frame_groups: r.frame_groups,
        }
    }

    /// Name of the deciding strategy.
    pub fn strategy_name(&self) -> &'static str {
        self.strategy.name()
    }

    /// SRAM requirement in KB (the Pareto axis the tables use).
    pub fn sram_kb(&self) -> f64 {
        self.sram_bytes as f64 / 1e3
    }

    /// DRAM traffic in MB.
    pub fn dram_mb(&self) -> f64 {
        self.dram_bytes as f64 / 1e6
    }

    /// Re-compile this point and pack it into a deployable [`Program`]
    /// (stage 6, [`Compiler::pack`]) — the hand-off from *search* to
    /// *deploy*.
    pub fn pack(&self) -> Result<Program, CompileError> {
        // zoo name, imported .onnx, or frozen .json — same resolution
        // the CLI uses; imported parameters ride into the artifact
        let (graph, params) = crate::import::resolve(&self.model, self.input)?;
        let mut compiler = Compiler::with_strategy(self.cfg.clone(), self.strategy.clone());
        let analyzed = compiler.analyze(&graph)?;
        if let Some(p) = params {
            compiler = compiler.with_params(p);
        }
        let lowered =
            compiler.lower(&compiler.allocate(&compiler.optimize(&analyzed)?)?)?;
        compiler.pack(&lowered)
    }

    /// Flat JSON record for machine-readable sweep output.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::str(&self.model)),
            ("input", Json::num(self.input as f64)),
            ("strategy", Json::str(self.strategy.name())),
            ("config", Json::str(&self.cfg.name)),
            ("ti", Json::num(self.cfg.ti as f64)),
            ("to", Json::num(self.cfg.to as f64)),
            ("sram_budget", Json::num(self.cfg.sram_budget as f64)),
            ("dram_gbps", Json::num(self.cfg.dram_gbps)),
            ("latency_ms", Json::num(self.latency_ms)),
            ("dram_bytes", Json::num(self.dram_bytes as f64)),
            ("dram_classes", self.classes.to_json()),
            ("sram_bytes", Json::num(self.sram_bytes as f64)),
            ("bram18k", Json::num(self.bram18k as f64)),
            ("gops", Json::num(self.gops)),
            ("reduction_pct", Json::num(self.reduction_pct)),
            ("feasible", Json::Bool(self.feasible)),
            ("row_groups", Json::num(self.row_groups as f64)),
            ("frame_groups", Json::num(self.frame_groups as f64)),
        ])
    }
}

/// A point the sweep could not cost, with the failing candidate's
/// description.
#[derive(Debug)]
pub struct ExploreFailure {
    /// `model@input [strategy] on cfg` of the failing point.
    pub point: String,
    /// The typed compile failure.
    pub error: CompileError,
}

/// The finished sweep: every costed point plus the pruning/failure
/// context needed to read it honestly.
#[derive(Debug)]
pub struct Exploration {
    /// Costed points, in enumeration (model-major) order.
    pub points: Vec<ExplorePoint>,
    /// Candidates rejected by constraint pruning before costing.
    pub pruned: Vec<Pruned>,
    /// Candidates whose compile failed (isolated per point, like
    /// [`Session::run_jobs`]).
    pub failures: Vec<ExploreFailure>,
}

impl Exploration {
    /// Unique model names in enumeration order.
    pub fn models(&self) -> Vec<String> {
        let mut seen = Vec::new();
        for p in &self.points {
            if !seen.contains(&p.model) {
                seen.push(p.model.clone());
            }
        }
        seen
    }

    /// Feasible points of one model.
    fn feasible_of(&self, model: &str) -> Vec<ExplorePoint> {
        self.points.iter().filter(|p| p.model == model && p.feasible).cloned().collect()
    }

    /// The Pareto front over `(latency, DRAM bytes, SRAM bytes)` of one
    /// model's *feasible* points.
    pub fn pareto_front(&self, model: &str) -> ParetoFront {
        ParetoFront::of(&self.feasible_of(model))
    }

    /// The best feasible point of one model: minimum latency, ties broken
    /// by DRAM traffic, then SRAM footprint (the optimizer's own
    /// ranking), then by enumeration order — so with the default strategy
    /// ordering an exact tie goes to the cut-point optimizer, not a
    /// baseline. `None` when no point of the model satisfies its budget.
    pub fn recommend(&self, model: &str) -> Option<&ExplorePoint> {
        self.points
            .iter()
            .filter(|p| p.model == model && p.feasible)
            .fold(None, |best: Option<&ExplorePoint>, p| match best {
                Some(b)
                    if (b.latency_ms, b.dram_bytes, b.sram_bytes)
                        <= (p.latency_ms, p.dram_bytes, p.sram_bytes) =>
                {
                    Some(b)
                }
                _ => Some(p),
            })
    }
}

impl SearchSpace {
    /// Enumerate, prune, and cost the space through `session`, fanning
    /// the points out over `threads` scoped workers.
    ///
    /// The session's analysis cache shares one fusion analysis per
    /// `(model, input)` across every configuration and strategy, and its
    /// report cache makes re-exploring overlapping spaces (or re-running
    /// a sweep on a warm session) O(1) per revisited point. Per-point
    /// compile failures are isolated into [`Exploration::failures`].
    pub fn explore(
        &self,
        session: &Session,
        threads: usize,
    ) -> Result<Exploration, CompileError> {
        if threads == 0 {
            return Err(CompileError::config("need at least one explore worker thread"));
        }
        let Enumeration { points, pruned } = self.enumerate()?;
        let results: Vec<Result<Arc<CompileReport>, CompileError>> =
            fan_out(points.len(), threads, |i| {
                let p = &points[i];
                session.compile_with(&p.model, p.input, &p.cfg, &p.strategy)
            });
        let mut costed = Vec::with_capacity(points.len());
        let mut failures = Vec::new();
        for (point, result) in points.iter().zip(results) {
            match result {
                Ok(report) => costed.push(ExplorePoint::from_report(point, &report)),
                Err(error) => failures.push(ExploreFailure {
                    point: format!(
                        "{}@{} [{}] on {}",
                        point.model,
                        point.input,
                        point.strategy.name(),
                        point.cfg.name
                    ),
                    error,
                }),
            }
        }
        Ok(Exploration { points: costed, pruned, failures })
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;

    /// A point with hand-set objectives, for Pareto unit tests.
    pub(crate) fn synthetic_point(
        model: &str,
        latency_ms: f64,
        dram_bytes: u64,
        sram_bytes: usize,
    ) -> ExplorePoint {
        ExplorePoint {
            model: model.to_string(),
            input: 64,
            cfg: AccelConfig::kcu1500_int8(),
            strategy: Arc::new(crate::compiler::CutPointStrategy),
            latency_ms,
            dram_bytes,
            classes: ClassBytes::default(),
            sram_bytes,
            bram18k: 0,
            gops: 0.0,
            reduction_pct: 0.0,
            feasible: true,
            row_groups: 0,
            frame_groups: 0,
        }
    }

    #[test]
    fn explore_shares_analysis_and_isolates_failures() {
        let session = Session::new();
        let exploration = SearchSpace::new(AccelConfig::kcu1500_int8())
            .model("resnet18")
            .input_sizes(&[64])
            .sram_budgets(&[2_000_000, 8_000_000])
            .strategy_names(&["fixed-row", "fixed-frame"])
            .unwrap()
            .explore(&session, 4)
            .unwrap();
        assert_eq!(exploration.points.len(), 4);
        assert!(exploration.failures.is_empty());
        let stats = session.stats();
        assert_eq!(stats.analysis_misses, 1, "one fusion analysis for all 4 points");
        assert_eq!(stats.report_misses, 4);
        // fixed strategies are budget-independent in cost, so both budget
        // points of one strategy report identical objectives
        let rows: Vec<_> =
            exploration.points.iter().filter(|p| p.strategy_name() == "fixed-row").collect();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].latency_ms, rows[1].latency_ms);
        assert_eq!(rows[0].dram_bytes, rows[1].dram_bytes);
    }

    #[test]
    fn recommend_prefers_feasible_minimum_latency() {
        let exploration = Exploration {
            points: vec![
                ExplorePoint { feasible: false, ..synthetic_point("m", 0.5, 10, 10) },
                synthetic_point("m", 2.0, 10, 10),
                synthetic_point("m", 1.0, 20, 10),
                synthetic_point("other", 0.1, 1, 1),
            ],
            pruned: Vec::new(),
            failures: Vec::new(),
        };
        let best = exploration.recommend("m").unwrap();
        assert_eq!(best.latency_ms, 1.0, "infeasible 0.5 ms point must lose");
        assert!(exploration.recommend("missing").is_none());
        assert_eq!(exploration.models(), vec!["m".to_string(), "other".to_string()]);
        // the front keeps both feasible trade-offs of model m
        assert_eq!(exploration.pareto_front("m").len(), 2);
    }
}
