//! The typed design-space description: axis grids, resource constraints,
//! and the pruned enumerator that turns them into concrete
//! [`DesignPoint`]s.

use std::fmt;
use std::sync::Arc;

use crate::compiler::strategy::{
    self, CutPointStrategy, FixedReuseStrategy, ReuseStrategy, TileStreamingStrategy,
};
use crate::compiler::CompileError;
use crate::config::AccelConfig;
use crate::isa::ReuseMode;
use crate::zoo;

/// Resource ceilings checked *before* a point is costed. A candidate
/// configuration that cannot exist on the target device is pruned by the
/// enumerator instead of wasting a cut-point search on it.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Constraints {
    /// Device BRAM18K ceiling. Prunes buffer budgets that would need
    /// more BRAM than this ceiling can back (2 KB of usable data per
    /// block at 16-bit width), and clamps each surviving config's
    /// `bram18k_total` so the eq-(10) feasibility check honours the
    /// ceiling too.
    pub max_bram18k: Option<usize>,
    /// Board DRAM bandwidth ceiling in GB/s; faster points are pruned.
    pub max_dram_gbps: Option<f64>,
    /// DSP ceiling for the whole design (`dsp_total`: the MAC arrays'
    /// `Ti×To / mults_per_dsp` plus the base design's datapath
    /// overhead); configurations needing more are pruned.
    pub max_dsp: Option<usize>,
}

/// Usable data bytes one BRAM18K block backs at the 16-bit port width
/// the accelerator's buffers use (1024 × 16-bit words).
pub const BRAM18K_BYTES: usize = 2048;

impl Constraints {
    /// Why `cfg` cannot be realised, or `None` if it satisfies every
    /// ceiling.
    pub fn violation(&self, cfg: &AccelConfig) -> Option<String> {
        if let Some(max) = self.max_bram18k {
            let need = cfg.sram_budget.div_ceil(BRAM18K_BYTES);
            if need > max {
                return Some(format!(
                    "SRAM budget {} B needs ≥ {need} BRAM18K, ceiling {max}",
                    cfg.sram_budget
                ));
            }
        }
        if let Some(max) = self.max_dram_gbps {
            if cfg.dram_gbps > max {
                return Some(format!(
                    "DRAM bandwidth {:.1} GB/s exceeds ceiling {max:.1} GB/s",
                    cfg.dram_gbps
                ));
            }
        }
        if let Some(max) = self.max_dsp {
            if cfg.dsp_total > max {
                return Some(format!(
                    "{}×{} MAC array needs {} DSPs ({} MAC + datapath overhead), ceiling {max}",
                    cfg.ti, cfg.to, cfg.dsp_total, cfg.dsp_mac
                ));
            }
        }
        None
    }
}

/// One concrete candidate of the design space: a model at an input
/// resolution, a fully derived target configuration, and the reuse
/// strategy that will pick its policy.
#[derive(Clone)]
pub struct DesignPoint {
    /// Zoo model name.
    pub model: String,
    /// Square input resolution.
    pub input: usize,
    /// The derived target configuration (axes already applied).
    pub cfg: AccelConfig,
    /// Strategy that decides the reuse policy for this point.
    pub strategy: Arc<dyn ReuseStrategy>,
}

impl fmt::Debug for DesignPoint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("DesignPoint")
            .field("model", &self.model)
            .field("input", &self.input)
            .field("cfg", &self.cfg.name)
            .field("strategy", &self.strategy.name())
            .finish()
    }
}

/// A candidate the enumerator rejected before costing, with the ceiling
/// it violated.
#[derive(Debug, Clone)]
pub struct Pruned {
    /// Zoo model name of the rejected point.
    pub model: String,
    /// Representative input resolution (the rejected config is
    /// input-independent, so one record covers every swept input).
    pub input: usize,
    /// Derived name of the rejected configuration.
    pub cfg_name: String,
    /// Human-readable constraint violation.
    pub reason: String,
}

/// The enumerator's output: the surviving points plus everything it
/// pruned (so sweep reports can say what was skipped and why — a silent
/// cap would read as "covered everything").
#[derive(Debug)]
pub struct Enumeration {
    /// Points that satisfy every constraint, in model-major order.
    pub points: Vec<DesignPoint>,
    /// Constraint-violating candidates, with reasons.
    pub pruned: Vec<Pruned>,
}

/// Builder for a reuse-aware design-space sweep (§IV as an *optimization
/// tool*): grids over the [`AccelConfig`] axes the paper tunes — on-chip
/// buffer budget, MAC-array geometry, DRAM bandwidth, input resolution —
/// crossed with any set of [`ReuseStrategy`]s and models, under device
/// resource constraints.
///
/// Every axis defaults to the base configuration's value, so an empty
/// builder describes exactly one point per model × strategy.
///
/// ```
/// use shortcutfusion::compiler::Session;
/// use shortcutfusion::config::AccelConfig;
/// use shortcutfusion::explorer::SearchSpace;
///
/// let space = SearchSpace::new(AccelConfig::kcu1500_int8())
///     .model("tinynet")
///     .sram_budgets(&[64_000, 8_000_000])
///     .strategy_names(&["fixed-row", "fixed-frame"])
///     .unwrap();
/// let exploration = space.explore(&Session::new(), 2).unwrap();
/// assert_eq!(exploration.points.len(), 4);
/// let best = exploration.recommend("tinynet").unwrap();
/// assert!(best.feasible);
/// ```
#[derive(Clone)]
pub struct SearchSpace {
    base: AccelConfig,
    models: Vec<String>,
    inputs: Vec<usize>,
    sram_budgets: Vec<usize>,
    mac_arrays: Vec<(usize, usize)>,
    dram_gbps: Vec<f64>,
    strategies: Vec<Arc<dyn ReuseStrategy>>,
    constraints: Constraints,
}

impl fmt::Debug for SearchSpace {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("SearchSpace")
            .field("base", &self.base.name)
            .field("models", &self.models)
            .field("inputs", &self.inputs)
            .field("sram_budgets", &self.sram_budgets)
            .field("mac_arrays", &self.mac_arrays)
            .field("dram_gbps", &self.dram_gbps)
            .field("strategies", &self.strategies.iter().map(|s| s.name()).collect::<Vec<_>>())
            .field("constraints", &self.constraints)
            .finish()
    }
}

impl SearchSpace {
    /// An empty space anchored at `base`; unset axes inherit its values.
    pub fn new(base: AccelConfig) -> SearchSpace {
        SearchSpace {
            base,
            models: Vec::new(),
            inputs: Vec::new(),
            sram_budgets: Vec::new(),
            mac_arrays: Vec::new(),
            dram_gbps: Vec::new(),
            strategies: Vec::new(),
            constraints: Constraints::default(),
        }
    }

    /// Add one zoo model (name is validated at [`SearchSpace::enumerate`]).
    pub fn model(mut self, name: &str) -> SearchSpace {
        self.models.push(name.to_string());
        self
    }

    /// Add several zoo models.
    pub fn models(mut self, names: &[&str]) -> SearchSpace {
        self.models.extend(names.iter().map(|n| n.to_string()));
        self
    }

    /// Sweep the whole paper zoo ([`zoo::MODEL_NAMES`]).
    pub fn whole_zoo(self) -> SearchSpace {
        self.models(zoo::MODEL_NAMES)
    }

    /// Input-resolution axis. Unset, every model uses its paper-default
    /// input ([`zoo::default_input`]).
    pub fn input_sizes(mut self, sizes: &[usize]) -> SearchSpace {
        self.inputs = sizes.to_vec();
        self
    }

    /// On-chip buffer budget axis (`sram_budget` bytes, the eq-(10)
    /// constraint the optimizer searches under).
    pub fn sram_budgets(mut self, budgets: &[usize]) -> SearchSpace {
        self.sram_budgets = budgets.to_vec();
        self
    }

    /// MAC-array geometry axis as `(Ti, To)` pairs; `dsp_mac` is derived
    /// as `Ti×To / mults_per_dsp` for each point.
    pub fn mac_arrays(mut self, dims: &[(usize, usize)]) -> SearchSpace {
        self.mac_arrays = dims.to_vec();
        self
    }

    /// Effective DRAM bandwidth axis in GB/s.
    pub fn dram_bandwidths(mut self, gbps: &[f64]) -> SearchSpace {
        self.dram_gbps = gbps.to_vec();
        self
    }

    /// Add one reuse strategy (any [`ReuseStrategy`] implementation,
    /// registry or custom). Unset, the paper's cut-point optimizer runs
    /// alone.
    pub fn strategy(mut self, s: Arc<dyn ReuseStrategy>) -> SearchSpace {
        self.strategies.push(s);
        self
    }

    /// Add registry strategies by name ([`strategy::STRATEGY_NAMES`]);
    /// unknown names are a typed `Config` error.
    pub fn strategy_names(mut self, names: &[&str]) -> Result<SearchSpace, CompileError> {
        for &name in names {
            let s = strategy::by_name(name).ok_or_else(|| {
                CompileError::config(format!(
                    "unknown strategy {name:?} — one of {:?}",
                    strategy::STRATEGY_NAMES
                ))
            })?;
            self.strategies.push(Arc::from(s));
        }
        Ok(self)
    }

    /// The default sweep grid: the paper's ablation trio (`cutpoint`,
    /// `fixed-row`, `fixed-frame`) plus the auto-sweeping depth-first
    /// `tile` streamer, so constrained-SRAM corners where every
    /// whole-frame strategy spills still surface a viable point.
    pub fn ablation_strategies(self) -> SearchSpace {
        self.strategy(Arc::new(CutPointStrategy))
            .strategy(Arc::new(FixedReuseStrategy(ReuseMode::Row)))
            .strategy(Arc::new(FixedReuseStrategy(ReuseMode::Frame)))
            .strategy(Arc::new(TileStreamingStrategy::default()))
    }

    /// Depth-first tile-streaming axis ([`crate::tile`]): one
    /// [`TileStreamingStrategy`] per fixed tile height, so each height
    /// lands as its own sweep point (and can earn its own spot on the
    /// Pareto front). An empty slice adds the single auto-sweeping
    /// strategy, which picks the best height per point itself.
    pub fn tile_sizes(mut self, sizes: &[usize]) -> SearchSpace {
        if sizes.is_empty() {
            self.strategies.push(Arc::new(TileStreamingStrategy::default()));
        }
        for &t in sizes {
            self.strategies.push(Arc::new(TileStreamingStrategy { tile_rows: Some(t) }));
        }
        self
    }

    /// Device BRAM18K ceiling (see [`Constraints::max_bram18k`]).
    pub fn max_bram18k(mut self, blocks: usize) -> SearchSpace {
        self.constraints.max_bram18k = Some(blocks);
        self
    }

    /// Board DRAM bandwidth ceiling in GB/s.
    pub fn max_dram_gbps(mut self, gbps: f64) -> SearchSpace {
        self.constraints.max_dram_gbps = Some(gbps);
        self
    }

    /// Whole-design DSP ceiling (see [`Constraints::max_dsp`]).
    pub fn max_dsp(mut self, dsps: usize) -> SearchSpace {
        self.constraints.max_dsp = Some(dsps);
        self
    }

    /// The configured constraints.
    pub fn constraints(&self) -> &Constraints {
        &self.constraints
    }

    /// Derive the concrete target configuration for one axis combination.
    fn derive_cfg(
        &self,
        (ti, to): (usize, usize),
        sram_budget: usize,
        dram_gbps: f64,
    ) -> AccelConfig {
        let mut cfg = self.base.clone();
        if (ti, to) != (self.base.ti, self.base.to) {
            // Re-derive the MAC-array DSP count only for swept
            // geometries; the base dimensions keep the preset's declared
            // dsp_mac/dsp_total, so the un-swept point reproduces the
            // base config's own compile results exactly.
            cfg.ti = ti;
            cfg.to = to;
            cfg.dsp_mac = (ti * to).div_ceil(cfg.mults_per_dsp.max(1));
            // keep the non-MAC datapath DSP overhead of the base design
            cfg.dsp_total = cfg.dsp_mac + self.base.dsp_total.saturating_sub(self.base.dsp_mac);
        }
        cfg.dram_gbps = dram_gbps;
        cfg.sram_budget = sram_budget;
        if let Some(max) = self.constraints.max_bram18k {
            cfg.bram18k_total = cfg.bram18k_total.min(max);
        }
        // `{}` on f64 prints the shortest round-trip form, so distinct
        // bandwidths always yield distinct names (the CLI keys its
        // Pareto/best markers on the name).
        cfg.name =
            format!("{}/{}x{}-sram{}-dram{}", self.base.name, ti, to, sram_budget, dram_gbps);
        cfg
    }

    /// Expand the grids into concrete points, pruning every candidate
    /// that violates a [`Constraints`] ceiling *before* it is costed.
    ///
    /// Order is model-major (all points of one model are adjacent), which
    /// keeps the shared analysis cache hot during parallel sweeps.
    /// Unknown model names fail the enumeration as a typed
    /// [`CompileError::UnknownModel`].
    pub fn enumerate(&self) -> Result<Enumeration, CompileError> {
        if self.models.is_empty() {
            return Err(CompileError::config("search space has no models"));
        }
        let strategies: Vec<Arc<dyn ReuseStrategy>> = if self.strategies.is_empty() {
            vec![Arc::new(CutPointStrategy)]
        } else {
            self.strategies.clone()
        };
        let budgets = non_empty(&self.sram_budgets, self.base.sram_budget);
        let macs = non_empty(&self.mac_arrays, (self.base.ti, self.base.to));
        let bandwidths = non_empty(&self.dram_gbps, self.base.dram_gbps);
        // Validate the *effective* axes (base-injected defaults
        // included, so a degenerate base config is caught too): a zero
        // MAC dimension or a DRAM bandwidth under one byte per clock
        // (e.g. 0.1 GB/s at 200 MHz truncates to zero bytes/cycle)
        // would divide-by-zero deep in the timing simulator — reject
        // typed instead of panicking in a worker thread.
        if let Some(&(ti, to)) = macs.iter().find(|(ti, to)| *ti == 0 || *to == 0) {
            return Err(CompileError::config(format!(
                "invalid MAC array {ti}x{to}: dimensions must be >= 1"
            )));
        }
        let min_gbps = self.base.freq_mhz * 1e6 / 1e9;
        if let Some(&g) = bandwidths.iter().find(|&&g| !(g >= min_gbps)) {
            return Err(CompileError::config(format!(
                "invalid DRAM bandwidth {g} GB/s: need at least one byte per cycle \
                 ({min_gbps:.3} GB/s at {} MHz)",
                self.base.freq_mhz
            )));
        }

        let mut points = Vec::new();
        let mut pruned = Vec::new();
        for model in &self.models {
            // Fixed-geometry models (tinynet) ignore requested sizes, so
            // points are labeled with the size actually compiled instead
            // of a resolution the builder silently discarded. Model files
            // (.onnx / frozen .json) carry their own geometry and are
            // treated the same way.
            let fixed = match zoo::try_default_input(model) {
                Some(_) => zoo::fixed_input(model),
                None => Some(crate::import::resolve(model, 0)?.0.input().out_shape.h),
            };
            let inputs = match fixed {
                Some(fixed) => vec![fixed],
                None => non_empty(&self.inputs, zoo::default_input(model)),
            };
            for &dims in &macs {
                for &budget in &budgets {
                    for &gbps in &bandwidths {
                        // One derivation + constraint check per config: a
                        // rejected config is recorded once, not once per
                        // input or strategy (the config is independent of
                        // both).
                        let cfg = self.derive_cfg(dims, budget, gbps);
                        if let Some(reason) = self.constraints.violation(&cfg) {
                            pruned.push(Pruned {
                                model: model.clone(),
                                input: inputs[0],
                                cfg_name: cfg.name,
                                reason,
                            });
                            continue;
                        }
                        for &input in &inputs {
                            for strategy in &strategies {
                                points.push(DesignPoint {
                                    model: model.clone(),
                                    input,
                                    cfg: cfg.clone(),
                                    strategy: strategy.clone(),
                                });
                            }
                        }
                    }
                }
            }
        }
        Ok(Enumeration { points, pruned })
    }
}

fn non_empty<T: Clone>(axis: &[T], default: T) -> Vec<T> {
    if axis.is_empty() {
        vec![default]
    } else {
        axis.to_vec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_axes_describe_one_point_per_model_and_strategy() {
        let e = SearchSpace::new(AccelConfig::kcu1500_int8())
            .models(&["resnet18", "yolov2"])
            .ablation_strategies()
            .enumerate()
            .unwrap();
        assert_eq!(e.points.len(), 2 * 4);
        assert!(e.pruned.is_empty());
        // model-major order keeps the analysis cache hot
        assert!(e.points[..4].iter().all(|p| p.model == "resnet18"));
        // defaults inherited from the base config
        assert_eq!(e.points[0].input, 224);
        assert_eq!(e.points[0].cfg.sram_budget, AccelConfig::kcu1500_int8().sram_budget);
    }

    #[test]
    fn grids_cross_and_configs_derive() {
        let e = SearchSpace::new(AccelConfig::kcu1500_int8())
            .model("resnet18")
            .input_sizes(&[64, 96])
            .sram_budgets(&[1_000_000, 2_000_000])
            .mac_arrays(&[(32, 32), (64, 64)])
            .dram_bandwidths(&[8.0])
            .enumerate()
            .unwrap();
        assert_eq!(e.points.len(), 2 * 2 * 2);
        let small = e
            .points
            .iter()
            .find(|p| p.cfg.ti == 32 && p.cfg.sram_budget == 1_000_000)
            .unwrap();
        // dsp_mac tracks the array geometry: 32×32 / 2 mults per DSP
        assert_eq!(small.cfg.dsp_mac, 512);
        assert_eq!(small.cfg.dram_gbps, 8.0);
        assert!(small.cfg.name.contains("32x32"));
        // distinct derived names -> distinct session cache keys
        let names: std::collections::BTreeSet<_> =
            e.points.iter().map(|p| p.cfg.name.clone()).collect();
        assert_eq!(names.len(), 4, "input axis reuses cfg, other axes rename");
    }

    #[test]
    fn tile_axis_adds_one_strategy_per_height() {
        let e = SearchSpace::new(AccelConfig::kcu1500_int8())
            .model("resnet18")
            .tile_sizes(&[8, 32])
            .enumerate()
            .unwrap();
        assert_eq!(e.points.len(), 2);
        let names: Vec<_> = e.points.iter().map(|p| p.strategy.name()).collect();
        assert!(names.contains(&"tile-8") && names.contains(&"tile-32"), "{names:?}");
        let auto = SearchSpace::new(AccelConfig::kcu1500_int8())
            .model("resnet18")
            .tile_sizes(&[])
            .enumerate()
            .unwrap();
        assert_eq!(auto.points.len(), 1);
        assert_eq!(auto.points[0].strategy.name(), "tile");
    }

    #[test]
    fn constraints_prune_before_costing() {
        let e = SearchSpace::new(AccelConfig::kcu1500_int8())
            .model("resnet18")
            .sram_budgets(&[1_000_000, 100_000_000])
            .mac_arrays(&[(64, 64), (256, 256)])
            .max_bram18k(4320)
            .max_dsp(4096)
            .enumerate()
            .unwrap();
        // 100 MB of SRAM needs ~48k BRAM18K; 256×256 needs 32k DSPs
        assert_eq!(e.points.len(), 1);
        assert_eq!(e.pruned.len(), 3);
        assert!(e.pruned.iter().any(|p| p.reason.contains("BRAM18K")));
        assert!(e.pruned.iter().any(|p| p.reason.contains("DSPs")));
        // the surviving config honours the BRAM ceiling in feasibility
        assert!(e.points[0].cfg.bram18k_total <= 4320);
    }

    #[test]
    fn fixed_geometry_models_ignore_the_input_axis_honestly() {
        // tinynet always builds at 16×16×8; its points must be labeled
        // with the size actually compiled, not the requested axis value.
        let e = SearchSpace::new(AccelConfig::kcu1500_int8())
            .model("tinynet")
            .input_sizes(&[224])
            .enumerate()
            .unwrap();
        assert_eq!(e.points.len(), 1);
        assert_eq!(e.points[0].input, crate::zoo::TINYNET_INPUT.w);
    }

    #[test]
    fn base_mac_dims_keep_the_preset_dsp_counts() {
        // table2_int16 declares dsp_mac = 2048 at Ti=To=32 (shared
        // array); the un-swept point must reproduce it, not re-derive
        // 32*32/1 = 1024.
        let base = AccelConfig::table2_int16();
        let e = SearchSpace::new(base.clone()).model("resnet18").enumerate().unwrap();
        assert_eq!(e.points[0].cfg.dsp_mac, base.dsp_mac);
        assert_eq!(e.points[0].cfg.dsp_total, base.dsp_total);
        // a genuinely swept geometry is re-derived
        let e = SearchSpace::new(base.clone())
            .model("resnet18")
            .mac_arrays(&[(16, 16)])
            .enumerate()
            .unwrap();
        assert_eq!(e.points[0].cfg.dsp_mac, 16 * 16 / base.mults_per_dsp);
    }

    #[test]
    fn sub_byte_per_cycle_bandwidth_is_a_typed_error() {
        // 0.1 GB/s at 200 MHz rounds to zero DRAM bytes per cycle, which
        // the timing model divides by — must be rejected up front.
        for bad in [0.0, 0.1, -1.0, f64::NAN] {
            let err = SearchSpace::new(AccelConfig::kcu1500_int8())
                .model("resnet18")
                .dram_bandwidths(&[bad])
                .enumerate()
                .unwrap_err();
            assert!(matches!(err, CompileError::Config(_)), "{bad}");
        }
        // a degenerate *base* bandwidth is caught even with no explicit
        // axis (the default axis injects the base value)
        let mut slow = AccelConfig::kcu1500_int8();
        slow.dram_gbps = 0.1;
        let err =
            SearchSpace::new(slow).model("resnet18").enumerate().unwrap_err();
        assert!(matches!(err, CompileError::Config(_)));
    }

    #[test]
    fn zero_mac_dimension_is_a_typed_error() {
        let err = SearchSpace::new(AccelConfig::kcu1500_int8())
            .model("resnet18")
            .mac_arrays(&[(0, 64)])
            .enumerate()
            .unwrap_err();
        assert!(matches!(err, CompileError::Config(_)), "{err}");
    }

    #[test]
    fn unknown_model_fails_enumeration_typed() {
        let err = SearchSpace::new(AccelConfig::kcu1500_int8())
            .model("alexnet")
            .enumerate()
            .unwrap_err();
        assert!(matches!(err, CompileError::UnknownModel { .. }));
        let err = SearchSpace::new(AccelConfig::kcu1500_int8()).enumerate().unwrap_err();
        assert!(matches!(err, CompileError::Config(_)));
    }
}
