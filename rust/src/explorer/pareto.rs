//! Pareto-front extraction over evaluated design points.
//!
//! The explorer's objective space is `(latency ms, DRAM bytes, SRAM
//! bytes)` — the three quantities the paper trades against each other in
//! Tables II–IV: a point is worth reporting only if no other point is at
//! least as good on every axis and strictly better on one.

use super::ExplorePoint;

/// `true` when `a` dominates `b`: no worse on latency, DRAM traffic and
/// SRAM footprint, and strictly better on at least one of them.
pub fn dominates(a: &ExplorePoint, b: &ExplorePoint) -> bool {
    dominates_objectives(&objectives_of(a), &objectives_of(b))
}

/// Generic dominance over equal-length objective vectors, every axis
/// minimized: `a` dominates `b` when it is no worse everywhere and
/// strictly better somewhere. Exactly-equal vectors never dominate each
/// other, and non-finite costs never dominate anything.
pub fn dominates_objectives(a: &[f64], b: &[f64]) -> bool {
    debug_assert_eq!(a.len(), b.len(), "objective vectors must align");
    let mut strictly_better = false;
    for (x, y) in a.iter().zip(b) {
        if x > y {
            return false;
        }
        if x < y {
            strictly_better = true;
        }
    }
    strictly_better
}

/// Indices of the non-dominated subset of `objectives` (all axes
/// minimized), in input order.
///
/// Survival is **order-independent**: a vector survives iff nothing in
/// the *whole* input dominates it, so permuting the candidates can never
/// change which objective vectors make the front. Exactly-equal vectors
/// (duplicates, or distinct designs tied on every axis) are reported
/// once, keeping the first occurrence — with the explorer's
/// deterministic enumeration order that deterministically picks the
/// representative, instead of letting incremental-insertion order decide
/// survival.
pub fn pareto_indices(objectives: &[Vec<f64>]) -> Vec<usize> {
    let mut keep: Vec<usize> = Vec::new();
    'candidates: for (i, obj) in objectives.iter().enumerate() {
        if objectives.iter().any(|other| dominates_objectives(other, obj)) {
            continue;
        }
        for &k in &keep {
            if objectives[k] == *obj {
                continue 'candidates; // keep-first dedup of exact ties
            }
        }
        keep.push(i);
    }
    keep
}

fn objectives_of(p: &ExplorePoint) -> Vec<f64> {
    // u64 DRAM bytes and usize SRAM bytes are far below 2^53, so the
    // f64 view is exact
    vec![p.latency_ms, p.dram_bytes as f64, p.sram_bytes as f64]
}

/// The non-dominated subset of a set of evaluated points, sorted by
/// latency (ties by DRAM traffic, then SRAM footprint).
#[derive(Debug, Clone, Default)]
pub struct ParetoFront {
    /// The surviving (non-dominated) points.
    pub points: Vec<ExplorePoint>,
}

impl ParetoFront {
    /// Eliminate dominated points via [`pareto_indices`]: survival is
    /// order-independent, and duplicate objective vectors keep their
    /// first (enumeration-order) representative only, so the front never
    /// lists the same trade-off twice.
    pub fn of(candidates: &[ExplorePoint]) -> ParetoFront {
        let objectives: Vec<Vec<f64>> = candidates.iter().map(objectives_of).collect();
        let mut points: Vec<ExplorePoint> = pareto_indices(&objectives)
            .into_iter()
            .map(|i| candidates[i].clone())
            .collect();
        points.sort_by(|a, b| {
            (a.latency_ms, a.dram_bytes, a.sram_bytes)
                .partial_cmp(&(b.latency_ms, b.dram_bytes, b.sram_bytes))
                .expect("cost metrics are finite")
        });
        ParetoFront { points }
    }

    /// Number of points on the front.
    pub fn len(&self) -> usize {
        self.points.len()
    }

    /// `true` when no candidate survived (empty input).
    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::synthetic_point;
    use super::*;

    #[test]
    fn dominated_points_are_eliminated() {
        let fast = synthetic_point("m", 1.0, 100, 50);
        let worse_everywhere = synthetic_point("m", 2.0, 200, 60);
        let tradeoff = synthetic_point("m", 2.0, 40, 50); // slower, less DRAM
        let front =
            ParetoFront::of(&[worse_everywhere.clone(), fast.clone(), tradeoff.clone()]);
        assert_eq!(front.len(), 2);
        assert_eq!(front.points[0].latency_ms, 1.0); // sorted by latency
        assert_eq!(front.points[1].dram_bytes, 40);
        assert!(dominates(&fast, &worse_everywhere));
        assert!(!dominates(&fast, &tradeoff));
        assert!(!dominates(&tradeoff, &fast));
    }

    #[test]
    fn equal_points_do_not_dominate_but_dedupe() {
        let a = synthetic_point("m", 1.0, 100, 50);
        let b = synthetic_point("m", 1.0, 100, 50);
        assert!(!dominates(&a, &b));
        assert_eq!(ParetoFront::of(&[a, b]).len(), 1);
    }

    #[test]
    fn empty_input_gives_empty_front() {
        assert!(ParetoFront::of(&[]).is_empty());
    }

    #[test]
    fn survival_is_order_independent_with_duplicates_and_ties() {
        // regression: insertion order must never decide *survival* —
        // only which exact-tie representative is reported (keep-first).
        let a = synthetic_point("m", 1.0, 100, 50);
        let dup = synthetic_point("m", 1.0, 100, 50); // duplicate of a
        let tied = synthetic_point("m", 1.0, 100, 50); // tied on all axes
        let trade = synthetic_point("m", 2.0, 40, 50);
        let dominated = synthetic_point("m", 3.0, 200, 60);
        let candidates = [a, dup, tied, trade, dominated];

        // every permutation of the 5 candidates yields the same
        // surviving objective vectors: (1,100,50) once + (2,40,50)
        let perms: Vec<Vec<usize>> = vec![
            vec![0, 1, 2, 3, 4],
            vec![4, 3, 2, 1, 0],
            vec![2, 4, 0, 3, 1],
            vec![3, 0, 4, 1, 2],
        ];
        for perm in perms {
            let shuffled: Vec<_> = perm.iter().map(|&i| candidates[i].clone()).collect();
            let front = ParetoFront::of(&shuffled);
            assert_eq!(front.len(), 2, "perm {perm:?}");
            let objs: Vec<(f64, u64, usize)> = front
                .points
                .iter()
                .map(|p| (p.latency_ms, p.dram_bytes, p.sram_bytes))
                .collect();
            assert_eq!(objs, vec![(1.0, 100, 50), (2.0, 40, 50)], "perm {perm:?}");
        }
    }

    #[test]
    fn exact_ties_keep_the_first_representative() {
        // two distinct designs tied on every axis: the enumeration-order
        // first one is the reported representative
        let mut first = synthetic_point("m", 1.0, 100, 50);
        first.input = 64;
        let mut second = synthetic_point("m", 1.0, 100, 50);
        second.input = 96;
        let front = ParetoFront::of(&[first.clone(), second.clone()]);
        assert_eq!(front.len(), 1);
        assert_eq!(front.points[0].input, 64);
        let front = ParetoFront::of(&[second, first]);
        assert_eq!(front.len(), 1);
        assert_eq!(front.points[0].input, 96);
    }

    #[test]
    fn generic_objectives_handle_higher_dimensions() {
        // the 4-axis shard front reuses pareto_indices directly
        let objs = vec![
            vec![1.0, 1.0, 10.0, 2.0],
            vec![1.0, 1.0, 10.0, 2.0], // duplicate -> deduped
            vec![2.0, 0.5, 10.0, 2.0], // trade-off on axis 1
            vec![2.0, 1.0, 20.0, 3.0], // dominated by the first
        ];
        assert_eq!(pareto_indices(&objs), vec![0, 2]);
        assert!(dominates_objectives(&objs[0], &objs[3]));
        assert!(!dominates_objectives(&objs[0], &objs[1]), "equals never dominate");
        assert!(!dominates_objectives(&objs[0], &objs[2]));
    }
}
