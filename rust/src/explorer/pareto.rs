//! Pareto-front extraction over evaluated design points.
//!
//! The explorer's objective space is `(latency ms, DRAM bytes, SRAM
//! bytes)` — the three quantities the paper trades against each other in
//! Tables II–IV: a point is worth reporting only if no other point is at
//! least as good on every axis and strictly better on one.

use super::ExplorePoint;

/// `true` when `a` dominates `b`: no worse on latency, DRAM traffic and
/// SRAM footprint, and strictly better on at least one of them.
pub fn dominates(a: &ExplorePoint, b: &ExplorePoint) -> bool {
    let no_worse = a.latency_ms <= b.latency_ms
        && a.dram_bytes <= b.dram_bytes
        && a.sram_bytes <= b.sram_bytes;
    let strictly_better = a.latency_ms < b.latency_ms
        || a.dram_bytes < b.dram_bytes
        || a.sram_bytes < b.sram_bytes;
    no_worse && strictly_better
}

/// The non-dominated subset of a set of evaluated points, sorted by
/// latency (ties by DRAM traffic, then SRAM footprint).
#[derive(Debug, Clone, Default)]
pub struct ParetoFront {
    /// The surviving (non-dominated) points.
    pub points: Vec<ExplorePoint>,
}

impl ParetoFront {
    /// Eliminate dominated points. Duplicate objective vectors keep their
    /// first representative only, so the front never lists the same
    /// trade-off twice.
    pub fn of(candidates: &[ExplorePoint]) -> ParetoFront {
        let mut points: Vec<ExplorePoint> = Vec::new();
        for c in candidates {
            if points.iter().any(|p| dominates(p, c) || same_objectives(p, c)) {
                continue;
            }
            points.retain(|p| !dominates(c, p));
            points.push(c.clone());
        }
        points.sort_by(|a, b| {
            (a.latency_ms, a.dram_bytes, a.sram_bytes)
                .partial_cmp(&(b.latency_ms, b.dram_bytes, b.sram_bytes))
                .expect("cost metrics are finite")
        });
        ParetoFront { points }
    }

    /// Number of points on the front.
    pub fn len(&self) -> usize {
        self.points.len()
    }

    /// `true` when no candidate survived (empty input).
    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }
}

fn same_objectives(a: &ExplorePoint, b: &ExplorePoint) -> bool {
    a.latency_ms == b.latency_ms && a.dram_bytes == b.dram_bytes && a.sram_bytes == b.sram_bytes
}

#[cfg(test)]
mod tests {
    use super::super::tests::synthetic_point;
    use super::*;

    #[test]
    fn dominated_points_are_eliminated() {
        let fast = synthetic_point("m", 1.0, 100, 50);
        let worse_everywhere = synthetic_point("m", 2.0, 200, 60);
        let tradeoff = synthetic_point("m", 2.0, 40, 50); // slower, less DRAM
        let front =
            ParetoFront::of(&[worse_everywhere.clone(), fast.clone(), tradeoff.clone()]);
        assert_eq!(front.len(), 2);
        assert_eq!(front.points[0].latency_ms, 1.0); // sorted by latency
        assert_eq!(front.points[1].dram_bytes, 40);
        assert!(dominates(&fast, &worse_everywhere));
        assert!(!dominates(&fast, &tradeoff));
        assert!(!dominates(&tradeoff, &fast));
    }

    #[test]
    fn equal_points_do_not_dominate_but_dedupe() {
        let a = synthetic_point("m", 1.0, 100, 50);
        let b = synthetic_point("m", 1.0, 100, 50);
        assert!(!dominates(&a, &b));
        assert_eq!(ParetoFront::of(&[a, b]).len(), 1);
    }

    #[test]
    fn empty_input_gives_empty_front() {
        assert!(ParetoFront::of(&[]).is_empty());
    }
}
