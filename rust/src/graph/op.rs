//! Operator kinds and attributes.

/// Padding convention (TensorFlow naming).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PadMode {
    /// Output spatial = ceil(input / stride).
    Same,
    /// No padding.
    Valid,
}

/// Activation functions supported by the accelerator datapath.
///
/// ReLU-family activations run in dynamic fixed-point; `Swish` and
/// `Sigmoid` go through the 8-bit LUT (one 18 Kb BRAM per two LUTs,
/// §III-B) and therefore support a single fixed-point format only.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Activation {
    /// Identity (no activation).
    Linear,
    /// Standard ReLU.
    Relu,
    /// Leaky ReLU with slope 1/8 as in the YOLO accelerator line of work
    /// (hardware-friendly shift implementation).
    Leaky,
    /// ReLU clipped at 6.
    Relu6,
    /// x * sigmoid(x) — EfficientNet/MobileNetV3; 8-bit LUT in hardware.
    Swish,
    /// SE-block gate; 8-bit LUT in hardware.
    Sigmoid,
    /// MobileNetV3 hard-swish: x * relu6(x + 3) / 6.
    HardSwish,
    /// MobileNetV3 / SE hard gate: relu6(x + 3) / 6.
    HardSigmoid,
}

impl Activation {
    /// True when the activation needs the 8-bit LUT path.
    pub fn needs_lut(&self) -> bool {
        matches!(self, Activation::Swish | Activation::Sigmoid)
    }

    /// True when the functional simulator evaluates this activation
    /// through a 256-entry LUT in [`crate::funcsim::Params`] — a
    /// superset of [`Activation::needs_lut`]: the hard (shift-friendly)
    /// variants share the LUT datapath in the simulator even though the
    /// hardware computes them in dynamic fixed-point.
    pub fn lut_evaluated(&self) -> bool {
        matches!(
            self,
            Activation::Relu6
                | Activation::Swish
                | Activation::Sigmoid
                | Activation::HardSwish
                | Activation::HardSigmoid
        )
    }
}

/// Operator kind with static attributes.
///
/// Weight-carrying ops (`Conv`, `Fc`) know their kernel geometry; the
/// actual weight values live outside the IR (the compiler only needs
/// geometry; the functional simulator materializes values from the
/// quantized parameter store).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// Graph input placeholder.
    Input,
    /// Convolution. `depthwise` selects the per-channel form (groups ==
    /// channels); then `out_c` must equal the input channel count.
    Conv {
        /// Square kernel size.
        k: usize,
        /// Spatial stride.
        stride: usize,
        /// Output channel count.
        out_c: usize,
        /// Padding convention.
        pad: PadMode,
        /// Per-channel (depthwise) form when set.
        depthwise: bool,
    },
    /// Fully-connected layer (SE reduce/expand, classifier heads).
    Fc {
        /// Output feature count.
        out_c: usize,
    },
    /// Per-channel affine (folded batch-norm). Fuses into the preceding conv.
    BatchNorm,
    /// Per-element bias add (TF BiasAdd). Fuses into the preceding conv.
    BiasAdd,
    /// Standalone activation node.
    Act(Activation),
    /// Max pooling.
    MaxPool {
        /// Square window size.
        k: usize,
        /// Spatial stride.
        stride: usize,
    },
    /// Average pooling.
    AvgPool {
        /// Square window size.
        k: usize,
        /// Spatial stride.
        stride: usize,
    },
    /// Global average pool → 1×1×C (SE squeeze, classifier pre-FC).
    GlobalAvgPool,
    /// Element-wise addition of two inputs — the *shortcut* layer.
    EltwiseAdd,
    /// Channel-wise scale: input 0 (H×W×C) × input 1 (1×1×C) — the SE
    /// excitation multiply ("works in the same way as the 1x1 depthwise
    /// CONV layer without batch normalization", §IV-A).
    ScaleMul,
    /// Channel concatenation of two inputs (YOLO route layers, FPN).
    Concat,
    /// Nearest-neighbour upsampling by an integer factor.
    Upsample {
        /// Integer scale factor.
        factor: usize,
    },
    /// Detection / output head marker (kept for graph fidelity; no compute).
    Identity,
}

impl OpKind {
    /// Does this op carry weights read from DRAM?
    pub fn has_weights(&self) -> bool {
        matches!(self, OpKind::Conv { .. } | OpKind::Fc { .. })
    }

    /// Is this an element-wise shortcut addition?
    pub fn is_shortcut(&self) -> bool {
        matches!(self, OpKind::EltwiseAdd)
    }

    /// Is this a concat/route op (long-lifetime data kept off-chip,
    /// §IV-A)?
    pub fn is_concat(&self) -> bool {
        matches!(self, OpKind::Concat)
    }

    /// Short mnemonic for reports.
    pub fn mnemonic(&self) -> &'static str {
        match self {
            OpKind::Input => "input",
            OpKind::Conv { depthwise: true, .. } => "dwconv",
            OpKind::Conv { .. } => "conv",
            OpKind::Fc { .. } => "fc",
            OpKind::BatchNorm => "bn",
            OpKind::BiasAdd => "bias",
            OpKind::Act(_) => "act",
            OpKind::MaxPool { .. } => "maxpool",
            OpKind::AvgPool { .. } => "avgpool",
            OpKind::GlobalAvgPool => "gap",
            OpKind::EltwiseAdd => "add",
            OpKind::ScaleMul => "scale",
            OpKind::Concat => "concat",
            OpKind::Upsample { .. } => "upsample",
            OpKind::Identity => "id",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lut_activations() {
        assert!(Activation::Swish.needs_lut());
        assert!(Activation::Sigmoid.needs_lut());
        assert!(!Activation::Relu.needs_lut());
        assert!(!Activation::HardSwish.needs_lut());
    }

    #[test]
    fn op_classification() {
        assert!(OpKind::Conv { k: 3, stride: 1, out_c: 8, pad: PadMode::Same, depthwise: false }
            .has_weights());
        assert!(OpKind::Fc { out_c: 10 }.has_weights());
        assert!(OpKind::EltwiseAdd.is_shortcut());
        assert!(OpKind::Concat.is_concat());
        assert!(!OpKind::MaxPool { k: 2, stride: 2 }.has_weights());
    }
}
