//! Structural validation of a [`Graph`].

use super::{Graph, OpKind};
use std::fmt;

/// Validation failure with the offending node index.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ValidateError {
    /// Index of the offending node.
    pub node: usize,
    /// What the node violated.
    pub reason: String,
}

impl fmt::Display for ValidateError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "graph invalid at node {}: {}", self.node, self.reason)
    }
}

impl std::error::Error for ValidateError {}

/// Check the graph invariants documented on [`Graph`].
pub fn validate(g: &Graph) -> Result<(), ValidateError> {
    let err = |node: usize, reason: String| Err(ValidateError { node, reason });

    if g.nodes.is_empty() {
        return err(0, "empty graph".into());
    }
    let n_inputs = g.nodes.iter().filter(|n| matches!(n.op, OpKind::Input)).count();
    if n_inputs != 1 {
        return err(0, format!("expected exactly 1 Input node, found {n_inputs}"));
    }
    if !matches!(g.nodes[0].op, OpKind::Input) {
        return err(0, "node 0 must be the Input".into());
    }

    let mut seen_names = std::collections::HashSet::new();
    for (i, n) in g.nodes.iter().enumerate() {
        if n.id.0 != i {
            return err(i, format!("id {} != position {}", n.id.0, i));
        }
        if !seen_names.insert(n.name.as_str()) {
            return err(i, format!("duplicate name {:?}", n.name));
        }
        // topological order
        for &inp in &n.inputs {
            if inp.0 >= i {
                return err(i, format!("input {} not before node", inp.0));
            }
        }
        // arity
        let arity = n.inputs.len();
        let want: std::ops::RangeInclusive<usize> = match n.op {
            OpKind::Input => 0..=0,
            OpKind::EltwiseAdd | OpKind::ScaleMul | OpKind::Concat => 2..=2,
            _ => 1..=1,
        };
        if !want.contains(&arity) {
            return err(i, format!("{} has arity {arity}, expected {want:?}", n.op.mnemonic()));
        }
        // cached input shapes in sync
        for (j, &inp) in n.inputs.iter().enumerate() {
            if g.nodes[inp.0].out_shape != n.in_shapes[j] {
                return err(i, format!("cached in_shape[{j}] stale"));
            }
        }
        // shape functions
        match n.op {
            OpKind::EltwiseAdd => {
                if n.in_shapes[0] != n.in_shapes[1] || n.out_shape != n.in_shapes[0] {
                    return err(i, "eltwise-add shape mismatch".into());
                }
            }
            OpKind::ScaleMul => {
                let (f, gate) = (n.in_shapes[0], n.in_shapes[1]);
                if gate.h != 1 || gate.w != 1 || gate.c != f.c || n.out_shape != f {
                    return err(i, "scale-mul gate must be 1x1xC".into());
                }
            }
            OpKind::Concat => {
                let (a, b) = (n.in_shapes[0], n.in_shapes[1]);
                if (a.h, a.w) != (b.h, b.w) || n.out_shape.c != a.c + b.c {
                    return err(i, "concat shape mismatch".into());
                }
            }
            OpKind::Conv { depthwise: true, out_c, .. } => {
                if out_c != n.in_shapes[0].c {
                    return err(i, "depthwise conv must preserve channels".into());
                }
            }
            OpKind::Upsample { factor } => {
                if n.out_shape != n.in_shapes[0].upsample(factor) {
                    return err(i, "upsample shape mismatch".into());
                }
            }
            _ => {}
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Activation, GraphBuilder, Node, NodeId, Shape};

    #[test]
    fn valid_graph_passes() {
        let mut b = GraphBuilder::new("ok", Shape::new(8, 8, 4));
        let c = b.conv("c", b.input_id(), 3, 1, 8, crate::graph::PadMode::Same);
        let _ = b.activation("a", c, Activation::Relu);
        assert!(validate(&b.finish()).is_ok());
    }

    #[test]
    fn rejects_forward_reference() {
        let mut b = GraphBuilder::new("bad", Shape::new(8, 8, 4));
        let c = b.conv("c", b.input_id(), 3, 1, 8, crate::graph::PadMode::Same);
        let mut g = b.finish();
        // corrupt: make conv depend on a later node
        g.nodes[c.0].inputs = vec![NodeId(2)];
        g.nodes.push(Node {
            id: NodeId(2),
            name: "x".into(),
            op: crate::graph::OpKind::Identity,
            inputs: vec![NodeId(1)],
            in_shapes: vec![g.nodes[1].out_shape],
            out_shape: g.nodes[1].out_shape,
        });
        assert!(validate(&g).is_err());
    }

    #[test]
    fn rejects_duplicate_names() {
        let mut b = GraphBuilder::new("dup", Shape::new(8, 8, 4));
        let c = b.conv("c", b.input_id(), 3, 1, 8, crate::graph::PadMode::Same);
        let mut g = b.finish();
        let shape = g.nodes[c.0].out_shape;
        g.nodes.push(Node {
            id: NodeId(2),
            name: "c".into(),
            op: crate::graph::OpKind::Identity,
            inputs: vec![c],
            in_shapes: vec![shape],
            out_shape: shape,
        });
        assert!(validate(&g).is_err());
    }

    #[test]
    fn rejects_bad_arity() {
        let mut b = GraphBuilder::new("arity", Shape::new(8, 8, 4));
        let c = b.conv("c", b.input_id(), 3, 1, 8, crate::graph::PadMode::Same);
        let mut g = b.finish();
        let shape = g.nodes[c.0].out_shape;
        g.nodes.push(Node {
            id: NodeId(2),
            name: "add".into(),
            op: crate::graph::OpKind::EltwiseAdd,
            inputs: vec![c], // needs two
            in_shapes: vec![shape],
            out_shape: shape,
        });
        assert!(validate(&g).is_err());
    }
}
