//! Fluent graph construction used by the model zoo and the JSON parser.

use super::{Activation, Graph, Node, NodeId, OpKind, PadMode, Shape};

/// Builds a [`Graph`] in topological order with automatic shape inference.
///
/// All `add_*` helpers return the new node's id so builders read like the
/// network definitions they mirror:
///
/// ```
/// use shortcutfusion::graph::{GraphBuilder, Shape, PadMode, Activation};
/// let mut b = GraphBuilder::new("demo", Shape::new(32, 32, 3));
/// let c = b.conv("conv1", b.input_id(), 3, 1, 16, PadMode::Same);
/// let r = b.activation("conv1_relu", c, Activation::Relu);
/// let g = b.finish();
/// assert_eq!(g.nodes.len(), 3);
/// ```
pub struct GraphBuilder {
    name: String,
    nodes: Vec<Node>,
}

impl GraphBuilder {
    /// Start a graph with a single input of the given shape.
    pub fn new(name: &str, input: Shape) -> Self {
        let mut b = GraphBuilder { name: name.to_string(), nodes: Vec::new() };
        b.push("input", OpKind::Input, vec![], input);
        b
    }

    /// Id of the input node (always 0).
    pub fn input_id(&self) -> NodeId {
        NodeId(0)
    }

    /// Shape of an already-added node.
    pub fn shape(&self, id: NodeId) -> Shape {
        self.nodes[id.0].out_shape
    }

    fn push(&mut self, name: &str, op: OpKind, inputs: Vec<NodeId>, out: Shape) -> NodeId {
        let id = NodeId(self.nodes.len());
        debug_assert!(
            self.nodes.iter().all(|n| n.name != name),
            "duplicate node name {name}"
        );
        let in_shapes = inputs.iter().map(|i| self.nodes[i.0].out_shape).collect();
        self.nodes.push(Node { id, name: name.to_string(), op, inputs, in_shapes, out_shape: out });
        id
    }

    /// Normal convolution.
    pub fn conv(
        &mut self,
        name: &str,
        from: NodeId,
        k: usize,
        stride: usize,
        out_c: usize,
        pad: PadMode,
    ) -> NodeId {
        let s = self.shape(from);
        let out = match pad {
            PadMode::Same => s.conv_same(stride, out_c),
            PadMode::Valid => s.conv_valid(k, stride, out_c),
        };
        self.push(name, OpKind::Conv { k, stride, out_c, pad, depthwise: false }, vec![from], out)
    }

    /// Depthwise convolution (out channels = in channels).
    pub fn dwconv(
        &mut self,
        name: &str,
        from: NodeId,
        k: usize,
        stride: usize,
        pad: PadMode,
    ) -> NodeId {
        let s = self.shape(from);
        let out_c = s.c;
        let out = match pad {
            PadMode::Same => s.conv_same(stride, out_c),
            PadMode::Valid => s.conv_valid(k, stride, out_c),
        };
        self.push(name, OpKind::Conv { k, stride, out_c, pad, depthwise: true }, vec![from], out)
    }

    /// Fully-connected layer over a 1×1×C activation.
    pub fn fc(&mut self, name: &str, from: NodeId, out_c: usize) -> NodeId {
        self.push(name, OpKind::Fc { out_c }, vec![from], Shape::vec(out_c))
    }

    /// Folded batch-norm (per-channel affine).
    pub fn batchnorm(&mut self, name: &str, from: NodeId) -> NodeId {
        let s = self.shape(from);
        self.push(name, OpKind::BatchNorm, vec![from], s)
    }

    /// Bias add.
    pub fn bias(&mut self, name: &str, from: NodeId) -> NodeId {
        let s = self.shape(from);
        self.push(name, OpKind::BiasAdd, vec![from], s)
    }

    /// Activation node.
    pub fn activation(&mut self, name: &str, from: NodeId, a: Activation) -> NodeId {
        let s = self.shape(from);
        self.push(name, OpKind::Act(a), vec![from], s)
    }

    /// Max-pooling node.
    pub fn maxpool(&mut self, name: &str, from: NodeId, k: usize, stride: usize) -> NodeId {
        let s = self.shape(from);
        let out = s.conv_same(stride, s.c);
        self.push(name, OpKind::MaxPool { k, stride }, vec![from], out)
    }

    /// Average-pooling node.
    pub fn avgpool(&mut self, name: &str, from: NodeId, k: usize, stride: usize) -> NodeId {
        let s = self.shape(from);
        let out = s.conv_same(stride, s.c);
        self.push(name, OpKind::AvgPool { k, stride }, vec![from], out)
    }

    /// Global average pool to 1×1×C.
    pub fn gap(&mut self, name: &str, from: NodeId) -> NodeId {
        let s = self.shape(from);
        self.push(name, OpKind::GlobalAvgPool, vec![from], Shape::vec(s.c))
    }

    /// Element-wise shortcut addition. Operand order is `[main, shortcut]`.
    pub fn add(&mut self, name: &str, main: NodeId, shortcut: NodeId) -> NodeId {
        let s = self.shape(main);
        debug_assert_eq!(s, self.shape(shortcut), "eltwise-add shape mismatch at {name}");
        self.push(name, OpKind::EltwiseAdd, vec![main, shortcut], s)
    }

    /// Channel-wise SE scale: `fmap * gate` with gate of shape 1×1×C.
    pub fn scale(&mut self, name: &str, fmap: NodeId, gate: NodeId) -> NodeId {
        let s = self.shape(fmap);
        debug_assert_eq!(self.shape(gate).c, s.c, "SE gate channel mismatch at {name}");
        self.push(name, OpKind::ScaleMul, vec![fmap, gate], s)
    }

    /// Channel concatenation.
    pub fn concat(&mut self, name: &str, a: NodeId, b_: NodeId) -> NodeId {
        let (sa, sb) = (self.shape(a), self.shape(b_));
        debug_assert_eq!((sa.h, sa.w), (sb.h, sb.w), "concat spatial mismatch at {name}");
        self.push(name, OpKind::Concat, vec![a, b_], Shape::new(sa.h, sa.w, sa.c + sb.c))
    }

    /// Nearest-neighbour upsample.
    pub fn upsample(&mut self, name: &str, from: NodeId, factor: usize) -> NodeId {
        let s = self.shape(from).upsample(factor);
        self.push(name, OpKind::Upsample { factor }, vec![from], s)
    }

    /// No-op marker node (detection heads / named outputs).
    pub fn identity(&mut self, name: &str, from: NodeId) -> NodeId {
        let s = self.shape(from);
        self.push(name, OpKind::Identity, vec![from], s)
    }

    /// Convenience: conv → batch-norm → activation, the most common
    /// frozen-graph triplet.
    pub fn conv_bn_act(
        &mut self,
        base: &str,
        from: NodeId,
        k: usize,
        stride: usize,
        out_c: usize,
        act: Activation,
    ) -> NodeId {
        let c = self.conv(&format!("{base}"), from, k, stride, out_c, PadMode::Same);
        let b = self.batchnorm(&format!("{base}/bn"), c);
        self.activation(&format!("{base}/{}", act_name(act)), b, act)
    }

    /// Convenience: depthwise conv → batch-norm → activation.
    pub fn dw_bn_act(
        &mut self,
        base: &str,
        from: NodeId,
        k: usize,
        stride: usize,
        act: Activation,
    ) -> NodeId {
        let c = self.dwconv(&format!("{base}"), from, k, stride, PadMode::Same);
        let b = self.batchnorm(&format!("{base}/bn"), c);
        self.activation(&format!("{base}/{}", act_name(act)), b, act)
    }

    /// Finalize. Panics (debug) if the graph is empty.
    pub fn finish(self) -> Graph {
        Graph { name: self.name, nodes: self.nodes }
    }
}

fn act_name(a: Activation) -> &'static str {
    match a {
        Activation::Linear => "linear",
        Activation::Relu => "relu",
        Activation::Leaky => "leaky",
        Activation::Relu6 => "relu6",
        Activation::Swish => "swish",
        Activation::Sigmoid => "sigmoid",
        Activation::HardSwish => "hswish",
        Activation::HardSigmoid => "hsigmoid",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::validate;

    #[test]
    fn residual_block_shapes() {
        let mut b = GraphBuilder::new("res", Shape::new(56, 56, 64));
        let x = b.input_id();
        let c1 = b.conv_bn_act("c1", x, 3, 1, 64, Activation::Relu);
        let c2 = b.conv("c2", c1, 3, 1, 64, PadMode::Same);
        let bn2 = b.batchnorm("c2/bn", c2);
        let add = b.add("add", bn2, x);
        let out = b.activation("out", add, Activation::Relu);
        let g = b.finish();
        validate(&g).unwrap();
        assert_eq!(g.node(out).out_shape, Shape::new(56, 56, 64));
        assert_eq!(g.node(add).inputs.len(), 2);
    }

    #[test]
    fn se_block_shapes() {
        let mut b = GraphBuilder::new("se", Shape::new(28, 28, 96));
        let x = b.input_id();
        let g1 = b.gap("gap", x);
        let f1 = b.fc("fc1", g1, 4);
        let a1 = b.activation("fc1/swish", f1, Activation::Swish);
        let f2 = b.fc("fc2", a1, 96);
        let a2 = b.activation("fc2/sigmoid", f2, Activation::Sigmoid);
        let s = b.scale("scale", x, a2);
        let g = b.finish();
        validate(&g).unwrap();
        assert_eq!(g.node(s).out_shape, Shape::new(28, 28, 96));
        assert_eq!(g.node(f1).out_shape, Shape::vec(4));
    }

    #[test]
    fn concat_adds_channels() {
        let mut b = GraphBuilder::new("cat", Shape::new(13, 13, 256));
        let x = b.input_id();
        let c1 = b.conv("a", x, 1, 1, 128, PadMode::Same);
        let c2 = b.conv("b", x, 1, 1, 64, PadMode::Same);
        let cat = b.concat("cat", c1, c2);
        let g = b.finish();
        assert_eq!(g.node(cat).out_shape.c, 192);
    }
}
