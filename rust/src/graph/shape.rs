//! Feature-map shapes (`H × W × C`, batch 1).

use std::fmt;

/// Shape of a feature-map tensor: height, width, channels (batch = 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Shape {
    /// Height.
    pub h: usize,
    /// Width.
    pub w: usize,
    /// Channels.
    pub c: usize,
}

impl Shape {
    /// A shape from height / width / channels.
    pub const fn new(h: usize, w: usize, c: usize) -> Self {
        Shape { h, w, c }
    }

    /// 1×1×c shape (SE-block squeeze outputs, FC activations).
    pub const fn vec(c: usize) -> Self {
        Shape { h: 1, w: 1, c }
    }

    /// Number of elements.
    pub fn numel(&self) -> usize {
        self.h * self.w * self.c
    }

    /// Size in bytes at `bytes_per_elem` precision (the paper's `Q_A`).
    pub fn bytes(&self, bytes_per_elem: usize) -> usize {
        self.numel() * bytes_per_elem
    }

    /// Output spatial size after a `k`-kernel, stride-`s` op with SAME
    /// padding (TF convention: `ceil(in / s)`).
    pub fn conv_same(&self, s: usize, out_c: usize) -> Shape {
        Shape::new(self.h.div_ceil(s), self.w.div_ceil(s), out_c)
    }

    /// Output spatial size with VALID padding: `floor((in - k)/s) + 1`.
    pub fn conv_valid(&self, k: usize, s: usize, out_c: usize) -> Shape {
        Shape::new((self.h - k) / s + 1, (self.w - k) / s + 1, out_c)
    }

    /// Nearest-neighbour upsample by `f`.
    pub fn upsample(&self, f: usize) -> Shape {
        Shape::new(self.h * f, self.w * f, self.c)
    }
}

impl fmt::Display for Shape {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}x{}x{}", self.h, self.w, self.c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_padding_ceil() {
        // 416 -> stride 2 -> 208; odd input 13 -> stride 2 -> 7
        assert_eq!(Shape::new(416, 416, 3).conv_same(2, 32), Shape::new(208, 208, 32));
        assert_eq!(Shape::new(13, 13, 8).conv_same(2, 8), Shape::new(7, 7, 8));
    }

    #[test]
    fn valid_padding() {
        assert_eq!(Shape::new(7, 7, 64).conv_valid(7, 1, 10), Shape::new(1, 1, 10));
    }

    #[test]
    fn bytes_and_numel() {
        let s = Shape::new(4, 4, 2);
        assert_eq!(s.numel(), 32);
        assert_eq!(s.bytes(2), 64);
    }

    #[test]
    fn upsample_doubles_spatial() {
        assert_eq!(Shape::new(13, 13, 256).upsample(2), Shape::new(26, 26, 256));
    }
}
