//! Graph nodes.

use super::{OpKind, Shape};

/// Index of a node within its [`super::Graph`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub usize);

/// One operator instance in the graph.
#[derive(Debug, Clone)]
pub struct Node {
    /// This node's index.
    pub id: NodeId,
    /// Unique name (protobuf node name in the TF front-end).
    pub name: String,
    /// Operator kind and static attributes.
    pub op: OpKind,
    /// Producers, in operand order. `EltwiseAdd`: `[main, shortcut]`;
    /// `ScaleMul`: `[fmap, gate]`; `Concat`: `[a, b]`.
    pub inputs: Vec<NodeId>,
    /// Shape of each input (cached at build time, same order as `inputs`).
    pub in_shapes: Vec<Shape>,
    /// Output feature-map shape.
    pub out_shape: Shape,
}

impl Node {
    /// Input channel count of the (first) operand.
    pub fn in_c(&self) -> usize {
        self.in_shapes.first().map(|s| s.c).unwrap_or(0)
    }

    /// Weight element count (0 for weight-less ops).
    ///
    /// Depthwise conv: `k·k·C`; normal conv: `k·k·Cin·Cout`; FC:
    /// `Cin·Cout` (an FC is a 1×1 conv on a 1×1 frame).
    pub fn weight_count(&self) -> u64 {
        match self.op {
            OpKind::Conv { k, out_c, depthwise, .. } => {
                let k = (k * k) as u64;
                if depthwise {
                    k * self.in_c() as u64
                } else {
                    k * self.in_c() as u64 * out_c as u64
                }
            }
            OpKind::Fc { out_c } => self.in_c() as u64 * out_c as u64,
            _ => 0,
        }
    }

    /// Multiply-accumulate count.
    pub fn macs(&self) -> u64 {
        match self.op {
            OpKind::Conv { k, depthwise, .. } => {
                let per_pix = if depthwise {
                    (k * k) as u64 * self.out_shape.c as u64
                } else {
                    (k * k) as u64 * self.in_c() as u64 * self.out_shape.c as u64
                };
                per_pix * (self.out_shape.h * self.out_shape.w) as u64
            }
            OpKind::Fc { out_c } => self.in_c() as u64 * out_c as u64,
            // ScaleMul is C·H·W multiplications; counted like the paper's
            // "1x1 depthwise conv without BN".
            OpKind::ScaleMul => self.out_shape.numel() as u64,
            _ => 0,
        }
    }

    /// Bytes of the output feature-map at `qa` bytes/element.
    pub fn out_bytes(&self, qa: usize) -> usize {
        self.out_shape.bytes(qa)
    }

    /// Bytes of the first-operand input feature-map at `qa` bytes/element.
    pub fn in_bytes(&self, qa: usize) -> usize {
        self.in_shapes.first().map(|s| s.bytes(qa)).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::PadMode;

    fn conv_node(depthwise: bool) -> Node {
        Node {
            id: NodeId(0),
            name: "c".into(),
            op: OpKind::Conv {
                k: 3,
                stride: 1,
                out_c: if depthwise { 16 } else { 32 },
                pad: PadMode::Same,
                depthwise,
            },
            inputs: vec![],
            in_shapes: vec![Shape::new(10, 10, 16)],
            out_shape: Shape::new(10, 10, if depthwise { 16 } else { 32 }),
        }
    }

    #[test]
    fn weight_count_normal_vs_depthwise() {
        assert_eq!(conv_node(false).weight_count(), 9 * 16 * 32);
        assert_eq!(conv_node(true).weight_count(), 9 * 16);
    }

    #[test]
    fn macs_normal_vs_depthwise() {
        assert_eq!(conv_node(false).macs(), 9 * 16 * 32 * 100);
        assert_eq!(conv_node(true).macs(), 9 * 16 * 100);
    }

    #[test]
    fn fc_weights_and_macs() {
        let n = Node {
            id: NodeId(1),
            name: "fc".into(),
            op: OpKind::Fc { out_c: 1000 },
            inputs: vec![],
            in_shapes: vec![Shape::vec(1280)],
            out_shape: Shape::vec(1000),
        };
        assert_eq!(n.weight_count(), 1280 * 1000);
        assert_eq!(n.macs(), 1280 * 1000);
    }
}
