//! CNN graph intermediate representation.
//!
//! This IR plays the role of the parsed TensorFlow frozen graph in the
//! paper's front-end (Fig. 4, "CNN parser & analyzer"). Nodes are
//! *fine-grained* — convolution, bias, batch-norm, activation, pooling,
//! element-wise addition (shortcut), concatenation, upsampling, SE-block
//! pieces are each separate nodes, exactly as a frozen protobuf presents
//! them — so that the [`crate::analyzer`] has real fusion work to do
//! (e.g. EfficientNet-B1's 418 nodes → 139 executable groups, Fig. 5a).
//!
//! Shapes are `HWC` with an implicit batch of 1: the paper optimizes
//! single-image latency ("this work optimizes the latency with batch size
//! of 1", §II).

mod shape;
mod op;
mod node;
mod build;
mod validate;

pub use shape::Shape;
pub use op::{Activation, OpKind, PadMode};
pub use node::{Node, NodeId};
pub use build::GraphBuilder;
pub use validate::{validate, ValidateError};

use std::collections::HashMap;

/// A CNN compute graph: nodes in topological order.
///
/// Invariants (checked by [`validate`]):
/// * node inputs always refer to earlier nodes (builder emits topo order),
/// * shapes are consistent with each op's shape function,
/// * exactly one `Input` node, at least one output (no consumers).
#[derive(Debug, Clone)]
pub struct Graph {
    /// Human-readable model name, e.g. `"ResNet50"`.
    pub name: String,
    /// Nodes in topological order; `NodeId` indexes into this vector.
    pub nodes: Vec<Node>,
}

impl Graph {
    /// Node lookup by id.
    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id.0]
    }

    /// The single `Input` node of the graph.
    pub fn input(&self) -> &Node {
        self.nodes
            .iter()
            .find(|n| matches!(n.op, OpKind::Input))
            .expect("graph has an Input node")
    }

    /// Ids of nodes with no consumers (the network outputs).
    pub fn outputs(&self) -> Vec<NodeId> {
        let mut consumed = vec![false; self.nodes.len()];
        for n in &self.nodes {
            for &i in &n.inputs {
                consumed[i.0] = true;
            }
        }
        (0..self.nodes.len())
            .filter(|&i| !consumed[i])
            .map(NodeId)
            .collect()
    }

    /// Consumer map: for every node, the ids of nodes that read it.
    pub fn consumers(&self) -> Vec<Vec<NodeId>> {
        let mut out = vec![Vec::new(); self.nodes.len()];
        for (i, n) in self.nodes.iter().enumerate() {
            for &inp in &n.inputs {
                out[inp.0].push(NodeId(i));
            }
        }
        out
    }

    /// Number of convolution-like nodes (Conv + FC), the paper's
    /// "CONV layers" count.
    pub fn conv_layer_count(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| matches!(n.op, OpKind::Conv { .. } | OpKind::Fc { .. }))
            .count()
    }

    /// Total multiply-accumulate count of the network (for GOP figures).
    pub fn total_macs(&self) -> u64 {
        self.nodes.iter().map(|n| n.macs()).sum()
    }

    /// Total GOP (2 ops per MAC), the "CNN size (GOP)" rows of Tables II/V.
    pub fn total_gop(&self) -> f64 {
        2.0 * self.total_macs() as f64 / 1e9
    }

    /// Total weight bytes at the given weight precision.
    pub fn total_weight_bytes(&self, bytes_per_weight: u64) -> u64 {
        self.nodes
            .iter()
            .map(|n| n.weight_count() * bytes_per_weight)
            .sum()
    }

    /// Find a node id by name (used by tests and the JSON round-trip).
    pub fn find(&self, name: &str) -> Option<NodeId> {
        self.nodes.iter().position(|n| n.name == name).map(NodeId)
    }

    /// Map from node name to id for bulk lookups.
    pub fn name_index(&self) -> HashMap<&str, NodeId> {
        self.nodes
            .iter()
            .enumerate()
            .map(|(i, n)| (n.name.as_str(), NodeId(i)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Graph {
        let mut b = GraphBuilder::new("tiny", Shape::new(8, 8, 3));
        let c = b.conv("c1", b.input_id(), 3, 1, 16, PadMode::Same);
        let r = b.activation("r1", c, Activation::Relu);
        let _p = b.maxpool("p1", r, 2, 2);
        b.finish()
    }

    #[test]
    fn topo_order_and_outputs() {
        let g = tiny();
        validate(&g).unwrap();
        assert_eq!(g.outputs().len(), 1);
        assert_eq!(g.node(g.outputs()[0]).name, "p1");
    }

    #[test]
    fn conv_count_and_macs() {
        let g = tiny();
        assert_eq!(g.conv_layer_count(), 1);
        // 3x3x3x16 kernel over an 8x8 output frame
        assert_eq!(g.total_macs(), 3 * 3 * 3 * 16 * 8 * 8);
    }

    #[test]
    fn consumers_map() {
        let g = tiny();
        let cons = g.consumers();
        let c1 = g.find("c1").unwrap();
        assert_eq!(cons[c1.0].len(), 1);
        assert_eq!(g.node(cons[c1.0][0]).name, "r1");
    }

    #[test]
    fn gop_matches_macs() {
        let g = tiny();
        assert!((g.total_gop() - 2.0 * g.total_macs() as f64 / 1e9).abs() < 1e-12);
    }
}
