//! Power / energy model (§V-C).
//!
//! "The power of the accelerator is estimated as the sum of FPGA-chip
//! power plus the DRAM power. The FPGA-chip power is calculated by
//! Xilinx Power Estimator with the signal switching frequency from RTL
//! simulation. The DRAM access energy is estimated from the total DRAM
//! access and the energy per access from [56]."
//!
//! We reproduce the same structure: a parametric chip-power model
//! (static + per-resource dynamic terms, calibrated against Table VII's
//! 21.09 W at 256×256) plus DRAM energy at the per-bit figure from
//! Malladi et al. [56].

use crate::config::AccelConfig;

/// DRAM energy per bit transferred (DDR3-class, [56]): ~70 pJ/bit.
pub const DRAM_PJ_PER_BIT: f64 = 70.0;

/// On-chip SRAM energy per bit (~45nm-class global buffer, Han et al.
/// [37]: SRAM access ≈ 1/100 of a DRAM access): ~0.7 pJ/bit.
pub const SRAM_PJ_PER_BIT: f64 = 0.7;

/// Energy-per-inference breakdown from the instruction-level traffic
/// replay (the [37] argument: off-chip access dominates energy, which is
/// why eq. 10 constrains DRAM traffic).
#[derive(Debug, Clone, Copy)]
pub struct EnergyBreakdown {
    /// DRAM access energy per inference, millijoules.
    pub dram_mj: f64,
    /// On-chip SRAM access energy per inference, millijoules.
    pub sram_mj: f64,
    /// DRAM energy / total memory energy.
    pub dram_fraction: f64,
}

/// Compute the memory-system energy of one inference from replayed
/// traffic counts ([`crate::sim::TrafficCount`]).
pub fn memory_energy(t: &crate::sim::TrafficCount) -> EnergyBreakdown {
    let dram_bits = (t.dram_total() * 8) as f64;
    let sram_bits = ((t.buf_read + t.buf_write) * 8) as f64;
    let dram_mj = dram_bits * DRAM_PJ_PER_BIT * 1e-9;
    let sram_mj = sram_bits * SRAM_PJ_PER_BIT * 1e-9;
    EnergyBreakdown {
        dram_mj,
        sram_mj,
        dram_fraction: dram_mj / (dram_mj + sram_mj).max(1e-12),
    }
}

/// Calibrated chip-power coefficients (XPE-style decomposition).
#[derive(Debug, Clone, Copy)]
pub struct PowerModel {
    /// Static + infrastructure watts (clocking, I/O, uncore).
    pub static_w: f64,
    /// Dynamic watts of the fully-utilized MAC arrays.
    pub mac_w: f64,
    /// Dynamic watts per active BRAM18K.
    pub bram_w: f64,
}

impl Default for PowerModel {
    fn default() -> Self {
        // Calibration anchor: EfficientNet-B1@256 on the KCU1500 design
        // (Table VII): 2594 BRAM, 19.4 % MAC utilization, 60.7 MB / 4.69 ms
        // DRAM traffic → 21.09 W total. The three Table VII points fit to
        // within ~13 % with these coefficients.
        PowerModel { static_w: 4.0, mac_w: 8.0, bram_w: 0.0035 }
    }
}

/// Power estimate for one run.
#[derive(Debug, Clone, Copy)]
pub struct PowerEstimate {
    /// FPGA-side power (static + MAC + BRAM), watts.
    pub chip_w: f64,
    /// DRAM interface power, watts.
    pub dram_w: f64,
    /// Chip + DRAM, watts.
    pub total_w: f64,
    /// Energy per frame in millijoules.
    pub frame_mj: f64,
    /// Throughput per watt (the Tables V/VII efficiency row).
    pub gops_per_w: f64,
}

/// Estimate power for a simulated run.
///
/// * `mac_utilization` — the timing simulator's MAC efficiency;
/// * `bram18k` — allocated BRAM count (eq. 7);
/// * `dram_bytes` — total DRAM traffic per frame (eq. 9);
/// * `latency_ms` — per-frame latency;
/// * `gops` — achieved average GOPS.
pub fn estimate(
    model: &PowerModel,
    _cfg: &AccelConfig,
    mac_utilization: f64,
    bram18k: usize,
    dram_bytes: u64,
    latency_ms: f64,
    gops: f64,
) -> PowerEstimate {
    let chip_w = model.static_w + model.mac_w * mac_utilization + model.bram_w * bram18k as f64;
    let dram_j = dram_bytes as f64 * 8.0 * DRAM_PJ_PER_BIT * 1e-12;
    let dram_w = dram_j / (latency_ms * 1e-3);
    let total_w = chip_w + dram_w;
    PowerEstimate {
        chip_w,
        dram_w,
        total_w,
        frame_mj: total_w * latency_ms,
        gops_per_w: gops / total_w,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_anchor_table7_256() {
        // EfficientNet-B1@256: 19.37 % util, 2594 BRAM, 60.7 MB, 4.69 ms,
        // 317.1 GOPS → paper: 21.09 W, 15.0 GOPS/W.
        let cfg = AccelConfig::kcu1500_int8();
        let p = estimate(
            &PowerModel::default(),
            &cfg,
            0.1937,
            2594,
            60_700_000,
            4.69,
            317.1,
        );
        assert!(
            (p.total_w - 21.09).abs() < 3.0,
            "total {} vs paper 21.09",
            p.total_w
        );
        assert!((p.gops_per_w - 15.0).abs() < 3.0, "{} vs 15.0", p.gops_per_w);
    }

    #[test]
    fn table7_points_within_25pct() {
        // Table VII: 21.09 / 23.76 / 26.71 W across 256/512/768.
        let cfg = AccelConfig::kcu1500_int8();
        let m = PowerModel::default();
        let cases = [
            (0.1937, 2594, 60_700_000u64, 4.69, 21.09),
            (0.163, 2723, 216_000_000, 20.6, 23.76),
            (0.1675, 3845, 475_000_000, 45.3, 26.71),
        ];
        for (util, bram, bytes, lat, want) in cases {
            let p = estimate(&m, &cfg, util, bram, bytes, lat, 300.0);
            let err = (p.total_w - want).abs() / want;
            assert!(err < 0.25, "{} W vs paper {want} ({:.0} % off)", p.total_w, err * 100.0);
        }
        // and the largest resolution draws the most power
        let p256 = estimate(&m, &cfg, 0.1937, 2594, 60_700_000, 4.69, 317.1);
        let p768 = estimate(&m, &cfg, 0.1675, 3845, 475_000_000, 45.3, 274.4);
        assert!(p768.total_w > p256.total_w);
    }

    #[test]
    fn energy_breakdown_from_replay() {
        // off-chip access must dominate memory energy even at 100:1
        // traffic ratio in favour of SRAM — the [37] premise.
        let t = crate::sim::TrafficCount {
            fm_read: 1_000_000,
            fm_write: 1_000_000,
            weight_read: 8_000_000,
            buf_read: 500_000_000,
            buf_write: 500_000_000,
            ..Default::default()
        };
        let e = memory_energy(&t);
        assert!(e.dram_fraction > 0.4, "dram fraction {}", e.dram_fraction);
        assert!(e.dram_mj > 0.0 && e.sram_mj > 0.0);
    }

    #[test]
    fn dram_energy_per_bit() {
        let cfg = AccelConfig::kcu1500_int8();
        // 1 GB in 1 s at 70 pJ/bit = 0.56 W
        let p = estimate(&PowerModel::default(), &cfg, 0.0, 0, 1_000_000_000, 1000.0, 0.0);
        assert!((p.dram_w - 0.56).abs() < 0.01);
    }
}
