//! ShortcutMining (Azizimazreah & Chen, HPCA'19 [8]) baseline model —
//! the Table II comparison.
//!
//! ShortcutMining "mines" cross-layer shortcut reuse by reserving
//! untouched buffer space for shortcut tensors, but keeps a **fixed**
//! data-reuse scheme for the main path: every layer's input and output
//! feature-maps still stream through DRAM once (its large banked buffer
//! holds tiles + shortcuts, not whole inter-layer tensors). Weights are
//! re-fetched per tile pass in [8]'s weight-stationary flavour; Table II
//! lists "Weight Load: Multiple times" — we model the dominant fmap term
//! and a 2× weight factor.

use crate::analyzer::{GroupKind, GroupedGraph};
use crate::config::AccelConfig;

/// Feature-map DRAM traffic under the ShortcutMining policy: in + out of
/// every compute layer streams once; *shortcut second operands are free*
/// (the mined on-chip reuse — the 40 % saving the paper cites), as are
/// fused-pool intermediates.
pub fn shortcut_mining_fm_traffic(gg: &GroupedGraph, cfg: &AccelConfig) -> u64 {
    let qa = cfg.qa;
    let mut bytes = 0u64;
    for gr in &gg.groups {
        match gr.kind {
            GroupKind::Input | GroupKind::Concat => continue,
            GroupKind::Fc => continue, // vectors, negligible
            _ => {}
        }
        if gr.out_shape.h * gr.out_shape.w <= 1 {
            continue;
        }
        bytes += gr.in_shape.bytes(qa) as u64;
        bytes += gr.out_shape.bytes(qa) as u64;
        // shortcut operand: mined on-chip -> no traffic
    }
    bytes
}

/// Total weight traffic under [8]: loaded "multiple times" — modelled as
/// twice (once per reuse pass over the large banked buffer).
pub fn shortcut_mining_weight_traffic(gg: &GroupedGraph, cfg: &AccelConfig) -> u64 {
    2 * gg.graph.total_weight_bytes(cfg.qw as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::allocate;
    use crate::analyzer::analyze;
    use crate::isa::ReuseMode;
    use crate::optimizer::dram_access;
    use crate::zoo;

    #[test]
    fn table2_resnet152_fm_traffic_scale() {
        // Table II (16-bit, 224×224): ShortcutMining off-chip FMs
        // = 62.93 MB; proposed = 11.97 MB.
        let gg = analyze(&zoo::resnet152(224));
        let cfg = AccelConfig::table2_int16();
        let sm = shortcut_mining_fm_traffic(&gg, &cfg) as f64 / 1e6;
        assert!(
            (40.0..95.0).contains(&sm),
            "ShortcutMining FM {sm:.1} MB vs paper 62.93"
        );
    }

    #[test]
    fn proposed_beats_shortcut_mining_5x() {
        // Abstract: "the proposed work reduces off-chip access for
        // feature-maps 5.27×" given a similar buffer size.
        let gg = analyze(&zoo::resnet152(224));
        let cfg = AccelConfig::table2_int16();
        let sm = shortcut_mining_fm_traffic(&gg, &cfg);
        let policy = vec![ReuseMode::Frame; gg.groups.len()];
        let alloc = allocate(&gg, &policy, &cfg);
        let ours = dram_access(&gg, &policy, &alloc, &cfg).fm_bytes;
        let factor = sm as f64 / ours as f64;
        assert!(factor > 3.0, "only {factor:.2}× better (sm {sm}, ours {ours})");
    }
}
