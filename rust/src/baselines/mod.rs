//! Comparison baselines for the paper's evaluation tables.
//!
//! | module | reproduces |
//! |---|---|
//! | [`fixed_reuse`] | Fig 16's "fixed row-based" baseline + fixed-frame |
//! | [`shortcut_mining`] | ShortcutMining (HPCA'19 [8]) — Table II |
//! | [`smartshuttle`] | SmartShuttle (DATE'18 [12]) — Table IV |
//! | [`olaccel`] | OLAccel (ISCA'18 [38]) constants — Table IV |
//! | [`frameworks`] | ML-Suite / FPL'19 / Cloud-DNN constants — Table VI |
//! | [`gpu_model`] | analytical GPU latency/power — Figs 2/18 |

pub mod fixed_reuse;
pub mod shortcut_mining;
pub mod smartshuttle;
pub mod olaccel;
pub mod frameworks;
pub mod gpu_model;

pub use gpu_model::{Gpu, GpuEstimate};
pub use shortcut_mining::shortcut_mining_fm_traffic;
pub use smartshuttle::{smartshuttle_dram, SmartShuttleResult};
