//! SmartShuttle (Li et al., DATE'18 [12]) baseline — the Table IV
//! comparison on VGG-CONV.
//!
//! SmartShuttle switches *per layer* between a partial-sum-oriented
//! scheme (outputs resident, inputs/weights re-streamed) and a
//! weight-oriented scheme (weights resident per tile, inputs re-read per
//! output-channel tile), under a global buffer capacity. We reproduce
//! its published cost model at tile granularity and pick the per-layer
//! minimum — enough to land at its reported ~58 MB for VGG16-CONV with a
//! 512 KB buffer ("the buffer size, which is larger than 512 KB, does
//! not help to reduce the DRAM access").

use crate::analyzer::{GroupKind, GroupedGraph};
use crate::config::AccelConfig;
use crate::graph::OpKind;

/// Per-network result of the SmartShuttle model.
#[derive(Debug, Clone, Copy)]
pub struct SmartShuttleResult {
    /// Total modeled DRAM traffic, bytes.
    pub dram_bytes: u64,
    /// Layers that chose the psum-oriented scheme.
    pub psum_layers: usize,
    /// Layers that chose the weight-oriented scheme.
    pub weight_layers: usize,
}

/// Weight traffic charged by [`smartshuttle_dram`]'s cost model: every
/// standard convolution streams its weights exactly once under either
/// per-layer scheme; depthwise and FC layers fall outside [12]'s model
/// and are not charged.
pub fn smartshuttle_weight_traffic(gg: &GroupedGraph, cfg: &AccelConfig) -> u64 {
    let qw = cfg.qw as u64;
    let mut bytes = 0u64;
    for gr in &gg.groups {
        let node = gg.graph.node(gr.main);
        if let OpKind::Conv { k, out_c, depthwise: false, .. } = node.op {
            let in_c = node.in_shapes[0].c as u64;
            bytes += (k as u64) * (k as u64) * in_c * (out_c as u64) * qw;
        }
    }
    bytes
}

/// Evaluate SmartShuttle's DRAM traffic with `buffer_bytes` of on-chip
/// SRAM.
pub fn smartshuttle_dram(
    gg: &GroupedGraph,
    cfg: &AccelConfig,
    buffer_bytes: usize,
) -> SmartShuttleResult {
    let qa = cfg.qa as u64;
    let qw = cfg.qw as u64;
    let qs = 4u64; // psum width
    let mut dram = 0u64;
    let (mut psum_layers, mut weight_layers) = (0usize, 0usize);

    for gr in &gg.groups {
        let node = gg.graph.node(gr.main);
        let (k, in_c, out_c, oh, ow) = match node.op {
            OpKind::Conv { k, out_c, depthwise: false, .. } => (
                k as u64,
                node.in_shapes[0].c as u64,
                out_c as u64,
                node.out_shape.h as u64,
                node.out_shape.w as u64,
            ),
            _ => {
                // non-conv groups stream once (pool/eltwise handled by the
                // conv they fuse with in [12]'s model)
                if matches!(gr.kind, GroupKind::Pool | GroupKind::Eltwise | GroupKind::Upsample) {
                    dram +=
                        (gr.in_shape.bytes(qa as usize) + gr.out_shape.bytes(qa as usize)) as u64;
                }
                continue;
            }
        };
        let in_size = gr.in_shape.bytes(qa as usize) as u64;
        let out_size = (oh * ow * out_c) * qa;
        let w_size = k * k * in_c * out_c * qw;
        let buf = buffer_bytes as u64;

        // --- psum-oriented: output tile resident in Q_S; weights stream
        // once; inputs re-read once per output-channel pass.
        // passes_po = ceil(out_c / oc_tile) where oc_tile fills the buffer
        // with an oh×ow×oc_tile psum block.
        let oc_tile = (buf / (oh * ow * qs)).clamp(1, out_c);
        let passes_po = out_c.div_ceil(oc_tile);
        let cost_po = passes_po * in_size + out_size + w_size;

        // --- weight-oriented: weight tile resident; inputs stream once
        // per input-channel pass; partial sums spill to DRAM between
        // passes (read+write per extra pass) and the final pass writes
        // the quantized output.
        let ic_tile = (buf / (k * k * out_c * qw).max(1)).clamp(1, in_c);
        let passes_wo = in_c.div_ceil(ic_tile);
        let cost_wo = in_size + w_size + (passes_wo - 1) * 2 * (oh * ow * out_c) * qs + out_size;

        if cost_po <= cost_wo {
            psum_layers += 1;
            dram += cost_po;
        } else {
            weight_layers += 1;
            dram += cost_wo;
        }
    }
    SmartShuttleResult { dram_bytes: dram, psum_layers, weight_layers }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analyzer::analyze;
    use crate::zoo;

    #[test]
    fn table4_vgg_traffic_scale() {
        // Table IV: SmartShuttle on VGG-CONV (8-bit, 0.75 MB buffer):
        // 58.1 MB DRAM.
        let gg = analyze(&zoo::vgg16_conv(224));
        let cfg = AccelConfig::kcu1500_int8();
        let r = smartshuttle_dram(&gg, &cfg, 750_000);
        let mb = r.dram_bytes as f64 / 1e6;
        assert!((35.0..85.0).contains(&mb), "SmartShuttle {mb:.1} MB vs paper 58.1");
        assert!(r.psum_layers + r.weight_layers == 13);
    }

    #[test]
    fn bigger_buffer_saturates() {
        // [12]: ">512 KB does not help" — traffic must plateau.
        let gg = analyze(&zoo::vgg16_conv(224));
        let cfg = AccelConfig::kcu1500_int8();
        let small = smartshuttle_dram(&gg, &cfg, 256_000).dram_bytes;
        let mid = smartshuttle_dram(&gg, &cfg, 1_000_000).dram_bytes;
        let big = smartshuttle_dram(&gg, &cfg, 8_000_000).dram_bytes;
        assert!(small >= mid && mid >= big);
        let plateau = (mid - big) as f64 / mid as f64;
        assert!(plateau < 0.35, "still improving by {plateau}");
    }
}
