//! Fixed single-scheme baselines (no block-wise switching).

use crate::alloc::allocate;
use crate::analyzer::GroupedGraph;
use crate::config::AccelConfig;
use crate::isa::ReuseMode;
use crate::optimizer::{dram_access, sram_size, DramBreakdown, SramBreakdown};
use crate::sim::{simulate, simulate_fixed_row_baseline, NetworkTiming};

/// Metrics of a fixed-policy run.
pub struct FixedResult {
    /// Cycle-accurate timing under the uniform policy.
    pub timing: NetworkTiming,
    /// DRAM traffic breakdown.
    pub dram: DramBreakdown,
    /// SRAM requirement breakdown.
    pub sram: SramBreakdown,
}

/// The proposed hardware running a *uniform* policy (all-row or
/// all-frame) — the single-scheme ablation of the block-wise switch.
pub fn fixed_policy(gg: &GroupedGraph, cfg: &AccelConfig, mode: ReuseMode) -> FixedResult {
    let policy = vec![mode; gg.groups.len()];
    let alloc = allocate(gg, &policy, cfg);
    FixedResult {
        timing: simulate(gg, &policy, &alloc, cfg),
        dram: dram_access(gg, &policy, &alloc, cfg),
        sram: sram_size(gg, &policy, &alloc, cfg),
    }
}

/// The *naive* fixed row-based scheme of Fig. 16's baseline: weights
/// re-fetched per output row (Table I), everything streamed off-chip.
pub fn naive_row_baseline(gg: &GroupedGraph, cfg: &AccelConfig) -> NetworkTiming {
    simulate_fixed_row_baseline(gg, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analyzer::analyze;
    use crate::zoo;

    #[test]
    fn naive_row_is_slowest() {
        let gg = analyze(&zoo::yolov2(416));
        let cfg = AccelConfig::kcu1500_int8();
        let naive = naive_row_baseline(&gg, &cfg);
        let row = fixed_policy(&gg, &cfg, ReuseMode::Row);
        let frame = fixed_policy(&gg, &cfg, ReuseMode::Frame);
        assert!(naive.latency_ms >= row.timing.latency_ms);
        assert!(row.timing.latency_ms >= frame.timing.latency_ms * 0.99);
    }
}
