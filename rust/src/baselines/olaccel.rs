//! OLAccel (Park, Kim & Yoo, ISCA'18 [38]) — literature constants for
//! the Table IV row (a closed design; the paper compares against its
//! published numbers, as do we).

/// OLAccel on VGG-CONV as reported in Table IV.
#[derive(Debug, Clone, Copy)]
pub struct OlAccel {
    /// Reported arithmetic precision.
    pub precision: &'static str,
    /// Reported on-chip SRAM, MB.
    pub sram_mb: f64,
    /// Reported DRAM traffic, MB.
    pub dram_mb: f64,
}

/// Table IV row.
pub const OLACCEL_VGG: OlAccel =
    OlAccel { precision: "mixed (4,8)", sram_mb: 2.4, dram_mb: 42.8 };

#[cfg(test)]
mod tests {
    #[test]
    fn constants_match_table4() {
        assert_eq!(super::OLACCEL_VGG.sram_mb, 2.4);
        assert_eq!(super::OLACCEL_VGG.dram_mb, 42.8);
    }
}
