//! Analytical GPU latency/power model (Figs 2 and 18).
//!
//! We have no RTX 2080 Ti / Titan Xp / RTX 3090 (see DESIGN.md §2); the
//! comparison uses a two-regime roofline with per-kernel launch
//! overhead, calibrated to the paper's published measurements:
//!
//! * small inputs → *launch-bound*: batch-1 compact CNNs issue one CUDA
//!   kernel per fused op, each costing tens of µs (this is Fig 2's
//!   observation — EfficientNet-B1@256 takes ~13 ms on a 13-TFLOP GPU);
//! * large inputs → *compute-bound*: utilization rises with work per
//!   kernel and the GPU overtakes the fixed-parallelism accelerator
//!   (Fig 18a's crossover).
//!
//! The *shape* — who wins where, crossover position, and the ~10×
//! power-efficiency gap — is the reproduction target, not the exact ms.

use crate::analyzer::{GroupKind, GroupedGraph};

/// A GPU's published characteristics.
#[derive(Debug, Clone, Copy)]
pub struct Gpu {
    /// Marketing name.
    pub name: &'static str,
    /// FP32 peak TFLOPS.
    pub peak_tflops: f64,
    /// Memory bandwidth GB/s.
    pub mem_gbps: f64,
    /// Per-kernel launch + framework overhead (µs), PyTorch-class.
    pub launch_us: f64,
    /// Board power under inference load (W) — nvidia-smi-style.
    pub board_w: f64,
}

/// The GPUs of Fig 18.
pub const RTX_2080_TI: Gpu = Gpu {
    name: "RTX 2080 Ti",
    peak_tflops: 13.45,
    mem_gbps: 616.0,
    launch_us: 55.0,
    board_w: 120.0,
};
/// RTX 3090 published characteristics (Fig 18).
pub const RTX_3090: Gpu =
    Gpu { name: "RTX 3090", peak_tflops: 35.6, mem_gbps: 936.0, launch_us: 50.0, board_w: 160.0 };
/// Titan Xp published characteristics (Fig 18).
pub const TITAN_XP: Gpu =
    Gpu { name: "Titan Xp", peak_tflops: 12.15, mem_gbps: 548.0, launch_us: 65.0, board_w: 115.0 };
/// Keras/TF-2.3 overhead multiplier (Fig 2 vs Fig 18a: "the GPU
/// performance on Pytorch is much higher than on Keras").
pub const KERAS_OVERHEAD: f64 = 2.2;

/// Latency/power estimate for one network on one GPU.
#[derive(Debug, Clone, Copy)]
pub struct GpuEstimate {
    /// Estimated batch-1 latency, ms.
    pub latency_ms: f64,
    /// Estimated board power, W.
    pub power_w: f64,
    /// Resulting efficiency, GOPS/W.
    pub gops_per_w: f64,
}

/// Sustained-utilization curve: batch-1 inference reaches only a
/// fraction of peak, growing with the average work per kernel.
fn utilization(avg_gflop_per_kernel: f64) -> f64 {
    // ~6 % at 10 MFLOP/kernel → ~35 % at 1 GFLOP/kernel, saturating.
    (0.35 * avg_gflop_per_kernel / (avg_gflop_per_kernel + 0.12)).max(0.02)
}

/// Estimate GPU latency for a compiled network (PyTorch-class runtime).
pub fn estimate(gg: &GroupedGraph, gpu: &Gpu) -> GpuEstimate {
    // one kernel per fused group ≈ what TorchScript/cuDNN issues
    let kernels = gg
        .groups
        .iter()
        .filter(|g| !matches!(g.kind, GroupKind::Input | GroupKind::Concat))
        .count();
    let gflop = gg.graph.total_gop();
    let util = utilization(gflop / kernels as f64);
    let compute_ms = gflop / (gpu.peak_tflops * 1e3 * util) * 1e3;
    // memory-bound floor: activations+weights at fp16 through HBM
    let bytes = 2.0
        * (gg.graph.total_weight_bytes(1) as f64
            + gg.groups.iter().map(|g| g.out_shape.numel() as f64).sum::<f64>());
    let mem_ms = bytes / (gpu.mem_gbps * 1e9) * 1e3;
    let launch_ms = kernels as f64 * gpu.launch_us / 1e3;
    let latency_ms = launch_ms + compute_ms.max(mem_ms);
    GpuEstimate {
        latency_ms,
        power_w: gpu.board_w,
        gops_per_w: gflop / (latency_ms / 1e3) / gpu.board_w,
    }
}

/// Keras/TF variant (Fig 2).
pub fn estimate_keras(gg: &GroupedGraph, gpu: &Gpu) -> GpuEstimate {
    let base = estimate(gg, gpu);
    GpuEstimate {
        latency_ms: base.latency_ms * KERAS_OVERHEAD,
        power_w: base.power_w,
        gops_per_w: base.gops_per_w / KERAS_OVERHEAD,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analyzer::analyze;
    use crate::zoo;

    fn b1(input: usize) -> GroupedGraph {
        analyze(&zoo::efficientnet_b1(input))
    }

    #[test]
    fn fig18_2080ti_latency_at_256() {
        // Paper: proposed 4.69 ms is 2.8× faster than the 2080 Ti at 256
        // ⇒ GPU ≈ 13 ms.
        let e = estimate(&b1(256), &RTX_2080_TI);
        assert!((8.0..20.0).contains(&e.latency_ms), "{}", e.latency_ms);
    }

    #[test]
    fn fig18_crossover_at_large_inputs() {
        // GPUs overtake the accelerator for larger inputs: GPU latency
        // grows sub-quadratically thanks to rising utilization.
        let l256 = estimate(&b1(256), &RTX_2080_TI).latency_ms;
        let l768 = estimate(&b1(768), &RTX_2080_TI).latency_ms;
        let work_ratio =
            zoo::efficientnet_b1(768).total_gop() / zoo::efficientnet_b1(256).total_gop();
        assert!(l768 / l256 < work_ratio * 0.6, "{} -> {}", l256, l768);
    }

    #[test]
    fn fig2_keras_slower_than_pytorch() {
        let py = estimate(&b1(512), &RTX_2080_TI).latency_ms;
        let keras = estimate_keras(&b1(512), &RTX_2080_TI).latency_ms;
        assert!(keras > py * 1.5);
    }

    #[test]
    fn power_efficiency_gap_vs_fpga() {
        // Fig 18b: FPGA ≈ 15 GOPS/W at 256 vs GPU ≈ 1.5 GOPS/W → ~10×.
        let e = estimate(&b1(256), &RTX_2080_TI);
        assert!(
            (0.4..4.0).contains(&e.gops_per_w),
            "GPU {} GOPS/W (paper ≈ 1.5)",
            e.gops_per_w
        );
    }

    #[test]
    fn faster_gpu_is_faster() {
        let a = estimate(&b1(512), &RTX_2080_TI).latency_ms;
        let b = estimate(&b1(512), &RTX_3090).latency_ms;
        assert!(b < a);
    }
}
