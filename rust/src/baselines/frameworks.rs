//! End-to-end FPGA framework baselines (Table VI) — published numbers
//! for ML-Suite [44], FPL'19 [33] and Cloud-DNN [17] on ResNet50
//! inference (closed systems; compared by their reported figures).

/// One Table VI column.
#[derive(Debug, Clone, Copy)]
pub struct Framework {
    /// Published framework name.
    pub name: &'static str,
    /// FPGA platform it reports on.
    pub platform: &'static str,
    /// Reported clock, MHz.
    pub freq_mhz: f64,
    /// Input resolution of the reported run.
    pub input: usize,
    /// Arithmetic precision, bits.
    pub precision_bits: usize,
    /// Reported ResNet50 latency, ms.
    pub latency_ms: f64,
    /// Reported LUT usage, thousands.
    pub luts_k: f64,
    /// Reported DSP usage.
    pub dsps: usize,
    /// Reported throughput, GOPS.
    pub gops: f64,
    /// Whether the design switches reuse schemes per layer.
    pub flexible_reuse: bool,
    /// Whether shortcut data is fused in hardware.
    pub shortcut_fusion_hw: bool,
    /// Reported on-chip SRAM, MB.
    pub sram_mb: f64,
    /// Reported DSP efficiency, %.
    pub dsp_efficiency_pct: f64,
}

/// Table VI literature rows.
pub const TABLE6_FRAMEWORKS: [Framework; 3] = [
    Framework {
        name: "ML-Suite",
        platform: "VU9P (16nm)",
        freq_mhz: 500.0,
        input: 224,
        precision_bits: 8,
        latency_ms: 7.77,
        luts_k: 612.0,
        dsps: 5493,
        gops: 1290.0,
        flexible_reuse: false,
        shortcut_fusion_hw: false,
        sram_mb: 31.2,
        dsp_efficiency_pct: 23.47,
    },
    Framework {
        name: "FPL'19",
        platform: "VU9P (16nm)",
        freq_mhz: 125.0,
        input: 224,
        precision_bits: 8,
        latency_ms: 23.8,
        luts_k: 605.0,
        dsps: 6005,
        gops: 328.0,
        flexible_reuse: false,
        shortcut_fusion_hw: false,
        sram_mb: 18.8,
        dsp_efficiency_pct: 21.85,
    },
    Framework {
        name: "Cloud-DNN",
        platform: "VU9P (16nm)",
        freq_mhz: 214.0,
        input: 224,
        precision_bits: 16,
        latency_ms: 8.12,
        luts_k: 696.0,
        dsps: 5489,
        gops: 1235.0,
        flexible_reuse: false,
        shortcut_fusion_hw: false,
        sram_mb: 38.3,
        dsp_efficiency_pct: 52.58,
    },
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn claims_hold_against_constants() {
        // §V-B: "7.4× less SRAM than Cloud-DNN", "2.4× higher DSP
        // efficiency than ML-Suite", "6.0× less SRAM than ML-Suite".
        let ours_sram = 5.2;
        let ours_eff = 56.14;
        let cloud = &TABLE6_FRAMEWORKS[2];
        let mls = &TABLE6_FRAMEWORKS[0];
        assert!((cloud.sram_mb / ours_sram - 7.4).abs() < 0.3);
        assert!((ours_eff / mls.dsp_efficiency_pct - 2.4).abs() < 0.1);
        assert!((mls.sram_mb / ours_sram - 6.0).abs() < 0.1);
    }
}
