//! Bench harness (criterion is unavailable offline — DESIGN.md §9).
//!
//! Provides wall-clock measurement with warmup + median/mean reporting,
//! aligned text tables, and the *paper-vs-measured* row format every
//! `benches/*.rs` target uses to regenerate its table or figure.

use std::time::Instant;

/// Result of timing a closure.
#[derive(Debug, Clone, Copy)]
pub struct Timing {
    /// Measured iterations (after one warmup).
    pub iters: usize,
    /// Median wall-clock per iteration, ms.
    pub median_ms: f64,
    /// Mean wall-clock per iteration, ms.
    pub mean_ms: f64,
    /// Fastest iteration, ms.
    pub min_ms: f64,
    /// Slowest iteration, ms.
    pub max_ms: f64,
}

/// Time `f` for `iters` iterations after one warmup run.
pub fn time<R>(iters: usize, mut f: impl FnMut() -> R) -> Timing {
    assert!(iters > 0);
    std::hint::black_box(f()); // warmup
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Timing {
        iters,
        median_ms: samples[samples.len() / 2],
        mean_ms: samples.iter().sum::<f64>() / samples.len() as f64,
        min_ms: samples[0],
        max_ms: *samples.last().unwrap(),
    }
}

/// Print a harness-timing line in a stable, grep-friendly format.
pub fn report_timing(name: &str, t: &Timing) {
    println!(
        "bench {name}: median {:.3} ms, mean {:.3} ms (min {:.3}, max {:.3}, n={})",
        t.median_ms, t.mean_ms, t.min_ms, t.max_ms, t.iters
    );
}

/// Aligned text table builder.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// An empty table with a title and column headers.
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (must match the header count).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Print to stdout (see [`Table::render`]).
    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// The aligned table as a string (so CLI commands with `--out FILE`
    /// can write the same thing they print).
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = format!("\n== {} ==\n", self.title);
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:<w$}  ", c, w = widths[i]));
            }
            format!("{}\n", s.trim_end())
        };
        out.push_str(&line(&self.headers));
        out.push_str(&widths.iter().map(|w| "-".repeat(*w + 2)).collect::<String>());
        out.push('\n');
        for r in &self.rows {
            out.push_str(&line(r));
        }
        out
    }
}

/// Format a paper-vs-measured comparison cell: `measured (paper X, ×r)`.
pub fn vs_paper(measured: f64, paper: f64, unit: &str) -> String {
    let ratio = if paper != 0.0 { measured / paper } else { f64::NAN };
    format!("{measured:.2} {unit} (paper {paper:.2}, x{ratio:.2})")
}

/// Two-column number formatting helpers used across benches.
pub fn f2(v: f64) -> String {
    format!("{v:.2}")
}

/// One-decimal formatting.
pub fn f1(v: f64) -> String {
    format!("{v:.1}")
}

/// Integer formatting.
pub fn i0(v: usize) -> String {
    format!("{v}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_measures_something() {
        let t = time(5, || {
            let mut s = 0u64;
            for i in 0..10_000u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(t.median_ms >= 0.0 && t.min_ms <= t.median_ms && t.median_ms <= t.max_ms);
    }

    #[test]
    fn table_roundtrips() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        t.print(); // shouldn't panic
    }

    #[test]
    #[should_panic(expected = "column count")]
    fn table_checks_columns() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(&["1".into()]);
    }

    #[test]
    fn vs_paper_format() {
        let s = vs_paper(10.0, 20.0, "ms");
        assert!(s.contains("x0.50"), "{s}");
    }
}
