//! Accelerator configuration (the hardware the compiler targets).
//!
//! Mirrors the paper's two instantiations: the KCU1500 8-bit design of
//! Table V (Ti = To = 64, 200 MHz, shared-MAC double-INT8) and the 16-bit
//! VC707-class comparison configuration of Table II (one multiply per
//! DSP, ShortcutMining-equivalent BRAM budget). A TOML-subset parser
//! loads overrides from `configs/*.toml` (serde/toml are unavailable
//! offline — DESIGN.md §9).

use crate::compiler::CompileError;
use crate::serialize::Json;
use crate::Result;
use std::collections::BTreeMap;
use std::path::Path;

/// Hardware description consumed by the optimizer, the timing simulator
/// and the power model.
#[derive(Debug, Clone, PartialEq)]
pub struct AccelConfig {
    /// Human-readable name for reports.
    pub name: String,
    /// Input-channel parallelism (buffer banks).
    pub ti: usize,
    /// Output-channel parallelism (MAC array pairs).
    pub to: usize,
    /// Clock frequency in MHz.
    pub freq_mhz: f64,
    /// DSP slices used by the MAC arrays.
    pub dsp_mac: usize,
    /// Total DSPs reported for utilization rows (MAC + datapath misc).
    pub dsp_total: usize,
    /// Multiplications per DSP per cycle for *normal* conv (2 with the
    /// shared-MAC double-INT8 trick, 1 in 16-bit mode — Fig. 7).
    pub mults_per_dsp: usize,
    /// BRAM18K blocks available on the device.
    pub bram18k_total: usize,
    /// Feature-map precision in bytes (`Q_A`).
    pub qa: usize,
    /// Weight precision in bytes.
    pub qw: usize,
    /// Partial-sum precision in bytes (`Q_S`, 4-byte accumulators).
    pub qs: usize,
    /// Effective DRAM bandwidth in GB/s (one DDR4-2400 x64 channel
    /// de-rated to 85 % efficiency on the KCU1500).
    pub dram_gbps: f64,
    /// SRAM budget for the three physical buffers + fixed buffers, bytes.
    /// The optimizer's eq-10 constraint.
    pub sram_budget: usize,
}

impl AccelConfig {
    /// The paper's main 8-bit KCU1500 configuration (Table V).
    pub fn kcu1500_int8() -> Self {
        AccelConfig {
            name: "KCU1500-int8".into(),
            ti: 64,
            to: 64,
            freq_mhz: 200.0,
            // 2048 shared MACs ("the shared MAC array contains 2048 MACs,
            // which supports 4096 multiplications per [cycle]").
            dsp_mac: 2048,
            dsp_total: 2240,
            mults_per_dsp: 2,
            bram18k_total: 4320,
            qa: 1,
            qw: 1,
            qs: 4,
            dram_gbps: 19.2 * 0.85,
            // Bounded by the device BRAM (4320 x 18 Kb ~ 9 MB raw); Table VI
            // reports 5.2 MB for the paper instance — per-network BRAM
            // utilization varies 50-87 % in Tables V/VII.
            sram_budget: 8_000_000,
        }
    }

    /// 16-bit configuration used for the ShortcutMining comparison
    /// (Table II): one multiplication per DSP, BRAM constrained to the
    /// VC707's 2040 × 18 Kb budget.
    pub fn table2_int16() -> Self {
        AccelConfig {
            name: "KCU1500-int16-T2".into(),
            ti: 32,
            to: 32,
            freq_mhz: 200.0,
            dsp_mac: 2048,
            dsp_total: 2240,
            mults_per_dsp: 1,
            bram18k_total: 2040,
            qa: 2,
            qw: 2,
            qs: 4,
            dram_gbps: 19.2 * 0.85,
            // ShortcutMining's 2040 BRAM18K ≈ 4.48 MB of raw SRAM.
            sram_budget: 4_480_000,
        }
    }

    /// Peak GOPS of the MAC arrays (the denominator of the paper's DSP
    /// efficiency metric: `4 × freq × N_DSP` in INT8 mode).
    pub fn peak_gops(&self) -> f64 {
        // mults/cycle × 2 ops (mul+acc) × freq
        (self.dsp_mac * self.mults_per_dsp) as f64 * 2.0 * self.freq_mhz / 1e3
    }

    /// Multiplications per cycle for normal convolution.
    pub fn mults_per_cycle_normal(&self) -> usize {
        self.dsp_mac * self.mults_per_dsp
    }

    /// Multiplications per cycle for depthwise convolution (no input
    /// sharing — single-mult mode, Fig. 7b).
    pub fn mults_per_cycle_depthwise(&self) -> usize {
        self.dsp_mac
    }

    /// DRAM bytes transferable per accelerator clock cycle.
    pub fn dram_bytes_per_cycle(&self) -> f64 {
        self.dram_gbps * 1e9 / (self.freq_mhz * 1e6)
    }

    /// Serialize every field to JSON (the packed [`crate::program`]
    /// artifact embeds the full target description, so a loaded program
    /// never depends on a preset being available).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("ti", Json::num(self.ti as f64)),
            ("to", Json::num(self.to as f64)),
            ("freq_mhz", Json::num(self.freq_mhz)),
            ("dsp_mac", Json::num(self.dsp_mac as f64)),
            ("dsp_total", Json::num(self.dsp_total as f64)),
            ("mults_per_dsp", Json::num(self.mults_per_dsp as f64)),
            ("bram18k_total", Json::num(self.bram18k_total as f64)),
            ("qa", Json::num(self.qa as f64)),
            ("qw", Json::num(self.qw as f64)),
            ("qs", Json::num(self.qs as f64)),
            ("dram_gbps", Json::num(self.dram_gbps)),
            ("sram_budget", Json::num(self.sram_budget as f64)),
        ])
    }

    /// Exact inverse of [`AccelConfig::to_json`]; every field must be
    /// present (a partial config would silently change the target).
    pub fn from_json(doc: &Json) -> Result<Self> {
        let text = |key: &str| -> Result<String> {
            doc.get(key)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| CompileError::config(format!("config json: missing string {key:?}")))
        };
        let float = |key: &str| -> Result<f64> {
            doc.get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| CompileError::config(format!("config json: missing number {key:?}")))
        };
        let uint = |key: &str| -> Result<usize> {
            doc.get(key).and_then(Json::as_usize).ok_or_else(|| {
                CompileError::config(format!("config json: missing integer {key:?}"))
            })
        };
        Ok(AccelConfig {
            name: text("name")?,
            ti: uint("ti")?,
            to: uint("to")?,
            freq_mhz: float("freq_mhz")?,
            dsp_mac: uint("dsp_mac")?,
            dsp_total: uint("dsp_total")?,
            mults_per_dsp: uint("mults_per_dsp")?,
            bram18k_total: uint("bram18k_total")?,
            qa: uint("qa")?,
            qw: uint("qw")?,
            qs: uint("qs")?,
            dram_gbps: float("dram_gbps")?,
            sram_budget: uint("sram_budget")?,
        })
    }

    /// Load from a TOML-subset file, starting from the named preset and
    /// applying overrides.
    pub fn from_toml_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path).map_err(|e| CompileError::io(path, e))?;
        Self::from_toml(&text)
    }

    /// Parse the TOML subset: `key = value` lines, `#` comments, one
    /// optional `[accelerator]` section header, string/number/bool values.
    pub fn from_toml(text: &str) -> Result<Self> {
        let kv = parse_toml_subset(text)?;
        let preset = kv.get("preset").map(String::as_str).unwrap_or("kcu1500_int8");
        let mut cfg = match preset {
            "kcu1500_int8" => Self::kcu1500_int8(),
            "table2_int16" => Self::table2_int16(),
            other => return Err(CompileError::config(format!("unknown preset {other:?}"))),
        };
        for (k, v) in &kv {
            match k.as_str() {
                "preset" => {}
                "name" => cfg.name = v.clone(),
                "ti" => cfg.ti = parse_num(k, v)? as usize,
                "to" => cfg.to = parse_num(k, v)? as usize,
                "freq_mhz" => cfg.freq_mhz = parse_num(k, v)?,
                "dsp_mac" => cfg.dsp_mac = parse_num(k, v)? as usize,
                "dsp_total" => cfg.dsp_total = parse_num(k, v)? as usize,
                "mults_per_dsp" => cfg.mults_per_dsp = parse_num(k, v)? as usize,
                "bram18k_total" => cfg.bram18k_total = parse_num(k, v)? as usize,
                "qa" => cfg.qa = parse_num(k, v)? as usize,
                "qw" => cfg.qw = parse_num(k, v)? as usize,
                "qs" => cfg.qs = parse_num(k, v)? as usize,
                "dram_gbps" => cfg.dram_gbps = parse_num(k, v)?,
                "sram_budget" => cfg.sram_budget = parse_num(k, v)? as usize,
                other => return Err(CompileError::config(format!("unknown config key {other:?}"))),
            }
        }
        Ok(cfg)
    }
}

fn parse_num(key: &str, v: &str) -> Result<f64> {
    v.parse::<f64>()
        .map_err(|_| CompileError::config(format!("config key {key}: bad number {v:?}")))
}

/// `key = value` lines with comments and an optional section header.
fn parse_toml_subset(text: &str) -> Result<BTreeMap<String, String>> {
    let mut out = BTreeMap::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() || (line.starts_with('[') && line.ends_with(']')) {
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| CompileError::config(format!("line {}: expected key = value", ln + 1)))?;
        let v = v.trim().trim_matches('"').to_string();
        out.insert(k.trim().to_string(), v);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_gops_matches_paper() {
        // 2048 DSPs × 2 mult × 2 op × 0.2 GHz = 1638.4 GOPS — the
        // denominator behind Table V's 71 % MAC efficiency at 1163 GOPS.
        let c = AccelConfig::kcu1500_int8();
        assert!((c.peak_gops() - 1638.4).abs() < 0.1);
        // Table II (16-bit): 2048 × 1 × 2 × 0.2 = 819.2 GOPS peak.
        let c16 = AccelConfig::table2_int16();
        assert!((c16.peak_gops() - 819.2).abs() < 0.1);
    }

    #[test]
    fn toml_overrides() {
        let cfg = AccelConfig::from_toml(
            "# comment\n[accelerator]\npreset = \"kcu1500_int8\"\nfreq_mhz = 300\nti = 32\n",
        )
        .unwrap();
        assert_eq!(cfg.freq_mhz, 300.0);
        assert_eq!(cfg.ti, 32);
        assert_eq!(cfg.to, 64); // untouched
    }

    #[test]
    fn toml_rejects_unknown_keys() {
        assert!(AccelConfig::from_toml("bogus = 1\n").is_err());
        assert!(AccelConfig::from_toml("preset = \"nope\"\n").is_err());
    }

    #[test]
    fn json_round_trip_is_exact() {
        for cfg in [AccelConfig::kcu1500_int8(), AccelConfig::table2_int16()] {
            let j = cfg.to_json();
            let back = AccelConfig::from_json(&j).unwrap();
            assert_eq!(cfg, back);
            // and the serialized text is stable across a reparse
            let text = j.to_string_compact();
            let j2 = crate::serialize::parse(&text).unwrap();
            assert_eq!(AccelConfig::from_json(&j2).unwrap().to_json().to_string_compact(), text);
        }
    }

    #[test]
    fn json_rejects_missing_fields() {
        let mut j = AccelConfig::kcu1500_int8().to_json();
        if let Json::Obj(m) = &mut j {
            m.remove("freq_mhz");
        }
        assert!(AccelConfig::from_json(&j).is_err());
    }

    #[test]
    fn dram_bytes_per_cycle_sane() {
        let c = AccelConfig::kcu1500_int8();
        // 16.3 GB/s at 200 MHz ≈ 81 B/cycle.
        assert!((c.dram_bytes_per_cycle() - 81.6).abs() < 1.0);
    }
}
