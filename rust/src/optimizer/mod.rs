//! The reuse-aware shortcut optimizer (§IV).
//!
//! Pipeline:
//! 1. [`blocks::basic_blocks`] — partition groups into *basic blocks*
//!    (a residual block, or a single layer outside any residual block,
//!    Fig. 10); all layers of a block share one reuse scheme.
//! 2. [`segments::segments`] — split the block sequence into maximal
//!    monotone feature-map-size runs; each run carries exactly one
//!    cut-point (the paper's relaxation, Fig. 11/12: classifier = 1 cut,
//!    FPN = 2, PANet = 3, BiFPN×r = 2r+1).
//! 3. [`bufcalc`] — Algorithm 1 + equations (1)–(7): required SRAM and
//!    BRAM18K for a candidate policy.
//! 4. [`dram`] — equations (8)–(9): DRAM traffic for a candidate policy.
//! 5. [`cutpoint`] — exhaustive O(N^k) search (coordinate descent beyond
//!    k = 4) for the latency-optimal policy under the eq-(10) buffer and
//!    DRAM constraints.

pub mod blocks;
pub mod segments;
pub mod bufcalc;
pub mod dram;
pub mod cutpoint;

pub use blocks::{basic_blocks, BasicBlock};
pub use bufcalc::{sram_size, sram_size_tiled, SramBreakdown};
pub use cutpoint::{CutPolicy, Evaluation, LatencyFn, Optimizer, SweepPoint};
pub use dram::{dram_access, DramBreakdown};
pub use segments::{segments, Direction, Segment};
