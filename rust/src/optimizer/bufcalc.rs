//! On-chip buffer sizing: Algorithm 1 and equations (1)–(7).

use crate::alloc::AllocResult;
use crate::analyzer::{GroupKind, GroupedGraph};
use crate::config::AccelConfig;
use crate::graph::OpKind;
use crate::isa::ReuseMode;

/// SRAM requirement of a reuse policy, itemized as in §IV-B.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SramBreakdown {
    /// Algorithm 1's `buff[0..2](L)` — the three physical buffers
    /// (buffer 1 already merged with the weight buffer per eq. 2).
    pub buff: [usize; 3],
    /// eq. (1): largest whole-layer weight preload among row-reuse layers.
    pub weight_buff: usize,
    /// eq. (3): six-row circular input buffer.
    pub row_buff: usize,
    /// eq. (4): whole-frame partial-sum buffer for frame-reuse layers.
    pub out_buff: usize,
    /// eq. (5): write-back buffer.
    pub write_buff: usize,
    /// SE / FC vector SRAM (Fig. 13c).
    pub aux: usize,
    /// Depth-first tile working set ([`crate::tile::tile_buff`]); 0 for
    /// whole-frame policies.
    pub tile_buff: usize,
    /// eq. (6): total raw SRAM bytes.
    pub total: usize,
    /// eq. (7): BRAM18K blocks.
    pub bram18k: usize,
}

/// Compute the SRAM breakdown for `policy` given the allocator's
/// placement result.
pub fn sram_size(
    gg: &GroupedGraph,
    policy: &[ReuseMode],
    alloc: &AllocResult,
    cfg: &AccelConfig,
) -> SramBreakdown {
    sram_size_impl(gg, policy, alloc, cfg, None)
}

/// eq. (1)–(7) extended for a depth-first tile plan: groups inside a
/// tiled region drop out of the eq-(1) whole-layer weight-preload max
/// (their weights are accounted in the region's working set — resident
/// or chunk-streamed), and the plan's largest [`crate::tile::tile_buff`]
/// working set joins the eq-(6)/(7) totals. The eq-(3) row buffer and
/// eq-(5) write buffer keep their all-group maxima, a conservative
/// over-estimate for tiled groups.
pub fn sram_size_tiled(
    gg: &GroupedGraph,
    policy: &[ReuseMode],
    alloc: &AllocResult,
    cfg: &AccelConfig,
    plan: &crate::tile::TilePlan,
) -> SramBreakdown {
    sram_size_impl(gg, policy, alloc, cfg, Some(plan))
}

fn sram_size_impl(
    gg: &GroupedGraph,
    policy: &[ReuseMode],
    alloc: &AllocResult,
    cfg: &AccelConfig,
    plan: Option<&crate::tile::TilePlan>,
) -> SramBreakdown {
    let qa = cfg.qa;
    let qs = cfg.qs;
    let to = cfg.to;

    // Algorithm 1: the physical-buffer peaks come from the allocator's
    // liveness walk (same max() recurrences, machine-checked there).
    let mut buff = alloc.buf_peak;

    // eq. (1): in row-reuse mode the entire layer weights are preloaded.
    // Tiled-region groups are excluded — their weights live in the tile
    // working set instead (resident sum or streamed chunk).
    let weight_buff = gg
        .groups
        .iter()
        .enumerate()
        .filter(|(gi, _)| policy[*gi] == ReuseMode::Row)
        .filter(|(gi, _)| plan.is_none_or(|p| p.region_of(*gi).is_none()))
        .map(|(_, gr)| gr.weight_bytes(&gg.graph, cfg.qw as u64) as usize)
        .max()
        .unwrap_or(0);

    // eq. (2): buffer 1 is shared between feature maps and weights.
    buff[1] = buff[1].max(weight_buff);

    // eq. (3): six rows (one for prefetch) of `w × N` input pixels.
    let row_buff = gg
        .groups
        .iter()
        .filter(|gr| is_conv_like(gg, gr))
        .map(|gr| 6 * gr.in_shape.w * gr.in_shape.c * qa)
        .max()
        .unwrap_or(0);

    // eq. (4): frame-reuse layers accumulate To channels of the whole
    // output frame in Q_S-wide partial sums. The frame is the *conv*
    // output (pre-pooling).
    let out_buff = gg
        .groups
        .iter()
        .enumerate()
        .filter(|(gi, gr)| policy[*gi] == ReuseMode::Frame && is_conv_like(gg, gr))
        .map(|(_, gr)| {
            let conv_out = gg.graph.node(gr.main).out_shape;
            conv_out.w * conv_out.h * to.min(conv_out.c.max(1)) * qs
        })
        .max()
        .unwrap_or(0);

    // eq. (5): write buffer — one row (row-reuse) vs one frame slice
    // (frame-reuse final layers).
    let consumers = gg.consumers();
    let write_row = gg
        .groups
        .iter()
        .enumerate()
        .filter(|(gi, _)| policy[*gi] == ReuseMode::Row)
        .map(|(_, gr)| gr.out_shape.w * to * qa)
        .max()
        .unwrap_or(0);
    let write_frame_final = gg
        .groups
        .iter()
        .enumerate()
        .filter(|(gi, _)| policy[*gi] == ReuseMode::Frame && consumers[*gi].is_empty())
        .map(|(_, gr)| gr.out_shape.w * gr.out_shape.h * to * qa)
        .max()
        .unwrap_or(0);
    let write_buff = write_row.max(write_frame_final);

    // eq. (6), extended with the depth-first tile working set
    let aux = alloc.aux_peak;
    let tile_buff = plan.map(|p| crate::tile::tile_buff(gg, cfg, p)).unwrap_or(0);
    let total = row_buff + out_buff + write_buff + buff[0] + buff[1] + buff[2] + aux + tile_buff;

    // eq. (7): BRAM18K per buffer with To banks of 18-bit-wide ports
    // (16 data bits): depth_per_bank = bytes / (banks × 2).
    let bram = |bytes: usize, width_bytes: usize| -> usize {
        if bytes == 0 {
            return 0;
        }
        let banks = to;
        let depth = (bytes / width_bytes).div_ceil(banks);
        banks * depth.div_ceil(1024) * (width_bytes * 8).div_ceil(18)
    };
    let bram18k = bram(buff[0], 2)
        + bram(buff[1], 2)
        + bram(buff[2], 2)
        + bram(row_buff, 2)
        + bram(out_buff, 4)
        + bram(write_buff, 2)
        + bram(aux.max(1), 2)
        + bram(tile_buff, 2)
        // swish/sigmoid LUTs: two per 18 Kb BRAM, To of each (§III-B).
        + to;

    SramBreakdown {
        buff,
        weight_buff,
        row_buff,
        out_buff,
        write_buff,
        aux,
        tile_buff,
        total,
        bram18k,
    }
}

fn is_conv_like(gg: &GroupedGraph, gr: &crate::analyzer::Group) -> bool {
    matches!(gr.kind, GroupKind::Conv | GroupKind::DwConv)
        && matches!(gg.graph.node(gr.main).op, OpKind::Conv { .. })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::allocate;
    use crate::analyzer::analyze;
    use crate::zoo;

    fn eval(name: &str, mode: ReuseMode) -> SramBreakdown {
        let gg = analyze(&zoo::by_name(name, zoo::default_input(name)).unwrap());
        let cfg = AccelConfig::kcu1500_int8();
        let policy = vec![mode; gg.groups.len()];
        let alloc = allocate(&gg, &policy, &cfg);
        sram_size(&gg, &policy, &alloc, &cfg)
    }

    #[test]
    fn all_row_needs_weight_buffer_not_fmap_buffers() {
        let s = eval("vgg16-conv", ReuseMode::Row);
        assert_eq!(s.buff[0], 0);
        assert_eq!(s.buff[2], 0);
        // largest VGG conv layer: 3x3x512x512 = 2.36 MB
        assert_eq!(s.weight_buff, 3 * 3 * 512 * 512);
        assert_eq!(s.buff[1], s.weight_buff);
        assert_eq!(s.out_buff, 0);
    }

    #[test]
    fn all_frame_needs_fmap_buffers_not_weight_buffer() {
        let s = eval("vgg16-conv", ReuseMode::Frame);
        assert_eq!(s.weight_buff, 0);
        // conv1_1/conv1_2 frames: 224*224*64 output, input staged 224*224*3
        assert!(s.buff.iter().any(|&b| b == 224 * 224 * 64));
        // eq 4: psum frame 224*224*64ch*4B
        assert_eq!(s.out_buff, 224 * 224 * 64 * 4);
    }

    #[test]
    fn row_buffer_is_six_rows() {
        let s = eval("vgg16-conv", ReuseMode::Row);
        // widest w×N among convs: 224 wide, 64 channels = 14336 per row
        assert_eq!(s.row_buff, 6 * 224 * 64);
    }

    #[test]
    fn total_is_sum_of_parts() {
        for mode in [ReuseMode::Row, ReuseMode::Frame] {
            let s = eval("resnet50", mode);
            assert_eq!(
                s.total,
                s.row_buff
                    + s.out_buff
                    + s.write_buff
                    + s.buff[0]
                    + s.buff[1]
                    + s.buff[2]
                    + s.aux
                    + s.tile_buff
            );
            assert_eq!(s.tile_buff, 0, "whole-frame policies carry no tile working set");
        }
    }

    #[test]
    fn tiled_sram_swaps_weight_preload_for_tile_working_set() {
        let gg = analyze(&zoo::vgg16_conv(224));
        let mut cfg = AccelConfig::kcu1500_int8();
        cfg.sram_budget = 1_000_000;
        let policy = vec![ReuseMode::Row; gg.groups.len()];
        let alloc = allocate(&gg, &policy, &cfg);
        let plain = sram_size(&gg, &policy, &alloc, &cfg);
        let plan = crate::tile::plan(&gg, &cfg, 8);
        assert!(!plan.is_empty());
        let tiled = sram_size_tiled(&gg, &policy, &alloc, &cfg, &plan);
        assert_eq!(tiled.tile_buff, crate::tile::tile_buff(&gg, &cfg, &plan));
        assert!(tiled.tile_buff > 0);
        // Tiled regions leave the eq-(1) preload max; under a 1 MB budget
        // that max (2.36 MB conv5 weights untiled) must shrink.
        assert!(
            tiled.weight_buff < plain.weight_buff,
            "tiled {} !< plain {}",
            tiled.weight_buff,
            plain.weight_buff
        );
    }

    #[test]
    fn bram_estimate_tracks_bytes() {
        let s8 = eval("resnet50", ReuseMode::Frame);
        // BRAM capacity must cover the raw bytes (2 KB data per BRAM18K
        // at 16 usable bits) within bank-quantization slack.
        let capacity = s8.bram18k * 2048;
        assert!(capacity >= s8.total, "{} < {}", capacity, s8.total);
        assert!(s8.bram18k < 4320 * 3, "absurd BRAM count {}", s8.bram18k);
    }

    #[test]
    fn sixteen_bit_doubles_fmap_sram() {
        let gg = analyze(&zoo::resnet152(224));
        let mut cfg = AccelConfig::table2_int16();
        cfg.to = 64; // isolate the qa effect from bank count
        let policy = vec![ReuseMode::Frame; gg.groups.len()];
        let alloc16 = allocate(&gg, &policy, &cfg);
        let s16 = sram_size(&gg, &policy, &alloc16, &cfg);

        let cfg8 = AccelConfig::kcu1500_int8();
        let alloc8 = allocate(&gg, &policy, &cfg8);
        let s8 = sram_size(&gg, &policy, &alloc8, &cfg8);
        assert!(s16.buff[0] >= 2 * s8.buff[0].min(1).max(s8.buff[0] / 2));
        assert!(s16.total > s8.total);
    }
}
