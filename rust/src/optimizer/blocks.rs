//! Basic-block partition (Fig. 10): "a block of layers is defined as a
//! residual block or a single CNN layer which does not belong to any
//! residual blocks."

use crate::analyzer::{GroupKind, GroupedGraph};

/// A contiguous run of groups sharing one reuse decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BasicBlock {
    /// First group index (inclusive).
    pub start: usize,
    /// Last group index (inclusive).
    pub end: usize,
    /// True when the block closes with a fused shortcut addition.
    pub is_residual: bool,
}

impl BasicBlock {
    /// The group indices this block spans.
    pub fn groups(&self) -> std::ops::RangeInclusive<usize> {
        self.start..=self.end
    }

    /// Number of groups in the block.
    pub fn len(&self) -> usize {
        self.end - self.start + 1
    }

    /// Always `false`: a block spans at least one group.
    pub fn is_empty(&self) -> bool {
        false
    }
}

/// Partition all groups (except the input feed) into basic blocks.
///
/// Residual spans come from fused shortcuts: a group `g` with
/// `shortcut_of = s` closes the block `[s+1, g]` (both branches of the
/// residual live inside). Long FPN skips that would swallow previously
/// closed blocks are clamped — the paper stores those shortcut tensors
/// off-chip anyway (§IV-A), so they do not bind reuse decisions together.
pub fn basic_blocks(gg: &GroupedGraph) -> Vec<BasicBlock> {
    let n = gg.groups.len();
    // Collect residual spans (clamped later), ordered by end.
    let mut spans: Vec<(usize, usize)> = Vec::new();
    for (gi, gr) in gg.groups.iter().enumerate() {
        if let Some(s) = gr.shortcut_of {
            spans.push((s.0 + 1, gi));
        }
    }
    spans.sort_by_key(|&(_, e)| e);

    let mut blocks = Vec::new();
    let mut cur = 1usize; // group 0 is the Input feed
    for (s, e) in spans {
        if e < cur {
            continue; // nested within an already-closed block
        }
        let s = s.max(cur);
        // groups before the span are single-layer blocks
        for g in cur..s {
            blocks.push(BasicBlock { start: g, end: g, is_residual: false });
        }
        blocks.push(BasicBlock { start: s, end: e, is_residual: true });
        cur = e + 1;
    }
    for g in cur..n {
        blocks.push(BasicBlock { start: g, end: g, is_residual: false });
    }
    blocks
}

/// Representative feature-map pixel count of a block (used for the
/// monotone-size segmentation): the largest *spatial* fmap its groups
/// produce. Vector tensors (SE gates, FC activations) are scale-neutral
/// and return 0 — the segmentation carries the surrounding scale across
/// them.
pub fn block_scale(gg: &GroupedGraph, b: &BasicBlock) -> u64 {
    b.groups()
        .map(|g| {
            let s = gg.groups[g].out_shape;
            if s.h * s.w <= 1 {
                0
            } else {
                (s.h * s.w) as u64
            }
        })
        .max()
        .unwrap_or(0)
}

/// True when the block contains any compute group.
pub fn block_has_compute(gg: &GroupedGraph, b: &BasicBlock) -> bool {
    b.groups().any(|g| {
        matches!(
            gg.groups[g].kind,
            GroupKind::Conv | GroupKind::DwConv | GroupKind::Fc | GroupKind::Scale
        )
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analyzer::analyze;
    use crate::zoo;

    #[test]
    fn blocks_tile_all_groups() {
        for &name in zoo::MODEL_NAMES {
            let gg = analyze(&zoo::by_name(name, zoo::default_input(name)).unwrap());
            let blocks = basic_blocks(&gg);
            let mut next = 1usize;
            for b in &blocks {
                assert_eq!(b.start, next, "{name}: gap before block");
                assert!(b.end >= b.start, "{name}");
                next = b.end + 1;
            }
            assert_eq!(next, gg.groups.len(), "{name}: trailing gap");
        }
    }

    #[test]
    fn resnet50_residual_block_count() {
        let gg = analyze(&zoo::resnet50(224));
        let blocks = basic_blocks(&gg);
        let residual = blocks.iter().filter(|b| b.is_residual).count();
        assert_eq!(residual, 16);
    }

    #[test]
    fn vgg_is_all_single_blocks() {
        let gg = analyze(&zoo::vgg16_conv(224));
        let blocks = basic_blocks(&gg);
        assert!(blocks.iter().all(|b| !b.is_residual));
        assert_eq!(blocks.len(), gg.groups.len() - 1);
    }

    #[test]
    fn efficientnet_blocks() {
        let gg = analyze(&zoo::efficientnet_b1(256));
        let blocks = basic_blocks(&gg);
        // 16 identity-shortcut MBConv blocks are residual.
        assert_eq!(blocks.iter().filter(|b| b.is_residual).count(), 16);
        // residual MBConv blocks span the whole expand→project chain
        for b in blocks.iter().filter(|b| b.is_residual) {
            assert!(b.len() >= 5, "MBConv block too small: {}", b.len());
        }
    }
}
