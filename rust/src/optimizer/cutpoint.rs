//! Cut-point search (§IV-B): find the reuse policy L minimizing latency
//! subject to the buffer and DRAM-access constraints (eq. 10).

use super::blocks::{basic_blocks, BasicBlock};
use super::bufcalc::{sram_size, SramBreakdown};
use super::dram::{dram_access, DramBreakdown};
use super::segments::{segments, Direction, Segment};
use crate::alloc::{allocate, AllocResult};
use crate::analyzer::GroupedGraph;
use crate::config::AccelConfig;
use crate::isa::ReuseMode;
use crate::sim::simulate;

/// One cut position per segment.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CutPolicy {
    /// Cut position per segment (block index within the segment).
    pub cuts: Vec<usize>,
}

/// Pluggable latency estimator. The default is the crate's cycle-accurate
/// simulator; tests may supply a proxy. A plain (non-capturing) function
/// pointer: the optimizer stays `Copy`-free of drop glue, `Send + Sync`,
/// and borrowing it never extends the grouped graph's borrow (the seed's
/// `Box<dyn Fn>` forced a `drop(opt)` workaround in the pipeline).
pub type LatencyFn = fn(&GroupedGraph, &[ReuseMode], &AllocResult, &AccelConfig) -> f64;

/// Full evaluation of one candidate policy.
#[derive(Debug, Clone)]
pub struct Evaluation {
    /// The cut positions that produced this policy (empty for uniform
    /// baseline policies that bypass the cut search).
    pub cuts: CutPolicy,
    /// Reuse scheme per group.
    pub policy: Vec<ReuseMode>,
    /// SRAM requirement breakdown (eqs. 1–7).
    pub sram: SramBreakdown,
    /// DRAM traffic breakdown (eqs. 8–9).
    pub dram: DramBreakdown,
    /// Simulated end-to-end latency, ms.
    pub latency_ms: f64,
    /// eq. (10): SRAM within budget and BRAM within the device.
    pub feasible: bool,
    /// Depth-first tile plan ([`crate::tile`]); `None` for whole-frame
    /// strategies. When set, the SRAM/DRAM breakdowns include the plan's
    /// tile-buffer and halo/weight-restream terms.
    pub tiles: Option<crate::tile::TilePlan>,
}

/// One point of a Fig-16/17-style sweep.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    /// Cut position in the swept (first) segment.
    pub cut: usize,
    /// Total SRAM requirement, MB.
    pub sram_mb: f64,
    /// BRAM18K blocks.
    pub bram18k: usize,
    /// Total DRAM traffic, MB.
    pub dram_total_mb: f64,
    /// Feature-map DRAM traffic, MB.
    pub dram_fm_mb: f64,
    /// Simulated latency, ms.
    pub latency_ms: f64,
    /// Whether the point meets the eq-(10) constraints.
    pub feasible: bool,
}

/// The reuse-aware shortcut optimizer.
#[derive(Clone)]
pub struct Optimizer<'a> {
    /// The analyzed network.
    pub gg: &'a GroupedGraph,
    /// The target configuration.
    pub cfg: &'a AccelConfig,
    /// Basic-block partition (Fig. 10).
    pub blocks: Vec<BasicBlock>,
    /// Monotone segments, one cut-point each (Fig. 11/12).
    pub segs: Vec<Segment>,
    latency: LatencyFn,
}

/// Exhaustive-search cap; larger spaces fall back to coordinate descent.
const EXHAUSTIVE_CAP: f64 = 200_000.0;

impl<'a> Optimizer<'a> {
    /// Build with the cycle-accurate simulator as the latency oracle.
    pub fn new(gg: &'a GroupedGraph, cfg: &'a AccelConfig) -> Self {
        Self::with_latency(gg, cfg, |gg, policy, alloc, cfg| {
            simulate(gg, policy, alloc, cfg).latency_ms
        })
    }

    /// Build with a custom latency oracle.
    pub fn with_latency(gg: &'a GroupedGraph, cfg: &'a AccelConfig, latency: LatencyFn) -> Self {
        let blocks = basic_blocks(gg);
        let segs = segments(gg, &blocks);
        Optimizer { gg, cfg, blocks, segs, latency }
    }

    /// Expand segment cuts into a per-group reuse policy.
    ///
    /// Decreasing segment (backbone): blocks before the cut are
    /// row-reuse (large maps stream), after it frame-reuse. Increasing
    /// segment (top-down/decoder): the mirror image (frame while maps
    /// are small, row once they grow) — Fig. 15's
    /// `i=row if i < L1 || i ≥ N1+L2`.
    pub fn expand_cuts(&self, cuts: &[usize]) -> Vec<ReuseMode> {
        assert_eq!(cuts.len(), self.segs.len());
        let mut policy = vec![ReuseMode::Frame; self.gg.groups.len()];
        for (seg, &cut) in self.segs.iter().zip(cuts) {
            debug_assert!(cut <= seg.len);
            for rel in 0..seg.len {
                let block = &self.blocks[seg.first_block + rel];
                let mode = match seg.dir {
                    Direction::Dec => {
                        if rel < cut {
                            ReuseMode::Row
                        } else {
                            ReuseMode::Frame
                        }
                    }
                    Direction::Inc => {
                        if rel < cut {
                            ReuseMode::Frame
                        } else {
                            ReuseMode::Row
                        }
                    }
                };
                for g in block.groups() {
                    policy[g] = mode;
                }
            }
        }
        policy
    }

    /// Evaluate one candidate.
    pub fn evaluate(&self, cuts: &[usize]) -> Evaluation {
        let policy = self.expand_cuts(cuts);
        let alloc = allocate(self.gg, &policy, self.cfg);
        let sram = sram_size(self.gg, &policy, &alloc, self.cfg);
        let dram = dram_access(self.gg, &policy, &alloc, self.cfg);
        let latency_ms = (self.latency)(self.gg, &policy, &alloc, self.cfg);
        let feasible =
            sram.total <= self.cfg.sram_budget && sram.bram18k <= self.cfg.bram18k_total;
        Evaluation {
            cuts: CutPolicy { cuts: cuts.to_vec() },
            policy,
            sram,
            dram,
            latency_ms,
            feasible,
            tiles: None,
        }
    }

    /// Search space size.
    pub fn space(&self) -> f64 {
        self.segs.iter().map(|s| s.cut_candidates() as f64).product()
    }

    /// Find the latency-optimal feasible policy (exhaustive when the
    /// space allows, coordinate descent otherwise).
    pub fn optimize(&self) -> Evaluation {
        if self.space() <= EXHAUSTIVE_CAP {
            self.optimize_exhaustive()
        } else {
            self.optimize_descent()
        }
    }

    fn better(a: &Evaluation, b: &Evaluation) -> bool {
        // feasible first; then latency, DRAM, SRAM
        match (a.feasible, b.feasible) {
            (true, false) => true,
            (false, true) => false,
            _ => (a.latency_ms, a.dram.total, a.sram.total)
                < (b.latency_ms, b.dram.total, b.sram.total),
        }
    }

    fn optimize_exhaustive(&self) -> Evaluation {
        let mut cuts = vec![0usize; self.segs.len()];
        let mut best: Option<Evaluation> = None;
        loop {
            let e = self.evaluate(&cuts);
            if best.as_ref().is_none_or(|b| Self::better(&e, b)) {
                best = Some(e);
            }
            // odometer increment
            let mut i = 0;
            loop {
                if i == self.segs.len() {
                    return best.unwrap();
                }
                cuts[i] += 1;
                if cuts[i] <= self.segs[i].len {
                    break;
                }
                cuts[i] = 0;
                i += 1;
            }
        }
    }

    fn optimize_descent(&self) -> Evaluation {
        // Start from the all-row corner (minimal SRAM — feasible whenever
        // anything is) so the feasibility-first ordering can only improve.
        let mut cuts: Vec<usize> = self
            .segs
            .iter()
            .map(|s| match s.dir {
                Direction::Dec => s.len,
                Direction::Inc => 0,
            })
            .collect();
        let mut best = self.evaluate(&cuts);
        for _round in 0..8 {
            let mut improved = false;
            for si in 0..self.segs.len() {
                for c in 0..=self.segs[si].len {
                    if c == cuts[si] {
                        continue;
                    }
                    let mut cand = cuts.clone();
                    cand[si] = c;
                    let e = self.evaluate(&cand);
                    if Self::better(&e, &best) {
                        best = e;
                        cuts = cand;
                        improved = true;
                    }
                }
            }
            if !improved {
                break;
            }
        }
        best
    }

    /// Minimum-buffer policy (Table III): the smallest SRAM total over
    /// the whole cut space (every candidate already meets the eq.-10
    /// DRAM constraint by construction: weights once, fmaps ≤ once).
    pub fn min_buffer(&self) -> Evaluation {
        let mut cuts = vec![0usize; self.segs.len()];
        let mut best: Option<Evaluation> = None;
        if self.space() <= EXHAUSTIVE_CAP {
            loop {
                let e = self.evaluate(&cuts);
                if best
                    .as_ref()
                    .is_none_or(|b| (e.sram.total, e.latency_ms) < (b.sram.total, b.latency_ms))
                {
                    best = Some(e);
                }
                let mut i = 0;
                loop {
                    if i == self.segs.len() {
                        return best.unwrap();
                    }
                    cuts[i] += 1;
                    if cuts[i] <= self.segs[i].len {
                        break;
                    }
                    cuts[i] = 0;
                    i += 1;
                }
            }
        }
        // descent on SRAM
        let mut cur: Vec<usize> = self.segs.iter().map(|s| s.len / 2).collect();
        let mut best = self.evaluate(&cur);
        for _ in 0..8 {
            let mut improved = false;
            for si in 0..self.segs.len() {
                for c in 0..=self.segs[si].len {
                    let mut cand = cur.clone();
                    cand[si] = c;
                    let e = self.evaluate(&cand);
                    if (e.sram.total, e.latency_ms) < (best.sram.total, best.latency_ms) {
                        best = e;
                        cur = cand;
                        improved = true;
                    }
                }
            }
            if !improved {
                break;
            }
        }
        best
    }

    /// Fig-16/17 sweep: vary the first segment's cut across its full
    /// range, holding the other segments at the global optimum.
    pub fn sweep_first_segment(&self) -> Vec<SweepPoint> {
        let opt = self.optimize();
        let mut out = Vec::new();
        for c in 0..=self.segs[0].len {
            let mut cuts = opt.cuts.cuts.clone();
            cuts[0] = c;
            let e = self.evaluate(&cuts);
            out.push(SweepPoint {
                cut: c,
                sram_mb: e.sram.total as f64 / 1e6,
                bram18k: e.sram.bram18k,
                dram_total_mb: e.dram.total as f64 / 1e6,
                dram_fm_mb: e.dram.fm_bytes as f64 / 1e6,
                latency_ms: e.latency_ms,
                feasible: e.feasible,
            });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analyzer::analyze;
    use crate::zoo;

    fn optimizer_for<'a>(gg: &'a GroupedGraph, cfg: &'a AccelConfig) -> Optimizer<'a> {
        Optimizer::new(gg, cfg)
    }

    #[test]
    fn yolov2_optimum_beats_fixed_row() {
        // Fig 16(c): the proposed scheme achieves a 2.17× speed-up over
        // the *naive* fixed row-based baseline (weights re-read per row,
        // Table I).
        let gg = analyze(&zoo::yolov2(416));
        let cfg = AccelConfig::kcu1500_int8();
        let o = optimizer_for(&gg, &cfg);
        let best = o.optimize();
        assert!(best.feasible);
        let baseline = crate::sim::simulate_fixed_row_baseline(&gg, &cfg);
        let speedup = baseline.latency_ms / best.latency_ms;
        assert!(
            (1.5..4.0).contains(&speedup),
            "speed-up {speedup:.2} vs paper's 2.17 (best {} baseline {})",
            best.latency_ms,
            baseline.latency_ms
        );
        // And the optimum is no worse than the proposed design's own
        // all-row policy (weights preloaded once).
        let row_cuts: Vec<usize> = o
            .segs
            .iter()
            .map(|s| match s.dir {
                Direction::Dec => s.len,
                Direction::Inc => 0,
            })
            .collect();
        let row = o.evaluate(&row_cuts);
        assert!(best.latency_ms <= row.latency_ms * 1.0001);
    }

    #[test]
    fn min_buffer_is_below_budget_scale() {
        // Table III: YOLOv2 0.762 MB, VGG 0.712 MB, EfficientNet-B1
        // 0.43 MB — all well under 3 MB.
        for (name, paper_mb) in [
            ("yolov2", 0.762),
            ("vgg16-conv", 0.712),
            ("efficientnet-b1", 0.43),
        ] {
            let gg = analyze(&zoo::by_name(name, zoo::default_input(name)).unwrap());
            let cfg = AccelConfig::kcu1500_int8();
            let o = optimizer_for(&gg, &cfg);
            let e = o.min_buffer();
            let mb = e.sram.total as f64 / 1e6;
            assert!(
                mb < paper_mb * 4.0 && mb > paper_mb / 4.0,
                "{name}: min buffer {mb:.3} MB vs paper {paper_mb}"
            );
        }
    }

    #[test]
    fn optimum_is_feasible_for_all_models() {
        for &name in zoo::MODEL_NAMES {
            let gg = analyze(&zoo::by_name(name, zoo::default_input(name)).unwrap());
            let cfg = AccelConfig::kcu1500_int8();
            let o = optimizer_for(&gg, &cfg);
            let e = o.optimize();
            assert!(e.feasible, "{name}: optimum infeasible (sram {})", e.sram.total);
            assert!(e.latency_ms > 0.0, "{name}");
        }
    }

    #[test]
    fn sweep_shape_matches_fig16() {
        // Fig 16: early cut (more frame-reuse) = larger buffer, less DRAM;
        // late cut = smaller buffer, more DRAM.
        let gg = analyze(&zoo::yolov2(416));
        let cfg = AccelConfig::kcu1500_int8();
        let o = optimizer_for(&gg, &cfg);
        let sweep = o.sweep_first_segment();
        let first = sweep.first().unwrap();
        let last = sweep.last().unwrap();
        assert!(first.sram_mb > last.sram_mb, "frame-heavy needs more SRAM");
        assert!(first.dram_total_mb < last.dram_total_mb, "frame-heavy needs less DRAM");
        assert!(first.latency_ms < last.latency_ms, "frame-heavy is faster");
    }

    #[test]
    fn exhaustive_and_descent_agree_on_yolov3() {
        let gg = analyze(&zoo::yolov3(416));
        let cfg = AccelConfig::kcu1500_int8();
        let o = optimizer_for(&gg, &cfg);
        assert!(o.space() <= EXHAUSTIVE_CAP, "space {}", o.space());
        let ex = o.optimize_exhaustive();
        let de = o.optimize_descent();
        // descent must land within 5 % of the exhaustive optimum
        assert!(
            de.latency_ms <= ex.latency_ms * 1.05,
            "descent {} vs exhaustive {}",
            de.latency_ms,
            ex.latency_ms
        );
    }

    #[test]
    fn policy_expansion_respects_blocks() {
        let gg = analyze(&zoo::resnet50(256));
        let cfg = AccelConfig::kcu1500_int8();
        let o = optimizer_for(&gg, &cfg);
        let cuts = vec![3]; // a few early blocks in row mode
        let policy = o.expand_cuts(&cuts);
        // blocks share one mode
        for b in &o.blocks {
            let modes: std::collections::HashSet<_> =
                b.groups().map(|g| policy[g]).collect();
            assert_eq!(modes.len(), 1, "block {b:?} mixes modes");
        }
        // exactly 3 row blocks
        let row_blocks = o
            .blocks
            .iter()
            .filter(|b| policy[b.start] == ReuseMode::Row)
            .count();
        assert_eq!(row_blocks, 3);
    }
}
