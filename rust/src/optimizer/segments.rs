//! Monotone feature-map-size segmentation (§IV, Fig. 11/12).
//!
//! "In all the recent CNNs, the feature-map size monotonically increases
//! or decreases in a certain sequence of blocks. [...] a sequence of
//! increasing or decreasing size blocks is assumed to have exactly one
//! cut-point."

use super::blocks::{block_scale, BasicBlock};
use crate::analyzer::GroupedGraph;

/// Direction of a monotone run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    /// Feature maps shrink (classifier backbones): row-reuse first,
    /// frame-reuse after the cut.
    Dec,
    /// Feature maps grow (decoder / top-down FPN paths): frame-reuse
    /// first, row-reuse after the cut.
    Inc,
}

/// One monotone run of basic blocks carrying a single cut-point.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Segment {
    /// Index of the first block (into the `basic_blocks` vector).
    pub first_block: usize,
    /// Number of blocks.
    pub len: usize,
    /// Whether feature maps shrink or grow along the run.
    pub dir: Direction,
}

impl Segment {
    /// Valid cut positions: 0..=len.
    pub fn cut_candidates(&self) -> usize {
        self.len + 1
    }
}

/// Oscillations whose peak stays below this pixel count never open a new
/// segment: fmaps this small are frame-reuse material under any policy,
/// so a cut-point inside them cannot pay off (keeps deep head stacks from
/// fragmenting the search space).
const SMALL_PIXELS: u64 = 1024; // 32×32

/// Split the block sequence into maximal monotone segments.
///
/// Ties (equal sizes) extend the current run; vector-only blocks inherit
/// the preceding scale. A new segment opens only on a strict direction
/// reversal above [`SMALL_PIXELS`], so a classifier yields 1 segment, an
/// FPN detector 2–3, and BiFPN×r networks `2r+1`-ish — matching the
/// paper's cut-point counts (Fig. 12).
pub fn segments(gg: &GroupedGraph, blocks: &[BasicBlock]) -> Vec<Segment> {
    assert!(!blocks.is_empty());
    let mut sizes: Vec<u64> = blocks.iter().map(|b| block_scale(gg, b)).collect();
    // carry the surrounding scale across vector-only blocks
    let first_nz = sizes.iter().copied().find(|&s| s > 0).unwrap_or(1);
    let mut prev = first_nz;
    for s in sizes.iter_mut() {
        if *s == 0 {
            *s = prev;
        } else {
            prev = *s;
        }
    }
    let small = SMALL_PIXELS;

    let mut segs: Vec<Segment> = Vec::new();
    let mut start = 0usize;
    let mut dir: Option<Direction> = None;
    for i in 1..sizes.len() {
        let step = if sizes[i] > sizes[i - 1] {
            Some(Direction::Inc)
        } else if sizes[i] < sizes[i - 1] {
            Some(Direction::Dec)
        } else {
            None
        };
        // Oscillations entirely below the "small" threshold do not open
        // new segments — those blocks are frame-reuse material regardless.
        let negligible = sizes[i].max(sizes[i - 1]) <= small;
        match (dir, step) {
            (_, None) => {}
            (None, Some(d)) => dir = Some(d),
            (Some(d), Some(s)) if d == s || negligible => {}
            (Some(d), Some(s)) => {
                segs.push(Segment { first_block: start, len: i - start, dir: d });
                start = i;
                // the reversal step i-1 → i seeds the new run's direction
                dir = Some(s);
            }
        }
    }
    segs.push(Segment {
        first_block: start,
        len: sizes.len() - start,
        dir: dir.unwrap_or(Direction::Dec),
    });
    segs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analyzer::analyze;
    use crate::optimizer::basic_blocks;
    use crate::zoo;

    fn segs_of(name: &str) -> Vec<Segment> {
        let gg = analyze(&zoo::by_name(name, zoo::default_input(name)).unwrap());
        let blocks = basic_blocks(&gg);
        segments(&gg, &blocks)
    }

    #[test]
    fn classifiers_have_one_segment() {
        // Fig 11 (left): "a classification CNN has a single cut-point".
        for name in ["vgg16-conv", "resnet50", "resnet152", "efficientnet-b1", "mobilenetv3-large"]
        {
            let s = segs_of(name);
            assert_eq!(s.len(), 1, "{name}: {s:?}");
            assert_eq!(s[0].dir, Direction::Dec, "{name}");
        }
    }

    #[test]
    fn yolov2_single_segment() {
        // Plain trunk ending at 13×13 (the reorg branch stays small).
        let s = segs_of("yolov2");
        assert_eq!(s.len(), 1, "{s:?}");
    }

    #[test]
    fn yolov3_has_fpn_cut_structure() {
        // Fig 12(a): FPN detectors need two cut-points — a decreasing
        // backbone segment and an increasing top-down segment.
        let s = segs_of("yolov3");
        assert!(s.len() >= 2 && s.len() <= 3, "{s:?}");
        assert_eq!(s[0].dir, Direction::Dec);
        assert!(s.iter().any(|seg| seg.dir == Direction::Inc));
    }

    #[test]
    fn segments_tile_blocks() {
        for &name in zoo::MODEL_NAMES {
            let gg = analyze(&zoo::by_name(name, zoo::default_input(name)).unwrap());
            let blocks = basic_blocks(&gg);
            let segs = segments(&gg, &blocks);
            let mut next = 0usize;
            for s in &segs {
                assert_eq!(s.first_block, next, "{name}");
                next += s.len;
            }
            assert_eq!(next, blocks.len(), "{name}");
        }
    }

    #[test]
    fn cut_point_counts_match_fig12() {
        // Classifier = 1, FPN = 2–3, BiFPN×3 ≈ 7 (paper: 2r+1). Anything
        // beyond the exhaustive cap is handled by coordinate descent, but
        // the segment count itself must stay architectural (≤ 10).
        for &name in zoo::MODEL_NAMES {
            let gg = analyze(&zoo::by_name(name, zoo::default_input(name)).unwrap());
            let blocks = basic_blocks(&gg);
            let segs = segments(&gg, &blocks);
            assert!(
                (1..=10).contains(&segs.len()),
                "{name}: {} segments",
                segs.len()
            );
        }
    }
}
