//! DRAM traffic model: equations (8)–(9) evaluated over the allocator's
//! placements.
//!
//! The placement-driven form subsumes eq. (8)'s three terms:
//! * row-reuse conv layers stream `in + out` (their operands/results live
//!   in DRAM),
//! * fused shortcut layers in row-reuse read their second operand once,
//! * frame-reuse concat feeds cost a write + downstream read
//!   (`2 × in_size`),
//! and additionally captures the cut-boundary effect the paper's tables
//! reflect (a row-reuse layer feeding only frame-reuse consumers hands
//! its output over on-chip — e.g. ResNet50@256's 0.19 MB off-chip
//! feature-map traffic, which is exactly the network input).

use crate::alloc::{AllocResult, Loc};
use crate::analyzer::{GroupKind, GroupedGraph};
use crate::config::AccelConfig;
use crate::isa::ReuseMode;
use crate::telemetry::ClassBytes;

/// Itemized DRAM traffic for one policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DramBreakdown {
    /// Feature-map bytes (eq. 8).
    pub fm_bytes: u64,
    /// Weight bytes — exactly once by construction (eq. 10 constraint).
    pub weight_bytes: u64,
    /// Extra traffic from capacity evictions (FPN long-lifetime data).
    pub spill_bytes: u64,
    /// eq. (9): everything.
    pub total: u64,
    /// The paper's `[*]` baseline: weights/inputs/outputs all accessed
    /// from DRAM exactly once.
    pub baseline_once: u64,
    /// Per-tensor-class attribution of `total`. Invariants:
    /// `classes.total() == total` and `classes.fm_total() == fm_bytes`
    /// (spill stores land in `ofm`, spill re-reads in `ifm`).
    pub classes: ClassBytes,
}

impl DramBreakdown {
    /// "Off-chip reduction" row of Tables V/VII.
    pub fn reduction_pct(&self) -> f64 {
        100.0 * (1.0 - self.total as f64 / self.baseline_once as f64)
    }
}

/// Evaluate DRAM traffic for `policy` under `alloc` placements.
pub fn dram_access(
    gg: &GroupedGraph,
    policy: &[ReuseMode],
    alloc: &AllocResult,
    cfg: &AccelConfig,
) -> DramBreakdown {
    assert_eq!(policy.len(), gg.groups.len());
    let qa = cfg.qa;
    let mut fm: u64 = 0;
    let mut classes = ClassBytes::default();

    for (gi, gr) in gg.groups.iter().enumerate() {
        if gr.kind == GroupKind::Input {
            continue;
        }
        let a = &alloc.assigns[gi];

        // Concat groups are pure redirection: their operands were already
        // written to the destination region by the producers; the reads
        // happen at the concat's consumers.
        if gr.kind != GroupKind::Concat {
            // main operand read
            let in_bytes = gr.in_shape.bytes(qa) as u64;
            if a.in_loc == Loc::Dram || a.staged_input {
                fm += in_bytes;
                classes.ifm += in_bytes;
            }
            // second operand (fused shortcut / scale gate / eltwise)
            if let Some(Loc::Dram) = a.aux_loc {
                let src = gr
                    .shortcut_of
                    .or_else(|| gr.inputs.get(1).copied())
                    .expect("aux operand exists");
                let aux_bytes = gg.groups[src.0].out_shape.bytes(qa) as u64;
                fm += aux_bytes;
                // a residual shortcut read is the paper's headline class;
                // a plain eltwise/gate second operand is ordinary input
                if gr.shortcut_of.is_some() {
                    classes.shortcut += aux_bytes;
                } else {
                    classes.ifm += aux_bytes;
                }
            }
        }

        // output write
        let out_bytes = gr.out_shape.bytes(qa) as u64;
        if gr.kind != GroupKind::Concat && a.out_loc == Loc::Dram {
            fm += out_bytes;
            classes.ofm += out_bytes;
        }
        if a.also_dram {
            fm += out_bytes;
            classes.ofm += out_bytes;
        }
    }

    let weight_bytes = gg.graph.total_weight_bytes(cfg.qw as u64);
    let spill = alloc.spill_bytes;
    let total = fm + weight_bytes + spill;
    // spill traffic: one writeback (ofm) per eviction, the rest re-reads
    classes.weights = weight_bytes;
    classes.ofm += alloc.spill_write_bytes;
    classes.ifm += spill - alloc.spill_write_bytes;

    DramBreakdown {
        fm_bytes: fm + spill,
        weight_bytes,
        spill_bytes: spill,
        total,
        baseline_once: baseline_once(gg, cfg),
        classes,
    }
}

/// The `[*]` baseline of Tables V/VII: every weight, every layer input
/// and every layer output crosses DRAM exactly once.
pub fn baseline_once(gg: &GroupedGraph, cfg: &AccelConfig) -> u64 {
    let qa = cfg.qa;
    let mut bytes = gg.graph.total_weight_bytes(cfg.qw as u64);
    for gr in &gg.groups {
        if gr.kind == GroupKind::Input || gr.kind == GroupKind::Concat {
            continue;
        }
        bytes += gr.in_shape.bytes(qa) as u64; // read
        if let Some(src) = gr.shortcut_of.or_else(|| {
            if matches!(gr.kind, GroupKind::Scale | GroupKind::Eltwise) {
                gr.inputs.get(1).copied()
            } else {
                None
            }
        }) {
            bytes += gg.groups[src.0].out_shape.bytes(qa) as u64;
        }
        bytes += gr.out_shape.bytes(qa) as u64; // write
    }
    bytes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::allocate;
    use crate::analyzer::analyze;
    use crate::zoo;

    fn eval(name: &str, input: usize, mode: ReuseMode) -> DramBreakdown {
        let gg = analyze(&zoo::by_name(name, input).unwrap());
        let cfg = AccelConfig::kcu1500_int8();
        let policy = vec![mode; gg.groups.len()];
        let alloc = allocate(&gg, &policy, &cfg);
        dram_access(&gg, &policy, &alloc, &cfg)
    }

    #[test]
    fn resnet50_all_frame_fm_is_input_only() {
        // Table V: ResNet50@256 off-chip FMs = 0.19 MB = the 256×256×3
        // input image; everything else stays on-chip.
        let d = eval("resnet50", 256, ReuseMode::Frame);
        let input = 256 * 256 * 3;
        // final FC output is tiny; allow it on top of the input.
        assert!(
            d.fm_bytes >= input && d.fm_bytes < input + 16 * 1024,
            "fm {} vs input {}",
            d.fm_bytes,
            input
        );
    }

    #[test]
    fn resnet50_weights_read_once() {
        let d = eval("resnet50", 256, ReuseMode::Frame);
        let gg = analyze(&zoo::resnet50(256));
        assert_eq!(d.weight_bytes, gg.graph.total_weight_bytes(1));
    }

    #[test]
    fn all_row_matches_eq8_form() {
        // Pure row policy on a plain net: every conv streams in+out; the
        // only sharing is at fused pools. Check against a hand model.
        let gg = analyze(&zoo::vgg16_conv(224));
        let cfg = AccelConfig::kcu1500_int8();
        let policy = vec![ReuseMode::Row; gg.groups.len()];
        let alloc = allocate(&gg, &policy, &cfg);
        let d = dram_access(&gg, &policy, &alloc, &cfg);
        let mut expect = 0u64;
        for gr in gg.groups.iter().skip(1) {
            expect += gr.in_shape.bytes(1) as u64 + gr.out_shape.bytes(1) as u64;
        }
        assert_eq!(d.fm_bytes, expect);
    }

    #[test]
    fn frame_beats_row_on_traffic() {
        for name in ["resnet50", "yolov2", "efficientnet-b1"] {
            let row = eval(name, zoo::default_input(name), ReuseMode::Row);
            let frame = eval(name, zoo::default_input(name), ReuseMode::Frame);
            assert!(
                frame.total < row.total,
                "{name}: frame {} !< row {}",
                frame.total,
                row.total
            );
        }
    }

    #[test]
    fn reduction_matches_table5_scale() {
        // Table V: EfficientNet-B1@256 total baseline 60.7 MB, reduction
        // 84.81 % with the optimized policy; the all-frame bound must be
        // at least that good.
        let d = eval("efficientnet-b1", 256, ReuseMode::Frame);
        let baseline_mb = d.baseline_once as f64 / 1e6;
        assert!(
            (40.0..80.0).contains(&baseline_mb),
            "baseline {baseline_mb} MB vs paper 60.7"
        );
        assert!(d.reduction_pct() > 80.0, "reduction {}", d.reduction_pct());
    }

    #[test]
    fn yolov3_concat_keeps_offchip_traffic() {
        // FPN routes keep long-path tensors off-chip even in frame mode.
        let d = eval("yolov3", 416, ReuseMode::Frame);
        let input = 416 * 416 * 3;
        assert!(d.fm_bytes > input as u64 * 2, "routes must add traffic");
    }

    #[test]
    fn classes_partition_totals_for_every_model() {
        // The attribution must conserve eq. (8)/(9) exactly: no byte
        // unclassified, no byte double-counted.
        for &name in zoo::MODEL_NAMES {
            for mode in [ReuseMode::Row, ReuseMode::Frame] {
                let d = eval(name, zoo::default_input(name), mode);
                assert_eq!(d.classes.total(), d.total, "{name} {mode:?}: total");
                assert_eq!(d.classes.fm_total(), d.fm_bytes, "{name} {mode:?}: fm");
                assert_eq!(d.classes.weights, d.weight_bytes, "{name} {mode:?}: weights");
            }
        }
    }

    #[test]
    fn row_policy_shortcut_share_is_large_on_resnets() {
        // All-row streaming reads every residual shortcut from DRAM —
        // the ~40 % feature-map share the paper's §I cites.
        for name in ["resnet18", "resnet34", "resnet50"] {
            let d = eval(name, zoo::default_input(name), ReuseMode::Row);
            assert!(
                d.classes.shortcut_share() > 0.10,
                "{name}: shortcut share {:.3} unexpectedly small",
                d.classes.shortcut_share()
            );
        }
    }

    #[test]
    fn baseline_exceeds_any_policy() {
        for &name in zoo::MODEL_NAMES {
            for mode in [ReuseMode::Row, ReuseMode::Frame] {
                let d = eval(name, zoo::default_input(name), mode);
                assert!(
                    d.total <= d.baseline_once + d.spill_bytes,
                    "{name} {mode:?}: {} > baseline {}",
                    d.total,
                    d.baseline_once
                );
            }
        }
    }
}
