//! ONNX exporter: `graph::Graph` (+ optional quantized `Params`) → ONNX.
//!
//! The inverse of [`super::lower`], used for hermetic round-trip
//! fixtures (every zoo model exports to ONNX and re-imports bit-
//! identically, so the importer is tested against real topologies
//! without committing binary blobs) and for handing compiled models to
//! external ONNX tooling.
//!
//! Faithfulness contract:
//! * one ONNX node per graph node, same names, same order (a `Swish`
//!   node becomes the canonical `Sigmoid("{n}.sig") → Mul("{n}")` pair
//!   that the importer re-fuses);
//! * quantized parameters ride along *exactly*: weights as `INT8`
//!   initializers in ONNX layout (OIHW / `[C,1,k,k]` depthwise / IO
//!   Gemm), biases as `INT32`, and the accelerator-specific scalars as
//!   custom node attributes `sf_shift` / `sf_elt_shift` / `sf_lut` on
//!   each group's main node. `INT8` weight tensors signal the importer
//!   to take the exact (pre-quantized) path, making the round trip
//!   bit-identical under the functional simulator.

use super::error::ImportError;
use super::proto::{
    data_type, AttrValue, Attribute, GraphProto, ModelProto, NodeProto, TensorProto,
    ValueInfo,
};
use crate::analyzer::analyze;
use crate::funcsim::{GroupParams, Params};
use crate::graph::{validate, Activation, Graph, Node, OpKind, PadMode, Shape};
use std::collections::{HashMap, HashSet};

/// Alpha of the hardware leaky-ReLU (negative slope 1/8, a shift).
pub const LEAKY_ALPHA: f32 = 0.125;

/// Alpha/beta of ONNX `HardSigmoid` matching `relu6(x+3)/6`.
pub const HARD_SIGMOID_ALPHA: f32 = 1.0 / 6.0;

fn a_int(name: &str, v: i64) -> Attribute {
    Attribute { name: name.into(), value: AttrValue::Int(v) }
}

fn a_ints(name: &str, vs: Vec<i64>) -> Attribute {
    Attribute { name: name.into(), value: AttrValue::Ints(vs) }
}

fn a_float(name: &str, v: f32) -> Attribute {
    Attribute { name: name.into(), value: AttrValue::Float(v) }
}

fn a_str(name: &str, v: &str) -> Attribute {
    Attribute { name: name.into(), value: AttrValue::Str(v.into()) }
}

fn a_tensor(name: &str, t: TensorProto) -> Attribute {
    Attribute { name: name.into(), value: AttrValue::Tensor(t) }
}

/// `[1, C, H, W]` value-info dims for a feature-map shape.
fn nchw(s: Shape) -> Vec<i64> {
    vec![1, s.c as i64, s.h as i64, s.w as i64]
}

/// Permute repo conv weights (HWIO `[kh][kw][cin][cout]`) into ONNX
/// OIHW `[cout][cin][kh][kw]`. Pure index shuffle — bit-exact.
fn hwio_to_oihw(w: &[i8], k: usize, cin: usize, cout: usize) -> Vec<i8> {
    let mut out = vec![0i8; w.len()];
    for y in 0..k {
        for x in 0..k {
            for i in 0..cin {
                let src_base = ((y * k + x) * cin + i) * cout;
                for o in 0..cout {
                    out[((o * cin + i) * k + y) * k + x] = w[src_base + o];
                }
            }
        }
    }
    out
}

/// Permute repo depthwise weights (`[ky][kx][c]`) into ONNX
/// `[C][1][kh][kw]`.
fn hwc_to_c1hw(w: &[i8], k: usize, c: usize) -> Vec<i8> {
    let mut out = vec![0i8; w.len()];
    for y in 0..k {
        for x in 0..k {
            for ch in 0..c {
                out[(ch * k + y) * k + x] = w[(y * k + x) * c + ch];
            }
        }
    }
    out
}

struct Exporter<'a> {
    g: &'a Graph,
    /// Params of each group keyed by the group's *main* node id.
    main_params: HashMap<usize, &'a GroupParams>,
    /// Group params visible from any node in the group (weight lookup).
    node_params: HashMap<usize, &'a GroupParams>,
    nodes: Vec<NodeProto>,
    initializers: Vec<TensorProto>,
    value_infos: Vec<ValueInfo>,
    names: HashSet<String>,
}

impl<'a> Exporter<'a> {
    fn claim(&mut self, name: &str) -> Result<(), ImportError> {
        if !self.names.insert(name.to_string()) {
            return Err(ImportError::model(format!(
                "exported tensor name {name:?} collides (node names and derived \
                 initializer names must be unique)"
            )));
        }
        Ok(())
    }

    fn init(&mut self, t: TensorProto) -> Result<(), ImportError> {
        self.claim(&t.name)?;
        self.initializers.push(t);
        Ok(())
    }

    fn in_name(&self, n: &Node, operand: usize) -> String {
        self.g.node(n.inputs[operand]).name.clone()
    }

    fn emit(&mut self, op_type: &str, n: &Node, inputs: Vec<String>, attrs: Vec<Attribute>) {
        self.nodes.push(NodeProto {
            name: n.name.clone(),
            op_type: op_type.into(),
            input: inputs,
            output: vec![n.name.clone()],
            attribute: attrs,
        });
        self.value_infos.push(ValueInfo::concrete(
            &n.name,
            data_type::INT8,
            &nchw(n.out_shape),
        ));
    }

    /// The `sf_*` carrier attributes of a group-main node.
    fn sf_attrs(&self, n: &Node) -> Vec<Attribute> {
        let Some(gp) = self.main_params.get(&n.id.0) else {
            return Vec::new();
        };
        let mut out = vec![a_int("sf_shift", gp.shift as i64)];
        if gp.elt_shift != 0 {
            out.push(a_int("sf_elt_shift", gp.elt_shift as i64));
        }
        if let Some(lut) = &gp.lut {
            out.push(a_tensor(
                "sf_lut",
                TensorProto::i8s(format!("{}.lut", n.name), vec![256], lut.clone()),
            ));
        }
        out
    }

    fn export_conv(&mut self, n: &Node) -> Result<(), ImportError> {
        let OpKind::Conv { k, stride, out_c, pad, depthwise } = n.op else {
            unreachable!()
        };
        let cin = n.in_c();
        let wcount = n.weight_count() as usize;
        let (weights, bias) = match self.node_params.get(&n.id.0) {
            Some(gp) if gp.weights.len() == wcount => {
                let w = if depthwise {
                    hwc_to_c1hw(&gp.weights, k, cin)
                } else {
                    hwio_to_oihw(&gp.weights, k, cin, out_c)
                };
                let mut b = gp.bias.clone();
                b.resize(out_c, 0);
                (w, b)
            }
            Some(gp) => {
                return Err(ImportError::model(format!(
                    "group {:?} carries {} weights, node geometry needs {wcount}",
                    n.name,
                    gp.weights.len()
                )))
            }
            None => (vec![0i8; wcount], vec![0i32; out_c]),
        };
        let wdims = if depthwise {
            vec![cin as i64, 1, k as i64, k as i64]
        } else {
            vec![out_c as i64, cin as i64, k as i64, k as i64]
        };
        let wname = format!("{}.w", n.name);
        let bname = format!("{}.b", n.name);
        self.init(TensorProto::i8s(&wname, wdims, weights))?;
        self.init(TensorProto::i32s(&bname, vec![out_c as i64], bias))?;
        let mut attrs = vec![
            a_ints("kernel_shape", vec![k as i64, k as i64]),
            a_ints("strides", vec![stride as i64, stride as i64]),
            a_ints("dilations", vec![1, 1]),
            a_int("group", if depthwise { cin as i64 } else { 1 }),
            a_str(
                "auto_pad",
                match pad {
                    PadMode::Same => "SAME_UPPER",
                    PadMode::Valid => "VALID",
                },
            ),
        ];
        attrs.extend(self.sf_attrs(n));
        self.emit("Conv", n, vec![self.in_name(n, 0), wname, bname], attrs);
        Ok(())
    }

    fn export_fc(&mut self, n: &Node) -> Result<(), ImportError> {
        let OpKind::Fc { out_c } = n.op else { unreachable!() };
        let cin = n.in_c();
        let wcount = cin * out_c;
        let (weights, bias) = match self.node_params.get(&n.id.0) {
            Some(gp) if gp.weights.len() == wcount => {
                let mut b = gp.bias.clone();
                b.resize(out_c, 0);
                (gp.weights.clone(), b)
            }
            Some(gp) => {
                return Err(ImportError::model(format!(
                    "group {:?} carries {} weights, fc geometry needs {wcount}",
                    n.name,
                    gp.weights.len()
                )))
            }
            None => (vec![0i8; wcount], vec![0i32; out_c]),
        };
        let wname = format!("{}.w", n.name);
        let bname = format!("{}.b", n.name);
        // transB=0: B is [cin][cout] — exactly the repo's IO layout
        self.init(TensorProto::i8s(&wname, vec![cin as i64, out_c as i64], weights))?;
        self.init(TensorProto::i32s(&bname, vec![out_c as i64], bias))?;
        let attrs = self.sf_attrs(n);
        self.emit("Gemm", n, vec![self.in_name(n, 0), wname, bname], attrs);
        Ok(())
    }

    fn export_act(&mut self, n: &Node, act: Activation) -> Result<(), ImportError> {
        let x = self.in_name(n, 0);
        match act {
            Activation::Linear => {
                // marker so Act(Linear) survives the Identity round trip
                let mut attrs = vec![a_int("sf_linear_act", 1)];
                attrs.extend(self.sf_attrs(n));
                self.emit("Identity", n, vec![x], attrs);
            }
            Activation::Relu => {
                let attrs = self.sf_attrs(n);
                self.emit("Relu", n, vec![x], attrs);
            }
            Activation::Leaky => {
                let mut attrs = vec![a_float("alpha", LEAKY_ALPHA)];
                attrs.extend(self.sf_attrs(n));
                self.emit("LeakyRelu", n, vec![x], attrs);
            }
            Activation::Relu6 => {
                let min_name = format!("{}.min", n.name);
                let max_name = format!("{}.max", n.name);
                self.init(TensorProto::f32s(&min_name, vec![], vec![0.0]))?;
                self.init(TensorProto::f32s(&max_name, vec![], vec![6.0]))?;
                let attrs = self.sf_attrs(n);
                self.emit("Clip", n, vec![x, min_name, max_name], attrs);
            }
            Activation::Sigmoid => {
                let attrs = self.sf_attrs(n);
                self.emit("Sigmoid", n, vec![x], attrs);
            }
            Activation::Swish => {
                // canonical SiLU decomposition the importer re-fuses
                let sig = format!("{}.sig", n.name);
                self.claim(&sig)?;
                self.nodes.push(NodeProto {
                    name: sig.clone(),
                    op_type: "Sigmoid".into(),
                    input: vec![x.clone()],
                    output: vec![sig.clone()],
                    attribute: vec![],
                });
                self.value_infos.push(ValueInfo::concrete(
                    &sig,
                    data_type::INT8,
                    &nchw(n.out_shape),
                ));
                let attrs = self.sf_attrs(n);
                self.emit("Mul", n, vec![x, sig], attrs);
            }
            Activation::HardSwish => {
                let attrs = self.sf_attrs(n);
                self.emit("HardSwish", n, vec![x], attrs);
            }
            Activation::HardSigmoid => {
                let mut attrs =
                    vec![a_float("alpha", HARD_SIGMOID_ALPHA), a_float("beta", 0.5)];
                attrs.extend(self.sf_attrs(n));
                self.emit("HardSigmoid", n, vec![x], attrs);
            }
        }
        Ok(())
    }

    fn export_node(&mut self, n: &Node) -> Result<(), ImportError> {
        self.claim(&n.name)?;
        match n.op {
            OpKind::Input => unreachable!("input handled by caller"),
            OpKind::Conv { .. } => self.export_conv(n)?,
            OpKind::Fc { .. } => self.export_fc(n)?,
            OpKind::Act(a) => self.export_act(n, a)?,
            OpKind::BatchNorm => {
                // identity statistics: the real scale/shift already live
                // in the quantized conv weights (exact-path contract)
                let c = n.out_shape.c as i64;
                let names: Vec<String> = ["scale", "bn_b", "mean", "var"]
                    .iter()
                    .map(|s| format!("{}.{s}", n.name))
                    .collect();
                let vals = [1.0f32, 0.0, 0.0, 1.0];
                for (name, v) in names.iter().zip(vals) {
                    self.init(TensorProto::f32s(name, vec![c], vec![v; c as usize]))?;
                }
                let mut attrs = vec![a_float("epsilon", 0.0)];
                attrs.extend(self.sf_attrs(n));
                let mut inputs = vec![self.in_name(n, 0)];
                inputs.extend(names);
                self.emit("BatchNormalization", n, inputs, attrs);
            }
            OpKind::BiasAdd => {
                // per-channel zeros: real bias is folded into the group's
                // INT32 bias initializer; the importer re-folds additively
                let c = n.out_shape.c;
                let bname = format!("{}.b", n.name);
                self.init(TensorProto::i32s(
                    &bname,
                    vec![c as i64, 1, 1],
                    vec![0i32; c],
                ))?;
                let attrs = self.sf_attrs(n);
                self.emit("Add", n, vec![self.in_name(n, 0), bname], attrs);
            }
            OpKind::MaxPool { k, stride } | OpKind::AvgPool { k, stride } => {
                let op = if matches!(n.op, OpKind::MaxPool { .. }) {
                    "MaxPool"
                } else {
                    "AveragePool"
                };
                let mut attrs = vec![
                    a_ints("kernel_shape", vec![k as i64, k as i64]),
                    a_ints("strides", vec![stride as i64, stride as i64]),
                    a_str("auto_pad", "SAME_UPPER"),
                ];
                if op == "AveragePool" {
                    // the datapath divides by k² with zero-padded taps
                    attrs.push(a_int("count_include_pad", 1));
                }
                attrs.extend(self.sf_attrs(n));
                self.emit(op, n, vec![self.in_name(n, 0)], attrs);
            }
            OpKind::GlobalAvgPool => {
                let attrs = self.sf_attrs(n);
                self.emit("GlobalAveragePool", n, vec![self.in_name(n, 0)], attrs);
            }
            OpKind::EltwiseAdd => {
                let attrs = self.sf_attrs(n);
                self.emit("Add", n, vec![self.in_name(n, 0), self.in_name(n, 1)], attrs);
            }
            OpKind::ScaleMul => {
                let attrs = self.sf_attrs(n);
                self.emit("Mul", n, vec![self.in_name(n, 0), self.in_name(n, 1)], attrs);
            }
            OpKind::Concat => {
                let mut attrs = vec![a_int("axis", 1)];
                attrs.extend(self.sf_attrs(n));
                self.emit(
                    "Concat",
                    n,
                    vec![self.in_name(n, 0), self.in_name(n, 1)],
                    attrs,
                );
            }
            OpKind::Upsample { factor } => {
                let sname = format!("{}.scales", n.name);
                self.init(TensorProto::f32s(
                    &sname,
                    vec![4],
                    vec![1.0, 1.0, factor as f32, factor as f32],
                ))?;
                let mut attrs = vec![
                    a_str("mode", "nearest"),
                    a_str("nearest_mode", "floor"),
                    a_str("coordinate_transformation_mode", "asymmetric"),
                ];
                attrs.extend(self.sf_attrs(n));
                // input 1 (roi) is the omitted optional input
                self.emit(
                    "Resize",
                    n,
                    vec![self.in_name(n, 0), String::new(), sname],
                    attrs,
                );
            }
            OpKind::Identity => {
                let attrs = self.sf_attrs(n);
                self.emit("Identity", n, vec![self.in_name(n, 0)], attrs);
            }
        }
        Ok(())
    }
}

/// Export a validated graph (and optionally its quantized parameters)
/// into an ONNX [`ModelProto`].
pub fn export_graph(g: &Graph, params: Option<&Params>) -> Result<ModelProto, ImportError> {
    validate(g).map_err(|e| ImportError::model(e.to_string()))?;
    let gg = analyze(g);
    let mut main_params: HashMap<usize, &GroupParams> = HashMap::new();
    let mut node_params: HashMap<usize, &GroupParams> = HashMap::new();
    if let Some(p) = params {
        for gr in &gg.groups {
            if let Some(gp) = p.get(&g.node(gr.main).name) {
                main_params.insert(gr.main.0, gp);
                for &nid in &gr.nodes {
                    node_params.insert(nid.0, gp);
                }
            }
        }
    }
    let mut ex = Exporter {
        g,
        main_params,
        node_params,
        nodes: Vec::new(),
        initializers: Vec::new(),
        value_infos: Vec::new(),
        names: HashSet::new(),
    };
    let input = g.input();
    ex.claim(&input.name)?;
    for n in &g.nodes {
        if matches!(n.op, OpKind::Input) {
            continue;
        }
        ex.export_node(n)?;
    }
    let outputs: Vec<ValueInfo> = g
        .outputs()
        .into_iter()
        .map(|id| {
            let n = g.node(id);
            ValueInfo::concrete(&n.name, data_type::INT8, &nchw(n.out_shape))
        })
        .collect();
    // graph outputs are not also listed as value_info
    let out_names: HashSet<&str> = outputs.iter().map(|v| v.name.as_str()).collect();
    let value_info = ex
        .value_infos
        .into_iter()
        .filter(|v| !out_names.contains(v.name.as_str()))
        .collect();
    Ok(ModelProto {
        ir_version: 8,
        producer_name: "shortcutfusion".into(),
        producer_version: env!("CARGO_PKG_VERSION").into(),
        // HardSwish needs opset >= 14
        opset_version: 14,
        graph: Some(GraphProto {
            name: g.name.clone(),
            node: ex.nodes,
            initializer: ex.initializers,
            input: vec![ValueInfo::concrete(
                &input.name,
                data_type::INT8,
                &nchw(input.out_shape),
            )],
            output: outputs,
            value_info,
        }),
    })
}

/// Export straight to `.onnx` bytes.
pub fn export_bytes(g: &Graph, params: Option<&Params>) -> Result<Vec<u8>, ImportError> {
    Ok(super::proto::encode_model(&export_graph(g, params)?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::funcsim::Params;

    #[test]
    fn weight_permutations_invert() {
        let (k, cin, cout) = (3, 2, 4);
        let w: Vec<i8> = (0..(k * k * cin * cout) as i32).map(|v| (v % 100) as i8).collect();
        let oihw = hwio_to_oihw(&w, k, cin, cout);
        // invert by hand: hwio[((y*k+x)*cin+i)*cout+o] == oihw[((o*cin+i)*k+y)*k+x]
        for y in 0..k {
            for x in 0..k {
                for i in 0..cin {
                    for o in 0..cout {
                        assert_eq!(
                            w[((y * k + x) * cin + i) * cout + o],
                            oihw[((o * cin + i) * k + y) * k + x]
                        );
                    }
                }
            }
        }
        let dw: Vec<i8> = (0..(k * k * cin) as i32).map(|v| v as i8).collect();
        let c1hw = hwc_to_c1hw(&dw, k, cin);
        for y in 0..k {
            for x in 0..k {
                for c in 0..cin {
                    assert_eq!(dw[(y * k + x) * cin + c], c1hw[(c * k + y) * k + x]);
                }
            }
        }
    }

    #[test]
    fn tinynet_exports_with_and_without_params() {
        let g = crate::zoo::tinynet();
        let m = export_graph(&g, None).unwrap();
        let graph = m.graph.as_ref().unwrap();
        // one ONNX node per non-input graph node, plus one .sig per Swish
        let swishes = g
            .nodes
            .iter()
            .filter(|n| matches!(n.op, OpKind::Act(Activation::Swish)))
            .count();
        assert_eq!(graph.node.len(), g.nodes.len() - 1 + swishes);
        assert_eq!(graph.input.len(), 1);
        assert!(!graph.output.is_empty());

        let gg = analyze(&g);
        let p = Params::random(&gg, 7);
        let m2 = export_graph(&g, Some(&p)).unwrap();
        // params surface as sf_shift attrs on main nodes
        let with_shift = m2
            .graph
            .unwrap()
            .node
            .iter()
            .filter(|n| n.attr("sf_shift").is_some())
            .count();
        assert_eq!(with_shift, p.groups.len());
    }

    #[test]
    fn exported_bytes_decode() {
        let g = crate::zoo::tinynet();
        let bytes = export_bytes(&g, None).unwrap();
        let m = super::super::proto::decode_model(&bytes).unwrap();
        assert_eq!(m.opset_version, 14);
        assert_eq!(m.producer_name, "shortcutfusion");
    }
}
