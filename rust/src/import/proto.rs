//! The ONNX protobuf message subset.
//!
//! Plain structs mirroring the handful of `onnx.proto3` messages the
//! front end needs (`ModelProto`, `GraphProto`, `NodeProto`,
//! `AttributeProto`, `TensorProto`, `ValueInfoProto`), decoded from and
//! encoded to the wire format by hand. Field numbers follow the ONNX
//! schema; unknown fields are skipped on read so models produced by
//! richer exporters still parse.

use super::error::ImportError;
use super::wire::{Reader, WireType, Writer};

/// ONNX `TensorProto.DataType` codes this front end understands.
pub mod data_type {
    /// IEEE-754 float32.
    pub const FLOAT: i64 = 1;
    /// Signed 8-bit integer (the accelerator's activation/weight type).
    pub const INT8: i64 = 3;
    /// Signed 32-bit integer (bias accumulator type).
    pub const INT32: i64 = 6;
    /// Signed 64-bit integer (shape/index data).
    pub const INT64: i64 = 7;
}

/// Decoded tensor payload, canonicalized from whichever of `raw_data` /
/// `float_data` / `int32_data` / `int64_data` the producer used.
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    /// No payload (shape-only tensors, zero-element initializers).
    Empty,
    /// float32 values.
    F32(Vec<f32>),
    /// int8 values.
    I8(Vec<i8>),
    /// int32 values.
    I32(Vec<i32>),
    /// int64 values.
    I64(Vec<i64>),
}

impl TensorData {
    /// Number of scalar elements.
    pub fn len(&self) -> usize {
        match self {
            TensorData::Empty => 0,
            TensorData::F32(v) => v.len(),
            TensorData::I8(v) => v.len(),
            TensorData::I32(v) => v.len(),
            TensorData::I64(v) => v.len(),
        }
    }

    /// True when no elements are present.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// ONNX `TensorProto`: a named, typed, shaped constant.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorProto {
    /// Tensor name (initializers are referenced by it).
    pub name: String,
    /// Dimensions, outermost first.
    pub dims: Vec<i64>,
    /// One of the [`data_type`] codes.
    pub data_type: i64,
    /// The canonicalized payload.
    pub data: TensorData,
}

impl TensorProto {
    /// An int8 tensor (exporter weights).
    pub fn i8s(name: impl Into<String>, dims: Vec<i64>, data: Vec<i8>) -> Self {
        TensorProto {
            name: name.into(),
            dims,
            data_type: data_type::INT8,
            data: TensorData::I8(data),
        }
    }

    /// An int32 tensor (exporter biases).
    pub fn i32s(name: impl Into<String>, dims: Vec<i64>, data: Vec<i32>) -> Self {
        TensorProto {
            name: name.into(),
            dims,
            data_type: data_type::INT32,
            data: TensorData::I32(data),
        }
    }

    /// A float32 tensor (BN stats, Resize scales, Clip bounds).
    pub fn f32s(name: impl Into<String>, dims: Vec<i64>, data: Vec<f32>) -> Self {
        TensorProto {
            name: name.into(),
            dims,
            data_type: data_type::FLOAT,
            data: TensorData::F32(data),
        }
    }

    /// Element count implied by `dims` (empty dims = scalar = 1).
    pub fn numel(&self) -> Result<usize, ImportError> {
        let mut n: usize = 1;
        for &d in &self.dims {
            if d < 0 {
                return Err(ImportError::shape(
                    &self.name,
                    format!("negative dimension {d}"),
                ));
            }
            n = n.saturating_mul(d as usize);
        }
        Ok(n)
    }

    fn decode(r: &mut Reader<'_>) -> Result<TensorProto, ImportError> {
        let mut name = String::new();
        let mut dims = Vec::new();
        let mut dt: i64 = 0;
        let mut raw: Option<Vec<u8>> = None;
        let mut f32s: Vec<f32> = Vec::new();
        let mut i32s: Vec<i64> = Vec::new();
        let mut i64s: Vec<i64> = Vec::new();
        while !r.at_end() {
            let (field, wt) = r.tag()?;
            match field {
                1 => r.int64s(wt, &mut dims)?,
                2 => dt = r.varint()? as i64,
                4 => r.floats(wt, &mut f32s)?,
                5 => r.int64s(wt, &mut i32s)?,
                7 => r.int64s(wt, &mut i64s)?,
                8 => name = r.string()?,
                9 => raw = Some(r.bytes()?.to_vec()),
                _ => r.skip(wt)?,
            }
        }
        let data = if let Some(raw) = raw {
            decode_raw(&name, dt, &raw)?
        } else if !f32s.is_empty() {
            TensorData::F32(f32s)
        } else if !i32s.is_empty() {
            // int32_data also carries int8/uint8 payloads per the spec
            TensorData::I32(i32s.into_iter().map(|v| v as i32).collect())
        } else if !i64s.is_empty() {
            TensorData::I64(i64s)
        } else {
            TensorData::Empty
        };
        // int32_data-carried int8 canonicalizes to I8 so consumers see
        // one representation per data_type
        let data = match data {
            TensorData::I32(v) if dt == data_type::INT8 => {
                let mut i8s = Vec::with_capacity(v.len());
                for x in v {
                    let b = i8::try_from(x).map_err(|_| {
                        ImportError::schema(format!(
                            "tensor {name:?}: int8 value {x} out of range"
                        ))
                    })?;
                    i8s.push(b);
                }
                TensorData::I8(i8s)
            }
            other => other,
        };
        let t = TensorProto { name, dims, data_type: dt, data };
        if !t.data.is_empty() && t.data.len() != t.numel()? {
            return Err(ImportError::shape(
                &t.name,
                format!(
                    "initializer has {} elements but dims {:?} imply {}",
                    t.data.len(),
                    t.dims,
                    t.numel()?
                ),
            ));
        }
        Ok(t)
    }

    fn encode(&self) -> Writer {
        let mut w = Writer::new();
        if !self.dims.is_empty() {
            w.packed_int64s(1, &self.dims);
        }
        w.int(2, self.data_type);
        w.string(8, &self.name);
        // always emit raw_data: fixed-width little-endian, the densest
        // and least ambiguous of the encodings
        let mut raw = Vec::new();
        match &self.data {
            TensorData::Empty => {}
            TensorData::F32(v) => {
                for x in v {
                    raw.extend_from_slice(&x.to_bits().to_le_bytes());
                }
            }
            TensorData::I8(v) => raw.extend(v.iter().map(|&x| x as u8)),
            TensorData::I32(v) => {
                for x in v {
                    raw.extend_from_slice(&x.to_le_bytes());
                }
            }
            TensorData::I64(v) => {
                for x in v {
                    raw.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
        if !raw.is_empty() {
            w.bytes(9, &raw);
        }
        w
    }
}

fn decode_raw(name: &str, dt: i64, raw: &[u8]) -> Result<TensorData, ImportError> {
    let bad = |detail: String| ImportError::schema(format!("tensor {name:?}: {detail}"));
    Ok(match dt {
        d if d == data_type::INT8 => {
            TensorData::I8(raw.iter().map(|&b| b as i8).collect())
        }
        d if d == data_type::FLOAT => {
            if raw.len() % 4 != 0 {
                return Err(bad(format!("raw float data length {} not /4", raw.len())));
            }
            TensorData::F32(
                raw.chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            )
        }
        d if d == data_type::INT32 => {
            if raw.len() % 4 != 0 {
                return Err(bad(format!("raw int32 data length {} not /4", raw.len())));
            }
            TensorData::I32(
                raw.chunks_exact(4)
                    .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            )
        }
        d if d == data_type::INT64 => {
            if raw.len() % 8 != 0 {
                return Err(bad(format!("raw int64 data length {} not /8", raw.len())));
            }
            TensorData::I64(
                raw.chunks_exact(8)
                    .map(|c| {
                        i64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]])
                    })
                    .collect(),
            )
        }
        other => return Err(bad(format!("unsupported data_type {other}"))),
    })
}

/// Decoded ONNX attribute value.
#[derive(Debug, Clone, PartialEq)]
pub enum AttrValue {
    /// `i`: a single int64.
    Int(i64),
    /// `f`: a single float.
    Float(f32),
    /// `s`: a byte string.
    Str(String),
    /// `t`: a tensor.
    Tensor(TensorProto),
    /// `ints`: repeated int64.
    Ints(Vec<i64>),
    /// `floats`: repeated float.
    Floats(Vec<f32>),
}

/// ONNX `AttributeProto`.
#[derive(Debug, Clone, PartialEq)]
pub struct Attribute {
    /// Attribute name.
    pub name: String,
    /// The decoded value.
    pub value: AttrValue,
}

impl Attribute {
    fn decode(r: &mut Reader<'_>) -> Result<Attribute, ImportError> {
        let mut name = String::new();
        let mut f: Option<f32> = None;
        let mut i: Option<i64> = None;
        let mut s: Option<String> = None;
        let mut t: Option<TensorProto> = None;
        let mut floats: Vec<f32> = Vec::new();
        let mut ints: Vec<i64> = Vec::new();
        let mut ty: i64 = 0;
        while !r.at_end() {
            let (field, wt) = r.tag()?;
            match field {
                1 => name = r.string()?,
                2 => f = Some(f32::from_bits(r.fixed32()?)),
                3 => i = Some(r.varint()? as i64),
                4 => s = Some(r.string()?),
                5 => t = Some(TensorProto::decode(&mut r.msg()?)?),
                7 => r.floats(wt, &mut floats)?,
                8 => r.int64s(wt, &mut ints)?,
                20 => ty = r.varint()? as i64,
                _ => r.skip(wt)?,
            }
        }
        // prefer the declared type; fall back to whichever field is set
        // (required `type` is occasionally missing in the wild)
        let value = match ty {
            1 => AttrValue::Float(f.unwrap_or(0.0)),
            2 => AttrValue::Int(i.unwrap_or(0)),
            3 => AttrValue::Str(s.unwrap_or_default()),
            4 => AttrValue::Tensor(t.ok_or_else(|| {
                ImportError::schema(format!("attribute {name:?}: TENSOR type without tensor"))
            })?),
            6 => AttrValue::Floats(floats),
            7 => AttrValue::Ints(ints),
            0 => {
                if let Some(v) = i {
                    AttrValue::Int(v)
                } else if let Some(v) = f {
                    AttrValue::Float(v)
                } else if let Some(v) = s {
                    AttrValue::Str(v)
                } else if let Some(v) = t {
                    AttrValue::Tensor(v)
                } else if !ints.is_empty() {
                    AttrValue::Ints(ints)
                } else if !floats.is_empty() {
                    AttrValue::Floats(floats)
                } else {
                    return Err(ImportError::schema(format!(
                        "attribute {name:?} has no value"
                    )));
                }
            }
            other => {
                return Err(ImportError::schema(format!(
                    "attribute {name:?}: unsupported attribute type {other}"
                )))
            }
        };
        Ok(Attribute { name, value })
    }

    fn encode(&self) -> Writer {
        let mut w = Writer::new();
        w.string(1, &self.name);
        match &self.value {
            AttrValue::Float(v) => {
                w.float(2, *v);
                w.int(20, 1);
            }
            AttrValue::Int(v) => {
                w.int(3, *v);
                w.int(20, 2);
            }
            AttrValue::Str(v) => {
                w.string(4, v);
                w.int(20, 3);
            }
            AttrValue::Tensor(t) => {
                w.message(5, t.encode());
                w.int(20, 4);
            }
            AttrValue::Floats(vs) => {
                for v in vs {
                    w.float(7, *v);
                }
                w.int(20, 6);
            }
            AttrValue::Ints(vs) => {
                w.packed_int64s(8, vs);
                w.int(20, 7);
            }
        }
        w
    }
}

/// ONNX `NodeProto`.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct NodeProto {
    /// Node name (may be empty; the first output then names it).
    pub name: String,
    /// Operator type, e.g. `"Conv"`.
    pub op_type: String,
    /// Input tensor names (empty string = omitted optional input).
    pub input: Vec<String>,
    /// Output tensor names.
    pub output: Vec<String>,
    /// Attributes.
    pub attribute: Vec<Attribute>,
}

impl NodeProto {
    /// The attribute with this name, if present.
    pub fn attr(&self, name: &str) -> Option<&AttrValue> {
        self.attribute.iter().find(|a| a.name == name).map(|a| &a.value)
    }

    /// An int attribute, or `default` when absent.
    pub fn attr_int(&self, name: &str, default: i64) -> i64 {
        match self.attr(name) {
            Some(AttrValue::Int(v)) => *v,
            _ => default,
        }
    }

    /// An ints attribute as a slice (empty when absent).
    pub fn attr_ints(&self, name: &str) -> &[i64] {
        match self.attr(name) {
            Some(AttrValue::Ints(v)) => v,
            _ => &[],
        }
    }

    /// A float attribute, or `default` when absent.
    pub fn attr_float(&self, name: &str, default: f32) -> f32 {
        match self.attr(name) {
            Some(AttrValue::Float(v)) => *v,
            _ => default,
        }
    }

    /// A string attribute, or `default` when absent.
    pub fn attr_str<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        match self.attr(name) {
            Some(AttrValue::Str(v)) => v,
            _ => default,
        }
    }

    /// The display name: `name` when set, else the first output.
    pub fn display_name(&self) -> &str {
        if !self.name.is_empty() {
            &self.name
        } else {
            self.output.first().map(String::as_str).unwrap_or("<unnamed>")
        }
    }

    fn decode(r: &mut Reader<'_>) -> Result<NodeProto, ImportError> {
        let mut n = NodeProto::default();
        while !r.at_end() {
            let (field, wt) = r.tag()?;
            match field {
                1 => n.input.push(r.string()?),
                2 => n.output.push(r.string()?),
                3 => n.name = r.string()?,
                4 => n.op_type = r.string()?,
                5 => n.attribute.push(Attribute::decode(&mut r.msg()?)?),
                _ => r.skip(wt)?,
            }
        }
        Ok(n)
    }

    fn encode(&self) -> Writer {
        let mut w = Writer::new();
        for i in &self.input {
            w.string(1, i);
        }
        for o in &self.output {
            w.string(2, o);
        }
        if !self.name.is_empty() {
            w.string(3, &self.name);
        }
        w.string(4, &self.op_type);
        for a in &self.attribute {
            w.message(5, a.encode());
        }
        w
    }
}

/// ONNX `ValueInfoProto`, flattened to what shape checking needs.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ValueInfo {
    /// Tensor name.
    pub name: String,
    /// Element type code (0 when undeclared).
    pub elem_type: i64,
    /// Dimensions; `None` for symbolic (`dim_param`) entries.
    pub dims: Vec<Option<i64>>,
}

impl ValueInfo {
    /// A value-info with all-concrete dims and the given element type.
    pub fn concrete(name: impl Into<String>, elem_type: i64, dims: &[i64]) -> Self {
        ValueInfo {
            name: name.into(),
            elem_type,
            dims: dims.iter().map(|&d| Some(d)).collect(),
        }
    }

    fn decode(r: &mut Reader<'_>) -> Result<ValueInfo, ImportError> {
        let mut v = ValueInfo::default();
        while !r.at_end() {
            let (field, wt) = r.tag()?;
            match field {
                1 => v.name = r.string()?,
                2 => {
                    // TypeProto
                    let mut ty = r.msg()?;
                    while !ty.at_end() {
                        let (f2, wt2) = ty.tag()?;
                        if f2 == 1 {
                            // TypeProto.Tensor
                            let mut tt = ty.msg()?;
                            while !tt.at_end() {
                                let (f3, wt3) = tt.tag()?;
                                match f3 {
                                    1 => v.elem_type = tt.varint()? as i64,
                                    2 => {
                                        // TensorShapeProto
                                        let mut sh = tt.msg()?;
                                        while !sh.at_end() {
                                            let (f4, wt4) = sh.tag()?;
                                            if f4 == 1 {
                                                // Dimension
                                                let mut dim = sh.msg()?;
                                                let mut val: Option<i64> = None;
                                                while !dim.at_end() {
                                                    let (f5, wt5) = dim.tag()?;
                                                    match f5 {
                                                        1 => {
                                                            val =
                                                                Some(dim.varint()? as i64)
                                                        }
                                                        _ => dim.skip(wt5)?,
                                                    }
                                                }
                                                v.dims.push(val);
                                            } else {
                                                sh.skip(wt4)?;
                                            }
                                        }
                                    }
                                    _ => tt.skip(wt3)?,
                                }
                            }
                        } else {
                            ty.skip(wt2)?;
                        }
                    }
                }
                _ => r.skip(wt)?,
            }
        }
        Ok(v)
    }

    fn encode(&self) -> Writer {
        let mut shape = Writer::new();
        for d in &self.dims {
            let mut dim = Writer::new();
            if let Some(v) = d {
                dim.int(1, *v);
            } else {
                dim.string(2, "N");
            }
            shape.message(1, dim);
        }
        let mut tensor_type = Writer::new();
        tensor_type.int(1, self.elem_type);
        tensor_type.message(2, shape);
        let mut ty = Writer::new();
        ty.message(1, tensor_type);
        let mut w = Writer::new();
        w.string(1, &self.name);
        w.message(2, ty);
        w
    }
}

/// ONNX `GraphProto`.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct GraphProto {
    /// Graph name.
    pub name: String,
    /// Nodes in (required) topological order.
    pub node: Vec<NodeProto>,
    /// Constant tensors.
    pub initializer: Vec<TensorProto>,
    /// Declared graph inputs (initializers may be re-listed here).
    pub input: Vec<ValueInfo>,
    /// Declared graph outputs.
    pub output: Vec<ValueInfo>,
    /// Optional intermediate-tensor shape declarations.
    pub value_info: Vec<ValueInfo>,
}

impl GraphProto {
    fn decode(r: &mut Reader<'_>) -> Result<GraphProto, ImportError> {
        let mut g = GraphProto::default();
        while !r.at_end() {
            let (field, wt) = r.tag()?;
            match field {
                1 => g.node.push(NodeProto::decode(&mut r.msg()?)?),
                2 => g.name = r.string()?,
                5 => g.initializer.push(TensorProto::decode(&mut r.msg()?)?),
                11 => g.input.push(ValueInfo::decode(&mut r.msg()?)?),
                12 => g.output.push(ValueInfo::decode(&mut r.msg()?)?),
                13 => g.value_info.push(ValueInfo::decode(&mut r.msg()?)?),
                _ => r.skip(wt)?,
            }
        }
        Ok(g)
    }

    fn encode(&self) -> Writer {
        let mut w = Writer::new();
        for n in &self.node {
            w.message(1, n.encode());
        }
        w.string(2, &self.name);
        for t in &self.initializer {
            w.message(5, t.encode());
        }
        for v in &self.input {
            w.message(11, v.encode());
        }
        for v in &self.output {
            w.message(12, v.encode());
        }
        for v in &self.value_info {
            w.message(13, v.encode());
        }
        w
    }
}

/// ONNX `ModelProto` (the file-level envelope).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ModelProto {
    /// ONNX IR version.
    pub ir_version: i64,
    /// Producer tool name.
    pub producer_name: String,
    /// Producer tool version.
    pub producer_version: String,
    /// Declared default-domain opset version.
    pub opset_version: i64,
    /// The graph.
    pub graph: Option<GraphProto>,
}

/// Decode a whole `.onnx` byte buffer into a [`ModelProto`].
///
/// A model without a graph is rejected — every other unknown field is
/// skipped, so files from richer exporters still decode.
pub fn decode_model(bytes: &[u8]) -> Result<ModelProto, ImportError> {
    let mut m = ModelProto::default();
    let mut r = Reader::new(bytes);
    while !r.at_end() {
        let (field, wt) = r.tag()?;
        match field {
            1 => m.ir_version = r.varint()? as i64,
            2 => m.producer_name = r.string()?,
            3 => m.producer_version = r.string()?,
            7 => m.graph = Some(GraphProto::decode(&mut r.msg()?)?),
            8 => {
                // OperatorSetIdProto { domain = 1, version = 2 }
                let mut op = r.msg()?;
                let mut domain = String::new();
                let mut version = 0i64;
                while !op.at_end() {
                    let (f2, wt2) = op.tag()?;
                    match f2 {
                        1 => domain = op.string()?,
                        2 => version = op.varint()? as i64,
                        _ => op.skip(wt2)?,
                    }
                }
                if domain.is_empty() || domain == "ai.onnx" {
                    m.opset_version = version;
                }
            }
            _ => r.skip(wt)?,
        }
    }
    if m.graph.is_none() {
        return Err(ImportError::schema("model has no graph"));
    }
    Ok(m)
}

/// Encode a [`ModelProto`] to `.onnx` bytes.
pub fn encode_model(m: &ModelProto) -> Vec<u8> {
    let mut w = Writer::new();
    w.int(1, m.ir_version);
    w.string(2, &m.producer_name);
    w.string(3, &m.producer_version);
    if let Some(g) = &m.graph {
        w.message(7, g.encode());
    }
    let mut opset = Writer::new();
    opset.string(1, "");
    opset.int(2, m.opset_version);
    w.message(8, opset);
    w.into_bytes()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_model() -> ModelProto {
        ModelProto {
            ir_version: 8,
            producer_name: "shortcutfusion".into(),
            producer_version: "0.7.0".into(),
            opset_version: 14,
            graph: Some(GraphProto {
                name: "demo".into(),
                node: vec![NodeProto {
                    name: "c1".into(),
                    op_type: "Conv".into(),
                    input: vec!["x".into(), "c1.w".into()],
                    output: vec!["c1".into()],
                    attribute: vec![
                        Attribute {
                            name: "kernel_shape".into(),
                            value: AttrValue::Ints(vec![3, 3]),
                        },
                        Attribute {
                            name: "auto_pad".into(),
                            value: AttrValue::Str("SAME_UPPER".into()),
                        },
                        Attribute { name: "sf_shift".into(), value: AttrValue::Int(7) },
                        Attribute {
                            name: "alpha".into(),
                            value: AttrValue::Float(0.125),
                        },
                    ],
                }],
                initializer: vec![TensorProto::i8s(
                    "c1.w",
                    vec![2, 1, 3, 3],
                    (0..18).map(|v| v as i8 - 9).collect(),
                )],
                input: vec![ValueInfo::concrete("x", data_type::INT8, &[1, 1, 8, 8])],
                output: vec![ValueInfo::concrete("c1", data_type::INT8, &[1, 2, 8, 8])],
                value_info: vec![],
            }),
        }
    }

    #[test]
    fn model_round_trips_through_the_wire() {
        let m = demo_model();
        let bytes = encode_model(&m);
        let m2 = decode_model(&bytes).unwrap();
        assert_eq!(m, m2);
    }

    #[test]
    fn missing_graph_is_schema_error() {
        let mut w = Writer::new();
        w.int(1, 8);
        let e = decode_model(&w.into_bytes()).unwrap_err();
        assert!(matches!(e, ImportError::Schema(_)), "{e}");
    }

    #[test]
    fn initializer_dims_must_match_payload() {
        let mut m = demo_model();
        m.graph.as_mut().unwrap().initializer[0].dims = vec![2, 1, 3, 4]; // 24 != 18
        let bytes = encode_model(&m);
        let e = decode_model(&bytes).unwrap_err();
        assert!(matches!(e, ImportError::ShapeMismatch { .. }), "{e}");
    }

    #[test]
    fn truncations_never_panic() {
        let bytes = encode_model(&demo_model());
        for cut in 0..bytes.len() {
            let _ = decode_model(&bytes[..cut]); // must return, not panic
        }
    }

    #[test]
    fn attr_accessors() {
        let m = demo_model();
        let n = &m.graph.as_ref().unwrap().node[0];
        assert_eq!(n.attr_ints("kernel_shape"), &[3, 3]);
        assert_eq!(n.attr_str("auto_pad", "NOTSET"), "SAME_UPPER");
        assert_eq!(n.attr_int("sf_shift", 0), 7);
        assert_eq!(n.attr_int("group", 1), 1);
        assert!((n.attr_float("alpha", 0.0) - 0.125).abs() < 1e-9);
    }
}
