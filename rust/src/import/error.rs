//! Typed import failures.
//!
//! Everything the ONNX front end can reject is expressed here — the
//! reader and the lowering pass never panic on untrusted bytes (the
//! corruption tests in `rust/tests/import_roundtrip.rs` fuzz truncations
//! and bad tags against this contract).

use std::fmt;
use std::path::PathBuf;

/// Every way an ONNX import (or export) can fail.
#[derive(Debug)]
pub enum ImportError {
    /// Malformed protobuf wire data (truncated varint, over-long length,
    /// unsupported wire type). `offset` is the absolute byte position in
    /// the model file where decoding stopped.
    Wire {
        /// Absolute byte offset of the failure in the input buffer.
        offset: usize,
        /// What went wrong at that offset.
        detail: String,
    },
    /// The wire data decoded, but the message violates the ONNX schema
    /// subset this front end understands (missing graph, tensor without
    /// a name, attribute with no value, …).
    Schema(String),
    /// A node uses an operator (or an attribute combination) outside the
    /// accelerator's op set.
    UnsupportedOp {
        /// The ONNX `op_type` that failed to lower.
        op_type: String,
        /// Name of the offending node (or its first output).
        node: String,
        /// Why this instance could not be lowered.
        detail: String,
    },
    /// Shape inference disagreed with the model: an initializer whose
    /// element count contradicts its `dims`, a declared `value_info`
    /// that contradicts the computed shape, or operand shapes an op
    /// cannot accept.
    ShapeMismatch {
        /// Name of the node or tensor with the inconsistent shape.
        node: String,
        /// The disagreement.
        detail: String,
    },
    /// Whole-model inconsistency: no single graph input, duplicate
    /// tensor names, a dangling edge, or a lowered graph that failed
    /// [`crate::graph::validate`].
    Model(String),
    /// Filesystem failure, with the path being accessed.
    Io {
        /// The path being read or written.
        path: PathBuf,
        /// The underlying OS error.
        source: std::io::Error,
    },
}

impl ImportError {
    /// Shorthand for [`ImportError::Wire`].
    pub fn wire(offset: usize, detail: impl Into<String>) -> Self {
        ImportError::Wire { offset, detail: detail.into() }
    }

    /// Shorthand for [`ImportError::Schema`].
    pub fn schema(detail: impl Into<String>) -> Self {
        ImportError::Schema(detail.into())
    }

    /// Shorthand for [`ImportError::UnsupportedOp`].
    pub fn unsupported(
        op_type: impl Into<String>,
        node: impl Into<String>,
        detail: impl Into<String>,
    ) -> Self {
        ImportError::UnsupportedOp {
            op_type: op_type.into(),
            node: node.into(),
            detail: detail.into(),
        }
    }

    /// Shorthand for [`ImportError::ShapeMismatch`].
    pub fn shape(node: impl Into<String>, detail: impl Into<String>) -> Self {
        ImportError::ShapeMismatch { node: node.into(), detail: detail.into() }
    }

    /// Shorthand for [`ImportError::Model`].
    pub fn model(detail: impl Into<String>) -> Self {
        ImportError::Model(detail.into())
    }

    /// Shorthand for [`ImportError::Io`].
    pub fn io(path: impl Into<PathBuf>, source: std::io::Error) -> Self {
        ImportError::Io { path: path.into(), source }
    }
}

impl fmt::Display for ImportError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ImportError::Wire { offset, detail } => {
                write!(f, "bad wire data at byte {offset}: {detail}")
            }
            ImportError::Schema(m) => write!(f, "onnx schema error: {m}"),
            ImportError::UnsupportedOp { op_type, node, detail } => {
                write!(f, "unsupported op {op_type:?} at node {node:?}: {detail}")
            }
            ImportError::ShapeMismatch { node, detail } => {
                write!(f, "shape mismatch at {node:?}: {detail}")
            }
            ImportError::Model(m) => write!(f, "inconsistent model: {m}"),
            ImportError::Io { path, source } => write!(f, "{}: {source}", path.display()),
        }
    }
}

impl std::error::Error for ImportError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ImportError::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

impl From<ImportError> for crate::compiler::CompileError {
    fn from(e: ImportError) -> Self {
        use crate::compiler::CompileError;
        match e {
            ImportError::Wire { .. } | ImportError::Schema(_) => {
                CompileError::Parse(e.to_string())
            }
            ImportError::UnsupportedOp { .. } => CompileError::Unsupported(e.to_string()),
            ImportError::ShapeMismatch { .. } | ImportError::Model(_) => {
                CompileError::Graph(e.to_string())
            }
            ImportError::Io { path, source } => CompileError::Io { path, source },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::CompileError;

    #[test]
    fn display_carries_context() {
        let e = ImportError::wire(42, "truncated varint");
        assert!(e.to_string().contains("42"));
        assert!(e.to_string().contains("truncated varint"));
        let e = ImportError::unsupported("Softmax", "probs", "not in the accelerator op set");
        assert!(e.to_string().contains("Softmax"));
        assert!(e.to_string().contains("probs"));
    }

    #[test]
    fn maps_into_compile_error_classes() {
        assert!(matches!(
            CompileError::from(ImportError::wire(0, "x")),
            CompileError::Parse(_)
        ));
        assert!(matches!(
            CompileError::from(ImportError::unsupported("Softmax", "n", "d")),
            CompileError::Unsupported(_)
        ));
        assert!(matches!(
            CompileError::from(ImportError::shape("n", "d")),
            CompileError::Graph(_)
        ));
        assert!(matches!(
            CompileError::from(ImportError::io(
                "/nope",
                std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
            )),
            CompileError::Io { .. }
        ));
    }
}
