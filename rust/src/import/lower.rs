//! Lowering: decoded ONNX → `graph::Graph` + `funcsim::Params`.
//!
//! The inverse of [`super::export`]. Maps the ONNX opset subset in the
//! ARCHITECTURE.md lowering table onto `graph::OpKind`, cross-checking
//! every computed shape against the model's declared `value_info`, and
//! assembles the quantized parameter store:
//!
//! * `INT8` weight initializers take the **exact** path — values are
//!   permuted (OIHW→HWIO, `[C,1,k,k]`→HWC, Gemm `transB` handled) but
//!   never re-quantized, so a model produced by [`super::export`]
//!   round-trips bit-identically under the functional simulator;
//! * `FLOAT` weight initializers take the **quantize** path — standalone
//!   `BatchNormalization` folds into the preceding conv
//!   (`w·γ/√(σ²+ε)`), then symmetric per-tensor max-abs quantization to
//!   int8 (structural fidelity: the graph and datapath are faithful, the
//!   fixed-point calibration is a placeholder for a real calibration
//!   pass);
//! * the accelerator scalars ride on custom attributes (`sf_shift`,
//!   `sf_elt_shift`, `sf_lut`) of each fused group's main node;
//! * activations the simulator evaluates through the 256-entry LUT get
//!   a synthesized table when the model carries none.
//!
//! Everything that cannot lower returns a typed [`ImportError`] — this
//! module never panics on untrusted models.

use super::error::ImportError;
use super::proto::{
    data_type, decode_model, AttrValue, GraphProto, NodeProto, TensorData, TensorProto,
    ValueInfo,
};
use crate::analyzer::{analyze, GroupedGraph};
use crate::funcsim::{GroupParams, Params};
use crate::graph::{
    validate, Activation, Graph, Node, NodeId, OpKind, PadMode, Shape,
};
use std::collections::{HashMap, HashSet};

/// The result of a successful import: a validated graph plus the
/// parameter store feeding [`crate::funcsim`] and the program packer.
#[derive(Debug, Clone)]
pub struct Imported {
    /// The lowered, validated compute graph.
    pub graph: Graph,
    /// Quantized parameters for every weight-carrying / LUT group.
    pub params: Params,
}

/// Sideband quantization attributes read off a node (`sf_*`).
#[derive(Debug, Clone, Default)]
struct SfAttrs {
    shift: Option<i32>,
    elt_shift: Option<i32>,
    lut: Option<Vec<i8>>,
}

/// Weight payload recorded for a Conv/Gemm node, keyed by node name.
enum WeightSpec {
    /// Pre-quantized int8 weights in repo layout + int32 bias.
    Exact { weights: Vec<i8>, bias: Vec<i32> },
    /// Float weights in repo layout + float bias — quantized after BN
    /// folding in [`assemble_params`].
    Float { weights: Vec<f32>, bias: Vec<f32> },
}

/// A standalone `Add`-with-constant folded into the group bias.
enum BiasSpec {
    I32(Vec<i32>),
    F32(Vec<f32>),
}

/// BatchNormalization statistics folded into the producer's weights.
struct BnFold {
    gamma: Vec<f32>,
    beta: Vec<f32>,
    mean: Vec<f32>,
    var: Vec<f32>,
    eps: f32,
}

/// Permute ONNX OIHW conv weights into repo HWIO. Bit-exact shuffle.
fn oihw_to_hwio<T: Copy + Default>(w: &[T], k: usize, cin: usize, cout: usize) -> Vec<T> {
    let mut out = vec![T::default(); w.len()];
    for o in 0..cout {
        for i in 0..cin {
            for y in 0..k {
                for x in 0..k {
                    out[((y * k + x) * cin + i) * cout + o] = w[((o * cin + i) * k + y) * k + x];
                }
            }
        }
    }
    out
}

/// Permute ONNX `[C,1,kh,kw]` depthwise weights into repo `[ky][kx][c]`.
fn c1hw_to_hwc<T: Copy + Default>(w: &[T], k: usize, c: usize) -> Vec<T> {
    let mut out = vec![T::default(); w.len()];
    for ch in 0..c {
        for y in 0..k {
            for x in 0..k {
                out[(y * k + x) * c + ch] = w[(ch * k + y) * k + x];
            }
        }
    }
    out
}

fn dim_usize(name: &str, d: i64) -> Result<usize, ImportError> {
    usize::try_from(d)
        .ok()
        .filter(|&v| v > 0)
        .ok_or_else(|| ImportError::shape(name, format!("dimension {d} must be positive")))
}

/// `[1,C,H,W]` / `[C,H,W]` / `[1,C]` / `[C]` declared dims → repo shape.
fn shape_from_dims(name: &str, dims: &[Option<i64>]) -> Result<Shape, ImportError> {
    let concrete = |i: usize| -> Result<usize, ImportError> {
        match dims[i] {
            Some(d) => dim_usize(name, d),
            None => Err(ImportError::shape(
                name,
                format!("dimension {i} is symbolic; the input shape must be concrete"),
            )),
        }
    };
    let batch_ok = |d: Option<i64>| d.is_none() || d == Some(1);
    match dims.len() {
        4 => {
            if !batch_ok(dims[0]) {
                return Err(ImportError::unsupported(
                    "Input",
                    name,
                    "batch size must be 1 (the accelerator optimizes single-image latency)",
                ));
            }
            Ok(Shape::new(concrete(2)?, concrete(3)?, concrete(1)?))
        }
        3 => Ok(Shape::new(concrete(1)?, concrete(2)?, concrete(0)?)),
        2 => {
            if !batch_ok(dims[0]) {
                return Err(ImportError::unsupported("Input", name, "batch size must be 1"));
            }
            Ok(Shape::vec(concrete(1)?))
        }
        1 => Ok(Shape::vec(concrete(0)?)),
        r => Err(ImportError::shape(name, format!("rank-{r} tensors are not feature maps"))),
    }
}

/// Scalar float from a 0-d / 1-element initializer (Clip bounds).
fn scalar_f32(t: &TensorProto) -> Option<f32> {
    match &t.data {
        TensorData::F32(v) if v.len() == 1 => Some(v[0]),
        _ => None,
    }
}

/// Synthesize the 256-entry activation LUT for imported float models
/// that carry no `sf_lut`. Input index is the int8 pre-activation in
/// Q3.4 (x = v / 16), output is the int8 post-activation in the same
/// format — matching how the functional simulator indexes the table.
fn synth_lut(act: Activation) -> Vec<i8> {
    let f = |x: f32| -> f32 {
        match act {
            Activation::Relu6 => x.clamp(0.0, 6.0),
            Activation::Swish => x / (1.0 + (-x).exp()),
            Activation::Sigmoid => 1.0 / (1.0 + (-x).exp()),
            Activation::HardSwish => x * (x + 3.0).clamp(0.0, 6.0) / 6.0,
            Activation::HardSigmoid => ((x + 3.0) / 6.0).clamp(0.0, 1.0),
            // non-LUT activations never reach here
            _ => x,
        }
    };
    (0..256u16)
        .map(|i| {
            let v = (i as u8) as i8;
            (f(v as f32 / 16.0) * 16.0).round().clamp(-128.0, 127.0) as i8
        })
        .collect()
}

/// The lowering state machine: one pass over the ONNX node list.
struct Lowerer {
    nodes: Vec<Node>,
    /// Claimed graph-node / alias names (repo graphs use one namespace).
    names: HashSet<String>,
    /// Tensor name → producing node (aliases point at the producer).
    tensors: HashMap<String, NodeId>,
    /// Initializers (plus `Constant` node outputs) by name.
    inits: HashMap<String, TensorProto>,
    /// Declared intermediate/output dims for shape cross-checking.
    vinfo: HashMap<String, Vec<Option<i64>>>,
    /// Conv/Gemm weight payloads keyed by graph-node name.
    weight_specs: HashMap<String, WeightSpec>,
    /// `sf_*` attributes keyed by graph-node name.
    sf: HashMap<String, SfAttrs>,
    /// BatchNormalization statistics keyed by graph-node name.
    bn: HashMap<String, BnFold>,
    /// Constant-add bias folds keyed by graph-node name.
    bias_adds: HashMap<String, BiasSpec>,
}

impl Lowerer {
    fn new() -> Self {
        Lowerer {
            nodes: Vec::new(),
            names: HashSet::new(),
            tensors: HashMap::new(),
            inits: HashMap::new(),
            vinfo: HashMap::new(),
            weight_specs: HashMap::new(),
            sf: HashMap::new(),
            bn: HashMap::new(),
            bias_adds: HashMap::new(),
        }
    }

    /// First output tensor name — the graph-node name.
    fn out_name(&self, n: &NodeProto) -> Result<String, ImportError> {
        n.output
            .first()
            .filter(|s| !s.is_empty())
            .cloned()
            .ok_or_else(|| {
                ImportError::schema(format!(
                    "node {:?} ({}) has no output tensor",
                    n.display_name(),
                    n.op_type
                ))
            })
    }

    /// The `idx`-th input tensor name (empty string = absent optional).
    fn operand<'b>(&self, n: &'b NodeProto, idx: usize) -> Result<&'b str, ImportError> {
        n.input
            .get(idx)
            .map(String::as_str)
            .filter(|s| !s.is_empty())
            .ok_or_else(|| {
                ImportError::schema(format!(
                    "node {:?} ({}) is missing input {idx}",
                    n.display_name(),
                    n.op_type
                ))
            })
    }

    /// Feature-map operand: must resolve to a lowered node.
    fn src(&self, n: &NodeProto, idx: usize) -> Result<NodeId, ImportError> {
        let t = self.operand(n, idx)?;
        if let Some(&id) = self.tensors.get(t) {
            return Ok(id);
        }
        if self.inits.contains_key(t) {
            return Err(ImportError::unsupported(
                &n.op_type,
                n.display_name(),
                format!("input {t:?} is a constant where a feature map is required"),
            ));
        }
        Err(ImportError::model(format!(
            "node {:?} ({}) reads unknown tensor {t:?}",
            n.display_name(),
            n.op_type
        )))
    }

    /// Constant operand: must resolve to an initializer.
    fn init_of(&self, n: &NodeProto, idx: usize) -> Result<&TensorProto, ImportError> {
        let t = self.operand(n, idx)?;
        self.inits.get(t).ok_or_else(|| {
            ImportError::unsupported(
                &n.op_type,
                n.display_name(),
                format!("input {t:?} must be a constant initializer"),
            )
        })
    }

    fn shape_of(&self, id: NodeId) -> Shape {
        self.nodes[id.0].out_shape
    }

    /// Cross-check a computed shape against declared `value_info`.
    fn check_vinfo(&self, name: &str, got: Shape) -> Result<(), ImportError> {
        let Some(dims) = self.vinfo.get(name) else { return Ok(()) };
        let dim_ok = |d: Option<i64>, v: usize| d.is_none() || d == Some(v as i64);
        let ok = match dims.len() {
            4 => {
                dim_ok(dims[0], 1)
                    && dim_ok(dims[1], got.c)
                    && dim_ok(dims[2], got.h)
                    && dim_ok(dims[3], got.w)
            }
            3 => dim_ok(dims[0], got.c) && dim_ok(dims[1], got.h) && dim_ok(dims[2], got.w),
            2 => got.h == 1 && got.w == 1 && dim_ok(dims[0], 1) && dim_ok(dims[1], got.c),
            1 => got.h == 1 && got.w == 1 && dim_ok(dims[0], got.c),
            _ => false,
        };
        if ok {
            Ok(())
        } else {
            Err(ImportError::shape(
                name,
                format!("declared value_info {dims:?} contradicts computed shape {got}"),
            ))
        }
    }

    fn claim(&mut self, name: &str) -> Result<(), ImportError> {
        if !self.names.insert(name.to_string()) {
            return Err(ImportError::model(format!("duplicate tensor name {name:?}")));
        }
        Ok(())
    }

    fn push(
        &mut self,
        name: String,
        op: OpKind,
        inputs: Vec<NodeId>,
        out_shape: Shape,
    ) -> Result<NodeId, ImportError> {
        self.claim(&name)?;
        self.check_vinfo(&name, out_shape)?;
        let in_shapes = inputs.iter().map(|&i| self.shape_of(i)).collect();
        let id = NodeId(self.nodes.len());
        self.nodes.push(Node { id, name: name.clone(), op, inputs, in_shapes, out_shape });
        self.tensors.insert(name, id);
        Ok(id)
    }

    /// Harvest `sf_shift` / `sf_elt_shift` / `sf_lut` off an ONNX node.
    fn take_sf(&mut self, n: &NodeProto, gname: &str) -> Result<(), ImportError> {
        let mut sf = SfAttrs::default();
        let mut any = false;
        if let Some(AttrValue::Int(v)) = n.attr("sf_shift") {
            sf.shift = Some(*v as i32);
            any = true;
        }
        if let Some(AttrValue::Int(v)) = n.attr("sf_elt_shift") {
            sf.elt_shift = Some(*v as i32);
            any = true;
        }
        if let Some(AttrValue::Tensor(t)) = n.attr("sf_lut") {
            let TensorData::I8(v) = &t.data else {
                return Err(ImportError::schema(format!(
                    "node {gname:?}: sf_lut must be an INT8 tensor"
                )));
            };
            if v.len() != 256 {
                return Err(ImportError::schema(format!(
                    "node {gname:?}: sf_lut must have 256 entries, got {}",
                    v.len()
                )));
            }
            sf.lut = Some(v.clone());
            any = true;
        }
        if any {
            self.sf.insert(gname.to_string(), sf);
        }
        Ok(())
    }

    /// Conv / pooling padding → `PadMode`, TF-convention check.
    fn infer_pad(
        &self,
        name: &str,
        n: &NodeProto,
        xs: Shape,
        k: usize,
        s: usize,
    ) -> Result<PadMode, ImportError> {
        match n.attr_str("auto_pad", "NOTSET") {
            "SAME_UPPER" | "SAME_LOWER" => Ok(PadMode::Same),
            "VALID" => {
                if xs.h < k || xs.w < k {
                    return Err(ImportError::shape(
                        name,
                        format!("VALID {k}x{k} kernel does not fit {xs}"),
                    ));
                }
                Ok(PadMode::Valid)
            }
            "NOTSET" | "" => {
                let pads = n.attr_ints("pads");
                let p: [usize; 4] = if pads.is_empty() {
                    [0; 4]
                } else if pads.len() == 4 {
                    let mut out = [0usize; 4];
                    for (slot, &v) in out.iter_mut().zip(pads) {
                        *slot = usize::try_from(v).map_err(|_| {
                            ImportError::schema(format!("node {name:?}: negative pad {v}"))
                        })?;
                    }
                    out
                } else {
                    return Err(ImportError::schema(format!(
                        "node {name:?}: pads must have 4 entries, got {}",
                        pads.len()
                    )));
                };
                if p == [0; 4] {
                    // unpadded 1x1 is SAME and VALID at once; prefer SAME
                    // (identical output: ceil(in/s) == floor((in-1)/s)+1)
                    if k == 1 {
                        return Ok(PadMode::Same);
                    }
                    if xs.h < k || xs.w < k {
                        return Err(ImportError::shape(
                            name,
                            format!("unpadded {k}x{k} kernel does not fit {xs}"),
                        ));
                    }
                    return Ok(PadMode::Valid);
                }
                // explicit pads must reproduce TF SAME semantics
                for (dim, p0, p1) in [(xs.h, p[0], p[2]), (xs.w, p[1], p[3])] {
                    let same_out = dim.div_ceil(s);
                    let needed = ((same_out - 1) * s + k).saturating_sub(dim);
                    if p0 + p1 != needed || p0.abs_diff(p1) > 1 {
                        return Err(ImportError::shape(
                            name,
                            format!(
                                "explicit pads {p:?} are neither VALID nor TF-SAME for \
                                 {dim} elements, k={k}, stride={s}"
                            ),
                        ));
                    }
                }
                Ok(PadMode::Same)
            }
            other => Err(ImportError::unsupported(
                &n.op_type,
                name,
                format!("auto_pad {other:?}"),
            )),
        }
    }

    /// `kernel_shape` / `strides` attributes → square `(k, s)`.
    fn kernel_stride(
        &self,
        name: &str,
        n: &NodeProto,
        default_k: Option<usize>,
    ) -> Result<(usize, usize), ImportError> {
        let ks = n.attr_ints("kernel_shape");
        let k = if ks.is_empty() {
            default_k.ok_or_else(|| {
                ImportError::schema(format!("node {name:?}: kernel_shape is required"))
            })?
        } else if ks.len() == 2 && ks[0] == ks[1] && ks[0] > 0 {
            ks[0] as usize
        } else {
            return Err(ImportError::unsupported(
                &n.op_type,
                name,
                format!("only square kernels are supported, got {ks:?}"),
            ));
        };
        let ss = n.attr_ints("strides");
        let s = if ss.is_empty() {
            1
        } else if ss.len() == 2 && ss[0] == ss[1] && ss[0] > 0 {
            ss[0] as usize
        } else {
            return Err(ImportError::unsupported(
                &n.op_type,
                name,
                format!("only uniform strides are supported, got {ss:?}"),
            ));
        };
        Ok((k, s))
    }

    fn lower_conv(&mut self, n: &NodeProto) -> Result<(), ImportError> {
        let name = self.out_name(n)?;
        let x = self.src(n, 0)?;
        let xs = self.shape_of(x);
        let w = self.init_of(n, 1)?.clone();
        let bias_t = if n.input.len() > 2 && !n.input[2].is_empty() {
            Some(self.init_of(n, 2)?.clone())
        } else {
            None
        };
        if n.attr_ints("dilations").iter().any(|&d| d != 1) {
            return Err(ImportError::unsupported(&n.op_type, &name, "dilated convolution"));
        }
        if w.dims.len() != 4 {
            return Err(ImportError::shape(
                &name,
                format!("conv weights must be rank 4, got dims {:?}", w.dims),
            ));
        }
        let m = dim_usize(&name, w.dims[0])?;
        let cg = dim_usize(&name, w.dims[1])?;
        let kh = dim_usize(&name, w.dims[2])?;
        let kw = dim_usize(&name, w.dims[3])?;
        if kh != kw {
            return Err(ImportError::unsupported(
                &n.op_type,
                &name,
                format!("non-square {kh}x{kw} kernel"),
            ));
        }
        let (k, s) = self.kernel_stride(&name, n, Some(kh))?;
        if k != kh {
            return Err(ImportError::shape(
                &name,
                format!("kernel_shape {k} contradicts weight dims {:?}", w.dims),
            ));
        }
        let cin = xs.c;
        let group = n.attr_int("group", 1);
        let depthwise = if group == 1 {
            false
        } else if group == cin as i64 && cg == 1 && m == cin {
            true
        } else {
            return Err(ImportError::unsupported(
                &n.op_type,
                &name,
                format!(
                    "group={group} with weight dims {:?}: only group=1 and depthwise \
                     (group == channels) convolutions are supported",
                    w.dims
                ),
            ));
        };
        if !depthwise && cg != cin {
            return Err(ImportError::shape(
                &name,
                format!("weights expect {cg} input channels, feature map has {cin}"),
            ));
        }
        let out_c = m;
        let pad = self.infer_pad(&name, n, xs, k, s)?;
        let out_shape = match pad {
            PadMode::Same => xs.conv_same(s, out_c),
            PadMode::Valid => xs.conv_valid(k, s, out_c),
        };
        let spec = match &w.data {
            TensorData::I8(v) => {
                let weights = if depthwise {
                    c1hw_to_hwc(v, k, cin)
                } else {
                    oihw_to_hwio(v, k, cin, out_c)
                };
                WeightSpec::Exact { weights, bias: bias_i32(&name, bias_t.as_ref(), out_c)? }
            }
            TensorData::F32(v) => {
                let weights = if depthwise {
                    c1hw_to_hwc(v, k, cin)
                } else {
                    oihw_to_hwio(v, k, cin, out_c)
                };
                WeightSpec::Float { weights, bias: bias_f32(&name, bias_t.as_ref(), out_c)? }
            }
            _ => {
                return Err(ImportError::unsupported(
                    &n.op_type,
                    &name,
                    format!("weight data_type {} (INT8 or FLOAT expected)", w.data_type),
                ))
            }
        };
        self.weight_specs.insert(name.clone(), spec);
        self.take_sf(n, &name)?;
        self.push(name, OpKind::Conv { k, stride: s, out_c, pad, depthwise }, vec![x], out_shape)?;
        Ok(())
    }

    fn lower_gemm(&mut self, n: &NodeProto) -> Result<(), ImportError> {
        let name = self.out_name(n)?;
        let x = self.src(n, 0)?;
        let xs = self.shape_of(x);
        if xs.h != 1 || xs.w != 1 {
            return Err(ImportError::unsupported(
                &n.op_type,
                &name,
                format!("Gemm input must be a 1x1xC vector, got {xs}"),
            ));
        }
        if (n.attr_float("alpha", 1.0) - 1.0).abs() > 1e-6
            || (n.attr_float("beta", 1.0) - 1.0).abs() > 1e-6
            || n.attr_int("transA", 0) != 0
        {
            return Err(ImportError::unsupported(
                &n.op_type,
                &name,
                "only alpha=1, beta=1, transA=0 Gemm is supported",
            ));
        }
        let trans_b = n.attr_int("transB", 0) != 0;
        let w = self.init_of(n, 1)?.clone();
        if w.dims.len() != 2 {
            return Err(ImportError::shape(
                &name,
                format!("Gemm weights must be rank 2, got dims {:?}", w.dims),
            ));
        }
        let (cin, out_c) = if trans_b {
            (dim_usize(&name, w.dims[1])?, dim_usize(&name, w.dims[0])?)
        } else {
            (dim_usize(&name, w.dims[0])?, dim_usize(&name, w.dims[1])?)
        };
        if cin != xs.c {
            return Err(ImportError::shape(
                &name,
                format!("Gemm weights expect {cin} inputs, vector has {}", xs.c),
            ));
        }
        // repo FC layout is IO ([cin][cout]) == transB=0 verbatim
        fn io_layout<T: Copy + Default>(v: &[T], cin: usize, cout: usize, tb: bool) -> Vec<T> {
            if !tb {
                return v.to_vec();
            }
            let mut out = vec![T::default(); v.len()];
            for i in 0..cin {
                for o in 0..cout {
                    out[i * cout + o] = v[o * cin + i];
                }
            }
            out
        }
        let bias_t = if n.input.len() > 2 && !n.input[2].is_empty() {
            Some(self.init_of(n, 2)?.clone())
        } else {
            None
        };
        let spec = match &w.data {
            TensorData::I8(v) => WeightSpec::Exact {
                weights: io_layout(v, cin, out_c, trans_b),
                bias: bias_i32(&name, bias_t.as_ref(), out_c)?,
            },
            TensorData::F32(v) => WeightSpec::Float {
                weights: io_layout(v, cin, out_c, trans_b),
                bias: bias_f32(&name, bias_t.as_ref(), out_c)?,
            },
            _ => {
                return Err(ImportError::unsupported(
                    &n.op_type,
                    &name,
                    format!("weight data_type {} (INT8 or FLOAT expected)", w.data_type),
                ))
            }
        };
        self.weight_specs.insert(name.clone(), spec);
        self.take_sf(n, &name)?;
        self.push(name, OpKind::Fc { out_c }, vec![x], Shape::vec(out_c))?;
        Ok(())
    }

    fn lower_batchnorm(&mut self, n: &NodeProto) -> Result<(), ImportError> {
        let name = self.out_name(n)?;
        let x = self.src(n, 0)?;
        let xs = self.shape_of(x);
        let mut stats = Vec::with_capacity(4);
        for idx in 1..=4 {
            let t = self.init_of(n, idx)?;
            let TensorData::F32(v) = &t.data else {
                return Err(ImportError::unsupported(
                    &n.op_type,
                    &name,
                    format!("BN statistic {:?} must be FLOAT", t.name),
                ));
            };
            if v.len() != xs.c {
                return Err(ImportError::shape(
                    &name,
                    format!("BN statistic {:?} has {} values for {} channels", t.name, v.len(), xs.c),
                ));
            }
            stats.push(v.clone());
        }
        let var = stats.pop().unwrap();
        let mean = stats.pop().unwrap();
        let beta = stats.pop().unwrap();
        let gamma = stats.pop().unwrap();
        self.bn.insert(
            name.clone(),
            BnFold { gamma, beta, mean, var, eps: n.attr_float("epsilon", 1e-5) },
        );
        self.take_sf(n, &name)?;
        self.push(name, OpKind::BatchNorm, vec![x], xs)?;
        Ok(())
    }

    fn lower_act(&mut self, n: &NodeProto, a: Activation) -> Result<(), ImportError> {
        let name = self.out_name(n)?;
        let x = self.src(n, 0)?;
        let xs = self.shape_of(x);
        self.take_sf(n, &name)?;
        self.push(name, OpKind::Act(a), vec![x], xs)?;
        Ok(())
    }

    fn lower_clip(&mut self, n: &NodeProto) -> Result<(), ImportError> {
        let name = self.out_name(n)?;
        let (min, max) = if n.input.len() > 1 {
            let min = if n.input.len() > 1 && !n.input[1].is_empty() {
                scalar_f32(self.init_of(n, 1)?)
            } else {
                Some(f32::NEG_INFINITY)
            };
            let max = if n.input.len() > 2 && !n.input[2].is_empty() {
                scalar_f32(self.init_of(n, 2)?)
            } else {
                Some(f32::INFINITY)
            };
            (min, max)
        } else {
            (Some(n.attr_float("min", f32::NEG_INFINITY)), Some(n.attr_float("max", f32::INFINITY)))
        };
        match (min, max) {
            (Some(lo), Some(hi)) if lo == 0.0 && hi == 6.0 => self.lower_act(n, Activation::Relu6),
            _ => Err(ImportError::unsupported(
                &n.op_type,
                &name,
                format!("only Clip(0, 6) = ReLU6 is supported, got ({min:?}, {max:?})"),
            )),
        }
    }

    fn lower_identity(&mut self, n: &NodeProto) -> Result<(), ImportError> {
        if n.attr_int("sf_linear_act", 0) == 1 {
            return self.lower_act(n, Activation::Linear);
        }
        let name = self.out_name(n)?;
        let x = self.src(n, 0)?;
        let xs = self.shape_of(x);
        self.take_sf(n, &name)?;
        self.push(name, OpKind::Identity, vec![x], xs)?;
        Ok(())
    }

    fn lower_add(&mut self, n: &NodeProto) -> Result<(), ImportError> {
        let name = self.out_name(n)?;
        if n.input.len() != 2 {
            return Err(ImportError::unsupported(
                &n.op_type,
                &name,
                format!("{}-operand addition", n.input.len()),
            ));
        }
        let a = self.operand(n, 0)?.to_string();
        let b = self.operand(n, 1)?.to_string();
        match (self.inits.contains_key(&a), self.inits.contains_key(&b)) {
            (true, true) => Err(ImportError::unsupported(
                &n.op_type,
                &name,
                "addition of two constants (fold them offline)",
            )),
            (false, false) => {
                let x = self.src(n, 0)?;
                let y = self.src(n, 1)?;
                let (sx, sy) = (self.shape_of(x), self.shape_of(y));
                if sx != sy {
                    return Err(ImportError::shape(
                        &name,
                        format!("shortcut operands disagree: {sx} vs {sy}"),
                    ));
                }
                self.take_sf(n, &name)?;
                self.push(name, OpKind::EltwiseAdd, vec![x, y], sx)?;
                Ok(())
            }
            (a_const, _) => {
                let (xname, tname) = if a_const { (&b, &a) } else { (&a, &b) };
                let x = *self.tensors.get(xname.as_str()).ok_or_else(|| {
                    ImportError::model(format!("node {name:?} reads unknown tensor {xname:?}"))
                })?;
                let xs = self.shape_of(x);
                let t = self.inits.get(tname.as_str()).unwrap();
                if t.data.len() != xs.c {
                    return Err(ImportError::shape(
                        &name,
                        format!(
                            "bias constant {tname:?} has {} values for {} channels",
                            t.data.len(),
                            xs.c
                        ),
                    ));
                }
                let spec = match &t.data {
                    TensorData::I32(v) => BiasSpec::I32(v.clone()),
                    TensorData::I8(v) => BiasSpec::I32(v.iter().map(|&x| x as i32).collect()),
                    TensorData::I64(v) => {
                        let mut out = Vec::with_capacity(v.len());
                        for &x in v {
                            out.push(i32::try_from(x).map_err(|_| {
                                ImportError::schema(format!(
                                    "bias constant {tname:?}: {x} out of i32 range"
                                ))
                            })?);
                        }
                        BiasSpec::I32(out)
                    }
                    TensorData::F32(v) => BiasSpec::F32(v.clone()),
                    TensorData::Empty => {
                        return Err(ImportError::schema(format!(
                            "bias constant {tname:?} has no payload"
                        )))
                    }
                };
                self.bias_adds.insert(name.clone(), spec);
                self.take_sf(n, &name)?;
                self.push(name, OpKind::BiasAdd, vec![x], xs)?;
                Ok(())
            }
        }
    }

    fn lower_mul(&mut self, n: &NodeProto) -> Result<(), ImportError> {
        let name = self.out_name(n)?;
        if n.input.len() != 2 {
            return Err(ImportError::unsupported(
                &n.op_type,
                &name,
                format!("{}-operand multiplication", n.input.len()),
            ));
        }
        for idx in 0..2 {
            let t = self.operand(n, idx)?;
            if self.inits.contains_key(t) {
                return Err(ImportError::unsupported(
                    &n.op_type,
                    &name,
                    format!("multiplication by constant {t:?} (fold it into the weights)"),
                ));
            }
        }
        let x0 = self.src(n, 0)?;
        let x1 = self.src(n, 1)?;
        let (s0, s1) = (self.shape_of(x0), self.shape_of(x1));
        // the gate is the 1x1xC operand (SE excitation)
        let (fmap, gate, out) = if s1.h == 1 && s1.w == 1 && s1.c == s0.c {
            (x0, x1, s0)
        } else if s0.h == 1 && s0.w == 1 && s0.c == s1.c {
            (x1, x0, s1)
        } else {
            return Err(ImportError::unsupported(
                &n.op_type,
                &name,
                format!(
                    "element-wise multiply of {s0} by {s1}: only channel gating \
                     (one operand 1x1xC) is supported"
                ),
            ));
        };
        self.take_sf(n, &name)?;
        self.push(name, OpKind::ScaleMul, vec![fmap, gate], out)?;
        Ok(())
    }

    fn lower_pool(&mut self, n: &NodeProto, max: bool) -> Result<(), ImportError> {
        let name = self.out_name(n)?;
        let x = self.src(n, 0)?;
        let xs = self.shape_of(x);
        let (k, s) = self.kernel_stride(&name, n, None)?;
        // the datapath implements TF-SAME pooling only: out = ceil(in/s).
        // verify the ONNX attributes produce exactly that.
        let mut padded = false;
        match n.attr_str("auto_pad", "NOTSET") {
            "SAME_UPPER" | "SAME_LOWER" => {
                for dim in [xs.h, xs.w] {
                    padded |= (dim.div_ceil(s) - 1) * s + k > dim;
                }
            }
            "VALID" => {
                for dim in [xs.h, xs.w] {
                    if dim < k {
                        return Err(ImportError::shape(
                            &name,
                            format!("VALID {k}x{k} window does not fit {xs}"),
                        ));
                    }
                    if (dim - k) / s + 1 != dim.div_ceil(s) {
                        return Err(ImportError::shape(
                            &name,
                            format!(
                                "pooling must satisfy out == ceil(in/stride); VALID gives \
                                 {} for {dim} elements, k={k}, stride={s}",
                                (dim - k) / s + 1
                            ),
                        ));
                    }
                }
            }
            "NOTSET" | "" => {
                let pads = n.attr_ints("pads");
                let p: [usize; 4] = if pads.is_empty() {
                    [0; 4]
                } else if pads.len() == 4 {
                    let mut out = [0usize; 4];
                    for (slot, &v) in out.iter_mut().zip(pads) {
                        *slot = usize::try_from(v).map_err(|_| {
                            ImportError::schema(format!("node {name:?}: negative pad {v}"))
                        })?;
                    }
                    out
                } else {
                    return Err(ImportError::schema(format!(
                        "node {name:?}: pads must have 4 entries, got {}",
                        pads.len()
                    )));
                };
                padded = p.iter().any(|&v| v > 0);
                let ceil_mode = n.attr_int("ceil_mode", 0) != 0;
                for (dim, p0, p1) in [(xs.h, p[0], p[2]), (xs.w, p[1], p[3])] {
                    let span = dim + p0 + p1;
                    if span < k {
                        return Err(ImportError::shape(
                            &name,
                            format!("{k}x{k} window does not fit {dim}+{p0}+{p1} elements"),
                        ));
                    }
                    let out = if ceil_mode {
                        (span - k).div_ceil(s) + 1
                    } else {
                        (span - k) / s + 1
                    };
                    if out != dim.div_ceil(s) {
                        return Err(ImportError::shape(
                            &name,
                            format!(
                                "pooling must satisfy out == ceil(in/stride); pads {p:?} \
                                 give {out} for {dim} elements, k={k}, stride={s}"
                            ),
                        ));
                    }
                }
            }
            other => {
                return Err(ImportError::unsupported(
                    &n.op_type,
                    &name,
                    format!("auto_pad {other:?}"),
                ))
            }
        }
        if !max && padded && n.attr_int("count_include_pad", 0) == 0 {
            return Err(ImportError::unsupported(
                &n.op_type,
                &name,
                "padded AveragePool with count_include_pad=0: the datapath divides by \
                 k² including the zero-padded taps",
            ));
        }
        let out_shape = xs.conv_same(s, xs.c);
        let op = if max { OpKind::MaxPool { k, stride: s } } else { OpKind::AvgPool { k, stride: s } };
        self.take_sf(n, &name)?;
        self.push(name, op, vec![x], out_shape)?;
        Ok(())
    }

    fn lower_gap(&mut self, n: &NodeProto) -> Result<(), ImportError> {
        let name = self.out_name(n)?;
        let x = self.src(n, 0)?;
        let xs = self.shape_of(x);
        self.take_sf(n, &name)?;
        self.push(name, OpKind::GlobalAvgPool, vec![x], Shape::vec(xs.c))?;
        Ok(())
    }

    fn lower_reduce_mean(&mut self, n: &NodeProto) -> Result<(), ImportError> {
        let name = self.out_name(n)?;
        let mut axes: Vec<i64> = n.attr_ints("axes").to_vec();
        if axes.is_empty() && n.input.len() > 1 && !n.input[1].is_empty() {
            if let TensorData::I64(v) = &self.init_of(n, 1)?.data {
                axes = v.clone();
            }
        }
        let mut norm: Vec<i64> = axes.iter().map(|&a| if a < 0 { a + 4 } else { a }).collect();
        norm.sort_unstable();
        if norm != [2, 3] {
            return Err(ImportError::unsupported(
                &n.op_type,
                &name,
                format!("only spatial ReduceMean (axes [2,3]) lowers to GlobalAvgPool, got {axes:?}"),
            ));
        }
        self.lower_gap(n)
    }

    fn lower_concat(&mut self, n: &NodeProto) -> Result<(), ImportError> {
        let name = self.out_name(n)?;
        let axis = n.attr_int("axis", 1);
        if axis != 1 && axis != -3 {
            return Err(ImportError::unsupported(
                &n.op_type,
                &name,
                format!("only channel concatenation (axis 1) is supported, got axis {axis}"),
            ));
        }
        if n.input.len() < 2 {
            return Err(ImportError::schema(format!(
                "node {name:?}: Concat needs at least 2 inputs"
            )));
        }
        let mut cur = self.src(n, 0)?;
        for j in 1..n.input.len() {
            let nxt = self.src(n, j)?;
            let (sa, sb) = (self.shape_of(cur), self.shape_of(nxt));
            if sa.h != sb.h || sa.w != sb.w {
                return Err(ImportError::shape(
                    &name,
                    format!("concat operands disagree spatially: {sa} vs {sb}"),
                ));
            }
            let out = Shape::new(sa.h, sa.w, sa.c + sb.c);
            // n-ary concats lower to a binary chain
            let node_name =
                if j + 1 == n.input.len() { name.clone() } else { format!("{name}.cat{j}") };
            cur = self.push(node_name, OpKind::Concat, vec![cur, nxt], out)?;
        }
        self.take_sf(n, &name)?;
        Ok(())
    }

    fn lower_resize(&mut self, n: &NodeProto) -> Result<(), ImportError> {
        let name = self.out_name(n)?;
        let x = self.src(n, 0)?;
        let xs = self.shape_of(x);
        let mode = n.attr_str("mode", "nearest");
        if mode != "nearest" {
            return Err(ImportError::unsupported(
                &n.op_type,
                &name,
                format!("only nearest-neighbour resize is supported, got mode {mode:?}"),
            ));
        }
        // factor from the scales input (Resize: input 2; Upsample: input
        // 1), the sizes input (Resize: input 3), or a scales attribute
        // (legacy Upsample-7).
        let mut factor: Option<f32> = None;
        let scales_idx = if n.op_type == "Upsample" { 1 } else { 2 };
        if n.input.len() > scales_idx && !n.input[scales_idx].is_empty() {
            if let TensorData::F32(v) = &self.init_of(n, scales_idx)?.data {
                if v.len() == 4 && v[0] == 1.0 && v[1] == 1.0 && v[2] == v[3] {
                    factor = Some(v[2]);
                }
            }
        } else if n.input.len() > 3 && !n.input[3].is_empty() {
            if let TensorData::I64(v) = &self.init_of(n, 3)?.data {
                if v.len() == 4
                    && v[2] > 0
                    && v[3] > 0
                    && v[2] as usize % xs.h == 0
                    && v[3] as usize % xs.w == 0
                    && v[2] as usize / xs.h == v[3] as usize / xs.w
                {
                    factor = Some((v[2] as usize / xs.h) as f32);
                }
            }
        } else if let Some(AttrValue::Floats(v)) = n.attr("scales") {
            if v.len() == 4 && v[0] == 1.0 && v[1] == 1.0 && v[2] == v[3] {
                factor = Some(v[2]);
            }
        }
        let Some(f) = factor else {
            return Err(ImportError::unsupported(
                &n.op_type,
                &name,
                "resize must scale H and W by the same integer factor (batch and \
                 channel scales = 1)",
            ));
        };
        if f < 1.0 || (f - f.round()).abs() > 1e-6 {
            return Err(ImportError::unsupported(
                &n.op_type,
                &name,
                format!("non-integer upsample factor {f}"),
            ));
        }
        let factor = f.round() as usize;
        self.take_sf(n, &name)?;
        self.push(name, OpKind::Upsample { factor }, vec![x], xs.upsample(factor))?;
        Ok(())
    }

    /// Flatten / Reshape / Squeeze / Unsqueeze on an already-flat
    /// (1×1×C) map is a pure rename: alias the tensor, emit no node.
    fn lower_alias(&mut self, n: &NodeProto) -> Result<(), ImportError> {
        let name = self.out_name(n)?;
        let x = self.src(n, 0)?;
        let xs = self.shape_of(x);
        if xs.h != 1 || xs.w != 1 {
            return Err(ImportError::unsupported(
                &n.op_type,
                &name,
                format!(
                    "{} of a {xs} map: only 1x1xC (already-flat) inputs are supported",
                    n.op_type
                ),
            ));
        }
        if n.op_type == "Reshape" && n.input.len() > 1 && !n.input[1].is_empty() {
            if let TensorData::I64(v) = &self.init_of(n, 1)?.data {
                let mut fixed: usize = 1;
                let mut wildcard = false;
                for &d in v {
                    match d {
                        -1 => wildcard = true,
                        d if d > 0 => fixed = fixed.saturating_mul(d as usize),
                        _ => {
                            return Err(ImportError::unsupported(
                                &n.op_type,
                                &name,
                                format!("reshape target dim {d}"),
                            ))
                        }
                    }
                }
                let ok = if wildcard { fixed != 0 && xs.c % fixed == 0 } else { fixed == xs.c };
                if !ok {
                    return Err(ImportError::shape(
                        &name,
                        format!("reshape target {v:?} does not hold {} elements", xs.c),
                    ));
                }
            }
        }
        self.claim(&name)?;
        self.check_vinfo(&name, xs)?;
        self.tensors.insert(name, x);
        Ok(())
    }

    /// `Constant` nodes become initializers.
    fn lower_constant(&mut self, n: &NodeProto) -> Result<(), ImportError> {
        let name = self.out_name(n)?;
        let Some(AttrValue::Tensor(t)) = n.attr("value") else {
            return Err(ImportError::unsupported(
                &n.op_type,
                &name,
                "only tensor-valued Constant nodes are supported",
            ));
        };
        let mut t = t.clone();
        t.name = name.clone();
        self.inits.insert(name, t);
        Ok(())
    }

    fn lower(&mut self, n: &NodeProto) -> Result<(), ImportError> {
        if n.op_type != "Constant" && n.output.len() != 1 {
            return Err(ImportError::unsupported(
                &n.op_type,
                n.display_name(),
                format!("{}-output nodes are not supported", n.output.len()),
            ));
        }
        match n.op_type.as_str() {
            "Conv" => self.lower_conv(n),
            "Gemm" => self.lower_gemm(n),
            "BatchNormalization" => self.lower_batchnorm(n),
            "Relu" => self.lower_act(n, Activation::Relu),
            "LeakyRelu" => self.lower_act(n, Activation::Leaky),
            "Clip" => self.lower_clip(n),
            "Sigmoid" => self.lower_act(n, Activation::Sigmoid),
            "HardSwish" => self.lower_act(n, Activation::HardSwish),
            "HardSigmoid" => self.lower_act(n, Activation::HardSigmoid),
            "Identity" => self.lower_identity(n),
            "Add" | "Sum" => self.lower_add(n),
            "Mul" => self.lower_mul(n),
            "MaxPool" => self.lower_pool(n, true),
            "AveragePool" => self.lower_pool(n, false),
            "GlobalAveragePool" => self.lower_gap(n),
            "ReduceMean" => self.lower_reduce_mean(n),
            "Concat" => self.lower_concat(n),
            "Resize" | "Upsample" => self.lower_resize(n),
            "Flatten" | "Reshape" | "Squeeze" | "Unsqueeze" => self.lower_alias(n),
            "Constant" => self.lower_constant(n),
            _ => Err(ImportError::unsupported(
                &n.op_type,
                n.display_name(),
                "not in the accelerator op set (see the lowering table in \
                 docs/ARCHITECTURE.md)",
            )),
        }
    }
}

fn bias_i32(
    name: &str,
    t: Option<&TensorProto>,
    out_c: usize,
) -> Result<Vec<i32>, ImportError> {
    let Some(t) = t else { return Ok(vec![0; out_c]) };
    let v: Vec<i32> = match &t.data {
        TensorData::I32(v) => v.clone(),
        TensorData::I8(v) => v.iter().map(|&x| x as i32).collect(),
        TensorData::I64(v) => {
            let mut out = Vec::with_capacity(v.len());
            for &x in v {
                out.push(i32::try_from(x).map_err(|_| {
                    ImportError::schema(format!("bias {:?}: {x} out of i32 range", t.name))
                })?);
            }
            out
        }
        TensorData::F32(v) => v.iter().map(|&x| x.round() as i32).collect(),
        TensorData::Empty => vec![0; out_c],
    };
    if v.len() != out_c {
        return Err(ImportError::shape(
            name,
            format!("bias {:?} has {} values for {out_c} output channels", t.name, v.len()),
        ));
    }
    Ok(v)
}

fn bias_f32(
    name: &str,
    t: Option<&TensorProto>,
    out_c: usize,
) -> Result<Vec<f32>, ImportError> {
    let Some(t) = t else { return Ok(vec![0.0; out_c]) };
    let v: Vec<f32> = match &t.data {
        TensorData::F32(v) => v.clone(),
        TensorData::I32(v) => v.iter().map(|&x| x as f32).collect(),
        TensorData::I8(v) => v.iter().map(|&x| x as f32).collect(),
        TensorData::I64(v) => v.iter().map(|&x| x as f32).collect(),
        TensorData::Empty => vec![0.0; out_c],
    };
    if v.len() != out_c {
        return Err(ImportError::shape(
            name,
            format!("bias {:?} has {} values for {out_c} output channels", t.name, v.len()),
        ));
    }
    Ok(v)
}

/// Fold the recorded side tables into per-group [`GroupParams`].
fn assemble_params(gg: &GroupedGraph, lw: &Lowerer) -> Result<Params, ImportError> {
    let mut groups = HashMap::new();
    for gr in &gg.groups {
        let mut shift: Option<i32> = None;
        let mut elt: Option<i32> = None;
        let mut lut: Option<Vec<i8>> = None;
        let mut wspec: Option<(&str, &WeightSpec)> = None;
        let mut folds: Vec<&BnFold> = Vec::new();
        let mut adds: Vec<&BiasSpec> = Vec::new();
        for &nid in &gr.nodes {
            let nm = gg.graph.node(nid).name.as_str();
            if let Some(s) = lw.sf.get(nm) {
                shift = shift.or(s.shift);
                elt = elt.or(s.elt_shift);
                if lut.is_none() {
                    lut = s.lut.clone();
                }
            }
            if let Some(ws) = lw.weight_specs.get(nm) {
                if let Some((prev, _)) = wspec {
                    return Err(ImportError::model(format!(
                        "nodes {prev:?} and {nm:?} both carry weights inside one fused group"
                    )));
                }
                wspec = Some((nm, ws));
            }
            if let Some(f) = lw.bn.get(nm) {
                folds.push(f);
            }
            if let Some(a) = lw.bias_adds.get(nm) {
                adds.push(a);
            }
        }
        let add_into_i32 = |b: &mut Vec<i32>, adds: &[&BiasSpec], who: &str| {
            for a in adds {
                let vals: Vec<i32> = match a {
                    BiasSpec::I32(v) => v.clone(),
                    BiasSpec::F32(v) => v.iter().map(|&x| x.round() as i32).collect(),
                };
                if vals.len() != b.len() {
                    return Err(ImportError::model(format!(
                        "group {who:?}: bias-add length {} vs {} output channels",
                        vals.len(),
                        b.len()
                    )));
                }
                for (dst, v) in b.iter_mut().zip(vals) {
                    *dst = dst.wrapping_add(v);
                }
            }
            Ok(())
        };
        let (weights, bias) = match wspec {
            // exact path: the model was produced by our exporter (or a
            // compatible quantizer) — BN nodes carry identity statistics
            // by contract, so only explicit bias-adds fold in
            Some((nm, WeightSpec::Exact { weights, bias })) => {
                let mut b = bias.clone();
                add_into_i32(&mut b, &adds, nm)?;
                (weights.clone(), b)
            }
            Some((nm, WeightSpec::Float { weights, bias })) => {
                let mut w = weights.clone();
                let mut b = bias.clone();
                let cout = b.len();
                for f in &folds {
                    if f.gamma.len() != cout {
                        return Err(ImportError::model(format!(
                            "group {nm:?}: BN folds {} channels into {cout} outputs",
                            f.gamma.len()
                        )));
                    }
                    // channel is the innermost axis in HWIO, HWC and IO
                    for (idx, wv) in w.iter_mut().enumerate() {
                        let o = idx % cout;
                        *wv *= f.gamma[o] / (f.var[o] + f.eps).sqrt();
                    }
                    for o in 0..cout {
                        let fac = f.gamma[o] / (f.var[o] + f.eps).sqrt();
                        b[o] = (b[o] - f.mean[o]) * fac + f.beta[o];
                    }
                }
                for a in &adds {
                    let vals: Vec<f32> = match a {
                        BiasSpec::I32(v) => v.iter().map(|&x| x as f32).collect(),
                        BiasSpec::F32(v) => v.clone(),
                    };
                    if vals.len() != cout {
                        return Err(ImportError::model(format!(
                            "group {nm:?}: bias-add length {} vs {cout} output channels",
                            vals.len()
                        )));
                    }
                    for (dst, v) in b.iter_mut().zip(vals) {
                        *dst += v;
                    }
                }
                // symmetric per-tensor max-abs quantization; activations
                // are Q3.4, so the bias lands in the accumulator domain
                // at scale·16 (structural placeholder for calibration)
                let maxabs = w.iter().fold(0.0f32, |m, v| m.max(v.abs()));
                let scale = if maxabs > 0.0 { 127.0 / maxabs } else { 1.0 };
                let wi: Vec<i8> =
                    w.iter().map(|v| (v * scale).round().clamp(-127.0, 127.0) as i8).collect();
                let bi: Vec<i32> = b
                    .iter()
                    .map(|v| (v * scale * 16.0).round().clamp(i32::MIN as f32, i32::MAX as f32)
                        as i32)
                    .collect();
                (wi, bi)
            }
            None => (Vec::new(), Vec::new()),
        };
        if lut.is_none() && gr.act.lut_evaluated() {
            lut = Some(synth_lut(gr.act));
        }
        if wspec.is_none() && shift.is_none() && elt.is_none() && lut.is_none() {
            continue;
        }
        let name = gg.graph.node(gr.main).name.clone();
        groups.insert(
            name,
            GroupParams {
                weights,
                bias,
                shift: shift.unwrap_or(7),
                elt_shift: elt.unwrap_or(0),
                lut,
            },
        );
    }
    Ok(Params { groups })
}

/// Import a `.onnx` byte buffer into a validated graph + parameters.
pub fn import_model(bytes: &[u8]) -> Result<Imported, ImportError> {
    let m = decode_model(bytes)?;
    let gp: GraphProto = m.graph.expect("decode_model guarantees a graph");
    let GraphProto { name, node: pnodes, initializer, input, output, value_info } = gp;
    let mut lw = Lowerer::new();
    for t in initializer {
        lw.inits.insert(t.name.clone(), t);
    }
    for v in value_info.iter().chain(output.iter()) {
        if !v.dims.is_empty() {
            lw.vinfo.insert(v.name.clone(), v.dims.clone());
        }
    }
    // the single data input (initializers may be re-listed as inputs)
    let data_inputs: Vec<&ValueInfo> =
        input.iter().filter(|v| !lw.inits.contains_key(&v.name)).collect();
    let [vi] = data_inputs.as_slice() else {
        return Err(ImportError::model(format!(
            "expected exactly 1 data input, found {} ({:?})",
            data_inputs.len(),
            data_inputs.iter().map(|v| v.name.as_str()).collect::<Vec<_>>()
        )));
    };
    if vi.name.is_empty() {
        return Err(ImportError::schema("graph input has no name"));
    }
    let in_shape = shape_from_dims(&vi.name, &vi.dims)?;
    lw.push(vi.name.clone(), OpKind::Input, Vec::new(), in_shape)?;
    // tensor use counts + declared outputs gate the Swish re-fusion
    let mut uses: HashMap<&str, usize> = HashMap::new();
    for n in &pnodes {
        for i in &n.input {
            if !i.is_empty() {
                *uses.entry(i.as_str()).or_insert(0) += 1;
            }
        }
    }
    let out_names: HashSet<&str> = output.iter().map(|v| v.name.as_str()).collect();
    let mut i = 0;
    while i < pnodes.len() {
        let n = &pnodes[i];
        // Sigmoid(x) immediately followed by Mul(x, sigmoid) — and the
        // sigmoid used nowhere else — is the SiLU/Swish decomposition
        if n.op_type == "Sigmoid" && n.input.len() == 1 && n.output.len() == 1 {
            let sig_out = n.output[0].as_str();
            if let Some(mul) = pnodes.get(i + 1) {
                let fuses = mul.op_type == "Mul"
                    && mul.input.len() == 2
                    && mul.output.len() == 1
                    && mul.input.iter().any(|t| t == sig_out)
                    && mul.input.iter().any(|t| t == &n.input[0])
                    && n.input[0] != sig_out
                    && uses.get(sig_out).copied().unwrap_or(0) == 1
                    && !out_names.contains(sig_out)
                    && lw.tensors.contains_key(n.input[0].as_str());
                if fuses {
                    let name = lw.out_name(mul)?;
                    let x = lw.tensors[n.input[0].as_str()];
                    let xs = lw.shape_of(x);
                    lw.take_sf(mul, &name)?;
                    lw.push(name, OpKind::Act(Activation::Swish), vec![x], xs)?;
                    i += 2;
                    continue;
                }
            }
        }
        lw.lower(n)?;
        i += 1;
    }
    for o in &output {
        if !lw.tensors.contains_key(&o.name) {
            return Err(ImportError::model(format!(
                "declared graph output {:?} was never produced",
                o.name
            )));
        }
    }
    let graph = Graph {
        name: if name.is_empty() { "imported".into() } else { name },
        nodes: std::mem::take(&mut lw.nodes),
    };
    validate(&graph).map_err(|e| ImportError::model(e.to_string()))?;
    let gg = analyze(&graph);
    let params = assemble_params(&gg, &lw)?;
    Ok(Imported { graph, params })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::import::export::export_bytes;
    use crate::import::proto::{encode_model, Attribute, ModelProto};

    fn attr_ints(name: &str, v: Vec<i64>) -> Attribute {
        Attribute { name: name.into(), value: AttrValue::Ints(v) }
    }

    fn attr_str(name: &str, v: &str) -> Attribute {
        Attribute { name: name.into(), value: AttrValue::Str(v.into()) }
    }

    fn conv_node(name: &str, x: &str, extra: Vec<Attribute>) -> NodeProto {
        let mut attribute = vec![attr_ints("kernel_shape", vec![3, 3])];
        attribute.extend(extra);
        NodeProto {
            name: name.into(),
            op_type: "Conv".into(),
            input: vec![x.into(), format!("{name}.w"), format!("{name}.b")],
            output: vec![name.into()],
            attribute,
        }
    }

    fn model_with(nodes: Vec<NodeProto>, inits: Vec<TensorProto>, out: &str) -> ModelProto {
        ModelProto {
            ir_version: 8,
            producer_name: "test".into(),
            producer_version: "0".into(),
            opset_version: 14,
            graph: Some(GraphProto {
                name: "t".into(),
                node: nodes,
                initializer: inits,
                input: vec![ValueInfo::concrete("input", data_type::INT8, &[1, 2, 8, 8])],
                output: vec![ValueInfo {
                    name: out.into(),
                    elem_type: data_type::INT8,
                    dims: vec![],
                }],
                value_info: vec![],
            }),
        }
    }

    fn conv_inits(name: &str, cin: usize, cout: usize) -> Vec<TensorProto> {
        vec![
            TensorProto::i8s(
                format!("{name}.w"),
                vec![cout as i64, cin as i64, 3, 3],
                (0..9 * cin * cout).map(|v| (v % 11) as i8 - 5).collect(),
            ),
            TensorProto::i32s(
                format!("{name}.b"),
                vec![cout as i64],
                (0..cout as i32).collect(),
            ),
        ]
    }

    #[test]
    fn pad_inference_rules() {
        let lw = Lowerer::new();
        let xs = Shape::new(9, 9, 2);
        let n = |attrs: Vec<Attribute>| NodeProto {
            op_type: "Conv".into(),
            output: vec!["c".into()],
            attribute: attrs,
            ..Default::default()
        };
        // auto_pad strings
        assert_eq!(
            lw.infer_pad("c", &n(vec![attr_str("auto_pad", "SAME_UPPER")]), xs, 3, 1).unwrap(),
            PadMode::Same
        );
        assert_eq!(
            lw.infer_pad("c", &n(vec![attr_str("auto_pad", "VALID")]), xs, 3, 1).unwrap(),
            PadMode::Valid
        );
        // zero pads: 1x1 → Same, 3x3 → Valid
        assert_eq!(lw.infer_pad("c", &n(vec![]), xs, 1, 1).unwrap(), PadMode::Same);
        assert_eq!(lw.infer_pad("c", &n(vec![]), xs, 3, 1).unwrap(), PadMode::Valid);
        // TF-SAME explicit pads: k=3 s=1 → 1,1,1,1
        assert_eq!(
            lw.infer_pad("c", &n(vec![attr_ints("pads", vec![1, 1, 1, 1])]), xs, 3, 1).unwrap(),
            PadMode::Same
        );
        // k=3 s=2 on 9 elements: same_out=5, needed = 4*2+3-9 = 2 → (0,1)+(1,?) ...
        // symmetric [1,1,1,1] has p0+p1=2=needed per dim → Same
        assert_eq!(
            lw.infer_pad("c", &n(vec![attr_ints("pads", vec![1, 1, 1, 1])]), xs, 3, 2).unwrap(),
            PadMode::Same
        );
        // lopsided pads that change the output → ShapeMismatch
        let e = lw
            .infer_pad("c", &n(vec![attr_ints("pads", vec![2, 2, 2, 2])]), xs, 3, 1)
            .unwrap_err();
        assert!(matches!(e, ImportError::ShapeMismatch { .. }), "{e}");
        // VALID kernel larger than the map → ShapeMismatch, not a panic
        let e = lw
            .infer_pad("c", &n(vec![attr_str("auto_pad", "VALID")]), Shape::new(2, 2, 1), 3, 1)
            .unwrap_err();
        assert!(matches!(e, ImportError::ShapeMismatch { .. }), "{e}");
    }

    #[test]
    fn imports_a_hand_written_conv_model() {
        let nodes = vec![conv_node("c1", "input", vec![attr_str("auto_pad", "SAME_UPPER")])];
        let m = model_with(nodes, conv_inits("c1", 2, 4), "c1");
        let imp = import_model(&encode_model(&m)).unwrap();
        assert_eq!(imp.graph.nodes.len(), 2);
        let c1 = imp.graph.node(imp.graph.find("c1").unwrap());
        assert!(matches!(c1.op, OpKind::Conv { k: 3, stride: 1, out_c: 4, .. }));
        assert_eq!(c1.out_shape, Shape::new(8, 8, 4));
        // exact path: bias carried verbatim, default shift 7
        let gp = imp.params.get("c1").unwrap();
        assert_eq!(gp.bias, vec![0, 1, 2, 3]);
        assert_eq!(gp.shift, 7);
        assert_eq!(gp.weights.len(), 9 * 2 * 4);
    }

    #[test]
    fn bias_add_folds_into_the_group_bias() {
        let mut nodes =
            vec![conv_node("c1", "input", vec![attr_str("auto_pad", "SAME_UPPER")])];
        nodes.push(NodeProto {
            name: "badd".into(),
            op_type: "Add".into(),
            input: vec!["c1".into(), "badd.t".into()],
            output: vec!["badd".into()],
            attribute: vec![],
        });
        let mut inits = conv_inits("c1", 2, 4);
        inits.push(TensorProto::i32s("badd.t", vec![4, 1, 1], vec![10, 20, 30, 40]));
        let m = model_with(nodes, inits, "badd");
        let imp = import_model(&encode_model(&m)).unwrap();
        // the BiasAdd fuses into the conv group; bias = conv.b + constant
        let gp = imp.params.get("c1").unwrap();
        assert_eq!(gp.bias, vec![10, 21, 32, 43]);
    }

    #[test]
    fn swish_pair_refuses_into_one_node() {
        let g = {
            use crate::graph::GraphBuilder;
            let mut b = GraphBuilder::new("sw", Shape::new(8, 8, 3));
            let c = b.conv("c1", b.input_id(), 3, 1, 8, PadMode::Same);
            let _a = b.activation("silu", c, Activation::Swish);
            b.finish()
        };
        let bytes = export_bytes(&g, None).unwrap();
        let imp = import_model(&bytes).unwrap();
        assert_eq!(imp.graph.nodes.len(), g.nodes.len());
        let silu = imp.graph.node(imp.graph.find("silu").unwrap());
        assert!(matches!(silu.op, OpKind::Act(Activation::Swish)));
        assert!(imp.graph.find("silu.sig").is_none());
        // a LUT is synthesized even without sf_lut
        assert_eq!(imp.params.get("c1").unwrap().lut.as_ref().unwrap().len(), 256);
    }

    #[test]
    fn softmax_is_a_typed_unsupported_error() {
        let mut nodes =
            vec![conv_node("c1", "input", vec![attr_str("auto_pad", "SAME_UPPER")])];
        nodes.push(NodeProto {
            name: "probs".into(),
            op_type: "Softmax".into(),
            input: vec!["c1".into()],
            output: vec!["probs".into()],
            attribute: vec![],
        });
        let m = model_with(nodes, conv_inits("c1", 2, 4), "probs");
        let e = import_model(&encode_model(&m)).unwrap_err();
        let ImportError::UnsupportedOp { op_type, node, .. } = e else {
            panic!("expected UnsupportedOp, got {e}");
        };
        assert_eq!(op_type, "Softmax");
        assert_eq!(node, "probs");
    }

    #[test]
    fn synth_lut_is_bounded_and_plausible() {
        for act in [
            Activation::Relu6,
            Activation::Swish,
            Activation::Sigmoid,
            Activation::HardSwish,
            Activation::HardSigmoid,
        ] {
            let lut = synth_lut(act);
            assert_eq!(lut.len(), 256);
        }
        let relu6 = synth_lut(Activation::Relu6);
        // index 127 = +7.94 in Q3.4 → clamps to 6.0 → 96
        assert_eq!(relu6[127], 96);
        // index 255 = -1/16 → negative → 0
        assert_eq!(relu6[255], 0);
        let sig = synth_lut(Activation::Sigmoid);
        // sigmoid(0) = 0.5 → 8 in Q3.4
        assert_eq!(sig[0], 8);
    }

    #[test]
    fn weight_permutations_match_the_exporter() {
        let (k, cin, cout) = (3usize, 2usize, 4usize);
        let hwio: Vec<i8> = (0..(k * k * cin * cout) as i32).map(|v| (v % 100) as i8).collect();
        let oihw = {
            // the exporter-side permutation, inlined
            let mut out = vec![0i8; hwio.len()];
            for y in 0..k {
                for x in 0..k {
                    for i in 0..cin {
                        for o in 0..cout {
                            out[((o * cin + i) * k + y) * k + x] =
                                hwio[((y * k + x) * cin + i) * cout + o];
                        }
                    }
                }
            }
            out
        };
        assert_eq!(oihw_to_hwio(&oihw, k, cin, cout), hwio);
        let hwc: Vec<i8> = (0..(k * k * cin) as i32).map(|v| v as i8).collect();
        let c1hw = {
            let mut out = vec![0i8; hwc.len()];
            for y in 0..k {
                for x in 0..k {
                    for c in 0..cin {
                        out[(c * k + y) * k + x] = hwc[(y * k + x) * cin + c];
                    }
                }
            }
            out
        };
        assert_eq!(c1hw_to_hwc(&c1hw, k, cin), hwc);
    }
}
