//! ONNX front end: dependency-free model import and export.
//!
//! The paper's toolchain starts "From TensorFlow" — a real frozen graph
//! is parsed, analyzed and lowered onto the accelerator. This module is
//! that front door for ONNX, built from nothing but `std`:
//!
//! * [`wire`] — a minimal protobuf wire-format reader/writer (varints +
//!   length-delimited fields, the subset ONNX actually uses);
//! * [`proto`] — the `ModelProto`/`GraphProto`/`NodeProto`/`TensorProto`
//!   message subset, decoded by hand with absolute-offset errors;
//! * [`lower`] — the lowering pass ONNX op → [`crate::graph::OpKind`]
//!   with `value_info` shape cross-checking and parameter assembly
//!   (exact INT8 path and a quantizing FLOAT path with BN folding);
//! * [`export`] — the inverse, `graph::Graph` → ONNX, carrying the
//!   quantized parameters on `sf_*` attributes so every zoo model
//!   round-trips export→import→funcsim **bit-identically** (the
//!   hermetic fixture strategy: no binary blobs in the repo);
//! * [`error`] — the typed [`ImportError`] taxonomy; nothing in this
//!   module panics on untrusted bytes.

pub mod error;
pub mod wire;
pub mod proto;
pub mod export;
pub mod lower;

pub use error::ImportError;
pub use export::{export_bytes, export_graph};
pub use lower::{import_model, Imported};

use crate::compiler::CompileError;
use crate::funcsim::Params;
use crate::graph::Graph;
use std::path::Path;

/// Import a `.onnx` file from disk.
pub fn import_file(path: impl AsRef<Path>) -> crate::Result<Imported> {
    let path = path.as_ref();
    let bytes = std::fs::read(path).map_err(|e| ImportError::io(path, e))?;
    Ok(import_model(&bytes)?)
}

/// Export a graph (and optionally its parameters) to a `.onnx` file.
pub fn export_file(
    g: &Graph,
    params: Option<&Params>,
    path: impl AsRef<Path>,
) -> crate::Result<()> {
    let path = path.as_ref();
    let bytes = export_bytes(g, params)?;
    std::fs::write(path, bytes).map_err(|e| CompileError::io(path, e))
}

/// Resolve a CLI model argument: a zoo name, a `.onnx` model, or a
/// frozen-graph `.json` file.
///
/// Zoo names build at the requested square `input` resolution; file
/// paths carry their own input geometry, so `input` is ignored for
/// them. `.onnx` files also carry parameters ([`Imported::params`]);
/// the other two forms return `None` and callers fall back to the
/// seeded-random parameter convention.
pub fn resolve(name_or_path: &str, input: usize) -> crate::Result<(Graph, Option<Params>)> {
    if let Some(g) = crate::zoo::by_name(name_or_path, input) {
        return Ok((g, None));
    }
    let path = Path::new(name_or_path);
    match path.extension().and_then(|e| e.to_str()) {
        Some("onnx") => {
            let imp = import_file(path)?;
            Ok((imp.graph, Some(imp.params)))
        }
        Some("json") => Ok((crate::serialize::load_frozen(path)?, None)),
        _ => Err(CompileError::unknown_model(name_or_path)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_prefers_zoo_names() {
        let (g, p) = resolve("tinynet", 16).unwrap();
        assert_eq!(g.name, "TinyNet-SE");
        assert!(p.is_none());
    }

    #[test]
    fn resolve_rejects_unknown_names_with_the_typed_error() {
        let e = resolve("not-a-model", 32).unwrap_err();
        assert!(matches!(e, CompileError::UnknownModel { .. }), "{e}");
    }

    #[test]
    fn resolve_surfaces_io_errors_for_missing_files() {
        let e = resolve("/nonexistent/model.onnx", 32).unwrap_err();
        assert!(matches!(e, CompileError::Io { .. }), "{e}");
    }
}
